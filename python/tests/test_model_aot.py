"""L2/AOT tests: the lowered HLO text is parseable, self-consistent, and the
jitted model matches the oracle."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from compile import model
from compile.kernels.ref import triangle_count_ref


def test_model_matches_ref():
    rng = np.random.default_rng(3)
    m = np.triu((rng.random((128, 128)) < 0.1).astype(np.float32), k=1)
    (got,) = model.triangle_count(jnp.asarray(m))
    assert int(got) == int(triangle_count_ref(jnp.asarray(m)))


def test_model_output_is_f64_scalar():
    m = jnp.zeros((128, 128), jnp.float32)
    (out,) = model.triangle_count(m)
    assert out.dtype == jnp.float64
    assert out.shape == ()


@pytest.mark.parametrize("n", [128, 256])
def test_lowering_produces_hlo_text(n):
    text = model.lower_to_hlo_text(model.triangle_count, n)
    assert text.startswith("HloModule"), text[:80]
    # The entry computation must consume an f32[n,n] parameter and return a
    # tuple containing an f64 scalar.
    assert f"f32[{n},{n}]" in text
    assert "f64[]" in text
    # No Mosaic custom-calls: interpret=True must have lowered to plain HLO.
    assert "tpu_custom_call" not in text
    assert "mosaic" not in text.lower()


def test_hlo_text_is_deterministic():
    a = model.lower_to_hlo_text(model.triangle_count, 128)
    b = model.lower_to_hlo_text(model.triangle_count, 128)
    assert a == b


def test_aot_writes_artifacts(tmp_path):
    import subprocess
    import sys

    out = tmp_path / "artifacts"
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--sizes", "128"],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert r.returncode == 0, r.stderr
    path = out / "triangle_count_128.hlo.txt"
    assert path.exists()
    assert path.read_text().startswith("HloModule")
