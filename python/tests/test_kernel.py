"""L1 correctness: the Pallas kernel vs the pure-jnp oracle vs a naive
python counter — the core build-time correctness signal, swept over shapes,
block sizes, densities and seeds with hypothesis."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from compile.kernels.ref import triangle_count_naive, triangle_count_ref
from compile.kernels.triangle import triangle_count_pallas, triangle_count_tiles


def oriented_matrix(n: int, density: float, seed: int) -> np.ndarray:
    """Random strictly-upper-triangular 0/1 matrix (a valid ≺-oriented
    adjacency of some graph)."""
    rng = np.random.default_rng(seed)
    m = (rng.random((n, n)) < density).astype(np.float32)
    return np.triu(m, k=1)


def test_empty_matrix():
    m = np.zeros((128, 128), np.float32)
    assert int(triangle_count_pallas(jnp.asarray(m))) == 0


def test_complete_graph_k128():
    # K_128 as an oriented matrix: strictly upper triangular ones.
    m = np.triu(np.ones((128, 128), np.float32), k=1)
    expect = 128 * 127 * 126 // 6
    assert int(triangle_count_pallas(jnp.asarray(m))) == expect
    assert int(triangle_count_ref(jnp.asarray(m))) == expect


def test_single_triangle():
    m = np.zeros((128, 128), np.float32)
    m[3, 10] = m[10, 77] = m[3, 77] = 1.0
    assert int(triangle_count_pallas(jnp.asarray(m))) == 1


def test_multiblock_grid():
    # 256 with block 128 → 2x2x2 grid: exercises the K accumulation loop.
    m = np.triu(np.ones((256, 256), np.float32), k=1)
    expect = 256 * 255 * 254 // 6
    assert int(triangle_count_pallas(jnp.asarray(m), block=128)) == expect


@pytest.mark.parametrize("block", [32, 64, 128])
def test_block_size_invariance(block):
    m = oriented_matrix(256, 0.05, seed=1)
    ref = int(triangle_count_ref(jnp.asarray(m)))
    got = int(triangle_count_pallas(jnp.asarray(m), block=block))
    assert got == ref


@settings(max_examples=25, deadline=None)
@given(
    nb=st.integers(min_value=1, max_value=3),
    density=st.floats(min_value=0.0, max_value=0.4),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_kernel_matches_ref_hypothesis(nb, density, seed):
    """Pallas == jnp-oracle across shapes/densities/seeds (block 32 keeps
    interpret-mode fast; block-size invariance is covered separately)."""
    n = 32 * nb
    m = jnp.asarray(oriented_matrix(n, density, seed))
    assert int(triangle_count_pallas(m, block=32)) == int(triangle_count_ref(m))


@settings(max_examples=10, deadline=None)
@given(
    density=st.floats(min_value=0.0, max_value=0.6),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_ref_matches_naive_hypothesis(density, seed):
    """jnp-oracle == plain-python counter on small matrices (independent
    implementations)."""
    m = oriented_matrix(24, density, seed)
    # Pad to kernel-friendly 32 for the pallas path.
    p = np.zeros((32, 32), np.float32)
    p[:24, :24] = m
    naive = triangle_count_naive(m)
    assert int(triangle_count_ref(jnp.asarray(m))) == naive
    assert int(triangle_count_pallas(jnp.asarray(p), block=32)) == naive


def test_tiles_sum_to_total():
    m = jnp.asarray(oriented_matrix(256, 0.1, seed=7))
    tiles = triangle_count_tiles(m, block=64)
    assert tiles.shape == (4, 4)
    assert int(jnp.sum(tiles.astype(jnp.float64))) == int(triangle_count_ref(m))


def test_f32_exactness_bound():
    # Worst-case density at the largest export size: every per-tile partial
    # must be < 2^24 so the f32 accumulation is exact.
    n, block = 512, 128
    m = np.triu(np.ones((n, n), np.float32), k=1)
    tiles = np.asarray(triangle_count_tiles(jnp.asarray(m), block=block))
    assert tiles.max() < 2**24, f"tile partial {tiles.max()} overflows f32 exactness"
    expect = n * (n - 1) * (n - 2) // 6
    assert int(tiles.astype(np.float64).sum()) == expect
