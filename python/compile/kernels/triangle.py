"""L1 — Pallas blocked dense triangle-count kernel.

TPU adaptation of the paper's compute hot-spot (DESIGN.md §Hardware-
Adaptation): the sorted-list intersection ``|N_v ∩ N_u|`` of the CPU/MPI
algorithm becomes, on a dense 0/1 oriented adjacency block ``L``, the fused
matmul + mask + reduce

    T = sum((L @ L) * L)

which is exactly what the MXU systolic array wants.  The kernel tiles the
``(I, J, K)`` contraction over ``B x B`` VMEM blocks:

* grid ``(N/B, N/B, N/B)``; step ``(i, j, k)`` loads ``L[i,k]`` and
  ``L[k,j]`` (the two matmul operands) plus ``L[i,j]`` (the mask tile);
* a VMEM scratch accumulator carries the partial ``(L@L)[i,j]`` across the
  ``k`` steps (double-buffered HBM->VMEM pipelining is Pallas's default
  behaviour for sequential grid axes);
* on the last ``k`` step the accumulated tile is masked by ``L[i,j]``,
  reduced, and accumulated into a per-``(i,j)`` partial-sum output.

The host-side wrapper sums the ``(N/B)²`` f32 partials in f64.

Exactness: every ``acc`` entry is a count ``<= N``; the masked per-tile sum
is ``<= B*B*N`` (= 2^23 for B=128, N=512) — below 2^24, so f32 arithmetic
is exact; the final f64 tree-sum of partials is exact far beyond any count
representable here.

VMEM/MXU estimate (B = 128, f32): 4 input/scratch tiles x 64 KiB = 256 KiB
of VMEM (1.6% of 16 MiB — double-buffering and larger B both fit easily);
the inner op is a 128x128x128 MXU matmul with one VPU multiply + reduce —
compute intensity identical to a standard blocked matmul, so the roofline
ratio tracks XLA's own GEMM (see DESIGN.md §Perf).

``interpret=True`` everywhere: the CPU PJRT backend cannot run Mosaic
custom-calls; interpret-mode lowers to plain HLO, which is what the Rust
runtime loads.  On a real TPU the same ``pallas_call`` compiles natively.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, y_ref, m_ref, o_ref, acc_ref, *, nk: int):
    """One (i, j, k) grid step.

    x_ref: L[i·B:(i+1)·B, k·B:(k+1)·B]   (matmul LHS tile)
    y_ref: L[k·B:(k+1)·B, j·B:(j+1)·B]   (matmul RHS tile)
    m_ref: L[i·B:(i+1)·B, j·B:(j+1)·B]   (mask tile)
    o_ref: per-(i,j) partial sum (1x1)
    acc_ref: VMEM scratch, B x B accumulator across k
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU: B x B x B matmul accumulated in f32.
    acc_ref[...] += jnp.dot(x_ref[...], y_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finish():
        # VPU: mask by the adjacency tile and reduce to one scalar.
        o_ref[0, 0] = jnp.sum(acc_ref[...] * m_ref[...])


@functools.partial(jax.jit, static_argnames=("block",))
def triangle_count_tiles(mat: jnp.ndarray, block: int = 128) -> jnp.ndarray:
    """Per-(i,j)-tile partial triangle counts, shape (N/B, N/B), f32.

    ``mat`` must be square with side divisible by ``block``.
    """
    n = mat.shape[0]
    assert mat.shape == (n, n), f"square matrix required, got {mat.shape}"
    assert n % block == 0, f"N={n} not divisible by block={block}"
    nb = n // block
    grid = (nb, nb, nb)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j, k: (i, k)),  # LHS
            pl.BlockSpec((block, block), lambda i, j, k: (k, j)),  # RHS
            pl.BlockSpec((block, block), lambda i, j, k: (i, j)),  # mask
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nb, nb), jnp.float32),
        scratch_shapes=[pltpu_scratch(block)],
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(mat, mat, mat)


def pltpu_scratch(block: int):
    """VMEM scratch accumulator spec (API differs across jax versions)."""
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM((block, block), jnp.float32)
    except Exception:  # pragma: no cover - fallback for non-tpu pallas builds
        return pl.ANY((block, block), jnp.float32)


def triangle_count_pallas(mat: jnp.ndarray, block: int = 128) -> jnp.ndarray:
    """Full dense triangle count: Pallas tiles + exact f64 tile reduction."""
    tiles = triangle_count_tiles(mat, block=block)
    return jnp.sum(tiles.astype(jnp.float64))
