"""Pure-jnp oracle for the dense triangle count.

Given a 0/1 oriented adjacency matrix ``L`` (edge ``a -> b`` iff ``a`` precedes
``b`` in the degree ordering), the number of triangles whose three vertices
all lie in the block is::

    T = sum((L @ L) * L)

because ``(L @ L)[a, c]`` counts the 2-paths ``a -> b -> c`` and the mask keeps
those closed by the edge ``a -> c``; with a total order every triangle appears
exactly once.  The reduction is performed in float64 so the result is exact for
every supported block size (see kernels/triangle.py for the error analysis).
"""

import jax.numpy as jnp


def triangle_count_ref(mat: jnp.ndarray) -> jnp.ndarray:
    """Exact dense triangle count of a 0/1 oriented adjacency matrix."""
    paths = jnp.matmul(mat, mat)  # f32: entries <= N < 2**24, exact
    closed = paths * mat
    return jnp.sum(closed.astype(jnp.float64))


def triangle_count_naive(mat) -> int:
    """Plain-python O(N^3) cross-check used only in tests."""
    import numpy as np

    m = np.asarray(mat)
    n = m.shape[0]
    t = 0
    for a in range(n):
        for b in range(n):
            if m[a, b]:
                t += int((m[a, :] * m[:, b]).sum())
    return t
