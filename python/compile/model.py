"""L2 — the JAX compute graph around the L1 Pallas kernel.

The "model" of this systems paper is the dense-core triangle counter: a
blocked ``sum((L @ L) * L)`` over a 0/1 oriented adjacency matrix, with the
per-tile work done by the Pallas kernel (kernels/triangle.py) and the exact
f64 tile reduction done here.  ``aot.py`` lowers :func:`triangle_count` once
per supported block size to HLO text; the Rust runtime executes it on the
request path (python never is).
"""

import jax
import jax.numpy as jnp

from compile.kernels.triangle import triangle_count_tiles

#: Matrix sizes the AOT pipeline exports. 512 is the default dense-core
#: size; 128/256 serve smaller graphs. Per-tile f32 partials stay exact
#: (< 2^24) for all of these (see kernels/triangle.py).
EXPORT_SIZES = (128, 256, 512)

#: Pallas tile edge. 128 = one MXU-aligned f32 tile.
BLOCK = 128


def triangle_count(mat: jnp.ndarray) -> tuple[jnp.ndarray]:
    """Count triangles in the dense 0/1 oriented adjacency ``mat``.

    Returns a 1-tuple (lowered with ``return_tuple=True``) of an f64 scalar;
    integral for every valid 0/1 input of supported size.
    """
    n = mat.shape[0]
    block = min(BLOCK, n)
    tiles = triangle_count_tiles(mat, block=block)
    return (jnp.sum(tiles.astype(jnp.float64)),)


def triangle_count_ref_model(mat: jnp.ndarray) -> tuple[jnp.ndarray]:
    """Reference L2 graph using the pure-jnp oracle (compiled for A/B
    validation of the AOT pipeline itself)."""
    from compile.kernels.ref import triangle_count_ref

    return (triangle_count_ref(mat),)


def lower_to_hlo_text(fn, n: int) -> str:
    """Lower ``fn`` over an (n, n) f32 input to HLO text.

    HLO *text* (not ``HloModuleProto.serialize``) is the interchange format:
    jax >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 (the
    version the published ``xla`` rust crate binds) rejects; the text parser
    reassigns ids (see /opt/xla-example/README.md).
    """
    from jax._src.lib import xla_client as xc

    spec = jax.ShapeDtypeStruct((n, n), jnp.float32)
    lowered = jax.jit(fn).lower(spec)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()
