"""AOT entry point: lower the L2 model to HLO text artifacts.

Run via ``make artifacts`` (or ``cd python && python -m compile.aot``).
Writes ``artifacts/triangle_count_<N>.hlo.txt`` for each supported size.
Python runs only here, at build time; the Rust binary is self-contained
afterwards.
"""

import argparse
import os
import sys

# Force float64 support before jax initializes (exact tile reduction).
os.environ.setdefault("JAX_ENABLE_X64", "true")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

from compile import model  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    ap.add_argument(
        "--sizes",
        default=",".join(str(s) for s in model.EXPORT_SIZES),
        help="comma-separated matrix sizes to export",
    )
    ap.add_argument(
        "--out",
        default=None,
        help="(compat) also write the largest artifact to this exact path",
    )
    args = ap.parse_args()

    sizes = [int(s) for s in args.sizes.split(",") if s]
    os.makedirs(args.out_dir, exist_ok=True)
    last_path = None
    for n in sizes:
        text = model.lower_to_hlo_text(model.triangle_count, n)
        path = os.path.join(args.out_dir, f"triangle_count_{n}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")
        last_path = path
    if args.out and last_path:
        with open(last_path) as src, open(args.out, "w") as dst:
            dst.write(src.read())
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
