//! END-TO-END driver — proves all layers compose on a realistic workload.
//!
//! Pipeline: generate a ~1M-node / ~14M-edge skewed social network (RMAT) →
//! build CSR → ≺-orient → cost-balanced partitioning → run the paper's two
//! algorithms on the real threaded message-passing runtime → run the hybrid
//! counter through the **AOT XLA/PJRT artifact** (L1 Pallas kernel inside)
//! → cross-check every count for exact equality → report the paper's
//! headline metrics (memory ratio, message economics, load balance) plus a
//! virtual-time P=200 projection. Results are recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example e2e_pipeline`
//! (≈ 1-2 minutes; set E2E_SCALE=small for a 10× smaller run.)

use std::sync::Arc;
use std::time::Instant;

use tricount::adj::HubThreshold;
use tricount::algo::{dynamic_lb, surrogate};
use tricount::config::CostFn;
use tricount::gen::rng::Rng;
use tricount::graph::ordering::Oriented;
use tricount::partition::balance::balanced_ranges;
use tricount::partition::cost::{cost_vector, prefix_sums};
use tricount::partition::{nonoverlap, overlap};
use tricount::runtime::engine::Engine;
use tricount::seq::node_iterator;
use tricount::sim;
use tricount::tensor::hybrid;

fn main() -> anyhow::Result<()> {
    let small = std::env::var("E2E_SCALE").map(|s| s == "small").unwrap_or(false);
    let (scale, ef) = if small { (17u32, 14usize) } else { (20u32, 14usize) };

    // ---- 1. Workload ------------------------------------------------------
    let t0 = Instant::now();
    let g = tricount::gen::rmat::rmat(scale, ef, Default::default(), &mut Rng::seeded(0xE2E));
    let stats = tricount::graph::stats::degree_stats(&g);
    println!("[1] workload (RMAT 2^{scale}, ef={ef}): {stats}  [{:.1?}]", t0.elapsed());

    // ---- 2. Orientation ---------------------------------------------------
    let t0 = Instant::now();
    let o = Arc::new(Oriented::from_graph(&g));
    println!("[2] ≺-oriented: {} directed edges, d̂_max={}  [{:.1?}]",
        o.num_edges(),
        (0..g.num_nodes() as u32).map(|v| o.effective_degree(v)).max().unwrap_or(0),
        t0.elapsed());

    // ---- 3. Sequential baseline ------------------------------------------
    let t0 = Instant::now();
    let t_seq = node_iterator::count(&o);
    let seq_time = t0.elapsed();
    println!("[3] sequential (Fig 1): {t_seq} triangles  [{seq_time:.1?}]");

    // ---- 4. Partitioning + memory accounting (paper Table II headline) ----
    let p = 8usize;
    let prefix = prefix_sums(&cost_vector(&o, CostFn::SurrogateNew));
    let ranges = balanced_ranges(&prefix, p);
    let non_mb = nonoverlap::partition_sizes(&o, &ranges).iter().map(|s| s.mb()).fold(0.0f64, f64::max);
    let over_mb = overlap::overlap_sizes(&g, &o, &ranges).iter().map(|s| s.mb()).fold(0.0f64, f64::max);
    println!("[4] largest partition @P={p}: non-overlap {non_mb:.1}MB vs PATRIC-overlap {over_mb:.1}MB ({:.1}x)", over_mb / non_mb);

    // ---- 5. §IV surrogate algorithm on the real message-passing runtime ---
    //        (ranks hold materialized partitions; residency is measured and
    //        must equal the Table-II prediction exactly)
    let t0 = Instant::now();
    let s = surrogate::run(&o, &ranges, HubThreshold::Auto)?;
    let st = s.metrics.totals();
    assert_eq!(s.metrics.partition_accounting_divergence(), None, "mem accounting diverged");
    println!(
        "[5] surrogate (threads, P={p}): {} triangles, {} msgs, {:.1}MB moved, imbalance {:.2}, largest rank {:.1}MB of G (== prediction)  [{:.1?}]",
        s.triangles,
        st.messages_sent,
        st.bytes_sent as f64 / 1e6,
        s.metrics.imbalance(),
        s.metrics.max_partition_bytes() as f64 / 1e6,
        t0.elapsed()
    );

    // ---- 6. §V dynamic load balancing on the real runtime -----------------
    let t0 = Instant::now();
    let d = dynamic_lb::run(&o, p, dynamic_lb::Options::default())?;
    println!(
        "[6] dynamic-LB (threads, P={p}): {} triangles, imbalance {:.2}  [{:.1?}]",
        d.triangles,
        d.metrics.imbalance(),
        t0.elapsed()
    );

    // ---- 7. Hybrid dense-core through the XLA/PJRT artifact ---------------
    let engine = Engine::cpu()?;
    let t0 = Instant::now();
    let h = hybrid::count_with_engine(&o, &engine, "artifacts", 0)?;
    println!(
        "[7] hybrid (XLA {} block, core {} nodes, {} edges offloaded): {} = {} dense + {} sparse  [{:.1?}]",
        h.block, h.core_size, h.offloaded_edges, h.triangles, h.dense_triangles, h.sparse_triangles,
        t0.elapsed()
    );

    // ---- 8. Cross-check ----------------------------------------------------
    assert_eq!(t_seq, s.triangles, "surrogate mismatch");
    assert_eq!(t_seq, d.triangles, "dynamic-LB mismatch");
    assert_eq!(t_seq, h.triangles, "hybrid/XLA mismatch");
    println!("[8] all counters agree exactly ✓");

    // ---- 9. Virtual-time projection at the paper's P=200 ------------------
    let model = sim::calibrate::calibrated();
    let sur = sim::space_efficient::simulate_balanced(
        &o, 200, CostFn::SurrogateNew, sim::space_efficient::Scheme::Surrogate, &model);
    let dir = sim::space_efficient::simulate_balanced(
        &o, 200, CostFn::SurrogateNew, sim::space_efficient::Scheme::Direct, &model);
    let pat = sim::space_efficient::simulate_patric_balanced(&o, 200, CostFn::PatricBest, &model);
    let dyn200 = sim::dynamic::simulate(
        &o, 200, CostFn::Degree, sim::dynamic::SimGranularity::Shrinking, &model);
    println!(
        "[9] virtual P=200 (α={:.2}ns): patric {:.0}ms | direct {:.0}ms | surrogate {:.0}ms | dynamic {:.0}ms (speedup {:.0})",
        model.alpha_ns,
        pat.makespan_ns / 1e6,
        dir.makespan_ns / 1e6,
        sur.makespan_ns / 1e6,
        dyn200.makespan_ns / 1e6,
        dyn200.speedup()
    );
    println!("e2e pipeline complete ✓ (record in EXPERIMENTS.md)");
    Ok(())
}
