//! The paper's core motivation, measured: on networks with large degrees,
//! PATRIC's overlapping partitions blow up while non-overlapping partitions
//! stay at ~m/P — and the surrogate scheme keeps communication linear.
//!
//! Sweeps degree and skew, printing the partition-memory ratio and the
//! message economics of surrogate vs direct.
//!
//! Run: `cargo run --release --example skewed_degrees`

use tricount::adj::HubThreshold;
use tricount::algo::{direct, surrogate};
use tricount::gen::rng::Rng;
use tricount::graph::ordering::Oriented;
use tricount::partition::balance::balanced_ranges;
use tricount::partition::cost::prefix_sums;
use tricount::partition::nonoverlap::partition_sizes;
use tricount::partition::overlap::overlap_sizes;

fn main() -> anyhow::Result<()> {
    println!("== partition blow-up vs average degree (PA(30K, d), P = 32) ==");
    println!("{:>4}  {:>12}  {:>12}  {:>7}", "d", "non-overlap", "overlap", "ratio");
    for d in [10, 20, 40, 80] {
        let g = tricount::gen::pa::preferential_attachment(30_000, d, &mut Rng::seeded(11));
        let o = Oriented::from_graph(&g);
        let edge_costs: Vec<u64> =
            (0..o.num_nodes() as u32).map(|v| o.effective_degree(v) as u64).collect();
        let ranges = balanced_ranges(&prefix_sums(&edge_costs), 32);
        let non = partition_sizes(&o, &ranges).iter().map(|s| s.mb()).fold(0.0f64, f64::max);
        let over = overlap_sizes(&g, &o, &ranges).iter().map(|s| s.mb()).fold(0.0f64, f64::max);
        println!("{d:>4}  {non:>10.2}MB  {over:>10.2}MB  {:>6.1}x", over / non);
    }

    println!("\n== worst case: one O(n)-degree hub (star + noise) ==");
    // §III: "consider a node v with degree n-1 — the partition containing v
    // will be equal to the whole network."
    let n = 20_000u32;
    let mut edges: Vec<(u32, u32)> = (1..n).map(|v| (0, v)).collect();
    let mut rng = Rng::seeded(5);
    for _ in 0..(n as usize * 4) {
        edges.push((rng.below(n as u64) as u32, rng.below(n as u64) as u32));
    }
    let g = tricount::graph::builder::from_edge_list(n as usize, edges)?;
    let o = Oriented::from_graph(&g);
    let edge_costs: Vec<u64> =
        (0..o.num_nodes() as u32).map(|v| o.effective_degree(v) as u64).collect();
    let ranges = balanced_ranges(&prefix_sums(&edge_costs), 16);
    let non = partition_sizes(&o, &ranges);
    let over = overlap_sizes(&g, &o, &ranges);
    let whole = o.memory_bytes() as f64 / (1024.0 * 1024.0);
    let max_over = over.iter().map(|s| s.mb()).fold(0.0f64, f64::max);
    let max_non = non.iter().map(|s| s.mb()).fold(0.0f64, f64::max);
    println!("whole graph {whole:.2}MB; largest overlap {max_over:.2}MB ({:.0}% of G); largest non-overlap {max_non:.2}MB", 100.0 * max_over / whole);

    println!("\n== message economics: surrogate vs direct (PA(30K, 40), P = 8) ==");
    let g = tricount::gen::pa::preferential_attachment(30_000, 40, &mut Rng::seeded(13));
    let o = Oriented::from_graph(&g);
    let prefix = prefix_sums(
        &tricount::partition::cost::cost_vector(&o, tricount::config::CostFn::SurrogateNew),
    );
    let ranges = balanced_ranges(&prefix, 8);
    let s = surrogate::run(&o, &ranges, HubThreshold::Auto)?;
    let d = direct::run(&o, &ranges, HubThreshold::Auto)?;
    assert_eq!(s.triangles, d.triangles);
    let (st, dt) = (s.metrics.totals(), d.metrics.totals());
    println!(
        "surrogate: {:>9} msgs  {:>8} KiB",
        st.messages_sent,
        st.bytes_sent / 1024
    );
    println!(
        "direct:    {:>9} msgs  {:>8} KiB   ({:.1}x msgs, {:.1}x bytes)",
        dt.messages_sent,
        dt.bytes_sent / 1024,
        dt.messages_sent as f64 / st.messages_sent as f64,
        dt.bytes_sent as f64 / st.bytes_sent as f64
    );
    println!("triangles = {} (both schemes agree ✓)", s.triangles);
    Ok(())
}
