//! Social-network analysis — the §I applications the paper motivates
//! triangle counting with: clustering coefficients, transitivity, and
//! triadic closure, computed on a generated contact network *and* the
//! embedded real network (Zachary's karate club).
//!
//! Run: `cargo run --release --example social_analysis`

use tricount::gen::rng::Rng;
use tricount::graph::classic;
use tricount::graph::ordering::Oriented;
use tricount::graph::stats::degree_stats;
use tricount::seq::{local, node_iterator};

fn analyze(name: &str, g: &tricount::graph::csr::Csr) {
    let o = Oriented::from_graph(g);
    let t = node_iterator::count(&o);
    let tv = local::per_node_counts(&o);
    let cc = local::avg_clustering(g, &tv);
    let trans = local::transitivity(g, t);
    let s = degree_stats(g);
    println!("\n== {name} ==");
    println!("  {s}");
    println!("  triangles           = {t}");
    println!("  avg clustering      = {cc:.4}");
    println!("  transitivity        = {trans:.4}");
    // Top-5 most clustered high-degree nodes (homophily hot-spots).
    let mut nodes: Vec<u32> = (0..g.num_nodes() as u32).filter(|&v| g.degree(v) >= 5).collect();
    nodes.sort_by(|&a, &b| tv[b as usize].cmp(&tv[a as usize]));
    print!("  top triangle nodes  =");
    for &v in nodes.iter().take(5) {
        print!(" {v}(T={}, d={})", tv[v as usize], g.degree(v));
    }
    println!();
}

fn main() -> anyhow::Result<()> {
    // The classic real social network: 45 triangles, heavily clustered.
    analyze("Zachary karate club (real)", &classic::karate());

    // A Miami-like synthetic contact network: even degrees, triangle-rich
    // locality (this is what [26] is in the paper).
    let contact = tricount::gen::geometric::miami_like(100_000, 47, &mut Rng::seeded(3));
    analyze("contact network (Miami-like, n=100K)", &contact);

    // A preferential-attachment web: skewed degrees, lower clustering.
    let pa = tricount::gen::pa::preferential_attachment(100_000, 14, &mut Rng::seeded(4));
    analyze("preferential attachment (n=100K)", &pa);

    // The social-science sanity check (§I): contact networks close
    // triangles far more than degree-matched random attachment.
    let o_c = Oriented::from_graph(&contact);
    let o_p = Oriented::from_graph(&pa);
    let tc = local::transitivity(&contact, node_iterator::count(&o_c));
    let tp = local::transitivity(&pa, node_iterator::count(&o_p));
    println!("\ntriadic closure: contact {tc:.4} vs PA {tp:.4} (expect contact ≫ PA)");
    assert!(tc > tp, "contact networks should close more triangles");

    // Cohesive-subgraph analysis (§I "triangular connectivity"): k-truss on
    // the real karate network and MR-shuffle blow-up on the skewed one.
    let kmax = tricount::seq::truss::max_truss(&classic::karate());
    println!("karate max k-truss = {kmax} (the densest social core)");
    let blow = tricount::baseline::mapreduce::blowup_factor(&pa);
    println!("MapReduce 2-path blow-up on the PA graph: {blow:.1}x the edge set");

    // Approximate counters vs the exact kernel on the contact network.
    let mut rng = Rng::seeded(99);
    let exact = node_iterator::count(&o_c) as f64;
    let est = tricount::approx::wedge_sampling(&contact, 200_000, &mut rng);
    println!(
        "wedge-sampling estimate {est:.0} vs exact {exact:.0} ({:+.2}% error)",
        100.0 * (est / exact - 1.0)
    );
    Ok(())
}
