//! Quickstart: generate a small social-style network, count its triangles
//! four ways (sequential, surrogate, dynamic-LB, hybrid reference), and
//! print the cross-checked result.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use tricount::adj::HubThreshold;
use tricount::algo::{dynamic_lb, surrogate};
use tricount::config::CostFn;
use tricount::gen::rng::Rng;
use tricount::graph::ordering::Oriented;
use tricount::partition::balance::balanced_ranges;
use tricount::partition::cost::{cost_vector, prefix_sums};
use tricount::seq::node_iterator;
use tricount::tensor::hybrid;

fn main() -> anyhow::Result<()> {
    // 1. A 50K-node preferential-attachment network (power-law degrees).
    let g = tricount::gen::pa::preferential_attachment(50_000, 16, &mut Rng::seeded(7));
    let o = Arc::new(Oriented::from_graph(&g));
    println!(
        "network: n={} m={} d̄={:.1} d_max={}",
        g.num_nodes(),
        g.num_edges(),
        g.avg_degree(),
        g.max_degree()
    );

    // 2. Sequential state-of-the-art kernel (paper Fig 1).
    let t0 = std::time::Instant::now();
    let seq = node_iterator::count(&o);
    println!("sequential:  {seq} triangles in {:.2?}", t0.elapsed());

    // 3. §IV space-efficient algorithm, surrogate scheme, P = 8 ranks —
    //    each rank holds only its materialized partition (measured below).
    let prefix = prefix_sums(&cost_vector(&o, CostFn::SurrogateNew));
    let ranges = balanced_ranges(&prefix, 8);
    let t0 = std::time::Instant::now();
    let s = surrogate::run(&o, &ranges, HubThreshold::Auto)?;
    let totals = s.metrics.totals();
    println!(
        "surrogate:   {} triangles in {:.2?}  (P=8, {} data msgs, {} KiB, largest rank holds {} KiB of G)",
        s.triangles,
        t0.elapsed(),
        totals.messages_sent,
        totals.bytes_sent / 1024,
        s.metrics.max_partition_bytes() / 1024
    );
    assert_eq!(s.metrics.partition_accounting_divergence(), None);

    // 4. §V dynamic load balancing, P = 8 (1 coordinator + 7 workers).
    let t0 = std::time::Instant::now();
    let d = dynamic_lb::run(&o, 8, dynamic_lb::Options::default())?;
    println!(
        "dynamic-LB:  {} triangles in {:.2?}  (imbalance {:.3})",
        d.triangles,
        t0.elapsed(),
        d.metrics.imbalance()
    );

    // 5. Hybrid dense-core split (rust reference path; `--example
    //    e2e_pipeline` exercises the XLA artifact path).
    let h = hybrid::count_reference(&o, 512);
    println!(
        "hybrid:      {} triangles  ({} in the {}-node dense core, {} sparse)",
        h.triangles, h.dense_triangles, h.core_size, h.sparse_triangles
    );

    assert_eq!(seq, s.triangles);
    assert_eq!(seq, d.triangles);
    assert_eq!(seq, h.triangles);
    println!("all four counters agree ✓");
    Ok(())
}
