//! One bench target per paper table/figure: each runs the corresponding
//! experiment driver from `tricount::exp` (quick workloads unless
//! `TRICOUNT_BENCH_FULL=1`) and reports wall time, regenerating the
//! paper-shaped rows as a side effect. `cargo bench --offline paper`.
//!
//! Full-scale results for EXPERIMENTS.md come from `tricount exp --id all`.

use std::time::Instant;

fn main() {
    let full = std::env::var("TRICOUNT_BENCH_FULL").map(|s| s == "1").unwrap_or(false);
    let opts = tricount::exp::Options {
        scale: 1.0,
        out_dir: Some("results/bench".into()),
        quick: !full,
    };
    println!(
        "paper benches ({} mode) — one per table/figure\n",
        if full { "FULL" } else { "quick" }
    );
    let mut failures = 0;
    for e in tricount::exp::registry() {
        let t0 = Instant::now();
        match (e.run)(&opts) {
            Ok(report) => {
                println!("bench_{:<8} {:>9.2?}   ({} rows, {})", e.id, t0.elapsed(), report.rows.len(), e.paper_ref);
            }
            Err(err) => {
                failures += 1;
                println!("bench_{:<8} FAILED: {err}", e.id);
            }
        }
    }
    if failures > 0 {
        std::process::exit(1);
    }
}
