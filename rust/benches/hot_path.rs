//! Micro-benchmarks of the hot paths (criterion is unavailable offline —
//! this is a self-contained harness: warmup + N timed reps, reporting
//! median and throughput). Run with `cargo bench --offline hot_path`.

use std::time::Instant;

use tricount::gen::rng::Rng;
use tricount::graph::ordering::Oriented;
use tricount::intersect;
use tricount::seq::node_iterator;

fn bench<F: FnMut() -> u64>(name: &str, units: u64, unit_name: &str, mut f: F) {
    // Warmup.
    let mut sink = 0u64;
    sink = sink.wrapping_add(f());
    // Timed reps.
    let mut samples = Vec::new();
    let reps = 5;
    for _ in 0..reps {
        let t0 = Instant::now();
        sink = sink.wrapping_add(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = samples[reps / 2];
    println!(
        "{name:<44} {:>10.3} ms   {:>10.1} M{unit_name}/s",
        med * 1e3,
        units as f64 / med / 1e6
    );
    std::hint::black_box(sink);
}

fn sorted_list(rng: &mut Rng, len: usize, universe: u32) -> Vec<u32> {
    let mut v: Vec<u32> = (0..len).map(|_| rng.next_u32() % universe).collect();
    v.sort_unstable();
    v.dedup();
    v
}

fn main() {
    println!("== intersection kernels ==");
    let mut rng = Rng::seeded(1);
    let a = sorted_list(&mut rng, 10_000, 1_000_000);
    let b = sorted_list(&mut rng, 10_000, 1_000_000);
    let units = (a.len() + b.len()) as u64 * 200;
    bench("merge balanced 10K∩10K ×200", units, "elem", || {
        let mut c = 0;
        for _ in 0..200 {
            intersect::count_merge(&a, &b, &mut c);
        }
        c
    });
    bench("adaptive balanced 10K∩10K ×200", units, "elem", || {
        let mut c = 0;
        for _ in 0..200 {
            intersect::count_adaptive(&a, &b, &mut c);
        }
        c
    });
    // The SWAR tier the hybrid dispatch now prefers on balanced list×list
    // pairs — same inputs as the two rows above, so the win is directly
    // readable from the table.
    bench("simd-blocked balanced 10K∩10K ×200", units, "elem", || {
        let mut c = 0;
        for _ in 0..200 {
            intersect::count_simd_blocked(&a, &b, &mut c);
        }
        c
    });

    let small = sorted_list(&mut rng, 64, 1_000_000);
    let units = (small.len() + b.len()) as u64 * 2000;
    bench("merge skewed 64∩10K ×2000", units, "elem", || {
        let mut c = 0;
        for _ in 0..2000 {
            intersect::count_merge(&small, &b, &mut c);
        }
        c
    });
    bench("gallop skewed 64∩10K ×2000", units, "elem", || {
        let mut c = 0;
        for _ in 0..2000 {
            intersect::count_galloping(&small, &b, &mut c);
        }
        c
    });
    bench("adaptive skewed 64∩10K ×2000", units, "elem", || {
        let mut c = 0;
        for _ in 0..2000 {
            intersect::count_adaptive(&small, &b, &mut c);
        }
        c
    });

    println!("\n== obs overhead: hybrid dispatch ± per-rank kernel sink ==");
    {
        use tricount::adj::{self, stats, NeighborView};
        let a = sorted_list(&mut rng, 10_000, 1_000_000);
        let b = sorted_list(&mut rng, 10_000, 1_000_000);
        let units = (a.len() + b.len()) as u64 * 200;
        let body = |a: &[u32], b: &[u32]| {
            let mut t = 0;
            for _ in 0..200 {
                adj::intersect_count(NeighborView::sorted(a), NeighborView::sorted(b), &mut t);
            }
            t
        };
        bench("dispatch 10K∩10K ×200 (global ctrs)", units, "elem", || body(&a, &b));
        let sink = std::sync::Arc::new(stats::RankKernelCounters::default());
        let _scope = stats::install_rank(sink);
        bench("dispatch 10K∩10K ×200 (+rank sink)", units, "elem", || body(&a, &b));
    }

    println!("\n== end-to-end sequential counting ==");
    for (name, g) in [
        ("PA(200K, 16)", tricount::gen::pa::preferential_attachment(200_000, 16, &mut Rng::seeded(2))),
        ("RMAT(2^17, 16)", tricount::gen::rmat::rmat(17, 16, Default::default(), &mut Rng::seeded(3))),
        ("contact(200K, 30)", tricount::gen::geometric::miami_like(200_000, 30, &mut Rng::seeded(4))),
    ] {
        let o = Oriented::from_graph(&g);
        let work: u64 = (0..o.num_nodes() as u32).map(|v| node_iterator::node_work(&o, v)).sum();
        bench(&format!("count {name} (m={})", g.num_edges()), work, "workunit", || {
            node_iterator::count(&o)
        });
    }

    println!("\n== CSR build: radix vs comparison sort ==");
    {
        use tricount::graph::builder::{from_edge_list_sort_baseline, from_edge_list_threads};
        let g = tricount::gen::pa::preferential_attachment(200_000, 32, &mut Rng::seeded(21));
        let edges: Vec<(u32, u32)> = g.edges().collect();
        let (n, m) = (g.num_nodes(), edges.len() as u64);
        // The clone is inside the timed region for every variant, so the
        // comparison stays apples-to-apples.
        bench("build sort-baseline PA(200K,32)", m, "edge", || {
            from_edge_list_sort_baseline(n, edges.clone()).unwrap().num_edges()
        });
        bench("build radix T=1    PA(200K,32)", m, "edge", || {
            from_edge_list_threads(n, edges.clone(), 1).unwrap().num_edges()
        });
        let auto = tricount::par::BuildThreads::Auto.resolve();
        bench(&format!("build radix T={auto} PA(200K,32)"), m, "edge", || {
            from_edge_list_threads(n, edges.clone(), auto).unwrap().num_edges()
        });
    }

    println!("\n== orientation + partitioning ==");
    let g = tricount::gen::pa::preferential_attachment(500_000, 20, &mut Rng::seeded(5));
    bench("orient PA(500K,20) T=1", g.num_edges() * 2, "edge", || {
        Oriented::from_graph(&g).num_edges()
    });
    {
        let auto = tricount::par::BuildThreads::Auto.resolve();
        bench(&format!("orient PA(500K,20) T={auto}"), g.num_edges() * 2, "edge", || {
            Oriented::from_graph_threads(&g, Default::default(), auto).num_edges()
        });
    }
    let o = Oriented::from_graph(&g);
    bench("cost vector (new estimator)", o.num_edges(), "edge", || {
        tricount::partition::cost::cost_vector(&o, tricount::config::CostFn::SurrogateNew)
            .len() as u64
    });
    let costs = tricount::partition::cost::cost_vector(&o, tricount::config::CostFn::SurrogateNew);
    bench("prefix sums + 200 balanced ranges", o.num_nodes() as u64, "node", || {
        let prefix = tricount::partition::cost::prefix_sums(&costs);
        tricount::partition::balance::balanced_ranges(&prefix, 200).len() as u64
    });

    println!("\n== streaming updates (incremental engine) ==");
    {
        use tricount::stream::parallel::{self, StreamOptions};
        use tricount::stream::workload::{edge_stream, StreamSpec};
        // Large-degree PA source; half the edges form the snapshot, the
        // rest arrive as batches. Throughput = updates/s maintained exact.
        let src = tricount::gen::pa::preferential_attachment(100_000, 16, &mut Rng::seeded(11));
        let inserts_spec = StreamSpec {
            base_fraction: 0.5,
            batch_size: 1_000,
            batches: 20,
            delete_fraction: 0.0,
        };
        let mixed_spec = StreamSpec { delete_fraction: 0.3, ..inserts_spec };
        for (tag, spec) in [("inserts", inserts_spec), ("mixed 30% del", mixed_spec)] {
            let w = edge_stream(&src, &spec, &mut Rng::seeded(12));
            // Static count of the snapshot stays outside the timed region:
            // the bench tracks incremental update throughput, not setup.
            let initial = node_iterator::count(&Oriented::from_graph(&w.base));
            for p in [1usize, 4, 8] {
                let name = format!("stream PA(100K,16) {tag} 20×1k P={p}");
                bench(&name, w.updates as u64, "upd", || {
                    parallel::run_with_initial(&w.base, &w.batches, p, StreamOptions::default(), initial)
                        .unwrap()
                        .final_triangles
                });
            }
        }
    }

    println!("\n== XLA dense-core path (requires `make artifacts`) ==");
    match tricount::runtime::artifact::discover("artifacts") {
        Ok(arts) if !arts.is_empty() => {
            let engine = tricount::runtime::engine::Engine::cpu().unwrap();
            for art in &arts {
                let counter = engine.load_dense_counter(&art.path, art.n).unwrap();
                let core = {
                    let g = tricount::graph::classic::complete(art.n.min(256));
                    let o = Oriented::from_graph(&g);
                    let c = tricount::tensor::core_extract::DenseCore::extract(&o, art.n.min(256));
                    tricount::tensor::pack::pack_core(&o, &c, art.n)
                };
                // FLOPs of the blocked matmul: 2·N³ per execution.
                let flops = 2 * (art.n as u64).pow(3);
                bench(&format!("XLA dense count N={}", art.n), flops, "flop", || {
                    counter.count(&core).unwrap()
                });
            }
        }
        _ => println!("  [skipped: no artifacts]"),
    }
}
