//! `hub_kernels` — proof that the `adj/` hybrid hub-bitmap kernels beat
//! the merge kernel in the large-degree regime (same self-contained
//! harness as `hot_path.rs`; criterion is unavailable offline). Run with
//! `cargo bench --offline hub_kernels`.
//!
//! Three sections:
//! 1. micro: list×bitmap probe and bitmap×bitmap word-AND vs merge/gallop
//!    on synthetic hub rows;
//! 2. a PA(100K, 64) hub workload: the actual oriented pairs that involve
//!    a hub row, merge-only vs hybrid dispatch;
//! 3. end-to-end `node_iterator::count` on PA(100K, 64), `off` vs `auto`,
//!    with the kernel-path mix.

use std::time::Instant;

use tricount::adj::bitmap::BitmapRow;
use tricount::adj::{self, HubThreshold, NeighborView};
use tricount::gen::rng::Rng;
use tricount::graph::ordering::Oriented;
use tricount::intersect;
use tricount::seq::node_iterator;
use tricount::VertexId;

fn bench<F: FnMut() -> u64>(name: &str, units: u64, unit_name: &str, mut f: F) -> f64 {
    // Warmup.
    let mut sink = 0u64;
    sink = sink.wrapping_add(f());
    // Timed reps.
    let mut samples = Vec::new();
    let reps = 5;
    for _ in 0..reps {
        let t0 = Instant::now();
        sink = sink.wrapping_add(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = samples[reps / 2];
    println!(
        "{name:<46} {:>10.3} ms   {:>10.1} M{unit_name}/s",
        med * 1e3,
        units as f64 / med / 1e6
    );
    std::hint::black_box(sink);
    med
}

fn sorted_list(rng: &mut Rng, len: usize, universe: u32) -> Vec<VertexId> {
    let mut v: Vec<VertexId> = (0..len).map(|_| rng.next_u32() % universe).collect();
    v.sort_unstable();
    v.dedup();
    v
}

fn main() {
    let mut rng = Rng::seeded(1);

    println!("== micro: hub kernels vs merge ==");
    // A hub row (d̂ = 4096) intersected with small lists (d̂ = 64) — the
    // dominant pair shape in the large-degree regime.
    let hub = sorted_list(&mut rng, 4096, 100_000);
    let hub_row = BitmapRow::from_sorted(&hub);
    let smalls: Vec<Vec<VertexId>> =
        (0..256).map(|_| sorted_list(&mut rng, 64, 100_000)).collect();
    let units: u64 = smalls.iter().map(|s| (s.len() + hub.len()) as u64).sum::<u64>() * 20;
    let t_merge = bench("merge       hub(4096)×small(64) ×256×20", units, "elem", || {
        let mut c = 0;
        for _ in 0..20 {
            for s in &smalls {
                intersect::count_merge(s, &hub, &mut c);
            }
        }
        c
    });
    bench("gallop      hub(4096)×small(64) ×256×20", units, "elem", || {
        let mut c = 0;
        for _ in 0..20 {
            for s in &smalls {
                intersect::count_galloping(s, &hub, &mut c);
            }
        }
        c
    });
    let t_probe = bench("list×bitmap hub(4096)×small(64) ×256×20", units, "elem", || {
        let mut c = 0;
        let hv = NeighborView::hybrid(&hub, Some(&hub_row));
        for _ in 0..20 {
            for s in &smalls {
                adj::intersect_count(hv, NeighborView::sorted(s), &mut c);
            }
        }
        c
    });
    println!("  -> list×bitmap vs merge: {:.1}x", t_merge / t_probe);
    assert!(t_probe < t_merge, "probe must beat merge on hub×small");

    // Dense hub×hub (two 4096-rows in a 64K universe): word-AND territory.
    let ha = sorted_list(&mut rng, 4096, 65_536);
    let hb = sorted_list(&mut rng, 4096, 65_536);
    let (ra, rb) = (BitmapRow::from_sorted(&ha), BitmapRow::from_sorted(&hb));
    let units = (ha.len() + hb.len()) as u64 * 2000;
    let t_merge2 = bench("merge         hub(4096)×hub(4096) ×2000", units, "elem", || {
        let mut c = 0;
        for _ in 0..2000 {
            intersect::count_merge(&ha, &hb, &mut c);
        }
        c
    });
    let t_bb = bench("bitmap×bitmap hub(4096)×hub(4096) ×2000", units, "elem", || {
        let mut c = 0;
        let (va, vb) = (NeighborView::hybrid(&ha, Some(&ra)), NeighborView::hybrid(&hb, Some(&rb)));
        for _ in 0..2000 {
            adj::intersect_count(va, vb, &mut c);
        }
        c
    });
    println!("  -> bitmap×bitmap vs merge: {:.1}x", t_merge2 / t_bb);
    assert!(t_bb < t_merge2, "word-AND must beat merge on dense hub×hub");

    println!("\n== PA(100K, 64) hub workload ==");
    let g = tricount::gen::pa::preferential_attachment(100_000, 64, &mut Rng::seeded(2));
    let mut o = Oriented::from_graph_with(&g, HubThreshold::Auto);
    if o.hub_stats().hubs == 0 {
        // Degenerate draw (auto found nothing): pin the cutoff to the
        // p99.9 of d̂ so the hub-workload section still measures something.
        let mut ds: Vec<usize> =
            (0..o.num_nodes() as u32).map(|v| o.effective_degree(v)).collect();
        ds.sort_unstable_by(|a, b| b.cmp(a));
        let t = ds[o.num_nodes() / 1000].max(1);
        println!("(auto selected no hubs; falling back to fixed d̂ ≥ {t})");
        o = Oriented::from_graph_with(&g, HubThreshold::Fixed(t));
    }
    let stats = o.hub_stats();
    println!(
        "n={} m={} effective threshold={} hubs={} bitmap_kb={}",
        g.num_nodes(),
        g.num_edges(),
        stats.threshold.unwrap_or(0),
        stats.hubs,
        stats.bitmap_bytes / 1024
    );
    // The oriented pairs (v, u∈N_v) where either row is a hub — exactly the
    // pairs the dispatch upgrades.
    let mut pairs: Vec<(VertexId, VertexId)> = Vec::new();
    for v in 0..o.num_nodes() as VertexId {
        let v_hub = o.hub_row(v).is_some();
        for &u in o.nbrs(v) {
            if v_hub || o.hub_row(u).is_some() {
                pairs.push((v, u));
            }
        }
    }
    let units: u64 = pairs
        .iter()
        .map(|&(v, u)| (o.effective_degree(v) + o.effective_degree(u)) as u64)
        .sum();
    println!("hub pairs: {} ({} Melem of merge work)", pairs.len(), units / 1_000_000);
    let t_merge3 = bench("merge kernel   over oriented hub pairs", units, "elem", || {
        let mut c = 0;
        for &(v, u) in &pairs {
            intersect::count_merge(o.nbrs(v), o.nbrs(u), &mut c);
        }
        c
    });
    let t_hyb = bench("hybrid dispatch over oriented hub pairs", units, "elem", || {
        let mut c = 0;
        for &(v, u) in &pairs {
            adj::intersect_count(o.view(v), o.view(u), &mut c);
        }
        c
    });
    println!("  -> hybrid vs merge on oriented hub pairs: {:.2}x", t_merge3 / t_hyb);

    // The *unoriented* rows are where PA hubs really live (degree in the
    // thousands) — the shape the streaming Δ counter and the edge-iterator
    // oracle intersect. Bitmap the 16 heaviest full rows and intersect each
    // with all of its neighbors' rows: list×bitmap probe vs merge.
    let mut by_degree: Vec<VertexId> = (0..g.num_nodes() as VertexId).collect();
    by_degree.sort_unstable_by_key(|&v| std::cmp::Reverse(g.degree(v)));
    let top: Vec<VertexId> = by_degree[..16].to_vec();
    let rows: Vec<BitmapRow> =
        top.iter().map(|&h| BitmapRow::from_sorted(g.neighbors(h))).collect();
    let units: u64 = top
        .iter()
        .map(|&h| {
            g.neighbors(h)
                .iter()
                .map(|&u| (g.degree(u) + g.degree(h)) as u64)
                .sum::<u64>()
        })
        .sum();
    println!(
        "unoriented hubs: top-16 degrees {}..{}",
        g.degree(top[15]),
        g.degree(top[0])
    );
    let t_merge4 = bench("merge       hub full rows × nbr rows", units, "elem", || {
        let mut c = 0;
        for &h in &top {
            let nh = g.neighbors(h);
            for &u in nh {
                intersect::count_merge(g.neighbors(u), nh, &mut c);
            }
        }
        c
    });
    let t_probe4 = bench("list×bitmap hub full rows × nbr rows", units, "elem", || {
        let mut c = 0;
        for (i, &h) in top.iter().enumerate() {
            let hv = NeighborView::hybrid(g.neighbors(h), Some(&rows[i]));
            for &u in g.neighbors(h) {
                adj::intersect_count(NeighborView::sorted(g.neighbors(u)), hv, &mut c);
            }
        }
        c
    });
    println!("  -> list×bitmap vs merge on unoriented hub rows: {:.1}x", t_merge4 / t_probe4);
    assert!(
        t_probe4 < t_merge4,
        "list×bitmap must beat merge on the PA(100K,64) hub rows"
    );

    println!("\n== end-to-end: node_iterator::count on PA(100K, 64) ==");
    let o_off = Oriented::from_graph_with(&g, HubThreshold::Off);
    let work: u64 = (0..o.num_nodes() as u32).map(|v| node_iterator::node_work(&o_off, v)).sum();
    let t_off = bench("count, hub-threshold=off ", work, "workunit", || {
        node_iterator::count(&o_off)
    });
    tricount::adj::stats::reset();
    let t_auto = bench("count, hub-threshold=auto", work, "workunit", || {
        node_iterator::count(&o)
    });
    let k = tricount::adj::stats::snapshot();
    println!(
        "  kernels (auto): list×list={} list×bitmap={} bitmap×bitmap={}",
        k.list_list, k.list_bitmap, k.bitmap_bitmap
    );
    println!("  -> end-to-end auto vs off: {:.2}x", t_off / t_auto);
    assert_eq!(
        node_iterator::count(&o),
        node_iterator::count(&o_off),
        "hybrid and sorted counts must agree"
    );
}
