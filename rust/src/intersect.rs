//! Sorted-set intersection kernels — the compute hot-spot of every
//! algorithm in the paper (`S ← N_v ∩ N_u`, Fig 1 line 9).
//!
//! Four variants:
//! * [`count_merge`] — linear two-pointer merge, `O(|a| + |b|)`; the
//!   paper's assumed kernel.
//! * [`count_galloping`] — exponential search of the longer list,
//!   `O(|a| log |b|)`; wins when lengths are very unbalanced, which is
//!   exactly the "large degrees" regime this paper targets.
//! * [`count_adaptive`] — picks between them by length ratio; the threshold
//!   was tuned by `benches/hot_path.rs` (see EXPERIMENTS.md §Perf).
//! * [`count_simd_blocked`] — SIMD-within-a-register blocked merge: packs
//!   two u32 candidates per u64 word and tests 8 candidate pairs per
//!   iteration with XOR lane-zero checks (stable Rust, no intrinsics, no
//!   new dependencies). Requires strictly sorted duplicate-free inputs —
//!   exactly the CSR row contract. Dispatched by [`crate::adj::view`] on
//!   balanced mid-size list pairs (DESIGN.md §12).
//!
//! These are the **list×list** kernels. Counting drivers no longer call
//! them on raw slices: they intersect through the hybrid dispatch in
//! [`crate::adj::view`], which falls back to [`count_adaptive`] when
//! neither side is a hub bitmap row.

use crate::VertexId;

/// Two-pointer merge intersection count, branchless add/sub stepping.
///
/// Perf note (EXPERIMENTS.md §Perf): a 4-wide run-skipping variant beats
/// this by 1.5-8× on synthetic sparse lists, but on *real* oriented
/// adjacency workloads (short, heavily interleaved lists) it lost 10-30%
/// to branch overhead — this branchless form is the measured winner on
/// PA/RMAT/contact counting end-to-end.
#[inline]
pub fn count_merge(a: &[VertexId], b: &[VertexId], out_count: &mut u64) {
    let (mut i, mut j) = (0usize, 0usize);
    let mut c = 0u64;
    while i < a.len() && j < b.len() {
        let (x, y) = (a[i], b[j]);
        // Branch-light stepping: advance each side on <=/>=.
        c += (x == y) as u64;
        i += (x <= y) as usize;
        j += (y <= x) as usize;
    }
    *out_count += c;
}

/// Galloping (exponential-search) intersection count: for each element of
/// the shorter list, gallop in the remainder of the longer list.
#[inline]
pub fn count_galloping(short: &[VertexId], long: &[VertexId], out_count: &mut u64) {
    debug_assert!(short.len() <= long.len());
    let mut base = 0usize;
    let mut c = 0u64;
    for &x in short {
        if base >= long.len() {
            break;
        }
        // Gallop: find the range (base+lo, base+hi] that brackets x.
        let mut hi = 1usize;
        while base + hi < long.len() && long[base + hi] < x {
            hi <<= 1;
        }
        let lo = base + (hi >> 1);
        let end = (base + hi + 1).min(long.len());
        match long[lo..end].binary_search(&x) {
            Ok(p) => {
                c += 1;
                base = lo + p + 1;
            }
            Err(p) => {
                base = lo + p;
            }
        }
    }
    *out_count += c;
}

/// Length-ratio threshold above which galloping beats merging.
/// Tuned on real counting workloads on this container's CPU: 8 beat 16/64
/// on PA, RMAT and contact networks (see EXPERIMENTS.md §Perf and
/// `tricount exp --id ablation-gallop`).
pub const GALLOP_RATIO: usize = 8;

/// Adaptive intersection count — the production kernel.
#[inline]
pub fn count_adaptive(a: &[VertexId], b: &[VertexId], out_count: &mut u64) {
    let (s, l) = if a.len() <= b.len() { (a, b) } else { (b, a) };
    if s.is_empty() {
        return;
    }
    if l.len() / s.len() >= GALLOP_RATIO {
        count_galloping(s, l, out_count);
    } else {
        count_merge(s, l, out_count);
    }
}

/// Minimum shorter-list length before the blocked SWAR kernel pays off.
/// Below this the blocked loop barely runs (its 2×4 window needs a few
/// iterations to amortize the packing) and the scalar merge's tighter
/// epilogue wins — the same measured-guard philosophy as the 4-wide
/// run-skipping variant retired in EXPERIMENTS.md §Perf.
pub const SIMD_BLOCK_MIN: usize = 16;

#[inline(always)]
fn pack2(lo: VertexId, hi: VertexId) -> u64 {
    (lo as u64) | ((hi as u64) << 32)
}

/// SWAR blocked merge intersection count.
///
/// Compares a 2-wide window of `a` against a 4-wide window of `b` per
/// iteration: the windows are packed into u64 words (two u32 lanes each)
/// and all 8 candidate pairs are tested with four XORs + lane-zero checks,
/// then the window with the smaller maximum advances (both on a tie).
/// The scalar [`count_merge`] finishes the tails.
///
/// **Contract:** both inputs strictly sorted and duplicate-free (the CSR
/// row invariant, `Csr::validate`). Duplicates would be double-counted by
/// the windowed comparison; sortedness is what makes "advance the window
/// with the smaller max" lossless — every future element of the other
/// list is strictly greater than the discarded window's max, so no
/// matching pair is ever skipped.
#[inline]
pub fn count_simd_blocked(a: &[VertexId], b: &[VertexId], out_count: &mut u64) {
    // Orient so the 4-wide window walks the longer list: the wider window
    // advances over more elements per step on the denser side.
    let (a, b) = if a.len() <= b.len() { (a, b) } else { (b, a) };
    let (mut i, mut j) = (0usize, 0usize);
    let mut c = 0u64;
    while i + 2 <= a.len() && j + 4 <= b.len() {
        let (a0, a1) = (a[i], a[i + 1]);
        let (b0, b1, b2, b3) = (b[j], b[j + 1], b[j + 2], b[j + 3]);
        let wa = pack2(a0, a1); // lanes (lo, hi) = (a0, a1)
        let wr = pack2(a1, a0); // swapped lanes
        let wb0 = pack2(b0, b1);
        let wb1 = pack2(b2, b3);
        // z = x ^ y has an all-zero lane exactly where the lanes match, so
        // the four XORs cover all 8 (aᵢ, bⱼ) candidate pairs.
        let z0 = wa ^ wb0; // lo: a0==b0, hi: a1==b1
        let z1 = wr ^ wb0; // lo: a1==b0, hi: a0==b1
        let z2 = wa ^ wb1; // lo: a0==b2, hi: a1==b3
        let z3 = wr ^ wb1; // lo: a1==b2, hi: a0==b3
        c += ((z0 & 0xFFFF_FFFF) == 0) as u64
            + ((z0 >> 32) == 0) as u64
            + ((z1 & 0xFFFF_FFFF) == 0) as u64
            + ((z1 >> 32) == 0) as u64
            + ((z2 & 0xFFFF_FFFF) == 0) as u64
            + ((z2 >> 32) == 0) as u64
            + ((z3 & 0xFFFF_FFFF) == 0) as u64
            + ((z3 >> 32) == 0) as u64;
        // Branchless window advance on max comparison (ties advance both).
        i += 2 * (a1 <= b3) as usize;
        j += 4 * (b3 <= a1) as usize;
    }
    *out_count += c;
    count_merge(&a[i..], &b[j..], out_count);
}

/// Model of what [`count_adaptive`] actually costs, in "element steps":
/// `min + max` for the merge path, `min·(1 + log₂(max/min))` for galloping.
/// This is the list×list term of the hybrid cost model — pairs involving
/// hub bitmap rows are charged by [`crate::adj::intersect_cost`] instead
/// (probe length or word-AND span), which is what the simulators and the
/// `hybrid` estimator use. The paper's estimators model the merge cost
/// `d̂_v + d̂_u`, and the gap between estimate and executed cost is
/// precisely the error that §V's dynamic load balancing exists to absorb.
#[inline]
pub fn adaptive_cost(la: usize, lb: usize) -> u64 {
    let (s, l) = if la <= lb { (la, lb) } else { (lb, la) };
    if s == 0 {
        return 1;
    }
    if l / s >= GALLOP_RATIO {
        let log = (usize::BITS - (l / s).leading_zeros()) as u64;
        s as u64 * (1 + log)
    } else {
        (s + l) as u64
    }
}

/// Materializing merge intersection into a caller-owned buffer (appends,
/// ascending id order) — shared by [`intersect_vec`] and the list×list arm
/// of [`crate::adj::intersect_into`].
pub fn merge_into(a: &[VertexId], b: &[VertexId], out: &mut Vec<VertexId>) {
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        if a[i] == b[j] {
            out.push(a[i]);
            i += 1;
            j += 1;
        } else if a[i] < b[j] {
            i += 1;
        } else {
            j += 1;
        }
    }
}

/// Materializing intersection (tests, per-node triangle listings).
pub fn intersect_vec(a: &[VertexId], b: &[VertexId]) -> Vec<VertexId> {
    let mut out = Vec::new();
    merge_into(a, b, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_all(a: &[VertexId], b: &[VertexId], expect: u64) {
        let mut c = 0;
        count_merge(a, b, &mut c);
        assert_eq!(c, expect, "merge {a:?} ∩ {b:?}");
        let mut c = 0;
        let (s, l) = if a.len() <= b.len() { (a, b) } else { (b, a) };
        count_galloping(s, l, &mut c);
        assert_eq!(c, expect, "gallop {a:?} ∩ {b:?}");
        let mut c = 0;
        count_adaptive(a, b, &mut c);
        assert_eq!(c, expect, "adaptive {a:?} ∩ {b:?}");
        let mut c = 0;
        count_simd_blocked(a, b, &mut c);
        assert_eq!(c, expect, "simd-blocked {a:?} ∩ {b:?}");
        assert_eq!(intersect_vec(a, b).len() as u64, expect);
    }

    #[test]
    fn basic_cases() {
        check_all(&[], &[], 0);
        check_all(&[1], &[], 0);
        check_all(&[1, 2, 3], &[2, 3, 4], 2);
        check_all(&[1, 2, 3], &[4, 5, 6], 0);
        check_all(&[1, 2, 3], &[1, 2, 3], 3);
        check_all(&[5], &[1, 2, 3, 4, 5, 6, 7, 8, 9], 1);
    }

    #[test]
    fn unbalanced_lists() {
        let long: Vec<VertexId> = (0..10_000).map(|x| x * 3).collect();
        let short: Vec<VertexId> = vec![3, 2999 * 3, 9999 * 3, 29_999];
        check_all(&short, &long, 3);
    }

    #[test]
    fn randomized_agreement() {
        use crate::gen::rng::Rng;
        let mut rng = Rng::seeded(99);
        for _ in 0..200 {
            let la = rng.below_usize(60);
            let lb = rng.below_usize(600);
            let mut a: Vec<VertexId> = (0..la).map(|_| rng.next_u32() % 500).collect();
            let mut b: Vec<VertexId> = (0..lb).map(|_| rng.next_u32() % 500).collect();
            a.sort_unstable();
            a.dedup();
            b.sort_unstable();
            b.dedup();
            let expect = a.iter().filter(|x| b.binary_search(x).is_ok()).count() as u64;
            check_all(&a, &b, expect);
        }
    }

    #[test]
    fn gallop_handles_prefix_exhaustion() {
        let mut c = 0;
        count_galloping(&[100, 200], &[1, 2, 3], &mut c);
        assert_eq!(c, 0);
    }

    /// Adversarial coverage for the SWAR kernel: every window/tail shape
    /// the blocked loop can reach, checked against the scalar merge.
    #[test]
    fn simd_blocked_adversarial_shapes() {
        // Empty / sub-window lists never enter the blocked loop.
        check_all(&[], &[], 0);
        check_all(&[7], &[7], 1);
        check_all(&[1, 3], &[2, 4, 6], 0);
        // Disjoint interleaved (forces alternating window advances).
        let evens: Vec<VertexId> = (0..64).map(|x| 2 * x).collect();
        let odds: Vec<VertexId> = (0..64).map(|x| 2 * x + 1).collect();
        check_all(&evens, &odds, 0);
        // Disjoint ranges (one side exhausts immediately).
        let lo: Vec<VertexId> = (0..32).collect();
        let hi: Vec<VertexId> = (1000..1040).collect();
        check_all(&lo, &hi, 0);
        // Identical lists, including lengths exercising every tail residue
        // 0–5 on both the 2-wide and 4-wide windows.
        for len in [0usize, 1, 2, 3, 4, 5, 6, 7, 8, 9, 16, 17, 21] {
            let v: Vec<VertexId> = (0..len as u32).map(|x| 3 * x + 1).collect();
            check_all(&v, &v, len as u64);
        }
        // Duplicate-free runs with partial overlap and ragged tails.
        for (la, lb, shift) in [(20, 23, 5), (33, 6, 2), (7, 41, 3), (19, 22, 40)] {
            let a: Vec<VertexId> = (0..la).collect();
            let b: Vec<VertexId> = (0..lb).map(|x| x + shift).collect();
            let expect = a.iter().filter(|x| b.binary_search(x).is_ok()).count() as u64;
            check_all(&a, &b, expect);
        }
        // Shared max element (tie path: both windows advance together).
        check_all(&[1, 2, 3, 7], &[4, 5, 6, 7], 1);
    }

    /// The blocked kernel's advance rule must not skip matches when
    /// windows tie on their maxima mid-stream.
    #[test]
    fn simd_blocked_tie_advances_are_lossless() {
        let a: Vec<VertexId> = vec![0, 3, 4, 7, 8, 11, 12, 15, 16, 19];
        let b: Vec<VertexId> = vec![1, 2, 3, 7, 9, 10, 11, 15, 17, 18, 19, 23];
        let expect = a.iter().filter(|x| b.binary_search(x).is_ok()).count() as u64;
        check_all(&a, &b, expect);
    }
}
