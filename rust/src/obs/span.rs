//! Per-rank phase-span recording — the timeline substrate of `obs/`.
//!
//! A *span* is one `{phase, t_start, t_end}` interval on a rank's
//! timeline: a compute section, a blocking `recv` wait, a barrier, a
//! reduce, a send hand-off, or a stream batch-apply. Spans are recorded
//! into a fixed-capacity ring buffer so the recorder is allocation-free
//! and O(1) per span on the hot path — when the ring is full the oldest
//! span is overwritten and `dropped` counts the loss (never silent).
//!
//! Two clock domains (DESIGN.md §11):
//!
//! * **Wall** — ticks are microseconds since the recorder's creation
//!   (`Instant`-based), used on the threads/channel backend.
//! * **Virtual** — ticks are the testkit scheduler's virtual clock
//!   (`Transport::virtual_now`), so the same `SimConfig` seed replays to
//!   a *bit-identical* timeline. 1 virtual tick is exported as 1 µs.
//!
//! The recorder itself never reads a clock in the virtual domain — the
//! caller (`comm::threads::Comm`) stamps ticks via `record`/`begin_at`/
//! `end_at`, which keeps this module free of any transport dependency.

use std::time::Instant;

use crate::comm::transport::{Wire, WireReader};
use crate::error::{Error, Result};

/// Phases a rank timeline is decomposed into. `name()` strings are part
/// of the snapshot schema (`obs::registry`) — append variants, never
/// rename.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SpanPhase {
    /// Local counting work (intersections, task execution).
    Compute,
    /// Handing an envelope to the transport (data or control).
    Send,
    /// Blocked in `Comm::recv` waiting for an envelope.
    RecvWait,
    /// Inside `Comm::barrier`.
    Barrier,
    /// Inside `Comm::reduce_sum`.
    Reduce,
    /// Applying a normalized stream batch to owned state (+ compaction).
    BatchApply,
    /// Fault-tolerant re-execution (`ft::supervisor`): work performed on a
    /// recovery attempt after a rank death — the ticks the run would not
    /// have spent fault-free.
    Recovery,
}

impl SpanPhase {
    /// Every phase, in schema order.
    pub const ALL: [SpanPhase; 7] = [
        SpanPhase::Compute,
        SpanPhase::Send,
        SpanPhase::RecvWait,
        SpanPhase::Barrier,
        SpanPhase::Reduce,
        SpanPhase::BatchApply,
        SpanPhase::Recovery,
    ];

    /// Stable schema / trace-event name.
    pub fn name(self) -> &'static str {
        match self {
            SpanPhase::Compute => "compute",
            SpanPhase::Send => "send",
            SpanPhase::RecvWait => "recv_wait",
            SpanPhase::Barrier => "barrier",
            SpanPhase::Reduce => "reduce",
            SpanPhase::BatchApply => "batch_apply",
            SpanPhase::Recovery => "recovery",
        }
    }
}

/// Phases travel as their index in [`SpanPhase::ALL`] (schema order —
/// append-only, like the snapshot names).
impl Wire for SpanPhase {
    fn write_to(&self, out: &mut Vec<u8>) {
        let idx = SpanPhase::ALL.iter().position(|p| p == self).unwrap() as u8;
        out.push(idx);
    }
    fn read_from(r: &mut WireReader<'_>) -> Result<Self> {
        let idx = r.u8()? as usize;
        SpanPhase::ALL
            .get(idx)
            .copied()
            .ok_or_else(|| Error::Comm(format!("unknown span phase index {idx}")))
    }
}

/// Which clock the ticks of a [`SpanLog`] were read from.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ClockDomain {
    /// Microseconds of wall time since the recorder's epoch.
    #[default]
    Wall,
    /// Testkit scheduler virtual ticks (deterministic under a seed).
    Virtual,
}

impl ClockDomain {
    /// Stable schema name ("wall" / "virtual").
    pub fn name(self) -> &'static str {
        match self {
            ClockDomain::Wall => "wall",
            ClockDomain::Virtual => "virtual",
        }
    }
}

impl Wire for ClockDomain {
    fn write_to(&self, out: &mut Vec<u8>) {
        out.push(match self {
            ClockDomain::Wall => 0,
            ClockDomain::Virtual => 1,
        });
    }
    fn read_from(r: &mut WireReader<'_>) -> Result<Self> {
        match r.u8()? {
            0 => Ok(ClockDomain::Wall),
            1 => Ok(ClockDomain::Virtual),
            b => Err(Error::Comm(format!("unknown clock domain byte {b}"))),
        }
    }
}

/// One closed interval on a rank's timeline, in the log's clock domain.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Span {
    pub phase: SpanPhase,
    pub t_start: u64,
    pub t_end: u64,
}

impl Span {
    /// Interval length in ticks (0 for inverted intervals, which cannot
    /// be produced by the recorder but may appear in hand-built logs).
    pub fn dur(&self) -> u64 {
        self.t_end.saturating_sub(self.t_start)
    }
}

impl Wire for Span {
    fn write_to(&self, out: &mut Vec<u8>) {
        self.phase.write_to(out);
        self.t_start.write_to(out);
        self.t_end.write_to(out);
    }
    fn read_from(r: &mut WireReader<'_>) -> Result<Self> {
        Ok(Span {
            phase: SpanPhase::read_from(r)?,
            t_start: u64::read_from(r)?,
            t_end: u64::read_from(r)?,
        })
    }
}

/// A finished, chronologically ordered span timeline for one rank, as
/// carried by `CommMetrics::spans`. Equality is structural, which is what
/// the conformance suite uses to assert replayed schedules reproduce
/// identical virtual-time timelines.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SpanLog {
    pub domain: ClockDomain,
    pub spans: Vec<Span>,
    /// Spans overwritten by ring wrap-around (oldest-first eviction).
    pub dropped: u64,
}

impl Wire for SpanLog {
    fn write_to(&self, out: &mut Vec<u8>) {
        self.domain.write_to(out);
        self.dropped.write_to(out);
        (self.spans.len() as u64).write_to(out);
        for s in &self.spans {
            s.write_to(out);
        }
    }
    fn read_from(r: &mut WireReader<'_>) -> Result<Self> {
        let domain = ClockDomain::read_from(r)?;
        let dropped = u64::read_from(r)?;
        let n = r.len_prefix(17)?;
        let mut spans = Vec::with_capacity(n);
        for _ in 0..n {
            spans.push(Span::read_from(r)?);
        }
        Ok(SpanLog { domain, spans, dropped })
    }
}

impl SpanLog {
    /// Σ duration of all recorded spans of `phase`, in ticks.
    pub fn phase_ticks(&self, phase: SpanPhase) -> u64 {
        self.spans.iter().filter(|s| s.phase == phase).map(|s| s.dur()).sum()
    }

    /// Number of spans retained in the log.
    pub fn recorded(&self) -> usize {
        self.spans.len()
    }
}

/// Default ring capacity: large enough that the conformance workloads and
/// the CLI smoke graphs never wrap, small enough (96 KiB/rank) to sit in
/// every `Comm` unconditionally.
pub const DEFAULT_CAPACITY: usize = 4096;

/// Low-overhead per-rank span recorder: a ring buffer of closed spans
/// plus a LIFO stack of open ones (spans nest; `end_at` closes the most
/// recent `begin_at`). Not thread-safe by design — each rank owns its
/// recorder, exactly like `CommMetrics`.
#[derive(Debug)]
pub struct SpanRecorder {
    domain: ClockDomain,
    /// Wall-clock epoch; `None` in the virtual domain (ticks come from
    /// the caller there).
    epoch: Option<Instant>,
    spans: Vec<Span>,
    /// Next eviction slot once the ring is full.
    head: usize,
    cap: usize,
    dropped: u64,
    open: Vec<(SpanPhase, u64)>,
}

impl Default for SpanRecorder {
    fn default() -> Self {
        SpanRecorder::wall()
    }
}

impl SpanRecorder {
    /// Wall-clock recorder; ticks are µs since this call.
    pub fn wall() -> Self {
        SpanRecorder {
            domain: ClockDomain::Wall,
            epoch: Some(Instant::now()),
            spans: Vec::new(),
            head: 0,
            cap: DEFAULT_CAPACITY,
            dropped: 0,
            open: Vec::new(),
        }
    }

    /// Virtual-clock recorder; the caller supplies every tick value.
    pub fn virtual_clock() -> Self {
        SpanRecorder { domain: ClockDomain::Virtual, epoch: None, ..SpanRecorder::wall() }
    }

    /// Override the ring capacity (builder-style; 0 is clamped to 1).
    pub fn with_capacity(mut self, cap: usize) -> Self {
        self.cap = cap.max(1);
        self
    }

    /// This recorder's clock domain.
    pub fn domain(&self) -> ClockDomain {
        self.domain
    }

    /// Re-anchor the wall epoch at "now" (no-op in the virtual domain).
    /// The cluster launcher calls this when the rank thread actually
    /// starts running, so span ticks and the rank's measured `total`
    /// share a time origin instead of including the spawn delay.
    pub fn reset_epoch(&mut self) {
        if self.epoch.is_some() {
            self.epoch = Some(Instant::now());
        }
    }

    /// Current wall tick (µs since the epoch); 0 in the virtual domain,
    /// where the transport's virtual clock is authoritative instead.
    pub fn wall_now(&self) -> u64 {
        self.epoch.map(|e| e.elapsed().as_micros() as u64).unwrap_or(0)
    }

    /// Record a closed span. O(1); evicts the oldest span when full.
    pub fn record(&mut self, phase: SpanPhase, t_start: u64, t_end: u64) {
        let s = Span { phase, t_start, t_end: t_end.max(t_start) };
        if self.spans.len() < self.cap {
            self.spans.push(s);
        } else {
            self.spans[self.head] = s;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Open a span at an explicit tick. Spans nest LIFO.
    pub fn begin_at(&mut self, phase: SpanPhase, t: u64) {
        self.open.push((phase, t));
    }

    /// Close the most recently opened span at an explicit tick. A close
    /// with no open span is ignored (robust against error paths).
    pub fn end_at(&mut self, t: u64) {
        if let Some((phase, t0)) = self.open.pop() {
            self.record(phase, t0, t);
        }
    }

    /// Wall-domain convenience: `begin_at(phase, wall_now())`.
    pub fn begin(&mut self, phase: SpanPhase) {
        let t = self.wall_now();
        self.begin_at(phase, t);
    }

    /// Wall-domain convenience: `end_at(wall_now())`.
    pub fn end(&mut self) {
        let t = self.wall_now();
        self.end_at(t);
    }

    /// Number of currently open (unclosed) spans.
    pub fn open_depth(&self) -> usize {
        self.open.len()
    }

    /// Snapshot the ring into a chronologically ordered log. Open spans
    /// are not included — close them first.
    pub fn log(&self) -> SpanLog {
        let mut spans = Vec::with_capacity(self.spans.len());
        spans.extend_from_slice(&self.spans[self.head..]);
        spans.extend_from_slice(&self.spans[..self.head]);
        SpanLog { domain: self.domain, spans, dropped: self.dropped }
    }

    /// Extract the log and reset the ring (open-span stack is cleared:
    /// anything still open when a rank finishes is an error-path remnant
    /// and is deliberately discarded).
    pub fn take_log(&mut self) -> SpanLog {
        let log = self.log();
        self.spans.clear();
        self.head = 0;
        self.dropped = 0;
        self.open.clear();
        log
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_nest_lifo() {
        let mut r = SpanRecorder::virtual_clock();
        r.begin_at(SpanPhase::Compute, 0);
        r.begin_at(SpanPhase::RecvWait, 3);
        r.end_at(7); // closes RecvWait
        r.end_at(10); // closes Compute
        let log = r.take_log();
        assert_eq!(log.domain, ClockDomain::Virtual);
        assert_eq!(
            log.spans,
            vec![
                Span { phase: SpanPhase::RecvWait, t_start: 3, t_end: 7 },
                Span { phase: SpanPhase::Compute, t_start: 0, t_end: 10 },
            ]
        );
        assert_eq!(log.phase_ticks(SpanPhase::Compute), 10);
        assert_eq!(log.phase_ticks(SpanPhase::RecvWait), 4);
        assert_eq!(log.dropped, 0);
    }

    #[test]
    fn unmatched_end_is_ignored() {
        let mut r = SpanRecorder::virtual_clock();
        r.end_at(5);
        assert_eq!(r.take_log().spans.len(), 0);
    }

    #[test]
    fn ring_overflow_evicts_oldest_and_counts_drops() {
        let mut r = SpanRecorder::virtual_clock().with_capacity(3);
        for i in 0..5u64 {
            r.record(SpanPhase::Send, i * 10, i * 10 + 1);
        }
        let log = r.log();
        assert_eq!(log.dropped, 2);
        // Oldest two (t_start 0, 10) evicted; remainder chronological.
        let starts: Vec<u64> = log.spans.iter().map(|s| s.t_start).collect();
        assert_eq!(starts, vec![20, 30, 40]);
    }

    #[test]
    fn take_log_resets_recorder() {
        let mut r = SpanRecorder::virtual_clock().with_capacity(2);
        r.record(SpanPhase::Barrier, 0, 1);
        r.record(SpanPhase::Barrier, 2, 3);
        r.record(SpanPhase::Barrier, 4, 5);
        assert_eq!(r.take_log().dropped, 1);
        let log = r.take_log();
        assert_eq!(log.spans.len(), 0);
        assert_eq!(log.dropped, 0);
    }

    #[test]
    fn inverted_interval_is_clamped() {
        let mut r = SpanRecorder::virtual_clock();
        r.record(SpanPhase::Reduce, 9, 4);
        let log = r.log();
        assert_eq!(log.spans[0].t_end, 9);
        assert_eq!(log.spans[0].dur(), 0);
    }

    #[test]
    fn wall_recorder_ticks_are_monotonic() {
        let mut r = SpanRecorder::wall();
        r.begin(SpanPhase::Compute);
        let t0 = r.wall_now();
        r.end();
        let log = r.take_log();
        assert_eq!(log.domain, ClockDomain::Wall);
        assert_eq!(log.spans.len(), 1);
        assert!(log.spans[0].t_end >= log.spans[0].t_start);
        assert!(r.wall_now() >= t0);
    }
}
