//! Human-readable observability reports: the Fig-13-style per-rank
//! compute/wait breakdown `tricount count` prints, and the renderer
//! behind `tricount obs-report <snapshot.json>`.
//!
//! The paper's Fig. 13 decomposes each rank's runtime into computation
//! vs idle time to motivate dynamic load balancing (§V); this module
//! reproduces that decomposition from span timelines: *idle* is the time
//! a rank spent in `recv`-wait, barriers and reduces, *busy* is the
//! remainder of its total runtime (compute + send hand-offs). Both
//! views — live `ClusterMetrics` and a parsed snapshot — go through the
//! same row renderer so the CLI and `obs-report` agree byte-for-byte on
//! format.

use crate::comm::metrics::ClusterMetrics;
use crate::obs::registry::JsonValue;
use crate::obs::span::SpanPhase;

/// One rank's breakdown row, in µs (or virtual ticks — same scale).
struct Row {
    rank: usize,
    total: u64,
    recv_wait: u64,
    barrier: u64,
    reduce: u64,
    send: u64,
    batch: u64,
    recorded: u64,
    dropped: u64,
    work: u64,
}

impl Row {
    fn idle(&self) -> u64 {
        self.recv_wait + self.barrier + self.reduce
    }

    fn idle_pct(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            100.0 * self.idle() as f64 / self.total as f64
        }
    }
}

fn render_rows(clock: &str, rows: &[Row]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "obs: per-rank breakdown (clock={clock}; idle = recv_wait + barrier + reduce, \
         paper Fig 13)\n"
    ));
    s.push_str(&format!(
        "{:>6} {:>12} {:>12} {:>11} {:>9} {:>9} {:>9} {:>9} {:>9} {:>6}\n",
        "rank", "total_us", "busy_us", "recv_wait", "barrier", "reduce", "send", "batch",
        "spans", "idle%"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:>6} {:>12} {:>12} {:>11} {:>9} {:>9} {:>9} {:>9} {:>9} {:>6.1}\n",
            r.rank,
            r.total,
            r.total.saturating_sub(r.idle()),
            r.recv_wait,
            r.barrier,
            r.reduce,
            r.send,
            r.batch,
            format!("{}/{}", r.recorded, r.dropped),
            r.idle_pct()
        ));
    }
    if !rows.is_empty() {
        let worst = rows
            .iter()
            .max_by(|a, b| a.idle_pct().partial_cmp(&b.idle_pct()).unwrap())
            .unwrap();
        let max_work = rows.iter().map(|r| r.work).max().unwrap() as f64;
        let mean_work = rows.iter().map(|r| r.work).sum::<u64>() as f64 / rows.len() as f64;
        let imb = if mean_work == 0.0 { 1.0 } else { max_work / mean_work };
        s.push_str(&format!(
            "obs: max idle {:.1}% (rank {}) | load imbalance (max/mean work) {imb:.2}\n",
            worst.idle_pct(),
            worst.rank
        ));
    }
    s
}

fn rows_from_metrics(m: &ClusterMetrics) -> Vec<Row> {
    m.per_rank
        .iter()
        .enumerate()
        .map(|(rank, rm)| Row {
            rank,
            total: rm.total.as_micros() as u64,
            recv_wait: rm.spans.phase_ticks(SpanPhase::RecvWait),
            barrier: rm.spans.phase_ticks(SpanPhase::Barrier),
            reduce: rm.spans.phase_ticks(SpanPhase::Reduce),
            send: rm.spans.phase_ticks(SpanPhase::Send),
            batch: rm.spans.phase_ticks(SpanPhase::BatchApply),
            recorded: rm.spans.recorded() as u64,
            dropped: rm.spans.dropped,
            work: rm.work_units,
        })
        .collect()
}

/// Render the breakdown of a live cluster run.
pub fn breakdown(m: &ClusterMetrics) -> String {
    let clock = m.per_rank.first().map(|rm| rm.spans.domain.name()).unwrap_or("wall");
    render_rows(clock, &rows_from_metrics(m))
}

/// Print the breakdown of a live cluster run (what `tricount count`
/// emits after the counts).
pub fn print_breakdown(m: &ClusterMetrics) {
    print!("{}", breakdown(m));
}

fn ru64(v: &JsonValue, key: &str, ctx: &str) -> Result<u64, String> {
    v.get(key)
        .and_then(JsonValue::as_u64)
        .ok_or_else(|| format!("{ctx}: missing integer \"{key}\""))
}

/// Render a validated snapshot document (see
/// [`crate::obs::registry::validate_snapshot`]) as the breakdown table
/// plus batch/phase summaries — the body of `tricount obs-report`.
pub fn render_snapshot(v: &JsonValue) -> Result<String, String> {
    let command = v.get("command").and_then(JsonValue::as_str).unwrap_or("?");
    let clock = v.get("clock_domain").and_then(JsonValue::as_str).unwrap_or("wall");
    let ranks = v
        .get("ranks")
        .and_then(JsonValue::as_arr)
        .ok_or("snapshot: missing ranks array")?;
    let mut rows = Vec::with_capacity(ranks.len());
    for (i, r) in ranks.iter().enumerate() {
        let ctx = format!("ranks[{i}]");
        let spans = r.get("spans").ok_or_else(|| format!("{ctx}: missing spans"))?;
        let by_phase =
            spans.get("by_phase_us").ok_or_else(|| format!("{ctx}: missing by_phase_us"))?;
        rows.push(Row {
            rank: ru64(r, "rank", &ctx)? as usize,
            total: ru64(r, "total_us", &ctx)?,
            recv_wait: ru64(by_phase, "recv_wait", &ctx)?,
            barrier: ru64(by_phase, "barrier", &ctx)?,
            reduce: ru64(by_phase, "reduce", &ctx)?,
            send: ru64(by_phase, "send", &ctx)?,
            batch: ru64(by_phase, "batch_apply", &ctx)?,
            recorded: ru64(spans, "recorded", &ctx)?,
            dropped: ru64(spans, "dropped", &ctx)?,
            work: ru64(r, "work_units", &ctx)?,
        });
    }
    let mut s = format!("obs snapshot: command={command} ranks={}\n", rows.len());
    s.push_str(&render_rows(clock, &rows));
    if let Some(kg) = v.get("kernels_global") {
        s.push_str(&format!(
            "obs: kernels (global) list_list={} list_bitmap={} bitmap_bitmap={} simd_blocked={}\n",
            ru64(kg, "list_list", "kernels_global")?,
            ru64(kg, "list_bitmap", "kernels_global")?,
            ru64(kg, "bitmap_bitmap", "kernels_global")?,
            ru64(kg, "simd_blocked", "kernels_global")?
        ));
    }
    if let Some(batches) = v.get("batches").and_then(JsonValue::as_arr) {
        if !batches.is_empty() {
            let mut net: i64 = 0;
            for b in batches {
                net += b.get("delta").and_then(JsonValue::as_i64).unwrap_or(0);
            }
            s.push_str(&format!("obs: {} stream batches, net delta {net:+}\n", batches.len()));
        }
    }
    if let Some(phases) = v.get("phases").and_then(JsonValue::as_arr) {
        for p in phases {
            let name = p.get("name").and_then(JsonValue::as_str).unwrap_or("?");
            let secs = p.get("secs").and_then(JsonValue::as_f64).unwrap_or(0.0);
            s.push_str(&format!("obs: phase {name:<28} {secs:>10.6}s\n"));
        }
    }
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adj::stats::KernelStats;
    use crate::comm::metrics::CommMetrics;
    use crate::obs::registry::{validate_snapshot, MetricsRegistry};
    use crate::obs::span::{ClockDomain, Span, SpanLog};
    use std::time::Duration;

    fn cluster() -> ClusterMetrics {
        ClusterMetrics {
            per_rank: vec![
                CommMetrics {
                    total: Duration::from_micros(100),
                    work_units: 30,
                    spans: SpanLog {
                        domain: ClockDomain::Virtual,
                        spans: vec![
                            Span { phase: SpanPhase::Compute, t_start: 0, t_end: 80 },
                            Span { phase: SpanPhase::RecvWait, t_start: 80, t_end: 100 },
                        ],
                        dropped: 0,
                    },
                    ..Default::default()
                },
                CommMetrics {
                    total: Duration::from_micros(100),
                    work_units: 10,
                    spans: SpanLog {
                        domain: ClockDomain::Virtual,
                        spans: vec![Span { phase: SpanPhase::Barrier, t_start: 0, t_end: 50 }],
                        dropped: 2,
                    },
                    ..Default::default()
                },
            ],
        }
    }

    #[test]
    fn breakdown_reports_idle_and_imbalance() {
        let text = breakdown(&cluster());
        assert!(text.contains("clock=virtual"), "{text}");
        // Rank 0: 20/100 idle; rank 1: 50/100 idle → worst is rank 1.
        assert!(text.contains("max idle 50.0% (rank 1)"), "{text}");
        // max/mean work = 30 / 20.
        assert!(text.contains("load imbalance (max/mean work) 1.50"), "{text}");
        assert!(text.contains("1/2"), "dropped count must surface: {text}");
    }

    #[test]
    fn empty_cluster_renders_header_only() {
        let text = breakdown(&ClusterMetrics::default());
        assert!(text.contains("per-rank breakdown"));
        assert!(!text.contains("max idle"));
    }

    #[test]
    fn snapshot_renderer_matches_live_renderer_rows() {
        let m = cluster();
        let mut reg = MetricsRegistry::new("count");
        reg.record_cluster(&m);
        reg.record_global_kernels(KernelStats::default());
        let v = validate_snapshot(&reg.snapshot_json()).unwrap();
        let rendered = render_snapshot(&v).unwrap();
        // The snapshot path must reproduce the live table verbatim.
        for line in breakdown(&m).lines() {
            assert!(rendered.contains(line), "missing line {line:?} in:\n{rendered}");
        }
        assert!(rendered.contains("command=count"));
    }
}
