//! The unified metrics registry: one versioned JSON snapshot per run.
//!
//! Before `obs/`, instrumentation was scattered — `comm/metrics.rs` held
//! per-rank counters, `adj/stats.rs` a process-global kernel mix, the
//! pipeline timed phases ad hoc, and the stream driver kept its own batch
//! stats. [`MetricsRegistry`] collects all of them into a single
//! schema-versioned snapshot (`--obs-out` on the CLI, rendered by
//! `tricount obs-report`), so every measurement a run produces has one
//! canonical, machine-checkable home.
//!
//! The schema (version [`SCHEMA_VERSION`]) is hand-written JSON — the
//! crate is dependency-free — and [`validate_snapshot`] is the gate: it
//! re-parses an emitted snapshot with the in-crate parser
//! ([`parse_json`]) and checks every required key, which is exactly what
//! the CI smoke step and the golden test below run. Schema evolution
//! contract (DESIGN.md §11): adding keys bumps nothing, removing or
//! renaming any key listed in the validators bumps `SCHEMA_VERSION`.

use crate::adj::stats::KernelStats;
use crate::comm::metrics::ClusterMetrics;
use crate::obs::span::{ClockDomain, SpanPhase};
use crate::stream::parallel::BatchStats;

/// Version stamped into (and required from) every snapshot.
pub const SCHEMA_VERSION: u64 = 1;

/// Quote + escape a string for JSON output.
fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// One stream batch, reduced to the schema's scalar fields.
#[derive(Clone, Copy, Debug)]
struct BatchRow {
    delta: i64,
    triangles: u64,
    inserts: u64,
    deletes: u64,
    work: u64,
}

/// Fault-tolerance summary of a supervised run (the additive `ft` key,
/// present iff [`MetricsRegistry::record_ft`] was called).
#[derive(Clone, Debug, Default)]
struct FtRow {
    attempts: u32,
    degraded: bool,
    dead_ranks: Vec<usize>,
    survivors: Vec<usize>,
    salvaged_units: usize,
    partial_units: usize,
    reexec_work_units: u64,
    reexec_bytes: u64,
    trace_hash: Option<u64>,
}

/// Collects a run's measurements and serializes them as one snapshot.
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    command: String,
    cluster: ClusterMetrics,
    global_kernels: KernelStats,
    batches: Vec<BatchRow>,
    phases: Vec<(String, f64)>,
    notes: Vec<String>,
    ft: Option<FtRow>,
}

impl MetricsRegistry {
    /// A registry for one CLI run (`command` names the subcommand).
    pub fn new(command: &str) -> Self {
        MetricsRegistry { command: command.to_string(), ..Default::default() }
    }

    /// Adopt the per-rank metrics of a finished cluster run (comm
    /// counters, kernel mix, span timelines).
    pub fn record_cluster(&mut self, m: &ClusterMetrics) {
        self.cluster = m.clone();
    }

    /// Record the process-global kernel snapshot (the cross-rank sum the
    /// CLI has always printed).
    pub fn record_global_kernels(&mut self, k: KernelStats) {
        self.global_kernels = k;
    }

    /// Record per-batch stream stats.
    pub fn record_batches(&mut self, batches: &[BatchStats]) {
        self.batches.extend(batches.iter().map(|b| BatchRow {
            delta: b.delta,
            triangles: b.triangles,
            inserts: b.inserts as u64,
            deletes: b.deletes as u64,
            work: b.work_per_rank.iter().sum(),
        }));
    }

    /// Record one named phase timing (pipeline stages, CLI-side timings).
    pub fn record_phase(&mut self, name: &str, secs: f64) {
        self.phases.push((name.to_string(), secs));
    }

    /// Record the fault-tolerance outcome of a supervised run (DESIGN.md
    /// §13): recovery attempts, victims, re-executed work/bytes and the
    /// replay trace hash. Emitted as the additive `ft` key — absent on
    /// unsupervised runs, so pre-`ft/` snapshots stay byte-identical.
    pub fn record_ft(&mut self, r: &crate::ft::RecoveryReport, trace_hash: Option<u64>) {
        self.ft = Some(FtRow {
            attempts: r.attempts,
            degraded: r.degraded,
            dead_ranks: r.dead_ranks.clone(),
            survivors: r.survivors.as_ref().map(|m| m.survivors.clone()).unwrap_or_default(),
            salvaged_units: r.salvaged_units,
            partial_units: r.partial_units,
            reexec_work_units: r.reexec_work_units,
            reexec_bytes: r.reexec_bytes,
            trace_hash,
        });
    }

    /// Attach a free-form annotation (workload, algorithm, config).
    pub fn note(&mut self, s: &str) {
        self.notes.push(s.to_string());
    }

    /// The run's clock domain, taken from rank 0's span log.
    fn clock_domain(&self) -> ClockDomain {
        self.cluster.per_rank.first().map(|m| m.spans.domain).unwrap_or_default()
    }

    /// Serialize the snapshot (schema version [`SCHEMA_VERSION`]).
    /// Deterministic: field order is fixed and no timestamps are stamped,
    /// so identical runs emit identical bytes.
    pub fn snapshot_json(&self) -> String {
        let mut s = String::with_capacity(4096);
        s.push_str("{\n");
        s.push_str(&format!("  \"schema_version\": {SCHEMA_VERSION},\n"));
        s.push_str(&format!("  \"command\": {},\n", quote(&self.command)));
        s.push_str(&format!(
            "  \"clock_domain\": {},\n",
            quote(self.clock_domain().name())
        ));
        s.push_str("  \"ranks\": [\n");
        for (rank, m) in self.cluster.per_rank.iter().enumerate() {
            let by_phase: Vec<String> = SpanPhase::ALL
                .iter()
                .map(|p| format!("\"{}\": {}", p.name(), m.spans.phase_ticks(*p)))
                .collect();
            s.push_str(&format!(
                "    {{\"rank\": {rank}, \"messages_sent\": {}, \"bytes_sent\": {}, \
                 \"messages_received\": {}, \"control_sent\": {}, \"control_received\": {}, \
                 \"recv_wait_us\": {}, \"total_us\": {}, \"work_units\": {}, \
                 \"partition_bytes\": {}, \"partition_bytes_pred\": {}, \"accel_bytes\": {}, \
                 \"transport_ops\": {}, \"retries\": {}, \"reexec_work_units\": {}, \
                 \"reexec_bytes\": {}, \"frames_sent\": {}, \"frames_received\": {}, \
                 \"coalesced_sent\": {}, \"coalesced_received\": {}, \
                 \"wire_overhead_bytes\": {}, \"kernel\": {}, \
                 \"spans\": {{\"recorded\": {}, \"dropped\": {}, \"by_phase_us\": {{{}}}}}}}{}\n",
                m.messages_sent,
                m.bytes_sent,
                m.messages_received,
                m.control_sent,
                m.control_received,
                m.recv_wait.as_micros(),
                m.total.as_micros(),
                m.work_units,
                m.partition_bytes,
                m.partition_bytes_pred,
                m.accel_bytes,
                m.transport_ops,
                m.retries,
                m.reexec_work_units,
                m.reexec_bytes,
                m.frames_sent,
                m.frames_received,
                m.coalesced_sent,
                m.coalesced_received,
                m.wire_overhead_bytes,
                kernel_json(&m.kernel),
                m.spans.recorded(),
                m.spans.dropped,
                by_phase.join(", "),
                if rank + 1 < self.cluster.per_rank.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
        s.push_str(&format!("  \"kernels_global\": {},\n", kernel_json(&self.global_kernels)));
        s.push_str("  \"batches\": [\n");
        for (i, b) in self.batches.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"batch\": {i}, \"delta\": {}, \"triangles\": {}, \"inserts\": {}, \
                 \"deletes\": {}, \"work\": {}}}{}\n",
                b.delta,
                b.triangles,
                b.inserts,
                b.deletes,
                b.work,
                if i + 1 < self.batches.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
        s.push_str("  \"phases\": [\n");
        for (i, (name, secs)) in self.phases.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"name\": {}, \"secs\": {secs:.6}}}{}\n",
                quote(name),
                if i + 1 < self.phases.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
        // Additive `ft` section (schema evolution contract: adding keys
        // bumps nothing; the key is absent on unsupervised runs).
        if let Some(ft) = &self.ft {
            s.push_str(&format!(
                "  \"ft\": {{\"attempts\": {}, \"degraded\": {}, \"dead_ranks\": {:?}, \
                 \"survivors\": {:?}, \"salvaged_units\": {}, \"partial_units\": {}, \
                 \"reexec_work_units\": {}, \"reexec_bytes\": {}, \"trace_hash\": {}}},\n",
                ft.attempts,
                ft.degraded,
                ft.dead_ranks,
                ft.survivors,
                ft.salvaged_units,
                ft.partial_units,
                ft.reexec_work_units,
                ft.reexec_bytes,
                ft.trace_hash.map_or("null".to_string(), |h| quote(&format!("{h:016x}")))
            ));
        }
        let notes: Vec<String> = self.notes.iter().map(|n| quote(n)).collect();
        s.push_str(&format!("  \"notes\": [{}]\n", notes.join(", ")));
        s.push_str("}\n");
        s
    }
}

fn kernel_json(k: &KernelStats) -> String {
    format!(
        "{{\"list_list\": {}, \"list_bitmap\": {}, \"bitmap_bitmap\": {}, \"simd_blocked\": {}}}",
        k.list_list, k.list_bitmap, k.bitmap_bitmap, k.simd_blocked
    )
}

// ---------------------------------------------------------------------------
// Minimal JSON parser (recursive descent) — powers `tricount obs-report`,
// snapshot/trace validation, and the golden schema test. Full JSON value
// grammar; numbers are f64 (every value the schemas emit fits exactly).
// ---------------------------------------------------------------------------

/// A parsed JSON value. Objects preserve key order.
#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JsonValue>),
    Obj(Vec<(String, JsonValue)>),
}

impl JsonValue {
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(fields) => {
                fields.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Number, required to be a non-negative integer.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            JsonValue::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    /// Number, required to be an integer (possibly negative).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            JsonValue::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(v) => Some(v),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<JsonValue, String> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(JsonValue::Str(self.string()?)),
            Some(b't') => self.literal("true", JsonValue::Bool(true)),
            Some(b'f') => self.literal("false", JsonValue::Bool(false)),
            Some(b'n') => self.literal("null", JsonValue::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected '{}' at byte {}", c as char, self.i)),
            None => Err("unexpected end of input".to_string()),
        }
    }

    fn literal(&mut self, lit: &str, v: JsonValue) -> Result<JsonValue, String> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<JsonValue, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(JsonValue::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000c}'),
                        Some(b'u') => {
                            let cp = self.hex4()?;
                            // Surrogate pairs: combine, else replacement.
                            if (0xD800..0xDC00).contains(&cp)
                                && self.b[self.i..].starts_with(b"\\u")
                            {
                                self.i += 1; // consume '\', hex4 eats "uXXXX"
                                let lo = self.hex4()?;
                                let c = 0x10000
                                    + ((cp - 0xD800) << 10)
                                    + (lo.wrapping_sub(0xDC00) & 0x3FF);
                                out.push(char::from_u32(c).unwrap_or('\u{FFFD}'));
                            } else {
                                out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            }
                            continue;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input is a &str upstream,
                    // so boundaries are valid).
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    /// Parse a `uXXXX` escape tail (cursor on the 'u'); consumes all 5
    /// bytes and returns the code unit.
    fn hex4(&mut self) -> Result<u32, String> {
        let s = self
            .b
            .get(self.i + 1..self.i + 5)
            .and_then(|b| std::str::from_utf8(b).ok())
            .ok_or_else(|| format!("bad \\u escape at byte {}", self.i))?;
        let cp = u32::from_str_radix(s, 16)
            .map_err(|_| format!("bad \\u escape at byte {}", self.i))?;
        self.i += 5;
        Ok(cp)
    }

    fn array(&mut self) -> Result<JsonValue, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(JsonValue::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(JsonValue::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<JsonValue, String> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(JsonValue::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.push((key, val));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(JsonValue::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

/// Parse a JSON document (trailing whitespace allowed, nothing else).
pub fn parse_json(s: &str) -> Result<JsonValue, String> {
    let mut p = Parser { b: s.as_bytes(), i: 0 };
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

// ---------------------------------------------------------------------------
// Schema validation
// ---------------------------------------------------------------------------

// `transport_ops`/`retries`/`reexec_*` were added by the `ft/` PR under
// the evolution contract, like `simd_blocked` before them;
// `frames_*`/`coalesced_*` by the coalescing-plane PR the same way, and
// `wire_overhead_bytes` by the socket-fabric PR (TCP framing bytes,
// additive over the declared-payload counters; 0 on in-process fabrics).
const RANK_KEYS: [&str; 23] = [
    "rank",
    "messages_sent",
    "bytes_sent",
    "messages_received",
    "control_sent",
    "control_received",
    "recv_wait_us",
    "total_us",
    "work_units",
    "partition_bytes",
    "partition_bytes_pred",
    "accel_bytes",
    "transport_ops",
    "retries",
    "reexec_work_units",
    "reexec_bytes",
    "frames_sent",
    "frames_received",
    "coalesced_sent",
    "coalesced_received",
    "wire_overhead_bytes",
    "kernel",
    "spans",
];

// `simd_blocked` was added by the PR-7 kernel tier under the evolution
// contract (adding keys bumps nothing): readers must require the four
// known keys and ignore unknown ones.
const KERNEL_KEYS: [&str; 4] = ["list_list", "list_bitmap", "bitmap_bitmap", "simd_blocked"];

fn require<'v>(v: &'v JsonValue, key: &str, ctx: &str) -> Result<&'v JsonValue, String> {
    v.get(key).ok_or_else(|| format!("{ctx}: missing key \"{key}\""))
}

fn require_kernel(v: &JsonValue, ctx: &str) -> Result<(), String> {
    for k in KERNEL_KEYS {
        require(v, k, ctx)?
            .as_u64()
            .ok_or_else(|| format!("{ctx}: \"{k}\" must be a non-negative integer"))?;
    }
    Ok(())
}

/// Parse `json` and check it against snapshot schema [`SCHEMA_VERSION`].
/// Returns the parsed document so renderers don't parse twice.
pub fn validate_snapshot(json: &str) -> Result<JsonValue, String> {
    let v = parse_json(json)?;
    let ver = require(&v, "schema_version", "snapshot")?
        .as_u64()
        .ok_or("snapshot: schema_version must be an integer")?;
    if ver != SCHEMA_VERSION {
        return Err(format!("snapshot: schema_version {ver} != supported {SCHEMA_VERSION}"));
    }
    require(&v, "command", "snapshot")?.as_str().ok_or("snapshot: command must be a string")?;
    let domain = require(&v, "clock_domain", "snapshot")?
        .as_str()
        .ok_or("snapshot: clock_domain must be a string")?;
    if domain != "wall" && domain != "virtual" {
        return Err(format!("snapshot: unknown clock_domain \"{domain}\""));
    }
    let ranks = require(&v, "ranks", "snapshot")?
        .as_arr()
        .ok_or("snapshot: ranks must be an array")?;
    for (i, r) in ranks.iter().enumerate() {
        let ctx = format!("ranks[{i}]");
        for k in RANK_KEYS {
            require(r, k, &ctx)?;
        }
        require_kernel(require(r, "kernel", &ctx)?, &format!("{ctx}.kernel"))?;
        let spans = require(r, "spans", &ctx)?;
        require(spans, "recorded", &ctx)?
            .as_u64()
            .ok_or_else(|| format!("{ctx}.spans.recorded must be an integer"))?;
        require(spans, "dropped", &ctx)?
            .as_u64()
            .ok_or_else(|| format!("{ctx}.spans.dropped must be an integer"))?;
        let by_phase = require(spans, "by_phase_us", &ctx)?;
        for p in SpanPhase::ALL {
            require(by_phase, p.name(), &format!("{ctx}.spans.by_phase_us"))?
                .as_u64()
                .ok_or_else(|| {
                    format!("{ctx}.spans.by_phase_us.{} must be an integer", p.name())
                })?;
        }
    }
    require_kernel(require(&v, "kernels_global", "snapshot")?, "kernels_global")?;
    require(&v, "batches", "snapshot")?.as_arr().ok_or("snapshot: batches must be an array")?;
    require(&v, "phases", "snapshot")?.as_arr().ok_or("snapshot: phases must be an array")?;
    // `ft` is additive (present only on supervised runs), but when present
    // it must carry the full recovery summary.
    if let Some(ft) = v.get("ft") {
        for k in [
            "attempts",
            "salvaged_units",
            "partial_units",
            "reexec_work_units",
            "reexec_bytes",
        ] {
            require(ft, k, "ft")?
                .as_u64()
                .ok_or_else(|| format!("ft: \"{k}\" must be a non-negative integer"))?;
        }
        for k in ["degraded", "dead_ranks", "survivors", "trace_hash"] {
            require(ft, k, "ft")?;
        }
    }
    require(&v, "notes", "snapshot")?.as_arr().ok_or("snapshot: notes must be an array")?;
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::metrics::CommMetrics;
    use crate::obs::span::{Span, SpanLog};
    use std::time::Duration;

    fn synthetic_cluster() -> ClusterMetrics {
        let mk = |rank: u64| CommMetrics {
            messages_sent: rank + 1,
            bytes_sent: 10 * (rank + 1),
            messages_received: rank,
            recv_wait: Duration::from_micros(7 * rank),
            total: Duration::from_micros(100),
            work_units: 5,
            kernel: KernelStats {
                list_list: rank,
                list_bitmap: 1,
                bitmap_bitmap: 0,
                simd_blocked: 2,
            },
            spans: SpanLog {
                domain: ClockDomain::Virtual,
                spans: vec![
                    Span { phase: SpanPhase::Compute, t_start: 0, t_end: 60 },
                    Span { phase: SpanPhase::RecvWait, t_start: 60, t_end: 60 + 7 * rank },
                ],
                dropped: 0,
            },
            ..Default::default()
        };
        ClusterMetrics { per_rank: vec![mk(0), mk(1)] }
    }

    #[test]
    fn golden_snapshot_roundtrips_and_validates() {
        let mut reg = MetricsRegistry::new("count");
        reg.record_cluster(&synthetic_cluster());
        reg.record_global_kernels(KernelStats {
            list_list: 1,
            list_bitmap: 2,
            bitmap_bitmap: 0,
            simd_blocked: 3,
        });
        reg.record_phase("parse", 0.25);
        reg.note("workload=pa:160:6");
        let json = reg.snapshot_json();
        let v = validate_snapshot(&json).expect("snapshot must satisfy its own schema");
        assert_eq!(v.get("schema_version").unwrap().as_u64(), Some(SCHEMA_VERSION));
        assert_eq!(v.get("command").unwrap().as_str(), Some("count"));
        assert_eq!(v.get("clock_domain").unwrap().as_str(), Some("virtual"));
        let ranks = v.get("ranks").unwrap().as_arr().unwrap();
        assert_eq!(ranks.len(), 2);
        assert_eq!(ranks[1].get("recv_wait_us").unwrap().as_u64(), Some(7));
        let by_phase = ranks[1].get("spans").unwrap().get("by_phase_us").unwrap();
        assert_eq!(by_phase.get("compute").unwrap().as_u64(), Some(60));
        assert_eq!(by_phase.get("recv_wait").unwrap().as_u64(), Some(7));
        assert_eq!(v.get("phases").unwrap().as_arr().unwrap().len(), 1);
        // Determinism: same registry ⇒ identical bytes.
        assert_eq!(json, reg.snapshot_json());
    }

    #[test]
    fn validation_rejects_missing_keys_and_bad_version() {
        assert!(validate_snapshot("{}").is_err());
        assert!(validate_snapshot("{\"schema_version\": 999}").is_err());
        let mut reg = MetricsRegistry::new("count");
        reg.record_cluster(&synthetic_cluster());
        let good = reg.snapshot_json();
        let bad = good.replace("\"recv_wait_us\"", "\"recv_wait_renamed\"");
        assert!(validate_snapshot(&bad).is_err());
    }

    #[test]
    fn batches_and_notes_serialize() {
        let mut reg = MetricsRegistry::new("stream");
        reg.record_batches(&[BatchStats {
            delta: -3,
            triangles: 42,
            inserts: 4,
            deletes: 2,
            work_per_rank: vec![5, 6],
        }]);
        reg.note("quoted \"note\" with\nnewline");
        let json = reg.snapshot_json();
        let v = validate_snapshot(&json).unwrap();
        let batches = v.get("batches").unwrap().as_arr().unwrap();
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].get("delta").unwrap().as_i64(), Some(-3));
        assert_eq!(batches[0].get("triangles").unwrap().as_u64(), Some(42));
        assert_eq!(batches[0].get("work").unwrap().as_u64(), Some(11));
        let notes = v.get("notes").unwrap().as_arr().unwrap();
        assert_eq!(notes[0].as_str(), Some("quoted \"note\" with\nnewline"));
    }

    #[test]
    fn ft_section_serializes_and_validates() {
        let mut reg = MetricsRegistry::new("count");
        reg.record_cluster(&synthetic_cluster());
        // Absent unless recorded — unsupervised snapshots are unchanged.
        assert!(validate_snapshot(&reg.snapshot_json()).unwrap().get("ft").is_none());
        let rec = crate::ft::RecoveryReport {
            attempts: 1,
            dead_ranks: vec![2],
            survivors: Some(crate::ft::RankMap::surviving(4, &[2])),
            reexec_work_units: 77,
            reexec_bytes: 123,
            salvaged_units: 5,
            partial_units: 1,
            degraded: false,
        };
        reg.record_ft(&rec, Some(0xDEAD_BEEF));
        let json = reg.snapshot_json();
        let v = validate_snapshot(&json).unwrap();
        let ft = v.get("ft").expect("ft section present after record_ft");
        assert_eq!(ft.get("attempts").unwrap().as_u64(), Some(1));
        assert_eq!(ft.get("reexec_work_units").unwrap().as_u64(), Some(77));
        assert_eq!(ft.get("dead_ranks").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(ft.get("survivors").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(ft.get("trace_hash").unwrap().as_str(), Some("00000000deadbeef"));
        // Per-rank retry/re-execution counters are part of the rank rows.
        let ranks = v.get("ranks").unwrap().as_arr().unwrap();
        assert_eq!(ranks[0].get("retries").unwrap().as_u64(), Some(0));
        assert_eq!(ranks[0].get("transport_ops").unwrap().as_u64(), Some(0));
        // Determinism: same registry ⇒ identical bytes.
        assert_eq!(json, reg.snapshot_json());
    }

    #[test]
    fn parser_handles_core_grammar() {
        let v = parse_json(r#"{"a": [1, -2.5, true, false, null], "b": {"c": "x\ty"}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[0].as_u64(), Some(1));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(-2.5));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("x\ty"));
        assert!(parse_json("{\"a\": 1,}").is_err());
        assert!(parse_json("[1 2]").is_err());
        assert!(parse_json("{} trailing").is_err());
        assert_eq!(parse_json("[]").unwrap(), JsonValue::Arr(vec![]));
        assert_eq!(
            parse_json("\"\\u00e9\\u0041\"").unwrap(),
            JsonValue::Str("\u{e9}A".to_string())
        );
    }
}
