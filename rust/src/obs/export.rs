//! Chrome/Perfetto trace-event export (`--trace-out`).
//!
//! Emits the legacy Chrome trace-event JSON format — an object with a
//! `traceEvents` array of complete (`"ph": "X"`) duration events — which
//! Perfetto (<https://ui.perfetto.dev>) and `chrome://tracing` both load
//! directly. Layout: one process, one track (`tid`) per rank, `ts`/`dur`
//! in microseconds. On the virtual fabric 1 scheduler tick is exported
//! as 1 µs (DESIGN.md §11), and because span logs there replay
//! bit-identically under a seed, the emitted file is *byte-identical*
//! across runs — the conformance CLI relies on that.
//!
//! The emitter is deterministic by construction: fixed field order,
//! fixed event order (metadata first, then ranks in order, spans in log
//! order), no timestamps of its own.

use crate::comm::metrics::ClusterMetrics;
use crate::obs::registry::{parse_json, JsonValue};

/// Serialize a cluster run as one Perfetto trace: per rank, a
/// `thread_name` metadata event plus one `X` event per recorded span.
pub fn cluster_trace_json(process_name: &str, m: &ClusterMetrics) -> String {
    let mut ev = vec![format!(
        "{{\"name\": \"process_name\", \"ph\": \"M\", \"pid\": 0, \"tid\": 0, \
         \"args\": {{\"name\": \"{}\"}}}}",
        escape(process_name)
    )];
    for (rank, rm) in m.per_rank.iter().enumerate() {
        ev.push(format!(
            "{{\"name\": \"thread_name\", \"ph\": \"M\", \"pid\": 0, \"tid\": {rank}, \
             \"args\": {{\"name\": \"rank {rank} ({}, {} dropped)\"}}}}",
            rm.spans.domain.name(),
            rm.spans.dropped
        ));
        for s in &rm.spans.spans {
            ev.push(format!(
                "{{\"name\": \"{}\", \"ph\": \"X\", \"ts\": {}, \"dur\": {}, \
                 \"pid\": 0, \"tid\": {rank}}}",
                s.phase.name(),
                s.t_start,
                s.dur()
            ));
        }
    }
    wrap_events(&ev)
}

/// Serialize named sequential stages (e.g. the preprocessing pipeline's
/// per-workload phase timings) as one trace track: stage `i` starts where
/// stage `i-1` ended. Durations are given in seconds and exported in µs.
pub fn stages_trace_json(process_name: &str, stages: &[(String, f64)]) -> String {
    let mut ev = vec![format!(
        "{{\"name\": \"process_name\", \"ph\": \"M\", \"pid\": 0, \"tid\": 0, \
         \"args\": {{\"name\": \"{}\"}}}}",
        escape(process_name)
    )];
    let mut ts: u64 = 0;
    for (name, secs) in stages {
        let dur = (secs * 1e6).max(0.0) as u64;
        ev.push(format!(
            "{{\"name\": \"{}\", \"ph\": \"X\", \"ts\": {ts}, \"dur\": {dur}, \
             \"pid\": 0, \"tid\": 0}}",
            escape(name)
        ));
        ts += dur;
    }
    wrap_events(&ev)
}

fn wrap_events(events: &[String]) -> String {
    let mut s = String::with_capacity(64 + events.iter().map(|e| e.len() + 6).sum::<usize>());
    s.push_str("{\"displayTimeUnit\": \"ms\", \"traceEvents\": [\n");
    for (i, e) in events.iter().enumerate() {
        s.push_str("  ");
        s.push_str(e);
        s.push_str(if i + 1 < events.len() { ",\n" } else { "\n" });
    }
    s.push_str("]}\n");
    s
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Parse `json` and check it is a loadable trace-event document: a top
/// object with a `traceEvents` array whose entries carry `name`, `ph`,
/// `pid`, `tid` (and `ts`/`dur` for `X` events). Returns the event count.
pub fn validate_trace(json: &str) -> Result<usize, String> {
    let v = parse_json(json)?;
    let events = v
        .get("traceEvents")
        .and_then(JsonValue::as_arr)
        .ok_or("trace: missing traceEvents array")?;
    for (i, e) in events.iter().enumerate() {
        let ctx = format!("traceEvents[{i}]");
        let str_field = |key: &str| {
            e.get(key)
                .and_then(JsonValue::as_str)
                .ok_or_else(|| format!("{ctx}: missing string {key}"))
        };
        let int_field = |key: &str| {
            e.get(key)
                .and_then(JsonValue::as_u64)
                .ok_or_else(|| format!("{ctx}: missing integer {key}"))
        };
        str_field("name")?;
        let ph = str_field("ph")?;
        int_field("pid")?;
        int_field("tid")?;
        if ph == "X" {
            int_field("ts")?;
            int_field("dur")?;
        }
    }
    Ok(events.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::metrics::CommMetrics;
    use crate::obs::span::{ClockDomain, Span, SpanLog, SpanPhase};

    fn one_rank_cluster() -> ClusterMetrics {
        ClusterMetrics {
            per_rank: vec![CommMetrics {
                spans: SpanLog {
                    domain: ClockDomain::Virtual,
                    spans: vec![
                        Span { phase: SpanPhase::Compute, t_start: 0, t_end: 10 },
                        Span { phase: SpanPhase::Barrier, t_start: 10, t_end: 12 },
                    ],
                    dropped: 0,
                },
                ..Default::default()
            }],
        }
    }

    #[test]
    fn cluster_trace_is_valid_and_deterministic() {
        let m = one_rank_cluster();
        let a = cluster_trace_json("tricount count", &m);
        let b = cluster_trace_json("tricount count", &m);
        assert_eq!(a, b, "same metrics must serialize to identical bytes");
        // 1 process_name + 1 thread_name + 2 spans.
        assert_eq!(validate_trace(&a), Ok(4));
        let v = parse_json(&a).unwrap();
        let events = v.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events[2].get("name").unwrap().as_str(), Some("compute"));
        assert_eq!(events[2].get("dur").unwrap().as_u64(), Some(10));
        assert_eq!(events[3].get("ts").unwrap().as_u64(), Some(10));
    }

    #[test]
    fn stages_lay_out_sequentially() {
        let stages = vec![("parse x".to_string(), 0.001), ("build \"q\"".to_string(), 0.002)];
        let json = stages_trace_json("tricount bench-pipeline", &stages);
        assert_eq!(validate_trace(&json), Ok(3));
        let v = parse_json(&json).unwrap();
        let events = v.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events[1].get("ts").unwrap().as_u64(), Some(0));
        assert_eq!(events[1].get("dur").unwrap().as_u64(), Some(1000));
        assert_eq!(events[2].get("ts").unwrap().as_u64(), Some(1000));
        assert_eq!(events[2].get("name").unwrap().as_str(), Some("build \"q\""));
    }

    #[test]
    fn validate_trace_rejects_malformed() {
        assert!(validate_trace("{}").is_err());
        assert!(validate_trace("{\"traceEvents\": [{\"ph\": \"X\"}]}").is_err());
        assert!(validate_trace("not json").is_err());
    }
}
