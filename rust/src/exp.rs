//! Experiment harness — one driver per table/figure of the paper's
//! evaluation (see DESIGN.md §5 for the index). Run via
//! `tricount exp --id <id>` or `cargo bench`.

pub mod ablations;
pub mod cache;
pub mod report;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod fig12;
pub mod fig13;
pub mod fig14;
pub mod fig15;

use crate::error::{Error, Result};

/// An experiment driver: prints paper-shaped rows, optionally writes CSV.
pub struct Experiment {
    pub id: &'static str,
    pub paper_ref: &'static str,
    pub description: &'static str,
    pub run: fn(&Options) -> Result<report::Report>,
}

/// Common experiment options.
#[derive(Clone, Debug)]
pub struct Options {
    /// Workload scale multiplier (1.0 = DESIGN.md default sizes).
    pub scale: f64,
    /// Output directory for CSV (None = stdout only).
    pub out_dir: Option<String>,
    /// Quick mode: smaller sweeps for CI.
    pub quick: bool,
}

impl Default for Options {
    fn default() -> Self {
        Options { scale: 1.0, out_dir: Some("results".into()), quick: false }
    }
}

/// The registry, in paper order.
pub fn registry() -> Vec<Experiment> {
    vec![
        Experiment { id: "table1", paper_ref: "Table I", description: "dataset summary (presets vs paper)", run: table1::run },
        Experiment { id: "table2", paper_ref: "Table II", description: "memory of largest partition, ours vs PATRIC, P=100", run: table2::run },
        Experiment { id: "table3", paper_ref: "Table III", description: "runtime: PATRIC vs direct vs surrogate (+ counts)", run: table3::run },
        Experiment { id: "table4", paper_ref: "Table IV", description: "runtime: dynamic-LB vs PATRIC", run: table4::run },
        Experiment { id: "fig4", paper_ref: "Fig 4", description: "strong scaling, direct vs surrogate", run: fig4::run },
        Experiment { id: "fig5", paper_ref: "Fig 5", description: "effect of cost-estimation function f(v)", run: fig5::run },
        Experiment { id: "fig6", paper_ref: "Fig 6", description: "scalability with network size (§IV)", run: fig6::run },
        Experiment { id: "fig7", paper_ref: "Fig 7", description: "partition memory vs average degree", run: fig7::run },
        Experiment { id: "fig8", paper_ref: "Fig 8", description: "partition memory vs #processors", run: fig8::run },
        Experiment { id: "fig9", paper_ref: "Fig 9", description: "weak scaling (§IV)", run: fig9::run },
        Experiment { id: "fig12", paper_ref: "Fig 12", description: "strong scaling dyn-LB, f=1 vs f=d_v", run: fig12::run },
        Experiment { id: "fig13", paper_ref: "Fig 13", description: "idle time, static vs dynamic granularity", run: fig13::run },
        Experiment { id: "fig14", paper_ref: "Fig 14", description: "scalability with network size (§V) vs PATRIC", run: fig14::run },
        Experiment { id: "fig15", paper_ref: "Fig 15", description: "weak scaling (§V)", run: fig15::run },
        Experiment { id: "ablation-noise", paper_ref: "(extra)", description: "σ-sensitivity of dynamic-vs-static gap", run: ablations::run_noise },
        Experiment { id: "ablation-granularity", paper_ref: "(extra)", description: "task granularity policies", run: ablations::run_granularity },
        Experiment { id: "ablation-gallop", paper_ref: "(extra)", description: "intersection kernel switch point (measured)", run: ablations::run_gallop },
    ]
}

/// Look up and run one experiment by id (or `all`).
pub fn run_by_id(id: &str, opts: &Options) -> Result<()> {
    let reg = registry();
    if id == "all" {
        for e in &reg {
            run_one(e, opts)?;
        }
        return Ok(());
    }
    let e = reg
        .iter()
        .find(|e| e.id == id)
        .ok_or_else(|| Error::Config(format!("unknown experiment `{id}`; try `tricount exp --list`")))?;
    run_one(e, opts)
}

fn run_one(e: &Experiment, opts: &Options) -> Result<()> {
    println!("\n=== {} ({}) — {} ===", e.id, e.paper_ref, e.description);
    let report = (e.run)(opts)?;
    report.print();
    if let Some(dir) = &opts.out_dir {
        std::fs::create_dir_all(dir)?;
        let path = format!("{dir}/{}.csv", e.id);
        report.write_csv(&path)?;
        let json = format!("{dir}/{}.json", e.id);
        report.write_json(&json)?;
        println!("[written: {path}, {json}]");
    }
    Ok(())
}
