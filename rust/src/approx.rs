//! Approximation baselines the paper positions itself against (§I):
//!
//! * **DOULION** [13] — count triangles on an edge-sparsified graph (keep
//!   each edge with probability `p`) and rescale by `1/p³`; unbiased.
//! * **Wedge sampling** [18] — estimate the closure probability of a
//!   uniformly sampled wedge (2-path) and scale by the wedge count / 3.
//!
//! Both trade exactness for speed; the paper's contribution is *exact*
//! counting, so these serve as accuracy/cost baselines in the examples and
//! tests.

use crate::gen::rng::Rng;
use crate::graph::csr::Csr;
use crate::graph::ordering::Oriented;
use crate::seq::node_iterator;
use crate::VertexId;

/// DOULION: sparsify with keep-probability `p`, count exactly on the
/// sparsified graph, rescale by `1/p³`. Unbiased; variance shrinks as p→1.
pub fn doulion(g: &Csr, p: f64, rng: &mut Rng) -> f64 {
    assert!(p > 0.0 && p <= 1.0);
    let kept: Vec<(VertexId, VertexId)> =
        g.edges().filter(|_| rng.chance(p)).collect();
    let sparse = crate::graph::builder::from_edge_list(g.num_nodes(), kept)
        .expect("sparsified edges are valid");
    let t = node_iterator::count(&Oriented::from_graph(&sparse));
    t as f64 / (p * p * p)
}

/// Wedge sampling: sample `samples` uniform wedges (center chosen
/// ∝ d_v·(d_v−1)/2), check closure, return `closed_fraction · W / 3`
/// where `W` is the total wedge count.
pub fn wedge_sampling(g: &Csr, samples: usize, rng: &mut Rng) -> f64 {
    let n = g.num_nodes();
    // Wedge counts per node and cumulative distribution.
    let wedges: Vec<u64> = (0..n as VertexId)
        .map(|v| {
            let d = g.degree(v) as u64;
            d * d.saturating_sub(1) / 2
        })
        .collect();
    let total: u64 = wedges.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let mut cum = Vec::with_capacity(n);
    let mut acc = 0u64;
    for &w in &wedges {
        acc += w;
        cum.push(acc);
    }
    let mut closed = 0u64;
    for _ in 0..samples {
        // Sample a center ∝ wedges.
        let x = rng.below(total);
        let v = cum.partition_point(|&c| c <= x) as VertexId;
        let nv = g.neighbors(v);
        let d = nv.len();
        // Two distinct neighbors uniformly.
        let i = rng.below_usize(d);
        let mut j = rng.below_usize(d - 1);
        if j >= i {
            j += 1;
        }
        if g.has_edge(nv[i], nv[j]) {
            closed += 1;
        }
    }
    (closed as f64 / samples as f64) * total as f64 / 3.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::classic;

    #[test]
    fn doulion_p1_is_exact() {
        let g = classic::karate();
        let est = doulion(&g, 1.0, &mut Rng::seeded(1));
        assert_eq!(est as u64, classic::KARATE_TRIANGLES);
    }

    #[test]
    fn doulion_is_approximately_unbiased() {
        let g = crate::gen::pa::preferential_attachment(3000, 12, &mut Rng::seeded(2));
        let exact = node_iterator::count(&Oriented::from_graph(&g)) as f64;
        let mut rng = Rng::seeded(3);
        let trials = 30;
        let mean: f64 =
            (0..trials).map(|_| doulion(&g, 0.5, &mut rng)).sum::<f64>() / trials as f64;
        let rel = (mean - exact).abs() / exact;
        assert!(rel < 0.15, "mean {mean} vs exact {exact} (rel {rel:.3})");
    }

    #[test]
    fn wedge_sampling_converges() {
        let g = crate::gen::geometric::miami_like(4000, 20, &mut Rng::seeded(4));
        let exact = node_iterator::count(&Oriented::from_graph(&g)) as f64;
        let est = wedge_sampling(&g, 200_000, &mut Rng::seeded(5));
        let rel = (est - exact).abs() / exact;
        assert!(rel < 0.1, "est {est} vs exact {exact} (rel {rel:.3})");
    }

    #[test]
    fn wedge_sampling_zero_on_stars() {
        let g = classic::star(50);
        assert_eq!(wedge_sampling(&g, 10_000, &mut Rng::seeded(6)), 0.0);
    }

    #[test]
    fn wedge_sampling_exact_on_complete() {
        // Every wedge in K_n is closed → estimator = W/3 = C(n,3) exactly.
        let g = classic::complete(10);
        let est = wedge_sampling(&g, 5_000, &mut Rng::seeded(7));
        assert_eq!(est as u64, 120);
    }

    #[test]
    fn doulion_unbiased_on_er_within_concentration() {
        // ER is the near-regular regime: DOULION's variance is mild, so a
        // modest trial mean must sit close to the exact count.
        let g = crate::gen::erdos_renyi::gnm(2000, 16_000, &mut Rng::seeded(21));
        let exact = node_iterator::count(&Oriented::from_graph(&g)) as f64;
        assert!(exact > 0.0, "need a graph with triangles");
        let mut rng = Rng::seeded(22);
        let trials = 30;
        let mean: f64 =
            (0..trials).map(|_| doulion(&g, 0.5, &mut rng)).sum::<f64>() / trials as f64;
        let rel = (mean - exact).abs() / exact;
        assert!(rel < 0.2, "mean {mean} vs exact {exact} (rel {rel:.3})");
    }

    #[test]
    fn wedge_closure_fraction_concentrates_on_pa() {
        // Hoeffding: with k iid wedge samples, the closure fraction p̂
        // deviates from p = 3T/W by more than ε with prob ≤ 2·exp(−2kε²).
        // k = 40_000, ε = 0.03 ⇒ prob < 10⁻³¹ — a failure here is a bug,
        // not bad luck.
        let g = crate::gen::pa::preferential_attachment(3000, 16, &mut Rng::seeded(23));
        let o = Oriented::from_graph(&g);
        let t = node_iterator::count(&o) as f64;
        let wedges: f64 = (0..g.num_nodes() as VertexId)
            .map(|v| {
                let d = g.degree(v) as f64;
                d * (d - 1.0) / 2.0
            })
            .sum();
        let k = 40_000;
        let est = wedge_sampling(&g, k, &mut Rng::seeded(24));
        let p_hat = 3.0 * est / wedges;
        let p = 3.0 * t / wedges;
        assert!((p_hat - p).abs() < 0.03, "p̂ {p_hat:.4} vs p {p:.4}");
    }

    #[test]
    fn prop_doulion_p1_is_exact_on_arbitrary_graphs() {
        crate::prop::quickcheck("doulion(p=1) == exact", |rng, _| {
            let g = crate::prop::arb_graph(rng, 80);
            let exact = node_iterator::count(&Oriented::from_graph(&g)) as f64;
            let est = doulion(&g, 1.0, rng);
            if est != exact {
                return Err(format!("p=1 estimate {est} != exact {exact}"));
            }
            // Any keep-probability must produce a finite, non-negative
            // estimate (no panic, no NaN) on arbitrary inputs.
            let p = 0.05 + 0.95 * rng.f64();
            let est = doulion(&g, p, rng);
            if !(est.is_finite() && est >= 0.0) {
                return Err(format!("p={p}: degenerate estimate {est}"));
            }
            Ok(())
        });
    }

    #[test]
    fn degenerate_inputs_return_zero_without_panicking() {
        // Empty graphs — zero nodes and zero edges — and triangle-free
        // graphs must yield exactly 0 from both estimators.
        for g in [crate::graph::csr::Csr::empty(0), crate::graph::csr::Csr::empty(12)] {
            assert_eq!(doulion(&g, 0.5, &mut Rng::seeded(1)), 0.0);
            assert_eq!(wedge_sampling(&g, 1_000, &mut Rng::seeded(2)), 0.0);
        }
        // Triangle-free with wedges (star) and without hubs (Petersen).
        for g in [classic::star(40), classic::petersen()] {
            assert_eq!(doulion(&g, 1.0, &mut Rng::seeded(3)), 0.0);
            assert_eq!(doulion(&g, 0.4, &mut Rng::seeded(4)), 0.0);
            assert_eq!(wedge_sampling(&g, 5_000, &mut Rng::seeded(5)), 0.0);
        }
    }
}
