//! Preprocessing-pipeline benchmark (`tricount bench-pipeline`).
//!
//! The paper's counting phase assumes the graph is already resident and
//! ordered; this module measures everything that happens *before* a single
//! triangle is counted — parse → CSR build → degree relabel → orientation
//! + hub index — serially and at each requested `--build-threads` count,
//! and records the result as the repo's perf baseline
//! (`BENCH_pipeline.json`, the shared [`crate::exp::report`] JSON schema).
//!
//! Every timed run is also a correctness check: the radix build at every
//! thread count is compared bit-for-bit against the seed's comparison-sort
//! builder (kept as [`crate::graph::builder::from_edge_list_sort_baseline`]),
//! and the parallel orientation against the serial one. Divergence is an
//! error — the CI smoke step runs a small preset through here so the
//! determinism guarantee is enforced on every push.

use std::io::Write as _;
use std::time::Instant;

use crate::adj::HubThreshold;
use crate::config::build_workload;
use crate::error::{Error, Result};
use crate::exp::report::{Cell, Report};
use crate::graph::builder::{from_edge_list_sort_baseline, from_edge_list_threads};
use crate::graph::io::{parse_edge_list_bytes, read_tcg, write_tcg};
use crate::graph::ordering::Oriented;
use crate::graph::relabel::degree_order_permutation;
use crate::VertexId;

/// What to measure.
#[derive(Clone, Debug)]
pub struct Options {
    /// Workload specs (`pa:<n>:<d>` etc.; see [`build_workload`]).
    pub workloads: Vec<String>,
    /// Thread counts to sweep. 1 is always measured first (it is the
    /// speedup reference).
    pub threads: Vec<usize>,
    /// Timed repetitions per stage; the median is reported.
    pub reps: usize,
    /// Generator seed.
    pub seed: u64,
    /// Hub policy for the orientation stage.
    pub hub_threshold: HubThreshold,
}

impl Default for Options {
    fn default() -> Self {
        Options {
            workloads: vec![
                "pa:100000:64".into(),
                "rmat:16:16".into(),
                "er:200000:16".into(),
            ],
            threads: vec![1, 2, 4, 8],
            reps: 3,
            seed: 42,
            hub_threshold: HubThreshold::Auto,
        }
    }
}

/// Median-of-`reps` wall time for `f`, plus `f`'s last result.
fn timed<R>(reps: usize, mut f: impl FnMut() -> R) -> (f64, R) {
    let reps = reps.max(1);
    let mut samples = Vec::with_capacity(reps);
    let mut out = None;
    for _ in 0..reps {
        let t0 = Instant::now();
        out = Some(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (samples[reps / 2], out.unwrap())
}

/// One thread count's stage timings over one workload. `parse_par_s` is
/// the chunk-parallel text parse at this thread count — the parse stage a
/// `--build-threads t` run actually executes, hence the one in `total_s`.
struct StageTimes {
    parse_par_s: f64,
    build_s: f64,
    relabel_s: f64,
    orient_s: f64,
}

impl StageTimes {
    fn total(&self) -> f64 {
        self.parse_par_s + self.build_s + self.relabel_s + self.orient_s
    }
}

fn divergence(workload: &str, threads: usize, stage: &str) -> Error {
    Error::InvalidGraph(format!(
        "bench-pipeline: {stage} at build-threads={threads} diverged from the \
         serial reference on `{workload}` — the deterministic-build guarantee is broken"
    ))
}

/// Run the sweep; returns the report (also the `BENCH_pipeline.json`
/// payload). Errors if any parallel stage output differs from serial.
pub fn run(opts: &Options) -> Result<Report> {
    let mut threads = opts.threads.clone();
    threads.retain(|&t| t >= 1);
    if !threads.contains(&1) {
        threads.push(1);
    }
    threads.sort_unstable();
    threads.dedup();

    let mut report = Report::new([
        "workload",
        "n",
        "m",
        "threads",
        "parse_s",
        "parse_text_par_s",
        "load_tcg_s",
        "build_radix_s",
        "build_sort_s",
        "relabel_s",
        "orient_hub_s",
        "total_s",
        "speedup_vs_serial",
    ]);

    for spec in &opts.workloads {
        let g = build_workload(spec, 1.0, opts.seed)?;
        let n = g.num_nodes();
        let edges: Vec<(VertexId, VertexId)> = g.edges().collect();
        let m = edges.len();

        // Serialize once: the parse stage reads this in-memory edge list,
        // so parse timings measure the byte scanner, not disk.
        let mut text: Vec<u8> = Vec::with_capacity(m * 14 + 64);
        writeln!(text, "# bench-pipeline {spec} n={n} m={m}")?;
        for &(u, v) in &edges {
            writeln!(text, "{u} {v}")?;
        }

        // Serial references — the sort baseline doubles as the timing
        // baseline the radix build must beat, and the serial byte-scan
        // parse is the reference for both the chunked parse and the
        // zero-parse `.tcg` load.
        let (sort_s, csr_ref) = timed(opts.reps, || from_edge_list_sort_baseline(n, edges.clone()));
        let csr_ref = csr_ref?;
        let (parse_serial_s, parse_ref) =
            timed(opts.reps, || parse_edge_list_bytes(&text, 1).expect("bench parse"));

        // `.tcg` load: write the reference CSR once, time the bulk reload,
        // and gate text-vs-binary equality (the formats must be two
        // encodings of the same graph).
        let tcg_path = std::env::temp_dir().join(format!(
            "tricount_bench_{}_{}.tcg",
            std::process::id(),
            spec.replace([':', '/'], "_")
        ));
        write_tcg(&csr_ref, &tcg_path)?;
        let (load_tcg_s, tcg_loaded) =
            timed(opts.reps, || read_tcg(&tcg_path).expect("bench .tcg load"));
        let _ = std::fs::remove_file(&tcg_path);
        if tcg_loaded != csr_ref {
            return Err(divergence(spec, 1, ".tcg round-trip"));
        }

        let mut serial_total = 0.0f64;
        let mut serial_oriented: Option<Oriented> = None;

        for &t in &threads {
            let (parse_par_s, parsed) =
                timed(opts.reps, || parse_edge_list_bytes(&text, t).expect("bench parse"));
            if parsed != parse_ref {
                return Err(divergence(spec, t, "chunk-parallel parse"));
            }

            let (build_s, built) =
                timed(opts.reps, || from_edge_list_threads(n, edges.clone(), t));
            let built = built?;
            if built != csr_ref {
                return Err(divergence(spec, t, "radix CSR build"));
            }

            let (relabel_s, relabeled) = timed(opts.reps, || {
                let perm = degree_order_permutation(&built);
                let mapped: Vec<(VertexId, VertexId)> = built
                    .edges()
                    .map(|(u, v)| (perm[u as usize], perm[v as usize]))
                    .collect();
                from_edge_list_threads(n, mapped, t).expect("relabel rebuild")
            });

            let (orient_s, oriented) = timed(opts.reps, || {
                Oriented::from_graph_threads(&relabeled, opts.hub_threshold, t)
            });
            match &serial_oriented {
                None => serial_oriented = Some(oriented),
                Some(r) => {
                    let same = r.offsets() == oriented.offsets()
                        && r.targets() == oriented.targets()
                        && r.degrees() == oriented.degrees()
                        && r.hub_stats() == oriented.hub_stats();
                    if !same {
                        return Err(divergence(spec, t, "orientation + hub index"));
                    }
                }
            }

            let st = StageTimes { parse_par_s, build_s, relabel_s, orient_s };
            if t == 1 {
                serial_total = st.total();
            }
            let speedup = if st.total() > 0.0 { serial_total / st.total() } else { 0.0 };
            report.row([
                spec.clone().into(),
                n.into(),
                m.into(),
                t.into(),
                Cell::Secs(parse_serial_s),
                Cell::Secs(st.parse_par_s),
                Cell::Secs(load_tcg_s),
                Cell::Secs(st.build_s),
                Cell::Secs(sort_s),
                Cell::Secs(st.relabel_s),
                Cell::Secs(st.orient_s),
                Cell::Secs(st.total()),
                speedup.into(),
            ]);
        }
    }
    report.note(format!(
        "determinism verified: radix CSR == comparison-sort CSR and parallel \
         orientation == serial at every thread count above (cores on this host: {})",
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    ));
    report.note(
        "build_sort_s = the seed's serial comparison-sort builder \
         (from_edge_list_sort_baseline), the timing baseline the radix build replaces"
            .to_string(),
    );
    report.note(
        "parse_s = serial byte-scan text parse (per-workload constant); \
         parse_text_par_s = chunk-parallel parse at this row's thread count \
         (the stage total_s includes); load_tcg_s = zero-parse binary reload \
         of the same graph, text-vs-binary equality gated"
            .to_string(),
    );
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_runs_and_verifies() {
        let opts = Options {
            workloads: vec!["pa:3000:8".into()],
            threads: vec![2], // 1 is inserted automatically
            reps: 1,
            seed: 7,
            hub_threshold: HubThreshold::Auto,
        };
        let r = run(&opts).unwrap();
        assert_eq!(r.rows.len(), 2, "one row per thread count (1 and 2)");
        assert_eq!(r.columns.len(), 13);
        // JSON emission stays schema-valid.
        assert!(r.to_json().contains("\"build_radix_s\""));
    }

    #[test]
    fn bad_workload_is_an_error() {
        let opts = Options { workloads: vec!["wat:1".into()], ..Options::default() };
        assert!(run(&opts).is_err());
    }
}
