//! §V — the fast parallel algorithm with **dynamic load balancing**
//! (paper Fig 11).
//!
//! Assumes every machine stores the whole network (shared read-only `Arc`
//! here, faithful to that assumption — unlike the §IV drivers, whose ranks
//! hold only their [`crate::partition::owned::OwnedPartition`]). Rank 0 is
//! the dedicated **coordinator**; ranks `1..P` are **workers**.
//!
//! * Initial assignment (Eqn 1): half the total cost is split into `P−1`
//!   equal tasks, picked up deterministically without coordinator traffic.
//! * Dynamic phase (Eqn 2): the coordinator serves tasks from a queue whose
//!   granularity shrinks geometrically; an idle worker sends `⟨i⟩`, gets
//!   `⟨v,t⟩` back, or `⟨terminate⟩` when the queue is dry.
//! * Cost functions `f(v) = 1` or `f(v) = d_v` (paper §V-A: cheap,
//!   zero-overhead choices), plus the richer estimators for ablations.

use std::sync::Arc;

use crate::algo::driver::{self, RunResult};
use crate::algo::tasks::{self, Task};
use crate::comm::threads::{Comm, Payload, Progress, ProgressUnit};
use crate::comm::transport::{RetryPolicy, Wire, WireReader};
use crate::error::Result;
use crate::config::CostFn;
use crate::graph::ordering::Oriented;
use crate::obs::span::SpanPhase;
use crate::partition::cost::{cost_vector, prefix_sums};
use crate::seq::node_iterator;
use crate::testkit::sim::Fabric;
use crate::testkit::trace::TraceReport;
use crate::TriangleCount;

/// Task-granularity policy for the dynamic phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    /// Paper's scheme: size shrinks by `1/(P−1)` of the remainder (Eqn 2).
    Shrinking,
    /// Static strawman (Fig 13): the dynamic region is cut into `k` tasks
    /// of equal cost up front.
    Fixed(usize),
}

/// Wire messages of the coordinator/worker protocol.
pub enum Msg {
    /// Worker `i` is idle (paper `⟨i⟩`; sender rank is carried by the
    /// envelope). Carries the worker's count of *completed* dynamic tasks
    /// so the coordinator can tell "finished my last assignment" from
    /// "never received it" — a request whose `completed` lags the
    /// assignment counter retransmits the outstanding task instead of
    /// leaking it (DESIGN.md §13).
    Request { completed: u64 },
    /// A task assignment `⟨v, t⟩`.
    Assign(Task),
    /// No more tasks (`⟨terminate⟩`).
    Terminate,
}

impl Wire for Msg {
    fn write_to(&self, out: &mut Vec<u8>) {
        match self {
            Msg::Request { completed } => {
                out.push(0);
                completed.write_to(out);
            }
            Msg::Assign(t) => {
                out.push(1);
                t.write_to(out);
            }
            Msg::Terminate => out.push(2),
        }
    }
    fn read_from(r: &mut WireReader<'_>) -> Result<Self> {
        match r.u8()? {
            0 => Ok(Msg::Request { completed: u64::read_from(r)? }),
            1 => Ok(Msg::Assign(Task::read_from(r)?)),
            2 => Ok(Msg::Terminate),
            b => Err(crate::error::Error::Comm(format!(
                "dynamic-lb: unknown message discriminant {b}"
            ))),
        }
    }
}

impl Payload for Msg {
    fn size_bytes(&self) -> u64 {
        match self {
            Msg::Request { .. } => 16,
            Msg::Assign(_) => 16,
            Msg::Terminate => 8,
        }
    }
}

/// Options for a dynamic-LB run.
#[derive(Clone, Copy, Debug)]
pub struct Options {
    pub cost_fn: CostFn,
    pub granularity: Granularity,
}

impl Default for Options {
    fn default() -> Self {
        Options { cost_fn: CostFn::Degree, granularity: Granularity::Shrinking }
    }
}

/// Run with `p` ranks (1 coordinator + `p−1` workers; `p ≥ 2` or the run
/// is rejected as an invalid configuration).
pub fn run(graph: &Arc<Oriented>, p: usize, opts: Options) -> Result<RunResult> {
    run_on(&Fabric::Channel, graph, p, opts).0
}

/// [`run`] on an explicit fabric (conformance entry point).
pub fn run_on(
    fabric: &Fabric,
    graph: &Arc<Oriented>,
    p: usize,
    opts: Options,
) -> (Result<RunResult>, Option<TraceReport>) {
    run_hooked_on(fabric, graph, p, opts, None)
}

/// [`run_on`] with an `ft/` checkpoint sink (`ft::supervisor` entry
/// point). Workers ack each task with its exact count the moment it
/// finishes, so recovery re-counts only tasks nobody acked.
pub fn run_hooked_on(
    fabric: &Fabric,
    graph: &Arc<Oriented>,
    p: usize,
    opts: Options,
    progress: Option<Arc<dyn Progress>>,
) -> (Result<RunResult>, Option<TraceReport>) {
    if p < 2 {
        let e = crate::error::Error::Config(format!(
            "dynamic-lb needs P >= 2 (a coordinator and at least one worker), got P={p}"
        ));
        return (Err(e), None);
    }
    let costs = cost_vector(graph, opts.cost_fn);
    let prefix = Arc::new(prefix_sums(&costs));
    let workers = p - 1;

    // Deterministic pre-computation shared by all ranks (paper: "all P
    // processors work in parallel to determine initial tasks").
    let tp = tasks::half_point(&prefix);
    let initial = Arc::new(tasks::equal_cost_tasks(&prefix, 0, tp, workers));
    let queue: Arc<Vec<Task>> = Arc::new(match opts.granularity {
        Granularity::Shrinking => tasks::shrinking_tasks(&prefix, tp, workers),
        Granularity::Fixed(k) => tasks::fixed_tasks(&prefix, tp, k),
    });
    launch(fabric, graph, p, initial, queue, progress)
}

/// Run an *explicit* task list through the coordinator/worker protocol —
/// no initial assignment, every task served dynamically. This is the
/// supervisor's recovery entry point (§V semantics: survivors steal the
/// unclaimed ranges of a dead rank), which is why executed tasks show as
/// [`SpanPhase::Recovery`] work when a sink is installed.
pub fn run_tasks_on(
    fabric: &Fabric,
    graph: &Arc<Oriented>,
    p: usize,
    work_list: &[Task],
    progress: Option<Arc<dyn Progress>>,
) -> (Result<RunResult>, Option<TraceReport>) {
    if p < 2 {
        let e = crate::error::Error::Config(format!(
            "dynamic-lb needs P >= 2 (a coordinator and at least one worker), got P={p}"
        ));
        return (Err(e), None);
    }
    let initial = Arc::new(Vec::new());
    let queue = Arc::new(work_list.to_vec());
    launch(fabric, graph, p, initial, queue, progress)
}

fn launch(
    fabric: &Fabric,
    graph: &Arc<Oriented>,
    p: usize,
    initial: Arc<Vec<Task>>,
    queue: Arc<Vec<Task>>,
    progress: Option<Arc<dyn Progress>>,
) -> (Result<RunResult>, Option<TraceReport>) {
    let recovery = initial.is_empty() && progress.is_some();
    let (results, trace) = fabric.try_run_hooked::<Msg, TriangleCount, _>(p, progress, |c| {
        if c.rank() == 0 {
            coordinator(c, &queue)
        } else {
            worker(c, graph.clone(), &initial, recovery)
        }
    });
    match results {
        Ok(r) => (Ok(driver::fold(r)), trace),
        Err(e) => (Err(e), trace),
    }
}

/// Coordinator (paper Fig 11 lines 4-12). Comm failures propagate as
/// `Err` through [`Cluster::try_run`] instead of poisoning the cluster.
///
/// Fault hardening: the coordinator remembers, per worker, how many tasks
/// it assigned and which one is outstanding. A request whose `completed`
/// count lags the assignment counter means the last `Assign` was lost on
/// the wire — it is retransmitted rather than skipped, so no task can leak
/// out of the queue. Duplicate terminate-requests (a worker retrying a
/// lost `Terminate`) are answered again without double-counting the
/// worker.
fn coordinator(c: &mut Comm<Msg>, queue: &Arc<Vec<Task>>) -> Result<TriangleCount> {
    let mut next = 0usize;
    let mut terminated = 0usize;
    let workers = c.size() - 1;
    let mut assigned = vec![0u64; c.size()];
    let mut outstanding: Vec<Option<Task>> = vec![None; c.size()];
    let mut done = vec![false; c.size()];
    while terminated < workers {
        let (src, msg) = c.recv()?;
        match msg {
            Msg::Request { completed } => {
                if completed < assigned[src] {
                    let task = outstanding[src]
                        .expect("a lagging worker always has an outstanding task");
                    c.send_control(src, Msg::Assign(task))?;
                } else if next < queue.len() {
                    let t = queue[next];
                    next += 1;
                    assigned[src] += 1;
                    outstanding[src] = Some(t);
                    c.send_control(src, Msg::Assign(t))?;
                } else {
                    c.send_control(src, Msg::Terminate)?;
                    if !done[src] {
                        done[src] = true;
                        terminated += 1;
                    }
                }
            }
            _ => unreachable!("coordinator only receives requests"),
        }
    }
    c.reduce_sum(0)?;
    Ok(0)
}

/// Worker (paper Fig 11 lines 14-23).
fn worker(
    c: &mut Comm<Msg>,
    graph: Arc<Oriented>,
    initial: &Arc<Vec<Task>>,
    recovery: bool,
) -> Result<TriangleCount> {
    let wid = c.rank() - 1; // worker index 0..P-1
    let phase = if recovery { SpanPhase::Recovery } else { SpanPhase::Compute };
    let mut t: TriangleCount = 0;
    let mut work = 0u64;
    let mut completed = 0u64;

    // Initial task — deterministic, no coordinator involved (Eqn 1).
    // Each task executes under its own Compute span, so the timeline
    // shows the task granularity and the request/assign gaps between.
    if let Some(task) = initial.get(wid) {
        c.span_begin(phase);
        let dt = run_task(&graph, *task, &mut t, &mut work);
        c.span_end();
        c.ckpt_ack(ProgressUnit::task(task.start, task.len), dt);
    }

    // Dynamic phase: request → assign/terminate loop. A lost assignment
    // or terminate is retried under the bounded policy; when retries
    // exhaust against a coordinator the liveness board still calls alive,
    // it can only be past termination (parked in the reduce with every
    // worker accounted for), so the lost message was a `Terminate` and
    // self-terminating is exact. A dead coordinator propagates as `Err`.
    let policy = RetryPolicy::default();
    let mut last_done: Option<Task> = None;
    'outer: loop {
        c.send_control(0, Msg::Request { completed })?;
        let msg = 'recv: loop {
            let got =
                c.recv_retry(0, &policy, |c| c.send_control(0, Msg::Request { completed }))?;
            match got {
                // Retries exhausted, coordinator alive ⇒ lost Terminate.
                None => break 'outer,
                // A retransmit of the task we just ran (the coordinator
                // answered a duplicate request): skip it without counting
                // — the answer to the *current* request is still coming.
                Some((_src, Msg::Assign(task))) if last_done == Some(task) => {
                    continue 'recv;
                }
                Some((_src, m)) => break 'recv m,
            }
        };
        match msg {
            Msg::Assign(task) => {
                c.span_begin(phase);
                let dt = run_task(&graph, task, &mut t, &mut work);
                c.span_end();
                completed += 1;
                last_done = Some(task);
                c.ckpt_ack(ProgressUnit::task(task.start, task.len), dt);
            }
            Msg::Terminate => break,
            Msg::Request { .. } => unreachable!("workers never receive requests"),
        }
    }

    c.metrics.work_units = work;
    c.reduce_sum(t)?;
    Ok(t)
}

/// `COUNTTRIANGLES⟨v,t⟩` (paper Fig 10) + work accounting (the executed
/// hybrid-dispatch measure, consistent with every other driver's
/// `work_units`). Returns the task's own contribution (the checkpoint
/// ack sum).
#[inline]
fn run_task(o: &Oriented, task: Task, t: &mut TriangleCount, work: &mut u64) -> u64 {
    let mut dt = 0u64;
    node_iterator::count_range(o, task.start, task.end(), &mut dt);
    for v in task.range() {
        *work += node_iterator::node_work_true(o, v);
    }
    *t += dt;
    dt
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::classic;

    fn run_on(g: &crate::graph::csr::Csr, p: usize, opts: Options) -> RunResult {
        let o = Arc::new(Oriented::from_graph(g));
        run(&o, p, opts).unwrap()
    }

    #[test]
    fn exact_on_classics_all_cost_fns() {
        for cost_fn in [
            CostFn::Unit,
            CostFn::Degree,
            CostFn::PatricBest,
            CostFn::SurrogateNew,
            CostFn::Hybrid,
        ] {
            let opts = Options { cost_fn, granularity: Granularity::Shrinking };
            assert_eq!(run_on(&classic::karate(), 4, opts).triangles, 45, "{cost_fn:?}");
            assert_eq!(run_on(&classic::complete(13), 3, opts).triangles, 286);
        }
    }

    #[test]
    fn fixed_granularity_also_exact() {
        let opts = Options { cost_fn: CostFn::Degree, granularity: Granularity::Fixed(10) };
        assert_eq!(run_on(&classic::karate(), 5, opts).triangles, 45);
    }

    #[test]
    fn matches_sequential_on_random() {
        use crate::gen::rng::Rng;
        let g = crate::gen::pa::preferential_attachment(800, 12, &mut Rng::seeded(44));
        let o = Oriented::from_graph(&g);
        let expect = node_iterator::count(&o);
        for p in [2, 3, 6, 10] {
            assert_eq!(run_on(&g, p, Options::default()).triangles, expect, "P={p}");
        }
    }

    #[test]
    fn minimum_cluster_is_two() {
        assert_eq!(run_on(&classic::complete(6), 2, Options::default()).triangles, 20);
    }

    #[test]
    fn p_below_two_is_a_config_error_not_a_panic() {
        let o = Arc::new(Oriented::from_graph(&classic::karate()));
        for p in [0, 1] {
            match run(&o, p, Options::default()) {
                Err(crate::error::Error::Config(msg)) => {
                    assert!(msg.contains("P >= 2"), "unexpected message: {msg}");
                    assert!(msg.contains(&format!("P={p}")), "unexpected message: {msg}");
                }
                Err(other) => panic!("P={p}: expected Config error, got {other}"),
                Ok(_) => panic!("P={p}: expected an error"),
            }
        }
    }

    #[test]
    fn coordinator_does_no_counting_work() {
        let r = run_on(&classic::karate(), 4, Options::default());
        assert_eq!(r.metrics.per_rank[0].work_units, 0);
        assert!(r.metrics.per_rank[1..].iter().any(|m| m.work_units > 0));
    }

    #[test]
    fn prop_dynamic_matches_sequential() {
        crate::prop::quickcheck("dynamic == sequential", |rng, _| {
            let g = crate::prop::arb_graph(rng, 60);
            let o = Arc::new(Oriented::from_graph(&g));
            let expect = node_iterator::count(&o);
            let p = 2 + rng.below_usize(5);
            let got = run(&o, p, Options::default()).map_err(|e| e.to_string())?.triangles;
            if got != expect {
                return Err(format!("P={p}: got {got}, expected {expect}"));
            }
            Ok(())
        });
    }
}
