//! PATRIC [21] — the overlapping-partition baseline.
//!
//! Each rank's partition contains `N_u` for its core nodes **and** for
//! every node referenced by a core neighborhood, so counting needs no
//! communication at all: rank `i` runs the Fig-1 loop over its core range
//! and the only messages are the final reduction. Its cost is paid in
//! *memory* (overlap blow-up, Table II / Fig 7) and in *static* load
//! balance.
//!
//! The rank now physically holds that blow-up: its
//! [`crate::partition::owned::OwnedPartition`] materializes core *and*
//! ghost rows behind a sorted member table, so the bytes
//! [`crate::partition::overlap::overlap_sizes`] predicts are bytes the
//! rank actually allocated — measured and gated, like the non-overlapping
//! scheme's.

use crate::adj;
use crate::adj::hub::HubThreshold;
use crate::algo::driver::{self, RunResult};
use crate::comm::threads::{Comm, Progress, ProgressUnit};
use crate::error::Result;
use crate::graph::csr::Csr;
use crate::graph::ordering::Oriented;
use crate::obs::span::SpanPhase;
use crate::partition::overlap::overlap_sizes;
use crate::partition::owned::{self, OwnedPartition};
use crate::testkit::sim::Fabric;
use crate::testkit::trace::TraceReport;
use crate::TriangleCount;

/// Run PATRIC over consecutive core ranges (balanced with its own best
/// estimator `f(v) = Σ_{u∈N_v}(d̂_v + d̂_u)` by the callers that reproduce
/// the paper's comparisons). Takes the unoriented graph too: overlap
/// membership is defined by *full* neighborhoods (PATRIC loads complete
/// neighborhoods and orients inside the partition).
pub fn run(
    g: &Csr,
    graph: &Oriented,
    ranges: &[std::ops::Range<u32>],
    hub: HubThreshold,
) -> Result<RunResult> {
    run_on(&Fabric::Channel, g, graph, ranges, hub).0
}

/// [`run`] on an explicit fabric (conformance entry point). PATRIC sends
/// no data messages, so the only protocol surface the virtual fabric
/// exercises is the final reduction — which is exactly where a dead rank
/// must surface as an `Err` instead of a hang.
pub fn run_on(
    fabric: &Fabric,
    g: &Csr,
    graph: &Oriented,
    ranges: &[std::ops::Range<u32>],
    hub: HubThreshold,
) -> (Result<RunResult>, Option<TraceReport>) {
    run_hooked_on(fabric, g, graph, ranges, hub, None)
}

/// [`run_on`] with an `ft/` checkpoint sink (`ft::supervisor` entry
/// point). PATRIC needs no communication to count, so the whole core
/// range is acked with its exact sum the moment the local sweep ends —
/// recovery then re-extracts partitions for the un-acked ranges only.
pub fn run_hooked_on(
    fabric: &Fabric,
    g: &Csr,
    graph: &Oriented,
    ranges: &[std::ops::Range<u32>],
    hub: HubThreshold,
    progress: Option<std::sync::Arc<dyn Progress>>,
) -> (Result<RunResult>, Option<TraceReport>) {
    let parts = owned::extract_overlapping(g, graph, ranges, hub);
    let predicted = overlap_sizes(g, graph, ranges).iter().map(|s| s.bytes()).collect();
    driver::run_owned_hooked_on::<u64, _>(fabric, parts, predicted, progress, rank_main)
}

fn rank_main(c: &mut Comm<u64>, part: &OwnedPartition) -> Result<TriangleCount> {
    let mut t: TriangleCount = 0;
    let mut work = 0u64;
    // PATRIC is embarrassingly local until the final reduce: one Compute
    // span covers the entire counting sweep.
    c.span_begin(SpanPhase::Compute);
    for v in part.range() {
        let vv = part.view(v);
        for &u in vv.list() {
            // u's list is in the overlap portion — local, by construction.
            let vu = part.view(u);
            adj::intersect_count(vv, vu, &mut t);
            work += adj::intersect_cost(vv, vu);
        }
    }
    c.span_end();
    let r = part.range();
    c.ckpt_ack(ProgressUnit::range(r.start, r.end), t);
    c.metrics.work_units = work;
    c.reduce_sum(t)?;
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CostFn;
    use crate::graph::classic;
    use crate::partition::balance::balanced_ranges;
    use crate::partition::cost::{cost_vector, prefix_sums};

    fn run_on(g: &crate::graph::csr::Csr, p: usize) -> RunResult {
        let o = Oriented::from_graph(g);
        let prefix = prefix_sums(&cost_vector(&o, CostFn::PatricBest));
        let ranges = balanced_ranges(&prefix, p);
        run(g, &o, &ranges, HubThreshold::Auto).unwrap()
    }

    #[test]
    fn exact_on_classics() {
        for p in [1, 3, 6] {
            assert_eq!(run_on(&classic::karate(), p).triangles, 45);
            assert_eq!(run_on(&classic::complete(15), p).triangles, 455);
            assert_eq!(run_on(&classic::petersen(), p).triangles, 0);
        }
    }

    #[test]
    fn zero_data_messages() {
        let r = run_on(&classic::karate(), 4);
        assert_eq!(r.metrics.totals().messages_sent, 0);
    }

    #[test]
    fn overlap_residency_measured_and_exact() {
        // A clique makes every partition hold (nearly) the whole graph —
        // the §III blow-up, now visible as measured resident bytes that
        // dwarf the non-overlapping scheme's.
        let g = classic::complete(60);
        let o = Oriented::from_graph(&g);
        let ranges = vec![0..20u32, 20..40u32, 40..60u32];
        let r = run(&g, &o, &ranges, HubThreshold::Off).unwrap();
        assert_eq!(r.triangles, 34_220);
        assert_eq!(r.metrics.partition_accounting_divergence(), None);
        let s = crate::algo::surrogate::run(&o, &ranges, HubThreshold::Off).unwrap();
        assert!(
            r.metrics.max_partition_bytes() > 2 * s.metrics.max_partition_bytes(),
            "overlap {} must dwarf non-overlap {}",
            r.metrics.max_partition_bytes(),
            s.metrics.max_partition_bytes()
        );
    }

    #[test]
    fn agrees_with_surrogate() {
        let g = crate::gen::rmat::rmat(9, 6, Default::default(), &mut crate::gen::rng::Rng::seeded(5));
        let o = Oriented::from_graph(&g);
        let prefix = prefix_sums(&cost_vector(&o, CostFn::PatricBest));
        let ranges = balanced_ranges(&prefix, 5);
        let a = run(&g, &o, &ranges, HubThreshold::Auto).unwrap().triangles;
        let b = crate::algo::surrogate::run(&o, &ranges, HubThreshold::Auto).unwrap().triangles;
        assert_eq!(a, b);
    }
}
