//! PATRIC [21] — the overlapping-partition baseline.
//!
//! Each rank's partition contains `N_u` for its core nodes **and** for
//! every node referenced by a core list, so counting needs no communication
//! at all: rank `i` runs the Fig-1 loop over its core range and the only
//! messages are the final reduction. Its cost is paid in *memory*
//! (overlap blow-up, Table II / Fig 7) and in *static* load balance.
//!
//! In-process, the overlap partition's content is a subset of the shared
//! `Oriented`, so ranks read it directly; the memory a real PATRIC rank
//! would allocate is accounted by [`crate::partition::overlap`].

use std::sync::Arc;

use crate::adj;
use crate::algo::surrogate::RunResult;
use crate::comm::metrics::ClusterMetrics;
use crate::comm::threads::Cluster;
use crate::error::Result;
use crate::graph::ordering::Oriented;
use crate::TriangleCount;

/// Run PATRIC over consecutive core ranges (balanced with its own best
/// estimator `f(v) = Σ_{u∈N_v}(d̂_v + d̂_u)` by the callers that reproduce
/// the paper's comparisons).
pub fn run(graph: &Arc<Oriented>, ranges: &[std::ops::Range<u32>]) -> Result<RunResult> {
    let p = ranges.len();
    let ranges: Arc<Vec<std::ops::Range<u32>>> = Arc::new(ranges.to_vec());
    let results = Cluster::run::<u64, TriangleCount, _>(p, |c| {
        let range = ranges[c.rank()].clone();
        let o = graph.clone();
        let mut t: TriangleCount = 0;
        let mut work = 0u64;
        for v in range {
            let vv = o.view(v);
            for &u in vv.list() {
                // u's list is in the overlap portion — local on a real
                // PATRIC rank, shared read-only here.
                let vu = o.view(u);
                adj::intersect_count(vv, vu, &mut t);
                work += adj::intersect_cost(vv, vu);
            }
        }
        c.metrics.work_units = work;
        c.reduce_sum(t);
        t
    })?;
    let mut metrics = ClusterMetrics::default();
    let mut triangles = 0;
    for (t, m) in results {
        triangles += t;
        metrics.per_rank.push(m);
    }
    Ok(RunResult { triangles, metrics })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CostFn;
    use crate::graph::classic;
    use crate::partition::balance::balanced_ranges;
    use crate::partition::cost::{cost_vector, prefix_sums};

    fn run_on(g: &crate::graph::csr::Csr, p: usize) -> RunResult {
        let o = Arc::new(Oriented::from_graph(g));
        let prefix = prefix_sums(&cost_vector(&o, CostFn::PatricBest));
        let ranges = balanced_ranges(&prefix, p);
        run(&o, &ranges).unwrap()
    }

    #[test]
    fn exact_on_classics() {
        for p in [1, 3, 6] {
            assert_eq!(run_on(&classic::karate(), p).triangles, 45);
            assert_eq!(run_on(&classic::complete(15), p).triangles, 455);
            assert_eq!(run_on(&classic::petersen(), p).triangles, 0);
        }
    }

    #[test]
    fn zero_data_messages() {
        let r = run_on(&classic::karate(), 4);
        assert_eq!(r.metrics.totals().messages_sent, 0);
    }

    #[test]
    fn agrees_with_surrogate() {
        use crate::partition::balance::owner_table;
        let g = crate::gen::rmat::rmat(9, 6, Default::default(), &mut crate::gen::rng::Rng::seeded(5));
        let o = Arc::new(Oriented::from_graph(&g));
        let prefix = prefix_sums(&cost_vector(&o, CostFn::PatricBest));
        let ranges = balanced_ranges(&prefix, 5);
        let owner = Arc::new(owner_table(&ranges, o.num_nodes()));
        let a = run(&o, &ranges).unwrap().triangles;
        let b = crate::algo::surrogate::run(&o, &ranges, &owner).unwrap().triangles;
        assert_eq!(a, b);
    }
}
