//! 2D tile-partitioned counting — the Tom–Karypis three-phase exchange
//! (arXiv 1907.09575) with coalesced communication (arXiv 2302.11443),
//! the fourth §IV-family driver.
//!
//! Rank `(i, j)` of the r×c grid owns tile `A_ij` — the oriented edges
//! `(v, u)` with `v ∈ R_i`, `u ∈ C_j` ([`crate::partition::tile2d`]) —
//! and counts the masked product `(A·A) ∘ A` restricted to its tile:
//! each mask edge `(v, u)` contributes `|N_v^out ∩ N_u^in|` (the triangle
//! `v → w → u` with `v → u`; every oriented triangle has exactly one
//! source→sink mask edge, so tile partials are globally disjoint — which
//! is what makes them salvageable under `ft/` supervision).
//!
//! Three phases:
//! 1. **Row broadcast** — each rank sends its tile's rows to the `c−1`
//!    peers in its grid row; afterwards every rank of grid row `i` holds
//!    the full rows `N_v` for `v ∈ R_i` (`≈ m/r` received bytes).
//! 2. **Column broadcast** — each rank sends its tile's *columns* (the
//!    tile CSC) to the `r−1` peers in its grid column; afterwards every
//!    rank of grid column `j` holds the full in-columns for `u ∈ C_j`
//!    (`≈ m/c` received bytes).
//! 3. **Tile-local intersection** — for every local mask edge, intersect
//!    the assembled row and column through [`adj::intersect_count`].
//!
//! Per-rank traffic is `m/r + m/c ≈ 2m/√P` vs the 1D drivers' O(m). All
//! pieces travel as coalesced frames ([`crate::comm::coalesce`]): one
//! record per row/column, packed to the flush watermark, counted as
//! frames vs logical records and per broadcast tag class in
//! [`crate::comm::metrics::CommMetrics`]. The whole protocol is one-way
//! (`Done` control markers end each broadcast; per-edge FIFO delivery
//! orders them after the frames), runs on any [`Fabric`], and replays
//! deterministically on the virtual one.

use std::ops::Range;

use crate::adj::hub::HubThreshold;
use crate::adj::{self, NeighborView};
use crate::algo::driver::{self, RunResult};
use crate::comm::coalesce::{CoalescingBuffer, Frame, DEFAULT_WATERMARK_WORDS};
use crate::comm::metrics::CommMetrics;
use crate::comm::threads::{Comm, Payload, Progress, ProgressUnit};
use crate::comm::transport::{Wire, WireReader};
use crate::error::Result;
use crate::graph::ordering::Oriented;
use crate::obs::span::SpanPhase;
use crate::partition::owned::OwnedPartition;
use crate::partition::tile2d::{self, Grid, TileLayout};
use crate::testkit::sim::Fabric;
use crate::testkit::trace::TraceReport;
use crate::{TriangleCount, VertexId};

/// Wire messages of the 2D exchange. Row/column pieces travel as
/// coalesced frames (one `[vertex, len, ids…]` record per non-empty
/// row/column); `*Done` markers are control messages closing a peer's
/// broadcast (FIFO per directed edge ⇒ they arrive after every frame).
pub enum Msg {
    /// Row-broadcast frame: records are `(v, N_v ∩ C_sender)` pieces.
    Row(Frame),
    /// Column-broadcast frame: records are `(u, in-sources ∩ R_sender)`.
    Col(Frame),
    /// The sender finished its row broadcast toward this peer.
    RowDone,
    /// The sender finished its column broadcast toward this peer.
    ColDone,
}

impl Payload for Msg {
    fn size_bytes(&self) -> u64 {
        match self {
            Msg::Row(f) | Msg::Col(f) => f.bytes(),
            Msg::RowDone | Msg::ColDone => 8,
        }
    }
}

impl Wire for Msg {
    fn write_to(&self, out: &mut Vec<u8>) {
        match self {
            Msg::Row(f) => {
                out.push(0);
                f.write_to(out);
            }
            Msg::Col(f) => {
                out.push(1);
                f.write_to(out);
            }
            Msg::RowDone => out.push(2),
            Msg::ColDone => out.push(3),
        }
    }
    fn read_from(r: &mut WireReader<'_>) -> Result<Self> {
        match r.u8()? {
            0 => Ok(Msg::Row(Frame::read_from(r)?)),
            1 => Ok(Msg::Col(Frame::read_from(r)?)),
            2 => Ok(Msg::RowDone),
            3 => Ok(Msg::ColDone),
            b => Err(crate::error::Error::Comm(format!(
                "tile2d: unknown message discriminant {b}"
            ))),
        }
    }
}

/// The exact frame sequences rank `(i, j)` broadcasts — row frames to
/// every grid-row peer, column frames to every grid-column peer (each
/// peer receives an identical clone; packing order is row/column
/// ascending, so the plan is a pure function of the tile). The
/// communication simulator replays this same plan, which is what makes
/// predicted tile2d bytes == measured bytes exact.
pub(crate) struct BcastPlan {
    pub row_frames: Vec<Frame>,
    pub col_frames: Vec<Frame>,
}

impl BcastPlan {
    /// (frames, logical records, payload bytes) of one row broadcast.
    pub fn row_cost(&self) -> (u64, u64, u64) {
        cost_of(&self.row_frames)
    }

    /// (frames, logical records, payload bytes) of one column broadcast.
    pub fn col_cost(&self) -> (u64, u64, u64) {
        cost_of(&self.col_frames)
    }
}

fn cost_of(frames: &[Frame]) -> (u64, u64, u64) {
    (
        frames.len() as u64,
        frames.iter().map(|f| f.items).sum(),
        frames.iter().map(|f| f.bytes()).sum(),
    )
}

/// The tile's CSC: per column `u ∈ col_block`, the id-sorted sources
/// `v ∈ R_i` with `(v, u)` in the tile (rows ascend ⇒ lists sorted).
pub(crate) fn tile_csc(tile: &OwnedPartition, col_block: &Range<u32>) -> Vec<Vec<VertexId>> {
    let mut cols: Vec<Vec<VertexId>> = vec![Vec::new(); col_block.len()];
    for v in tile.range() {
        for &u in tile.nbrs(v) {
            cols[(u - col_block.start) as usize].push(v);
        }
    }
    cols
}

/// Build the broadcast plan for one tile (see [`BcastPlan`]).
pub(crate) fn bcast_plan(tile: &OwnedPartition, csc: &[Vec<VertexId>], col_start: u32) -> BcastPlan {
    let mut row_frames = Vec::new();
    let mut buf = CoalescingBuffer::new(DEFAULT_WATERMARK_WORDS);
    for v in tile.range() {
        let nv = tile.nbrs(v);
        if nv.is_empty() {
            continue; // an absent record reads back as an empty piece
        }
        if let Some(f) = buf.push(v, nv) {
            row_frames.push(f);
        }
    }
    row_frames.extend(buf.flush());

    let mut col_frames = Vec::new();
    let mut buf = CoalescingBuffer::new(DEFAULT_WATERMARK_WORDS);
    for (k, list) in csc.iter().enumerate() {
        if list.is_empty() {
            continue;
        }
        if let Some(f) = buf.push(col_start + k as u32, list) {
            col_frames.push(f);
        }
    }
    col_frames.extend(buf.flush());
    BcastPlan { row_frames, col_frames }
}

/// Run the 2D driver on `p` ranks (grid + blocks derived internally by
/// [`tile2d::layout`]); `hub` sets the per-tile hub-bitmap policy.
pub fn run(graph: &Oriented, p: usize, hub: HubThreshold) -> Result<RunResult> {
    run_on(&Fabric::Channel, graph, p, hub).0
}

/// [`run`] on an explicit fabric — the conformance suite drives this
/// protocol through adversarial virtual schedules; the trace is `Some`
/// iff the fabric is virtual.
pub fn run_on(
    fabric: &Fabric,
    graph: &Oriented,
    p: usize,
    hub: HubThreshold,
) -> (Result<RunResult>, Option<TraceReport>) {
    run_hooked_on(fabric, graph, p, hub, None)
}

/// [`run_on`] with an `ft/` checkpoint sink. Tile partials are globally
/// disjoint (each triangle resolves at exactly one tile's mask edge), so
/// ranks publish monotone partials during the sweep and ack their tile
/// sum — the supervisor salvages acked tiles and recounts only the
/// missing ones ([`count_tile_seq`]).
pub fn run_hooked_on(
    fabric: &Fabric,
    graph: &Oriented,
    p: usize,
    hub: HubThreshold,
    progress: Option<std::sync::Arc<dyn Progress>>,
) -> (Result<RunResult>, Option<TraceReport>) {
    // Decorrelate ids from degree first (tile2d::shuffled, fixed seed) —
    // interval blocks over the raw degree order cannot balance tiles.
    let graph = tile2d::shuffled(graph);
    let layout = tile2d::layout(&graph, p);
    let parts = tile2d::extract_tiles(&graph, &layout, hub);
    let predicted = tile2d::tile_sizes(&graph, &layout).iter().map(|s| s.bytes()).collect();
    let layout = &layout;
    driver::run_owned_hooked_on::<Msg, _>(fabric, parts, predicted, progress, move |c, part| {
        rank_main(c, part, layout)
    })
}

/// Received-piece assembly state for one rank: a slot per (row, sending
/// grid column) and per (column, sending grid row). Blocks are ascending
/// id-intervals, so concatenating slots in block order yields id-sorted
/// full rows/columns.
struct RecvState {
    row_start: u32,
    col_start: u32,
    /// `row_slots[v - row_start][peer_j]` = `N_v ∩ C_peer_j`.
    row_slots: Vec<Vec<Vec<VertexId>>>,
    /// `col_slots[u - col_start][peer_i]` = in-sources of `u` in `R_peer_i`.
    col_slots: Vec<Vec<Vec<VertexId>>>,
    row_done: usize,
    col_done: usize,
}

impl RecvState {
    fn new(rb: &Range<u32>, cb: &Range<u32>, grid: Grid) -> Self {
        RecvState {
            row_start: rb.start,
            col_start: cb.start,
            row_slots: vec![vec![Vec::new(); grid.c]; rb.len()],
            col_slots: vec![vec![Vec::new(); grid.r]; cb.len()],
            row_done: 0,
            col_done: 0,
        }
    }

    fn absorb(&mut self, metrics: &mut CommMetrics, grid: Grid, src: usize, msg: Msg) {
        let (src_i, src_j) = grid.coords(src).expect("tile peers are active ranks");
        match msg {
            Msg::Row(f) => {
                metrics.frames_received += 1;
                metrics.coalesced_received += f.items;
                metrics.row_bcast_received += f.items;
                for (v, piece) in f.records() {
                    self.row_slots[(v - self.row_start) as usize][src_j] = piece.to_vec();
                }
            }
            Msg::Col(f) => {
                metrics.frames_received += 1;
                metrics.coalesced_received += f.items;
                metrics.col_bcast_received += f.items;
                for (u, piece) in f.records() {
                    self.col_slots[(u - self.col_start) as usize][src_i] = piece.to_vec();
                }
            }
            Msg::RowDone => self.row_done += 1,
            Msg::ColDone => self.col_done += 1,
        }
    }

    fn complete(&self, grid: Grid) -> bool {
        self.row_done == grid.c - 1 && self.col_done == grid.r - 1
    }
}

/// The per-rank program: broadcast (phases 1–2), assemble, intersect
/// (phase 3), reduce.
fn rank_main(c: &mut Comm<Msg>, part: &OwnedPartition, layout: &TileLayout) -> Result<TriangleCount> {
    let grid = layout.grid;
    let Some((i, j)) = grid.coords(c.rank()) else {
        // Remainder rank (r·c < P): empty tile, nothing to exchange —
        // contribute 0 to the reduce.
        c.reduce_sum(0)?;
        return Ok(0);
    };
    let rb = layout.row_blocks[i].clone();
    let cb = layout.col_blocks[j].clone();
    let csc = tile_csc(part, &cb);
    let plan = bcast_plan(part, &csc, cb.start);
    let mut st = RecvState::new(&rb, &cb, grid);

    // Phases 1–2: broadcast this tile along the grid row, then the grid
    // column, draining incoming pieces opportunistically between sends.
    for pj in 0..grid.c {
        if pj == j {
            continue;
        }
        let dst = grid.rank_of(i, pj);
        for f in &plan.row_frames {
            c.metrics.frames_sent += 1;
            c.metrics.coalesced_sent += f.items;
            c.metrics.row_bcast_sent += f.items;
            c.send(dst, Msg::Row(f.clone()))?;
            while let Some((src, msg)) = c.try_recv() {
                st.absorb(&mut c.metrics, grid, src, msg);
            }
        }
        c.send_control(dst, Msg::RowDone)?;
    }
    for pi in 0..grid.r {
        if pi == i {
            continue;
        }
        let dst = grid.rank_of(pi, j);
        for f in &plan.col_frames {
            c.metrics.frames_sent += 1;
            c.metrics.coalesced_sent += f.items;
            c.metrics.col_bcast_sent += f.items;
            c.send(dst, Msg::Col(f.clone()))?;
            while let Some((src, msg)) = c.try_recv() {
                st.absorb(&mut c.metrics, grid, src, msg);
            }
        }
        c.send_control(dst, Msg::ColDone)?;
    }
    while !st.complete(grid) {
        let (src, msg) = c.recv()?;
        st.absorb(&mut c.metrics, grid, src, msg);
    }

    // Phase 3: assemble and intersect. Full columns are cached (a column
    // serves every mask edge pointing at it); full rows are assembled
    // per row into a reused buffer.
    c.span_begin(SpanPhase::Compute);
    let cols: Vec<Vec<VertexId>> = (0..cb.len())
        .map(|k| {
            let mut full = Vec::new();
            for pi in 0..grid.r {
                if pi == i {
                    full.extend_from_slice(&csc[k]);
                } else {
                    full.extend_from_slice(&st.col_slots[k][pi]);
                }
            }
            full
        })
        .collect();
    let unit = ProgressUnit::batch(grid.rank_of(i, j) as u32);
    let mut t: TriangleCount = 0;
    let mut work = 0u64;
    let mut row_buf: Vec<VertexId> = Vec::new();
    for v in rb.clone() {
        let local = part.nbrs(v);
        row_buf.clear();
        for pj in 0..grid.c {
            if pj == j {
                row_buf.extend_from_slice(local);
            } else {
                row_buf.extend_from_slice(&st.row_slots[(v - rb.start) as usize][pj]);
            }
        }
        let rv = NeighborView::sorted(&row_buf);
        for &u in local {
            let uv = NeighborView::sorted(&cols[(u - cb.start) as usize]);
            adj::intersect_count(rv, uv, &mut t);
            work += adj::intersect_cost(rv, uv);
        }
        // Monotone partial every 1024 rows — the degrade floor.
        if (v - rb.start) % 1024 == 1023 {
            c.ckpt_partial(unit, t);
        }
    }
    c.span_end();
    c.ckpt_partial(unit, t);
    c.ckpt_ack(unit, t);
    c.metrics.work_units = work;
    c.reduce_sum(t)?;
    Ok(t)
}

/// Sequential recount of one tile's exact partial — the `ft/` salvage
/// path recounts only the tiles the fault left un-acked. `o` must be the
/// *shuffled* graph ([`tile2d::shuffled`]) the `layout` was built over —
/// the same pairing the live driver used, so salvaged and recounted
/// tiles mix freely. Returns `(count, work-units)`; work is charged per
/// wedge probe so recovery cost is reported in the same units as the
/// live sweep.
pub fn count_tile_seq(o: &Oriented, layout: &TileLayout, rank: usize) -> (TriangleCount, u64) {
    let Some((i, j)) = layout.grid.coords(rank) else {
        return (0, 0);
    };
    let rb = layout.row_blocks[i].clone();
    let cb = layout.col_blocks[j].clone();
    let mut t: TriangleCount = 0;
    let mut work = 0u64;
    for v in rb {
        let nv = o.nbrs(v);
        let lo = nv.partition_point(|&u| u < cb.start);
        let hi = nv.partition_point(|&u| u < cb.end);
        for &u in &nv[lo..hi] {
            // |N_v^out ∩ N_u^in| by probing u in each wedge row.
            for &w in nv {
                if o.nbrs(w).binary_search(&u).is_ok() {
                    t += 1;
                }
                work += 1;
            }
        }
    }
    (t, work)
}

/// Upper bound on one tile's count (degrade policy): every mask edge
/// `(v, u)` closes at most `d̂_v` wedges, so the tile is bounded by
/// `Σ_{v ∈ R_i} |N_v ∩ C_j| · d̂_v`. O(m/r) per tile.
pub fn tile_upper_bound(o: &Oriented, layout: &TileLayout, rank: usize) -> u64 {
    let Some((i, j)) = layout.grid.coords(rank) else {
        return 0;
    };
    let cb = layout.col_blocks[j].clone();
    let mut upper = 0u64;
    for v in layout.row_blocks[i].clone() {
        let nv = o.nbrs(v);
        let lo = nv.partition_point(|&u| u < cb.start);
        let hi = nv.partition_point(|&u| u < cb.end);
        upper += (hi - lo) as u64 * nv.len() as u64;
    }
    upper
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::rng::Rng;
    use crate::graph::classic;

    fn oracle(o: &Oriented) -> TriangleCount {
        crate::seq::node_iterator::count(o)
    }

    #[test]
    fn karate_exact_at_many_p() {
        let o = Oriented::from_graph(&classic::karate());
        for p in [1, 2, 4, 5, 8, 9, 13, 16] {
            let r = run(&o, p, HubThreshold::Auto).unwrap();
            assert_eq!(r.triangles, classic::KARATE_TRIANGLES, "P={p}");
        }
    }

    #[test]
    fn matches_oracle_across_generators() {
        let mut rng = Rng::seeded(77);
        let graphs = vec![
            crate::gen::pa::preferential_attachment(700, 8, &mut rng),
            crate::gen::rmat::rmat(9, 6, crate::gen::rmat::RmatParams::default(), &mut rng),
            crate::gen::erdos_renyi::gnm(500, 3000, &mut rng),
        ];
        for (gi, g) in graphs.iter().enumerate() {
            let o = Oriented::from_graph(g);
            let expect = oracle(&o);
            for p in [2, 6, 9, 16] {
                let r = run(&o, p, HubThreshold::Auto).unwrap();
                assert_eq!(r.triangles, expect, "graph {gi} P={p}");
            }
        }
    }

    #[test]
    fn measured_tile_bytes_equal_prediction() {
        let g = crate::gen::pa::preferential_attachment(900, 10, &mut Rng::seeded(5));
        let o = Oriented::from_graph(&g);
        let r = run(&o, 9, HubThreshold::Auto).unwrap();
        assert_eq!(r.metrics.partition_accounting_divergence(), None);
        assert!(r.metrics.max_partition_bytes() > 0);
        assert_eq!(r.triangles, oracle(&o));
    }

    #[test]
    fn broadcast_tag_classes_are_symmetric() {
        let g = crate::gen::pa::preferential_attachment(600, 8, &mut Rng::seeded(31));
        let o = Oriented::from_graph(&g);
        let r = run(&o, 6, HubThreshold::Auto).unwrap();
        let t = r.metrics.totals();
        assert_eq!(t.messages_sent, t.messages_received);
        assert_eq!(t.control_sent, t.control_received);
        assert_eq!(t.frames_sent, t.frames_received);
        assert_eq!(t.coalesced_sent, t.coalesced_received);
        assert_eq!(t.row_bcast_sent, t.row_bcast_received);
        assert_eq!(t.col_bcast_sent, t.col_bcast_received);
        assert!(t.row_bcast_sent > 0, "2×3 grid row-broadcasts");
        assert!(t.col_bcast_sent > 0);
        assert_eq!(t.coalesced_sent, t.row_bcast_sent + t.col_bcast_sent);
        // Aggregation: many records per frame on a dense-enough graph.
        assert!(t.frames_sent < t.coalesced_sent);
        assert!(r.metrics.aggregation_ratio() > 1.0);
    }

    #[test]
    fn tile_partials_are_disjoint_and_exact() {
        // Σ per-tile sequential recounts == oracle — the ft/ salvage
        // invariant (each triangle attributed to exactly one tile).
        let g = crate::gen::erdos_renyi::gnm(400, 2600, &mut Rng::seeded(13));
        let o = Oriented::from_graph(&g);
        let expect = oracle(&o);
        let sh = tile2d::shuffled(&o);
        for p in [4, 9, 13] {
            let l = tile2d::layout(&sh, p);
            let mut sum = 0u64;
            for rank in 0..p {
                let (t, _) = count_tile_seq(&sh, &l, rank);
                assert!(t <= tile_upper_bound(&sh, &l, rank), "P={p} rank={rank}");
                sum += t;
            }
            assert_eq!(sum, expect, "P={p}");
        }
    }

    #[test]
    fn per_rank_sums_match_tile_recounts() {
        // The live driver's per-rank returns equal the sequential
        // per-tile recounts — recovery can mix salvaged and recounted
        // tiles freely.
        let g = crate::gen::pa::preferential_attachment(500, 7, &mut Rng::seeded(41));
        let o = Oriented::from_graph(&g);
        let p = 6;
        // The recount must pair the shuffled graph with its layout —
        // exactly what the live driver ran over.
        let sh = tile2d::shuffled(&o);
        let l = tile2d::layout(&sh, p);
        let r = run(&o, p, HubThreshold::Auto).unwrap();
        assert_eq!(r.triangles, oracle(&o));
        let per_tile: Vec<u64> = (0..p).map(|k| count_tile_seq(&sh, &l, k).0).collect();
        assert_eq!(per_tile.iter().sum::<u64>(), r.triangles);
    }

    #[test]
    fn remainder_ranks_idle_exactly() {
        let o = Oriented::from_graph(&classic::karate());
        let r = run(&o, 5, HubThreshold::Auto).unwrap(); // 2×2 grid + 1 idle
        assert_eq!(r.triangles, classic::KARATE_TRIANGLES);
        let idle = &r.metrics.per_rank[4];
        assert_eq!(idle.messages_sent, 0);
        assert_eq!(idle.work_units, 0);
        assert_eq!(idle.partition_bytes, 8);
    }

    #[test]
    fn empty_graph_and_single_rank() {
        let o = Oriented::from_graph(&crate::graph::csr::Csr::empty(10));
        let r = run(&o, 4, HubThreshold::Auto).unwrap();
        assert_eq!(r.triangles, 0);
        let o = Oriented::from_graph(&classic::karate());
        let r = run(&o, 1, HubThreshold::Auto).unwrap();
        assert_eq!(r.triangles, classic::KARATE_TRIANGLES);
        assert_eq!(r.metrics.totals().messages_sent, 0);
    }
}
