//! Shared driver harness for the §IV partitioned algorithms.
//!
//! `surrogate::run`, `direct::run` and `patric::run` used to each repeat
//! the same boilerplate: clone ranges into an `Arc`, launch the cluster,
//! fold per-rank `(triangles, metrics)` into a [`RunResult`]. The harness
//! owns that once — and, more importantly, it owns the *memory discipline*:
//! every rank program receives `&OwnedPartition` (a fully materialized
//! per-rank subgraph) and nothing else, so no §IV counting rank closure
//! can capture the shared `Arc<Oriented>`. The harness records each rank's
//! **measured** partition residency next to the scheme's arithmetic
//! prediction; `tricount count` gates on their exact equality.
//!
//! Every driver is fabric-generic: the `*_on` entry points take a
//! [`Fabric`] and run the identical rank program over the production
//! channel transport or the seeded virtual transport the conformance
//! suite schedules adversarially (`testkit::sim`, DESIGN.md §10).

use std::sync::Arc;

use crate::comm::metrics::{ClusterMetrics, CommMetrics};
use crate::comm::threads::{Comm, Payload, Progress};
use crate::error::Result;
use crate::partition::owned::OwnedPartition;
use crate::testkit::sim::Fabric;
use crate::testkit::trace::TraceReport;
use crate::TriangleCount;

/// Result of a parallel run.
#[derive(Debug)]
pub struct RunResult {
    pub triangles: TriangleCount,
    pub metrics: ClusterMetrics,
}

/// Fold per-rank results into a [`RunResult`] (shared by the owned-partition
/// harness below and the §V dynamic-LB driver, which keeps the whole graph
/// per machine and therefore has no partitions to account).
pub(crate) fn fold(results: Vec<(TriangleCount, CommMetrics)>) -> RunResult {
    let mut metrics = ClusterMetrics::default();
    let mut triangles = 0;
    for (t, m) in results {
        triangles += t;
        metrics.per_rank.push(m);
    }
    RunResult { triangles, metrics }
}

/// Run a fallible per-rank program over owned partitions, one rank per
/// partition, on the chosen fabric. `predicted[i]` is the scheme's byte
/// prediction for partition `i`
/// ([`crate::partition::nonoverlap::PartitionSize::bytes`] or
/// [`crate::partition::overlap::OverlapSize::bytes`]); the measured
/// residency is taken from the partition each rank actually held. The
/// trace is `Some` iff the fabric is virtual, and is returned even when
/// the run errors (fault schedules are replay-checkable).
pub(crate) fn run_owned_on<M, F>(
    fabric: &Fabric,
    parts: Vec<OwnedPartition>,
    predicted: Vec<u64>,
    rank_main: F,
) -> (Result<RunResult>, Option<TraceReport>)
where
    M: Payload,
    F: Fn(&mut Comm<M>, &OwnedPartition) -> Result<TriangleCount> + Sync,
{
    run_owned_hooked_on(fabric, parts, predicted, None, rank_main)
}

/// [`run_owned_on`] with an `ft/` checkpoint sink installed on every rank
/// — the supervised entry point (`ft::supervisor`).
pub(crate) fn run_owned_hooked_on<M, F>(
    fabric: &Fabric,
    parts: Vec<OwnedPartition>,
    predicted: Vec<u64>,
    progress: Option<Arc<dyn Progress>>,
    rank_main: F,
) -> (Result<RunResult>, Option<TraceReport>)
where
    M: Payload,
    F: Fn(&mut Comm<M>, &OwnedPartition) -> Result<TriangleCount> + Sync,
{
    let p = parts.len();
    debug_assert_eq!(p, predicted.len());
    let parts = &parts;
    let (results, trace) = fabric.try_run_hooked::<M, TriangleCount, _>(p, progress, |c| {
        let part = &parts[c.rank()];
        c.metrics.partition_bytes = part.resident_bytes();
        c.metrics.accel_bytes = part.accel_bytes();
        rank_main(c, part)
    });
    let results = match results {
        Ok(r) => r,
        Err(e) => return (Err(e), trace),
    };
    let mut run = fold(results);
    for (m, pred) in run.metrics.per_rank.iter_mut().zip(predicted) {
        m.partition_bytes_pred = pred;
    }
    (Ok(run), trace)
}
