//! Task model for the §V dynamic load balancer.
//!
//! A task `⟨v, t⟩` (paper Definition 2) is a consecutive node range
//! `{v, …, v+t−1}`; its size `S(v,t) = Σ f(v+i)` (Definition 4). This
//! module implements the paper's task-construction policies:
//!
//! * **Initial assignment** (Eqn 1): find `t'` with
//!   `S(0,t') ≈ ½·S(0,n)` and split `⟨0,t'⟩` into `P−1` equal-size tasks,
//!   one per worker, deterministically.
//! * **Shrinking dynamic tasks** (Eqn 2): repeatedly carve the *remaining*
//!   cost into `1/(P−1)` chunks, so granularity decreases geometrically
//!   toward atomic tasks.
//! * **Fixed granularity** — the static strawman Fig 13 compares against.

use crate::comm::transport::{Wire, WireReader};
use crate::VertexId;

/// A task `⟨v, t⟩`: count triangles on nodes `v .. v+t`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Task {
    pub start: VertexId,
    pub len: u32,
}

impl Wire for Task {
    fn write_to(&self, out: &mut Vec<u8>) {
        self.start.write_to(out);
        self.len.write_to(out);
    }
    fn read_from(r: &mut WireReader<'_>) -> crate::error::Result<Self> {
        Ok(Task { start: u32::read_from(r)?, len: u32::read_from(r)? })
    }
}

impl Task {
    #[inline]
    pub fn end(&self) -> VertexId {
        self.start + self.len
    }
    #[inline]
    pub fn range(&self) -> std::ops::Range<VertexId> {
        self.start..self.end()
    }
}

/// Find the smallest `t'` such that `S(0,t') ≥ S(0,n)/2` (the paper's
/// initial/dynamic split point). `prefix` are cost prefix sums, length n+1.
pub fn half_point(prefix: &[u64]) -> usize {
    let total = *prefix.last().unwrap();
    let target = total / 2;
    let mut lo = 0usize;
    let mut hi = prefix.len() - 1;
    while lo < hi {
        let mid = (lo + hi) / 2;
        if prefix[mid] >= target {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    lo
}

/// Split `[lo, hi)` into `k` tasks of roughly equal cost (Eqn 1). Empty
/// tasks are skipped, so fewer than `k` may be returned for degenerate
/// inputs.
pub fn equal_cost_tasks(prefix: &[u64], lo: usize, hi: usize, k: usize) -> Vec<Task> {
    assert!(k >= 1 && lo <= hi);
    let total = prefix[hi] - prefix[lo];
    let mut out = Vec::with_capacity(k);
    let mut start = lo;
    for i in 1..=k {
        let target = prefix[lo] + (total as u128 * i as u128 / k as u128) as u64;
        // Smallest boundary ≥ target, but always at least start.
        let mut b = lower_bound(prefix, target, start, hi);
        if i == k {
            b = hi;
        }
        if b > start {
            out.push(Task { start: start as VertexId, len: (b - start) as u32 });
            start = b;
        }
    }
    out
}

fn lower_bound(prefix: &[u64], target: u64, lo: usize, hi: usize) -> usize {
    let mut lo = lo;
    let mut hi = hi;
    while lo < hi {
        let mid = (lo + hi) / 2;
        if prefix[mid] >= target {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    lo
}

/// Build the dynamic queue for `[from, n)` with **shrinking granularity**
/// (Eqn 2): each next task takes `1/(P−1)` of the cost still unassigned.
/// Terminates because every task contains ≥ 1 node (atomic-task floor,
/// Definition 3).
pub fn shrinking_tasks(prefix: &[u64], from: usize, p_workers: usize) -> Vec<Task> {
    assert!(p_workers >= 1);
    let n = prefix.len() - 1;
    let mut out = Vec::new();
    let mut start = from;
    while start < n {
        let remaining = prefix[n] - prefix[start];
        let chunk = remaining / p_workers as u64; // S(v,t) per Eqn 2
        let target = prefix[start] + chunk;
        let mut b = lower_bound(prefix, target, start + 1, n);
        if b <= start {
            b = start + 1;
        }
        out.push(Task { start: start as VertexId, len: (b - start) as u32 });
        start = b;
    }
    out
}

/// Fixed-granularity queue: `[from, n)` cut into tasks of equal cost
/// (`count` of them) — the static scheme of Fig 13.
pub fn fixed_tasks(prefix: &[u64], from: usize, count: usize) -> Vec<Task> {
    equal_cost_tasks(prefix, from, prefix.len() - 1, count.max(1))
}

/// Check that a task list exactly tiles `[from, n)` (test/prop helper).
pub fn tiles(tasks: &[Task], from: usize, n: usize) -> bool {
    let mut at = from as u64;
    for t in tasks {
        if t.start as u64 != at || t.len == 0 {
            return false;
        }
        at += t.len as u64;
    }
    at == n as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::cost::prefix_sums;

    #[test]
    fn half_point_balances() {
        let prefix = prefix_sums(&[1; 10]);
        assert_eq!(half_point(&prefix), 5);
        let prefix = prefix_sums(&[9, 1, 1, 1, 1, 1, 1, 1, 1, 1]);
        assert_eq!(half_point(&prefix), 1);
    }

    #[test]
    fn equal_cost_tasks_tile_and_balance() {
        let costs = [5, 1, 1, 1, 4, 1, 1, 1, 1, 1];
        let prefix = prefix_sums(&costs);
        let ts = equal_cost_tasks(&prefix, 0, 10, 3);
        assert!(tiles(&ts, 0, 10), "{ts:?}");
    }

    #[test]
    fn shrinking_tasks_tile_and_shrink() {
        let prefix = prefix_sums(&[1u64; 1000]);
        let ts = shrinking_tasks(&prefix, 500, 4);
        assert!(tiles(&ts, 500, 1000), "{ts:?}");
        // Cost (= len here) must be non-increasing until the atomic floor.
        for w in ts.windows(2) {
            assert!(
                w[1].len <= w[0].len || w[0].len == 1,
                "granularity must shrink: {:?}",
                w
            );
        }
        // First dynamic task ≈ remaining/P = 500/4.
        assert!((ts[0].len as i64 - 125).abs() <= 1, "{ts:?}");
    }

    #[test]
    fn shrinking_handles_tail() {
        let prefix = prefix_sums(&[1u64; 7]);
        let ts = shrinking_tasks(&prefix, 0, 3);
        assert!(tiles(&ts, 0, 7), "{ts:?}");
        assert_eq!(*ts.last().map(|t| &t.len).unwrap(), 1);
    }

    #[test]
    fn fixed_tasks_tile() {
        let prefix = prefix_sums(&[2u64; 40]);
        let ts = fixed_tasks(&prefix, 10, 6);
        assert!(tiles(&ts, 10, 40), "{ts:?}");
    }

    #[test]
    fn empty_remainder() {
        let prefix = prefix_sums(&[1u64; 4]);
        let ts = shrinking_tasks(&prefix, 4, 2);
        assert!(ts.is_empty());
    }

    #[test]
    fn prop_shrinking_always_tiles() {
        crate::prop::quickcheck("shrinking tiles", |rng, _| {
            let n = 1 + rng.below_usize(200);
            let costs: Vec<u64> = (0..n).map(|_| rng.below(20)).collect();
            let prefix = prefix_sums(&costs);
            let from = rng.below_usize(n + 1);
            let p = 1 + rng.below_usize(8);
            let ts = shrinking_tasks(&prefix, from, p);
            if !tiles(&ts, from, n) {
                return Err(format!("not a tiling: from={from} n={n} p={p} {ts:?}"));
            }
            Ok(())
        });
    }
}
