//! Parallel per-node triangle counts `T_v` with the §V dynamic load
//! balancer — the distributed version of the clustering-coefficient /
//! transitivity pipeline the paper's §I motivates.
//!
//! Same coordinator/worker protocol as [`crate::algo::dynamic_lb`], but a
//! task produces per-node counts: a triangle `(v,u,w)` found while
//! processing task-node `v` credits all three corners, so workers
//! accumulate into local `T` arrays merged by index at the end (each
//! triangle contributes exactly 3 across all workers).

use std::sync::Arc;

use crate::adj;
use crate::algo::tasks::{self, Task};
use crate::comm::metrics::ClusterMetrics;
use crate::comm::threads::{Comm, Payload, Progress, ProgressUnit};
use crate::comm::transport::{RetryPolicy, Wire, WireReader};
use crate::config::CostFn;
use crate::error::Result;
use crate::graph::ordering::Oriented;
use crate::obs::span::SpanPhase;
use crate::partition::cost::{cost_vector, prefix_sums};
use crate::testkit::sim::Fabric;
use crate::testkit::trace::TraceReport;

enum Msg {
    /// Worker is idle; carries its completed-task count so a lost
    /// `Assign` is retransmitted, never leaked (same hardening as
    /// [`crate::algo::dynamic_lb`]).
    Request { completed: u64 },
    Assign(Task),
    Terminate,
}

impl Payload for Msg {
    fn size_bytes(&self) -> u64 {
        match self {
            Msg::Request { .. } => 16,
            Msg::Terminate => 8,
            Msg::Assign(_) => 16,
        }
    }
}

impl Wire for Msg {
    fn write_to(&self, out: &mut Vec<u8>) {
        match self {
            Msg::Request { completed } => {
                out.push(0);
                completed.write_to(out);
            }
            Msg::Assign(t) => {
                out.push(1);
                t.write_to(out);
            }
            Msg::Terminate => out.push(2),
        }
    }
    fn read_from(r: &mut WireReader<'_>) -> Result<Self> {
        match r.u8()? {
            0 => Ok(Msg::Request { completed: u64::read_from(r)? }),
            1 => Ok(Msg::Assign(Task::read_from(r)?)),
            2 => Ok(Msg::Terminate),
            b => Err(crate::error::Error::Comm(format!(
                "local-counts: unknown message discriminant {b}"
            ))),
        }
    }
}

/// Compute `T_v` for every node on `p` ranks (1 coordinator + p−1 workers).
pub fn per_node_counts(graph: &Arc<Oriented>, p: usize) -> Result<Vec<u64>> {
    per_node_counts_on(&Fabric::Channel, graph, p).0.map(|(tv, _)| tv)
}

/// [`per_node_counts`] on an explicit fabric (conformance entry point);
/// also returns the per-rank comm metrics so the suite can check the
/// sent == received invariants.
pub fn per_node_counts_on(
    fabric: &Fabric,
    graph: &Arc<Oriented>,
    p: usize,
) -> (Result<(Vec<u64>, ClusterMetrics)>, Option<TraceReport>) {
    per_node_counts_hooked_on(fabric, graph, p, None)
}

/// [`per_node_counts_on`] with an `ft/` checkpoint sink (`ft::supervisor`
/// entry point). Tasks are acked with their *unscaled* triangle count
/// (each found triangle credits 3 corners in `T_v` but counts once here),
/// so the supervisor's salvage math is uniform across paths. The per-node
/// *vector* of a dead rank is unrecoverable from checkpoints — only the
/// global count is; `supervise` promises only the count.
pub fn per_node_counts_hooked_on(
    fabric: &Fabric,
    graph: &Arc<Oriented>,
    p: usize,
    progress: Option<Arc<dyn Progress>>,
) -> (Result<(Vec<u64>, ClusterMetrics)>, Option<TraceReport>) {
    if p < 2 {
        let e = crate::error::Error::Config(format!(
            "per-node counts need P >= 2 (a coordinator and at least one worker), got P={p}"
        ));
        return (Err(e), None);
    }
    let n = graph.num_nodes();
    let workers = p - 1;
    let prefix = Arc::new(prefix_sums(&cost_vector(graph, CostFn::Degree)));
    let tp = tasks::half_point(&prefix);
    let initial = Arc::new(tasks::equal_cost_tasks(&prefix, 0, tp, workers));
    let queue = Arc::new(tasks::shrinking_tasks(&prefix, tp, workers));

    let (results, trace) = fabric.try_run_hooked::<Msg, Vec<u64>, _>(p, progress, |c| {
        if c.rank() == 0 {
            coordinator(c, &queue)?;
            Ok(Vec::new())
        } else {
            worker(c, graph.clone(), &initial, n)
        }
    });
    let results = match results {
        Ok(r) => r,
        Err(e) => return (Err(e), trace),
    };

    let mut out = vec![0u64; n];
    let mut metrics = ClusterMetrics::default();
    for (tv, m) in results {
        for (i, t) in tv.iter().enumerate() {
            out[i] += t;
        }
        metrics.per_rank.push(m);
    }
    (Ok((out, metrics)), trace)
}

fn coordinator(c: &mut Comm<Msg>, queue: &Arc<Vec<Task>>) -> Result<()> {
    let mut next = 0usize;
    let mut terminated = 0usize;
    let mut assigned = vec![0u64; c.size()];
    let mut outstanding: Vec<Option<Task>> = vec![None; c.size()];
    let mut done = vec![false; c.size()];
    while terminated < c.size() - 1 {
        let (src, msg) = c.recv()?;
        match msg {
            Msg::Request { completed } => {
                if completed < assigned[src] {
                    // The last Assign was lost — retransmit it.
                    let task = outstanding[src]
                        .expect("a lagging worker always has an outstanding task");
                    c.send_control(src, Msg::Assign(task))?;
                } else if next < queue.len() {
                    let t = queue[next];
                    next += 1;
                    assigned[src] += 1;
                    outstanding[src] = Some(t);
                    c.send_control(src, Msg::Assign(t))?;
                } else {
                    c.send_control(src, Msg::Terminate)?;
                    if !done[src] {
                        done[src] = true;
                        terminated += 1;
                    }
                }
            }
            _ => unreachable!(),
        }
    }
    c.barrier()?;
    Ok(())
}

fn worker(c: &mut Comm<Msg>, o: Arc<Oriented>, initial: &Arc<Vec<Task>>, n: usize) -> Result<Vec<u64>> {
    let wid = c.rank() - 1;
    let mut tv = vec![0u64; n];
    let mut completed = 0u64;
    // One Compute span per executed task (same convention as dynamic_lb).
    if let Some(task) = initial.get(wid) {
        c.span_begin(SpanPhase::Compute);
        let found = run_task(&o, *task, &mut tv);
        c.span_end();
        c.ckpt_ack(ProgressUnit::task(task.start, task.len), found);
    }
    let policy = RetryPolicy::default();
    let mut last_done: Option<Task> = None;
    'outer: loop {
        c.send_control(0, Msg::Request { completed })?;
        let msg = 'recv: loop {
            let got = c
                .recv_retry(0, &policy, |c| c.send_control(0, Msg::Request { completed }))?;
            match got {
                // Retries exhausted, coordinator alive ⇒ lost Terminate.
                None => break 'outer,
                // Stale retransmit of an already-executed task: skip.
                Some((_src, Msg::Assign(task))) if last_done == Some(task) => {
                    continue 'recv;
                }
                Some((_src, m)) => break 'recv m,
            }
        };
        match msg {
            Msg::Assign(task) => {
                c.span_begin(SpanPhase::Compute);
                let found = run_task(&o, task, &mut tv);
                c.span_end();
                completed += 1;
                last_done = Some(task);
                c.ckpt_ack(ProgressUnit::task(task.start, task.len), found);
            }
            Msg::Terminate => break,
            Msg::Request { .. } => unreachable!(),
        }
    }
    c.barrier()?;
    Ok(tv)
}

/// Returns the number of triangles *found* while processing the task
/// (each credits 3 corners in `tv` but counts once toward the global
/// total — the checkpoint ack sum).
fn run_task(o: &Oriented, task: Task, tv: &mut [u64]) -> u64 {
    let mut found = 0u64;
    let mut ws = Vec::new();
    for v in task.range() {
        let vv = o.view(v);
        for &u in vv.list() {
            ws.clear();
            adj::intersect_into(vv, o.view(u), &mut ws);
            for &w in &ws {
                tv[v as usize] += 1;
                tv[u as usize] += 1;
                tv[w as usize] += 1;
                found += 1;
            }
        }
    }
    found
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::classic;
    use crate::seq::local;

    #[test]
    fn matches_sequential_per_node_counts() {
        let g = classic::karate();
        let o = Arc::new(Oriented::from_graph(&g));
        let expect = local::per_node_counts(&o);
        for p in [2, 4, 7] {
            let got = per_node_counts(&o, p).unwrap();
            assert_eq!(got, expect, "P={p}");
        }
    }

    #[test]
    fn sums_to_3t_on_random_graph() {
        let g = crate::gen::pa::preferential_attachment(
            1000,
            10,
            &mut crate::gen::rng::Rng::seeded(15),
        );
        let o = Arc::new(Oriented::from_graph(&g));
        let t = crate::seq::node_iterator::count(&o);
        let tv = per_node_counts(&o, 5).unwrap();
        assert_eq!(tv.iter().sum::<u64>(), 3 * t);
    }

    #[test]
    fn clustering_pipeline_parallel_equals_sequential() {
        let g = crate::gen::geometric::miami_like(2000, 16, &mut crate::gen::rng::Rng::seeded(16));
        let o = Arc::new(Oriented::from_graph(&g));
        let seq_cc = local::avg_clustering(&g, &local::per_node_counts(&o));
        let par_cc = local::avg_clustering(&g, &per_node_counts(&o, 6).unwrap());
        assert!((seq_cc - par_cc).abs() < 1e-12);
    }
}
