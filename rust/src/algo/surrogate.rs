//! §IV — the space-efficient parallel algorithm with the **surrogate**
//! communication scheme (paper Fig 3).
//!
//! Each rank owns a non-overlapping partition — a physically materialized
//! [`OwnedPartition`] holding the oriented lists `N_v` for `v ∈ V_i` and
//! nothing else (the rank closure does not capture the shared graph, so
//! remote data is unreachable except by message). For an oriented edge
//! `(v, u)` with `u ∈ V_j, j ≠ i`, rank `i` sends `N_v` to `j` **once per
//! destination partition** (the `LastProc` trick, realized here by walking
//! the id-sorted `N_v` as per-owner runs — sound because partitions are
//! id-intervals), and `j` — the *surrogate* — counts `|N_u ∩ N_v|` for
//! every `u ∈ N_v ∩ V_j` on `i`'s behalf (`SURROGATECOUNT`, paper Fig 2).
//! Completion notifiers implement the §IV-D termination protocol;
//! `MPI_Reduce` aggregates the counts. Comm failures propagate as
//! [`crate::error::Error`] through [`crate::comm::threads::Cluster::try_run`].

use std::sync::Arc;

use crate::adj::hub::HubThreshold;
use crate::adj::{self, NeighborView};
use crate::algo::driver::{self, RunResult};
use crate::comm::threads::{Comm, Payload, Progress, ProgressUnit};
use crate::comm::transport::{Wire, WireReader};
use crate::error::{Error, Result};
use crate::graph::ordering::Oriented;
use crate::obs::span::SpanPhase;
use crate::partition::nonoverlap::partition_sizes;
use crate::partition::owned::{self, OwnedPartition};
use crate::testkit::sim::Fabric;
use crate::testkit::trace::TraceReport;
use crate::{TriangleCount, VertexId};

/// Wire messages of the space-efficient algorithm (§IV-A: `⟨t, X⟩`).
///
/// The data payload is an `Arc<[VertexId]>`: a node sending `N_v` to
/// several partitions materializes the list once and the sends share it —
/// one allocation+copy per node instead of one per destination. On a real
/// wire each send still costs the full payload, which is what
/// [`Payload::size_bytes`] reports and the metrics account. (Wall-clock
/// effect is not measurable on the 1-core container, where thread
/// scheduling noise dominates the threaded backend — EXPERIMENTS.md §Perf.)
pub enum Msg {
    /// `⟨data, N_v⟩` — a neighbor list for surrogate counting.
    Data(Arc<[VertexId]>),
    /// `⟨completion, ·⟩` — the sender finished its own partition.
    Completion,
}

impl Payload for Msg {
    fn size_bytes(&self) -> u64 {
        match self {
            Msg::Data(x) => 8 + 4 * x.len() as u64,
            Msg::Completion => 8,
        }
    }
}

impl Wire for Msg {
    fn write_to(&self, out: &mut Vec<u8>) {
        match self {
            Msg::Data(x) => {
                out.push(0);
                x.write_to(out);
            }
            Msg::Completion => out.push(1),
        }
    }
    fn read_from(r: &mut WireReader<'_>) -> Result<Self> {
        match r.u8()? {
            0 => Ok(Msg::Data(Arc::<[u32]>::read_from(r)?)),
            1 => Ok(Msg::Completion),
            b => Err(Error::Comm(format!("surrogate: unknown message discriminant {b}"))),
        }
    }
}

/// `SURROGATECOUNT(X, i)` (paper Fig 2): count `|N_u ∩ X|` for every
/// `u ∈ X` owned by this rank. `X` is id-sorted, the owned range is an
/// id-interval, so the owned members form one contiguous slice of `X`.
/// `X` arrived over the wire, so it is a plain sorted view; the local
/// `N_u` goes through the hybrid dispatch, upgrading hub rows to probes.
#[inline]
fn surrogate_count(part: &OwnedPartition, x: &[VertexId], t: &mut TriangleCount, work: &mut u64) {
    let r = part.range();
    let lo = x.partition_point(|&u| u < r.start);
    let hi = x.partition_point(|&u| u < r.end);
    let xv = NeighborView::sorted(x);
    for &u in &x[lo..hi] {
        let vu = part.view(u);
        adj::intersect_count(vu, xv, t);
        *work += adj::intersect_cost(vu, xv);
    }
}

fn handle(part: &OwnedPartition, msg: Msg, t: &mut TriangleCount, work: &mut u64, completions: &mut usize) {
    match msg {
        Msg::Data(x) => surrogate_count(part, &x, t, work),
        Msg::Completion => *completions += 1,
    }
}

/// Run the surrogate algorithm over pre-computed consecutive ranges (from
/// [`crate::partition::balance::balanced_ranges`]), one rank per range.
/// Each rank's partition is materialized up front ([`owned`]); `hub` sets
/// the per-partition hub-bitmap policy.
pub fn run(
    graph: &Oriented,
    ranges: &[std::ops::Range<u32>],
    hub: HubThreshold,
) -> Result<RunResult> {
    run_on(&Fabric::Channel, graph, ranges, hub).0
}

/// [`run`] on an explicit fabric — the conformance suite passes
/// `Fabric::Sim` to drive this exact protocol through adversarial
/// schedules; the trace is `Some` iff the fabric is virtual.
pub fn run_on(
    fabric: &Fabric,
    graph: &Oriented,
    ranges: &[std::ops::Range<u32>],
    hub: HubThreshold,
) -> (Result<RunResult>, Option<TraceReport>) {
    run_hooked_on(fabric, graph, ranges, hub, None)
}

/// [`run_on`] with an `ft/` checkpoint sink (`ft::supervisor` entry
/// point). Surrogate counting is *entangled* — a triangle with min-vertex
/// `v` may be resolved at any surrogate — so ranks publish monotone
/// partials (valid global lower bounds), never acks; recovery is full
/// re-execution on the survivors (DESIGN.md §13).
pub fn run_hooked_on(
    fabric: &Fabric,
    graph: &Oriented,
    ranges: &[std::ops::Range<u32>],
    hub: HubThreshold,
    progress: Option<std::sync::Arc<dyn Progress>>,
) -> (Result<RunResult>, Option<TraceReport>) {
    let parts = owned::extract_nonoverlapping(graph, ranges, hub);
    let predicted = partition_sizes(graph, ranges).iter().map(|s| s.bytes()).collect();
    driver::run_owned_hooked_on::<Msg, _>(fabric, parts, predicted, progress, rank_main)
}

/// The per-rank program (paper Fig 3 lines 1-22 + reduce).
fn rank_main(c: &mut Comm<Msg>, part: &OwnedPartition) -> Result<TriangleCount> {
    let me = c.rank() as u32;
    let mut t: TriangleCount = 0;
    let mut work = 0u64;
    let mut completions = 0usize;

    // Lines 2-12: local counting + sends + opportunistic receive. N_v is
    // walked as per-owner runs (§IV-C `LastProc`): one contiguous run per
    // destination partition ⇒ exactly one send per (v, remote partition).
    // The whole sweep is one Compute span; the serve loop below shows up
    // as recv-wait on the timeline instead.
    c.span_begin(SpanPhase::Compute);
    for v in part.range() {
        let vv = part.view(v);
        let nv = vv.list();
        let mut payload: Option<Arc<[VertexId]>> = None; // materialized lazily, shared across sends
        for (j, run) in part.owners().runs(nv) {
            if j == me {
                for &u in &nv[run] {
                    let vu = part.view(u);
                    adj::intersect_count(vv, vu, &mut t);
                    work += adj::intersect_cost(vv, vu);
                }
            } else {
                let data = payload.get_or_insert_with(|| Arc::from(nv)).clone();
                c.send(j as usize, Msg::Data(data))?;
            }
        }
        // Line 10-14: check for incoming messages.
        while let Some((_src, msg)) = c.try_recv() {
            handle(part, msg, &mut t, &mut work, &mut completions);
        }
    }
    c.span_end();

    // Checkpoint: everything this rank counted so far, keyed by its own
    // range. The per-rank totals are globally disjoint (each triangle is
    // counted at exactly one rank), so their sum is a valid lower bound
    // even though served counts belong to other ranks' min-vertices.
    let r = part.range();
    let unit = ProgressUnit::range(r.start, r.end);
    c.ckpt_partial(unit, t);

    // Line 16: broadcast completion notifier.
    c.bcast_control(|| Msg::Completion)?;

    // Lines 17-22: serve data until all peers completed.
    while completions < c.size() - 1 {
        let (_src, msg) = c.recv()?;
        handle(part, msg, &mut t, &mut work, &mut completions);
        c.ckpt_partial(unit, t);
    }

    c.metrics.work_units = work;
    // Lines 24-25: barrier + reduce.
    c.reduce_sum(t)?;
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CostFn;
    use crate::graph::classic;
    use crate::partition::balance::balanced_ranges;
    use crate::partition::cost::{cost_vector, prefix_sums};

    fn run_on(g: &crate::graph::csr::Csr, p: usize) -> RunResult {
        let o = Oriented::from_graph(g);
        let prefix = prefix_sums(&cost_vector(&o, CostFn::SurrogateNew));
        let ranges = balanced_ranges(&prefix, p);
        run(&o, &ranges, HubThreshold::Auto).unwrap()
    }

    #[test]
    fn karate_exact_at_many_p() {
        for p in [1, 2, 3, 5, 8, 13] {
            let r = run_on(&classic::karate(), p);
            assert_eq!(r.triangles, classic::KARATE_TRIANGLES, "P={p}");
        }
    }

    #[test]
    fn classics_exact() {
        for (g, expect) in [
            (classic::complete(12), 220u64),
            (classic::petersen(), 0),
            (classic::wheel(10), 10),
            (classic::barbell_k4(), 8),
        ] {
            let r = run_on(&g, 4);
            assert_eq!(r.triangles, expect);
        }
    }

    #[test]
    fn matches_sequential_on_random_graphs() {
        use crate::gen::rng::Rng;
        let mut rng = Rng::seeded(55);
        for _ in 0..5 {
            let g = crate::gen::erdos_renyi::gnm(300, 2000, &mut rng);
            let o = Oriented::from_graph(&g);
            let expect = crate::seq::node_iterator::count(&o);
            for p in [2, 4, 7] {
                assert_eq!(run_on(&g, p).triangles, expect, "P={p}");
            }
        }
    }

    #[test]
    fn no_redundant_messages_vs_direct_bound() {
        // Surrogate sends at most one data message per (node, partition)
        // pair — far fewer than one per remote oriented edge.
        use crate::partition::balance::owner_table;
        let g = crate::gen::pa::preferential_attachment(
            500,
            8,
            &mut crate::gen::rng::Rng::seeded(66),
        );
        let o = Oriented::from_graph(&g);
        let prefix = prefix_sums(&cost_vector(&o, CostFn::SurrogateNew));
        let ranges = balanced_ranges(&prefix, 4);
        let owner = owner_table(&ranges, o.num_nodes());
        let r = run(&o, &ranges, HubThreshold::Auto).unwrap();
        let msgs: u64 = r.metrics.per_rank.iter().map(|m| m.messages_sent).sum();
        // Upper bound: Σ_v (#partitions ≤ P−1) but also ≤ remote oriented edges.
        let remote_edges: u64 = (0..o.num_nodes() as u32)
            .map(|v| {
                o.nbrs(v)
                    .iter()
                    .filter(|&&u| owner[u as usize] != owner[v as usize])
                    .count() as u64
            })
            .sum();
        assert!(msgs <= remote_edges, "msgs={msgs} remote_edges={remote_edges}");
        assert!(msgs <= (o.num_nodes() * 3) as u64);
        assert_eq!(
            r.triangles,
            crate::seq::node_iterator::count(&o)
        );
    }

    #[test]
    fn measured_partition_bytes_equal_prediction() {
        let g = crate::gen::pa::preferential_attachment(
            800,
            10,
            &mut crate::gen::rng::Rng::seeded(7),
        );
        let r = run_on(&g, 6);
        assert_eq!(r.metrics.partition_accounting_divergence(), None);
        assert!(r.metrics.max_partition_bytes() > 0);
        assert_eq!(
            r.metrics.max_partition_bytes(),
            r.metrics.max_partition_bytes_pred()
        );
    }

    #[test]
    fn empty_graph_and_single_rank() {
        let g = crate::graph::csr::Csr::empty(10);
        let r = run_on(&g, 3);
        assert_eq!(r.triangles, 0);
        let r = run_on(&classic::karate(), 1);
        assert_eq!(r.triangles, 45);
        assert_eq!(r.metrics.totals().messages_sent, 0);
    }
}
