//! §IV-C — the **direct approach** baseline.
//!
//! For every oriented edge `(v, u)` with a remote `u`, rank `i` requests
//! `N_u` from `u`'s owner and intersects locally. No redundancy elimination:
//! if `u` appears in many of rank `i`'s lists, `N_u` crosses the wire once
//! *per occurrence* — the high communication overhead the paper measures in
//! Fig 4 / Table III and the surrogate scheme exists to eliminate.
//!
//! The per-edge request/response records travel inside coalesced frames
//! ([`crate::comm::coalesce`]): a per-destination buffer packs them up to
//! the flush watermark, so the envelope constant is paid per frame while
//! the *logical* traffic (one record per remote oriented edge) is
//! unchanged — [`CommMetrics`](crate::comm::metrics::CommMetrics) counts
//! both (`coalesced_sent` records vs `frames_sent` envelopes), and the
//! cost-model simulator keeps predicting the logical record counts.
//!
//! Ranks hold the same materialized [`OwnedPartition`]s as the surrogate
//! scheme; only the communication protocol differs.

use std::collections::BTreeMap;

use crate::adj::hub::HubThreshold;
use crate::adj::{self, NeighborView};
use crate::algo::driver::{self, RunResult};
use crate::comm::coalesce::{CoalescingBuffer, Frame, DEFAULT_WATERMARK_WORDS};
use crate::comm::threads::{Comm, Payload, Progress, ProgressUnit};
use crate::comm::transport::{Liveness, RetryPolicy, Wire, WireReader};
use crate::error::{Error, Result};
use crate::graph::ordering::Oriented;
use crate::obs::span::SpanPhase;
use crate::partition::nonoverlap::partition_sizes;
use crate::partition::owned::{self, OwnedPartition};
use crate::testkit::sim::Fabric;
use crate::testkit::trace::TraceReport;
use crate::{TriangleCount, VertexId};

/// Frame-record tag: "send me `N_u`; it's for my node `v`" — payload
/// `[u, v]`.
pub const TAG_REQ: u32 = 1;
/// Frame-record tag: `N_u`, echoed with the full requested `(u, v)` pair —
/// payload `[u, v, N_u…]`. The echo lets the requester clear exactly one
/// outstanding entry, which is what makes retransmitted requests safe: a
/// duplicate response no longer matches an outstanding pair and is
/// discarded without counting.
pub const TAG_RESP: u32 = 2;

/// Wire messages of the direct scheme.
pub enum Msg {
    /// A coalesced frame of [`TAG_REQ`]/[`TAG_RESP`] records.
    Batch(Frame),
    /// Termination notifier (§IV-D).
    Completion,
}

impl Payload for Msg {
    fn size_bytes(&self) -> u64 {
        match self {
            Msg::Batch(f) => f.bytes(),
            Msg::Completion => 8,
        }
    }
}

impl Wire for Msg {
    fn write_to(&self, out: &mut Vec<u8>) {
        match self {
            Msg::Batch(f) => {
                out.push(0);
                f.write_to(out);
            }
            Msg::Completion => out.push(1),
        }
    }
    fn read_from(r: &mut WireReader<'_>) -> Result<Self> {
        match r.u8()? {
            0 => Ok(Msg::Batch(Frame::read_from(r)?)),
            1 => Ok(Msg::Completion),
            b => Err(Error::Comm(format!("direct: unknown message discriminant {b}"))),
        }
    }
}

/// Run the direct-approach algorithm over the same non-overlapping
/// partitions as [`crate::algo::surrogate::run`].
pub fn run(
    graph: &Oriented,
    ranges: &[std::ops::Range<u32>],
    hub: HubThreshold,
) -> Result<RunResult> {
    run_on(&Fabric::Channel, graph, ranges, hub).0
}

/// [`run`] on an explicit fabric (conformance entry point).
pub fn run_on(
    fabric: &Fabric,
    graph: &Oriented,
    ranges: &[std::ops::Range<u32>],
    hub: HubThreshold,
) -> (Result<RunResult>, Option<TraceReport>) {
    run_hooked_on(fabric, graph, ranges, hub, None)
}

/// [`run_on`] with an `ft/` checkpoint sink (`ft::supervisor` entry
/// point). Every triangle rank `i` counts has its min-vertex in rank `i`'s
/// own range, so once the response drain finishes, the range is *acked*
/// with its exact sum — recovery then re-counts only un-acked ranges.
pub fn run_hooked_on(
    fabric: &Fabric,
    graph: &Oriented,
    ranges: &[std::ops::Range<u32>],
    hub: HubThreshold,
    progress: Option<std::sync::Arc<dyn Progress>>,
) -> (Result<RunResult>, Option<TraceReport>) {
    let parts = owned::extract_nonoverlapping(graph, ranges, hub);
    let predicted = partition_sizes(graph, ranges).iter().map(|s| s.bytes()).collect();
    driver::run_owned_hooked_on::<Msg, _>(fabric, parts, predicted, progress, rank_main)
}

fn send_frame(c: &mut Comm<Msg>, dst: usize, f: Frame) -> Result<()> {
    c.metrics.frames_sent += 1;
    c.metrics.coalesced_sent += f.items;
    c.send(dst, Msg::Batch(f))
}

struct RankState {
    t: TriangleCount,
    work: u64,
    completions: usize,
    /// Requests awaiting a response, `(u, v) → owner rank`. A response
    /// clears its entry; one that matches nothing is a retransmit
    /// duplicate and is dropped without counting (exactly-once counting
    /// over an at-least-once wire).
    outstanding: BTreeMap<(VertexId, VertexId), usize>,
    /// Per-peer response buffers — flushed after every incoming frame so
    /// a requester blocked on its drain loop is never starved by an
    /// unfilled watermark.
    resp: Vec<CoalescingBuffer>,
}

fn handle(
    c: &mut Comm<Msg>,
    part: &OwnedPartition,
    src: usize,
    msg: Msg,
    st: &mut RankState,
) -> Result<()> {
    match msg {
        Msg::Batch(f) => {
            c.metrics.frames_received += 1;
            c.metrics.coalesced_received += f.items;
            for (tag, rec) in f.records() {
                match tag {
                    TAG_REQ => {
                        // We own u; batch N_u back, echoing the requested
                        // pair. Serving is idempotent — duplicate requests
                        // just cost a duplicate response, which the
                        // requester discards.
                        let (u, v) = (rec[0], rec[1]);
                        let nu = part.nbrs(u);
                        let mut payload = Vec::with_capacity(2 + nu.len());
                        payload.push(u);
                        payload.push(v);
                        payload.extend_from_slice(nu);
                        if let Some(out) = st.resp[src].push(TAG_RESP, &payload) {
                            send_frame(c, src, out)?;
                        }
                    }
                    TAG_RESP => {
                        let (u, v) = (rec[0], rec[1]);
                        if st.outstanding.remove(&(u, v)).is_none() {
                            continue; // duplicate response to a retransmit
                        }
                        // Remote N_u is a wire payload (plain sorted view);
                        // the local N_v goes through the hybrid dispatch.
                        let vv = part.view(v);
                        let nuv = NeighborView::sorted(&rec[2..]);
                        adj::intersect_count(vv, nuv, &mut st.t);
                        st.work += adj::intersect_cost(vv, nuv);
                    }
                    other => {
                        debug_assert!(false, "unknown direct record tag {other}");
                    }
                }
            }
            if let Some(out) = st.resp[src].flush() {
                send_frame(c, src, out)?;
            }
        }
        Msg::Completion => st.completions += 1,
    }
    Ok(())
}

fn rank_main(c: &mut Comm<Msg>, part: &OwnedPartition) -> Result<TriangleCount> {
    let me = c.rank() as u32;
    let size = c.size();
    let mut st = RankState {
        t: 0,
        work: 0,
        completions: 0,
        outstanding: BTreeMap::new(),
        resp: (0..size).map(|_| CoalescingBuffer::new(DEFAULT_WATERMARK_WORDS)).collect(),
    };
    let mut req: Vec<CoalescingBuffer> =
        (0..size).map(|_| CoalescingBuffer::new(DEFAULT_WATERMARK_WORDS)).collect();

    // Compute span over the request/count sweep; the drain loops below
    // appear as recv-wait on the timeline.
    c.span_begin(SpanPhase::Compute);
    for v in part.range() {
        let vv = part.view(v);
        let nv = vv.list();
        for (j, run) in part.owners().runs(nv) {
            if j == me {
                for &u in &nv[run] {
                    let vu = part.view(u);
                    adj::intersect_count(vv, vu, &mut st.t);
                    st.work += adj::intersect_cost(vv, vu);
                }
            } else {
                // One request record per remote oriented edge — redundancy
                // included; only the envelopes are coalesced.
                for &u in &nv[run] {
                    st.outstanding.insert((u, v), j as usize);
                    if let Some(f) = req[j as usize].push(TAG_REQ, &[u, v]) {
                        send_frame(c, j as usize, f)?;
                    }
                }
            }
        }
        while let Some((src, msg)) = c.try_recv() {
            handle(c, part, src, msg, &mut st)?;
        }
    }
    c.span_end();

    // The sweep is over — flush every partially-filled request buffer.
    for j in 0..size {
        if let Some(f) = req[j].flush() {
            send_frame(c, j, f)?;
        }
    }

    // Checkpoint the sweep-local partial before waiting on the wire.
    let r = part.range();
    let unit = ProgressUnit::range(r.start, r.end);
    c.ckpt_partial(unit, st.t);

    // Drain until all our responses arrived (serving peers' requests too,
    // otherwise two ranks could wait on each other forever). A deadline
    // expiry with requests still outstanding retransmits them — bounded
    // by the retry policy — and a dead owner fails fast through the
    // liveness board instead of burning the full guard.
    let policy = RetryPolicy::default();
    let mut attempt = 0u32;
    while !st.outstanding.is_empty() {
        match c.recv_deadline(policy.deadline_for(attempt))? {
            Some((src, msg)) => {
                handle(c, part, src, msg, &mut st)?;
                attempt = 0;
            }
            None => {
                if let Some(&dead) = st
                    .outstanding
                    .values()
                    .find(|&&j| c.liveness_of(j) == Liveness::Dead)
                {
                    return Err(Error::Cluster(format!(
                        "rank {}: peer rank {dead} died with {} responses outstanding",
                        c.rank(),
                        st.outstanding.len()
                    )));
                }
                if attempt >= policy.max_retries {
                    return Err(Error::Cluster(format!(
                        "rank {}: {} responses still outstanding after {} retries",
                        c.rank(),
                        st.outstanding.len(),
                        policy.max_retries
                    )));
                }
                attempt += 1;
                // Repack every outstanding pair into fresh frames —
                // BTreeMap order keeps the retransmit schedule (and the
                // replay trace) deterministic.
                let resend: Vec<((VertexId, VertexId), usize)> =
                    st.outstanding.iter().map(|(&k, &j)| (k, j)).collect();
                for ((u, v), j) in resend {
                    c.metrics.retries += 1;
                    if let Some(f) = req[j].push(TAG_REQ, &[u, v]) {
                        send_frame(c, j, f)?;
                    }
                }
                for j in 0..size {
                    if let Some(f) = req[j].flush() {
                        send_frame(c, j, f)?;
                    }
                }
            }
        }
    }

    // All of this rank's min-vertex triangles are now resolved — the own
    // range is exact from here on, whatever happens to the peers.
    c.ckpt_ack(unit, st.t);

    c.bcast_control(|| Msg::Completion)?;

    while st.completions < size - 1 {
        let (src, msg) = c.recv()?;
        handle(c, part, src, msg, &mut st)?;
    }

    c.metrics.work_units = st.work;
    c.reduce_sum(st.t)?;
    Ok(st.t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CostFn;
    use crate::graph::classic;
    use crate::partition::balance::balanced_ranges;
    use crate::partition::cost::{cost_vector, prefix_sums};

    fn run_on(g: &crate::graph::csr::Csr, p: usize) -> RunResult {
        let o = Oriented::from_graph(g);
        let prefix = prefix_sums(&cost_vector(&o, CostFn::SurrogateNew));
        let ranges = balanced_ranges(&prefix, p);
        run(&o, &ranges, HubThreshold::Auto).unwrap()
    }

    #[test]
    fn karate_exact_at_many_p() {
        for p in [1, 2, 4, 9] {
            assert_eq!(run_on(&classic::karate(), p).triangles, 45, "P={p}");
        }
    }

    #[test]
    fn matches_sequential_on_random() {
        use crate::gen::rng::Rng;
        let mut rng = Rng::seeded(77);
        let g = crate::gen::erdos_renyi::gnm(250, 1500, &mut rng);
        let o = Oriented::from_graph(&g);
        let expect = crate::seq::node_iterator::count(&o);
        assert_eq!(run_on(&g, 5).triangles, expect);
    }

    #[test]
    fn direct_sends_more_messages_than_surrogate() {
        // The paper's core §IV observation, as a test — stated on the
        // *logical* record counts, which coalescing leaves unchanged.
        let g = crate::gen::pa::preferential_attachment(
            600,
            10,
            &mut crate::gen::rng::Rng::seeded(88),
        );
        let o = Oriented::from_graph(&g);
        let prefix = prefix_sums(&cost_vector(&o, CostFn::SurrogateNew));
        let ranges = balanced_ranges(&prefix, 6);
        let d = run(&o, &ranges, HubThreshold::Auto).unwrap();
        let s = crate::algo::surrogate::run(&o, &ranges, HubThreshold::Auto).unwrap();
        assert_eq!(d.triangles, s.triangles);
        let dm = d.metrics.totals();
        let sm = s.metrics.totals();
        assert!(
            dm.coalesced_sent > 2 * sm.messages_sent,
            "direct={} surrogate={}",
            dm.coalesced_sent,
            sm.messages_sent
        );
        // Both schemes hold identical non-overlapping partitions.
        assert_eq!(dm.partition_bytes, sm.partition_bytes);
        assert_eq!(d.metrics.partition_accounting_divergence(), None);
    }

    #[test]
    fn coalescing_shrinks_envelopes_but_conserves_records() {
        let g = crate::gen::pa::preferential_attachment(
            500,
            12,
            &mut crate::gen::rng::Rng::seeded(9),
        );
        let o = Oriented::from_graph(&g);
        let prefix = prefix_sums(&cost_vector(&o, CostFn::SurrogateNew));
        let ranges = balanced_ranges(&prefix, 6);
        let d = run(&o, &ranges, HubThreshold::Auto).unwrap();
        let t = d.metrics.totals();
        // Tag-class symmetry: every record and frame sent is received.
        assert_eq!(t.frames_sent, t.frames_received);
        assert_eq!(t.coalesced_sent, t.coalesced_received);
        // Aggregation is real: strictly fewer envelopes than records.
        assert!(t.frames_sent < t.coalesced_sent);
        assert!(t.messages_sent == t.frames_sent, "data envelopes are frames");
        assert!(d.metrics.aggregation_ratio() > 1.0);
    }
}
