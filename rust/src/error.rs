//! Crate error types.

use thiserror::Error;

/// Errors surfaced by the `tricount` public API.
#[derive(Debug, Error)]
pub enum Error {
    /// Graph input was structurally invalid (bad endpoint, overflow, …).
    #[error("invalid graph: {0}")]
    InvalidGraph(String),

    /// A file could not be parsed as an edge list / binary graph.
    #[error("parse error at line {line}: {msg}")]
    Parse { line: usize, msg: String },

    /// Underlying I/O failure.
    #[error(transparent)]
    Io(#[from] std::io::Error),

    /// Invalid run configuration (CLI or TOML).
    #[error("invalid config: {0}")]
    Config(String),

    /// A parallel run failed (worker panic, channel breakage).
    #[error("cluster execution failed: {0}")]
    Cluster(String),

    /// AOT artifact missing or malformed.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// PJRT / XLA runtime failure.
    #[error("xla runtime error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Convenience alias used across the crate.
pub type Result<T> = std::result::Result<T, Error>;
