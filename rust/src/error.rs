//! Crate error types.
//!
//! Hand-rolled `Display`/`Error` impls (no `thiserror`): the container
//! build is fully offline and the crate is dependency-free.

use std::fmt;

/// Errors surfaced by the `tricount` public API.
#[derive(Debug)]
pub enum Error {
    /// Graph input was structurally invalid (bad endpoint, overflow, …).
    InvalidGraph(String),

    /// A file could not be parsed as an edge list / binary graph.
    Parse { line: usize, msg: String },

    /// Underlying I/O failure.
    Io(std::io::Error),

    /// Invalid run configuration (CLI or TOML).
    Config(String),

    /// A parallel run failed (worker panic, channel breakage).
    Cluster(String),

    /// Wire-level communication failure on a socket fabric: a malformed
    /// or truncated frame, a mid-stream disconnect, an undecodable
    /// payload. Distinct from [`Error::Cluster`] (protocol-level failure)
    /// so the conformance suite can assert corruption surfaces as a
    /// deterministic transport error, never a panic or a hang.
    Comm(String),

    /// A specific rank failed mid-protocol. Carries the rank id and the
    /// transport-op count at which it failed so the cluster launcher can
    /// attribute the *root cause* (lowest op count = earliest failure in
    /// protocol time) and the `ft/` supervisor can identify the victim
    /// without parsing message strings.
    RankFailure { rank: usize, ops: u64, msg: String },

    /// A report cell had an unexpected type or shape (typed accessor
    /// failure in `exp::report` — names the row, column and actual cell).
    Report(String),

    /// AOT artifact missing or malformed.
    Artifact(String),

    /// PJRT / XLA runtime failure (or runtime unavailable in this build).
    Xla(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::InvalidGraph(m) => write!(f, "invalid graph: {m}"),
            Error::Parse { line, msg } => write!(f, "parse error at line {line}: {msg}"),
            Error::Io(e) => write!(f, "{e}"),
            Error::Config(m) => write!(f, "invalid config: {m}"),
            Error::Cluster(m) => write!(f, "cluster execution failed: {m}"),
            Error::Comm(m) => write!(f, "communication error: {m}"),
            Error::RankFailure { rank, ops, msg } => {
                write!(f, "cluster execution failed: rank {rank} after {ops} transport ops: {msg}")
            }
            Error::Report(m) => write!(f, "malformed report: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Xla(m) => write!(f, "xla runtime error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "xla-runtime")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Convenience alias used across the crate.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_seed_format() {
        assert_eq!(Error::InvalidGraph("x".into()).to_string(), "invalid graph: x");
        assert_eq!(
            Error::Parse { line: 3, msg: "bad".into() }.to_string(),
            "parse error at line 3: bad"
        );
        assert_eq!(Error::Config("k".into()).to_string(), "invalid config: k");
        assert_eq!(Error::Comm("short frame".into()).to_string(), "communication error: short frame");
    }

    #[test]
    fn io_error_converts_and_chains() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(matches!(e, Error::Io(_)));
        assert!(std::error::Error::source(&e).is_some());
    }
}
