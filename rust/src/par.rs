//! Deterministic scoped-thread helpers for the preprocessing pipeline.
//!
//! Everything here backs the `--build-threads` knob: the O(m) radix CSR
//! build ([`crate::graph::builder`]), the parallel orientation
//! ([`crate::graph::ordering::Oriented::from_graph_threads`]) and the hub
//! bitmap packing ([`crate::adj::hub::HubIndex::build_threads`]). The
//! contract every consumer upholds is **bit-identical output at every
//! thread count**: work is split into contiguous index ranges, each part
//! writes only to regions it owns (either a `split_at_mut` chunk or a
//! cursor region proven disjoint by construction), and anything
//! order-sensitive — prefix sums, hub selection — stays serial. See
//! DESIGN.md §8 for the determinism argument.
//!
//! This is deliberately *not* built on [`crate::comm::threads`]: that layer
//! models an MPI cluster (ranks, messages, metrics); this one is plain
//! fork-join over slices with zero protocol.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::error::Error;

/// `--build-threads <n|auto>` policy for the preprocessing pipeline
/// (CSR build, degree ordering, relabel, orientation, hub index).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BuildThreads {
    /// One thread per available core (`std::thread::available_parallelism`).
    #[default]
    Auto,
    /// Exactly `n` threads (`n ≥ 1`).
    Fixed(usize),
}

impl BuildThreads {
    /// Resolve the policy to a concrete thread count (`≥ 1`).
    pub fn resolve(self) -> usize {
        match self {
            BuildThreads::Fixed(t) => t.max(1),
            BuildThreads::Auto => std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1),
        }
    }
}

impl std::str::FromStr for BuildThreads {
    type Err = Error;
    fn from_str(s: &str) -> crate::error::Result<Self> {
        match s {
            "auto" => Ok(BuildThreads::Auto),
            other => match other.parse::<usize>() {
                Ok(t) if t >= 1 => Ok(BuildThreads::Fixed(t)),
                _ => Err(Error::Config(format!(
                    "build threads `{other}` is not n≥1|auto"
                ))),
            },
        }
    }
}

impl std::fmt::Display for BuildThreads {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BuildThreads::Auto => write!(f, "auto"),
            BuildThreads::Fixed(t) => write!(f, "{t}"),
        }
    }
}

/// Process-wide default consulted by [`crate::graph::builder::from_edge_list`]
/// and [`crate::graph::ordering::Oriented::from_graph_with`] — the paths
/// whose signatures predate the knob. Starts at 1 (serial, the seed's
/// behavior); the CLI sets it from `--build-threads`. Because every
/// consumer is bit-identical at any thread count, changing this is a pure
/// performance decision — callers wanting explicit control use the
/// `*_threads` variants.
static DEFAULT_THREADS: AtomicUsize = AtomicUsize::new(1);

/// Set the process-wide default build-thread count (clamped to ≥ 1).
pub fn set_default_threads(t: usize) {
    DEFAULT_THREADS.store(t.max(1), Ordering::Relaxed);
}

/// Current process-wide default build-thread count.
pub fn default_threads() -> usize {
    DEFAULT_THREADS.load(Ordering::Relaxed)
}

/// Degrade a requested thread count toward serial when there are fewer
/// than `floor` work items per thread — spawn plus per-thread-table merge
/// overhead beats the win on small inputs. Shared by the builder
/// (edges-per-thread and table-width floors), the orientation
/// (rows-per-thread) and the hub packer (rows-per-thread), so the
/// "degrade toward serial" rule lives in one place.
pub fn clamp_threads(requested: usize, work_items: usize, floor: usize) -> usize {
    requested.clamp(1, (work_items / floor.max(1)).max(1))
}

/// Clamp a requested thread count to the host's available parallelism.
///
/// Oversubscribing a fork-join phase is never a win here: every `par/`
/// consumer splits work into exactly `t` contiguous ranges up front, so
/// `t` beyond the core count just multiplies spawn/join and cache-migration
/// overhead while the excess threads time-share cores (measured: the
/// rmat:16:16 pipeline hit 0.70× at T=8 on the 2-core bench host —
/// BENCH_pipeline.json, PR 6 rows). Because every consumer is
/// bit-identical at any thread count (DESIGN.md §8), clamping is a pure
/// performance decision; explicit `--build-threads 8` on a 2-core host now
/// means "use all 2 cores", not "context-switch 8 workers".
pub fn clamp_to_host(requested: usize) -> usize {
    let host = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    requested.clamp(1, host)
}

/// Split `0..len` into exactly `parts` contiguous near-equal ranges (the
/// first `len % parts` ranges are one longer; trailing ranges may be empty
/// when `parts > len`). The boundaries are a pure function of `(len,
/// parts)` — every pipeline phase that must agree on ownership calls this
/// with the same arguments.
pub fn ranges(len: usize, parts: usize) -> Vec<Range<usize>> {
    let parts = parts.max(1);
    let base = len / parts;
    let rem = len % parts;
    let mut out = Vec::with_capacity(parts);
    let mut at = 0;
    for i in 0..parts {
        let size = base + usize::from(i < rem);
        out.push(at..at + size);
        at += size;
    }
    debug_assert_eq!(at, len);
    out
}

/// Run `f(part, range)` over the [`ranges`] of `0..len`, on scoped threads
/// when `parts > 1` (inline otherwise). Results are returned in part
/// order. `f` must only write to locations its part owns.
pub fn for_ranges<R, F>(len: usize, parts: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize, Range<usize>) -> R + Sync,
{
    let rs = ranges(len, parts);
    if rs.len() == 1 {
        return vec![f(0, 0..len)];
    }
    std::thread::scope(|s| {
        let fr = &f;
        let handles: Vec<_> = rs
            .into_iter()
            .enumerate()
            .map(|(i, r)| s.spawn(move || fr(i, r)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("par worker panicked"))
            .collect()
    })
}

/// Split `data` at `bounds` (ascending; `bounds[0] == 0`, last ==
/// `data.len()`) into `bounds.len() - 1` chunks and run `f(part,
/// bounds[part], chunk)` on scoped threads. For phases whose per-part
/// extents are data-dependent (CSR row spans): the chunks are disjoint
/// `&mut` slices, so the scatter is safe Rust.
pub fn for_uneven_chunks_mut<T, R, F>(data: &mut [T], bounds: &[usize], f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, usize, &mut [T]) -> R + Sync,
{
    let parts = bounds.len() - 1;
    debug_assert!(parts >= 1);
    debug_assert_eq!(bounds[0], 0);
    debug_assert_eq!(bounds[parts], data.len());
    if parts == 1 {
        return vec![f(0, 0, data)];
    }
    let mut chunks = Vec::with_capacity(parts);
    let mut rest = data;
    for p in 0..parts {
        // `mem::take` moves the slice out so the split borrows for the full
        // original lifetime (a plain `rest.split_at_mut(..)` reborrow could
        // not be pushed into `chunks` and reassigned).
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(bounds[p + 1] - bounds[p]);
        chunks.push(head);
        rest = tail;
    }
    std::thread::scope(|s| {
        let fr = &f;
        let handles: Vec<_> = chunks
            .into_iter()
            .enumerate()
            .map(|(p, chunk)| s.spawn(move || fr(p, bounds[p], chunk)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("par worker panicked"))
            .collect()
    })
}

/// [`for_uneven_chunks_mut`] with the near-equal [`ranges`] boundaries:
/// `f(part, chunk_start_index, chunk)`.
pub fn for_chunks_mut<T, R, F>(data: &mut [T], parts: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, usize, &mut [T]) -> R + Sync,
{
    let rs = ranges(data.len(), parts);
    let mut bounds: Vec<usize> = rs.iter().map(|r| r.start).collect();
    bounds.push(data.len());
    for_uneven_chunks_mut(data, &bounds, f)
}

/// Shared mutable view over a slice for scatter phases whose write
/// positions interleave across owners (per-`(thread, bucket)` cursor
/// regions) and therefore cannot be expressed as `split_at_mut` chunks.
/// Callers prove disjointness by construction: every index is written by
/// exactly one part.
pub struct UnsafeSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _lt: std::marker::PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Sync for UnsafeSlice<'_, T> {}
unsafe impl<T: Send> Send for UnsafeSlice<'_, T> {}

impl<'a, T> UnsafeSlice<'a, T> {
    /// Wrap a slice; the borrow keeps the underlying storage alive and
    /// exclusive for `'a`.
    pub fn new(slice: &'a mut [T]) -> Self {
        UnsafeSlice { ptr: slice.as_mut_ptr(), len: slice.len(), _lt: std::marker::PhantomData }
    }

    /// Write `value` at `i`.
    ///
    /// # Safety
    ///
    /// `i < len`, and no other thread reads or writes index `i` while the
    /// wrapper is live (disjoint cursor regions guarantee this at every
    /// call site).
    #[inline]
    pub unsafe fn write(&self, i: usize, value: T) {
        debug_assert!(i < self.len);
        *self.ptr.add(i) = value;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_display_roundtrip() {
        for (s, t) in [("auto", BuildThreads::Auto), ("1", BuildThreads::Fixed(1)), ("16", BuildThreads::Fixed(16))] {
            assert_eq!(s.parse::<BuildThreads>().unwrap(), t);
            assert_eq!(t.to_string(), s);
        }
        assert!("0".parse::<BuildThreads>().is_err());
        assert!("-3".parse::<BuildThreads>().is_err());
        assert!("many".parse::<BuildThreads>().is_err());
        assert!(BuildThreads::Auto.resolve() >= 1);
        assert_eq!(BuildThreads::Fixed(0).resolve(), 1);
    }

    #[test]
    fn ranges_tile_exactly() {
        for (len, parts) in [(10, 3), (0, 4), (7, 1), (3, 8), (100, 7)] {
            let rs = ranges(len, parts);
            assert_eq!(rs.len(), parts.max(1));
            let mut at = 0;
            for r in &rs {
                assert_eq!(r.start, at);
                at = r.end;
            }
            assert_eq!(at, len);
            // Near-equal: sizes differ by at most one.
            let sizes: Vec<usize> = rs.iter().map(|r| r.len()).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(mx - mn <= 1);
        }
    }

    #[test]
    fn for_ranges_returns_in_part_order() {
        let got = for_ranges(100, 4, |i, r| (i, r.start, r.end));
        assert_eq!(got, vec![(0, 0, 25), (1, 25, 50), (2, 50, 75), (3, 75, 100)]);
        assert_eq!(for_ranges(5, 1, |i, r| (i, r.len())), vec![(0, 5)]);
    }

    #[test]
    fn chunks_mut_cover_disjointly() {
        let mut data = vec![0u32; 103];
        for parts in [1, 2, 5, 8] {
            for_chunks_mut(&mut data, parts, |_p, start, chunk| {
                for (i, x) in chunk.iter_mut().enumerate() {
                    *x += (start + i) as u32;
                }
            });
        }
        // Four passes each added the index once.
        for (i, x) in data.iter().enumerate() {
            assert_eq!(*x, 4 * i as u32);
        }
    }

    #[test]
    fn uneven_chunks_respect_bounds() {
        let mut data: Vec<usize> = vec![0; 10];
        let bounds = [0usize, 1, 1, 7, 10];
        let lens = for_uneven_chunks_mut(&mut data, &bounds, |p, start, chunk| {
            for x in chunk.iter_mut() {
                *x = p;
            }
            (start, chunk.len())
        });
        assert_eq!(lens, vec![(0, 1), (1, 0), (1, 6), (7, 3)]);
        assert_eq!(data, vec![0, 2, 2, 2, 2, 2, 2, 3, 3, 3]);
    }

    #[test]
    fn unsafe_slice_disjoint_scatter() {
        let mut data = vec![0u64; 64];
        {
            let out = UnsafeSlice::new(&mut data);
            for_ranges(64, 4, |_, r| {
                for i in r {
                    // Each part owns its range: disjoint by construction.
                    unsafe { out.write(i, i as u64 * 3) };
                }
            });
        }
        for (i, x) in data.iter().enumerate() {
            assert_eq!(*x, i as u64 * 3);
        }
    }

    #[test]
    fn clamp_to_host_bounds() {
        let host = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        assert_eq!(clamp_to_host(0), 1);
        assert_eq!(clamp_to_host(1), 1);
        assert_eq!(clamp_to_host(usize::MAX), host);
        assert_eq!(clamp_to_host(host), host);
    }

    #[test]
    fn default_threads_clamps() {
        let prev = default_threads();
        set_default_threads(0);
        assert_eq!(default_threads(), 1);
        set_default_threads(prev.max(1));
    }
}
