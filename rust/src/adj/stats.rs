//! Kernel-path hit counters for the hybrid dispatch.
//!
//! Every [`crate::adj::view::intersect_count`] / [`intersect_into`]
//! call records which kernel actually ran, so runs can report the
//! representation mix (`tricount count`: `k_list_list`, `k_list_bitmap`,
//! `k_bitmap_bitmap`, `k_simd_blocked` in the JSON schema). Two sinks
//! exist:
//!
//! * **Process-global** relaxed atomics — the cross-rank sum, as the
//!   CLI has always reported it.
//! * An optional **per-rank** sink: the cluster launcher installs one
//!   [`RankKernelCounters`] handle into each rank thread's TLS
//!   ([`install_rank`]), and [`record`] bumps it alongside the global
//!   counters. That scopes the mix per rank for the obs registry
//!   (`obs::registry`) without the global snapshot changing meaning —
//!   Σ per-rank == global delta, pinned by test.
//!
//! Each bump is a single uncontended add (plus one TLS read) next to an
//! intersection that walks whole lists; the obs overhead gate
//! (`rust/tests/obs_overhead.rs`) bounds the cost at < 3%.
//!
//! [`intersect_into`]: crate::adj::view::intersect_into

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// One counter per cache line so rank threads bumping different paths
/// don't false-share (they still share a line when hitting the *same*
/// path — acceptable on the target container, which is single-core).
#[repr(align(64))]
struct PaddedCounter(AtomicU64);

static LIST_LIST: PaddedCounter = PaddedCounter(AtomicU64::new(0));
static LIST_BITMAP: PaddedCounter = PaddedCounter(AtomicU64::new(0));
static BITMAP_BITMAP: PaddedCounter = PaddedCounter(AtomicU64::new(0));
static SIMD_BLOCKED: PaddedCounter = PaddedCounter(AtomicU64::new(0));

/// Which kernel the dispatch chose for one intersection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelPath {
    /// Sorted×sorted: the adaptive merge/gallop kernel.
    ListList,
    /// One side has a bitmap: probe the other side's list into it.
    ListBitmap,
    /// Both sides have bitmaps: word-AND + popcount.
    BitmapBitmap,
    /// Sorted×sorted on the SWAR blocked-merge tier
    /// ([`crate::intersect::count_simd_blocked`]): balanced mid-size
    /// pairs where the u64-packed window comparison beats the scalar
    /// merge (DESIGN.md §12).
    SimdBlocked,
}

/// Per-rank counter cell. The launcher owns one `Arc` per rank, installs
/// a clone into the rank thread's TLS for the duration of the rank
/// program, and snapshots it into that rank's `CommMetrics::kernel`.
/// Atomics (not `Cell`) so the owner may snapshot while the rank runs.
#[derive(Debug, Default)]
pub struct RankKernelCounters {
    list_list: AtomicU64,
    list_bitmap: AtomicU64,
    bitmap_bitmap: AtomicU64,
    simd_blocked: AtomicU64,
}

impl RankKernelCounters {
    #[inline]
    fn bump(&self, path: KernelPath) {
        let c = match path {
            KernelPath::ListList => &self.list_list,
            KernelPath::ListBitmap => &self.list_bitmap,
            KernelPath::BitmapBitmap => &self.bitmap_bitmap,
            KernelPath::SimdBlocked => &self.simd_blocked,
        };
        c.fetch_add(1, Ordering::Relaxed);
    }

    /// Read this rank's counters.
    pub fn snapshot(&self) -> KernelStats {
        KernelStats {
            list_list: self.list_list.load(Ordering::Relaxed),
            list_bitmap: self.list_bitmap.load(Ordering::Relaxed),
            bitmap_bitmap: self.bitmap_bitmap.load(Ordering::Relaxed),
            simd_blocked: self.simd_blocked.load(Ordering::Relaxed),
        }
    }
}

thread_local! {
    static RANK_COUNTERS: RefCell<Option<Arc<RankKernelCounters>>> =
        const { RefCell::new(None) };
}

/// RAII guard returned by [`install_rank`]; uninstalls the per-rank sink
/// from this thread's TLS on drop (including unwinds), so a finished rank
/// thread can never leak its sink into unrelated work.
pub struct RankScope {
    _priv: (),
}

impl Drop for RankScope {
    fn drop(&mut self) {
        RANK_COUNTERS.with(|s| *s.borrow_mut() = None);
    }
}

/// Install `counters` as the calling thread's per-rank kernel sink.
/// Nested installs replace (last wins) until their guard drops.
pub fn install_rank(counters: Arc<RankKernelCounters>) -> RankScope {
    RANK_COUNTERS.with(|s| *s.borrow_mut() = Some(counters));
    RankScope { _priv: () }
}

/// Record one dispatch decision: always into the process-global sum, and
/// additionally into the calling thread's per-rank sink if one is
/// installed.
#[inline]
pub fn record(path: KernelPath) {
    let c = match path {
        KernelPath::ListList => &LIST_LIST,
        KernelPath::ListBitmap => &LIST_BITMAP,
        KernelPath::BitmapBitmap => &BITMAP_BITMAP,
        KernelPath::SimdBlocked => &SIMD_BLOCKED,
    };
    c.0.fetch_add(1, Ordering::Relaxed);
    RANK_COUNTERS.with(|s| {
        if let Some(rc) = s.borrow().as_deref() {
            rc.bump(path);
        }
    });
}

/// Snapshot of the process-wide counters (the cross-rank sum).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KernelStats {
    pub list_list: u64,
    pub list_bitmap: u64,
    pub bitmap_bitmap: u64,
    /// SWAR blocked-merge list×list tier (a dispatch refinement of the
    /// list×list arm, counted separately so the mix is observable).
    pub simd_blocked: u64,
}

impl crate::comm::transport::Wire for KernelStats {
    fn write_to(&self, out: &mut Vec<u8>) {
        use crate::comm::transport::Wire;
        self.list_list.write_to(out);
        self.list_bitmap.write_to(out);
        self.bitmap_bitmap.write_to(out);
        self.simd_blocked.write_to(out);
    }
    fn read_from(r: &mut crate::comm::transport::WireReader<'_>) -> crate::error::Result<Self> {
        Ok(KernelStats {
            list_list: r.u64()?,
            list_bitmap: r.u64()?,
            bitmap_bitmap: r.u64()?,
            simd_blocked: r.u64()?,
        })
    }
}

impl KernelStats {
    /// Total intersections dispatched.
    pub fn total(&self) -> u64 {
        self.list_list + self.list_bitmap + self.bitmap_bitmap + self.simd_blocked
    }

    /// Intersections that used a bitmap kernel.
    pub fn bitmap_hits(&self) -> u64 {
        self.list_bitmap + self.bitmap_bitmap
    }

    /// Field-wise accumulate (used by `CommMetrics::merge`, so the
    /// cluster total of per-rank kernels is again a `KernelStats`).
    pub fn merge(&mut self, other: &KernelStats) {
        self.list_list += other.list_list;
        self.list_bitmap += other.list_bitmap;
        self.bitmap_bitmap += other.bitmap_bitmap;
        self.simd_blocked += other.simd_blocked;
    }
}

/// Read the counters.
pub fn snapshot() -> KernelStats {
    KernelStats {
        list_list: LIST_LIST.0.load(Ordering::Relaxed),
        list_bitmap: LIST_BITMAP.0.load(Ordering::Relaxed),
        bitmap_bitmap: BITMAP_BITMAP.0.load(Ordering::Relaxed),
        simd_blocked: SIMD_BLOCKED.0.load(Ordering::Relaxed),
    }
}

/// Zero the counters (drivers call this before the phase they report on).
pub fn reset() {
    LIST_LIST.0.store(0, Ordering::Relaxed);
    LIST_BITMAP.0.store(0, Ordering::Relaxed);
    BITMAP_BITMAP.0.store(0, Ordering::Relaxed);
    SIMD_BLOCKED.0.store(0, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_accumulates() {
        // Counters are process-global and tests run concurrently, so assert
        // on deltas of the path we touch being at least what we added.
        let before = snapshot();
        record(KernelPath::BitmapBitmap);
        record(KernelPath::BitmapBitmap);
        let after = snapshot();
        assert!(after.bitmap_bitmap >= before.bitmap_bitmap + 2);
        assert!(after.total() >= before.total() + 2);
    }

    #[test]
    fn rank_scope_routes_bumps_while_installed() {
        let mine = Arc::new(RankKernelCounters::default());
        {
            let _scope = install_rank(mine.clone());
            record(KernelPath::ListList);
            record(KernelPath::ListBitmap);
        }
        // Guard dropped: further bumps are global-only.
        record(KernelPath::ListList);
        let got = mine.snapshot();
        assert_eq!(
            got,
            KernelStats { list_list: 1, list_bitmap: 1, bitmap_bitmap: 0, simd_blocked: 0 }
        );
        // Per-rank cells are exact even though the globals are shared with
        // concurrently running tests: nothing else holds this Arc.
        assert_eq!(got.total(), 2);
    }

    #[test]
    fn kernel_stats_merge_is_fieldwise() {
        let mut a = KernelStats { list_list: 1, list_bitmap: 2, bitmap_bitmap: 3, simd_blocked: 4 };
        a.merge(&KernelStats {
            list_list: 10,
            list_bitmap: 20,
            bitmap_bitmap: 30,
            simd_blocked: 40,
        });
        assert_eq!(
            a,
            KernelStats { list_list: 11, list_bitmap: 22, bitmap_bitmap: 33, simd_blocked: 44 }
        );
        assert_eq!(a.total(), 110);
    }
}
