//! Kernel-path hit counters for the hybrid dispatch.
//!
//! Every [`crate::adj::view::intersect_count`] / [`intersect_into`]
//! call records which kernel actually ran, so runs can report the
//! representation mix (`tricount count`: `k_list_list`, `k_list_bitmap`,
//! `k_bitmap_bitmap` in the JSON schema). Counters are process-global
//! relaxed atomics — a single uncontended add next to an intersection that
//! walks whole lists — and are aggregated across rank threads, matching how
//! the rest of the metrics layer reports cluster-wide totals.
//!
//! [`intersect_into`]: crate::adj::view::intersect_into

use std::sync::atomic::{AtomicU64, Ordering};

/// One counter per cache line so rank threads bumping different paths
/// don't false-share (they still share a line when hitting the *same*
/// path — acceptable on the target container, which is single-core).
#[repr(align(64))]
struct PaddedCounter(AtomicU64);

static LIST_LIST: PaddedCounter = PaddedCounter(AtomicU64::new(0));
static LIST_BITMAP: PaddedCounter = PaddedCounter(AtomicU64::new(0));
static BITMAP_BITMAP: PaddedCounter = PaddedCounter(AtomicU64::new(0));

/// Which kernel the dispatch chose for one intersection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelPath {
    /// Sorted×sorted: the adaptive merge/gallop kernel.
    ListList,
    /// One side has a bitmap: probe the other side's list into it.
    ListBitmap,
    /// Both sides have bitmaps: word-AND + popcount.
    BitmapBitmap,
}

/// Record one dispatch decision.
#[inline]
pub fn record(path: KernelPath) {
    let c = match path {
        KernelPath::ListList => &LIST_LIST,
        KernelPath::ListBitmap => &LIST_BITMAP,
        KernelPath::BitmapBitmap => &BITMAP_BITMAP,
    };
    c.0.fetch_add(1, Ordering::Relaxed);
}

/// Snapshot of the process-wide counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KernelStats {
    pub list_list: u64,
    pub list_bitmap: u64,
    pub bitmap_bitmap: u64,
}

impl KernelStats {
    /// Total intersections dispatched.
    pub fn total(&self) -> u64 {
        self.list_list + self.list_bitmap + self.bitmap_bitmap
    }

    /// Intersections that used a bitmap kernel.
    pub fn bitmap_hits(&self) -> u64 {
        self.list_bitmap + self.bitmap_bitmap
    }
}

/// Read the counters.
pub fn snapshot() -> KernelStats {
    KernelStats {
        list_list: LIST_LIST.0.load(Ordering::Relaxed),
        list_bitmap: LIST_BITMAP.0.load(Ordering::Relaxed),
        bitmap_bitmap: BITMAP_BITMAP.0.load(Ordering::Relaxed),
    }
}

/// Zero the counters (drivers call this before the phase they report on).
pub fn reset() {
    LIST_LIST.0.store(0, Ordering::Relaxed);
    LIST_BITMAP.0.store(0, Ordering::Relaxed);
    BITMAP_BITMAP.0.store(0, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_accumulates() {
        // Counters are process-global and tests run concurrently, so assert
        // on deltas of the path we touch being at least what we added.
        let before = snapshot();
        record(KernelPath::BitmapBitmap);
        record(KernelPath::BitmapBitmap);
        let after = snapshot();
        assert!(after.bitmap_bitmap >= before.bitmap_bitmap + 2);
        assert!(after.total() >= before.total() + 2);
    }
}
