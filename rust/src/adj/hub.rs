//! Hub selection policy and the per-graph index of bitmap rows.
//!
//! A **hub** row gets a packed [`BitmapRow`] *in addition to* its sorted
//! slice, so every consumer can keep iterating lists while the
//! intersection dispatch upgrades hub pairs to probe / word-AND kernels.
//!
//! Threshold policy (CLI `--hub-threshold <n|auto|off>`):
//! * `off` — no bitmaps, the seed's pure sorted-slice behavior;
//! * `<n>` — fixed out-degree cutoff, every row with `d̂_v ≥ n` (explicit
//!   user choice: no memory budget, exact cutoff);
//! * `auto` — density rule: rows with `d̂_v ≥ `[`AUTO_FLOOR`] are taken
//!   **heaviest first** until their trimmed-span bytes reach the budget
//!   [`AUTO_BUDGET_BYTES_PER_EDGE`]`·m` (the size of the `targets` array —
//!   bitmaps at most double adjacency memory). Degree ordering tames the
//!   oriented tail (on PA(100K, 64) the maximum `d̂` is ≈ 50 against an
//!   average of 32), so the rule is *relative*: it bitmaps whatever rows
//!   are heaviest in this graph rather than demanding an absolute hub
//!   size no oriented row would ever reach.
//!
//! The streaming Δ counter caches bitmaps over *unoriented* merged rows
//! (true power-law hubs, degrees in the thousands); its per-batch rule is
//! [`HubThreshold::resolve`] — a plain cutoff, since the cache only ever
//! builds rows for endpoints the batch actually touches.

use crate::adj::bitmap::BitmapRow;
use crate::error::Error;
use crate::VertexId;

/// Minimum out-degree for a bitmap row — below this, merge is cheap enough
/// that the bitmap build/memory overhead cannot pay off.
pub const AUTO_FLOOR: usize = 32;

/// `auto` spends at most this many bitmap bytes per oriented edge (4 ⇒
/// the budget equals the size of the `targets` array itself).
pub const AUTO_BUDGET_BYTES_PER_EDGE: u64 = 4;

/// Streaming `auto` marks merged rows at least this multiple of the
/// average row length (see [`HubThreshold::resolve`]).
pub const AUTO_DENSITY_FACTOR: usize = 2;

/// Below this many selected hub rows per thread, bitmap packing stays
/// serial (rows are word-sized copies; spawning costs more than packing).
const MIN_HUB_ROWS_PER_THREAD: usize = 64;

/// Hub-bitmap threshold policy.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum HubThreshold {
    /// No bitmap rows at all.
    Off,
    /// Density rule with memory budget (see module docs).
    #[default]
    Auto,
    /// Fixed out-degree cutoff, unbudgeted.
    Fixed(usize),
}

impl HubThreshold {
    /// Resolve to a plain cutoff for rows holding `row_entries` total
    /// entries across `n` nodes; `None` = disabled. This is the policy the
    /// streaming Δ counter's per-batch cache uses (`auto` ⇒
    /// `max(`[`AUTO_FLOOR`]`, `[`AUTO_DENSITY_FACTOR`]`·⌈entries/n⌉)`);
    /// the static [`HubIndex::build`] additionally applies the `auto`
    /// memory budget.
    pub fn resolve(self, n: usize, row_entries: u64) -> Option<usize> {
        match self {
            HubThreshold::Off => None,
            HubThreshold::Fixed(t) => Some(t),
            HubThreshold::Auto => {
                let avg = if n == 0 { 0 } else { (row_entries as usize).div_ceil(n) };
                Some(AUTO_FLOOR.max(AUTO_DENSITY_FACTOR * avg))
            }
        }
    }
}

impl std::str::FromStr for HubThreshold {
    type Err = Error;
    fn from_str(s: &str) -> crate::error::Result<Self> {
        match s {
            "off" | "none" => Ok(HubThreshold::Off),
            "auto" => Ok(HubThreshold::Auto),
            other => other
                .parse::<usize>()
                .map(HubThreshold::Fixed)
                .map_err(|_| Error::Config(format!("hub threshold `{other}` is not n|auto|off"))),
        }
    }
}

impl std::fmt::Display for HubThreshold {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HubThreshold::Off => write!(f, "off"),
            HubThreshold::Auto => write!(f, "auto"),
            HubThreshold::Fixed(t) => write!(f, "{t}"),
        }
    }
}

/// Representation statistics for reports (`tricount count` JSON schema).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HubStats {
    /// Effective cutoff: the smallest `d̂` that got a bitmap (`Fixed` ⇒ the
    /// fixed value; `None` when bitmaps are disabled).
    pub threshold: Option<usize>,
    /// Rows that got a bitmap.
    pub hubs: usize,
    /// Heap bytes of all bitmap words plus the row index.
    pub bitmap_bytes: u64,
}

/// Per-graph index: which rows have bitmaps, and the rows themselves.
#[derive(Clone, Debug, Default)]
pub struct HubIndex {
    /// `row_of[v]` = index into `rows`, or `u32::MAX`. Empty ⇔ no hubs.
    row_of: Vec<u32>,
    rows: Vec<BitmapRow>,
    /// Effective cutoff (see [`HubStats::threshold`]).
    threshold: Option<usize>,
    /// `true` ⇒ the cutoff is exact (`Fixed`: bitmap ⇔ `d̂ ≥ t`); `false`
    /// for `Auto`, whose budget may stop inside a degree plateau.
    exact: bool,
}

impl HubIndex {
    /// Index with bitmaps disabled (also the `Default`).
    pub fn disabled() -> Self {
        HubIndex::default()
    }

    /// Build over CSR-shaped rows: row `v` is
    /// `targets[offsets[v]..offsets[v+1]]`.
    pub fn build(offsets: &[u64], targets: &[VertexId], policy: HubThreshold) -> Self {
        Self::build_threads(offsets, targets, policy, 1)
    }

    /// [`HubIndex::build`] with the bitmap-row packing fanned out over
    /// scoped threads. Selection stays serial — it is O(n) plus a sort of
    /// the candidates and fully determines row order — so the index is
    /// bit-identical at every thread count.
    pub fn build_threads(
        offsets: &[u64],
        targets: &[VertexId],
        policy: HubThreshold,
        threads: usize,
    ) -> Self {
        let row = |v: usize| &targets[offsets[v] as usize..offsets[v + 1] as usize];
        let n = offsets.len() - 1;
        let selected: Vec<usize> = match policy {
            HubThreshold::Off => return HubIndex::disabled(),
            HubThreshold::Fixed(t) => (0..n).filter(|&v| row(v).len() >= t).collect(),
            HubThreshold::Auto => {
                // Heaviest rows first, within the span-byte budget.
                let budget = AUTO_BUDGET_BYTES_PER_EDGE * targets.len() as u64;
                let mut cand: Vec<usize> = (0..n).filter(|&v| row(v).len() >= AUTO_FLOOR).collect();
                cand.sort_unstable_by_key(|&v| (std::cmp::Reverse(row(v).len()), v));
                let mut spent = 0u64;
                let mut sel = Vec::new();
                for v in cand {
                    let r = row(v);
                    // Trimmed span bytes, computable without building. Skip
                    // (don't stop at) rows that overflow the budget: one
                    // smeared-span row must not starve the denser rows
                    // behind it.
                    let bytes =
                        8 * (r[r.len() - 1] as u64 / 64 - r[0] as u64 / 64 + 1);
                    if spent + bytes > budget {
                        continue;
                    }
                    spent += bytes;
                    sel.push(v);
                }
                sel
            }
        };
        let threshold = match policy {
            HubThreshold::Fixed(t) => Some(t),
            // Effective auto cutoff: the lightest selected row (floor when
            // nothing qualified).
            _ => Some(selected.iter().map(|&v| row(v).len()).min().unwrap_or(AUTO_FLOOR)),
        };
        if selected.is_empty() {
            // Nothing qualified: drop the index so `get` is a length check.
            return HubIndex {
                row_of: Vec::new(),
                rows: Vec::new(),
                threshold,
                exact: matches!(policy, HubThreshold::Fixed(_)),
            };
        }
        let mut row_of = vec![u32::MAX; n];
        for (i, &v) in selected.iter().enumerate() {
            row_of[v] = i as u32;
        }
        // Packing is embarrassingly parallel per selected row; results are
        // concatenated in selection order.
        let t = crate::par::clamp_threads(threads, selected.len(), MIN_HUB_ROWS_PER_THREAD);
        let rows: Vec<BitmapRow> = crate::par::for_ranges(selected.len(), t, |_, r| {
            selected[r]
                .iter()
                .map(|&v| BitmapRow::from_sorted(row(v)))
                .collect::<Vec<_>>()
        })
        .into_iter()
        .flatten()
        .collect();
        HubIndex { row_of, rows, threshold, exact: matches!(policy, HubThreshold::Fixed(_)) }
    }

    /// The bitmap row of `v`, if `v` is a hub.
    #[inline]
    pub fn get(&self, v: VertexId) -> Option<&BitmapRow> {
        match self.row_of.get(v as usize) {
            Some(&i) if i != u32::MAX => Some(&self.rows[i as usize]),
            _ => None,
        }
    }

    /// Effective cutoff (`None` = disabled).
    #[inline]
    pub fn threshold(&self) -> Option<usize> {
        self.threshold
    }

    /// Representation stats for reports.
    pub fn stats(&self) -> HubStats {
        HubStats {
            threshold: self.threshold,
            hubs: self.rows.len(),
            bitmap_bytes: self.bytes(),
        }
    }

    /// Heap bytes of the rows plus the per-node index.
    pub fn bytes(&self) -> u64 {
        self.rows.iter().map(BitmapRow::bytes).sum::<u64>() + (self.row_of.len() * 4) as u64
    }

    /// Check index invariants against the rows it was built over: every
    /// bitmap encodes exactly its list and sits at/above the cutoff; with
    /// an exact cutoff, every qualifying row has a bitmap.
    pub fn validate(&self, offsets: &[u64], targets: &[VertexId]) -> Result<(), String> {
        for v in 0..offsets.len() - 1 {
            let list = &targets[offsets[v] as usize..offsets[v + 1] as usize];
            match (self.get(v as VertexId), self.threshold) {
                (Some(row), Some(t)) => {
                    if list.len() < t {
                        return Err(format!("node {v}: bitmap below cutoff {t}"));
                    }
                    if row.ones() != list.len() || !list.iter().all(|&u| row.contains(u)) {
                        return Err(format!("node {v}: bitmap disagrees with its list"));
                    }
                }
                (None, Some(t)) => {
                    if self.exact && list.len() >= t {
                        return Err(format!("node {v} (d̂={}) missing bitmap", list.len()));
                    }
                }
                (Some(_), None) => return Err(format!("node {v}: bitmap while disabled")),
                (None, None) => {}
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_display_roundtrip() {
        for (s, t) in [
            ("off", HubThreshold::Off),
            ("auto", HubThreshold::Auto),
            ("128", HubThreshold::Fixed(128)),
            ("0", HubThreshold::Fixed(0)),
        ] {
            assert_eq!(s.parse::<HubThreshold>().unwrap(), t);
            assert_eq!(t.to_string(), s);
        }
        assert_eq!("none".parse::<HubThreshold>().unwrap(), HubThreshold::Off);
        assert!("fast".parse::<HubThreshold>().is_err());
        assert!("-1".parse::<HubThreshold>().is_err());
    }

    #[test]
    fn resolve_rules() {
        assert_eq!(HubThreshold::Off.resolve(100, 1000), None);
        assert_eq!(HubThreshold::Fixed(7).resolve(100, 1000), Some(7));
        // Sparse: floor wins.
        assert_eq!(HubThreshold::Auto.resolve(1000, 2000), Some(AUTO_FLOOR));
        // Dense: 2× average row length (⌈10⁵/10³⌉ = 100 → 200).
        assert_eq!(HubThreshold::Auto.resolve(1000, 100_000), Some(200));
        assert_eq!(HubThreshold::Auto.resolve(0, 0), Some(AUTO_FLOOR));
    }

    #[test]
    fn fixed_marks_exactly_threshold_rows() {
        // Rows: [0..5], [5..5] (empty), [5..8].
        let offsets = [0u64, 5, 5, 8];
        let targets = [1u32, 2, 3, 4, 9, 0, 1, 2];
        let idx = HubIndex::build(&offsets, &targets, HubThreshold::Fixed(3));
        assert!(idx.get(0).is_some());
        assert!(idx.get(1).is_none());
        assert!(idx.get(2).is_some());
        assert_eq!(idx.stats().hubs, 2);
        assert!(idx.bytes() > 0);
        idx.validate(&offsets, &targets).unwrap();

        let idx0 = HubIndex::build(&offsets, &targets, HubThreshold::Fixed(0));
        assert_eq!(idx0.stats().hubs, 3, "threshold 0 bitmaps every row");
        assert!(idx0.get(1).is_some(), "even the empty row");
        idx0.validate(&offsets, &targets).unwrap();

        let off = HubIndex::build(&offsets, &targets, HubThreshold::Off);
        assert_eq!(off.stats().hubs, 0);
        assert!(off.get(0).is_none());
        assert_eq!(off.bytes(), 0);
        off.validate(&offsets, &targets).unwrap();
    }

    #[test]
    fn auto_takes_heaviest_rows_within_budget() {
        // Three rows ≥ AUTO_FLOOR with different lengths; tiny budget would
        // be exceeded by all three, so the heaviest win.
        let n = 3usize;
        let lens = [AUTO_FLOOR + 2, AUTO_FLOOR, AUTO_FLOOR + 1];
        let mut offsets = vec![0u64];
        let mut targets: Vec<VertexId> = Vec::new();
        for l in lens {
            targets.extend(0..l as VertexId);
            offsets.push(targets.len() as u64);
        }
        let idx = HubIndex::build(&offsets, &targets, HubThreshold::Auto);
        // Budget 4·m bytes is plenty here (spans are one word each): all in.
        assert_eq!(idx.stats().hubs, n);
        assert_eq!(idx.threshold(), Some(AUTO_FLOOR), "lightest selected row");
        idx.validate(&offsets, &targets).unwrap();
    }

    #[test]
    fn auto_budget_prefers_heaviest_but_backfills() {
        // Rows with huge trimmed spans: ids spread to multiples of 64 so
        // each row costs `8·len` span bytes against a `4·Σlen` budget.
        // Heaviest-first: row 3 (44·8=352) fits; rows 1 (320) and 2 (288)
        // would overflow the 608-byte budget and are skipped; row 0 (256)
        // still fits — over-budget rows must not starve later ones.
        let lens = [AUTO_FLOOR, AUTO_FLOOR + 8, AUTO_FLOOR + 4, AUTO_FLOOR + 12];
        let mut offsets = vec![0u64];
        let mut targets: Vec<VertexId> = Vec::new();
        for l in lens {
            targets.extend((0..l as VertexId).map(|x| x * 64));
            offsets.push(targets.len() as u64);
        }
        let idx = HubIndex::build(&offsets, &targets, HubThreshold::Auto);
        assert_eq!(idx.stats().hubs, 2, "budget must bite");
        assert!(idx.get(3).is_some(), "heaviest row selected first");
        assert!(idx.get(0).is_some(), "light row backfills the budget");
        assert!(idx.get(1).is_none() && idx.get(2).is_none());
        idx.validate(&offsets, &targets).unwrap();
    }

    #[test]
    fn below_floor_never_bitmapped_by_auto() {
        let offsets = [0u64, 3, 6];
        let targets = [1u32, 2, 3, 0, 2, 3];
        let idx = HubIndex::build(&offsets, &targets, HubThreshold::Auto);
        assert_eq!(idx.stats().hubs, 0);
        assert_eq!(idx.bytes(), 0, "index freed when nothing qualifies");
        assert_eq!(idx.threshold(), Some(AUTO_FLOOR));
        idx.validate(&offsets, &targets).unwrap();
    }
}
