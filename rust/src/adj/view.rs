//! `NeighborView` — the unified neighbor representation every counting
//! path intersects through — and the hybrid kernel dispatch.
//!
//! A view is a sorted slice plus, for hub rows, a packed [`BitmapRow`]
//! (the slice is *always* present; the bitmap is an accelerator, not a
//! replacement). [`intersect_count`] dispatches each pair to the cheapest
//! kernel:
//!
//! | a \ b        | sorted                       | bitmap                  |
//! |--------------|------------------------------|-------------------------|
//! | **sorted**   | SWAR blocked merge when      | probe a's list into b\* |
//! |              | balanced & ≥ both 16 long,   |                         |
//! |              | else adaptive merge/gallop   |                         |
//! | **bitmap**   | probe b's list into a\*      | word-AND + popcount,    |
//! |              |                              | else probe shorter list |
//!
//! Every choice is cost-guarded so the hybrid layer is never slower (in
//! element steps) than the adaptive kernel it replaced:
//! * mixed pairs probe only when the probing list is no longer than
//!   [`intersect::adaptive_cost`] — a short bitmap row against a long
//!   plain list (a wire payload, say) still wins by *galloping*, not by
//!   probing the long list (\*);
//! * bitmap×bitmap word-ANDs only when the span overlap is within the
//!   shorter list's length (hub neighbors smeared across a huge id range
//!   fall back to probing the shorter list, which costs `min` — at most
//!   the gallop cost).
//!
//! The executed kernel (not the available representations) is what
//! [`crate::adj::stats`] records and [`intersect_cost`] charges.

use crate::adj::bitmap::BitmapRow;
use crate::adj::stats::{self, KernelPath};
use crate::intersect;
use crate::VertexId;

/// A neighbor list as the kernels see it: sorted slice + optional bitmap.
#[derive(Clone, Copy, Debug)]
pub struct NeighborView<'a> {
    list: &'a [VertexId],
    bits: Option<&'a BitmapRow>,
}

impl<'a> NeighborView<'a> {
    /// Plain sorted-slice view (remote lists, overlay merges, oracles).
    #[inline]
    pub fn sorted(list: &'a [VertexId]) -> Self {
        NeighborView { list, bits: None }
    }

    /// View with an optional bitmap row (hub rows pass `Some`).
    #[inline]
    pub fn hybrid(list: &'a [VertexId], bits: Option<&'a BitmapRow>) -> Self {
        debug_assert!(match bits {
            Some(b) => b.ones() == list.len(),
            None => true,
        });
        NeighborView { list, bits }
    }

    /// The sorted id list.
    #[inline]
    pub fn list(&self) -> &'a [VertexId] {
        self.list
    }

    /// The bitmap row, when this is a hub.
    #[inline]
    pub fn bits(&self) -> Option<&'a BitmapRow> {
        self.bits
    }

    /// Neighbor count.
    #[inline]
    pub fn len(&self) -> usize {
        self.list.len()
    }

    /// `true` iff the list is empty.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.list.is_empty()
    }

    /// `true` iff this row carries a bitmap.
    #[inline]
    pub fn is_hub(&self) -> bool {
        self.bits.is_some()
    }
}

/// The kernel chosen for one pair (shared by count / materialize / cost so
/// all three always agree).
enum Plan<'a> {
    /// Adaptive merge/gallop on the two lists.
    Merge,
    /// Probe `list` into `bits`.
    Probe { list: &'a [VertexId], bits: &'a BitmapRow, path: KernelPath },
    /// Word-AND the two bitmap spans.
    Words { a: &'a BitmapRow, b: &'a BitmapRow },
}

/// Mixed pair: probe `list` into `bits` only when that beats the adaptive
/// list×list cost (galloping a short hub row through a long plain list is
/// cheaper than probing the long list element-by-element).
#[inline]
fn probe_or_merge<'a>(list: &'a [VertexId], bits: &'a BitmapRow, other_len: usize) -> Plan<'a> {
    if list.len() as u64 <= intersect::adaptive_cost(other_len, list.len()) {
        Plan::Probe { list, bits, path: KernelPath::ListBitmap }
    } else {
        Plan::Merge
    }
}

#[inline]
fn plan<'a>(a: NeighborView<'a>, b: NeighborView<'a>) -> Plan<'a> {
    match (a.bits, b.bits) {
        (Some(ba), Some(bb)) => {
            let min_len = a.len().min(b.len());
            if ba.overlap_words(bb) <= min_len {
                Plan::Words { a: ba, b: bb }
            } else {
                // Sparse spans: word-AND would scan more words than the
                // shorter list holds — probe the shorter list instead
                // (cost `min`, never above the gallop cost).
                let (list, bits) = if a.len() <= b.len() { (a.list, bb) } else { (b.list, ba) };
                Plan::Probe { list, bits, path: KernelPath::ListBitmap }
            }
        }
        (Some(ba), None) => probe_or_merge(b.list, ba, a.len()),
        (None, Some(bb)) => probe_or_merge(a.list, bb, b.len()),
        (None, None) => Plan::Merge,
    }
}

/// `|a ∩ b|`, added to `out_count` — the unified intersection kernel every
/// counting driver goes through (replaces direct `intersect::count_*`
/// calls on raw slices).
#[inline]
pub fn intersect_count(a: NeighborView, b: NeighborView, out_count: &mut u64) {
    match plan(a, b) {
        Plan::Merge => {
            // The list×list arm has one further cost-guarded tier: balanced
            // mid-size pairs go to the SWAR blocked merge (8 candidate
            // comparisons per u64-packed window). Skewed pairs still gallop
            // and short pairs still scalar-merge — the guard mirrors
            // `adaptive_cost`'s merge branch, so `intersect_cost` is
            // unchanged (the blocked tier is a constant-factor accelerator
            // over the same `min + max` element walk; DESIGN.md §12).
            let min_len = a.list.len().min(b.list.len());
            let max_len = a.list.len().max(b.list.len());
            if min_len >= intersect::SIMD_BLOCK_MIN
                && max_len / min_len < intersect::GALLOP_RATIO
            {
                stats::record(KernelPath::SimdBlocked);
                intersect::count_simd_blocked(a.list, b.list, out_count);
            } else {
                stats::record(KernelPath::ListList);
                intersect::count_adaptive(a.list, b.list, out_count);
            }
        }
        Plan::Probe { list, bits, path } => {
            stats::record(path);
            let mut c = 0u64;
            for &x in list {
                c += bits.contains(x) as u64;
            }
            *out_count += c;
        }
        Plan::Words { a, b } => {
            stats::record(KernelPath::BitmapBitmap);
            *out_count += a.and_popcount(b);
        }
    }
}

/// Materializing dispatch: `a ∩ b` appended to `out` in ascending id
/// order (the hybrid replacement for [`intersect::intersect_vec`]).
pub fn intersect_into(a: NeighborView, b: NeighborView, out: &mut Vec<VertexId>) {
    match plan(a, b) {
        Plan::Merge => {
            stats::record(KernelPath::ListList);
            intersect::merge_into(a.list, b.list, out);
        }
        Plan::Probe { list, bits, path } => {
            stats::record(path);
            out.extend(list.iter().copied().filter(|&x| bits.contains(x)));
        }
        Plan::Words { a, b } => {
            stats::record(KernelPath::BitmapBitmap);
            a.and_collect(b, out);
        }
    }
}

/// What [`intersect_count`] charges for this pair, in the element-step
/// units of [`intersect::adaptive_cost`] (one 64-bit word-AND ≙ one step).
/// This is the *true* execution cost the simulators and the hybrid-aware
/// estimator charge; the paper's estimators still model the merge cost
/// `d̂_v + d̂_u`, and the widened estimate-vs-reality gap is exactly what
/// §V's dynamic load balancing is there to absorb.
#[inline]
pub fn intersect_cost(a: NeighborView, b: NeighborView) -> u64 {
    match plan(a, b) {
        Plan::Merge => intersect::adaptive_cost(a.len(), b.len()),
        Plan::Probe { list, .. } => list.len().max(1) as u64,
        Plan::Words { a, b } => a.overlap_words(b).max(1) as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::rng::Rng;

    fn sorted_list(rng: &mut Rng, len: usize, universe: u32) -> Vec<VertexId> {
        let mut v: Vec<VertexId> = (0..len).map(|_| rng.next_u32() % universe).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// All four representation combinations must agree with the merge
    /// oracle for both counting and materializing dispatch.
    #[test]
    fn all_dispatch_paths_agree_with_merge() {
        let mut rng = Rng::seeded(0xD15);
        for case in 0..200 {
            // Mix dense (small universe) and sparse (large universe) so
            // both the word-AND and the probe fallback branches run.
            let universe = if case % 2 == 0 { 400 } else { 1 << 20 };
            let a = sorted_list(&mut rng, rng.below_usize(200), universe);
            let b = sorted_list(&mut rng, rng.below_usize(200), universe);
            let (ra, rb) = (BitmapRow::from_sorted(&a), BitmapRow::from_sorted(&b));
            let expect = crate::intersect::intersect_vec(&a, &b);

            let views = |wa: bool, wb: bool| {
                (
                    NeighborView::hybrid(&a, wa.then_some(&ra)),
                    NeighborView::hybrid(&b, wb.then_some(&rb)),
                )
            };
            for (wa, wb) in [(false, false), (true, false), (false, true), (true, true)] {
                let (va, vb) = views(wa, wb);
                let mut c = 0u64;
                intersect_count(va, vb, &mut c);
                assert_eq!(c, expect.len() as u64, "count case {case} ({wa},{wb})");
                let mut got = Vec::new();
                intersect_into(va, vb, &mut got);
                assert_eq!(got, expect, "into case {case} ({wa},{wb})");
                assert!(intersect_cost(va, vb) >= 1);
            }
        }
    }

    #[test]
    fn probe_cost_is_probing_list_length() {
        let hub: Vec<VertexId> = (0..1000).collect();
        let small = vec![5, 500, 999];
        let row = BitmapRow::from_sorted(&hub);
        let vh = NeighborView::hybrid(&hub, Some(&row));
        let vs = NeighborView::sorted(&small);
        assert_eq!(intersect_cost(vh, vs), 3);
        assert_eq!(intersect_cost(vs, vh), 3);
        // Merge would charge |a| + |b|.
        assert_eq!(
            intersect_cost(NeighborView::sorted(&hub), vs),
            crate::intersect::adaptive_cost(1000, 3)
        );
    }

    #[test]
    fn dense_pair_uses_word_and_and_charges_words() {
        let a: Vec<VertexId> = (0..640).collect();
        let b: Vec<VertexId> = (320..960).collect();
        let (ra, rb) = (BitmapRow::from_sorted(&a), BitmapRow::from_sorted(&b));
        let va = NeighborView::hybrid(&a, Some(&ra));
        let vb = NeighborView::hybrid(&b, Some(&rb));
        let mut c = 0u64;
        intersect_count(va, vb, &mut c);
        assert_eq!(c, 320);
        // Overlap span: words 5..10 → 5 words, far below the 1280 merge.
        assert_eq!(intersect_cost(va, vb), 5);
    }

    #[test]
    fn sparse_hub_pair_falls_back_to_probe() {
        // Two 4-element "hubs" smeared over 2^22 ids: word-AND would scan
        // thousands of words; the plan must probe instead.
        let a: Vec<VertexId> = vec![0, 1 << 20, 2 << 20, 3 << 20];
        let b: Vec<VertexId> = vec![1, 1 << 20, 5 << 20, 6 << 20];
        let (ra, rb) = (BitmapRow::from_sorted(&a), BitmapRow::from_sorted(&b));
        let va = NeighborView::hybrid(&a, Some(&ra));
        let vb = NeighborView::hybrid(&b, Some(&rb));
        let mut c = 0u64;
        intersect_count(va, vb, &mut c);
        assert_eq!(c, 1);
        assert_eq!(intersect_cost(va, vb), 4, "probe charges the shorter list");
    }

    #[test]
    fn empty_views() {
        let empty = NeighborView::sorted(&[]);
        let row = BitmapRow::from_sorted(&[]);
        let ve = NeighborView::hybrid(&[], Some(&row));
        let full: Vec<VertexId> = (0..100).collect();
        let vf = NeighborView::sorted(&full);
        for (x, y) in [(empty, vf), (vf, ve), (ve, ve)] {
            let mut c = 0u64;
            intersect_count(x, y, &mut c);
            assert_eq!(c, 0);
        }
    }
}
