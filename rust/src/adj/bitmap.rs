//! Packed bitmap rows for high-degree ("hub") adjacency lists.
//!
//! A [`BitmapRow`] stores a sorted neighbor list as one bit per node id,
//! trimmed to the span `[first/64, last/64]` of 64-bit words that actually
//! contain neighbors. Membership tests are O(1) and two rows intersect by
//! word-AND + popcount over the overlap of their spans — the dense-row
//! technique that Sanders & Uhl (2023) and Tom & Karypis (2019) identify as
//! the decisive single-node optimization in the large-degree regime this
//! paper targets. The sorted list is always kept alongside the bitmap (see
//! [`crate::adj::view`]), so the dispatch can pick whichever kernel is
//! cheaper per pair.

use crate::VertexId;

/// A trimmed, packed bitmap over node ids (see module docs).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct BitmapRow {
    /// Index of the first 64-bit word of the trimmed span.
    lo_word: usize,
    /// Packed bits for ids in `[lo_word·64, (lo_word + words.len())·64)`.
    words: Vec<u64>,
    /// Number of set bits (= neighbor count).
    ones: u32,
}

impl BitmapRow {
    /// Build from a strictly id-sorted neighbor list. O(d + span/64).
    pub fn from_sorted(list: &[VertexId]) -> Self {
        let (Some(&first), Some(&last)) = (list.first(), list.last()) else {
            return BitmapRow::default();
        };
        let lo_word = first as usize / 64;
        let hi_word = last as usize / 64;
        let mut words = vec![0u64; hi_word - lo_word + 1];
        for &x in list {
            words[x as usize / 64 - lo_word] |= 1u64 << (x % 64);
        }
        BitmapRow { lo_word, words, ones: list.len() as u32 }
    }

    /// O(1) membership test.
    #[inline]
    pub fn contains(&self, x: VertexId) -> bool {
        let w = x as usize / 64;
        w >= self.lo_word
            && w < self.lo_word + self.words.len()
            && (self.words[w - self.lo_word] >> (x % 64)) & 1 == 1
    }

    /// `|self ∩ other|` by word-AND + popcount over the span overlap.
    pub fn and_popcount(&self, other: &BitmapRow) -> u64 {
        let lo = self.lo_word.max(other.lo_word);
        let hi = (self.lo_word + self.words.len()).min(other.lo_word + other.words.len());
        let mut c = 0u64;
        for w in lo..hi {
            c += (self.words[w - self.lo_word] & other.words[w - other.lo_word]).count_ones() as u64;
        }
        c
    }

    /// Materialize `self ∩ other` into `out` in ascending id order, by
    /// word-AND + bit iteration over the span overlap.
    pub fn and_collect(&self, other: &BitmapRow, out: &mut Vec<VertexId>) {
        let lo = self.lo_word.max(other.lo_word);
        let hi = (self.lo_word + self.words.len()).min(other.lo_word + other.words.len());
        for w in lo..hi {
            let mut bits = self.words[w - self.lo_word] & other.words[w - other.lo_word];
            while bits != 0 {
                out.push((w as u64 * 64 + bits.trailing_zeros() as u64) as VertexId);
                bits &= bits - 1;
            }
        }
    }

    /// Words the AND kernel would touch for `self ∩ other` — the
    /// bitmap×bitmap term of the hybrid cost model, and the quantity the
    /// dispatch compares against the merge cost before choosing word-AND.
    #[inline]
    pub fn overlap_words(&self, other: &BitmapRow) -> usize {
        let lo = self.lo_word.max(other.lo_word);
        let hi = (self.lo_word + self.words.len()).min(other.lo_word + other.words.len());
        hi.saturating_sub(lo)
    }

    /// Set bits (the neighbor count the row encodes).
    #[inline]
    pub fn ones(&self) -> usize {
        self.ones as usize
    }

    /// Words in the trimmed span.
    #[inline]
    pub fn span_words(&self) -> usize {
        self.words.len()
    }

    /// Heap bytes held by the packed words.
    #[inline]
    pub fn bytes(&self) -> u64 {
        (self.words.len() * 8) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_row() {
        let r = BitmapRow::from_sorted(&[]);
        assert_eq!(r.ones(), 0);
        assert_eq!(r.span_words(), 0);
        assert_eq!(r.bytes(), 0);
        assert!(!r.contains(0));
        assert_eq!(r.and_popcount(&r), 0);
    }

    #[test]
    fn contains_matches_list() {
        let list = [3, 64, 65, 200, 1023];
        let r = BitmapRow::from_sorted(&list);
        assert_eq!(r.ones(), 5);
        for x in 0..1100u32 {
            assert_eq!(r.contains(x), list.contains(&x), "id {x}");
        }
    }

    #[test]
    fn span_is_trimmed() {
        // Ids 640..704 live in exactly one word despite the large universe.
        let list: Vec<VertexId> = (640..704).collect();
        let r = BitmapRow::from_sorted(&list);
        assert_eq!(r.span_words(), 1);
        assert_eq!(r.bytes(), 8);
    }

    #[test]
    fn and_popcount_matches_merge() {
        use crate::gen::rng::Rng;
        use crate::intersect::count_merge;
        let mut rng = Rng::seeded(7);
        for _ in 0..100 {
            let mk = |rng: &mut Rng, len: usize| {
                let mut v: Vec<VertexId> = (0..len).map(|_| rng.next_u32() % 5000).collect();
                v.sort_unstable();
                v.dedup();
                v
            };
            let a = mk(&mut rng, rng.below_usize(300));
            let b = mk(&mut rng, rng.below_usize(300));
            let (ra, rb) = (BitmapRow::from_sorted(&a), BitmapRow::from_sorted(&b));
            let mut expect = 0u64;
            count_merge(&a, &b, &mut expect);
            assert_eq!(ra.and_popcount(&rb), expect);
            assert_eq!(rb.and_popcount(&ra), expect);
        }
    }

    #[test]
    fn and_collect_matches_intersect_vec() {
        use crate::intersect::intersect_vec;
        let a: Vec<VertexId> = (0..500).step_by(3).collect();
        let b: Vec<VertexId> = (0..500).step_by(5).collect();
        let (ra, rb) = (BitmapRow::from_sorted(&a), BitmapRow::from_sorted(&b));
        let mut got = Vec::new();
        ra.and_collect(&rb, &mut got);
        assert_eq!(got, intersect_vec(&a, &b));
    }

    #[test]
    fn disjoint_spans_cost_nothing() {
        let a = BitmapRow::from_sorted(&[1, 2, 3]);
        let b = BitmapRow::from_sorted(&[1000, 1001]);
        assert_eq!(a.overlap_words(&b), 0);
        assert_eq!(a.and_popcount(&b), 0);
    }
}
