//! Supervised execution: run a counting job, survive rank death
//! (DESIGN.md §13).
//!
//! [`supervise`] wraps every counting path behind one fault policy:
//!
//! * **fail** — propagate the cluster error (the pre-`ft/` behavior).
//! * **recover** — identify the victims (the virtual fabric's
//!   [`TraceReport::dead_mask`], or [`Error::RankFailure`] on the channel
//!   fabric), salvage the [`CheckpointStore`]'s acked units, and
//!   re-execute only the un-acked remainder on the survivors. The result
//!   is the **exact** oracle count on every path.
//! * **degrade** — answer immediately from the checkpoints with a stated
//!   confidence bound `lower ≤ T ≤ upper` (DOULION-style cost-fraction
//!   scaling for the point estimate, `approx.rs`'s rescaling idea applied
//!   to coverage instead of sampling probability).
//!
//! Recovery strategy per path (what §IV/§V allow):
//!
//! | path         | salvage                  | remainder execution            |
//! |--------------|--------------------------|--------------------------------|
//! | surrogate    | none (entangled partials)| §IV re-extraction on survivors |
//! | direct       | acked own-range counts   | §V survivors steal the rest    |
//! | patric       | acked core-range counts  | §IV re-extraction of remainder |
//! | dynamic-lb   | acked task counts        | §V survivors steal the rest    |
//! | local-counts | acked task counts        | §V survivors steal the rest    |
//! | stream       | none (Δ watermarks only) | full re-stream on survivors    |
//! | tile2d       | acked tile counts        | sequential recount of missing tiles |
//!
//! Exactness holds because every salvageable unit carries **min-≺-vertex
//! attribution** (a triangle is counted at exactly one vertex range/task),
//! so `acked + recount(complement)` is the oracle count by construction.
//! Surrogate counting is entangled — a rank's total mixes triangles served
//! for other ranks — so its checkpoints are lower-bound partials only and
//! recovery is full re-execution on the shrunken cluster.

use std::sync::Arc;

use crate::adj::hub::HubThreshold;
use crate::algo::tasks::Task;
use crate::algo::{direct, dynamic_lb, local_counts, patric, surrogate, tile2d};
use crate::comm::metrics::ClusterMetrics;
use crate::comm::threads::Progress;
use crate::config::CostFn;
use crate::error::{Error, Result};
use crate::ft::checkpoint::{CheckpointStore, RankMap};
use crate::graph::csr::Csr;
use crate::graph::ordering::Oriented;
use crate::partition::balance::balanced_ranges;
use crate::partition::cost::{cost_vector, prefix_sums};
use crate::seq::node_iterator;
use crate::stream::batch::Batch;
use crate::stream::parallel::{self, StreamOptions};
use crate::testkit::sched::SimConfig;
use crate::testkit::sim::Fabric;
use crate::testkit::trace::{combine_hashes, TraceReport};
use crate::TriangleCount;

/// Recovery attempts before the supervisor gives up (each attempt runs on
/// a fabric whose kill plan is stripped, so >1 only happens when the
/// surviving schedule itself fails — drops on the recovery wire).
const MAX_ATTEMPTS: u32 = 3;

/// What `--on-fault` selects.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FaultPolicy {
    /// Propagate the error (pre-`ft/` behavior).
    #[default]
    Fail,
    /// Re-execute un-acked work on the survivors; exact count.
    Recover,
    /// Answer from checkpoints with a confidence bound; no re-execution.
    Degrade,
}

impl std::str::FromStr for FaultPolicy {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "fail" => Ok(FaultPolicy::Fail),
            "recover" => Ok(FaultPolicy::Recover),
            "degrade" => Ok(FaultPolicy::Degrade),
            other => Err(Error::Config(format!(
                "unknown fault policy `{other}` (expected fail|recover|degrade)"
            ))),
        }
    }
}

impl std::fmt::Display for FaultPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            FaultPolicy::Fail => "fail",
            FaultPolicy::Recover => "recover",
            FaultPolicy::Degrade => "degrade",
        })
    }
}

/// A counting job the supervisor knows how to run, kill, and finish.
/// Partition ranges are *not* part of the job — the supervisor re-derives
/// them from the cost function for whatever cluster size recovery ends up
/// with.
pub enum Job<'a> {
    Surrogate { graph: &'a Arc<Oriented>, cost: CostFn, hub: HubThreshold },
    Direct { graph: &'a Arc<Oriented>, cost: CostFn, hub: HubThreshold },
    Patric { g: &'a Csr, graph: &'a Arc<Oriented>, cost: CostFn, hub: HubThreshold },
    DynamicLb { graph: &'a Arc<Oriented>, opts: dynamic_lb::Options },
    LocalCounts { graph: &'a Arc<Oriented> },
    Stream { base: &'a Csr, batches: &'a [Batch], opts: StreamOptions, initial: TriangleCount },
    Tile2d { graph: &'a Arc<Oriented>, hub: HubThreshold },
}

/// The degraded answer's confidence bound: `lower ≤ T ≤ upper` holds
/// unconditionally (lower = checkpointed floor, upper = checkpointed exact
/// + Σ C(d̂_v, 2) over un-acked vertices — no un-acked vertex can anchor
/// more min-vertex triangles than its oriented out-degree pairs allow).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Bound {
    pub lower: u64,
    pub estimate: u64,
    pub upper: u64,
}

impl Bound {
    pub fn contains(&self, truth: u64) -> bool {
        self.lower <= truth && truth <= self.upper
    }
}

/// What the supervisor did about a fault (all-zero on a fault-free run).
#[derive(Clone, Debug, Default)]
pub struct RecoveryReport {
    /// Recovery clusters launched (0 = no fault).
    pub attempts: u32,
    /// Original rank ids of every victim, across all attempts.
    pub dead_ranks: Vec<usize>,
    /// Survivor map of the final recovery cluster.
    pub survivors: Option<RankMap>,
    /// Work units spent re-executing (the cost of surviving the fault).
    pub reexec_work_units: u64,
    /// Payload bytes re-sent during recovery.
    pub reexec_bytes: u64,
    /// Checkpoint units salvaged exactly (not re-counted).
    pub salvaged_units: usize,
    /// Units that had only partial sums when the fault hit.
    pub partial_units: usize,
    /// True iff the answer is a degraded estimate, not an exact count.
    pub degraded: bool,
}

/// Result of a supervised run.
#[derive(Debug)]
pub struct SupervisedRun {
    pub count: TriangleCount,
    /// `Some` iff the run degraded.
    pub bound: Option<Bound>,
    /// Per-rank metrics: the run's own on success, the recovery cluster's
    /// (with `reexec_*` attribution) after a recovery.
    pub metrics: ClusterMetrics,
    pub recovery: RecoveryReport,
    /// Combined trace fingerprint over primary + recovery runs (`Some`
    /// iff the fabric is virtual) — the replay-determinism gate's key.
    pub trace_hash: Option<u64>,
}

/// Run `job` on `p` ranks under `policy`. See the module docs for the
/// per-path recovery strategy.
pub fn supervise(
    job: &Job<'_>,
    fabric: &Fabric,
    p: usize,
    policy: FaultPolicy,
) -> Result<SupervisedRun> {
    let store = Arc::new(CheckpointStore::new());
    let sink: Arc<dyn Progress> = store.clone();
    let (res, trace) = run_primary(job, fabric, p, Some(sink));
    let mut hashes: Vec<u64> = trace.iter().map(|t| t.hash).collect();
    match res {
        Ok((count, metrics)) => Ok(SupervisedRun {
            count,
            bound: None,
            metrics,
            recovery: RecoveryReport::default(),
            trace_hash: hash_of(&hashes),
        }),
        Err(e) => match policy {
            FaultPolicy::Fail => Err(e),
            FaultPolicy::Degrade => degrade(job, p, &store, &trace, &e, hashes),
            FaultPolicy::Recover => recover(job, fabric, p, &store, &trace, e, hashes),
        },
    }
}

fn hash_of(hashes: &[u64]) -> Option<u64> {
    (!hashes.is_empty()).then(|| combine_hashes(hashes.iter().copied()))
}

/// Victims of a failed run: the trace's dead mask where there is one, the
/// attributed [`Error::RankFailure`] rank otherwise. Empty = unattributable.
fn victims_of(trace: &Option<TraceReport>, err: &Error) -> Vec<usize> {
    if let Some(t) = trace {
        let dead = t.dead_ranks();
        if !dead.is_empty() {
            return dead;
        }
    }
    if let Error::RankFailure { rank, .. } = err {
        return vec![*rank];
    }
    Vec::new()
}

/// The fabric a recovery attempt runs on: same transport family, kills
/// stripped (a victim cannot die twice), seed re-derived so replaying the
/// whole supervised run is still one deterministic schedule.
fn recovery_fabric(fabric: &Fabric, attempt: u32) -> Fabric {
    match fabric {
        Fabric::Channel => Fabric::Channel,
        Fabric::Sim(cfg) => Fabric::Sim(SimConfig {
            seed: combine_hashes([cfg.seed, attempt as u64]),
            policy: cfg.policy.clone(),
            faults: cfg.faults.without_kills(),
        }),
    }
}

/// Balanced consecutive ranges for a `p`-rank cluster (what every §IV
/// driver's caller computes; the supervisor re-derives it per cluster size).
fn ranges_for(graph: &Oriented, cost: CostFn, p: usize) -> Vec<std::ops::Range<u32>> {
    balanced_ranges(&prefix_sums(&cost_vector(graph, cost)), p)
}

fn run_primary(
    job: &Job<'_>,
    fabric: &Fabric,
    p: usize,
    progress: Option<Arc<dyn Progress>>,
) -> (Result<(TriangleCount, ClusterMetrics)>, Option<TraceReport>) {
    match job {
        Job::Surrogate { graph, cost, hub } => {
            let ranges = ranges_for(graph, *cost, p);
            let (r, t) = surrogate::run_hooked_on(fabric, graph, &ranges, *hub, progress);
            (r.map(|r| (r.triangles, r.metrics)), t)
        }
        Job::Direct { graph, cost, hub } => {
            let ranges = ranges_for(graph, *cost, p);
            let (r, t) = direct::run_hooked_on(fabric, graph, &ranges, *hub, progress);
            (r.map(|r| (r.triangles, r.metrics)), t)
        }
        Job::Patric { g, graph, cost, hub } => {
            let ranges = ranges_for(graph, *cost, p);
            let (r, t) = patric::run_hooked_on(fabric, g, graph, &ranges, *hub, progress);
            (r.map(|r| (r.triangles, r.metrics)), t)
        }
        Job::DynamicLb { graph, opts } => {
            let (r, t) = dynamic_lb::run_hooked_on(fabric, graph, p, *opts, progress);
            (r.map(|r| (r.triangles, r.metrics)), t)
        }
        Job::LocalCounts { graph } => {
            let (r, t) = local_counts::per_node_counts_hooked_on(fabric, graph, p, progress);
            (r.map(|(tv, m)| (tv.iter().sum::<u64>() / 3, m)), t)
        }
        Job::Stream { base, batches, opts, initial } => {
            let (r, t) = parallel::run_with_initial_hooked_on(
                fabric, base, batches, p, *opts, *initial, progress,
            );
            (r.map(|r| (r.final_triangles, r.metrics)), t)
        }
        Job::Tile2d { graph, hub } => {
            let (r, t) = tile2d::run_hooked_on(fabric, graph, p, *hub, progress);
            (r.map(|r| (r.triangles, r.metrics)), t)
        }
    }
}

fn recover(
    job: &Job<'_>,
    fabric: &Fabric,
    p: usize,
    store: &Arc<CheckpointStore>,
    trace: &Option<TraceReport>,
    first_err: Error,
    mut hashes: Vec<u64>,
) -> Result<SupervisedRun> {
    let mut victims = victims_of(trace, &first_err);
    if victims.is_empty() {
        return Err(first_err); // unattributable — nothing to recover from
    }
    // Snapshot the salvage *before* recovery runs publish into the store.
    let (salvaged_units, partial_units) = store.unit_counts();

    for attempt in 1..=MAX_ATTEMPTS {
        let map = RankMap::surviving(p, &victims);
        if map.is_empty() {
            return Err(Error::Cluster(format!(
                "recovery impossible: all {p} ranks died ({victims:?})"
            )));
        }
        let rf = recovery_fabric(fabric, attempt);
        let (res, rtrace) = run_recovery(job, &rf, p, &map, store);
        if let Some(t) = &rtrace {
            hashes.push(t.hash);
        }
        match res {
            Ok((count, mut metrics)) => {
                let mut reexec_work = 0u64;
                let mut reexec_bytes = 0u64;
                for m in &mut metrics.per_rank {
                    m.reexec_work_units = m.work_units;
                    m.reexec_bytes = m.bytes_sent;
                    reexec_work += m.work_units;
                    reexec_bytes += m.bytes_sent;
                }
                return Ok(SupervisedRun {
                    count,
                    bound: None,
                    metrics,
                    recovery: RecoveryReport {
                        attempts: attempt,
                        dead_ranks: victims,
                        survivors: Some(map),
                        reexec_work_units: reexec_work,
                        reexec_bytes,
                        salvaged_units,
                        partial_units,
                        degraded: false,
                    },
                    trace_hash: hash_of(&hashes),
                });
            }
            Err(e) => {
                // A recovery cluster failed too. Its victims are in *new*
                // rank ids — map them back before shrinking further.
                let more = victims_of(&rtrace, &e);
                if more.is_empty() || attempt == MAX_ATTEMPTS {
                    return Err(Error::Cluster(format!(
                        "recovery attempt {attempt} failed: {e}"
                    )));
                }
                for new in more {
                    let old = map.old_of(new);
                    if !victims.contains(&old) {
                        victims.push(old);
                    }
                }
                victims.sort_unstable();
            }
        }
    }
    unreachable!("the attempt loop always returns")
}

/// One recovery attempt on the survivor cluster. Exact by the min-≺-vertex
/// attribution argument in the module docs.
fn run_recovery(
    job: &Job<'_>,
    fabric: &Fabric,
    p: usize,
    map: &RankMap,
    store: &Arc<CheckpointStore>,
) -> (Result<(TriangleCount, ClusterMetrics)>, Option<TraceReport>) {
    let p2 = map.len();
    match job {
        // Entangled partials: full §IV re-extraction on the survivors.
        Job::Surrogate { graph, cost, hub } => {
            let ranges = ranges_for(graph, *cost, p2);
            let (r, t) = surrogate::run_hooked_on(fabric, graph, &ranges, *hub, None);
            (r.map(|r| (r.triangles, r.metrics)), t)
        }
        // Replicated state, Δ-watermarks only: full re-stream.
        Job::Stream { base, batches, opts, initial } => {
            let (r, t) = parallel::run_with_initial_hooked_on(
                fabric, base, batches, p2, *opts, *initial, None,
            );
            (r.map(|r| (r.final_triangles, r.metrics)), t)
        }
        // Acked core ranges are exact: §IV re-extraction over the
        // complement intervals only (each interval becomes a core range;
        // the recovery cluster is one rank per interval — at most the
        // original victim count plus boundary splits).
        Job::Patric { g, graph, cost: _, hub } => {
            let salvage = store.acked_sum();
            let rem = store.complement(graph.num_nodes() as u32);
            if rem.is_empty() {
                return (Ok((salvage, ClusterMetrics::default())), None);
            }
            let ranges: Vec<std::ops::Range<u32>> =
                rem.iter().map(|&(lo, hi)| lo..hi).collect();
            let (r, t) = patric::run_hooked_on(fabric, g, graph, &ranges, *hub, None);
            (r.map(|r| (salvage + r.triangles, r.metrics)), t)
        }
        // Tile partials are globally disjoint (each tile owns a distinct
        // set of oriented mask edges), so acked tiles are exact salvage.
        // The missing tiles are recounted sequentially against the
        // *original* p-rank layout — no fresh cluster is needed because a
        // tile recount touches only replicated read-only graph state.
        Job::Tile2d { graph, hub: _ } => {
            // Re-derive the driver's exact (shuffled graph, layout) pair
            // — the fixed-seed shuffle makes them identical.
            let sh = crate::partition::tile2d::shuffled(graph);
            let layout = crate::partition::tile2d::layout(&sh, p);
            let acked: std::collections::BTreeSet<u32> =
                store.acked_batches().iter().map(|&(i, _)| i).collect();
            let mut total = store.acked_sum();
            let mut work = 0u64;
            for rank in 0..layout.grid.active() {
                if acked.contains(&(rank as u32)) {
                    continue;
                }
                let (t, w) = tile2d::count_tile_seq(&sh, &layout, rank);
                total += t;
                work += w;
            }
            let mut metrics = ClusterMetrics::default();
            metrics.per_rank.push(crate::comm::metrics::CommMetrics {
                work_units: work,
                ..Default::default()
            });
            (Ok((total, metrics)), None)
        }
        // §V survivors-steal: the un-acked vertex intervals become the
        // dynamic task queue of a fresh coordinator/worker cluster (or a
        // sequential sweep when only one survivor remains).
        Job::Direct { graph, .. } | Job::DynamicLb { graph, .. } | Job::LocalCounts { graph } => {
            let salvage = store.acked_sum();
            let rem = store.complement(graph.num_nodes() as u32);
            if rem.is_empty() {
                return (Ok((salvage, ClusterMetrics::default())), None);
            }
            if p2 >= 2 {
                let tasks = remainder_tasks(&rem, p2 - 1);
                let (r, t) =
                    dynamic_lb::run_tasks_on(fabric, graph, p2, &tasks, Some(store.clone()));
                (r.map(|r| (salvage + r.triangles, r.metrics)), t)
            } else {
                // Lone survivor: count the remainder sequentially.
                let mut t: TriangleCount = 0;
                let mut work = 0u64;
                for &(lo, hi) in &rem {
                    node_iterator::count_range(graph, lo, hi, &mut t);
                    for v in lo..hi {
                        work += node_iterator::node_work_true(graph, v);
                    }
                }
                let mut metrics = ClusterMetrics::default();
                metrics.per_rank.push(crate::comm::metrics::CommMetrics {
                    work_units: work,
                    ..Default::default()
                });
                (Ok((salvage + t, metrics)), None)
            }
        }
    }
}

/// Split the complement intervals into a §V-style task list: roughly two
/// tasks per worker per interval, so the steal queue still load-balances.
fn remainder_tasks(rem: &[(u32, u32)], workers: usize) -> Vec<Task> {
    let mut tasks = Vec::new();
    for &(lo, hi) in rem {
        let len = hi - lo;
        let chunk = (len / (2 * workers.max(1)) as u32).max(1);
        let mut at = lo;
        while at < hi {
            let l = chunk.min(hi - at);
            tasks.push(Task { start: at, len: l });
            at += l;
        }
    }
    tasks
}

fn degrade(
    job: &Job<'_>,
    p: usize,
    store: &Arc<CheckpointStore>,
    trace: &Option<TraceReport>,
    err: &Error,
    hashes: Vec<u64>,
) -> Result<SupervisedRun> {
    let bound = match job {
        Job::Stream { base, batches, initial, .. } => {
            stream_bound(base, batches, *initial, store)
        }
        Job::Surrogate { graph, cost, .. } | Job::Direct { graph, cost, .. } => {
            static_bound(graph, *cost, store)
        }
        Job::Patric { graph, cost, .. } => static_bound(graph, *cost, store),
        Job::DynamicLb { graph, opts } => static_bound(graph, opts.cost_fn, store),
        Job::LocalCounts { graph } => static_bound(graph, CostFn::Degree, store),
        Job::Tile2d { graph, .. } => tile_bound(graph, p, store),
    };
    let (salvaged_units, partial_units) = store.unit_counts();
    Ok(SupervisedRun {
        count: bound.estimate,
        bound: Some(bound),
        metrics: ClusterMetrics::default(),
        recovery: RecoveryReport {
            attempts: 0,
            dead_ranks: victims_of(trace, err),
            survivors: None,
            reexec_work_units: 0,
            reexec_bytes: 0,
            salvaged_units,
            partial_units,
            degraded: true,
        },
        trace_hash: hash_of(&hashes),
    })
}

/// Bound for the static (non-stream) paths.
///
/// * `lower` — the checkpointed floor (acked exacts + disjoint partials).
/// * `upper` — acked exacts + Σ C(d̂_v, 2) over un-acked vertices: a vertex
///   with `d̂` oriented out-neighbors anchors at most `d̂(d̂−1)/2`
///   min-vertex triangles.
/// * `estimate` — the floor rescaled by the inverse covered-cost fraction
///   (DOULION's `1/p³` trick with coverage in place of sampling
///   probability), clamped into `[lower, upper]`; midpoint when nothing
///   was acked (no coverage signal at all).
fn static_bound(graph: &Oriented, cost: CostFn, store: &CheckpointStore) -> Bound {
    let n = graph.num_nodes() as u32;
    let lower = store.floor_sum();
    let mut upper = store.acked_sum();
    for &(lo, hi) in &store.complement(n) {
        for v in lo..hi {
            let d = graph.nbrs(v).len() as u64;
            upper += d * d.saturating_sub(1) / 2;
        }
    }
    let upper = upper.max(lower);

    let prefix = prefix_sums(&cost_vector(graph, cost));
    let total = *prefix.last().unwrap_or(&0);
    let covered: u64 =
        store.acked_ranges().iter().map(|&(lo, hi)| prefix[hi as usize] - prefix[lo as usize]).sum();
    let estimate = if covered > 0 && total > 0 {
        let scaled = (lower as f64 * total as f64 / covered as f64).round() as u64;
        scaled.clamp(lower, upper)
    } else {
        lower + (upper - lower) / 2
    };
    Bound { lower, estimate, upper }
}

/// Bound for the 2D-tiled path. Tiles partition the oriented mask-edge
/// set, so:
///
/// * `lower` — the checkpointed floor (acked tile exacts + monotone
///   partials of in-flight tiles, all globally disjoint).
/// * `upper` — acked exacts + Σ [`tile2d::tile_upper_bound`] over
///   un-acked tiles (no mask edge (v, u) of a tile can close more
///   wedges than v's oriented out-degree).
/// * `estimate` — the floor rescaled by the inverse acked-tile fraction
///   (the same coverage trick as [`static_bound`], with tiles as the
///   coverage unit), clamped into `[lower, upper]`.
fn tile_bound(graph: &Oriented, p: usize, store: &CheckpointStore) -> Bound {
    let sh = crate::partition::tile2d::shuffled(graph);
    let layout = crate::partition::tile2d::layout(&sh, p);
    let acked: std::collections::BTreeSet<u32> =
        store.acked_batches().iter().map(|&(i, _)| i).collect();
    let lower = store.floor_sum();
    let mut upper = store.acked_sum();
    let active = layout.grid.active();
    for rank in 0..active {
        if !acked.contains(&(rank as u32)) {
            upper += tile2d::tile_upper_bound(&sh, &layout, rank);
        }
    }
    let upper = upper.max(lower);
    let estimate = if !acked.is_empty() && active > 0 {
        let scaled = (lower as f64 * active as f64 / acked.len() as f64).round() as u64;
        scaled.clamp(lower, upper)
    } else {
        lower + (upper - lower) / 2
    };
    Bound { lower, estimate, upper }
}

/// Bound for the stream path. `known` = initial count + Σ acked batch Δs
/// (the watermark). Each un-acked update `{u,v}` can change the count by
/// at most `min(d_u, d_v)` common neighbors in the *current* graph, which
/// is bounded by the base degree plus every update in the stream (an
/// update raises any one degree by at most 1) — summed into `slack`.
fn stream_bound(base: &Csr, batches: &[Batch], initial: TriangleCount, store: &CheckpointStore) -> Bound {
    let acked = store.acked_batches();
    let known: i64 = initial as i64 + acked.iter().map(|&(_, d)| d).sum::<i64>();
    let acked_idx: std::collections::BTreeSet<u32> = acked.iter().map(|&(i, _)| i).collect();
    let total_ops: u64 = batches.iter().map(|b| b.updates.len() as u64).sum();
    let mut slack: i64 = 0;
    for (bi, b) in batches.iter().enumerate() {
        if acked_idx.contains(&(bi as u32)) {
            continue;
        }
        for up in &b.updates {
            let d = base.degree(up.u).min(base.degree(up.v)) as u64 + total_ops;
            slack += d as i64;
        }
    }
    let lower = (known - slack).max(0) as u64;
    let upper = (known + slack).max(0) as u64;
    let estimate = (known.max(0) as u64).clamp(lower, upper);
    Bound { lower, estimate, upper }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::classic;
    use crate::testkit::sched::FaultPlan;

    fn sim_kill(seed: u64, rank: usize, at_op: u64) -> Fabric {
        Fabric::Sim(SimConfig::with_faults(seed, FaultPlan::kill_one(rank, at_op)))
    }

    fn karate() -> Arc<Oriented> {
        Arc::new(Oriented::from_graph(&classic::karate()))
    }

    #[test]
    fn fail_policy_propagates_the_error() {
        let o = karate();
        let job = Job::DynamicLb { graph: &o, opts: dynamic_lb::Options::default() };
        let r = supervise(&job, &sim_kill(3, 1, 2), 4, FaultPolicy::Fail);
        assert!(r.is_err());
    }

    #[test]
    fn fault_free_supervised_run_is_plain() {
        let o = karate();
        let job = Job::Surrogate { graph: &o, cost: CostFn::SurrogateNew, hub: HubThreshold::Auto };
        let r = supervise(&job, &Fabric::Sim(SimConfig::adversarial(7)), 4, FaultPolicy::Recover)
            .unwrap();
        assert_eq!(r.count, 45);
        assert_eq!(r.recovery.attempts, 0);
        assert!(r.bound.is_none());
        assert!(r.trace_hash.is_some());
    }

    #[test]
    fn dynamic_lb_recovers_from_dead_coordinator() {
        // Regression for the contiguous-rank-id assumption: rank 0 (the
        // coordinator) is the victim, so the survivor set {1,2,3} must be
        // re-mapped, not assumed to start at 0.
        let o = karate();
        let job = Job::DynamicLb { graph: &o, opts: dynamic_lb::Options::default() };
        let r = supervise(&job, &sim_kill(11, 0, 1), 4, FaultPolicy::Recover).unwrap();
        assert_eq!(r.count, 45);
        assert_eq!(r.recovery.dead_ranks, vec![0]);
        let map = r.recovery.survivors.as_ref().unwrap();
        assert_eq!(map.survivors, vec![1, 2, 3]);
        assert_eq!(map.new_of(0), None);
    }

    #[test]
    fn each_path_recovers_exact_after_kill() {
        // Kill at the victim's *first* transport op — the only position
        // guaranteed to exist on every path (PATRIC's only transport op is
        // the reduce). The first/middle/last matrix with probe-derived
        // positions lives in the conformance suite.
        let g = classic::karate();
        let o = karate();
        let jobs: Vec<(&str, Job<'_>)> = vec![
            ("surrogate", Job::Surrogate { graph: &o, cost: CostFn::SurrogateNew, hub: HubThreshold::Auto }),
            ("direct", Job::Direct { graph: &o, cost: CostFn::SurrogateNew, hub: HubThreshold::Auto }),
            ("patric", Job::Patric { g: &g, graph: &o, cost: CostFn::PatricBest, hub: HubThreshold::Auto }),
            ("dynamic-lb", Job::DynamicLb { graph: &o, opts: dynamic_lb::Options::default() }),
            ("local-counts", Job::LocalCounts { graph: &o }),
            ("tile2d", Job::Tile2d { graph: &o, hub: HubThreshold::Auto }),
        ];
        for (name, job) in &jobs {
            let r = supervise(job, &sim_kill(23, 1, 1), 4, FaultPolicy::Recover)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(r.count, 45, "{name}");
            assert_eq!(r.recovery.attempts, 1, "{name}");
            assert!(!r.recovery.degraded, "{name}");
        }
    }

    #[test]
    fn stream_recovers_exact() {
        use crate::stream::batch::EdgeUpdate;
        let base = classic::karate();
        let batches = vec![
            Batch::new(vec![EdgeUpdate::insert(0, 9), EdgeUpdate::delete(0, 1)]),
            Batch::new(vec![EdgeUpdate::insert(14, 15)]),
        ];
        let initial = node_iterator::count(&Oriented::from_graph(&base));
        let oracle = {
            let mut st = crate::stream::state::StreamState::new(base.clone());
            for b in &batches {
                st.apply_batch(b).unwrap();
            }
            st.triangles()
        };
        let job = Job::Stream {
            base: &base,
            batches: &batches,
            opts: StreamOptions::default(),
            initial,
        };
        let r = supervise(&job, &sim_kill(31, 2, 2), 4, FaultPolicy::Recover).unwrap();
        assert_eq!(r.count, oracle);
        assert_eq!(r.recovery.attempts, 1);
    }

    #[test]
    fn recovery_replays_to_identical_hash_and_count() {
        let o = karate();
        let job = Job::Direct { graph: &o, cost: CostFn::SurrogateNew, hub: HubThreshold::Auto };
        let a = supervise(&job, &sim_kill(5, 2, 4), 4, FaultPolicy::Recover).unwrap();
        let b = supervise(&job, &sim_kill(5, 2, 4), 4, FaultPolicy::Recover).unwrap();
        assert_eq!(a.count, b.count);
        assert_eq!(a.trace_hash, b.trace_hash);
        assert!(a.trace_hash.is_some());
    }

    #[test]
    fn degrade_bound_contains_truth_on_every_static_path() {
        let g = classic::karate();
        let o = karate();
        let jobs: Vec<(&str, Job<'_>)> = vec![
            ("surrogate", Job::Surrogate { graph: &o, cost: CostFn::SurrogateNew, hub: HubThreshold::Auto }),
            ("direct", Job::Direct { graph: &o, cost: CostFn::SurrogateNew, hub: HubThreshold::Auto }),
            ("patric", Job::Patric { g: &g, graph: &o, cost: CostFn::PatricBest, hub: HubThreshold::Auto }),
            ("dynamic-lb", Job::DynamicLb { graph: &o, opts: dynamic_lb::Options::default() }),
            ("local-counts", Job::LocalCounts { graph: &o }),
            ("tile2d", Job::Tile2d { graph: &o, hub: HubThreshold::Auto }),
        ];
        for (name, job) in &jobs {
            let r = supervise(job, &sim_kill(41, 1, 1), 4, FaultPolicy::Degrade)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            let bound = r.bound.unwrap_or_else(|| panic!("{name}: no bound"));
            assert!(bound.contains(45), "{name}: {bound:?} must contain 45");
            assert!(r.recovery.degraded, "{name}");
            assert_eq!(r.count, bound.estimate, "{name}");
        }
    }

    #[test]
    fn degrade_bound_contains_truth_on_stream() {
        use crate::stream::batch::EdgeUpdate;
        let base = classic::karate();
        let batches =
            vec![Batch::new(vec![EdgeUpdate::insert(0, 9)]), Batch::new(vec![EdgeUpdate::delete(0, 1)])];
        let initial = node_iterator::count(&Oriented::from_graph(&base));
        let oracle = {
            let mut st = crate::stream::state::StreamState::new(base.clone());
            for b in &batches {
                st.apply_batch(b).unwrap();
            }
            st.triangles()
        };
        let job = Job::Stream {
            base: &base,
            batches: &batches,
            opts: StreamOptions::default(),
            initial,
        };
        let r = supervise(&job, &sim_kill(43, 1, 2), 4, FaultPolicy::Degrade).unwrap();
        assert!(r.bound.unwrap().contains(oracle), "{:?} vs {oracle}", r.bound);
    }

    #[test]
    fn recovery_reports_reexecuted_work() {
        let o = karate();
        let job = Job::Surrogate { graph: &o, cost: CostFn::SurrogateNew, hub: HubThreshold::Auto };
        let r = supervise(&job, &sim_kill(47, 1, 3), 4, FaultPolicy::Recover).unwrap();
        assert_eq!(r.count, 45);
        // Surrogate recovery is full re-execution: re-executed work must be
        // visible, and attributed in the per-rank metrics too.
        assert!(r.recovery.reexec_work_units > 0);
        assert_eq!(
            r.recovery.reexec_work_units,
            r.metrics.per_rank.iter().map(|m| m.reexec_work_units).sum::<u64>()
        );
    }

    #[test]
    fn remainder_tasks_tile_the_complement() {
        let tasks = remainder_tasks(&[(3, 10), (20, 21)], 3);
        let mut covered = Vec::new();
        for t in &tasks {
            covered.extend(t.range());
        }
        let expect: Vec<u32> = (3..10).chain(20..21).collect();
        assert_eq!(covered, expect);
    }
}
