//! Checkpointed progress for supervised runs (DESIGN.md §13).
//!
//! Every counting path publishes progress through the
//! [`Progress`] sink installed by `ft::supervisor`:
//!
//! * **acks** — a [`ProgressUnit`] (vertex range, §V task, or stream
//!   batch) fully resolved with its exact sum. Acked units never need
//!   re-counting: recovery's remainder is their complement.
//! * **partials** — monotone, globally disjoint contributions keyed by
//!   the publishing rank (surrogate/direct sweep totals). Partials of a
//!   rank that later dies were published *before* the death and survive
//!   it — they are the floor of the degraded confidence bound.
//!
//! The store is shared memory on this runtime (one process per cluster);
//! on a real MPI deployment it would be a replicated log, which is why
//! the interface is append/overwrite-only and queries are pull-style.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::comm::threads::{Progress, ProgressUnit};

#[derive(Clone, Debug, Default)]
struct UnitState {
    /// Exact final sum, set at most once per unit (re-acks overwrite with
    /// the same value — publication is idempotent).
    acked: Option<u64>,
    /// Per-rank monotone partials for a unit not yet acked.
    partials: BTreeMap<usize, u64>,
}

#[derive(Debug, Default)]
struct Inner {
    units: BTreeMap<ProgressUnit, UnitState>,
    /// Acks per publishing rank — the per-rank task watermark.
    acks_by_rank: BTreeMap<usize, u64>,
}

/// The shared checkpoint board of one supervised run.
#[derive(Debug, Default)]
pub struct CheckpointStore {
    inner: Mutex<Inner>,
}

impl Progress for CheckpointStore {
    fn partial(&self, rank: usize, unit: ProgressUnit, sum: u64) {
        let mut g = self.inner.lock().unwrap();
        g.units.entry(unit).or_default().partials.insert(rank, sum);
    }

    fn ack(&self, rank: usize, unit: ProgressUnit, sum: u64) {
        let mut g = self.inner.lock().unwrap();
        g.units.entry(unit).or_default().acked = Some(sum);
        *g.acks_by_rank.entry(rank).or_insert(0) += 1;
    }
}

impl CheckpointStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Σ of exact sums over acked units — salvaged work that recovery
    /// must not re-count.
    pub fn acked_sum(&self) -> u64 {
        let g = self.inner.lock().unwrap();
        g.units.values().filter_map(|u| u.acked).sum()
    }

    /// The guaranteed floor: per unit, the exact sum if acked, otherwise
    /// the sum of its per-rank partials (each a disjoint undercount).
    pub fn floor_sum(&self) -> u64 {
        let g = self.inner.lock().unwrap();
        g.units
            .values()
            .map(|u| u.acked.unwrap_or_else(|| u.partials.values().sum()))
            .sum()
    }

    /// Acked *vertex* coverage (range + task kinds; batch units are a
    /// separate axis): sorted, merged `[lo, hi)` intervals.
    pub fn acked_ranges(&self) -> Vec<(u32, u32)> {
        let g = self.inner.lock().unwrap();
        let mut spans: Vec<(u32, u32)> = g
            .units
            .iter()
            .filter(|(u, s)| u.kind <= 1 && s.acked.is_some() && u.hi > u.lo)
            .map(|(u, _)| (u.lo, u.hi))
            .collect();
        spans.sort_unstable();
        let mut merged: Vec<(u32, u32)> = Vec::with_capacity(spans.len());
        for (lo, hi) in spans {
            match merged.last_mut() {
                Some(last) if lo <= last.1 => last.1 = last.1.max(hi),
                _ => merged.push((lo, hi)),
            }
        }
        merged
    }

    /// The un-acked remainder of `[0, n)` — what recovery re-counts.
    pub fn complement(&self, n: u32) -> Vec<(u32, u32)> {
        let mut out = Vec::new();
        let mut at = 0u32;
        for (lo, hi) in self.acked_ranges() {
            if lo > at {
                out.push((at, lo.min(n)));
            }
            at = at.max(hi);
            if at >= n {
                break;
            }
        }
        if at < n {
            out.push((at, n));
        }
        out
    }

    /// Acked stream batches as `(index, signed Δ)` in batch order. The Δ
    /// was bit-cast to `u64` at the ack site; decode it here.
    pub fn acked_batches(&self) -> Vec<(u32, i64)> {
        let g = self.inner.lock().unwrap();
        g.units
            .iter()
            .filter(|(u, s)| u.kind == 2 && s.acked.is_some())
            .map(|(u, s)| (u.lo, s.acked.unwrap() as i64))
            .collect()
    }

    /// `(acked units, partial-only units)` — the recovery report's view
    /// of how much checkpointed state the fault left behind.
    pub fn unit_counts(&self) -> (usize, usize) {
        let g = self.inner.lock().unwrap();
        let acked = g.units.values().filter(|u| u.acked.is_some()).count();
        (acked, g.units.len() - acked)
    }

    /// Per-rank ack watermarks (how many units each rank resolved).
    pub fn watermarks(&self) -> BTreeMap<usize, u64> {
        self.inner.lock().unwrap().acks_by_rank.clone()
    }
}

/// Explicit survivor map for recovery clusters. Recovery launches a fresh
/// contiguous cluster of `survivors.len()` ranks; this map records which
/// *original* rank each new rank stands in for, so nothing downstream
/// assumes the survivor set is `0..p'` of the original ids — recovery
/// works identically when rank 0 (the §V coordinator) is the victim.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RankMap {
    /// Original rank ids of the survivors, ascending; index = new rank id.
    pub survivors: Vec<usize>,
}

impl RankMap {
    /// Survivors of a `p`-rank cluster after `dead` died.
    pub fn surviving(p: usize, dead: &[usize]) -> Self {
        RankMap { survivors: (0..p).filter(|r| !dead.contains(r)).collect() }
    }

    pub fn len(&self) -> usize {
        self.survivors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.survivors.is_empty()
    }

    /// The original rank a recovery rank stands in for.
    pub fn old_of(&self, new_rank: usize) -> usize {
        self.survivors[new_rank]
    }

    /// The recovery rank of an original rank (`None` if it died).
    pub fn new_of(&self, old_rank: usize) -> Option<usize> {
        self.survivors.binary_search(&old_rank).ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acks_and_partials_roll_up() {
        let s = CheckpointStore::new();
        s.ack(1, ProgressUnit::range(0, 10), 100);
        s.ack(2, ProgressUnit::task(10, 5), 7);
        s.partial(3, ProgressUnit::range(15, 20), 3);
        s.partial(4, ProgressUnit::range(15, 20), 9);
        assert_eq!(s.acked_sum(), 107);
        assert_eq!(s.floor_sum(), 107 + 3 + 9);
        assert_eq!(s.acked_ranges(), vec![(0, 15)]);
        assert_eq!(s.complement(30), vec![(15, 30)]);
        assert_eq!(s.unit_counts(), (2, 1));
        assert_eq!(s.watermarks().get(&1), Some(&1));
    }

    #[test]
    fn partial_is_overwrite_not_accumulate() {
        let s = CheckpointStore::new();
        s.partial(0, ProgressUnit::range(0, 4), 5);
        s.partial(0, ProgressUnit::range(0, 4), 8); // monotone refresh
        assert_eq!(s.floor_sum(), 8);
        // An ack supersedes the partials for the same unit.
        s.ack(0, ProgressUnit::range(0, 4), 11);
        assert_eq!(s.floor_sum(), 11);
    }

    #[test]
    fn complement_of_empty_store_is_everything() {
        let s = CheckpointStore::new();
        assert_eq!(s.complement(42), vec![(0, 42)]);
        assert_eq!(s.acked_sum(), 0);
    }

    #[test]
    fn complement_merges_adjacent_acks() {
        let s = CheckpointStore::new();
        // §V tasks acked out of order, tiling [0,8) and [12,16).
        s.ack(1, ProgressUnit::task(4, 4), 1);
        s.ack(2, ProgressUnit::task(0, 4), 1);
        s.ack(1, ProgressUnit::task(12, 4), 1);
        assert_eq!(s.acked_ranges(), vec![(0, 8), (12, 16)]);
        assert_eq!(s.complement(20), vec![(8, 12), (16, 20)]);
    }

    #[test]
    fn batch_deltas_survive_the_bit_cast() {
        let s = CheckpointStore::new();
        s.ack(0, ProgressUnit::batch(0), 5i64 as u64);
        s.ack(0, ProgressUnit::batch(1), (-3i64) as u64);
        assert_eq!(s.acked_batches(), vec![(0, 5), (1, -3)]);
    }

    #[test]
    fn rank_map_handles_dead_rank_zero() {
        let m = RankMap::surviving(4, &[0]);
        assert_eq!(m.survivors, vec![1, 2, 3]);
        assert_eq!(m.len(), 3);
        assert_eq!(m.old_of(0), 1); // new coordinator is old rank 1
        assert_eq!(m.new_of(0), None);
        assert_eq!(m.new_of(3), Some(2));
    }
}
