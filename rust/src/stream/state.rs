//! `StreamState` — the sequential incremental counting engine.
//!
//! Owns the base CSR, the [`AdjDelta`] overlay, the running exact triangle
//! count and the compaction policy. One [`StreamState::apply_batch`] call
//! is the full lifecycle: normalize → count Δ → apply to overlay →
//! maybe compact. The parallel driver in [`crate::stream::parallel`] runs
//! one replica of this state per rank and shards only the counting.

use crate::error::{Error, Result};
use crate::graph::csr::Csr;
use crate::graph::ordering::Oriented;
use crate::seq::node_iterator;
use crate::stream::batch::{normalize, Batch, NormalizedBatch};
use crate::stream::compact::{materialize, CompactionPolicy};
use crate::stream::delta::{count_batch, count_op, Scratch};
use crate::stream::overlay::AdjDelta;
use crate::TriangleCount;

/// Per-batch outcome returned by [`StreamState::apply_batch`].
#[derive(Clone, Debug)]
pub struct BatchOutcome {
    /// Signed triangle-count change.
    pub delta: i64,
    /// Triangle count after the batch.
    pub triangles: TriangleCount,
    /// Effective inserts / deletes after normalization.
    pub inserts: usize,
    pub deletes: usize,
    /// Element steps spent counting (see [`crate::stream::delta`]).
    pub work: u64,
    /// Whether this batch triggered a compaction.
    pub compacted: bool,
    /// The normalized batch (the window driver records effective inserts).
    pub normalized: NormalizedBatch,
}

/// Sequential incremental triangle counter (see module docs).
pub struct StreamState {
    base: Csr,
    overlay: AdjDelta,
    triangles: TriangleCount,
    policy: CompactionPolicy,
    batches_since_compact: usize,
    batches_applied: u64,
    compactions: u64,
    scratch: Scratch,
    hub_threshold: crate::adj::HubThreshold,
}

impl StreamState {
    /// Start from a snapshot, paying one static count (Fig 1 kernel).
    pub fn new(base: Csr) -> Self {
        StreamState::with_policy(base, CompactionPolicy::default())
    }

    /// Start with an explicit compaction policy.
    pub fn with_policy(base: Csr, policy: CompactionPolicy) -> Self {
        let triangles = node_iterator::count(&Oriented::from_graph(&base));
        StreamState::with_initial(base, policy, triangles)
    }

    /// Start from a snapshot whose triangle count is already known — the
    /// parallel driver counts once and hands the value to every replica.
    pub fn with_initial(base: Csr, policy: CompactionPolicy, triangles: TriangleCount) -> Self {
        let overlay = AdjDelta::new(base.num_nodes());
        StreamState {
            base,
            overlay,
            triangles,
            policy,
            batches_since_compact: 0,
            batches_applied: 0,
            compactions: 0,
            scratch: Scratch::default(),
            hub_threshold: crate::adj::HubThreshold::Auto,
        }
    }

    /// Set the hub-bitmap policy for the Δ counter's per-batch cache
    /// (`Off` reproduces the seed's pure sorted-merge streaming).
    pub fn set_hub_threshold(&mut self, t: crate::adj::HubThreshold) {
        self.hub_threshold = t;
    }

    /// Current exact triangle count.
    #[inline]
    pub fn triangles(&self) -> TriangleCount {
        self.triangles
    }

    /// Base snapshot (changes identity on compaction).
    pub fn base(&self) -> &Csr {
        &self.base
    }

    /// The overlay (empty right after a compaction).
    pub fn overlay(&self) -> &AdjDelta {
        &self.overlay
    }

    /// Undirected edges in the current graph.
    pub fn current_edges(&self) -> u64 {
        self.overlay.current_edge_count(&self.base)
    }

    /// Batches applied over the stream's lifetime.
    pub fn batches_applied(&self) -> u64 {
        self.batches_applied
    }

    /// Compactions performed over the stream's lifetime.
    pub fn compactions(&self) -> u64 {
        self.compactions
    }

    /// Normalize, count, apply and maybe compact one batch.
    pub fn apply_batch(&mut self, batch: &Batch) -> Result<BatchOutcome> {
        let nb = normalize(&self.base, &self.overlay, batch)?;
        self.scratch.begin_batch(&self.base, &self.overlay, self.hub_threshold);
        let mut delta = 0i64;
        let mut work = 0u64;
        for i in 0..nb.ops.len() {
            let r = count_op(&self.base, &self.overlay, &nb, i, &mut self.scratch);
            delta += r.delta;
            work += r.work;
        }
        self.apply_normalized(&nb, delta)?;
        let compacted = self.maybe_compact()?;
        Ok(BatchOutcome {
            delta,
            triangles: self.triangles,
            inserts: nb.inserts,
            deletes: nb.deletes,
            work,
            compacted,
            normalized: nb,
        })
    }

    /// Apply an already-normalized batch whose Δ was computed elsewhere
    /// (the parallel driver: every rank counted its shard, the reduced Δ
    /// comes in here so replicas stay in lockstep).
    pub fn apply_normalized(&mut self, nb: &NormalizedBatch, delta: i64) -> Result<()> {
        for op in &nb.ops {
            let changed = if op.insert {
                self.overlay.insert(&self.base, op.u, op.v)
            } else {
                self.overlay.remove(&self.base, op.u, op.v)
            };
            if !changed {
                return Err(Error::InvalidGraph(format!(
                    "normalized op on ({}, {}) was not effective — batch not normalized \
                     against this state",
                    op.u, op.v
                )));
            }
        }
        let t = self.triangles as i64 + delta;
        if t < 0 {
            return Err(Error::InvalidGraph(format!(
                "triangle count went negative ({t}) — corrupted delta"
            )));
        }
        self.triangles = t as u64;
        self.batches_since_compact += 1;
        self.batches_applied += 1;
        Ok(())
    }

    /// Count a batch without applying it (the parallel ranks' shard path
    /// uses [`count_op`] directly; this is the whole-batch variant).
    pub fn peek_batch(&self, nb: &NormalizedBatch) -> (i64, u64) {
        count_batch(&self.base, &self.overlay, nb)
    }

    /// Fold the overlay into a fresh CSR when the policy says so.
    pub fn maybe_compact(&mut self) -> Result<bool> {
        if !self
            .policy
            .should_compact(self.batches_since_compact, &self.base, &self.overlay)
        {
            return Ok(false);
        }
        self.compact()?;
        Ok(true)
    }

    /// Unconditional compaction.
    pub fn compact(&mut self) -> Result<()> {
        self.base = materialize(&self.base, &self.overlay)?;
        self.overlay = AdjDelta::new(self.base.num_nodes());
        self.batches_since_compact = 0;
        self.compactions += 1;
        Ok(())
    }

    /// Materialize the current graph (verification, hand-off to the static
    /// algorithms).
    pub fn snapshot(&self) -> Result<Csr> {
        materialize(&self.base, &self.overlay)
    }

    /// From-scratch recount of the current graph — the oracle every test
    /// and the CLI `--verify` path compare against.
    pub fn recount(&self) -> Result<TriangleCount> {
        Ok(node_iterator::count(&Oriented::from_graph(&self.snapshot()?)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::classic;
    use crate::stream::batch::EdgeUpdate;

    #[test]
    fn maintains_exact_count_over_batches() {
        let mut s = StreamState::new(classic::karate());
        assert_eq!(s.triangles(), classic::KARATE_TRIANGLES);
        let batches = [
            Batch::new(vec![EdgeUpdate::delete(0, 1), EdgeUpdate::insert(9, 10)]),
            Batch::new(vec![EdgeUpdate::insert(0, 1), EdgeUpdate::delete(33, 32)]),
            Batch::new(vec![EdgeUpdate::delete(0, 2), EdgeUpdate::delete(1, 2)]),
        ];
        for b in &batches {
            let out = s.apply_batch(b).unwrap();
            assert_eq!(out.triangles, s.recount().unwrap(), "after {b:?}");
        }
        assert_eq!(s.batches_applied(), 3);
    }

    #[test]
    fn compaction_preserves_count_and_graph() {
        let mut s = StreamState::with_policy(
            classic::karate(),
            CompactionPolicy { every_batches: 2, overlay_ratio: 0.0 },
        );
        let b1 = Batch::new(vec![EdgeUpdate::delete(0, 1)]);
        let b2 = Batch::new(vec![EdgeUpdate::insert(9, 12)]);
        let out1 = s.apply_batch(&b1).unwrap();
        assert!(!out1.compacted);
        let before = s.triangles();
        let out2 = s.apply_batch(&b2).unwrap();
        assert!(out2.compacted, "every_batches=2 must compact");
        assert!(s.overlay().is_empty());
        assert_eq!(s.triangles(), out2.triangles);
        assert_eq!(s.triangles(), s.recount().unwrap());
        assert_eq!(s.compactions(), 1);
        assert_eq!(out2.triangles as i64 - before as i64, out2.delta);
    }

    #[test]
    fn rejects_stale_normalized_batch() {
        let mut s = StreamState::new(classic::karate());
        let b = Batch::new(vec![EdgeUpdate::delete(0, 1)]);
        let nb = normalize(s.base(), s.overlay(), &b).unwrap();
        s.apply_normalized(&nb, 0).unwrap();
        // Re-applying the same normalized batch must fail loudly: the edge
        // is already gone.
        assert!(s.apply_normalized(&nb, 0).is_err());
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let mut s = StreamState::new(classic::complete(5));
        let out = s.apply_batch(&Batch::default()).unwrap();
        assert_eq!(out.delta, 0);
        assert_eq!(out.triangles, 10);
    }
}
