//! Deterministic edge-update stream generation.
//!
//! Turns any static workload graph (PA, R-MAT, contact, file…) into a
//! reproducible update stream: a fraction of the edges form the initial
//! CSR snapshot, the rest arrive as batched inserts interleaved with
//! deletions of currently-live streamed edges. This is what `tricount
//! stream`, the streaming benches and the acceptance tests all drive, so a
//! seed fully determines the run.

use crate::gen::rng::Rng;
use crate::graph::builder::from_edge_list;
use crate::graph::csr::Csr;
use crate::stream::batch::{Batch, EdgeUpdate};
use crate::VertexId;

/// Stream-shape parameters.
#[derive(Clone, Copy, Debug)]
pub struct StreamSpec {
    /// Fraction of the source graph's edges in the initial snapshot.
    pub base_fraction: f64,
    /// Updates per batch.
    pub batch_size: usize,
    /// Number of batches.
    pub batches: usize,
    /// Probability an update is a deletion of a live streamed edge (the
    /// rest are fresh inserts from the source graph's remaining edges).
    pub delete_fraction: f64,
}

impl Default for StreamSpec {
    fn default() -> Self {
        StreamSpec {
            base_fraction: 0.5,
            batch_size: 1_000,
            batches: 50,
            delete_fraction: 0.2,
        }
    }
}

/// A generated stream: initial snapshot + batch sequence.
pub struct StreamWorkload {
    pub base: Csr,
    pub batches: Vec<Batch>,
    /// Updates actually emitted (≤ `batch_size · batches` when the source
    /// graph runs out of fresh edges and no live edge remains to delete).
    pub updates: usize,
}

/// Build a stream from a source graph (see module docs). Deterministic in
/// `(g, spec, rng seed)`.
pub fn edge_stream(g: &Csr, spec: &StreamSpec, rng: &mut Rng) -> StreamWorkload {
    let mut edges: Vec<(VertexId, VertexId)> = g.edges().collect();
    rng.shuffle(&mut edges);
    let split = ((edges.len() as f64) * spec.base_fraction.clamp(0.0, 1.0)).round() as usize;
    let base = from_edge_list(g.num_nodes(), edges[..split].to_vec())
        .expect("source edges are valid");
    let mut pending = edges.split_off(split);
    pending.reverse(); // pop() consumes in shuffled order

    // Streamed edges currently live (inserted, not yet deleted) — indexable
    // for O(1) random victim selection via swap_remove.
    let mut live: Vec<(VertexId, VertexId)> = Vec::new();
    let mut batches = Vec::with_capacity(spec.batches);
    let mut updates = 0usize;
    for _ in 0..spec.batches {
        let mut b = Vec::with_capacity(spec.batch_size);
        for _ in 0..spec.batch_size {
            let want_delete = !live.is_empty() && rng.chance(spec.delete_fraction);
            if want_delete {
                let (u, v) = live.swap_remove(rng.below_usize(live.len()));
                b.push(EdgeUpdate::delete(u, v));
            } else if let Some((u, v)) = pending.pop() {
                live.push((u, v));
                b.push(EdgeUpdate::insert(u, v));
            } else if spec.delete_fraction > 0.0 && !live.is_empty() {
                // Fresh edges exhausted in a mixed stream: drain live ones.
                let (u, v) = live.swap_remove(rng.below_usize(live.len()));
                b.push(EdgeUpdate::delete(u, v));
            } else {
                break; // stream exhausted
            }
        }
        updates += b.len();
        batches.push(Batch::new(b));
    }
    StreamWorkload { base, batches, updates }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::graph::ordering::Oriented;
    use crate::seq::node_iterator;
    use crate::stream::parallel::{self, StreamOptions};

    #[test]
    fn stream_is_deterministic_per_seed() {
        let g = gen::pa::preferential_attachment(500, 6, &mut Rng::seeded(3));
        let spec = StreamSpec { batch_size: 40, batches: 8, ..Default::default() };
        let a = edge_stream(&g, &spec, &mut Rng::seeded(11));
        let b = edge_stream(&g, &spec, &mut Rng::seeded(11));
        assert_eq!(a.base, b.base);
        assert_eq!(a.updates, b.updates);
        for (x, y) in a.batches.iter().zip(&b.batches) {
            assert_eq!(x.updates, y.updates);
        }
    }

    #[test]
    fn deletes_target_live_edges_only() {
        let g = gen::erdos_renyi::gnm(200, 800, &mut Rng::seeded(5));
        let spec = StreamSpec {
            base_fraction: 0.3,
            batch_size: 50,
            batches: 10,
            delete_fraction: 0.4,
        };
        let w = edge_stream(&g, &spec, &mut Rng::seeded(17));
        // Replaying insert/delete multiset per edge: a delete must always
        // follow a live insert of the same edge.
        let mut live = std::collections::HashSet::new();
        for b in &w.batches {
            for up in &b.updates {
                let key = crate::stream::batch::edge_key(up.u, up.v);
                if up.insert {
                    assert!(live.insert(key), "double-insert of a live edge");
                } else {
                    assert!(live.remove(&key), "delete of a non-live edge");
                }
            }
        }
    }

    #[test]
    fn streaming_everything_reaches_the_source_graph() {
        // base 40% + streaming the rest with no deletes ⇒ final graph = g.
        let g = gen::pa::preferential_attachment(300, 8, &mut Rng::seeded(9));
        let m = g.num_edges() as usize;
        let spec = StreamSpec {
            base_fraction: 0.4,
            batch_size: m / 10 + 1,
            batches: 12,
            delete_fraction: 0.0,
        };
        let w = edge_stream(&g, &spec, &mut Rng::seeded(21));
        let r = parallel::run(&w.base, &w.batches, 2, StreamOptions::default()).unwrap();
        let expect = node_iterator::count(&Oriented::from_graph(&g));
        assert_eq!(r.final_triangles, expect);
        assert_eq!(r.final_graph.num_edges(), g.num_edges());
    }
}
