//! Parallel streaming driver over the [`crate::comm::threads`] runtime.
//!
//! Each of the `P` ranks keeps a full replica of the stream state (base CSR
//! + overlay), faithful to §V's "every machine stores the whole network"
//! model; only the *counting* is sharded. Per batch, every rank normalizes
//! identically (deterministic given the replicated state), then counts the
//! effective ops it **owns** and the partial Δs meet in an
//! `MPI_Allreduce(SUM)`.
//!
//! Ownership follows the non-overlapping §IV design transplanted to edge
//! updates: the owner of effective op `{u, v}` is the rank owning the
//! endpoint that comes *first* in the degree order `≺` (the min-degree
//! endpoint, degrees taken in the current graph) under the
//! [`crate::partition::balance::owner_table`] routing — surrogate-style,
//! every op counted by exactly one rank, no partition overlaps. Counting
//! from the min-degree side also feeds the adaptive intersection kernel
//! its cheap skewed case, which matters in the large-degree regime this
//! paper targets.

use std::sync::Arc;

use crate::comm::metrics::ClusterMetrics;
use crate::comm::threads::{Comm, Progress, ProgressUnit};
use crate::comm::transport::{Wire, WireReader};
use crate::config::CostFn;
use crate::error::{Error, Result};
use crate::graph::csr::Csr;
use crate::graph::ordering::{precedes, Oriented};
use crate::obs::span::SpanPhase;
use crate::partition::balance::{balanced_ranges, owner_table};
use crate::partition::cost::{cost_vector, prefix_sums};
use crate::seq::node_iterator;
use crate::stream::batch::Batch;
use crate::stream::compact::CompactionPolicy;
use crate::stream::delta::{count_op, Scratch};
use crate::stream::state::StreamState;
use crate::testkit::sim::Fabric;
use crate::testkit::trace::TraceReport;
use crate::TriangleCount;

/// Options for a parallel stream run.
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamOptions {
    pub policy: CompactionPolicy,
    /// Hub-bitmap policy for the Δ counter's per-batch cache
    /// (`--hub-threshold`; default `auto`, `off` reproduces the seed's
    /// pure sorted-merge streaming).
    pub hub_threshold: crate::adj::HubThreshold,
}

/// Per-batch statistics (rank-0 view of the reduced quantities plus the
/// per-rank work split for imbalance/sim projection).
#[derive(Clone, Debug)]
pub struct BatchStats {
    /// Reduced signed Δ.
    pub delta: i64,
    /// Global count after the batch.
    pub triangles: TriangleCount,
    /// Effective inserts / deletes after normalization.
    pub inserts: usize,
    pub deletes: usize,
    /// Counting work each rank performed for this batch.
    pub work_per_rank: Vec<u64>,
}

/// Result of streaming a batch sequence through `P` ranks.
#[derive(Clone, Debug)]
pub struct StreamRunResult {
    pub initial_triangles: TriangleCount,
    pub final_triangles: TriangleCount,
    pub per_batch: Vec<BatchStats>,
    /// The current graph after the last batch (rank 0's materialization) —
    /// what `--verify` recounts from scratch.
    pub final_graph: Csr,
    pub metrics: ClusterMetrics,
    /// Compactions performed (per replica; identical on every rank).
    pub compactions: u64,
}

impl StreamRunResult {
    /// Total effective updates applied.
    pub fn effective_updates(&self) -> u64 {
        self.per_batch.iter().map(|b| (b.inserts + b.deletes) as u64).sum()
    }

    /// Per-rank counting work over the whole stream.
    pub fn total_work(&self) -> u64 {
        self.per_batch.iter().flat_map(|b| &b.work_per_rank).sum()
    }
}

/// One rank's record of one batch.
#[derive(Clone, Copy)]
struct RankBatch {
    /// Reduced (global) Δ — identical on every rank after the allreduce.
    delta: i64,
    /// This rank's counting work.
    work: u64,
    /// Effective op counts (identical on every rank).
    inserts: u32,
    deletes: u32,
}

impl Wire for RankBatch {
    fn write_to(&self, out: &mut Vec<u8>) {
        self.delta.write_to(out);
        self.work.write_to(out);
        self.inserts.write_to(out);
        self.deletes.write_to(out);
    }
    fn read_from(r: &mut WireReader<'_>) -> Result<Self> {
        Ok(RankBatch {
            delta: i64::read_from(r)?,
            work: u64::read_from(r)?,
            inserts: u32::read_from(r)?,
            deletes: u32::read_from(r)?,
        })
    }
}

/// What each rank returns to the driver.
struct RankOutput {
    per_batch: Vec<RankBatch>,
    /// Rank 0 materializes the final graph; other ranks skip it.
    final_graph: Option<Csr>,
    compactions: u64,
}

/// `RankOutput` crosses the socket fabric twice: worker → rank 0 in the
/// result gather and back out in the assembled broadcast, final graph
/// included — the stream driver's fold reads `outputs[0].final_graph` on
/// every rank, so stripping it in transit would break worker-side folds.
impl Wire for RankOutput {
    fn write_to(&self, out: &mut Vec<u8>) {
        (self.per_batch.len() as u64).write_to(out);
        for b in &self.per_batch {
            b.write_to(out);
        }
        self.final_graph.write_to(out);
        self.compactions.write_to(out);
    }
    fn read_from(r: &mut WireReader<'_>) -> Result<Self> {
        let n = r.len_prefix(24)?;
        let mut per_batch = Vec::with_capacity(n);
        for _ in 0..n {
            per_batch.push(RankBatch::read_from(r)?);
        }
        Ok(RankOutput {
            per_batch,
            final_graph: Option::<Csr>::read_from(r)?,
            compactions: u64::read_from(r)?,
        })
    }
}

/// Stream `batches` through `p` ranks. The initial count is taken once on
/// the driver; every rank then maintains a replica in lockstep.
pub fn run(base: &Csr, batches: &[Batch], p: usize, opts: StreamOptions) -> Result<StreamRunResult> {
    let initial = node_iterator::count(&Oriented::from_graph(base));
    run_with_initial(base, batches, p, opts, initial)
}

/// [`run`] with the snapshot's triangle count already known — lets callers
/// that replay the same snapshot (benches, repeated experiments) keep the
/// one-time static count out of the measured region.
pub fn run_with_initial(
    base: &Csr,
    batches: &[Batch],
    p: usize,
    opts: StreamOptions,
    initial: TriangleCount,
) -> Result<StreamRunResult> {
    run_with_initial_on(&Fabric::Channel, base, batches, p, opts, initial).0
}

/// [`run_with_initial`] on an explicit fabric (conformance entry point).
/// The stream protocol's only collective surface is the per-batch
/// `MPI_Allreduce(SUM)` pair — which is exactly where a dead rank must
/// surface as an `Err` instead of a hang.
pub fn run_with_initial_on(
    fabric: &Fabric,
    base: &Csr,
    batches: &[Batch],
    p: usize,
    opts: StreamOptions,
    initial: TriangleCount,
) -> (Result<StreamRunResult>, Option<TraceReport>) {
    run_with_initial_hooked_on(fabric, base, batches, p, opts, initial, None)
}

/// [`run_with_initial_on`] with an `ft/` checkpoint sink (`ft::supervisor`
/// entry point). Rank 0 acks each batch with its reduced signed Δ
/// (bit-cast to `u64`) after the allreduce pair — a phase-boundary
/// watermark; batches past the watermark are re-streamed on recovery.
#[allow(clippy::too_many_arguments)]
pub fn run_with_initial_hooked_on(
    fabric: &Fabric,
    base: &Csr,
    batches: &[Batch],
    p: usize,
    opts: StreamOptions,
    initial: TriangleCount,
    progress: Option<Arc<dyn Progress>>,
) -> (Result<StreamRunResult>, Option<TraceReport>) {
    assert!(p >= 1, "need at least one rank");
    // Balance node ownership by degree (the streaming analogue of §IV-B:
    // an update's cost is the degree of its endpoints). Only degrees are
    // read, so skip building hub bitmaps for this throwaway orientation.
    let o = Oriented::from_graph_with(base, crate::adj::HubThreshold::Off);
    let ranges = balanced_ranges(&prefix_sums(&cost_vector(&o, CostFn::Degree)), p);
    let owner: Arc<Vec<u32>> = Arc::new(owner_table(&ranges, base.num_nodes()));
    drop(o);

    let base: Arc<Csr> = Arc::new(base.clone());
    let batches: Arc<Vec<Batch>> = Arc::new(batches.to_vec());

    let (results, trace) = fabric.try_run_hooked::<u64, RankOutput, _>(p, progress, |c| {
        rank_main(c, base.clone(), batches.clone(), owner.clone(), opts, initial)
    });
    let results = match results {
        Ok(r) => r,
        Err(e) => return (Err(e), trace),
    };

    let mut metrics = ClusterMetrics::default();
    let mut outputs = Vec::with_capacity(p);
    for (out, m) in results {
        metrics.per_rank.push(m);
        outputs.push(out);
    }
    let final_graph = match outputs[0].final_graph.take() {
        Some(g) => g,
        None => return (Err(Error::Cluster("rank 0 produced no final graph".into())), trace),
    };

    let mut per_batch = Vec::with_capacity(batches.len());
    let mut triangles = initial;
    for bi in 0..batches.len() {
        let rb = outputs[0].per_batch[bi];
        for out in &outputs {
            debug_assert_eq!(out.per_batch[bi].delta, rb.delta, "ranks disagree on batch {bi}");
        }
        triangles = (triangles as i64 + rb.delta) as u64;
        per_batch.push(BatchStats {
            delta: rb.delta,
            triangles,
            inserts: rb.inserts as usize,
            deletes: rb.deletes as usize,
            work_per_rank: outputs.iter().map(|o| o.per_batch[bi].work).collect(),
        });
    }
    let final_triangles = triangles;

    (
        Ok(StreamRunResult {
            initial_triangles: initial,
            final_triangles,
            per_batch,
            final_graph,
            metrics,
            compactions: outputs[0].compactions,
        }),
        trace,
    )
}

/// The per-rank program: replicate state, count owned ops, allreduce.
/// Comm and replica failures propagate as `Err` through the launcher
/// instead of poisoning the cluster with a panic.
fn rank_main(
    c: &mut Comm<u64>,
    base: Arc<Csr>,
    batches: Arc<Vec<Batch>>,
    owner: Arc<Vec<u32>>,
    opts: StreamOptions,
    initial: TriangleCount,
) -> Result<RankOutput> {
    let me = c.rank() as u32;
    let mut state = StreamState::with_initial((*base).clone(), opts.policy, initial);
    let mut scratch = Scratch::default();
    let mut per_batch = Vec::with_capacity(batches.len());

    for (bi, batch) in batches.iter().enumerate() {
        // Normalize + count under one Compute span; the replica update
        // below gets its own BatchApply span. The allreduce pair between
        // them records Reduce spans on its own.
        c.span_begin(SpanPhase::Compute);
        let nb = crate::stream::batch::normalize(state.base(), state.overlay(), batch)?;
        // Arm the hub-bitmap cache against this batch's snapshot (identical
        // on every rank — replicas are in lockstep, so the resolved
        // threshold and therefore the per-op work charge are deterministic).
        scratch.begin_batch(state.base(), state.overlay(), opts.hub_threshold);
        // Count the ops this rank owns: min-≺ endpoint routing.
        let (mut plus, mut minus, mut work) = (0u64, 0u64, 0u64);
        for (i, op) in nb.ops.iter().enumerate() {
            let du = state.overlay().current_degree(state.base(), op.u) as u32;
            let dv = state.overlay().current_degree(state.base(), op.v) as u32;
            let e = if precedes(du, op.u, dv, op.v) { op.u } else { op.v };
            if owner[e as usize] != me {
                continue;
            }
            let r = count_op(state.base(), state.overlay(), &nb, i, &mut scratch);
            if r.delta >= 0 {
                plus += r.delta as u64;
            } else {
                minus += (-r.delta) as u64;
            }
            work += r.work;
        }
        c.span_end();
        // MPI_Allreduce(SUM) ×2: positive and negative magnitudes.
        let delta = c.reduce_sum(plus)? as i64 - c.reduce_sum(minus)? as i64;
        // Batch watermark: the reduced Δ is identical on every rank; rank 0
        // publishes it once (signed, bit-cast) at this phase boundary.
        if c.rank() == 0 {
            c.ckpt_ack(ProgressUnit::batch(bi as u32), delta as u64);
        }
        c.metrics.work_units += work;
        c.span_begin(SpanPhase::BatchApply);
        state.apply_normalized(&nb, delta)?;
        state.maybe_compact()?;
        c.span_end();
        per_batch.push(RankBatch {
            delta,
            work,
            inserts: nb.inserts as u32,
            deletes: nb.deletes as u32,
        });
    }

    let final_graph = if c.rank() == 0 { Some(state.snapshot()?) } else { None };
    Ok(RankOutput { per_batch, final_graph, compactions: state.compactions() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::rng::Rng;
    use crate::graph::classic;
    use crate::stream::batch::EdgeUpdate;

    fn random_batches(base: &Csr, count: usize, size: usize, seed: u64) -> Vec<Batch> {
        let n = base.num_nodes() as u64;
        let mut rng = Rng::seeded(seed);
        (0..count)
            .map(|_| {
                Batch::new(
                    (0..size)
                        .map(|_| {
                            let u = rng.below(n) as u32;
                            let v = rng.below(n) as u32;
                            if rng.chance(0.45) {
                                EdgeUpdate::delete(u, v)
                            } else {
                                EdgeUpdate::insert(u, v)
                            }
                        })
                        .collect(),
                )
            })
            .collect()
    }

    #[test]
    fn parallel_matches_sequential_and_oracle() {
        let base = classic::karate();
        let batches = random_batches(&base, 12, 10, 0xABCD);
        // Sequential reference through StreamState.
        let mut seq = StreamState::new(base.clone());
        for b in &batches {
            seq.apply_batch(b).unwrap();
        }
        let expect = seq.recount().unwrap();
        assert_eq!(seq.triangles(), expect, "sequential engine must be exact");

        for p in [1, 2, 4, 7] {
            let r = run(&base, &batches, p, StreamOptions::default()).unwrap();
            assert_eq!(r.final_triangles, expect, "P={p}");
            let recount = node_iterator::count(&Oriented::from_graph(&r.final_graph));
            assert_eq!(r.final_triangles, recount, "P={p} recount");
        }
    }

    #[test]
    fn per_batch_deltas_sum_to_final() {
        let base = classic::complete(10);
        let batches = random_batches(&base, 6, 8, 7);
        let r = run(&base, &batches, 3, StreamOptions::default()).unwrap();
        let sum: i64 = r.per_batch.iter().map(|b| b.delta).sum();
        assert_eq!(
            r.initial_triangles as i64 + sum,
            r.final_triangles as i64
        );
        assert_eq!(r.per_batch.last().unwrap().triangles, r.final_triangles);
    }

    #[test]
    fn work_is_sharded_not_replicated() {
        // With 4 ranks, total work should equal the 1-rank total (each op
        // counted exactly once), split across ranks.
        let base = classic::karate();
        let batches = random_batches(&base, 8, 12, 99);
        let r1 = run(&base, &batches, 1, StreamOptions::default()).unwrap();
        let r4 = run(&base, &batches, 4, StreamOptions::default()).unwrap();
        assert_eq!(r1.total_work(), r4.total_work());
        let rank_works: Vec<u64> = (0..4)
            .map(|k| r4.per_batch.iter().map(|b| b.work_per_rank[k]).sum())
            .collect();
        assert!(rank_works.iter().filter(|&&w| w > 0).count() >= 2, "{rank_works:?}");
    }
}
