//! `AdjDelta` — a mutable adjacency overlay on top of the immutable
//! [`Csr`] snapshot.
//!
//! The static algorithms keep their zero-copy CSR; the streaming engine
//! layers per-node *added* and *removed* neighbor sets on top of it. The
//! current graph is
//!
//! ```text
//! G_cur = (G_base ∪ added) \ removed        added ∩ base = ∅, removed ⊆ base
//! ```
//!
//! Both delta sets are kept sorted by node id and symmetric (an edge
//! appears in both endpoints' lists), mirroring the CSR invariants so the
//! merged view [`AdjDelta::current_nbrs`] is id-sorted and feeds straight
//! into the hybrid [`crate::adj`] dispatch: the Δ counter's scratch
//! ([`crate::stream::delta::Scratch`]) builds hub bitmap rows over merged
//! views that cross the density threshold, one per batch per hub endpoint.
//! Deltas stay small between compactions
//! ([`crate::stream::compact`] folds them back into a fresh CSR), so the
//! sorted-`Vec` insert cost is bounded in practice.

use crate::graph::csr::Csr;
use crate::VertexId;

/// Mutable adjacency delta over a base CSR (see module docs).
#[derive(Clone, Debug, Default)]
pub struct AdjDelta {
    /// Per-node sorted lists of neighbors present in `G_cur` but not base.
    added: Vec<Vec<VertexId>>,
    /// Per-node sorted lists of base neighbors deleted from `G_cur`.
    removed: Vec<Vec<VertexId>>,
    /// Undirected added-edge count.
    added_edges: u64,
    /// Undirected removed-edge count.
    removed_edges: u64,
}

impl AdjDelta {
    /// Empty overlay for a graph on `n` nodes.
    pub fn new(n: usize) -> Self {
        AdjDelta {
            added: vec![Vec::new(); n],
            removed: vec![Vec::new(); n],
            added_edges: 0,
            removed_edges: 0,
        }
    }

    /// Number of nodes (fixed: streaming updates edges, never nodes).
    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.added.len()
    }

    /// Undirected edges added on top of the base snapshot.
    #[inline]
    pub fn added_edges(&self) -> u64 {
        self.added_edges
    }

    /// Undirected base edges masked out by deletions.
    #[inline]
    pub fn removed_edges(&self) -> u64 {
        self.removed_edges
    }

    /// Total overlay entries (the compaction policy's size signal).
    #[inline]
    pub fn delta_edges(&self) -> u64 {
        self.added_edges + self.removed_edges
    }

    /// `true` iff the overlay holds no deltas (current graph == base).
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.delta_edges() == 0
    }

    /// Bytes held by the overlay lists (edge entries, both directions).
    pub fn memory_bytes(&self) -> u64 {
        let entries: usize = self
            .added
            .iter()
            .chain(self.removed.iter())
            .map(|l| l.len())
            .sum();
        (entries * std::mem::size_of::<VertexId>()) as u64
    }

    /// `true` iff `{u, v}` is an edge of the current graph.
    pub fn has_edge(&self, base: &Csr, u: VertexId, v: VertexId) -> bool {
        if u == v {
            return false;
        }
        if contains(&self.removed[u as usize], v) {
            return false;
        }
        contains(&self.added[u as usize], v) || base.has_edge(u, v)
    }

    /// Degree of `v` in the current graph. O(1).
    #[inline]
    pub fn current_degree(&self, base: &Csr, v: VertexId) -> usize {
        base.degree(v) + self.added[v as usize].len() - self.removed[v as usize].len()
    }

    /// Undirected edge count of the current graph.
    #[inline]
    pub fn current_edge_count(&self, base: &Csr) -> u64 {
        base.num_edges() + self.added_edges - self.removed_edges
    }

    /// Insert edge `{u, v}` into the current graph. Returns `false` (and
    /// changes nothing) when the edge is already present or `u == v`.
    pub fn insert(&mut self, base: &Csr, u: VertexId, v: VertexId) -> bool {
        if u == v || self.has_edge(base, u, v) {
            return false;
        }
        if base.has_edge(u, v) {
            // Present in base but masked: un-delete.
            remove_sorted(&mut self.removed[u as usize], v);
            remove_sorted(&mut self.removed[v as usize], u);
            self.removed_edges -= 1;
        } else {
            insert_sorted(&mut self.added[u as usize], v);
            insert_sorted(&mut self.added[v as usize], u);
            self.added_edges += 1;
        }
        true
    }

    /// Delete edge `{u, v}` from the current graph. Returns `false` (and
    /// changes nothing) when the edge is absent or `u == v`.
    pub fn remove(&mut self, base: &Csr, u: VertexId, v: VertexId) -> bool {
        if u == v || !self.has_edge(base, u, v) {
            return false;
        }
        if contains(&self.added[u as usize], v) {
            remove_sorted(&mut self.added[u as usize], v);
            remove_sorted(&mut self.added[v as usize], u);
            self.added_edges -= 1;
        } else {
            insert_sorted(&mut self.removed[u as usize], v);
            insert_sorted(&mut self.removed[v as usize], u);
            self.removed_edges += 1;
        }
        true
    }

    /// Materialize `v`'s current neighbor list into `out` (sorted by id):
    /// a three-way merge of `base \ removed ∪ added`. O(d_v + |deltas_v|).
    pub fn current_nbrs(&self, base: &Csr, v: VertexId, out: &mut Vec<VertexId>) {
        out.clear();
        let bs = base.neighbors(v);
        let add = &self.added[v as usize];
        let del = &self.removed[v as usize];
        out.reserve(bs.len() + add.len());
        let (mut i, mut j, mut k) = (0usize, 0usize, 0usize);
        while i < bs.len() || j < add.len() {
            // added ∩ base = ∅, so exactly one side advances per step.
            let take_base = j >= add.len() || (i < bs.len() && bs[i] < add[j]);
            if take_base {
                let w = bs[i];
                i += 1;
                // Skip base neighbors masked by `removed` (both sorted).
                while k < del.len() && del[k] < w {
                    k += 1;
                }
                if k < del.len() && del[k] == w {
                    k += 1;
                    continue;
                }
                out.push(w);
            } else {
                out.push(add[j]);
                j += 1;
            }
        }
    }

    /// All undirected edges `(u, v)` with `u < v` of the current graph —
    /// the compaction input. O(n + m + |deltas|).
    pub fn current_edges(&self, base: &Csr) -> Vec<(VertexId, VertexId)> {
        let mut edges = Vec::with_capacity(self.current_edge_count(base) as usize);
        let mut buf = Vec::new();
        for v in 0..self.num_nodes() as VertexId {
            self.current_nbrs(base, v, &mut buf);
            for &u in &buf {
                if v < u {
                    edges.push((v, u));
                }
            }
        }
        edges
    }
}

/// Binary-search membership in a sorted list.
#[inline]
fn contains(list: &[VertexId], x: VertexId) -> bool {
    list.binary_search(&x).is_ok()
}

/// Sorted insert; `x` must be absent.
#[inline]
fn insert_sorted(list: &mut Vec<VertexId>, x: VertexId) {
    let i = list.partition_point(|&y| y < x);
    debug_assert!(i == list.len() || list[i] != x);
    list.insert(i, x);
}

/// Sorted removal; `x` must be present.
#[inline]
fn remove_sorted(list: &mut Vec<VertexId>, x: VertexId) {
    let i = list.binary_search(&x).expect("overlay symmetry violated");
    list.remove(i);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_edges;
    use crate::graph::classic;

    fn nbrs(d: &AdjDelta, base: &Csr, v: VertexId) -> Vec<VertexId> {
        let mut out = Vec::new();
        d.current_nbrs(base, v, &mut out);
        out
    }

    #[test]
    fn insert_and_remove_roundtrip() {
        let base = from_edges(4, [(0, 1), (1, 2)]).unwrap();
        let mut d = AdjDelta::new(4);
        assert!(d.insert(&base, 2, 3));
        assert!(!d.insert(&base, 2, 3), "duplicate insert is a no-op");
        assert!(d.has_edge(&base, 3, 2));
        assert_eq!(nbrs(&d, &base, 2), vec![1, 3]);
        assert_eq!(d.current_edge_count(&base), 3);

        assert!(d.remove(&base, 0, 1));
        assert!(!d.remove(&base, 0, 1), "double delete is a no-op");
        assert!(!d.has_edge(&base, 0, 1));
        assert_eq!(nbrs(&d, &base, 1), vec![2]);
        assert_eq!(d.current_edge_count(&base), 2);
    }

    #[test]
    fn undelete_restores_base_edge_without_growth() {
        let base = from_edges(3, [(0, 1)]).unwrap();
        let mut d = AdjDelta::new(3);
        assert!(d.remove(&base, 0, 1));
        assert_eq!(d.removed_edges(), 1);
        assert!(d.insert(&base, 0, 1));
        assert!(d.is_empty(), "delete+insert of a base edge cancels");
        assert_eq!(nbrs(&d, &base, 0), vec![1]);
    }

    #[test]
    fn insert_then_delete_of_new_edge_cancels() {
        let base = Csr::empty(3);
        let mut d = AdjDelta::new(3);
        assert!(d.insert(&base, 0, 2));
        assert!(d.remove(&base, 2, 0));
        assert!(d.is_empty());
        assert_eq!(d.memory_bytes(), 0);
    }

    #[test]
    fn self_loops_rejected() {
        let base = Csr::empty(2);
        let mut d = AdjDelta::new(2);
        assert!(!d.insert(&base, 1, 1));
        assert!(!d.remove(&base, 1, 1));
        assert!(!d.has_edge(&base, 1, 1));
    }

    #[test]
    fn merged_view_stays_sorted_and_degrees_agree() {
        let base = classic::karate();
        let n = base.num_nodes();
        let mut d = AdjDelta::new(n);
        d.insert(&base, 0, 9);
        d.remove(&base, 0, 1);
        d.insert(&base, 30, 2);
        for v in 0..n as VertexId {
            let ns = nbrs(&d, &base, v);
            assert!(ns.windows(2).all(|w| w[0] < w[1]), "N_{v} unsorted: {ns:?}");
            assert_eq!(ns.len(), d.current_degree(&base, v), "degree of {v}");
        }
    }

    #[test]
    fn current_edges_match_rebuilt_graph() {
        let base = classic::karate();
        let mut d = AdjDelta::new(base.num_nodes());
        d.remove(&base, 0, 1);
        d.remove(&base, 33, 32);
        d.insert(&base, 5, 25);
        let edges = d.current_edges(&base);
        assert_eq!(edges.len() as u64, d.current_edge_count(&base));
        let g = from_edges(base.num_nodes(), edges).unwrap();
        g.validate().unwrap();
        assert!(!g.has_edge(0, 1));
        assert!(g.has_edge(5, 25));
    }
}
