//! Compaction — fold the overlay back into a fresh immutable CSR.
//!
//! The overlay keeps per-batch updates cheap, but its sorted-`Vec` deltas
//! cost more per lookup than the flat CSR arrays and grow without bound on
//! a long stream. Periodically the engine *compacts*: materialize the
//! current graph through [`crate::graph::builder`] and restart with an
//! empty overlay. The current graph — and therefore the maintained count —
//! is unchanged by construction; only the base/delta split moves.
//!
//! ```text
//!   base₀ (CSR) ──┐
//!                 ├── overlay grows …  ──compact──▶  base₁ (CSR) ── ∅ overlay
//!   batches ──────┘                                      │
//!                                                        ▼ (repeat)
//! ```

use crate::error::Result;
use crate::graph::builder::from_edge_list;
use crate::graph::csr::Csr;
use crate::stream::overlay::AdjDelta;

/// When to fold the overlay into a fresh CSR.
#[derive(Clone, Copy, Debug)]
pub struct CompactionPolicy {
    /// Compact after this many batches (0 = never by count).
    pub every_batches: usize,
    /// Compact when `overlay.delta_edges() > ratio · base.num_edges()`
    /// (0.0 = never by size).
    pub overlay_ratio: f64,
}

impl Default for CompactionPolicy {
    fn default() -> Self {
        // Tuned for batch ≈ 1k on million-edge graphs: rebuild cost O(m)
        // amortizes over ~16k updates, overlay stays ≪ 10% of the base.
        CompactionPolicy { every_batches: 16, overlay_ratio: 0.10 }
    }
}

impl CompactionPolicy {
    /// Never compact (tests, micro-benches of the overlay path).
    pub fn never() -> Self {
        CompactionPolicy { every_batches: 0, overlay_ratio: 0.0 }
    }

    /// Decide given batches-since-last-compaction and the current sizes.
    pub fn should_compact(&self, batches_since: usize, base: &Csr, overlay: &AdjDelta) -> bool {
        if overlay.is_empty() {
            return false;
        }
        (self.every_batches > 0 && batches_since >= self.every_batches)
            || (self.overlay_ratio > 0.0
                && overlay.delta_edges() as f64 > self.overlay_ratio * base.num_edges() as f64)
    }
}

/// Materialize `base ⊕ overlay` as a fresh CSR (same node set).
pub fn materialize(base: &Csr, overlay: &AdjDelta) -> Result<Csr> {
    from_edge_list(base.num_nodes(), overlay.current_edges(base))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::classic;

    #[test]
    fn materialize_preserves_current_graph() {
        let base = classic::karate();
        let mut d = AdjDelta::new(base.num_nodes());
        d.remove(&base, 0, 1);
        d.insert(&base, 3, 9);
        let fresh = materialize(&base, &d).unwrap();
        fresh.validate().unwrap();
        assert_eq!(fresh.num_edges(), d.current_edge_count(&base));
        assert!(!fresh.has_edge(0, 1));
        assert!(fresh.has_edge(3, 9));
        // Identity compaction: empty overlay reproduces the base exactly.
        let again = materialize(&fresh, &AdjDelta::new(fresh.num_nodes())).unwrap();
        assert_eq!(again, fresh);
    }

    #[test]
    fn policy_triggers() {
        let base = classic::karate();
        let mut d = AdjDelta::new(base.num_nodes());
        let p = CompactionPolicy { every_batches: 4, overlay_ratio: 0.05 };
        assert!(!p.should_compact(100, &base, &d), "empty overlay never compacts");
        d.insert(&base, 0, 9);
        assert!(p.should_compact(4, &base, &d), "batch-count trigger");
        assert!(!p.should_compact(1, &base, &d));
        for v in 10..14 {
            assert!(d.insert(&base, 9, v), "9–{v} must be absent in karate");
        }
        // 5 delta edges > 5% of 78 base edges.
        assert!(p.should_compact(1, &base, &d), "size trigger");
        assert!(!CompactionPolicy::never().should_compact(usize::MAX, &base, &d));
    }
}
