//! Edge-update batches and their normalization.
//!
//! A raw [`Batch`] is what arrives from the outside world: an *ordered*
//! list of insert/delete operations, possibly containing duplicates,
//! self-loops, no-ops (inserting a present edge, deleting an absent one)
//! and insert/delete churn on the same edge. The triangle count after a
//! batch depends only on the **final** edge set, so normalization reduces
//! the batch to its net effect against the pre-batch snapshot:
//!
//! * `I` — edges absent before the batch and present after (inserts);
//! * `D` — edges present before and absent after (deletes);
//! * everything else (self-loops, duplicates, cancelled churn) dropped.
//!
//! The surviving *effective ops* are placed in a canonical total order
//! (deletes before inserts, each sorted by endpoint pair) and indexed —
//! the exact delta counter in [`crate::stream::delta`] evaluates op `i`
//! against the graph state with effective ops `< i` applied, which makes
//! the per-op counts order-defined and therefore shardable across ranks
//! without coordination.

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::graph::csr::Csr;
use crate::stream::overlay::AdjDelta;
use crate::VertexId;

/// One raw edge update.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EdgeUpdate {
    pub u: VertexId,
    pub v: VertexId,
    /// `true` = insert, `false` = delete.
    pub insert: bool,
}

impl EdgeUpdate {
    pub fn insert(u: VertexId, v: VertexId) -> Self {
        EdgeUpdate { u, v, insert: true }
    }

    pub fn delete(u: VertexId, v: VertexId) -> Self {
        EdgeUpdate { u, v, insert: false }
    }
}

/// An ordered list of raw edge updates applied atomically.
#[derive(Clone, Debug, Default)]
pub struct Batch {
    pub updates: Vec<EdgeUpdate>,
}

impl Batch {
    pub fn new(updates: Vec<EdgeUpdate>) -> Self {
        Batch { updates }
    }

    pub fn len(&self) -> usize {
        self.updates.len()
    }

    pub fn is_empty(&self) -> bool {
        self.updates.is_empty()
    }
}

/// One effective (net) op of a normalized batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EffOp {
    pub u: VertexId,
    pub v: VertexId,
    /// `true` = edge is inserted by the batch, `false` = deleted.
    pub insert: bool,
}

/// Canonical `u64` key of an undirected edge (`min ∥ max`).
#[inline]
pub fn edge_key(u: VertexId, v: VertexId) -> u64 {
    let (a, b) = if u <= v { (u, v) } else { (v, u) };
    ((a as u64) << 32) | b as u64
}

/// A batch reduced to its net effect, in canonical op order (see module
/// docs). Carries the lookup structures the delta counter needs to adjust
/// snapshot intersections for mid-batch state.
#[derive(Clone, Debug, Default)]
pub struct NormalizedBatch {
    /// Effective ops; position = op index in the canonical order.
    pub ops: Vec<EffOp>,
    /// Effective inserts (`= ops.iter().filter(|o| o.insert).count()`).
    pub inserts: usize,
    /// Effective deletes.
    pub deletes: usize,
    /// `edge_key → op index` over `ops`.
    index: HashMap<u64, usize>,
    /// `endpoint → sorted other-endpoints` over `ops` (both directions).
    incident: HashMap<VertexId, Vec<VertexId>>,
}

impl NormalizedBatch {
    /// Index of the effective op on `{u, v}`, if the batch touches it.
    #[inline]
    pub fn op_index(&self, u: VertexId, v: VertexId) -> Option<usize> {
        self.index.get(&edge_key(u, v)).copied()
    }

    /// Endpoints `w` such that the batch has an effective op on `{v, w}`.
    #[inline]
    pub fn touched(&self, v: VertexId) -> &[VertexId] {
        self.incident.get(&v).map_or(&[], Vec::as_slice)
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }
}

/// Normalize a raw batch against the current snapshot (`base` + `overlay`).
///
/// Replays the batch's sequential semantics on a per-edge presence bit
/// (later ops win), then keeps only edges whose final presence differs
/// from the pre-batch snapshot. Endpoints must be `< n`; self-loops are
/// dropped silently (mirroring [`crate::graph::builder`]).
pub fn normalize(base: &Csr, overlay: &AdjDelta, batch: &Batch) -> Result<NormalizedBatch> {
    let n = base.num_nodes();
    // edge key → (initial presence, desired presence after the batch).
    let mut net: HashMap<u64, (bool, bool)> = HashMap::with_capacity(batch.len());
    for up in &batch.updates {
        let (u, v) = (up.u, up.v);
        if u as usize >= n || v as usize >= n {
            return Err(Error::InvalidGraph(format!(
                "update ({u},{v}) out of range for n={n}"
            )));
        }
        if u == v {
            continue;
        }
        let e = net
            .entry(edge_key(u, v))
            .or_insert_with(|| {
                let present = overlay.has_edge(base, u, v);
                (present, present)
            });
        e.1 = up.insert;
    }

    let mut ops: Vec<EffOp> = net
        .into_iter()
        .filter(|&(_, (was, now))| was != now)
        .map(|(key, (_, now))| EffOp {
            u: (key >> 32) as VertexId,
            v: key as VertexId,
            insert: now,
        })
        .collect();
    // Canonical total order: deletes first, then inserts, each by (u, v).
    ops.sort_unstable_by_key(|o| (o.insert, o.u, o.v));

    let mut index = HashMap::with_capacity(ops.len());
    let mut incident: HashMap<VertexId, Vec<VertexId>> = HashMap::new();
    let mut inserts = 0;
    for (i, op) in ops.iter().enumerate() {
        index.insert(edge_key(op.u, op.v), i);
        incident.entry(op.u).or_default().push(op.v);
        incident.entry(op.v).or_default().push(op.u);
        inserts += op.insert as usize;
    }
    for list in incident.values_mut() {
        list.sort_unstable();
        list.dedup();
    }
    let deletes = ops.len() - inserts;
    Ok(NormalizedBatch { ops, inserts, deletes, index, incident })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_edges;

    fn setup() -> (Csr, AdjDelta) {
        // 0-1, 1-2 present.
        let base = from_edges(5, [(0, 1), (1, 2)]).unwrap();
        let d = AdjDelta::new(5);
        (base, d)
    }

    #[test]
    fn noops_and_self_loops_dropped() {
        let (base, d) = setup();
        let b = Batch::new(vec![
            EdgeUpdate::insert(0, 1), // already present
            EdgeUpdate::delete(3, 4), // already absent
            EdgeUpdate::insert(2, 2), // self loop
        ]);
        let nb = normalize(&base, &d, &b).unwrap();
        assert!(nb.is_empty());
    }

    #[test]
    fn churn_cancels_by_final_state() {
        let (base, d) = setup();
        let b = Batch::new(vec![
            EdgeUpdate::insert(3, 4),
            EdgeUpdate::delete(3, 4), // insert+delete of a new edge: net nothing
            EdgeUpdate::delete(0, 1),
            EdgeUpdate::insert(1, 0), // delete+insert of a present edge: net nothing
        ]);
        let nb = normalize(&base, &d, &b).unwrap();
        assert!(nb.is_empty());
    }

    #[test]
    fn canonical_order_deletes_first() {
        let (base, d) = setup();
        let b = Batch::new(vec![
            EdgeUpdate::insert(2, 3),
            EdgeUpdate::delete(1, 2),
            EdgeUpdate::insert(0, 4),
        ]);
        let nb = normalize(&base, &d, &b).unwrap();
        assert_eq!(nb.deletes, 1);
        assert_eq!(nb.inserts, 2);
        assert!(!nb.ops[0].insert);
        assert_eq!((nb.ops[0].u, nb.ops[0].v), (1, 2));
        assert_eq!((nb.ops[1].u, nb.ops[1].v), (0, 4));
        assert_eq!((nb.ops[2].u, nb.ops[2].v), (2, 3));
        assert_eq!(nb.op_index(4, 0), Some(1), "endpoint order irrelevant");
        assert_eq!(nb.op_index(0, 3), None);
        assert_eq!(nb.touched(2), &[1, 3]);
    }

    #[test]
    fn normalization_sees_the_overlay() {
        let (base, mut d) = setup();
        d.remove(&base, 0, 1);
        let b = Batch::new(vec![EdgeUpdate::insert(0, 1)]);
        let nb = normalize(&base, &d, &b).unwrap();
        assert_eq!(nb.inserts, 1, "edge deleted in overlay ⇒ insert is effective");
    }

    #[test]
    fn out_of_range_rejected() {
        let (base, d) = setup();
        let b = Batch::new(vec![EdgeUpdate::insert(0, 9)]);
        assert!(normalize(&base, &d, &b).is_err());
    }
}
