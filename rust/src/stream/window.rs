//! Sliding-window streaming: edges expire `W` batches after insertion.
//!
//! Social-graph traffic is recency-weighted — an interaction edge matters
//! for `W` ticks and then ages out. The window mode turns every insert
//! batch into a mixed workload automatically: when batch `t` arrives, the
//! edges batch `t − W` *effectively* inserted are prepended as deletions.
//! Expiry deletes may still be no-ops by then (the edge was deleted
//! mid-window) — normalization absorbs that. The TTL rule stays simple:
//! an edge's age runs from the batch that effectively inserted it, and
//! re-inserting a live edge refreshes nothing.
//!
//! Two forms:
//! * [`expand`] — offline: transform a whole insert-batch sequence into a
//!   windowed mixed sequence, runnable through the parallel driver;
//! * [`SlidingWindow`] — online: wrap a [`StreamState`] and push one batch
//!   at a time.

use std::collections::{HashMap, HashSet, VecDeque};

use crate::error::Result;
use crate::graph::csr::Csr;
use crate::stream::batch::{edge_key, Batch, EdgeUpdate};
use crate::stream::compact::CompactionPolicy;
use crate::stream::state::{BatchOutcome, StreamState};
use crate::VertexId;

/// Offline transform: batch `t` gains, as leading deletions, the edges
/// batch `t − window` **effectively** inserted. Effectiveness is decided
/// by replaying presence against `base` (an insert of an already-present
/// edge owns nothing and must not schedule an expiry — same rule as
/// [`SlidingWindow`], so offline and online runs produce the same graph).
pub fn expand(base: &Csr, batches: &[Batch], window: usize) -> Vec<Batch> {
    assert!(window > 0, "window of 0 batches would expire edges instantly");
    // Edges whose current presence differs from `base` (exact replay).
    let mut toggled: HashSet<u64> = HashSet::new();
    let present = |toggled: &HashSet<u64>, u: VertexId, v: VertexId| {
        base.has_edge(u, v) ^ toggled.contains(&edge_key(u, v))
    };
    let mut live: VecDeque<Vec<(VertexId, VertexId)>> = VecDeque::with_capacity(window);
    let mut out = Vec::with_capacity(batches.len());
    for b in batches {
        let mut updates: Vec<EdgeUpdate> = Vec::with_capacity(b.len() * 2);
        if live.len() == window {
            updates.extend(
                live.pop_front()
                    .expect("window queue non-empty")
                    .into_iter()
                    .map(|(u, v)| EdgeUpdate::delete(u, v)),
            );
        }
        updates.extend_from_slice(&b.updates);
        // Net effect per edge (later ops win), mirroring batch::normalize.
        let mut net: HashMap<u64, (bool, bool)> = HashMap::with_capacity(updates.len());
        for up in &updates {
            if up.u == up.v {
                continue;
            }
            let e = net.entry(edge_key(up.u, up.v)).or_insert_with(|| {
                let p = present(&toggled, up.u, up.v);
                (p, p)
            });
            e.1 = up.insert;
        }
        let mut eff_inserts: Vec<(VertexId, VertexId)> = Vec::new();
        for (key, (was, now)) in net {
            if was != now {
                if !toggled.remove(&key) {
                    toggled.insert(key);
                }
                if now {
                    eff_inserts.push(((key >> 32) as VertexId, key as VertexId));
                }
            }
        }
        eff_inserts.sort_unstable();
        live.push_back(eff_inserts);
        out.push(Batch::new(updates));
    }
    out
}

/// Online sliding-window engine (see module docs).
pub struct SlidingWindow {
    state: StreamState,
    window: usize,
    /// Effective inserts of the last `window` batches, oldest first.
    live: VecDeque<Vec<(VertexId, VertexId)>>,
}

impl SlidingWindow {
    pub fn new(base: Csr, window: usize, policy: CompactionPolicy) -> Self {
        assert!(window > 0);
        SlidingWindow {
            state: StreamState::with_policy(base, policy),
            window,
            live: VecDeque::with_capacity(window),
        }
    }

    /// The wrapped engine (count, recount, snapshot…).
    pub fn state(&self) -> &StreamState {
        &self.state
    }

    /// Apply one batch; edges effectively inserted `window` pushes ago are
    /// expired first (within the same atomic batch).
    pub fn push(&mut self, batch: &Batch) -> Result<BatchOutcome> {
        let mut updates: Vec<EdgeUpdate> = Vec::with_capacity(batch.len() * 2);
        if self.live.len() == self.window {
            updates.extend(
                self.live
                    .pop_front()
                    .expect("window queue non-empty")
                    .into_iter()
                    .map(|(u, v)| EdgeUpdate::delete(u, v)),
            );
        }
        updates.extend_from_slice(&batch.updates);
        let out = self.state.apply_batch(&Batch::new(updates))?;
        // Track what this batch *effectively* inserted — those are the
        // edges that will expire.
        self.live.push_back(
            out.normalized
                .ops
                .iter()
                .filter(|o| o.insert)
                .map(|o| (o.u, o.v))
                .collect(),
        );
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::classic;

    fn insert_batch(edges: &[(u32, u32)]) -> Batch {
        Batch::new(edges.iter().map(|&(u, v)| EdgeUpdate::insert(u, v)).collect())
    }

    #[test]
    fn edges_expire_after_window() {
        // Empty base; stream the three edges of a triangle in separate
        // batches with W=2: the first edge expires before the third
        // arrives, so the triangle never closes.
        let base = Csr::empty(3);
        let mut w = SlidingWindow::new(base, 2, CompactionPolicy::never());
        w.push(&insert_batch(&[(0, 1)])).unwrap();
        w.push(&insert_batch(&[(1, 2)])).unwrap();
        let out = w.push(&insert_batch(&[(0, 2)])).unwrap();
        assert_eq!(out.triangles, 0, "edge 0–1 must have expired");
        assert_eq!(w.state().current_edges(), 2);
        assert_eq!(w.state().recount().unwrap(), 0);
    }

    #[test]
    fn window_large_enough_closes_triangles() {
        let base = Csr::empty(3);
        let mut w = SlidingWindow::new(base, 3, CompactionPolicy::never());
        w.push(&insert_batch(&[(0, 1)])).unwrap();
        w.push(&insert_batch(&[(1, 2)])).unwrap();
        let out = w.push(&insert_batch(&[(0, 2)])).unwrap();
        assert_eq!(out.triangles, 1);
        // Next push expires 0–1: the triangle opens again.
        let out = w.push(&Batch::default()).unwrap();
        assert_eq!(out.triangles, 0);
        assert_eq!(out.deletes, 1);
    }

    #[test]
    fn expand_matches_online_engine() {
        let base = classic::karate();
        let batches = vec![
            insert_batch(&[(9, 10), (14, 16)]),
            insert_batch(&[(9, 11)]),
            insert_batch(&[(9, 12), (20, 24)]),
            insert_batch(&[(10, 11)]),
            insert_batch(&[(9, 13)]),
        ];
        let expanded = expand(&base, &batches, 2);
        let mut offline = StreamState::with_policy(base.clone(), CompactionPolicy::never());
        for b in &expanded {
            offline.apply_batch(b).unwrap();
        }
        let mut online = SlidingWindow::new(base, 2, CompactionPolicy::never());
        let mut last = 0;
        for b in &batches {
            last = online.push(b).unwrap().triangles;
        }
        assert_eq!(offline.triangles(), last);
        assert_eq!(offline.triangles(), offline.recount().unwrap());
    }

    #[test]
    fn expand_never_expires_base_edges_on_noop_inserts() {
        // Inserting an edge the base already has must not schedule an
        // expiry delete for it (regression: raw-insert expiry would tear
        // edge 0–1 out of the base graph).
        let base = crate::graph::builder::from_edges(3, [(0, 1)]).unwrap();
        let batches = vec![insert_batch(&[(0, 1)]), Batch::default(), Batch::default()];
        let expanded = expand(&base, &batches, 1);
        assert!(
            expanded.iter().flat_map(|b| &b.updates).all(|u| u.insert),
            "no deletes may be emitted: {expanded:?}"
        );
        // And the online engine agrees: the base edge survives.
        let mut sw = SlidingWindow::new(base, 1, CompactionPolicy::never());
        for b in &batches {
            sw.push(b).unwrap();
        }
        assert_eq!(sw.state().current_edges(), 1);
    }

    #[test]
    fn mid_window_delete_makes_expiry_a_noop() {
        let base = Csr::empty(4);
        let mut w = SlidingWindow::new(base, 3, CompactionPolicy::never());
        w.push(&insert_batch(&[(0, 1)])).unwrap();
        // Delete it explicitly before it expires.
        w.push(&Batch::new(vec![EdgeUpdate::delete(0, 1)])).unwrap();
        w.push(&Batch::default()).unwrap();
        let out = w.push(&Batch::default()).unwrap(); // expiry tick: no-op
        assert_eq!(out.deletes, 0);
        assert_eq!(w.state().current_edges(), 0);
    }
}
