//! Exact per-batch triangle-count delta — the streaming hot kernel.
//!
//! For a single edge toggle on `{u, v}` in graph `H`, the count changes by
//! `±|N_H(u) ∩ N_H(v)|`: exactly the triangles through the edge, and the
//! intersection is unaffected by the presence of `{u, v}` itself (no self
//! loops ⇒ `u ∉ N(u)`). Chaining over a normalized batch's canonical op
//! order `0..k`,
//!
//! ```text
//! T(G_final) − T(G₀) = Σ_i  s_i · |N_i(u_i) ∩ N_i(v_i)|
//! ```
//!
//! where `N_i` is adjacency in the state with effective ops `< i` applied
//! and `s_i = ±1`. Each term is evaluated **without materializing the
//! intermediate states**: intersect the pre-batch snapshot views (the
//! [`crate::intersect`] kernels over [`AdjDelta::current_nbrs`] merges),
//! then correct for the few candidates `w` whose edges `{u, w}` / `{v, w}`
//! are themselves toggled by an earlier op of the same batch. Corrections
//! touch only batch-incident endpoints, so op `i` costs
//! `O(d_u + d_v + b_{u,v} log b)` — independent of the op's position, which
//! is what makes the batch shardable across ranks with no coordination.

use std::collections::HashMap;

use crate::adj::bitmap::BitmapRow;
use crate::adj::hub::HubThreshold;
use crate::adj::{self, NeighborView};
use crate::graph::csr::Csr;
use crate::stream::batch::NormalizedBatch;
use crate::stream::overlay::AdjDelta;
use crate::VertexId;

/// Reusable buffers for the merged neighbor views, plus a per-batch cache
/// of hub bitmap rows.
///
/// All ops of a batch intersect the *same* pre-batch snapshot
/// (`base` ∪ overlay; corrections handle intra-batch effects), so a hub
/// endpoint touched by many ops pays the bitmap build once and every
/// later op on it gets the probe/word-AND kernels. [`Scratch::begin_batch`]
/// clears the cache and re-resolves the threshold; callers that never arm
/// it (`threshold = None`, the default) get pure sorted-merge behavior.
#[derive(Default)]
pub struct Scratch {
    nu: Vec<VertexId>,
    nv: Vec<VertexId>,
    /// Snapshot hub rows keyed by vertex, valid for the current batch only.
    rows: HashMap<VertexId, BitmapRow>,
    /// Resolved hub cutoff for the current batch (`None` = bitmaps off).
    threshold: Option<usize>,
}

impl Scratch {
    /// Arm the hub-bitmap cache for a new batch against the pre-batch
    /// snapshot `(base, overlay)`: drop stale rows, re-resolve `policy`
    /// against the *current* density (merged rows hold both edge
    /// directions, hence `2m`). `HubThreshold::Off` disables the cache for
    /// the batch (the seed's pure sorted-merge behavior).
    pub fn begin_batch(&mut self, base: &Csr, overlay: &AdjDelta, policy: HubThreshold) {
        self.rows.clear();
        self.threshold =
            policy.resolve(base.num_nodes(), 2 * overlay.current_edge_count(base));
    }
}

/// Outcome of counting one effective op.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OpDelta {
    /// Signed triangle-count change contributed by this op.
    pub delta: i64,
    /// Element steps charged by the hybrid dispatch
    /// ([`adj::intersect_cost`]: merge `|N_u| + |N_v|`, probe `min`, or
    /// word-AND span) — feeds rank metrics and the streaming simulator.
    pub work: u64,
}

/// Evaluate effective op `i` of the batch against the pre-batch snapshot.
pub fn count_op(
    base: &Csr,
    overlay: &AdjDelta,
    nb: &NormalizedBatch,
    i: usize,
    scratch: &mut Scratch,
) -> OpDelta {
    let op = nb.ops[i];
    let (u, v) = (op.u, op.v);
    let Scratch { nu, nv, rows, threshold } = scratch;
    overlay.current_nbrs(base, u, nu);
    overlay.current_nbrs(base, v, nv);
    if let Some(t) = *threshold {
        // Hub endpoints: build (or reuse) snapshot bitmap rows.
        if nu.len() >= t {
            rows.entry(u).or_insert_with(|| BitmapRow::from_sorted(nu));
        }
        if nv.len() >= t {
            rows.entry(v).or_insert_with(|| BitmapRow::from_sorted(nv));
        }
    }
    let vu = NeighborView::hybrid(nu, rows.get(&u));
    let vv = NeighborView::hybrid(nv, rows.get(&v));

    // |N₀(u) ∩ N₀(v)| on the snapshot, through the hybrid dispatch.
    let mut snapshot = 0u64;
    adj::intersect_count(vu, vv, &mut snapshot);
    let work = adj::intersect_cost(vu, vv);
    let mut count = snapshot as i64;

    // Correct to state i: only endpoints the batch touches at u or v can
    // differ from the snapshot. Both `touched` lists are sorted — merge.
    let (tu, tv) = (nb.touched(u), nb.touched(v));
    let (mut a, mut b) = (0usize, 0usize);
    while a < tu.len() || b < tv.len() {
        let w = match (tu.get(a), tv.get(b)) {
            (Some(&x), Some(&y)) => {
                let w = x.min(y);
                a += (x == w) as usize;
                b += (y == w) as usize;
                w
            }
            (Some(&x), None) => {
                a += 1;
                x
            }
            (None, Some(&y)) => {
                b += 1;
                y
            }
            (None, None) => unreachable!(),
        };
        if w == u || w == v {
            continue; // the op's own edge, not a wedge candidate
        }
        let p0u = nu.binary_search(&w).is_ok();
        let p0v = nv.binary_search(&w).is_ok();
        // An effective op always flips presence relative to the snapshot,
        // so "applied before i" ⇔ presence at state i is the negation.
        let piu = p0u ^ nb.op_index(u, w).is_some_and(|j| j < i);
        let piv = p0v ^ nb.op_index(v, w).is_some_and(|j| j < i);
        count += (piu && piv) as i64 - (p0u && p0v) as i64;
    }

    let sign = if op.insert { 1 } else { -1 };
    OpDelta { delta: sign * count, work }
}

/// Sum [`count_op`] over every effective op — the sequential batch kernel
/// (hub cache armed with the default `auto` policy; drivers that expose
/// `--hub-threshold` arm their own [`Scratch`]).
/// Returns `(Δ triangles, work units)`.
pub fn count_batch(base: &Csr, overlay: &AdjDelta, nb: &NormalizedBatch) -> (i64, u64) {
    let mut scratch = Scratch::default();
    scratch.begin_batch(base, overlay, HubThreshold::Auto);
    let mut delta = 0i64;
    let mut work = 0u64;
    for i in 0..nb.ops.len() {
        let r = count_op(base, overlay, nb, i, &mut scratch);
        delta += r.delta;
        work += r.work;
    }
    (delta, work)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_edges;
    use crate::graph::classic;
    use crate::graph::ordering::Oriented;
    use crate::seq::node_iterator;
    use crate::stream::batch::{normalize, Batch, EdgeUpdate};

    /// Oracle: apply the batch to a copy, recount from scratch.
    fn oracle_delta(base: &Csr, overlay: &AdjDelta, nb: &NormalizedBatch) -> i64 {
        let before = recount(base, overlay);
        let mut after = overlay.clone();
        for op in &nb.ops {
            let changed = if op.insert {
                after.insert(base, op.u, op.v)
            } else {
                after.remove(base, op.u, op.v)
            };
            assert!(changed, "effective op {op:?} must change presence");
        }
        recount(base, &after) as i64 - before as i64
    }

    fn recount(base: &Csr, overlay: &AdjDelta) -> u64 {
        let g = from_edges(base.num_nodes(), overlay.current_edges(base)).unwrap();
        node_iterator::count(&Oriented::from_graph(&g))
    }

    #[test]
    fn single_insert_closes_triangles() {
        // Path 1-0-2 plus edge (1,2) closes one triangle.
        let base = from_edges(3, [(0, 1), (0, 2)]).unwrap();
        let overlay = AdjDelta::new(3);
        let b = Batch::new(vec![EdgeUpdate::insert(1, 2)]);
        let nb = normalize(&base, &overlay, &b).unwrap();
        let (d, _) = count_batch(&base, &overlay, &nb);
        assert_eq!(d, 1);
    }

    #[test]
    fn single_delete_opens_triangles() {
        let base = classic::complete(4); // 4 triangles, each edge in 2
        let overlay = AdjDelta::new(4);
        let b = Batch::new(vec![EdgeUpdate::delete(0, 3)]);
        let nb = normalize(&base, &overlay, &b).unwrap();
        let (d, _) = count_batch(&base, &overlay, &nb);
        assert_eq!(d, -2);
    }

    #[test]
    fn batch_building_a_triangle_from_nothing() {
        // All three edges of a triangle in one batch: the corrections must
        // see the earlier inserts or the triangle is missed.
        let base = Csr::empty(3);
        let overlay = AdjDelta::new(3);
        let b = Batch::new(vec![
            EdgeUpdate::insert(0, 1),
            EdgeUpdate::insert(1, 2),
            EdgeUpdate::insert(0, 2),
        ]);
        let nb = normalize(&base, &overlay, &b).unwrap();
        let (d, _) = count_batch(&base, &overlay, &nb);
        assert_eq!(d, 1);
    }

    #[test]
    fn batch_destroying_a_triangle_entirely() {
        let base = from_edges(3, [(0, 1), (1, 2), (0, 2)]).unwrap();
        let overlay = AdjDelta::new(3);
        let b = Batch::new(vec![
            EdgeUpdate::delete(0, 1),
            EdgeUpdate::delete(1, 2),
            EdgeUpdate::delete(0, 2),
        ]);
        let nb = normalize(&base, &overlay, &b).unwrap();
        let (d, _) = count_batch(&base, &overlay, &nb);
        assert_eq!(d, -1);
    }

    #[test]
    fn mixed_batch_matches_oracle_on_karate() {
        let base = classic::karate();
        let mut overlay = AdjDelta::new(base.num_nodes());
        overlay.remove(&base, 0, 1);
        overlay.insert(&base, 9, 14);
        let b = Batch::new(vec![
            EdgeUpdate::insert(0, 1),
            EdgeUpdate::delete(33, 32),
            EdgeUpdate::insert(4, 12),
            EdgeUpdate::delete(2, 3),
            EdgeUpdate::insert(17, 20),
            EdgeUpdate::delete(9, 14),
        ]);
        let nb = normalize(&base, &overlay, &b).unwrap();
        let (d, work) = count_batch(&base, &overlay, &nb);
        assert_eq!(d, oracle_delta(&base, &overlay, &nb));
        assert!(work > 0);
    }

    #[test]
    fn randomized_batches_match_oracle() {
        use crate::gen::rng::Rng;
        let mut rng = Rng::seeded(0x5EED);
        for case in 0..40 {
            let n = 6 + rng.below_usize(30);
            let m = rng.below_usize(n * 2 + 1);
            let base = crate::gen::erdos_renyi::gnm(n, m, &mut rng);
            let overlay = AdjDelta::new(n);
            let updates: Vec<EdgeUpdate> = (0..rng.below_usize(25) + 1)
                .map(|_| {
                    let u = rng.below(n as u64) as VertexId;
                    let v = rng.below(n as u64) as VertexId;
                    if rng.chance(0.5) {
                        EdgeUpdate::insert(u, v)
                    } else {
                        EdgeUpdate::delete(u, v)
                    }
                })
                .collect();
            let nb = normalize(&base, &overlay, &Batch::new(updates)).unwrap();
            let (d, _) = count_batch(&base, &overlay, &nb);
            assert_eq!(d, oracle_delta(&base, &overlay, &nb), "case {case}");
        }
    }
}
