//! `tricount` — CLI launcher for the triangle-counting framework.
//!
//! Subcommands:
//! * `count`    — count triangles on a workload with a chosen algorithm;
//! * `generate` — write a workload graph to disk (edge list / binary);
//! * `partition-stats` — per-partition memory accounting (ours vs PATRIC);
//! * `exp`      — run paper experiments (`--id table2|fig4|…|all`);
//! * `info`     — PJRT backend + artifact inventory.
//!
//! Dependency-free argument parsing (the container is offline); every flag
//! can also be set in a `--config run.toml` file.

use std::sync::Arc;

use tricount::algo::{direct, dynamic_lb, patric, surrogate};
use tricount::config::{Algorithm, CostFn, RunConfig};
use tricount::error::{Error, Result};
use tricount::exp;
use tricount::graph::ordering::Oriented;
use tricount::partition::balance::{balanced_ranges, owner_table};
use tricount::partition::cost::{cost_vector, prefix_sums};
use tricount::seq::node_iterator;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    match cmd.as_str() {
        "count" => cmd_count(&args[1..]),
        "analyze" => cmd_analyze(&args[1..]),
        "generate" => cmd_generate(&args[1..]),
        "partition-stats" => cmd_partition_stats(&args[1..]),
        "exp" => cmd_exp(&args[1..]),
        "info" => cmd_info(&args[1..]),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => Err(Error::Config(format!("unknown command `{other}` (try `tricount help`)"))),
    }
}

fn print_help() {
    println!(
        "tricount — parallel triangle counting (Arifuzzaman et al. 2014 reproduction)

USAGE: tricount <command> [--key value]...

COMMANDS:
  count             count triangles
                    --workload SPEC  (karate | preset | pa:N:D | rmat:S:EF |
                                      contact:N:D | file:PATH | bin:PATH)
                    --algorithm A    (seq|surrogate|direct|patric|dynamic-lb|hybrid)
                    --procs P --cost-fn F (unit|dv|patric|new) --scale X
                    --dense-core K --artifacts-dir DIR --config FILE
  generate          build a workload and write it
                    --workload SPEC --out PATH [--format edges|bin]
  analyze           triangle-based network analysis (clustering,
                    transitivity, trussness, MR-shuffle blow-up, approx
                    baselines) --workload SPEC --procs P
  partition-stats   memory accounting for both partition schemes
                    --workload SPEC --procs P
  exp               paper experiments
                    --id ID|all [--list] [--quick] [--scale X] [--out DIR]
  info              PJRT platform + discovered artifacts"
    );
}

/// Parse `--key value` pairs into a RunConfig (after optional `--config`).
fn parse_config(args: &[String]) -> Result<(RunConfig, std::collections::BTreeMap<String, String>)> {
    let mut extra = std::collections::BTreeMap::new();
    let mut cfg = RunConfig::default();
    // First pass: --config file.
    let mut i = 0;
    while i + 1 < args.len() {
        if args[i] == "--config" {
            cfg = RunConfig::from_file(&args[i + 1])?;
        }
        i += 2;
    }
    // Second pass: overrides.
    let mut i = 0;
    while i < args.len() {
        let key = args[i]
            .strip_prefix("--")
            .ok_or_else(|| Error::Config(format!("expected --flag, got `{}`", args[i])))?;
        let value = args
            .get(i + 1)
            .ok_or_else(|| Error::Config(format!("--{key} needs a value")))?;
        if key != "config" {
            if cfg.set(key, value).is_err() {
                extra.insert(key.to_string(), value.clone());
            }
        }
        i += 2;
    }
    Ok((cfg, extra))
}

fn cmd_count(args: &[String]) -> Result<()> {
    let (cfg, extra) = parse_config(args)?;
    reject_unknown(&extra, &[])?;
    let t0 = std::time::Instant::now();
    let g = cfg.build_graph()?;
    let gen_time = t0.elapsed();
    let t0 = std::time::Instant::now();
    let o = Arc::new(Oriented::from_graph(&g));
    let orient_time = t0.elapsed();
    println!(
        "workload={} n={} m={} d̄={:.1} (gen {:.2?}, orient {:.2?})",
        cfg.workload,
        g.num_nodes(),
        g.num_edges(),
        g.avg_degree(),
        gen_time,
        orient_time
    );

    let t0 = std::time::Instant::now();
    let (triangles, detail) = match cfg.algorithm {
        Algorithm::Sequential => (node_iterator::count(&o), String::new()),
        Algorithm::Surrogate | Algorithm::Direct => {
            let prefix = prefix_sums(&cost_vector(&o, cfg.cost_fn));
            let ranges = balanced_ranges(&prefix, cfg.procs);
            let owner = Arc::new(owner_table(&ranges, o.num_nodes()));
            let r = if cfg.algorithm == Algorithm::Surrogate {
                surrogate::run(&o, &ranges, &owner)?
            } else {
                direct::run(&o, &ranges, &owner)?
            };
            let t = r.metrics.totals();
            (
                r.triangles,
                format!(
                    "msgs={} bytes={} imbalance={:.3}",
                    t.messages_sent,
                    t.bytes_sent,
                    r.metrics.imbalance()
                ),
            )
        }
        Algorithm::Patric => {
            let prefix = prefix_sums(&cost_vector(&o, CostFn::PatricBest));
            let ranges = balanced_ranges(&prefix, cfg.procs);
            let r = patric::run(&o, &ranges)?;
            (r.triangles, format!("imbalance={:.3}", r.metrics.imbalance()))
        }
        Algorithm::DynamicLb => {
            let r = dynamic_lb::run(
                &o,
                cfg.procs.max(2),
                dynamic_lb::Options {
                    cost_fn: cfg.cost_fn,
                    granularity: dynamic_lb::Granularity::Shrinking,
                },
            )?;
            (r.triangles, format!("imbalance={:.3}", r.metrics.imbalance()))
        }
        Algorithm::Hybrid => {
            let engine = tricount::runtime::engine::Engine::cpu()?;
            let r = tricount::tensor::hybrid::count_with_engine(
                &o,
                &engine,
                &cfg.artifacts_dir,
                cfg.dense_core,
            )?;
            (
                r.triangles,
                format!(
                    "dense={} sparse={} core={} block={} offloaded_edges={}",
                    r.dense_triangles, r.sparse_triangles, r.core_size, r.block, r.offloaded_edges
                ),
            )
        }
    };
    println!(
        "triangles={} algorithm={:?} procs={} time={:.3?} {detail}",
        triangles,
        cfg.algorithm,
        cfg.procs,
        t0.elapsed()
    );
    Ok(())
}

fn cmd_analyze(args: &[String]) -> Result<()> {
    let (cfg, extra) = parse_config(args)?;
    reject_unknown(&extra, &[])?;
    let g = cfg.build_graph()?;
    let o = Arc::new(Oriented::from_graph(&g));
    let stats = tricount::graph::stats::degree_stats(&g);
    println!("{stats}");

    // Per-node counts through the §V dynamic load balancer.
    let t0 = std::time::Instant::now();
    let tv = tricount::algo::local_counts::per_node_counts(&o, cfg.procs.max(2))?;
    let total: u64 = tv.iter().sum::<u64>() / 3;
    println!(
        "triangles            = {total}  (parallel per-node counts, P={}, {:.2?})",
        cfg.procs.max(2),
        t0.elapsed()
    );
    println!(
        "avg clustering coeff = {:.5}",
        tricount::seq::local::avg_clustering(&g, &tv)
    );
    println!(
        "transitivity         = {:.5}",
        tricount::seq::local::transitivity(&g, total)
    );

    // MapReduce baseline shuffle volume (the paper's §I motivation).
    let mr = tricount::baseline::mapreduce::shuffle_stats(&g);
    println!(
        "MR 2-path shuffle    = {} wedges ({:.1}x the edge set; ordered emit {}, max reducer {})",
        mr.wedges_all,
        tricount::baseline::mapreduce::blowup_factor(&g),
        mr.wedges_ordered,
        mr.max_reducer_records
    );

    // Approximation baselines vs the exact count.
    let mut rng = tricount::gen::rng::Rng::seeded(cfg.seed);
    let doulion = tricount::approx::doulion(&g, 0.3, &mut rng);
    let wedge = tricount::approx::wedge_sampling(&g, 100_000, &mut rng);
    println!(
        "approx: DOULION(p=.3) = {:.0} ({:+.1}%), wedge-sampling = {:.0} ({:+.1}%)",
        doulion,
        100.0 * (doulion / total as f64 - 1.0),
        wedge,
        100.0 * (wedge / total as f64 - 1.0)
    );

    // Truss decomposition for small graphs (O(m^1.5) peeling).
    if g.num_edges() <= 2_000_000 {
        let kmax = tricount::seq::truss::max_truss(&g);
        println!("max k-truss          = {kmax}");
    } else {
        println!("max k-truss          = (skipped: m > 2M)");
    }
    Ok(())
}

fn cmd_generate(args: &[String]) -> Result<()> {
    let (cfg, extra) = parse_config(args)?;
    let out = extra
        .get("out")
        .ok_or_else(|| Error::Config("generate needs --out PATH".into()))?;
    let format = extra.get("format").map(String::as_str).unwrap_or("edges");
    reject_unknown(&extra, &["out", "format"])?;
    let g = cfg.build_graph()?;
    match format {
        "edges" => tricount::graph::io::write_edge_list(&g, out)?,
        "bin" => tricount::graph::io::write_binary(&g, out)?,
        other => return Err(Error::Config(format!("unknown format `{other}`"))),
    }
    println!("wrote {} (n={}, m={})", out, g.num_nodes(), g.num_edges());
    Ok(())
}

fn cmd_partition_stats(args: &[String]) -> Result<()> {
    let (cfg, extra) = parse_config(args)?;
    reject_unknown(&extra, &[])?;
    let g = cfg.build_graph()?;
    let o = Oriented::from_graph(&g);
    let ours = balanced_ranges(&prefix_sums(&cost_vector(&o, CostFn::SurrogateNew)), cfg.procs);
    let patric = balanced_ranges(&prefix_sums(&cost_vector(&o, CostFn::PatricBest)), cfg.procs);
    let non = tricount::partition::nonoverlap::partition_sizes(&o, &ours);
    let over = tricount::partition::overlap::overlap_sizes(&g, &o, &patric);
    let max_non = non.iter().map(|s| s.mb()).fold(0.0f64, f64::max);
    let max_over = over.iter().map(|s| s.mb()).fold(0.0f64, f64::max);
    let sum_non: u64 = non.iter().map(|s| s.edges).sum();
    let sum_over: u64 = over.iter().map(|s| s.edges).sum();
    println!("P={} n={} m={}", cfg.procs, g.num_nodes(), g.num_edges());
    println!("non-overlapping (ours): largest {max_non:.2} MB, total edges stored {sum_non}");
    println!("overlapping (PATRIC):   largest {max_over:.2} MB, total edges stored {sum_over}");
    println!("ratio (largest): {:.2}x", max_over / max_non.max(1e-12));
    Ok(())
}

fn cmd_exp(args: &[String]) -> Result<()> {
    let mut opts = exp::Options::default();
    let mut id = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--list" => {
                for e in exp::registry() {
                    println!("{:8} {:10} {}", e.id, e.paper_ref, e.description);
                }
                return Ok(());
            }
            "--quick" => {
                opts.quick = true;
                i += 1;
            }
            "--id" => {
                id = Some(args.get(i + 1).cloned().ok_or_else(|| Error::Config("--id needs a value".into()))?);
                i += 2;
            }
            "--scale" => {
                opts.scale = args
                    .get(i + 1)
                    .and_then(|s| s.parse().ok())
                    .ok_or_else(|| Error::Config("--scale needs a number".into()))?;
                i += 2;
            }
            "--out" => {
                opts.out_dir = Some(
                    args.get(i + 1).cloned().ok_or_else(|| Error::Config("--out needs a dir".into()))?,
                );
                i += 2;
            }
            other => return Err(Error::Config(format!("unknown exp flag `{other}`"))),
        }
    }
    let id = id.ok_or_else(|| Error::Config("exp needs --id <id|all> (or --list)".into()))?;
    exp::run_by_id(&id, &opts)
}

fn cmd_info(args: &[String]) -> Result<()> {
    let (cfg, _extra) = parse_config(args)?;
    let engine = tricount::runtime::engine::Engine::cpu()?;
    println!("PJRT platform: {}", engine.platform());
    let arts = tricount::runtime::artifact::discover(&cfg.artifacts_dir)?;
    if arts.is_empty() {
        println!("artifacts: none in `{}` (run `make artifacts`)", cfg.artifacts_dir);
    } else {
        for a in arts {
            println!("artifact: {} (N={})", a.path.display(), a.n);
        }
    }
    Ok(())
}

fn reject_unknown(
    extra: &std::collections::BTreeMap<String, String>,
    allowed: &[&str],
) -> Result<()> {
    for k in extra.keys() {
        if !allowed.contains(&k.as_str()) {
            return Err(Error::Config(format!("unknown flag `--{k}`")));
        }
    }
    Ok(())
}
