//! `tricount` — CLI launcher for the triangle-counting framework.
//!
//! Subcommands:
//! * `count`    — count triangles on a workload with a chosen algorithm;
//! * `stream`   — incremental counting over batched edge updates;
//! * `generate` — write a workload graph to disk (edge list / binary);
//! * `convert`  — encode any workload as a zero-parse `.tcg` binary;
//! * `partition-stats` — per-partition memory accounting (ours vs PATRIC);
//! * `exp`      — run paper experiments (`--id table2|fig4|…|all`);
//! * `info`     — PJRT backend + artifact inventory.
//!
//! Dependency-free argument parsing (the container is offline); every flag
//! can also be set in a `--config run.toml` file.

use std::sync::Arc;

use tricount::algo::{direct, dynamic_lb, patric, surrogate};
use tricount::config::{Algorithm, CostFn, FabricKind, RunConfig};
use tricount::error::{Error, Result};
use tricount::exp;
use tricount::graph::ordering::Oriented;
use tricount::partition::balance::balanced_ranges;
use tricount::partition::cost::{cost_vector, prefix_sums};
use tricount::seq::node_iterator;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    match cmd.as_str() {
        "count" => cmd_count(&args[1..]),
        "launch" => cmd_launch(&args[1..]),
        "worker" => cmd_worker(&args[1..]),
        "stream" => cmd_stream(&args[1..]),
        "analyze" => cmd_analyze(&args[1..]),
        "generate" => cmd_generate(&args[1..]),
        "convert" => cmd_convert(&args[1..]),
        "partition-stats" => cmd_partition_stats(&args[1..]),
        "bench-pipeline" => cmd_bench_pipeline(&args[1..]),
        "bench-recovery" => cmd_bench_recovery(&args[1..]),
        "bench-comm" => cmd_bench_comm(&args[1..]),
        "conformance" => cmd_conformance(&args[1..]),
        "obs-report" => cmd_obs_report(&args[1..]),
        "exp" => cmd_exp(&args[1..]),
        "info" => cmd_info(&args[1..]),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => Err(Error::Config(format!("unknown command `{other}` (try `tricount help`)"))),
    }
}

fn print_help() {
    println!(
        "tricount — parallel triangle counting (Arifuzzaman et al. 2014 reproduction)

USAGE: tricount <command> [--key value]...

COMMANDS:
  count             count triangles
                    --workload SPEC  (karate | preset | pa:N:D | rmat:S:EF |
                                      er:N:D | contact:N:D | file:PATH |
                                      tcg:PATH | bin:PATH)
                    --format text|tcg (reinterpret a file-backed workload:
                    text = edge-list parse, tcg = zero-parse binary load;
                    see `tricount convert`)
                    --algorithm A    (seq|surrogate|direct|patric|dynamic-lb|
                                      tile2d|hybrid)
                    --procs P --cost-fn F (unit|dv|patric|new|hybrid) --scale X
                    --mem-budget B   (bytes, kb/mb/gb suffixes; surrogate|
                    direct|tile2d: searches BOTH the 1D range layout and the
                    2D tile layout, overrides --procs with the smallest P
                    whose largest partition/tile fits B, reports both
                    candidates and switches the algorithm to the winning
                    layout — partitioned runs report measured per-rank
                    partition bytes and fail on any divergence from the
                    prediction)
                    --hub-threshold T (n|auto|off: bitmap rows for d̂ ≥ T)
                    --build-threads T (n|auto: preprocessing threads — CSR
                    build, relabel, orientation, hub packing; output is
                    bit-identical at every T)
                    --dense-core K --artifacts-dir DIR --config FILE
                    --out DIR (write count.{{csv,json}} incl. representation
                    stats: hub count, bitmap bytes, kernel-path hits)
                    --on-fault fail|recover|degrade (what a supervised run
                    does when a rank dies: fail propagates, recover
                    re-executes the un-acked remainder on survivors for
                    the exact count, degrade answers from checkpoints
                    with a lower ≤ T ≤ upper confidence bound)
                    --fault kill:R:O (inject: kill rank R at its O-th
                    transport op on the seeded virtual fabric — the run
                    replays deterministically; prints the trace hash)
                    --fabric threads|tcp (threads = in-process ranks over
                    channels, the default; tcp = one OS process per rank
                    over loopback sockets — delegates to `launch`)
  launch            run a multi-process count over loopback TCP: spawns
                    P−1 `worker` processes of this binary, runs rank 0
                    in-process, reaps every child (DESIGN.md §15)
                    tricount launch --procs P [--bind IP:PORT]
                      [--job-id J] -- count <count flags>
  worker            join one rank of a TCP cluster by hand (two-terminal
                    loopback runs, remote hosts; see README)
                    tricount worker --connect IP:PORT --rank R --procs P
                      [--job-id J] [--join-timeout-ms N]
                      -- count <count flags> | conformance-cell
                         --path NAME --workload SPEC
  stream            incremental counting over batched edge updates
                    --workload SPEC --procs P --batch-size N --batches B
                    --window W (0 = no expiry) --delete-frac F --base-frac F
                    --compact-every C --hub-threshold T --out DIR
                    --verify on|off --format text|tcg
  generate          build a workload and write it
                    --workload SPEC --out PATH [--format edges|bin|tcg]
  convert           encode a workload as a zero-parse `.tcg` binary
                    (versioned header + bulk u32 CSR payload + FNV-1a
                    integrity footer; round-trip verified before exit)
                    --workload SPEC --out PATH.tcg
  analyze           triangle-based network analysis (clustering,
                    transitivity, trussness, MR-shuffle blow-up, approx
                    baselines) --workload SPEC --procs P
  partition-stats   memory accounting for both partition schemes
                    --workload SPEC --procs P
  bench-pipeline    time the preprocessing pipeline (parse → radix CSR
                    build → degree relabel → orientation + hub index)
                    serially and at each thread count, verifying the
                    parallel output is bit-identical to serial; also times
                    the chunk-parallel text parse and the zero-parse
                    `.tcg` reload of every workload
                    --workloads S1,S2,…  --threads T1,T2,… (n|auto)
                    --reps N --seed S --hub-threshold T
                    --format text|tcg (for file-backed workload specs)
                    --out PATH (default BENCH_pipeline.json)
  bench-recovery    measure rank-death recovery: latency and re-executed
                    work fraction vs kill position (first / middle / last
                    transport op of the victim) on the seeded virtual
                    fabric, each cell verified exact vs the fault-free run
                    --workload SPEC --procs P --algorithm A --seed S
                    --out PATH (default BENCH_recovery.json)
  bench-comm        per-rank communication volume for the four §IV-family
                    drivers (surrogate|direct|patric|tile2d) across a P
                    sweep; tile2d rows are gated within 1.1× of the
                    cost-model prediction (which replays the exact coalesced
                    frame plan), and on pa: workloads per-rank 2D bytes must
                    strictly fall with P and beat the best 1D driver
                    --workloads S1,S2,… --procs P1,P2,… --seed S
                    --out PATH (default BENCH_comm.json)
  conformance       adversarial-schedule conformance suite: every counting
                    path (surrogate|direct|patric|dynamic-lb|local-counts|
                    stream|tile2d) on the seeded virtual transport vs the
                    sequential oracle, each cell run twice (replay
                    determinism: identical trace hash), plus rank-death and
                    message-loss fault checks
                    --seeds N (schedules per config, default 16)
                    --procs P1,P2,…  --workloads S1,S2,…
                    --paths p1,p2,…  --faults on|off  --out DIR
                    --fabric sim|tcp (tcp: the same path × workload × P
                    grid with every cell as P OS processes over loopback
                    TCP — spawned from this binary and always reaped;
                    seeds/faults/trace-out apply to the sim fabric only)
  obs-report        validate and pretty-print an obs snapshot written by
                    `count --obs-out` / `stream --obs-out` (schema v1):
                    per-rank idle/imbalance breakdown, kernel mix, batches
                    tricount obs-report SNAPSHOT.json [--trace TRACE.json]
                    (--trace additionally validates a Perfetto trace file)
  exp               paper experiments
                    --id ID|all [--list] [--quick] [--scale X] [--out DIR]
  info              PJRT platform + discovered artifacts

OBSERVABILITY:
  count, stream     --trace-out FILE (Chrome/Perfetto trace: one track per
                    rank, spans for compute/send/recv-wait/barrier/reduce/
                    batch-apply) --obs-out FILE (versioned JSON metrics
                    snapshot; see `obs-report`)
  bench-pipeline    --trace-out FILE (stage timings as a timeline)
  conformance       --trace-out FILE (virtual-time timeline of a fixed
                    adversarial cell — byte-identical across runs)"
    );
}

/// Parse `--key value` pairs into a RunConfig (after optional `--config`).
fn parse_config(args: &[String]) -> Result<(RunConfig, std::collections::BTreeMap<String, String>)> {
    let mut extra = std::collections::BTreeMap::new();
    let mut cfg = RunConfig::default();
    // First pass: --config file.
    let mut i = 0;
    while i + 1 < args.len() {
        if args[i] == "--config" {
            cfg = RunConfig::from_file(&args[i + 1])?;
        }
        i += 2;
    }
    // Second pass: overrides.
    let mut i = 0;
    while i < args.len() {
        let key = args[i]
            .strip_prefix("--")
            .ok_or_else(|| Error::Config(format!("expected --flag, got `{}`", args[i])))?;
        let value = args
            .get(i + 1)
            .ok_or_else(|| Error::Config(format!("--{key} needs a value")))?;
        if key != "config" {
            if cfg.set(key, value).is_err() {
                extra.insert(key.to_string(), value.clone());
            }
        }
        i += 2;
    }
    // Install the preprocessing thread count process-wide: every
    // `from_edge_list` / `Oriented::from_graph_with` call this command
    // makes — including per-batch stream compaction — inherits it.
    tricount::par::set_default_threads(cfg.build_threads.resolve());
    Ok((cfg, extra))
}

fn cmd_count(args: &[String]) -> Result<()> {
    let (mut cfg, extra) = parse_config(args)?;
    reject_unknown(&extra, &["out", "trace-out", "obs-out", "format", "fault"])?;
    apply_format(&mut cfg, &extra)?;
    // `--fabric tcp`: one OS process per rank over loopback sockets —
    // delegate to the `launch` machinery with these same count flags.
    // Fault injection and the supervisor policies are in-process
    // machinery (virtual fabric, shared checkpoint store) and don't cross
    // the socket boundary.
    if cfg.fabric == FabricKind::Tcp {
        if extra.contains_key("fault") || cfg.on_fault != tricount::ft::FaultPolicy::Fail {
            return Err(Error::Config(
                "--fabric tcp does not support --fault/--on-fault (in-process machinery; \
                 rerun on the threads fabric)"
                    .into(),
            ));
        }
        return launch_processes(cfg.procs, None, None, args);
    }
    let t0 = std::time::Instant::now();
    let g = cfg.build_graph()?;
    let gen_time = t0.elapsed();
    let t0 = std::time::Instant::now();
    let o = Arc::new(Oriented::from_graph_with(&g, cfg.hub_threshold));
    let orient_time = t0.elapsed();
    let hubs = o.hub_stats();

    // `--mem-budget`: the Table II sizing question — pick the smallest P
    // whose largest (predicted == enforced) partition fits the budget.
    // The prefix sums are reused by the counting arm below.
    let mut balance_prefix: Option<Vec<u64>> = None;
    if let Some(budget) = cfg.mem_budget {
        use tricount::partition::nonoverlap::{
            min_procs_for_budget, min_procs_for_budget_layouts, Layout,
        };
        if !matches!(
            cfg.algorithm,
            Algorithm::Surrogate | Algorithm::Direct | Algorithm::Tile2d
        ) {
            return Err(Error::Config(
                "--mem-budget needs a partitioned algorithm (surrogate|direct|tile2d)".into(),
            ));
        }
        let prefix = prefix_sums(&cost_vector(&o, cfg.cost_fn));
        let max_p = o.num_nodes().max(1);
        let one_d = min_procs_for_budget(&o, &prefix, budget, max_p);
        let (p, layout) = min_procs_for_budget_layouts(&o, &prefix, budget, max_p)
            .ok_or_else(|| {
                Error::Config(format!(
                    "mem-budget {budget} B unsatisfiable under either layout even at P={max_p}"
                ))
            })?;
        match one_d {
            Some(q) => println!(
                "mem-budget: {budget} B → 1D candidate P={q}, 2D tiles searched up to it — winner: {layout} layout at P={p}"
            ),
            None => println!(
                "mem-budget: {budget} B → 1D unsatisfiable ≤ P={max_p} — winner: {layout} layout at P={p}"
            ),
        }
        cfg.procs = p;
        match layout {
            Layout::Tile2d => {
                if cfg.algorithm != Algorithm::Tile2d {
                    println!(
                        "mem-budget: switching algorithm {:?} → Tile2d (winning layout)",
                        cfg.algorithm
                    );
                    cfg.algorithm = Algorithm::Tile2d;
                }
            }
            Layout::OneD => {
                if cfg.algorithm == Algorithm::Tile2d {
                    println!("mem-budget: switching algorithm Tile2d → Surrogate (1D layout won)");
                    cfg.algorithm = Algorithm::Surrogate;
                }
                balance_prefix = Some(prefix);
            }
        }
    }
    println!(
        "workload={} n={} m={} d̄={:.1} (gen {:.2?}, orient {:.2?})",
        cfg.workload,
        g.num_nodes(),
        g.num_edges(),
        g.avg_degree(),
        gen_time,
        orient_time
    );
    println!(
        "adjacency: hub-threshold={} (resolved {}) hubs={} bitmap_bytes={}",
        cfg.hub_threshold,
        hubs.threshold.map_or("off".into(), |t| t.to_string()),
        hubs.hubs,
        hubs.bitmap_bytes
    );

    // Fault-tolerant execution (DESIGN.md §13): an injected `--fault` or a
    // non-`fail` `--on-fault` policy routes the run through the supervisor,
    // which installs the checkpoint store and recovers / degrades per
    // policy instead of letting a rank death abort the count.
    if extra.contains_key("fault") || cfg.on_fault != tricount::ft::FaultPolicy::Fail {
        return count_supervised(&cfg, &extra, &g, &o);
    }

    tricount::adj::stats::reset();
    let t0 = std::time::Instant::now();
    // Partitioned (§IV) runs leave their metrics here so the partition-
    // memory report and the measured==predicted gate below apply uniformly.
    let mut partitioned: Option<tricount::comm::metrics::ClusterMetrics> = None;
    // Every cluster-launching path also leaves its metrics here for the
    // obs/ per-rank breakdown and the trace/snapshot exports; the
    // single-process paths synthesize a one-rank timeline below.
    let mut cluster: Option<tricount::comm::metrics::ClusterMetrics> = None;
    let (triangles, detail) = match cfg.algorithm {
        Algorithm::Sequential => (node_iterator::count(&o), String::new()),
        Algorithm::Surrogate | Algorithm::Direct => {
            let prefix = balance_prefix
                .unwrap_or_else(|| prefix_sums(&cost_vector(&o, cfg.cost_fn)));
            let ranges = balanced_ranges(&prefix, cfg.procs);
            let r = if cfg.algorithm == Algorithm::Surrogate {
                surrogate::run(&o, &ranges, cfg.hub_threshold)?
            } else {
                direct::run(&o, &ranges, cfg.hub_threshold)?
            };
            let t = r.metrics.totals();
            let detail = format!(
                "msgs={} bytes={} imbalance={:.3}",
                t.messages_sent,
                t.bytes_sent,
                r.metrics.imbalance()
            );
            cluster = Some(r.metrics.clone());
            partitioned = Some(r.metrics);
            (r.triangles, detail)
        }
        Algorithm::Patric => {
            let prefix = prefix_sums(&cost_vector(&o, CostFn::PatricBest));
            let ranges = balanced_ranges(&prefix, cfg.procs);
            let r = patric::run(&g, &o, &ranges, cfg.hub_threshold)?;
            let detail = format!("imbalance={:.3}", r.metrics.imbalance());
            cluster = Some(r.metrics.clone());
            partitioned = Some(r.metrics);
            (r.triangles, detail)
        }
        Algorithm::Tile2d => {
            let r = tricount::algo::tile2d::run(&o, cfg.procs, cfg.hub_threshold)?;
            let t = r.metrics.totals();
            let detail = format!(
                "frames={} records={} bytes={} agg={:.1}x imbalance={:.3}",
                t.frames_sent,
                t.coalesced_sent,
                t.bytes_sent,
                r.metrics.aggregation_ratio(),
                r.metrics.imbalance()
            );
            cluster = Some(r.metrics.clone());
            partitioned = Some(r.metrics);
            (r.triangles, detail)
        }
        Algorithm::DynamicLb => {
            let r = dynamic_lb::run(
                &o,
                cfg.procs.max(2),
                dynamic_lb::Options {
                    cost_fn: cfg.cost_fn,
                    granularity: dynamic_lb::Granularity::Shrinking,
                },
            )?;
            let detail = format!("imbalance={:.3}", r.metrics.imbalance());
            cluster = Some(r.metrics);
            (r.triangles, detail)
        }
        Algorithm::Hybrid => {
            let engine = tricount::runtime::engine::Engine::cpu()?;
            let r = tricount::tensor::hybrid::count_with_engine(
                &o,
                &engine,
                &cfg.artifacts_dir,
                cfg.dense_core,
            )?;
            (
                r.triangles,
                format!(
                    "dense={} sparse={} core={} block={} offloaded_edges={}",
                    r.dense_triangles, r.sparse_triangles, r.core_size, r.block, r.offloaded_edges
                ),
            )
        }
    };
    let elapsed = t0.elapsed();
    let kernels = tricount::adj::stats::snapshot();
    println!(
        "triangles={} algorithm={:?} procs={} time={:.3?} {detail}",
        triangles, cfg.algorithm, cfg.procs, elapsed
    );
    println!(
        "kernels: list×list={} simd×blocked={} list×bitmap={} bitmap×bitmap={}",
        kernels.list_list, kernels.simd_blocked, kernels.list_bitmap, kernels.bitmap_bitmap
    );

    // Partitioned runs: per-rank partition residency, measured from the
    // OwnedPartition each rank actually held, against the scheme's
    // prediction — any divergence fails the run (CI gates on this).
    let (mem_max, mem_pred_max, accel_max) = match &partitioned {
        Some(m) => {
            println!(
                "partition memory: measured max={} B (total {} B), predicted max={} B, hub-accel max={} B",
                m.max_partition_bytes(),
                m.totals().partition_bytes,
                m.max_partition_bytes_pred(),
                m.max_accel_bytes()
            );
            if let Some(rank) = m.partition_accounting_divergence() {
                return Err(Error::Cluster(format!(
                    "MEM VERIFY FAILED: rank {rank} measured {} B != predicted {} B",
                    m.per_rank[rank].partition_bytes, m.per_rank[rank].partition_bytes_pred
                )));
            }
            println!("partition memory: measured == predicted on every rank");
            (m.max_partition_bytes(), m.max_partition_bytes_pred(), m.max_accel_bytes())
        }
        None => (0, 0, 0),
    };

    // Fig-13-style per-rank idle/imbalance breakdown from the obs/ span
    // timelines. Single-process paths (seq, hybrid) synthesize a one-rank
    // wall timeline covering the whole counting phase so every algorithm
    // produces a trace and a snapshot.
    let cluster = cluster.unwrap_or_else(|| {
        use tricount::obs::span::{ClockDomain, Span, SpanLog, SpanPhase};
        tricount::comm::metrics::ClusterMetrics {
            per_rank: vec![tricount::comm::metrics::CommMetrics {
                total: elapsed,
                kernel: kernels,
                spans: SpanLog {
                    domain: ClockDomain::Wall,
                    spans: vec![Span {
                        phase: SpanPhase::Compute,
                        t_start: 0,
                        t_end: elapsed.as_micros() as u64,
                    }],
                    dropped: 0,
                },
                ..Default::default()
            }],
        }
    });
    tricount::obs::report::print_breakdown(&cluster);
    if let Some(path) = extra.get("trace-out") {
        let json = tricount::obs::export::cluster_trace_json("tricount count", &cluster);
        std::fs::write(path, &json)?;
        println!("[written: {path} — load at ui.perfetto.dev or chrome://tracing]");
    }
    if let Some(path) = extra.get("obs-out") {
        let mut reg = tricount::obs::MetricsRegistry::new("count");
        reg.record_cluster(&cluster);
        reg.record_global_kernels(kernels);
        reg.note(&format!("workload={}", cfg.workload));
        reg.note(&format!("algorithm={:?}", cfg.algorithm));
        std::fs::write(path, reg.snapshot_json())?;
        println!("[written: {path} — inspect with `tricount obs-report {path}`]");
    }

    if let Some(dir) = extra.get("out") {
        std::fs::create_dir_all(dir)?;
        let mut report = exp::report::Report::new([
            "workload", "algorithm", "procs", "n", "m", "triangles", "time_s",
            "hub_threshold", "hubs", "bitmap_bytes", "k_list_list", "k_simd_blocked",
            "k_list_bitmap", "k_bitmap_bitmap", "mem_measured_max", "mem_pred_max",
            "accel_max",
        ]);
        report.row([
            cfg.workload.clone().into(),
            format!("{:?}", cfg.algorithm).into(),
            cfg.procs.into(),
            g.num_nodes().into(),
            g.num_edges().into(),
            triangles.into(),
            exp::report::Cell::Secs(elapsed.as_secs_f64()),
            hubs.threshold.map_or("off".into(), |t| t.to_string()).into(),
            hubs.hubs.into(),
            hubs.bitmap_bytes.into(),
            kernels.list_list.into(),
            kernels.simd_blocked.into(),
            kernels.list_bitmap.into(),
            kernels.bitmap_bitmap.into(),
            mem_max.into(),
            mem_pred_max.into(),
            accel_max.into(),
        ]);
        report.write_csv(&format!("{dir}/count.csv"))?;
        report.write_json(&format!("{dir}/count.json"))?;
        println!("[written: {dir}/count.{{csv,json}}]");
    }
    Ok(())
}

/// Split `args` at the first bare `--` into (own flags, nested command).
fn split_nested(args: &[String]) -> (&[String], &[String]) {
    match args.iter().position(|a| a == "--") {
        Some(i) => (&args[..i], &args[i + 1..]),
        None => (args, &[]),
    }
}

/// A fresh job id for a `launch` rendezvous: pid ‖ wall nanos, so two
/// launches on one host (even back-to-back) can't cross-join workers.
fn fresh_job_id() -> u64 {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    ((std::process::id() as u64) << 32) ^ nanos
}

/// Resolve `--bind`: a concrete address passes through; none (or a `:0`
/// port) picks a free loopback port by bind-and-drop.
fn resolve_bind(bind: Option<&str>) -> Result<String> {
    match bind {
        Some(a) if !a.ends_with(":0") => Ok(a.to_string()),
        Some(a) => {
            let l = std::net::TcpListener::bind(a)
                .map_err(|e| Error::Config(format!("launch: cannot bind `{a}`: {e}")))?;
            Ok(l.local_addr()?.to_string())
        }
        None => tricount::testkit::conformance::free_loopback_addr(),
    }
}

/// `tricount launch` — run a multi-process count over TCP: spawn P−1
/// `worker` processes of this binary against a rendezvous address, run
/// rank 0 in this process (it hosts the rendezvous and prints the
/// report), then reap every child — wait-with-timeout, then kill, so a
/// wedged worker fails the launch instead of orphaning.
fn cmd_launch(args: &[String]) -> Result<()> {
    let (own, nested) = split_nested(args);
    let mut procs = 4usize;
    let mut bind: Option<String> = None;
    let mut job_id: Option<u64> = None;
    let mut i = 0;
    while i < own.len() {
        let key = own[i]
            .strip_prefix("--")
            .ok_or_else(|| Error::Config(format!("expected --flag, got `{}`", own[i])))?;
        let value = own
            .get(i + 1)
            .ok_or_else(|| Error::Config(format!("--{key} needs a value")))?;
        match key {
            "procs" => {
                procs = value.parse().map_err(|e| Error::Config(format!("--procs: {e}")))?;
                if procs == 0 {
                    return Err(Error::Config("--procs must be >= 1".into()));
                }
            }
            "bind" => bind = Some(value.clone()),
            "job-id" => {
                job_id =
                    Some(value.parse().map_err(|e| Error::Config(format!("--job-id: {e}")))?)
            }
            other => return Err(Error::Config(format!("unknown launch flag `--{other}`"))),
        }
        i += 2;
    }
    let Some((cmd, count_args)) = nested.split_first() else {
        return Err(Error::Config(
            "launch needs a nested command: `launch --procs P -- count <flags>`".into(),
        ));
    };
    if cmd != "count" {
        return Err(Error::Config(format!("launch runs `count`, got `{cmd}`")));
    }
    launch_processes(procs, bind.as_deref(), job_id, count_args)
}

/// The launch engine shared by `tricount launch` and `count --fabric tcp`.
fn launch_processes(
    procs: usize,
    bind: Option<&str>,
    job_id: Option<u64>,
    count_args: &[String],
) -> Result<()> {
    use tricount::testkit::conformance::reap_children;
    let addr = resolve_bind(bind)?;
    let job_id = job_id.unwrap_or_else(fresh_job_id);
    let join_timeout_ms = 30_000u64;
    let exe = std::env::current_exe()?;
    let mut children: Vec<(usize, std::process::Child)> = Vec::new();
    for rank in 1..procs {
        let spawned = std::process::Command::new(&exe)
            .arg("worker")
            .args(["--connect", &addr])
            .args(["--rank", &rank.to_string()])
            .args(["--procs", &procs.to_string()])
            .args(["--job-id", &job_id.to_string()])
            .args(["--join-timeout-ms", &join_timeout_ms.to_string()])
            .arg("--")
            .arg("count")
            .args(count_args)
            .spawn();
        match spawned {
            Ok(child) => children.push((rank, child)),
            Err(e) => {
                reap_children(&mut children, std::time::Duration::from_secs(1), true);
                return Err(Error::Config(format!("launch: cannot spawn worker {rank}: {e}")));
            }
        }
    }
    let net = tricount::comm::tcp::TcpFabric {
        connect: addr,
        rank: 0,
        procs,
        job_id,
        join_timeout_ms,
    };
    let r0 = count_one_rank_tcp(&net, count_args);
    let timeout =
        tricount::comm::threads::recv_guard() + std::time::Duration::from_secs(5);
    let failures = reap_children(&mut children, timeout, r0.is_err());
    r0?;
    if !failures.is_empty() {
        return Err(Error::Cluster(format!("launch: {}", failures.join("; "))));
    }
    Ok(())
}

/// `tricount worker` — join one rank of a TCP cluster. The nested command
/// after `--` says what the cluster computes: `count <flags>` (every rank
/// must be handed the identical flags — workload prep is deterministic,
/// so no graph bytes cross the wire) or `conformance-cell` (spawned by
/// the `--fabric tcp` conformance matrix).
fn cmd_worker(args: &[String]) -> Result<()> {
    let (own, nested) = split_nested(args);
    let mut connect: Option<String> = None;
    let mut rank: Option<usize> = None;
    let mut procs: Option<usize> = None;
    let mut job_id = 0u64;
    let mut join_timeout_ms = 30_000u64;
    let mut i = 0;
    while i < own.len() {
        let key = own[i]
            .strip_prefix("--")
            .ok_or_else(|| Error::Config(format!("expected --flag, got `{}`", own[i])))?;
        let value = own
            .get(i + 1)
            .ok_or_else(|| Error::Config(format!("--{key} needs a value")))?;
        match key {
            "connect" => connect = Some(value.clone()),
            "rank" => {
                rank = Some(value.parse().map_err(|e| Error::Config(format!("--rank: {e}")))?)
            }
            "procs" => {
                procs =
                    Some(value.parse().map_err(|e| Error::Config(format!("--procs: {e}")))?)
            }
            "job-id" => {
                job_id = value.parse().map_err(|e| Error::Config(format!("--job-id: {e}")))?
            }
            "join-timeout-ms" => {
                join_timeout_ms = value
                    .parse()
                    .map_err(|e| Error::Config(format!("--join-timeout-ms: {e}")))?
            }
            other => return Err(Error::Config(format!("unknown worker flag `--{other}`"))),
        }
        i += 2;
    }
    let net = tricount::comm::tcp::TcpFabric {
        connect: connect.ok_or_else(|| Error::Config("worker needs --connect <ip:port>".into()))?,
        rank: rank.ok_or_else(|| Error::Config("worker needs --rank <r>".into()))?,
        procs: procs.ok_or_else(|| Error::Config("worker needs --procs <P>".into()))?,
        job_id,
        join_timeout_ms,
    };
    match nested.split_first() {
        Some((cmd, rest)) if cmd == "count" => count_one_rank_tcp(&net, rest),
        Some((cmd, rest)) if cmd == "conformance-cell" => conformance_cell_rank(&net, rest),
        _ => Err(Error::Config(
            "worker needs `-- count <flags>` or `-- conformance-cell --path NAME --workload SPEC`"
                .into(),
        )),
    }
}

/// One rank of a `--fabric tcp` count. Every process re-derives the
/// workload from the flags and runs the chosen driver over the socket
/// fabric; the end-of-run allgather hands each process the identical
/// rank-ordered result vector, so rank 0's report speaks for the cluster
/// and workers print nothing on success.
fn count_one_rank_tcp(net: &tricount::comm::tcp::TcpFabric, args: &[String]) -> Result<()> {
    use tricount::testkit::Fabric;
    let (mut cfg, extra) = parse_config(args)?;
    reject_unknown(&extra, &["out", "trace-out", "obs-out", "format", "fault"])?;
    apply_format(&mut cfg, &extra)?;
    if extra.contains_key("fault") || cfg.on_fault != tricount::ft::FaultPolicy::Fail {
        return Err(Error::Config(
            "--fabric tcp does not support --fault/--on-fault".into(),
        ));
    }
    if extra.contains_key("out") {
        return Err(Error::Config(
            "--out is not supported with --fabric tcp (use --obs-out / --trace-out)".into(),
        ));
    }
    let p = net.procs;
    cfg.procs = p;
    let t0 = std::time::Instant::now();
    let g = cfg.build_graph()?;
    let o = Arc::new(Oriented::from_graph_with(&g, cfg.hub_threshold));
    let prep = t0.elapsed();
    let fabric = Fabric::Tcp(net.clone());
    let t0 = std::time::Instant::now();
    let r = match cfg.algorithm {
        Algorithm::Surrogate | Algorithm::Direct => {
            let ranges = balanced_ranges(&prefix_sums(&cost_vector(&o, cfg.cost_fn)), p);
            let (r, _) = if cfg.algorithm == Algorithm::Surrogate {
                surrogate::run_on(&fabric, &o, &ranges, cfg.hub_threshold)
            } else {
                direct::run_on(&fabric, &o, &ranges, cfg.hub_threshold)
            };
            r?
        }
        Algorithm::Patric => {
            let ranges = balanced_ranges(&prefix_sums(&cost_vector(&o, CostFn::PatricBest)), p);
            let (r, _) = patric::run_on(&fabric, &g, &o, &ranges, cfg.hub_threshold);
            r?
        }
        Algorithm::Tile2d => {
            let (r, _) = tricount::algo::tile2d::run_on(&fabric, &o, p, cfg.hub_threshold);
            r?
        }
        Algorithm::DynamicLb => {
            if p < 2 {
                return Err(Error::Config("dynamic-lb needs --procs >= 2".into()));
            }
            let (r, _) = dynamic_lb::run_on(
                &fabric,
                &o,
                p,
                dynamic_lb::Options {
                    cost_fn: cfg.cost_fn,
                    granularity: dynamic_lb::Granularity::Shrinking,
                },
            );
            r?
        }
        other => {
            return Err(Error::Config(format!(
                "--fabric tcp needs a cluster algorithm \
                 (surrogate|direct|patric|dynamic-lb|tile2d), not {other:?}"
            )))
        }
    };
    let elapsed = t0.elapsed();
    if net.rank != 0 {
        return Ok(());
    }
    let t = r.metrics.totals();
    println!(
        "workload={} n={} m={} d̄={:.1} (prep {:.2?})",
        cfg.workload,
        g.num_nodes(),
        g.num_edges(),
        g.avg_degree(),
        prep
    );
    println!(
        "triangles={} algorithm={:?} procs={p} fabric=tcp time={:.3?} msgs={} bytes={} \
         wire_overhead={} B imbalance={:.3}",
        r.triangles,
        cfg.algorithm,
        elapsed,
        t.messages_sent,
        t.bytes_sent,
        t.wire_overhead_bytes,
        r.metrics.imbalance()
    );
    tricount::obs::report::print_breakdown(&r.metrics);
    if let Some(path) = extra.get("trace-out") {
        let json = tricount::obs::export::cluster_trace_json("tricount count", &r.metrics);
        std::fs::write(path, &json)?;
        println!("[written: {path} — load at ui.perfetto.dev or chrome://tracing]");
    }
    if let Some(path) = extra.get("obs-out") {
        let mut reg = tricount::obs::MetricsRegistry::new("count");
        reg.record_cluster(&r.metrics);
        reg.note(&format!("workload={}", cfg.workload));
        reg.note(&format!("algorithm={:?}", cfg.algorithm));
        reg.note("fabric=tcp");
        std::fs::write(path, reg.snapshot_json())?;
        println!("[written: {path} — inspect with `tricount obs-report {path}`]");
    }
    Ok(())
}

/// One rank of a TCP conformance cell. Every rank re-derives the
/// deterministic workload, runs the protocol over the wire, and checks
/// the allgathered count against its own oracle — a disagreeing worker
/// exits nonzero on its own, before rank 0 tallies the cell.
fn conformance_cell_rank(
    net: &tricount::comm::tcp::TcpFabric,
    args: &[String],
) -> Result<()> {
    use tricount::testkit::conformance::{self, Path};
    let mut path: Option<Path> = None;
    let mut workload: Option<String> = None;
    let mut i = 0;
    while i < args.len() {
        let key = args[i]
            .strip_prefix("--")
            .ok_or_else(|| Error::Config(format!("expected --flag, got `{}`", args[i])))?;
        let value = args
            .get(i + 1)
            .ok_or_else(|| Error::Config(format!("--{key} needs a value")))?;
        match key {
            "path" => {
                path = Some(Path::from_name(value).ok_or_else(|| {
                    Error::Config(format!("unknown conformance path `{value}`"))
                })?)
            }
            "workload" => workload = Some(value.clone()),
            other => {
                return Err(Error::Config(format!("unknown conformance-cell flag `--{other}`")))
            }
        }
        i += 2;
    }
    let path = path.ok_or_else(|| Error::Config("conformance-cell needs --path NAME".into()))?;
    let workload =
        workload.ok_or_else(|| Error::Config("conformance-cell needs --workload SPEC".into()))?;
    let outcome = conformance::run_cell(
        path,
        &workload,
        net.procs,
        &tricount::testkit::Fabric::Tcp(net.clone()),
    )?;
    if outcome.count != outcome.oracle {
        return Err(Error::Cluster(format!(
            "conformance-cell {} {workload} P={} rank {}: count {} != oracle {}",
            path.name(),
            net.procs,
            net.rank,
            outcome.count,
            outcome.oracle
        )));
    }
    if net.rank == 0 {
        println!(
            "cell ok: {} {workload} P={} count={}",
            path.name(),
            net.procs,
            outcome.count
        );
    }
    Ok(())
}

/// Map a CLI algorithm choice onto a supervisable [`tricount::ft::Job`].
/// Sequential and hybrid are single-process — there is no rank to lose.
fn supervised_job<'a>(
    cfg: &RunConfig,
    g: &'a tricount::graph::csr::Csr,
    o: &'a Arc<Oriented>,
) -> Result<tricount::ft::Job<'a>> {
    use tricount::ft::Job;
    Ok(match cfg.algorithm {
        Algorithm::Surrogate => {
            Job::Surrogate { graph: o, cost: cfg.cost_fn, hub: cfg.hub_threshold }
        }
        Algorithm::Direct => Job::Direct { graph: o, cost: cfg.cost_fn, hub: cfg.hub_threshold },
        Algorithm::Patric => {
            Job::Patric { g, graph: o, cost: CostFn::PatricBest, hub: cfg.hub_threshold }
        }
        Algorithm::DynamicLb => Job::DynamicLb {
            graph: o,
            opts: dynamic_lb::Options {
                cost_fn: cfg.cost_fn,
                granularity: dynamic_lb::Granularity::Shrinking,
            },
        },
        Algorithm::Tile2d => Job::Tile2d { graph: o, hub: cfg.hub_threshold },
        other => {
            return Err(Error::Config(format!(
                "--fault/--on-fault needs a cluster algorithm (surrogate|direct|patric|dynamic-lb|tile2d), not {other:?}"
            )))
        }
    })
}

/// Parse `--fault kill:<rank>:<op>` (`op` is 1-based: the victim's N-th
/// transport operation).
fn parse_fault(spec: &str, p: usize) -> Result<(usize, u64)> {
    let parts: Vec<&str> = spec.split(':').collect();
    match parts.as_slice() {
        ["kill", rank, op] => {
            let rank: usize =
                rank.parse().map_err(|e| Error::Config(format!("--fault rank: {e}")))?;
            let op: u64 = op.parse().map_err(|e| Error::Config(format!("--fault op: {e}")))?;
            if rank >= p {
                return Err(Error::Config(format!(
                    "--fault rank {rank} out of range (procs {p})"
                )));
            }
            if op == 0 {
                return Err(Error::Config("--fault op is 1-based (>= 1)".into()));
            }
            Ok((rank, op))
        }
        _ => Err(Error::Config(format!("--fault expects kill:<rank>:<op>, got `{spec}`"))),
    }
}

/// The `--fault` / `--on-fault` arm of `count` (DESIGN.md §13). An
/// injected fault puts the run on the seeded virtual fabric so the whole
/// fault + recovery schedule replays deterministically (the printed trace
/// hash is the replay key); without one, the production channel fabric is
/// supervised directly.
fn count_supervised(
    cfg: &RunConfig,
    extra: &std::collections::BTreeMap<String, String>,
    g: &tricount::graph::csr::Csr,
    o: &Arc<Oriented>,
) -> Result<()> {
    use tricount::ft::supervise;
    use tricount::testkit::{Fabric, FaultPlan, SimConfig};

    let p = if cfg.algorithm == Algorithm::DynamicLb { cfg.procs.max(2) } else { cfg.procs };
    let job = supervised_job(cfg, g, o)?;
    let fabric = match extra.get("fault") {
        Some(spec) => {
            let (rank, at_op) = parse_fault(spec, p)?;
            println!(
                "fault: killing rank {rank} at its transport op {at_op} (virtual fabric, seed {})",
                cfg.seed
            );
            Fabric::Sim(SimConfig::with_faults(cfg.seed, FaultPlan::kill_one(rank, at_op)))
        }
        None => Fabric::Channel,
    };
    let t0 = std::time::Instant::now();
    let run = supervise(&job, &fabric, p, cfg.on_fault)?;
    let elapsed = t0.elapsed();
    println!(
        "triangles={} algorithm={:?} procs={p} on-fault={} time={:.3?}",
        run.count, cfg.algorithm, cfg.on_fault, elapsed
    );
    let r = &run.recovery;
    if r.attempts > 0 || r.degraded {
        println!(
            "recovery: attempts={} dead_ranks={:?} survivors={:?} salvaged_units={} partial_units={} reexec_work={} reexec_bytes={}",
            r.attempts,
            r.dead_ranks,
            r.survivors.as_ref().map(|m| m.survivors.clone()).unwrap_or_default(),
            r.salvaged_units,
            r.partial_units,
            r.reexec_work_units,
            r.reexec_bytes
        );
    } else {
        println!("recovery: none needed (fault-free run)");
    }
    if let Some(b) = run.bound {
        println!(
            "degraded answer: {} ≤ T ≤ {} (estimate {}; not exact — rerun with --on-fault recover for the exact count)",
            b.lower, b.upper, b.estimate
        );
    }
    if let Some(h) = run.trace_hash {
        println!("trace hash: {h:016x} (same workload + seed + fault replays identically)");
    }
    tricount::obs::report::print_breakdown(&run.metrics);
    if let Some(path) = extra.get("trace-out") {
        let json = tricount::obs::export::cluster_trace_json("tricount count", &run.metrics);
        std::fs::write(path, &json)?;
        println!("[written: {path} — load at ui.perfetto.dev or chrome://tracing]");
    }
    if let Some(path) = extra.get("obs-out") {
        let mut reg = tricount::obs::MetricsRegistry::new("count");
        reg.record_cluster(&run.metrics);
        reg.record_ft(&run.recovery, run.trace_hash);
        reg.note(&format!("workload={}", cfg.workload));
        reg.note(&format!("algorithm={:?}", cfg.algorithm));
        std::fs::write(path, reg.snapshot_json())?;
        println!("[written: {path} — inspect with `tricount obs-report {path}`]");
    }
    Ok(())
}

/// `tricount bench-recovery` — recovery latency and re-executed-work
/// fraction vs kill position (first / middle / last transport op of the
/// victim), written to `BENCH_recovery.json`. Runs on the seeded virtual
/// fabric so every cell is deterministic, and verifies each recovered
/// count against the fault-free baseline.
fn cmd_bench_recovery(args: &[String]) -> Result<()> {
    use tricount::ft::{supervise, FaultPolicy};
    use tricount::testkit::{Fabric, FaultPlan, SimConfig};

    let (cfg, extra) = parse_config(args)?;
    reject_unknown(&extra, &["out"])?;
    let out = extra.get("out").map(String::as_str).unwrap_or("BENCH_recovery.json");
    let g = cfg.build_graph()?;
    let o = Arc::new(Oriented::from_graph_with(&g, cfg.hub_threshold));
    let p = cfg.procs.max(2);
    let job = supervised_job(&cfg, &g, &o)?;
    println!(
        "bench-recovery: workload={} n={} m={} algorithm={:?} P={p} seed={}",
        cfg.workload,
        g.num_nodes(),
        g.num_edges(),
        cfg.algorithm,
        cfg.seed
    );

    // Fault-free baseline on the same fabric family: the oracle count, the
    // total counting work, and the victim's transport-op budget (which
    // positions the middle/last kills).
    let t0 = std::time::Instant::now();
    let probe =
        supervise(&job, &Fabric::Sim(SimConfig::adversarial(cfg.seed)), p, FaultPolicy::Fail)?;
    let base_wall = t0.elapsed();
    let base_work = probe.metrics.totals().work_units.max(1);
    let victim = 1usize; // a worker rank on every path (0 is the §V coordinator)
    let v_ops = probe.metrics.per_rank[victim].transport_ops;

    let mut report = exp::report::Report::new([
        "position", "victim", "at_op", "attempts", "triangles", "exact", "wall_s",
        "reexec_work_frac", "reexec_bytes", "salvaged_units",
    ]);
    report.row([
        "baseline".into(),
        "-".into(),
        0u64.into(),
        0u64.into(),
        probe.count.into(),
        "true".into(),
        exp::report::Cell::Secs(base_wall.as_secs_f64()),
        0.0f64.into(),
        0u64.into(),
        0u64.into(),
    ]);
    let cells =
        [("first", 1u64), ("middle", (v_ops / 2).max(1)), ("last", v_ops.max(1))];
    for (pos, at_op) in cells {
        let fabric =
            Fabric::Sim(SimConfig::with_faults(cfg.seed, FaultPlan::kill_one(victim, at_op)));
        let t0 = std::time::Instant::now();
        let run = supervise(&job, &fabric, p, FaultPolicy::Recover)?;
        let wall = t0.elapsed();
        let exact = run.count == probe.count;
        let frac = run.recovery.reexec_work_units as f64 / base_work as f64;
        println!(
            "{pos:>7} (op {at_op}): triangles={} exact={exact} attempts={} wall={:.3?} reexec_work_frac={frac:.4} reexec_bytes={}",
            run.count, run.recovery.attempts, wall, run.recovery.reexec_bytes
        );
        report.row([
            pos.into(),
            victim.into(),
            at_op.into(),
            (run.recovery.attempts as usize).into(),
            run.count.into(),
            exact.to_string().into(),
            exp::report::Cell::Secs(wall.as_secs_f64()),
            frac.into(),
            run.recovery.reexec_bytes.into(),
            run.recovery.salvaged_units.into(),
        ]);
        if !exact {
            return Err(Error::Cluster(format!(
                "bench-recovery: {pos} kill recovered {} != baseline {}",
                run.count, probe.count
            )));
        }
    }
    report.note(format!(
        "victim rank {victim} of P={p}; its fault-free transport-op budget is {v_ops}; \
         reexec_work_frac is recovery work / fault-free counting work ({base_work} units)"
    ));
    report.print();
    report.write_json(out)?;
    println!("[written: {out}]");
    Ok(())
}

/// `tricount bench-comm` — per-rank communication volume for the four
/// §IV-family drivers (surrogate / direct / patric / tile2d) across a P
/// sweep, written to `BENCH_comm.json`.
///
/// Gates (CI smoke runs this on a small preset):
/// * every driver's count equals the others' on every cell;
/// * tile2d measured sent bytes ≤ 1.1× the cost-model prediction
///   ([`simulate_tile2d`] replays the exact coalesced frame plan, so the
///   two are normally *equal*);
/// * on `pa:` workloads, tile2d per-rank bytes strictly fall along the P
///   sweep and beat the best 1D §IV driver at the largest P — the
///   O(m/√P)-vs-O(m) headline.
fn cmd_bench_comm(args: &[String]) -> Result<()> {
    use tricount::sim::model::CostModel;
    use tricount::sim::space_efficient::simulate_tile2d;

    let (cfg, extra) = parse_config(args)?;
    reject_unknown(&extra, &["workloads", "procs", "out"])?;
    let out = extra.get("out").map(String::as_str).unwrap_or("BENCH_comm.json");
    let workloads: Vec<String> = match extra.get("workloads") {
        Some(w) => {
            w.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect()
        }
        None => vec!["pa:100000:64".into(), "rmat:16:16".into(), "er:200000:16".into()],
    };
    if workloads.is_empty() {
        return Err(Error::Config("--workloads needs at least one spec".into()));
    }
    // `--procs` here is a sweep list; a single value that parsed into the
    // RunConfig is honored as a one-point sweep.
    let procs: Vec<usize> = match extra.get("procs") {
        Some(s) => s
            .split(',')
            .map(|x| {
                x.trim().parse::<usize>().map_err(|e| Error::Config(format!("--procs: {e}")))
            })
            .collect::<Result<Vec<usize>>>()?,
        None if args.iter().any(|a| a == "--procs") => vec![cfg.procs],
        None => vec![4, 9, 16],
    };
    if procs.iter().any(|&p| p < 2) {
        return Err(Error::Config("--procs entries must be >= 2".into()));
    }

    let model = CostModel::default();
    let mut report = exp::report::Report::new([
        "workload", "algorithm", "P", "max_rank_sent_bytes", "total_sent_bytes", "frames",
        "logical_msgs", "agg_ratio", "pred_total_bytes",
    ]);
    for spec in &workloads {
        let g = tricount::config::build_workload(spec, cfg.scale, cfg.seed)?;
        let o = Arc::new(Oriented::from_graph_with(&g, cfg.hub_threshold));
        println!("bench-comm: workload={spec} n={} m={}", g.num_nodes(), g.num_edges());
        let prefix = prefix_sums(&cost_vector(&o, cfg.cost_fn));
        let patric_prefix = prefix_sums(&cost_vector(&o, CostFn::PatricBest));
        let mut tile_prev: Option<u64> = None;
        for (pi, &p) in procs.iter().enumerate() {
            let ranges = balanced_ranges(&prefix, p);
            let patric_ranges = balanced_ranges(&patric_prefix, p);
            let sim = simulate_tile2d(&o, p, &model);
            let runs: Vec<(&str, tricount::algo::RunResult, u64)> = vec![
                ("surrogate", surrogate::run(&o, &ranges, cfg.hub_threshold)?, 0),
                ("direct", direct::run(&o, &ranges, cfg.hub_threshold)?, 0),
                ("patric", patric::run(&g, &o, &patric_ranges, cfg.hub_threshold)?, 0),
                ("tile2d", tricount::algo::tile2d::run(&o, p, cfg.hub_threshold)?, sim.total_bytes()),
            ];
            let oracle = runs[0].1.triangles;
            let mut best_1d = u64::MAX;
            let mut tile_max = 0u64;
            for (name, r, pred) in &runs {
                if r.triangles != oracle {
                    return Err(Error::Cluster(format!(
                        "bench-comm: {name} on {spec} P={p} counted {} != {oracle}",
                        r.triangles
                    )));
                }
                let t = r.metrics.totals();
                let max_rank =
                    r.metrics.per_rank.iter().map(|m| m.bytes_sent).max().unwrap_or(0);
                // Logical messages: coalesced records where the driver
                // frames (direct, tile2d), raw envelopes where it doesn't.
                let logical = if t.coalesced_sent > 0 { t.coalesced_sent } else { t.messages_sent };
                println!(
                    "  {name:>9} P={p:<2}: max-rank {max_rank} B, total {} B, frames {}, records {}, agg {:.1}x",
                    t.bytes_sent, t.frames_sent, logical, r.metrics.aggregation_ratio()
                );
                report.row([
                    spec.clone().into(),
                    (*name).into(),
                    p.into(),
                    max_rank.into(),
                    t.bytes_sent.into(),
                    t.frames_sent.into(),
                    logical.into(),
                    r.metrics.aggregation_ratio().into(),
                    (*pred).into(),
                ]);
                match *name {
                    "surrogate" | "direct" => best_1d = best_1d.min(max_rank),
                    "tile2d" => {
                        tile_max = max_rank;
                        if t.bytes_sent > *pred + *pred / 10 {
                            return Err(Error::Cluster(format!(
                                "bench-comm: tile2d on {spec} P={p} sent {} B > 1.1× predicted {pred} B",
                                t.bytes_sent
                            )));
                        }
                    }
                    _ => {}
                }
            }
            if spec.starts_with("pa:") {
                if let Some(prev) = tile_prev {
                    if tile_max >= prev {
                        return Err(Error::Cluster(format!(
                            "bench-comm: tile2d per-rank bytes did not fall on {spec}: {prev} → {tile_max} at P={p}"
                        )));
                    }
                }
                tile_prev = Some(tile_max);
                if pi == procs.len() - 1 && tile_max >= best_1d {
                    return Err(Error::Cluster(format!(
                        "bench-comm: tile2d {tile_max} B !< best 1D {best_1d} B on {spec} at P={p}"
                    )));
                }
            }
        }
    }
    report.note(
        "max_rank_sent_bytes is the per-rank data-plane traffic (control markers excluded); \
         agg_ratio = logical records / frames for coalescing drivers, 1.0 otherwise; \
         pred_total_bytes (tile2d) replays the exact frame plan in the cost model"
            .to_string(),
    );
    report.print();
    report.write_json(out)?;
    println!("[written: {out}]");
    Ok(())
}

/// `tricount stream` — drive the incremental engine over a generated
/// update stream and report exact-count maintenance + projected scaling.
fn cmd_stream(args: &[String]) -> Result<()> {
    use tricount::stream::{compact::CompactionPolicy, parallel, window, workload};

    let (mut cfg, extra) = parse_config(args)?;
    apply_format(&mut cfg, &extra)?;
    let get = |key: &str| extra.get(key).map(String::as_str);
    let parse_f64 = |key: &str, default: f64| -> Result<f64> {
        get(key).map_or(Ok(default), |s| {
            s.parse().map_err(|e| Error::Config(format!("--{key}: {e}")))
        })
    };
    let parse_usize = |key: &str, default: usize| -> Result<usize> {
        get(key).map_or(Ok(default), |s| {
            s.parse().map_err(|e| Error::Config(format!("--{key}: {e}")))
        })
    };
    reject_unknown(
        &extra,
        &[
            "batch-size", "batches", "window", "delete-frac", "base-frac", "compact-every",
            "out", "verify", "trace-out", "obs-out", "format",
        ],
    )?;
    let spec = workload::StreamSpec {
        base_fraction: parse_f64("base-frac", 0.5)?,
        batch_size: parse_usize("batch-size", 1_000)?,
        batches: parse_usize("batches", 50)?,
        delete_fraction: parse_f64("delete-frac", 0.2)?,
    };
    let win = parse_usize("window", 0)?;
    let compact_every = parse_usize("compact-every", 16)?;
    let verify = match get("verify") {
        None | Some("on") => true,
        Some("off") => false,
        Some(other) => {
            return Err(Error::Config(format!("--verify expects on|off, got `{other}`")))
        }
    };

    let t0 = std::time::Instant::now();
    let g = cfg.build_graph()?;
    let mut rng = tricount::gen::rng::Rng::seeded(cfg.seed);
    let w = workload::edge_stream(&g, &spec, &mut rng);
    let batches = if win > 0 { window::expand(&w.base, &w.batches, win) } else { w.batches };
    println!(
        "workload={} n={} m={} → base m₀={} + {} updates in {} batches{} (prep {:.2?})",
        cfg.workload,
        g.num_nodes(),
        g.num_edges(),
        w.base.num_edges(),
        w.updates,
        batches.len(),
        if win > 0 { format!(", window={win}") } else { String::new() },
        t0.elapsed()
    );

    let opts = parallel::StreamOptions {
        policy: CompactionPolicy { every_batches: compact_every, overlay_ratio: 0.10 },
        hub_threshold: cfg.hub_threshold,
    };
    // Pay the one-time static count before resetting the kernel counters,
    // so the reported path mix describes the *streaming* Δ counter.
    let initial = node_iterator::count(&Oriented::from_graph(&w.base));
    tricount::adj::stats::reset();
    let t0 = std::time::Instant::now();
    let r = parallel::run_with_initial(&w.base, &batches, cfg.procs, opts, initial)?;
    let elapsed = t0.elapsed();
    let kernels = tricount::adj::stats::snapshot();

    let totals = r.metrics.totals();
    let mut report = exp::report::Report::new([
        "P", "batches", "updates", "eff_ins", "eff_del", "T_initial", "T_final",
        "compactions", "imbalance", "wall", "upd_per_s",
    ]);
    let eff_ins: usize = r.per_batch.iter().map(|b| b.inserts).sum();
    let eff_del: usize = r.per_batch.iter().map(|b| b.deletes).sum();
    report.row([
        cfg.procs.into(),
        r.per_batch.len().into(),
        w.updates.into(),
        eff_ins.into(),
        eff_del.into(),
        r.initial_triangles.into(),
        r.final_triangles.into(),
        r.compactions.into(),
        r.metrics.imbalance().into(),
        exp::report::Cell::Secs(elapsed.as_secs_f64()),
        ((w.updates as f64 / elapsed.as_secs_f64().max(1e-12)).round()).into(),
    ]);
    report.note(format!("counting work: {} element steps", totals.work_units));
    report.note(format!(
        "kernel paths: list×list={} simd×blocked={} list×bitmap={} bitmap×bitmap={}",
        kernels.list_list, kernels.simd_blocked, kernels.list_bitmap, kernels.bitmap_bitmap
    ));
    report.print();

    // Calibrated virtual-time projection: measured split at this P, then
    // an ideal-balance sweep (same CostModel the paper figures use).
    let model = tricount::sim::calibrate::calibrated();
    let per_batch_work: Vec<Vec<u64>> = r.per_batch.iter().map(|b| b.work_per_rank.clone()).collect();
    let measured = tricount::sim::streaming::project_measured(&model, &per_batch_work, w.updates as u64);
    let mut proj = exp::report::Report::new(["P", "mode", "virt_time", "upd_per_s", "speedup"]);
    proj.row([
        cfg.procs.into(),
        "measured".into(),
        exp::report::Cell::Secs(measured.makespan_ns * 1e-9),
        measured.updates_per_sec.round().into(),
        measured.speedup.into(),
    ]);
    let total_work = r.total_work();
    for p in [1usize, 2, 4, 8, 16, 32, 64] {
        let s = tricount::sim::streaming::project_ideal(
            &model,
            total_work,
            r.per_batch.len(),
            w.updates as u64,
            p,
        );
        proj.row([
            p.into(),
            "ideal".into(),
            exp::report::Cell::Secs(s.makespan_ns * 1e-9),
            s.updates_per_sec.round().into(),
            s.speedup.into(),
        ]);
    }
    proj.note(format!("α = {:.2} ns/unit (calibrated)", model.alpha_ns));
    proj.print();

    // obs/: per-rank span breakdown (Compute vs BatchApply vs the
    // allreduce barrier per batch) + trace/snapshot exports.
    tricount::obs::report::print_breakdown(&r.metrics);
    if let Some(path) = get("trace-out") {
        let json = tricount::obs::export::cluster_trace_json("tricount stream", &r.metrics);
        std::fs::write(path, &json)?;
        println!("[written: {path} — load at ui.perfetto.dev or chrome://tracing]");
    }
    if let Some(path) = get("obs-out") {
        let mut reg = tricount::obs::MetricsRegistry::new("stream");
        reg.record_cluster(&r.metrics);
        reg.record_global_kernels(kernels);
        reg.record_batches(&r.per_batch);
        reg.note(&format!("workload={}", cfg.workload));
        reg.note(&format!("updates={}", w.updates));
        std::fs::write(path, reg.snapshot_json())?;
        println!("[written: {path} — inspect with `tricount obs-report {path}`]");
    }

    if let Some(dir) = get("out") {
        std::fs::create_dir_all(dir)?;
        report.write_csv(&format!("{dir}/stream.csv"))?;
        report.write_json(&format!("{dir}/stream.json"))?;
        proj.write_csv(&format!("{dir}/stream-projection.csv"))?;
        proj.write_json(&format!("{dir}/stream-projection.json"))?;
        println!("[written: {dir}/stream.{{csv,json}}, {dir}/stream-projection.{{csv,json}}]");
    }

    if verify {
        let o = Oriented::from_graph(&r.final_graph);
        let recount = node_iterator::count(&o);
        if recount != r.final_triangles {
            return Err(Error::Cluster(format!(
                "VERIFY FAILED: incremental count {} != from-scratch recount {recount}",
                r.final_triangles
            )));
        }
        println!(
            "verify: OK — incremental count {} == from-scratch node-iterator recount",
            r.final_triangles
        );
    }
    Ok(())
}

fn cmd_analyze(args: &[String]) -> Result<()> {
    let (cfg, extra) = parse_config(args)?;
    reject_unknown(&extra, &[])?;
    let g = cfg.build_graph()?;
    let o = Arc::new(Oriented::from_graph_with(&g, cfg.hub_threshold));
    let stats = tricount::graph::stats::degree_stats(&g);
    println!("{stats}");

    // Per-node counts through the §V dynamic load balancer.
    let t0 = std::time::Instant::now();
    let tv = tricount::algo::local_counts::per_node_counts(&o, cfg.procs.max(2))?;
    let total: u64 = tv.iter().sum::<u64>() / 3;
    println!(
        "triangles            = {total}  (parallel per-node counts, P={}, {:.2?})",
        cfg.procs.max(2),
        t0.elapsed()
    );
    println!(
        "avg clustering coeff = {:.5}",
        tricount::seq::local::avg_clustering(&g, &tv)
    );
    println!(
        "transitivity         = {:.5}",
        tricount::seq::local::transitivity(&g, total)
    );

    // MapReduce baseline shuffle volume (the paper's §I motivation).
    let mr = tricount::baseline::mapreduce::shuffle_stats(&g);
    println!(
        "MR 2-path shuffle    = {} wedges ({:.1}x the edge set; ordered emit {}, max reducer {})",
        mr.wedges_all,
        tricount::baseline::mapreduce::blowup_factor(&g),
        mr.wedges_ordered,
        mr.max_reducer_records
    );

    // Approximation baselines vs the exact count.
    let mut rng = tricount::gen::rng::Rng::seeded(cfg.seed);
    let doulion = tricount::approx::doulion(&g, 0.3, &mut rng);
    let wedge = tricount::approx::wedge_sampling(&g, 100_000, &mut rng);
    println!(
        "approx: DOULION(p=.3) = {:.0} ({:+.1}%), wedge-sampling = {:.0} ({:+.1}%)",
        doulion,
        100.0 * (doulion / total as f64 - 1.0),
        wedge,
        100.0 * (wedge / total as f64 - 1.0)
    );

    // Truss decomposition for small graphs (O(m^1.5) peeling).
    if g.num_edges() <= 2_000_000 {
        let kmax = tricount::seq::truss::max_truss(&g);
        println!("max k-truss          = {kmax}");
    } else {
        println!("max k-truss          = (skipped: m > 2M)");
    }
    Ok(())
}

fn cmd_generate(args: &[String]) -> Result<()> {
    let (cfg, extra) = parse_config(args)?;
    let out = extra
        .get("out")
        .ok_or_else(|| Error::Config("generate needs --out PATH".into()))?;
    let format = extra.get("format").map(String::as_str).unwrap_or("edges");
    reject_unknown(&extra, &["out", "format"])?;
    let g = cfg.build_graph()?;
    match format {
        "edges" => tricount::graph::io::write_edge_list(&g, out)?,
        "bin" => tricount::graph::io::write_binary(&g, out)?,
        "tcg" => tricount::graph::io::write_tcg(&g, out)?,
        other => return Err(Error::Config(format!("unknown format `{other}`"))),
    }
    println!("wrote {} (n={}, m={})", out, g.num_nodes(), g.num_edges());
    Ok(())
}

/// `tricount convert` — materialize any workload (generator spec, text
/// edge list, legacy `bin:`) and encode it as a zero-parse `.tcg` binary
/// (DESIGN.md §12). The written file is immediately reloaded and compared
/// against the in-memory graph, so a successful exit certifies the
/// round-trip — `count --workload tcg:PATH` then loads it without parsing.
fn cmd_convert(args: &[String]) -> Result<()> {
    let (cfg, extra) = parse_config(args)?;
    reject_unknown(&extra, &["out"])?;
    let out = extra
        .get("out")
        .ok_or_else(|| Error::Config("convert needs --out PATH.tcg".into()))?;
    let t0 = std::time::Instant::now();
    let g = cfg.build_graph()?;
    let build_time = t0.elapsed();
    let t0 = std::time::Instant::now();
    tricount::graph::io::write_tcg(&g, out)?;
    let write_time = t0.elapsed();
    let t0 = std::time::Instant::now();
    let back = tricount::graph::io::read_tcg(out)?;
    let load_time = t0.elapsed();
    if back != g {
        return Err(Error::InvalidGraph(format!(
            "convert: `{out}` reloaded differently from the graph just written"
        )));
    }
    println!(
        "wrote {} (n={}, m={}; build {:.2?}, encode {:.2?}, verified reload {:.2?})",
        out,
        g.num_nodes(),
        g.num_edges(),
        build_time,
        write_time,
        load_time
    );
    Ok(())
}

/// `--format text|tcg`: reinterpret a file-backed `--workload` spec's
/// on-disk encoding. Generator specs are format-agnostic and pass through.
fn apply_format(cfg: &mut RunConfig, extra: &std::collections::BTreeMap<String, String>) -> Result<()> {
    if let Some(fmt) = extra.get("format") {
        cfg.workload = reformat_spec(&cfg.workload, fmt)?;
    }
    Ok(())
}

fn reformat_spec(spec: &str, fmt: &str) -> Result<String> {
    let path = spec.strip_prefix("file:").or_else(|| spec.strip_prefix("tcg:"));
    Ok(match (fmt, path) {
        ("text", Some(p)) => format!("file:{p}"),
        ("tcg", Some(p)) => format!("tcg:{p}"),
        ("text" | "tcg", None) => spec.to_string(),
        _ => {
            return Err(Error::Config(format!(
                "--format expects text|tcg, got `{fmt}`"
            )))
        }
    })
}

fn cmd_partition_stats(args: &[String]) -> Result<()> {
    let (cfg, extra) = parse_config(args)?;
    reject_unknown(&extra, &[])?;
    let g = cfg.build_graph()?;
    let o = Oriented::from_graph_with(&g, cfg.hub_threshold);
    let ours = balanced_ranges(&prefix_sums(&cost_vector(&o, CostFn::SurrogateNew)), cfg.procs);
    let patric = balanced_ranges(&prefix_sums(&cost_vector(&o, CostFn::PatricBest)), cfg.procs);
    let non = tricount::partition::nonoverlap::partition_sizes(&o, &ours);
    let over = tricount::partition::overlap::overlap_sizes(&g, &o, &patric);
    let max_non = non.iter().map(|s| s.mb()).fold(0.0f64, f64::max);
    let max_over = over.iter().map(|s| s.mb()).fold(0.0f64, f64::max);
    let sum_non: u64 = non.iter().map(|s| s.edges).sum();
    let sum_over: u64 = over.iter().map(|s| s.edges).sum();
    println!("P={} n={} m={}", cfg.procs, g.num_nodes(), g.num_edges());
    println!("non-overlapping (ours): largest {max_non:.2} MB, total edges stored {sum_non}");
    println!("overlapping (PATRIC):   largest {max_over:.2} MB, total edges stored {sum_over}");
    println!("ratio (largest): {:.2}x", max_over / max_non.max(1e-12));
    // The predictions above are enforced: materialize both owned layouts
    // and report what the ranks would physically hold.
    let own_non = tricount::partition::owned::extract_nonoverlapping(&o, &ours, cfg.hub_threshold);
    let own_over =
        tricount::partition::owned::extract_overlapping(&g, &o, &patric, cfg.hub_threshold);
    let meas_non = own_non.iter().map(|p| p.resident_bytes()).max().unwrap_or(0);
    let meas_over = own_over.iter().map(|p| p.resident_bytes()).max().unwrap_or(0);
    let exact = own_non.iter().zip(&non).all(|(p, s)| p.resident_bytes() == s.bytes())
        && own_over.iter().zip(&over).all(|(p, s)| p.resident_bytes() == s.bytes());
    println!(
        "measured (owned partitions): ours largest {:.2} MB, PATRIC largest {:.2} MB — {}",
        meas_non as f64 / (1024.0 * 1024.0),
        meas_over as f64 / (1024.0 * 1024.0),
        if exact { "measured == predicted on every partition" } else { "DIVERGED from prediction" }
    );
    if !exact {
        return Err(Error::Cluster("partition-stats: measured != predicted".into()));
    }
    // 2D tile layout at the same P (DESIGN.md §14): per-tile prediction vs
    // the materialized tiles, same gate as the 1D layouts above. Sizes are
    // taken over the driver's shuffled labeling.
    let sh = tricount::partition::tile2d::shuffled(&o);
    let l = tricount::partition::tile2d::layout(&sh, cfg.procs);
    let sizes = tricount::partition::tile2d::tile_sizes(&sh, &l);
    let tiles = tricount::partition::tile2d::extract_tiles(&sh, &l, cfg.hub_threshold);
    let pred_max = sizes.iter().map(|s| s.bytes()).max().unwrap_or(0);
    let meas_max = tiles.iter().map(|t| t.resident_bytes()).max().unwrap_or(0);
    let tiles_exact =
        tiles.iter().zip(&sizes).all(|(t, s)| t.resident_bytes() == s.bytes());
    let idle = cfg.procs - l.grid.active();
    println!(
        "tile2d ({}×{} grid{}): largest tile {:.2} MB predicted, {:.2} MB measured — {}",
        l.grid.r,
        l.grid.c,
        if idle > 0 { format!(" + {idle} idle") } else { String::new() },
        pred_max as f64 / (1024.0 * 1024.0),
        meas_max as f64 / (1024.0 * 1024.0),
        if tiles_exact { "measured == predicted on every tile" } else { "DIVERGED from prediction" }
    );
    if !tiles_exact {
        return Err(Error::Cluster("partition-stats: tile2d measured != predicted".into()));
    }
    Ok(())
}

/// `tricount bench-pipeline` — record the preprocessing perf baseline
/// (and enforce the parallel-==-serial determinism guarantee; CI runs
/// this on a small preset every push).
fn cmd_bench_pipeline(args: &[String]) -> Result<()> {
    let (cfg, extra) = parse_config(args)?;
    reject_unknown(&extra, &["workloads", "threads", "reps", "out", "trace-out", "format"])?;
    let mut opts = tricount::pipeline::Options {
        seed: cfg.seed,
        hub_threshold: cfg.hub_threshold,
        ..Default::default()
    };
    if let Some(w) = extra.get("workloads") {
        opts.workloads = w.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect();
        if opts.workloads.is_empty() {
            return Err(Error::Config("--workloads needs at least one spec".into()));
        }
    }
    if let Some(fmt) = extra.get("format") {
        opts.workloads = opts
            .workloads
            .iter()
            .map(|w| reformat_spec(w, fmt))
            .collect::<Result<Vec<String>>>()?;
    }
    if let Some(t) = extra.get("threads") {
        opts.threads = t
            .split(',')
            .map(|s| s.trim().parse::<tricount::par::BuildThreads>().map(|b| b.resolve()))
            .collect::<Result<Vec<usize>>>()?;
    }
    if let Some(r) = extra.get("reps") {
        opts.reps = r.parse().map_err(|e| Error::Config(format!("--reps: {e}")))?;
        if opts.reps == 0 {
            return Err(Error::Config("--reps must be >= 1".into()));
        }
    }
    let out = extra.get("out").map(String::as_str).unwrap_or("BENCH_pipeline.json");

    let report = tricount::pipeline::run(&opts)?;
    report.print();
    report.write_json(out)?;
    println!("[written: {out}]");

    // `--trace-out`: the stage timings as a sequential Perfetto timeline —
    // derived from the pinned 13-column Report, so the schema CI smokes
    // stays untouched. The parse span is the chunk-parallel parse the run
    // actually executes at this thread count (`parse_text_par_s`).
    if let Some(path) = extra.get("trace-out") {
        let mut stages: Vec<(String, f64)> = Vec::new();
        for i in 0..report.rows.len() {
            let w = report.text(i, "workload")?;
            let t = report.int(i, "threads")?;
            for (stage, col) in [
                ("parse", "parse_text_par_s"),
                ("load-tcg", "load_tcg_s"),
                ("build-radix", "build_radix_s"),
                ("relabel", "relabel_s"),
                ("orient+hub", "orient_hub_s"),
            ] {
                stages.push((format!("{stage} {w} T={t}"), report.secs(i, col)?));
            }
        }
        let json = tricount::obs::export::stages_trace_json("tricount bench-pipeline", &stages);
        std::fs::write(path, &json)?;
        println!("[written: {path} — load at ui.perfetto.dev or chrome://tracing]");
    }
    Ok(())
}

/// `tricount conformance` — the adversarial-schedule suite over the
/// virtual transport (DESIGN.md §10). Exits nonzero on any conformance
/// failure; the emitted JSON contains only schedule-deterministic fields,
/// so CI runs it twice and diffs the files as the replay gate.
fn cmd_conformance(args: &[String]) -> Result<()> {
    use tricount::testkit::conformance::{self, Options, Path};

    let mut opts = Options::default();
    let mut out: Option<String> = None;
    let mut trace_out: Option<String> = None;
    let mut fabric = "sim".to_string();
    let mut i = 0;
    while i < args.len() {
        let key = args[i]
            .strip_prefix("--")
            .ok_or_else(|| Error::Config(format!("expected --flag, got `{}`", args[i])))?;
        let value = args
            .get(i + 1)
            .ok_or_else(|| Error::Config(format!("--{key} needs a value")))?;
        match key {
            "seeds" => {
                opts.seeds = value.parse().map_err(|e| Error::Config(format!("--seeds: {e}")))?;
                if opts.seeds == 0 {
                    return Err(Error::Config("--seeds must be >= 1".into()));
                }
            }
            "procs" => {
                opts.procs = value
                    .split(',')
                    .map(|s| {
                        s.trim()
                            .parse::<usize>()
                            .map_err(|e| Error::Config(format!("--procs: {e}")))
                    })
                    .collect::<Result<Vec<usize>>>()?;
                if opts.procs.iter().any(|&p| p < 2) {
                    return Err(Error::Config(
                        "--procs entries must be >= 2 (the §V drivers need a coordinator)".into(),
                    ));
                }
            }
            "workloads" => {
                opts.workloads =
                    value.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect();
                if opts.workloads.is_empty() {
                    return Err(Error::Config("--workloads needs at least one spec".into()));
                }
            }
            "paths" => {
                opts.paths = value
                    .split(',')
                    .map(|s| {
                        Path::ALL
                            .iter()
                            .copied()
                            .find(|p| p.name() == s.trim())
                            .ok_or_else(|| Error::Config(format!("unknown path `{s}`")))
                    })
                    .collect::<Result<Vec<Path>>>()?;
            }
            "faults" => {
                opts.faults = match value.as_str() {
                    "on" => true,
                    "off" => false,
                    other => {
                        return Err(Error::Config(format!(
                            "--faults expects on|off, got `{other}`"
                        )))
                    }
                };
            }
            "out" => out = Some(value.clone()),
            "trace-out" => trace_out = Some(value.clone()),
            "fabric" => fabric = value.clone(),
            other => return Err(Error::Config(format!("unknown conformance flag `--{other}`"))),
        }
        i += 2;
    }

    // `--fabric tcp`: the live-wire matrix — every cell as P OS processes
    // over loopback TCP, spawned from this binary (DESIGN.md §15). The
    // seeds/faults/trace-out knobs are sim-fabric concepts and don't
    // apply here.
    if fabric == "tcp" {
        let mut topts =
            conformance::TcpOptions::new(std::env::current_exe()?);
        topts.workloads = opts.workloads;
        topts.procs = opts.procs;
        topts.paths = opts.paths;
        let t0 = std::time::Instant::now();
        let r = conformance::run_tcp_matrix(&topts)?;
        let mut report = exp::report::Report::new(["path", "workload", "P", "status"]);
        for c in &r.configs {
            report.row([
                c.path.into(),
                c.workload.clone().into(),
                c.p.into(),
                (if c.ok { "ok" } else { "FAIL" }).into(),
            ]);
        }
        report.note(format!("{} cells over loopback TCP, every worker process reaped", r.cells));
        report.print();
        println!(
            "conformance [tcp]: {} cells, {} failures ({:.2?})",
            r.cells,
            r.failures.len(),
            t0.elapsed()
        );
        for f in &r.failures {
            eprintln!("conformance FAIL: {f}");
        }
        if !r.failures.is_empty() {
            return Err(Error::Cluster(format!(
                "tcp conformance matrix failed: {} violation(s)",
                r.failures.len()
            )));
        }
        return Ok(());
    }
    if fabric != "sim" {
        return Err(Error::Config(format!(
            "conformance --fabric expects sim|tcp, got `{fabric}`"
        )));
    }

    let t0 = std::time::Instant::now();
    let r = conformance::run(&opts)?;
    let mut report = exp::report::Report::new(["path", "workload", "P", "schedules", "trace_hash", "status"]);
    for c in &r.configs {
        report.row([
            c.path.into(),
            c.workload.clone().into(),
            c.p.into(),
            (c.schedules as usize).into(),
            format!("{:016x}", c.hash).into(),
            (if c.ok { "ok" } else { "FAIL" }).into(),
        ]);
    }
    report.note(format!(
        "matrix hash {:016x} over {} schedule cells (each run twice) + {} fault checks",
        r.matrix_hash, r.cells, r.fault_checks
    ));
    report.print();
    println!(
        "conformance: {} configs, {} cells, {} fault checks, {} failures ({:.2?})",
        r.configs.len(),
        r.cells,
        r.fault_checks,
        r.failures.len(),
        t0.elapsed()
    );
    for f in &r.failures {
        eprintln!("conformance FAIL: {f}");
    }
    if let Some(dir) = out {
        std::fs::create_dir_all(&dir)?;
        report.write_csv(&format!("{dir}/conformance.csv"))?;
        report.write_json(&format!("{dir}/conformance.json"))?;
        println!("[written: {dir}/conformance.{{csv,json}}]");
    }
    if let Some(path) = trace_out {
        // A representative cell on a fixed adversarial schedule: virtual
        // ticks only, so the exported JSON is byte-identical across
        // invocations (CI diffs two runs as the replay-visibility gate).
        let m = tricount::testkit::conformance::demo_cell(0)?;
        let json = tricount::obs::export::cluster_trace_json("tricount conformance", &m);
        std::fs::write(&path, &json)?;
        println!("[written: {path} — virtual-time timeline of surrogate pa:160:6 P=4 seed 0]");
    }
    if !r.failures.is_empty() {
        return Err(Error::Cluster(format!(
            "conformance suite failed: {} violation(s)",
            r.failures.len()
        )));
    }
    Ok(())
}

/// `tricount obs-report` — validate an obs snapshot against schema v1 and
/// render it human-readably; optionally validate a Perfetto trace file too.
fn cmd_obs_report(args: &[String]) -> Result<()> {
    let mut snapshot: Option<String> = None;
    let mut trace: Option<String> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--trace" => {
                trace = Some(
                    args.get(i + 1)
                        .cloned()
                        .ok_or_else(|| Error::Config("--trace needs a file".into()))?,
                );
                i += 2;
            }
            flag if flag.starts_with("--") => {
                return Err(Error::Config(format!("unknown obs-report flag `{flag}`")))
            }
            path => {
                if snapshot.replace(path.to_string()).is_some() {
                    return Err(Error::Config(
                        "obs-report takes exactly one snapshot path".into(),
                    ));
                }
                i += 1;
            }
        }
    }
    let snapshot = snapshot
        .ok_or_else(|| Error::Config("obs-report needs a snapshot path (from --obs-out)".into()))?;

    let text = std::fs::read_to_string(&snapshot)?;
    let v = tricount::obs::registry::validate_snapshot(&text)
        .map_err(|e| Error::Report(format!("{snapshot}: {e}")))?;
    println!("{snapshot}: schema v{} OK", tricount::obs::SCHEMA_VERSION);
    let rendered = tricount::obs::report::render_snapshot(&v)
        .map_err(|e| Error::Report(format!("{snapshot}: {e}")))?;
    print!("{rendered}");

    if let Some(tpath) = trace {
        let ttext = std::fs::read_to_string(&tpath)?;
        let events = tricount::obs::export::validate_trace(&ttext)
            .map_err(|e| Error::Report(format!("{tpath}: {e}")))?;
        println!("{tpath}: Perfetto trace OK ({events} events)");
    }
    Ok(())
}

fn cmd_exp(args: &[String]) -> Result<()> {
    let mut opts = exp::Options::default();
    let mut id = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--list" => {
                for e in exp::registry() {
                    println!("{:8} {:10} {}", e.id, e.paper_ref, e.description);
                }
                return Ok(());
            }
            "--quick" => {
                opts.quick = true;
                i += 1;
            }
            "--id" => {
                id = Some(args.get(i + 1).cloned().ok_or_else(|| Error::Config("--id needs a value".into()))?);
                i += 2;
            }
            "--scale" => {
                opts.scale = args
                    .get(i + 1)
                    .and_then(|s| s.parse().ok())
                    .ok_or_else(|| Error::Config("--scale needs a number".into()))?;
                i += 2;
            }
            "--out" => {
                opts.out_dir = Some(
                    args.get(i + 1).cloned().ok_or_else(|| Error::Config("--out needs a dir".into()))?,
                );
                i += 2;
            }
            other => return Err(Error::Config(format!("unknown exp flag `{other}`"))),
        }
    }
    let id = id.ok_or_else(|| Error::Config("exp needs --id <id|all> (or --list)".into()))?;
    exp::run_by_id(&id, &opts)
}

fn cmd_info(args: &[String]) -> Result<()> {
    let (cfg, _extra) = parse_config(args)?;
    match tricount::runtime::engine::Engine::cpu() {
        Ok(engine) => println!("PJRT platform: {}", engine.platform()),
        Err(e) => println!("PJRT platform: unavailable ({e})"),
    }
    let arts = tricount::runtime::artifact::discover(&cfg.artifacts_dir)?;
    if arts.is_empty() {
        println!("artifacts: none in `{}` (run `make artifacts`)", cfg.artifacts_dir);
    } else {
        for a in arts {
            println!("artifact: {} (N={})", a.path.display(), a.n);
        }
    }
    Ok(())
}

fn reject_unknown(
    extra: &std::collections::BTreeMap<String, String>,
    allowed: &[&str],
) -> Result<()> {
    for k in extra.keys() {
        if !allowed.contains(&k.as_str()) {
            return Err(Error::Config(format!("unknown flag `--{k}`")));
        }
    }
    Ok(())
}
