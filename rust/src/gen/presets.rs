//! Paper-dataset presets (Table I substitutes).
//!
//! The container has no network access, so the paper's real datasets
//! (SNAP web graphs, LiveJournal, the 2.4B-edge Twitter crawl, the Miami
//! contact network) are substituted with generated networks that match the
//! *property each dataset exercises* — degree skew, average degree, and
//! scale — at roughly 1/10 of the paper's node counts (fits one machine,
//! keeps full experiment sweeps in minutes). The mapping and the paper's
//! original sizes are recorded here and printed by `tricount exp --id table1`.

use crate::gen::geometric;
use crate::gen::pa;
use crate::gen::rmat::{self, RmatParams};
use crate::gen::rng::Rng;
use crate::graph::csr::Csr;

/// Which generator family a preset uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    /// Preferential attachment (power-law skew).
    Pa,
    /// R-MAT (extreme heavy tail, web/Twitter-like).
    Rmat,
    /// Near-regular contact network (even degrees).
    Contact,
}

/// A named workload preset mirroring one of the paper's Table-I datasets.
#[derive(Clone, Debug)]
pub struct Preset {
    /// Our identifier, e.g. `"livejournal-like"`.
    pub name: &'static str,
    /// The paper dataset it stands in for.
    pub paper_name: &'static str,
    /// Paper's node count.
    pub paper_nodes: f64,
    /// Paper's edge count.
    pub paper_edges: f64,
    pub family: Family,
    /// Our node count at `scale = 1.0`.
    pub nodes: usize,
    /// Target average degree.
    pub avg_degree: usize,
    /// Deterministic seed.
    pub seed: u64,
}

impl Preset {
    /// Build the graph at a relative scale (`scale = 1.0` → the default
    /// reproduction size; smaller values shrink node counts proportionally,
    /// keeping average degree fixed).
    pub fn build_scaled(&self, scale: f64) -> Csr {
        let n = ((self.nodes as f64 * scale).round() as usize).max(16 * self.avg_degree);
        let mut rng = Rng::seeded(self.seed);
        match self.family {
            Family::Pa => {
                let d = if self.avg_degree % 2 == 0 { self.avg_degree } else { self.avg_degree + 1 };
                pa::preferential_attachment(n, d, &mut rng)
            }
            Family::Rmat => {
                // Round n up to a power of two (R-MAT requirement).
                let s = (usize::BITS - (n - 1).leading_zeros()) as u32;
                rmat::rmat(s, self.avg_degree / 2, RmatParams::default(), &mut rng)
            }
            Family::Contact => geometric::miami_like(n, self.avg_degree, &mut rng),
        }
    }

    /// Build at the default scale.
    pub fn build(&self) -> Csr {
        self.build_scaled(1.0)
    }
}

/// All presets, mirroring the rows of the paper's Table I.
pub const PRESETS: &[Preset] = &[
    Preset {
        name: "google-like",
        paper_name: "web-Google",
        paper_nodes: 0.88e6,
        paper_edges: 5.1e6,
        family: Family::Pa,
        nodes: 88_000,
        avg_degree: 12,
        seed: 0xD00D_0001,
    },
    Preset {
        name: "berkstan-like",
        paper_name: "web-BerkStan",
        paper_nodes: 0.69e6,
        paper_edges: 13e6,
        family: Family::Rmat,
        nodes: 65_536,
        avg_degree: 38,
        seed: 0xD00D_0002,
    },
    Preset {
        name: "miami-like",
        paper_name: "Miami",
        paper_nodes: 2.1e6,
        paper_edges: 100e6,
        family: Family::Contact,
        nodes: 210_000,
        avg_degree: 95,
        seed: 0xD00D_0003,
    },
    Preset {
        name: "livejournal-like",
        paper_name: "LiveJournal",
        paper_nodes: 4.8e6,
        paper_edges: 86e6,
        family: Family::Pa,
        nodes: 480_000,
        avg_degree: 36,
        seed: 0xD00D_0004,
    },
    Preset {
        name: "twitter-like",
        paper_name: "Twitter",
        paper_nodes: 42e6,
        paper_edges: 2.4e9,
        family: Family::Rmat,
        nodes: 262_144,
        avg_degree: 114,
        seed: 0xD00D_0005,
    },
];

/// Look up a preset by name.
pub fn by_name(name: &str) -> Option<&'static Preset> {
    PRESETS.iter().find(|p| p.name == name)
}

/// `PA(n, d)` convenience used by the parameterized experiments
/// (Figs 6, 7, 9, 14, 15; Table II's `PA(10M,100)` row at reduced scale).
pub fn pa_graph(n: usize, d: usize, seed: u64) -> Csr {
    let d = if d % 2 == 0 { d } else { d + 1 };
    pa::preferential_attachment(n, d, &mut Rng::seeded(seed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats::degree_stats;

    #[test]
    fn lookup() {
        assert!(by_name("miami-like").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn small_scale_builds_match_family_properties() {
        // Build tiny versions to keep tests fast; check skew properties.
        let lj = by_name("livejournal-like").unwrap().build_scaled(0.02);
        let mi = by_name("miami-like").unwrap().build_scaled(0.02);
        let slj = degree_stats(&lj);
        let smi = degree_stats(&mi);
        assert!(slj.cv > smi.cv, "PA should be more skewed: {slj} vs {smi}");
        lj.validate().unwrap();
        mi.validate().unwrap();
    }

    #[test]
    fn scaled_nodes_proportional() {
        let p = by_name("google-like").unwrap();
        let g = p.build_scaled(0.05);
        assert!((g.num_nodes() as f64 - 4400.0).abs() < 500.0);
    }
}
