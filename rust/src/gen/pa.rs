//! Preferential-attachment generator `PA(n, d)` (Barabási–Albert [27]).
//!
//! The paper's scaling experiments (Figs 6, 7, 9, 14, 15; Tables II-IV) use
//! `PA(n, d)`: `n` nodes, average degree `d` (≈ `d/2` edges added per new
//! node), power-law degree distribution. We use the standard
//! repeated-endpoint trick: attachment proportional to degree is achieved by
//! sampling uniformly from the multiset of all edge endpoints so far.

use crate::gen::rng::Rng;
use crate::graph::builder::from_edge_list;
use crate::graph::csr::Csr;
use crate::VertexId;

/// Generate `PA(n, d)`: `n` nodes, average degree ≈ `d` (so ≈ `n·d/2` edges).
/// `d` must be even and ≥ 2; `n > d`.
pub fn preferential_attachment(n: usize, d: usize, rng: &mut Rng) -> Csr {
    assert!(d >= 2 && d % 2 == 0, "d must be even and >= 2, got {d}");
    assert!(n > d, "need n > d (n={n}, d={d})");
    let k = d / 2; // edges per new node
    let mut edges: Vec<(VertexId, VertexId)> = Vec::with_capacity(n * k);
    // Endpoint pool: each inserted edge contributes both endpoints, giving
    // degree-proportional sampling.
    let mut pool: Vec<VertexId> = Vec::with_capacity(2 * n * k);
    // Seed: a (k+1)-clique so every early node has degree ≥ k.
    for u in 0..=k as VertexId {
        for v in (u + 1)..=k as VertexId {
            edges.push((u, v));
            pool.push(u);
            pool.push(v);
        }
    }
    let mut picked: Vec<VertexId> = Vec::with_capacity(k);
    for v in (k + 1)..n {
        let v = v as VertexId;
        picked.clear();
        // Rejection-sample k distinct neighbors (k is small; collisions rare).
        let mut guard = 0usize;
        while picked.len() < k {
            let u = pool[rng.below_usize(pool.len())];
            if !picked.contains(&u) {
                picked.push(u);
            } else {
                guard += 1;
                if guard > 64 * k {
                    // Degenerate corner (tiny pools): fall back to any node ≠ v.
                    let u = rng.below(v as u64) as VertexId;
                    if !picked.contains(&u) {
                        picked.push(u);
                    }
                }
            }
        }
        for &u in &picked {
            edges.push((v, u));
            pool.push(v);
            pool.push(u);
        }
    }
    from_edge_list(n, edges).expect("PA generator produces valid edges")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats::degree_stats;

    #[test]
    fn size_matches_spec() {
        let g = preferential_attachment(1000, 10, &mut Rng::seeded(1));
        assert_eq!(g.num_nodes(), 1000);
        // m ≈ n·d/2 (exact up to the seed clique and rare duplicate edges).
        let m = g.num_edges() as f64;
        assert!((m - 5000.0).abs() < 150.0, "m={m}");
        g.validate().unwrap();
    }

    #[test]
    fn deterministic() {
        let a = preferential_attachment(500, 6, &mut Rng::seeded(9));
        let b = preferential_attachment(500, 6, &mut Rng::seeded(9));
        assert_eq!(a, b);
    }

    #[test]
    fn power_law_skew() {
        let g = preferential_attachment(5000, 10, &mut Rng::seeded(2));
        let s = degree_stats(&g);
        // Power-law tail: hub degree far above average, high CV.
        assert!(s.max_degree > 10 * s.avg_degree as usize, "{s}");
        assert!(s.cv > 0.8, "expected skew, cv={}", s.cv);
    }

    #[test]
    fn min_degree_is_k() {
        let g = preferential_attachment(300, 8, &mut Rng::seeded(3));
        for v in 0..300u32 {
            assert!(g.degree(v) >= 4, "node {v} degree {}", g.degree(v));
        }
    }
}
