//! Erdős–Rényi `G(n, m)` generator — the "no structure" control used in
//! tests and ablations (uniform degrees, expected triangle count known in
//! closed form).

use crate::gen::rng::Rng;
use crate::graph::builder::from_edge_list;
use crate::graph::csr::Csr;
use crate::VertexId;

/// Sample a uniform graph with `n` nodes and exactly `m` distinct edges
/// (rejection sampling; requires `m ≤ n(n-1)/2`).
pub fn gnm(n: usize, m: usize, rng: &mut Rng) -> Csr {
    let max = n * (n - 1) / 2;
    assert!(m <= max, "m={m} exceeds max edges {max} for n={n}");
    // For dense requests fall back to sampling non-edges instead.
    if m > max / 2 {
        return dense_gnm(n, m, rng);
    }
    let mut set = std::collections::HashSet::with_capacity(m * 2);
    let mut edges = Vec::with_capacity(m);
    while edges.len() < m {
        let u = rng.below(n as u64) as VertexId;
        let v = rng.below(n as u64) as VertexId;
        if u == v {
            continue;
        }
        let key = if u < v { ((u as u64) << 32) | v as u64 } else { ((v as u64) << 32) | u as u64 };
        if set.insert(key) {
            edges.push((u, v));
        }
    }
    from_edge_list(n, edges).expect("G(n,m) edges valid")
}

fn dense_gnm(n: usize, m: usize, rng: &mut Rng) -> Csr {
    // Enumerate all pairs, shuffle, take m. O(n²) — only for small dense tests.
    let mut all: Vec<(VertexId, VertexId)> = Vec::with_capacity(n * (n - 1) / 2);
    for u in 0..n as VertexId {
        for v in (u + 1)..n as VertexId {
            all.push((u, v));
        }
    }
    rng.shuffle(&mut all);
    all.truncate(m);
    from_edge_list(n, all).expect("dense G(n,m) edges valid")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_edge_count() {
        let g = gnm(100, 500, &mut Rng::seeded(4));
        assert_eq!(g.num_edges(), 500);
        g.validate().unwrap();
    }

    #[test]
    fn dense_path() {
        let g = gnm(20, 150, &mut Rng::seeded(5)); // max=190, m>max/2
        assert_eq!(g.num_edges(), 150);
        g.validate().unwrap();
    }

    #[test]
    fn full_graph() {
        let g = gnm(10, 45, &mut Rng::seeded(6));
        assert_eq!(g.num_edges(), 45);
        assert_eq!(g.max_degree(), 9);
    }

    #[test]
    fn deterministic() {
        assert_eq!(gnm(50, 100, &mut Rng::seeded(7)), gnm(50, 100, &mut Rng::seeded(7)));
    }
}
