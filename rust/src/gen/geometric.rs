//! Near-regular random graphs — the "Miami-like" substitute.
//!
//! The paper's Miami network [26] is a synthetic social-contact network
//! whose *even* degree distribution makes both cost-estimation functions
//! coincide (Fig 5) and loads easy to balance. What matters for
//! reproduction is the narrow degree distribution plus social-network-like
//! triangle density; a random geometric-style construction — each node
//! links to `d/2` members of a bounded neighborhood window plus a few
//! uniform long-range contacts — reproduces both (high clustering from
//! window locality, binomial-narrow degrees).

use crate::gen::rng::Rng;
use crate::graph::builder::from_edge_list;
use crate::graph::csr::Csr;
use crate::VertexId;

/// Generate a near-regular "contact network": `n` nodes, average degree ≈ `d`.
/// A fraction `long_range` of each node's links go to uniform random nodes;
/// the rest stay within a window of width `4·d`, creating triangle-rich
/// locality like a geographic contact network.
pub fn contact_network(n: usize, d: usize, long_range: f64, rng: &mut Rng) -> Csr {
    assert!(d >= 2 && n > 4 * d, "need n > 4d (n={n}, d={d})");
    let k = (d / 2).max(1);
    let window = 4 * d;
    let mut edges: Vec<(VertexId, VertexId)> = Vec::with_capacity(n * k);
    for v in 0..n {
        for _ in 0..k {
            let u = if rng.chance(long_range) {
                rng.below(n as u64) as usize
            } else {
                // Window neighbor around v (wrapping).
                let off = 1 + rng.below_usize(window);
                if rng.chance(0.5) { (v + off) % n } else { (v + n - off) % n }
            };
            if u != v {
                edges.push((v as VertexId, u as VertexId));
            }
        }
    }
    from_edge_list(n, edges).expect("contact network edges valid")
}

/// Paper-preset flavor: `contact_network(n, d, 0.05)`.
pub fn miami_like(n: usize, d: usize, rng: &mut Rng) -> Csr {
    contact_network(n, d, 0.05, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats::degree_stats;

    #[test]
    fn near_regular_degrees() {
        let g = miami_like(5000, 20, &mut Rng::seeded(21));
        let s = degree_stats(&g);
        assert!((s.avg_degree - 20.0).abs() < 2.0, "{s}");
        // Even distribution: CV well under power-law levels.
        assert!(s.cv < 0.4, "expected even degrees, {s}");
        g.validate().unwrap();
    }

    #[test]
    fn has_triangles() {
        use crate::graph::ordering::Oriented;
        use crate::seq::node_iterator;
        let g = miami_like(2000, 16, &mut Rng::seeded(22));
        let t = node_iterator::count(&Oriented::from_graph(&g));
        assert!(t > 100, "contact network should be triangle-rich, got {t}");
    }

    #[test]
    fn deterministic() {
        assert_eq!(
            miami_like(1000, 10, &mut Rng::seeded(23)),
            miami_like(1000, 10, &mut Rng::seeded(23))
        );
    }
}
