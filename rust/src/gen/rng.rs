//! Deterministic, dependency-free PRNG (xoshiro256** seeded via SplitMix64).
//!
//! The container build is fully offline, so instead of the `rand` crate the
//! generators use this small, well-tested implementation. Determinism per
//! seed is load-bearing: every experiment in EXPERIMENTS.md records its seed
//! and is exactly re-runnable.

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Next 32-bit value.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` (Lemire's method, unbiased).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork an independent stream (for per-rank determinism).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::seeded(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_respects_bound_and_hits_all() {
        let mut r = Rng::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::seeded(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seeded(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn forked_streams_are_independent() {
        let mut base = Rng::seeded(5);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
