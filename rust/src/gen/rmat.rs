//! R-MAT generator — the "Twitter-like" heavy-tail substitute.
//!
//! The paper stresses its algorithms on Twitter's 2.4B-edge graph whose
//! extremely skewed degree distribution blows up overlapping partitions.
//! That dataset is not available in this container; R-MAT with the classic
//! (a,b,c,d) = (0.57, 0.19, 0.19, 0.05) parameters reproduces the skew that
//! drives the paper's phenomena at a size a single machine holds
//! (see DESIGN.md §3 Substitutions).

use crate::gen::rng::Rng;
use crate::graph::builder::from_edge_list;
use crate::graph::csr::Csr;
use crate::VertexId;

/// R-MAT parameters. Quadrant probabilities must sum to 1.
#[derive(Clone, Copy, Debug)]
pub struct RmatParams {
    pub a: f64,
    pub b: f64,
    pub c: f64,
    /// Per-level probability perturbation (breaks exact self-similarity,
    /// standard Graph500 practice).
    pub noise: f64,
}

impl Default for RmatParams {
    fn default() -> Self {
        RmatParams { a: 0.57, b: 0.19, c: 0.19, noise: 0.1 }
    }
}

/// Generate an R-MAT graph with `2^scale` nodes and ~`edge_factor·2^scale`
/// undirected edges (duplicates and self-loops dropped, so slightly fewer).
pub fn rmat(scale: u32, edge_factor: usize, params: RmatParams, rng: &mut Rng) -> Csr {
    let n = 1usize << scale;
    let m_target = edge_factor * n;
    let mut edges: Vec<(VertexId, VertexId)> = Vec::with_capacity(m_target);
    for _ in 0..m_target {
        let (mut u, mut v) = (0usize, 0usize);
        for _ in 0..scale {
            // Perturb quadrant probabilities per level.
            let f = 1.0 + params.noise * (2.0 * rng.f64() - 1.0);
            let a = params.a * f;
            let b = params.b * f;
            let c = params.c * f;
            let sum = a + b + c + (1.0 - params.a - params.b - params.c) * f;
            let r = rng.f64() * sum;
            u <<= 1;
            v <<= 1;
            if r < a {
                // top-left
            } else if r < a + b {
                v |= 1;
            } else if r < a + b + c {
                u |= 1;
            } else {
                u |= 1;
                v |= 1;
            }
        }
        edges.push((u as VertexId, v as VertexId));
    }
    from_edge_list(n, edges).expect("rmat edges valid")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats::degree_stats;

    #[test]
    fn size_and_validity() {
        let g = rmat(10, 8, RmatParams::default(), &mut Rng::seeded(11));
        assert_eq!(g.num_nodes(), 1024);
        // Dedup removes some; expect the bulk to survive.
        assert!(g.num_edges() > 4000, "m={}", g.num_edges());
        g.validate().unwrap();
    }

    #[test]
    fn heavy_tail() {
        let g = rmat(12, 16, RmatParams::default(), &mut Rng::seeded(12));
        let s = degree_stats(&g);
        assert!(s.cv > 1.0, "expected heavy tail, {s}");
        assert!(s.max_degree > 20 * s.avg_degree as usize, "{s}");
    }

    #[test]
    fn deterministic() {
        let p = RmatParams::default();
        assert_eq!(
            rmat(8, 4, p, &mut Rng::seeded(13)),
            rmat(8, 4, p, &mut Rng::seeded(13))
        );
    }
}
