//! Degree-distribution statistics — used by the dataset-summary experiment
//! (Table I) and by the skewed-degree example to characterize generated
//! networks against the paper's datasets.

use crate::graph::csr::Csr;
use crate::VertexId;

/// Summary statistics of a graph's degree distribution.
#[derive(Clone, Debug, PartialEq)]
pub struct DegreeStats {
    pub nodes: usize,
    pub edges: u64,
    pub avg_degree: f64,
    pub max_degree: usize,
    pub median_degree: usize,
    /// 99th-percentile degree.
    pub p99_degree: usize,
    /// Coefficient of variation (σ/μ) — the paper's "skewness" driver:
    /// ≈0.1-0.3 for Miami-like even distributions, >1 for power laws.
    pub cv: f64,
}

/// Compute [`DegreeStats`] in O(n + m).
pub fn degree_stats(g: &Csr) -> DegreeStats {
    let n = g.num_nodes();
    let mut degs: Vec<usize> = (0..n as VertexId).map(|v| g.degree(v)).collect();
    degs.sort_unstable();
    let mu = g.avg_degree();
    let var = if n == 0 {
        0.0
    } else {
        degs.iter().map(|&d| (d as f64 - mu) * (d as f64 - mu)).sum::<f64>() / n as f64
    };
    DegreeStats {
        nodes: n,
        edges: g.num_edges(),
        avg_degree: mu,
        max_degree: *degs.last().unwrap_or(&0),
        median_degree: if n == 0 { 0 } else { degs[n / 2] },
        p99_degree: if n == 0 { 0 } else { degs[(n - 1).min(n * 99 / 100)] },
        cv: if mu > 0.0 { var.sqrt() / mu } else { 0.0 },
    }
}

/// Degree histogram in log₂ buckets: `hist[k]` counts nodes with
/// `degree ∈ [2^k, 2^{k+1})` (`hist[0]` includes degree 0 and 1).
pub fn log2_degree_histogram(g: &Csr) -> Vec<u64> {
    let mut hist = vec![0u64; 1];
    for v in 0..g.num_nodes() as VertexId {
        let d = g.degree(v);
        let b = if d <= 1 { 0 } else { (usize::BITS - d.leading_zeros()) as usize - 1 };
        if b >= hist.len() {
            hist.resize(b + 1, 0);
        }
        hist[b] += 1;
    }
    hist
}

impl std::fmt::Display for DegreeStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} m={} d̄={:.2} d_max={} d_med={} d_p99={} cv={:.2}",
            self.nodes, self.edges, self.avg_degree, self.max_degree,
            self.median_degree, self.p99_degree, self.cv
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::classic;

    #[test]
    fn regular_graph_zero_cv() {
        let s = degree_stats(&classic::complete(8));
        assert_eq!(s.max_degree, 7);
        assert_eq!(s.median_degree, 7);
        assert!(s.cv.abs() < 1e-12);
    }

    #[test]
    fn star_is_skewed() {
        let s = degree_stats(&classic::star(100));
        assert_eq!(s.max_degree, 100);
        assert_eq!(s.median_degree, 1);
        assert!(s.cv > 3.0, "star should be highly skewed, cv={}", s.cv);
    }

    #[test]
    fn histogram_buckets() {
        // K_5: all degrees 4 → bucket 2 ([4,8)).
        let h = log2_degree_histogram(&classic::complete(5));
        assert_eq!(h, vec![0, 0, 5]);
    }

    #[test]
    fn histogram_counts_all_nodes() {
        let g = classic::karate();
        let h = log2_degree_histogram(&g);
        assert_eq!(h.iter().sum::<u64>(), 34);
    }
}
