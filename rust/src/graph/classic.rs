//! Small classic graphs with known triangle counts — the exactness fixtures
//! used across the test suite and the examples.

use crate::graph::builder::from_edges;
use crate::graph::csr::Csr;
use crate::VertexId;

/// Complete graph `K_n` — `C(n,3)` triangles.
pub fn complete(n: usize) -> Csr {
    let mut es = Vec::with_capacity(n * (n - 1) / 2);
    for u in 0..n as VertexId {
        for v in (u + 1)..n as VertexId {
            es.push((u, v));
        }
    }
    from_edges(n, es).expect("K_n is valid")
}

/// Cycle `C_n` (n ≥ 3) — 1 triangle iff n == 3, else 0.
pub fn cycle(n: usize) -> Csr {
    assert!(n >= 3);
    let es = (0..n as VertexId).map(|v| (v, ((v as usize + 1) % n) as VertexId));
    from_edges(n, es).expect("C_n is valid")
}

/// Star `K_{1,k}` (hub = node 0) — 0 triangles.
pub fn star(k: usize) -> Csr {
    from_edges(k + 1, (1..=k as VertexId).map(|v| (0, v))).expect("star is valid")
}

/// Complete bipartite `K_{a,b}` — 0 triangles.
pub fn complete_bipartite(a: usize, b: usize) -> Csr {
    let mut es = Vec::with_capacity(a * b);
    for u in 0..a as VertexId {
        for v in 0..b as VertexId {
            es.push((u, a as VertexId + v));
        }
    }
    from_edges(a + b, es).expect("K_{a,b} is valid")
}

/// Petersen graph — famously triangle-free (girth 5).
pub fn petersen() -> Csr {
    let outer = (0..5).map(|i| (i, (i + 1) % 5));
    let spokes = (0..5).map(|i| (i, i + 5));
    let inner = (0..5).map(|i| (i + 5, (i + 2) % 5 + 5));
    from_edges(10, outer.chain(spokes).chain(inner).map(|(u, v)| (u as VertexId, v as VertexId)))
        .expect("petersen is valid")
}

/// Zachary's karate club (34 nodes, 78 edges) — **45 triangles**, the classic
/// real social network used as an embedded "real data" fixture.
pub fn karate() -> Csr {
    // Standard edge list (0-indexed).
    const E: [(VertexId, VertexId); 78] = [
        (0, 1), (0, 2), (0, 3), (0, 4), (0, 5), (0, 6), (0, 7), (0, 8), (0, 10),
        (0, 11), (0, 12), (0, 13), (0, 17), (0, 19), (0, 21), (0, 31), (1, 2),
        (1, 3), (1, 7), (1, 13), (1, 17), (1, 19), (1, 21), (1, 30), (2, 3),
        (2, 7), (2, 8), (2, 9), (2, 13), (2, 27), (2, 28), (2, 32), (3, 7),
        (3, 12), (3, 13), (4, 6), (4, 10), (5, 6), (5, 10), (5, 16), (6, 16),
        (8, 30), (8, 32), (8, 33), (9, 33), (13, 33), (14, 32), (14, 33),
        (15, 32), (15, 33), (18, 32), (18, 33), (19, 33), (20, 32), (20, 33),
        (22, 32), (22, 33), (23, 25), (23, 27), (23, 29), (23, 32), (23, 33),
        (24, 25), (24, 27), (24, 31), (25, 31), (26, 29), (26, 33), (27, 33),
        (28, 31), (28, 33), (29, 32), (29, 33), (30, 32), (30, 33), (31, 32),
        (31, 33), (32, 33),
    ];
    from_edges(34, E).expect("karate is valid")
}

/// Known triangle count of [`karate`].
pub const KARATE_TRIANGLES: u64 = 45;

/// A wheel `W_n`: hub 0 joined to a cycle of n rim nodes — n triangles (n ≥ 3).
pub fn wheel(n: usize) -> Csr {
    assert!(n >= 3);
    let rim = (0..n).map(|i| ((i + 1) as VertexId, ((i + 1) % n + 1) as VertexId));
    let spokes = (1..=n).map(|i| (0 as VertexId, i as VertexId));
    from_edges(n + 1, rim.chain(spokes)).expect("wheel is valid")
}

/// Two `K_4`s sharing one vertex — 8 triangles; exercises articulation points.
pub fn barbell_k4() -> Csr {
    let mut es = Vec::new();
    for u in 0..4 {
        for v in (u + 1)..4 {
            es.push((u as VertexId, v as VertexId));
        }
    }
    // second K4 on {3,4,5,6} (node 3 shared)
    for u in 3..7 {
        for v in (u + 1)..7 {
            es.push((u as VertexId, v as VertexId));
        }
    }
    from_edges(7, es).expect("barbell is valid")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(complete(5).num_edges(), 10);
        assert_eq!(cycle(7).num_edges(), 7);
        assert_eq!(star(6).num_edges(), 6);
        assert_eq!(complete_bipartite(3, 4).num_edges(), 12);
        assert_eq!(petersen().num_edges(), 15);
        assert_eq!(karate().num_edges(), 78);
        assert_eq!(wheel(5).num_edges(), 10);
    }

    #[test]
    fn all_valid() {
        for g in [
            complete(6),
            cycle(4),
            star(3),
            complete_bipartite(2, 5),
            petersen(),
            karate(),
            wheel(8),
            barbell_k4(),
        ] {
            g.validate().unwrap();
        }
    }

    #[test]
    fn petersen_is_cubic() {
        let g = petersen();
        for v in 0..10 {
            assert_eq!(g.degree(v), 3);
        }
    }
}
