//! Compressed sparse row (CSR) representation of an undirected graph.
//!
//! This is the substrate every algorithm in the crate works on. Nodes are
//! labelled `0..n-1` (`VertexId = u32`); every undirected edge `{u, v}` is
//! stored twice (once in each endpoint's adjacency list) and each list is
//! sorted ascending by node id, which the intersection kernels and the
//! paper's `LastProc` message-elimination trick both rely on.

use crate::comm::transport::{Wire, WireReader};
use crate::VertexId;

/// An immutable undirected graph in CSR form.
///
/// Invariants (upheld by [`crate::graph::builder`] and checked by
/// [`Csr::validate`]):
/// * no self loops, no parallel edges;
/// * adjacency lists sorted ascending;
/// * symmetry: `v ∈ adj(u) ⇔ u ∈ adj(v)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Csr {
    /// `offsets[v]..offsets[v+1]` indexes `targets` for node `v`'s list.
    offsets: Vec<u64>,
    /// Concatenated adjacency lists.
    targets: Vec<VertexId>,
}

impl Wire for Csr {
    fn write_to(&self, out: &mut Vec<u8>) {
        self.offsets.write_to(out);
        self.targets.write_to(out);
    }
    fn read_from(r: &mut WireReader<'_>) -> crate::error::Result<Self> {
        let offsets = Vec::<u64>::read_from(r)?;
        let targets = Vec::<VertexId>::read_from(r)?;
        Csr::from_wire_parts(offsets, targets)
    }
}

impl Csr {
    /// Build from raw parts. `offsets` must have length `n + 1`, start at 0,
    /// be non-decreasing and end at `targets.len()`.
    pub fn from_parts(offsets: Vec<u64>, targets: Vec<VertexId>) -> Self {
        debug_assert!(!offsets.is_empty());
        debug_assert_eq!(*offsets.first().unwrap(), 0);
        debug_assert_eq!(*offsets.last().unwrap() as usize, targets.len());
        Csr { offsets, targets }
    }

    /// [`Csr::from_parts`] for data of wire provenance (`comm::tcp` result
    /// frames): the structural invariants are *checked*, not debug-asserted
    /// — a corrupt frame must surface as an error, never as UB downstream.
    fn from_wire_parts(offsets: Vec<u64>, targets: Vec<VertexId>) -> crate::error::Result<Self> {
        let bad = offsets.is_empty()
            || offsets[0] != 0
            || *offsets.last().unwrap() as usize != targets.len()
            || offsets.windows(2).any(|w| w[0] > w[1]);
        if bad {
            return Err(crate::error::Error::Comm("malformed CSR offsets on wire".into()));
        }
        Ok(Csr { offsets, targets })
    }

    /// The empty graph on `n` nodes.
    pub fn empty(n: usize) -> Self {
        Csr { offsets: vec![0; n + 1], targets: Vec::new() }
    }

    /// Number of nodes `n`.
    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of undirected edges `m` (each stored twice internally).
    #[inline]
    pub fn num_edges(&self) -> u64 {
        self.targets.len() as u64 / 2
    }

    /// Degree `d_v`.
    #[inline]
    pub fn degree(&self, v: VertexId) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    /// Sorted neighbor list `𝒩_v`.
    #[inline]
    pub fn neighbors(&self, v: VertexId) -> &[VertexId] {
        let s = self.offsets[v as usize] as usize;
        let e = self.offsets[v as usize + 1] as usize;
        &self.targets[s..e]
    }

    /// `true` iff `{u, v} ∈ E` (binary search over the shorter list).
    pub fn has_edge(&self, u: VertexId, v: VertexId) -> bool {
        if u == v {
            return false;
        }
        let (a, b) = if self.degree(u) <= self.degree(v) { (u, v) } else { (v, u) };
        self.neighbors(a).binary_search(&b).is_ok()
    }

    /// Iterate all undirected edges `(u, v)` with `u < v`, each once.
    pub fn edges(&self) -> impl Iterator<Item = (VertexId, VertexId)> + '_ {
        (0..self.num_nodes() as VertexId).flat_map(move |u| {
            self.neighbors(u)
                .iter()
                .copied()
                .filter(move |&v| u < v)
                .map(move |v| (u, v))
        })
    }

    /// Raw offsets (length `n + 1`).
    pub fn offsets(&self) -> &[u64] {
        &self.offsets
    }

    /// Raw concatenated targets (length `2m`).
    pub fn targets(&self) -> &[VertexId] {
        &self.targets
    }

    /// Bytes used by the CSR arrays (the paper's "memory for a partition"
    /// accounting uses the same formula on subgraphs).
    pub fn memory_bytes(&self) -> u64 {
        (self.offsets.len() * std::mem::size_of::<u64>()
            + self.targets.len() * std::mem::size_of::<VertexId>()) as u64
    }

    /// Maximum degree `d_max`.
    pub fn max_degree(&self) -> usize {
        (0..self.num_nodes() as VertexId)
            .map(|v| self.degree(v))
            .max()
            .unwrap_or(0)
    }

    /// Average degree `d̄ = 2m / n`.
    pub fn avg_degree(&self) -> f64 {
        if self.num_nodes() == 0 {
            return 0.0;
        }
        self.targets.len() as f64 / self.num_nodes() as f64
    }

    /// Exhaustively check the structural invariants. Intended for tests and
    /// debug assertions — O(m log m).
    pub fn validate(&self) -> Result<(), String> {
        let n = self.num_nodes();
        if self.offsets[0] != 0 {
            return Err("offsets[0] != 0".into());
        }
        for v in 0..n {
            if self.offsets[v] > self.offsets[v + 1] {
                return Err(format!("offsets decrease at {v}"));
            }
        }
        if *self.offsets.last().unwrap() as usize != self.targets.len() {
            return Err("offsets end != targets.len()".into());
        }
        for v in 0..n as VertexId {
            let ns = self.neighbors(v);
            for w in ns.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("adjacency of {v} not strictly sorted"));
                }
            }
            for &u in ns {
                if u as usize >= n {
                    return Err(format!("edge ({v},{u}) out of range"));
                }
                if u == v {
                    return Err(format!("self loop at {v}"));
                }
                if self.neighbors(u).binary_search(&v).is_err() {
                    return Err(format!("asymmetric edge ({v},{u})"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    fn path3() -> Csr {
        // 0 - 1 - 2
        GraphBuilder::new(3).edges([(0, 1), (1, 2)]).build().unwrap()
    }

    #[test]
    fn empty_graph() {
        let g = Csr::empty(5);
        assert_eq!(g.num_nodes(), 5);
        assert_eq!(g.num_edges(), 0);
        assert_eq!(g.degree(3), 0);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn degrees_and_neighbors() {
        let g = path3();
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.degree(0), 1);
        assert_eq!(g.degree(1), 2);
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn has_edge_both_directions() {
        let g = path3();
        assert!(g.has_edge(0, 1));
        assert!(g.has_edge(1, 0));
        assert!(!g.has_edge(0, 2));
        assert!(!g.has_edge(1, 1));
    }

    #[test]
    fn edges_iterator_yields_each_once() {
        let g = path3();
        let es: Vec<_> = g.edges().collect();
        assert_eq!(es, vec![(0, 1), (1, 2)]);
    }

    #[test]
    fn memory_accounting_matches_layout() {
        let g = path3();
        assert_eq!(g.memory_bytes(), (4 * 8 + 4 * 4) as u64);
    }

    #[test]
    fn max_and_avg_degree() {
        let g = path3();
        assert_eq!(g.max_degree(), 2);
        assert!((g.avg_degree() - 4.0 / 3.0).abs() < 1e-12);
    }
}
