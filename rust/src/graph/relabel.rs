//! Degree-order relabeling: renumber nodes so id order equals `≺` order.
//!
//! After relabeling, `u ≺ v ⇔ u < v`, so the orientation keeps exactly the
//! id-increasing edges and consecutive-id partitions become consecutive-≺
//! partitions — which concentrates the ≺-top hubs in the last partition
//! (useful with the dense-core tensor path, whose core is exactly a suffix
//! of the relabeled id space). Triangle counts are invariant under any
//! relabeling; tests assert it.

use crate::graph::builder::from_edge_list;
use crate::graph::csr::Csr;
use crate::VertexId;

/// The permutation (old id → new id) sorting nodes by `(degree, id)`.
///
/// Counting sort over degrees: histogram → bucket starts → an
/// id-ascending scatter, which is stable by id within each degree — the
/// same order the seed's `sort_unstable_by_key` produced, in
/// O(n + d_max) with no materialized `order` vector (the preprocessing
/// clone-pattern audit: this and the builder's cursor were the two extra
/// O(n) allocations; `ordering.rs` already filled rows cursor-free).
pub fn degree_order_permutation(g: &Csr) -> Vec<VertexId> {
    let n = g.num_nodes();
    let dmax = g.max_degree();
    let mut start = vec![0usize; dmax + 2];
    for v in 0..n as VertexId {
        start[g.degree(v) + 1] += 1;
    }
    for d in 0..=dmax {
        start[d + 1] += start[d];
    }
    let mut perm = vec![0 as VertexId; n];
    for v in 0..n as VertexId {
        let d = g.degree(v);
        perm[v as usize] = start[d] as VertexId;
        start[d] += 1;
    }
    perm
}

/// Apply a permutation (old id → new id) to a graph. The rebuild goes
/// through the O(m) radix builder (and its `--build-threads` parallelism),
/// which re-sorts every row under the new ids.
pub fn relabel(g: &Csr, perm: &[VertexId]) -> Csr {
    assert_eq!(perm.len(), g.num_nodes());
    let edges: Vec<(VertexId, VertexId)> = g
        .edges()
        .map(|(u, v)| (perm[u as usize], perm[v as usize]))
        .collect();
    from_edge_list(g.num_nodes(), edges).expect("permutation preserves validity")
}

/// Relabel by degree order (convenience).
pub fn relabel_by_degree(g: &Csr) -> Csr {
    relabel(g, &degree_order_permutation(g))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::classic;
    use crate::graph::ordering::Oriented;
    use crate::seq::node_iterator;

    #[test]
    fn permutation_is_bijective() {
        let g = classic::karate();
        let mut p = degree_order_permutation(&g);
        p.sort_unstable();
        assert_eq!(p, (0..34).collect::<Vec<_>>());
    }

    #[test]
    fn counting_permutation_matches_comparison_sort() {
        crate::prop::quickcheck("counting perm == (degree,id) sort", |rng, _| {
            let g = crate::prop::arb_graph(rng, 80);
            let n = g.num_nodes();
            let mut order: Vec<VertexId> = (0..n as VertexId).collect();
            order.sort_unstable_by_key(|&v| (g.degree(v), v));
            let mut expect = vec![0 as VertexId; n];
            for (new_id, &old) in order.iter().enumerate() {
                expect[old as usize] = new_id as VertexId;
            }
            if degree_order_permutation(&g) != expect {
                return Err(format!("permutation diverged on n={n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn degrees_sorted_after_relabel() {
        let g = crate::gen::pa::preferential_attachment(
            500,
            8,
            &mut crate::gen::rng::Rng::seeded(3),
        );
        let r = relabel_by_degree(&g);
        for v in 1..500u32 {
            assert!(r.degree(v - 1) <= r.degree(v), "degrees must be non-decreasing");
        }
    }

    #[test]
    fn triangle_count_invariant() {
        crate::prop::quickcheck("relabel invariance", |rng, _| {
            let g = crate::prop::arb_graph(rng, 60);
            let before = node_iterator::count(&Oriented::from_graph(&g));
            let after = node_iterator::count(&Oriented::from_graph(&relabel_by_degree(&g)));
            if before != after {
                return Err(format!("count changed: {before} → {after}"));
            }
            Ok(())
        });
    }

    #[test]
    fn relabeled_orientation_points_upward_in_id() {
        let g = classic::karate();
        let r = relabel_by_degree(&g);
        let o = Oriented::from_graph(&r);
        for v in 0..34u32 {
            for &u in o.nbrs(v) {
                assert!(u > v, "after relabel, oriented edges go id-upward");
            }
        }
    }

    #[test]
    fn dense_core_is_id_suffix_after_relabel() {
        let g = crate::gen::pa::preferential_attachment(
            400,
            8,
            &mut crate::gen::rng::Rng::seeded(5),
        );
        let r = relabel_by_degree(&g);
        let o = Oriented::from_graph(&r);
        let core = crate::tensor::core_extract::DenseCore::extract(&o, 32);
        let mut m = core.members.clone();
        m.sort_unstable();
        assert_eq!(m, (368u32..400).collect::<Vec<_>>());
    }
}
