//! Graph I/O: whitespace-separated edge-list text (the SNAP interchange
//! format the paper's datasets ship in) and a compact binary CSR format for
//! fast reloads of generated workloads.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::graph::builder::from_edge_list;
use crate::graph::csr::Csr;
use crate::VertexId;

/// Read a SNAP-style edge list: one `u v` pair per line, `#`/`%` comments
/// and blank lines ignored, node ids need not be contiguous — they are
/// compacted to `0..n` preserving relative order.
pub fn read_edge_list<P: AsRef<Path>>(path: P) -> Result<Csr> {
    let f = File::open(path)?;
    parse_edge_list(BufReader::new(f))
}

/// Parse an edge list from any reader (see [`read_edge_list`]).
///
/// Real SNAP dumps contain self-loops and both orientations of the same
/// undirected edge; both are scrubbed **at parse time** (canonicalize to
/// `(min, max)`, sort, dedup) rather than deferred to the builder: a node
/// mentioned only by self-loops does not survive id compaction, and
/// duplicates collapse before the compacted per-edge vector is built
/// (the builder's own dedup then sees no duplicates).
pub fn parse_edge_list<R: BufRead>(r: R) -> Result<Csr> {
    let mut raw: Vec<(u64, u64)> = Vec::new();
    for (i, line) in r.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let parse = |s: Option<&str>| -> Result<u64> {
            s.ok_or_else(|| Error::Parse { line: i + 1, msg: "missing endpoint".into() })?
                .parse()
                .map_err(|e| Error::Parse { line: i + 1, msg: format!("{e}") })
        };
        let u = parse(it.next())?;
        let v = parse(it.next())?;
        if u == v {
            continue; // self loop: never a triangle edge
        }
        raw.push(if u < v { (u, v) } else { (v, u) });
    }
    raw.sort_unstable();
    raw.dedup();
    // Compact ids.
    let mut ids: Vec<u64> = raw.iter().flat_map(|&(u, v)| [u, v]).collect();
    ids.sort_unstable();
    ids.dedup();
    let lookup = |x: u64| ids.binary_search(&x).unwrap() as VertexId;
    let edges: Vec<(VertexId, VertexId)> = raw.iter().map(|&(u, v)| (lookup(u), lookup(v))).collect();
    from_edge_list(ids.len(), edges)
}

/// Write a graph as an edge list (`u v` per line, each undirected edge once).
pub fn write_edge_list<P: AsRef<Path>>(g: &Csr, path: P) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    writeln!(w, "# tricount edge list: n={} m={}", g.num_nodes(), g.num_edges())?;
    for (u, v) in g.edges() {
        writeln!(w, "{u} {v}")?;
    }
    w.flush()?;
    Ok(())
}

const BIN_MAGIC: &[u8; 8] = b"TRICSR01";

/// Write the compact binary CSR format:
/// `magic | n: u64 | len(targets): u64 | offsets: (n+1)×u64 LE | targets: len×u32 LE`.
pub fn write_binary<P: AsRef<Path>>(g: &Csr, path: P) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(BIN_MAGIC)?;
    w.write_all(&(g.num_nodes() as u64).to_le_bytes())?;
    w.write_all(&(g.targets().len() as u64).to_le_bytes())?;
    for &o in g.offsets() {
        w.write_all(&o.to_le_bytes())?;
    }
    for &t in g.targets() {
        w.write_all(&t.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Read the binary CSR format written by [`write_binary`].
pub fn read_binary<P: AsRef<Path>>(path: P) -> Result<Csr> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != BIN_MAGIC {
        return Err(Error::Parse { line: 0, msg: "bad magic (not a TRICSR01 file)".into() });
    }
    let mut buf8 = [0u8; 8];
    r.read_exact(&mut buf8)?;
    let n = u64::from_le_bytes(buf8) as usize;
    r.read_exact(&mut buf8)?;
    let tl = u64::from_le_bytes(buf8) as usize;
    let mut offsets = vec![0u64; n + 1];
    for o in offsets.iter_mut() {
        r.read_exact(&mut buf8)?;
        *o = u64::from_le_bytes(buf8);
    }
    let mut targets = vec![0 as VertexId; tl];
    let mut buf4 = [0u8; 4];
    for t in targets.iter_mut() {
        r.read_exact(&mut buf4)?;
        *t = u32::from_le_bytes(buf4);
    }
    let g = Csr::from_parts(offsets, targets);
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::classic;
    use std::io::Cursor;

    #[test]
    fn parse_with_comments_and_gaps() {
        let txt = "# header\n10 20\n20 30\n\n% alt comment\n30 10\n";
        let g = parse_edge_list(Cursor::new(txt)).unwrap();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_edges(), 3);
    }

    #[test]
    fn duplicate_edges_merged_both_orientations() {
        // `u v` and `v u` (and a verbatim repeat) are one undirected edge.
        let txt = "1 2\n2 1\n1 2\n2 3\n";
        let g = parse_edge_list(Cursor::new(txt)).unwrap();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.degree(1), 2); // compacted id of node "2"
        g.validate().unwrap();
    }

    #[test]
    fn self_loops_dropped_at_parse_time() {
        // Node 9 appears only in a self-loop: it must not survive
        // compaction; the remaining graph is the single edge 1–2.
        let txt = "9 9\n1 2\n2 2\n";
        let g = parse_edge_list(Cursor::new(txt)).unwrap();
        assert_eq!(g.num_nodes(), 2);
        assert_eq!(g.num_edges(), 1);
        g.validate().unwrap();
    }

    #[test]
    fn percent_comments_and_whitespace_variants() {
        // Konect-style `%` headers, tabs, leading spaces.
        let txt = "% sym unweighted\n%more\n\t1\t2\n  2   3\n# snap too\n3 1\n";
        let g = parse_edge_list(Cursor::new(txt)).unwrap();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_edges(), 3);
    }

    #[test]
    fn only_self_loops_yields_empty_graph() {
        let g = parse_edge_list(Cursor::new("5 5\n7 7\n")).unwrap();
        assert_eq!(g.num_nodes(), 0);
        assert_eq!(g.num_edges(), 0);
    }

    #[test]
    fn parse_error_reports_line() {
        let err = parse_edge_list(Cursor::new("1 2\nxyz 4\n")).unwrap_err();
        match err {
            Error::Parse { line, .. } => assert_eq!(line, 2),
            other => panic!("expected parse error, got {other}"),
        }
    }

    #[test]
    fn missing_endpoint_rejected() {
        assert!(parse_edge_list(Cursor::new("7\n")).is_err());
    }

    #[test]
    fn edge_list_roundtrip() {
        let g = classic::karate();
        let dir = std::env::temp_dir().join("tricount_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("karate.txt");
        write_edge_list(&g, &p).unwrap();
        let g2 = read_edge_list(&p).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn binary_roundtrip() {
        let g = classic::petersen();
        let dir = std::env::temp_dir().join("tricount_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("petersen.bin");
        write_binary(&g, &p).unwrap();
        let g2 = read_binary(&p).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn binary_bad_magic() {
        let dir = std::env::temp_dir().join("tricount_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("junk.bin");
        std::fs::write(&p, b"NOTMAGIC rest").unwrap();
        assert!(read_binary(&p).is_err());
    }
}
