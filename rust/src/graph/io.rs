//! Graph I/O: whitespace-separated edge-list text (the SNAP interchange
//! format the paper's datasets ship in), the legacy `TRICSR01` binary dump,
//! and the versioned zero-parse `.tcg` format (magic, schema version, n/m,
//! offsets, packed u32 targets, FNV-1a integrity footer — DESIGN.md §12).
//!
//! The text parser is chunk-parallel: the input splits at newline
//! boundaries into `build_threads` byte chunks, each scanned by the PR-3
//! byte scanner into a private pair buffer, then stitched deterministically
//! — bit-identical to the serial scan at every thread count (the same
//! contract as the radix build, DESIGN.md §8).

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::graph::csr::Csr;
use crate::VertexId;

/// Read a SNAP-style edge list: one `u v` pair per line, `#`/`%` comments
/// and blank lines ignored, node ids need not be contiguous — they are
/// compacted to `0..n` preserving relative order.
pub fn read_edge_list<P: AsRef<Path>>(path: P) -> Result<Csr> {
    let f = File::open(path)?;
    parse_edge_list(BufReader::new(f))
}

/// Parse an edge list from any reader (see [`read_edge_list`]).
///
/// Byte-level scanner with hand-rolled integer parsing: the seed's UTF-8
/// line iterator allocated a `String` and re-validated UTF-8 per line,
/// which dominated load time on multi-million-edge dumps. SNAP/Konect
/// files are plain ASCII, so the scanner walks the raw bytes once,
/// folding the normalize pass into parsing — `(min, max)` orientation and
/// self-loop dropping happen as each pair is decoded. Memory tradeoff:
/// the whole input is slurped (`read_to_end`), so the text (~13 B/edge)
/// and the pair vector (16 B/edge) are briefly live together — fine for
/// the generated workloads this repo parses; a chunked `fill_buf` scan
/// carrying partial lines would reclaim that for multi-GB dumps. Both
/// orientations
/// of an undirected edge and verbatim repeats are still scrubbed here
/// (canonicalize, sort, dedup) rather than deferred: a node mentioned
/// only by self-loops must not survive id compaction. The builder then
/// receives pre-normalized edges and skips its own normalize pass.
pub fn parse_edge_list<R: BufRead>(mut r: R) -> Result<Csr> {
    let mut buf = Vec::new();
    r.read_to_end(&mut buf)?;
    parse_edge_list_bytes(&buf, crate::par::default_threads())
}

/// Floor on bytes per parse chunk: below this, thread spawn/join overhead
/// beats the scan win, so small inputs degrade toward the serial scan
/// (the `par::clamp_threads` rule, same as the builder's edge floor).
const MIN_PARSE_BYTES_PER_CHUNK: usize = 4096;

/// One chunk's scan state — the parallel parse's private buffer.
struct ChunkScan {
    /// Normalized `(min, max)` pairs decoded from this chunk.
    pairs: Vec<(u64, u64)>,
    /// Newlines this chunk consumed — the successors' line-number offset.
    newlines: usize,
    /// First parse error, at a 1-based line number local to this chunk.
    err: Option<(usize, String)>,
}

/// Demote a [`parse_u64`] error to its (local line, message) parts.
fn split_parse_err(e: Error) -> (usize, String) {
    match e {
        Error::Parse { line, msg } => (line, msg),
        other => (0, other.to_string()),
    }
}

/// The PR-3 byte scanner over one chunk. Chunks start at the byte after a
/// newline (or the input start), so line accounting is exact: the chunk's
/// line `k` is the document's line `newlines-before-chunk + k`.
fn scan_chunk(b: &[u8]) -> ChunkScan {
    let mut pairs: Vec<(u64, u64)> = Vec::with_capacity(b.len() / 12 + 1);
    let mut line = 1usize;
    let mut i = 0usize;
    let mut err = None;
    while i < b.len() {
        // Skip horizontal whitespace (spaces, tabs, CR of CRLF endings).
        while i < b.len() && matches!(b[i], b' ' | b'\t' | b'\r') {
            i += 1;
        }
        if i >= b.len() {
            break;
        }
        match b[i] {
            b'\n' => {
                line += 1;
                i += 1;
            }
            b'#' | b'%' => {
                // Comment line: skip to (not past) the newline.
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
            }
            _ => {
                let u = match parse_u64(b, &mut i, line) {
                    Ok(x) => x,
                    Err(e) => {
                        err = Some(split_parse_err(e));
                        break;
                    }
                };
                while i < b.len() && matches!(b[i], b' ' | b'\t' | b'\r') {
                    i += 1;
                }
                if i >= b.len() || b[i] == b'\n' {
                    err = Some((line, "missing endpoint".into()));
                    break;
                }
                let v = match parse_u64(b, &mut i, line) {
                    Ok(x) => x,
                    Err(e) => {
                        err = Some(split_parse_err(e));
                        break;
                    }
                };
                // Ignore the rest of the line (weights, timestamps).
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
                if u != v {
                    // Normalize inline: self loop dropped, (min, max) kept.
                    pairs.push(if u < v { (u, v) } else { (v, u) });
                }
            }
        }
    }
    ChunkScan { pairs, newlines: line - 1, err }
}

/// Chunk-parallel edge-list parse over an in-memory byte buffer.
///
/// The buffer splits at newline boundaries into up to `threads` chunks
/// (host-clamped, with a bytes-per-chunk floor), each scanned into a
/// private pair vector on the `par/` fork-join scope, then stitched in
/// chunk order. The stitch is deterministic by construction: the global
/// `sort_unstable + dedup` canonicalizes the pair multiset — which is
/// independent of chunk boundaries — so the output is **bit-identical to
/// the serial scan at every thread count**, and the first failing chunk's
/// error carries the same absolute line number the serial scan reports
/// (its predecessors completed, so their newline counts are exact).
pub fn parse_edge_list_bytes(b: &[u8], threads: usize) -> Result<Csr> {
    let threads = crate::par::clamp_to_host(threads);
    let t = crate::par::clamp_threads(threads, b.len(), MIN_PARSE_BYTES_PER_CHUNK);
    // Chunk bounds: near-equal byte ranges, each advanced past the next
    // newline so every line belongs to exactly one chunk.
    let mut bounds = Vec::with_capacity(t + 1);
    bounds.push(0usize);
    for r in crate::par::ranges(b.len(), t).iter().take(t - 1) {
        let mut cut = r.end.max(*bounds.last().unwrap());
        while cut < b.len() && b[cut] != b'\n' {
            cut += 1;
        }
        bounds.push((cut + 1).min(b.len()));
    }
    bounds.push(b.len());
    let chunks = bounds.len() - 1;
    let scans: Vec<ChunkScan> =
        crate::par::for_ranges(chunks, chunks, |c, _| scan_chunk(&b[bounds[c]..bounds[c + 1]]));

    // Stitch in chunk order. The first failing chunk holds the document's
    // first error (earlier chunks scanned their whole byte range cleanly).
    let mut line_offset = 0usize;
    let mut total = 0usize;
    for s in &scans {
        if let Some((local, msg)) = &s.err {
            return Err(Error::Parse { line: line_offset + local, msg: msg.clone() });
        }
        line_offset += s.newlines;
        total += s.pairs.len();
    }
    let mut raw: Vec<(u64, u64)> = Vec::with_capacity(total);
    for s in &scans {
        raw.extend_from_slice(&s.pairs);
    }
    drop(scans);
    raw.sort_unstable();
    raw.dedup();
    // Compact ids. The map is monotone, so mapped edges stay (min, max);
    // the id lookup is a pure per-edge function, so it parallelizes over
    // owned output chunks without touching the determinism contract.
    let mut ids: Vec<u64> = raw.iter().flat_map(|&(u, v)| [u, v]).collect();
    ids.sort_unstable();
    ids.dedup();
    let lookup = |x: u64| ids.binary_search(&x).unwrap() as VertexId;
    let mut edges: Vec<(VertexId, VertexId)> = vec![(0, 0); raw.len()];
    crate::par::for_chunks_mut(&mut edges, t, |_, start, chunk| {
        for (k, e) in chunk.iter_mut().enumerate() {
            let (u, v) = raw[start + k];
            *e = (lookup(u), lookup(v));
        }
    });
    crate::graph::builder::from_normalized_edge_list(ids.len(), edges, threads)
}

/// Decode one base-10 `u64` at `*i`, advancing past it. A token must be
/// digits terminated by whitespace or end-of-line — `12x` is malformed,
/// not an integer followed by junk (matching `str::parse`'s rejection).
fn parse_u64(b: &[u8], i: &mut usize, line: usize) -> Result<u64> {
    let start = *i;
    let mut x: u64 = 0;
    while *i < b.len() && b[*i].is_ascii_digit() {
        x = x
            .checked_mul(10)
            .and_then(|x| x.checked_add((b[*i] - b'0') as u64))
            .ok_or_else(|| Error::Parse { line, msg: "integer overflows u64".into() })?;
        *i += 1;
    }
    if *i == start {
        return Err(Error::Parse {
            line,
            msg: format!("expected an integer, found byte `{}`", b[*i].escape_ascii()),
        });
    }
    if *i < b.len() && !matches!(b[*i], b' ' | b'\t' | b'\r' | b'\n') {
        return Err(Error::Parse { line, msg: "malformed integer token".into() });
    }
    Ok(x)
}

/// Write a graph as an edge list (`u v` per line, each undirected edge once).
pub fn write_edge_list<P: AsRef<Path>>(g: &Csr, path: P) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    writeln!(w, "# tricount edge list: n={} m={}", g.num_nodes(), g.num_edges())?;
    for (u, v) in g.edges() {
        writeln!(w, "{u} {v}")?;
    }
    w.flush()?;
    Ok(())
}

const BIN_MAGIC: &[u8; 8] = b"TRICSR01";

/// Write the compact binary CSR format:
/// `magic | n: u64 | len(targets): u64 | offsets: (n+1)×u64 LE | targets: len×u32 LE`.
pub fn write_binary<P: AsRef<Path>>(g: &Csr, path: P) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(BIN_MAGIC)?;
    w.write_all(&(g.num_nodes() as u64).to_le_bytes())?;
    w.write_all(&(g.targets().len() as u64).to_le_bytes())?;
    for &o in g.offsets() {
        w.write_all(&o.to_le_bytes())?;
    }
    for &t in g.targets() {
        w.write_all(&t.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Read the binary CSR format written by [`write_binary`].
pub fn read_binary<P: AsRef<Path>>(path: P) -> Result<Csr> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != BIN_MAGIC {
        return Err(Error::Parse { line: 0, msg: "bad magic (not a TRICSR01 file)".into() });
    }
    let mut buf8 = [0u8; 8];
    r.read_exact(&mut buf8)?;
    let n = u64::from_le_bytes(buf8) as usize;
    r.read_exact(&mut buf8)?;
    let tl = u64::from_le_bytes(buf8) as usize;
    let mut offsets = vec![0u64; n + 1];
    for o in offsets.iter_mut() {
        r.read_exact(&mut buf8)?;
        *o = u64::from_le_bytes(buf8);
    }
    let mut targets = vec![0 as VertexId; tl];
    let mut buf4 = [0u8; 4];
    for t in targets.iter_mut() {
        r.read_exact(&mut buf4)?;
        *t = u32::from_le_bytes(buf4);
    }
    let g = Csr::from_parts(offsets, targets);
    Ok(g)
}

// ---------------------------------------------------------------------------
// .tcg — versioned zero-parse binary graph format (DESIGN.md §12)
// ---------------------------------------------------------------------------

/// `.tcg` magic bytes.
pub const TCG_MAGIC: &[u8; 8] = b"TCGRAPH1";

/// `.tcg` schema version this build writes and reads. Evolution is
/// append-only: new sections go between the targets array and the footer,
/// announced by `flags` bits; a reader rejects any *higher* version rather
/// than misread it (DESIGN.md §12).
pub const TCG_VERSION: u32 = 1;

/// Bytes ahead of the offsets array:
/// `magic[8] | version: u32 | flags: u32 | n: u64 | len(targets): u64`.
const TCG_HEADER_BYTES: usize = 8 + 4 + 4 + 8 + 8;

/// Streaming FNV-1a 64 over raw bytes (same constants as the
/// `testkit::trace` event fingerprint, which folds u64 events instead).
struct Fnv1a(u64);

impl Fnv1a {
    fn new() -> Self {
        Fnv1a(0xcbf2_9ce4_8422_2325)
    }

    fn update(&mut self, bytes: &[u8]) {
        let mut h = self.0;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        self.0 = h;
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

/// Write the `.tcg` zero-parse format: header, offsets as `(n+1)×u64` LE,
/// targets as `len×u32` LE, then an FNV-1a u64 footer over every preceding
/// byte. The payload streams through one 64 KiB scratch buffer, so the
/// writer never holds a second serialized copy of the graph.
pub fn write_tcg<P: AsRef<Path>>(g: &Csr, path: P) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    let mut hash = Fnv1a::new();
    let mut header = Vec::with_capacity(TCG_HEADER_BYTES);
    header.extend_from_slice(TCG_MAGIC);
    header.extend_from_slice(&TCG_VERSION.to_le_bytes());
    header.extend_from_slice(&0u32.to_le_bytes()); // flags: none defined in v1
    header.extend_from_slice(&(g.num_nodes() as u64).to_le_bytes());
    header.extend_from_slice(&(g.targets().len() as u64).to_le_bytes());
    hash.update(&header);
    w.write_all(&header)?;
    let mut buf: Vec<u8> = Vec::with_capacity(1 << 16);
    let mut flush = |w: &mut BufWriter<File>, hash: &mut Fnv1a, buf: &mut Vec<u8>| -> Result<()> {
        hash.update(buf);
        w.write_all(buf)?;
        buf.clear();
        Ok(())
    };
    for &o in g.offsets() {
        buf.extend_from_slice(&o.to_le_bytes());
        if buf.len() + 8 > (1 << 16) {
            flush(&mut w, &mut hash, &mut buf)?;
        }
    }
    for &t in g.targets() {
        buf.extend_from_slice(&t.to_le_bytes());
        if buf.len() + 8 > (1 << 16) {
            flush(&mut w, &mut hash, &mut buf)?;
        }
    }
    flush(&mut w, &mut hash, &mut buf)?;
    w.write_all(&hash.finish().to_le_bytes())?;
    w.flush()?;
    Ok(())
}

/// Load a `.tcg` file: header validation + two bulk `read_exact`s into
/// preallocated buffers + footer check — no tokenizing, no id compaction,
/// no sort. Cost collapses to the LE decode and the O(n + m) structural
/// validation.
///
/// Failure taxonomy: wrong magic / unsupported version / declared sizes
/// disagreeing with the file length / footer mismatch are all
/// [`Error::Config`] (the file is not a usable `.tcg`); a short read mid-
/// payload surfaces as [`Error::Io`] (`UnexpectedEof`) — never a panic —
/// and structurally invalid content behind a valid footer is
/// [`Error::InvalidGraph`]. The size check runs *before* any allocation,
/// so a corrupt header cannot drive a runaway allocation either.
pub fn read_tcg<P: AsRef<Path>>(path: P) -> Result<Csr> {
    let mut f = File::open(path)?;
    let file_len = f.metadata()?.len();
    let mut header = [0u8; TCG_HEADER_BYTES];
    f.read_exact(&mut header)?;
    if &header[..8] != TCG_MAGIC {
        return Err(Error::Config("not a .tcg file (bad magic)".into()));
    }
    let version = u32::from_le_bytes(header[8..12].try_into().unwrap());
    if version != TCG_VERSION {
        return Err(Error::Config(format!(
            ".tcg schema version {version} unsupported (this build reads {TCG_VERSION})"
        )));
    }
    let n64 = u64::from_le_bytes(header[16..24].try_into().unwrap());
    let tl64 = u64::from_le_bytes(header[24..32].try_into().unwrap());
    let expect = n64
        .checked_add(1)
        .and_then(|x| x.checked_mul(8))
        .and_then(|ob| tl64.checked_mul(4).and_then(|tb| ob.checked_add(tb)))
        .and_then(|x| x.checked_add(TCG_HEADER_BYTES as u64 + 8));
    if expect != Some(file_len) {
        return Err(Error::Config(format!(
            ".tcg size mismatch: header declares n={n64}, len(targets)={tl64} \
             ({} bytes expected, file has {file_len})",
            expect.map_or("overflowing".into(), |e| e.to_string())
        )));
    }
    let (n, tl) = (n64 as usize, tl64 as usize);
    let mut hash = Fnv1a::new();
    hash.update(&header);

    let mut obytes = vec![0u8; (n + 1) * 8];
    f.read_exact(&mut obytes)?;
    hash.update(&obytes);
    let mut offsets = vec![0u64; n + 1];
    for (o, c) in offsets.iter_mut().zip(obytes.chunks_exact(8)) {
        *o = u64::from_le_bytes(c.try_into().unwrap());
    }
    drop(obytes);

    let mut tbytes = vec![0u8; tl * 4];
    f.read_exact(&mut tbytes)?;
    hash.update(&tbytes);
    let mut targets = vec![0 as VertexId; tl];
    for (t, c) in targets.iter_mut().zip(tbytes.chunks_exact(4)) {
        *t = u32::from_le_bytes(c.try_into().unwrap());
    }
    drop(tbytes);

    let mut footer = [0u8; 8];
    f.read_exact(&mut footer)?;
    if u64::from_le_bytes(footer) != hash.finish() {
        return Err(Error::Config(
            ".tcg integrity footer mismatch (corrupt or partially written file)".into(),
        ));
    }
    // Structural validation before `Csr::from_parts` (whose checks are
    // debug-only): a well-footered but hand-mangled file must error, not
    // panic or smuggle an unsorted row into the kernels.
    if offsets.first() != Some(&0) || *offsets.last().unwrap() != tl as u64 {
        return Err(Error::InvalidGraph(".tcg offsets do not span the targets array".into()));
    }
    if offsets.windows(2).any(|w| w[0] > w[1]) {
        return Err(Error::InvalidGraph(".tcg offsets are not monotone".into()));
    }
    let g = Csr::from_parts(offsets, targets);
    g.validate().map_err(Error::InvalidGraph)?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::classic;
    use std::io::Cursor;

    #[test]
    fn parse_with_comments_and_gaps() {
        let txt = "# header\n10 20\n20 30\n\n% alt comment\n30 10\n";
        let g = parse_edge_list(Cursor::new(txt)).unwrap();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_edges(), 3);
    }

    #[test]
    fn duplicate_edges_merged_both_orientations() {
        // `u v` and `v u` (and a verbatim repeat) are one undirected edge.
        let txt = "1 2\n2 1\n1 2\n2 3\n";
        let g = parse_edge_list(Cursor::new(txt)).unwrap();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.degree(1), 2); // compacted id of node "2"
        g.validate().unwrap();
    }

    #[test]
    fn self_loops_dropped_at_parse_time() {
        // Node 9 appears only in a self-loop: it must not survive
        // compaction; the remaining graph is the single edge 1–2.
        let txt = "9 9\n1 2\n2 2\n";
        let g = parse_edge_list(Cursor::new(txt)).unwrap();
        assert_eq!(g.num_nodes(), 2);
        assert_eq!(g.num_edges(), 1);
        g.validate().unwrap();
    }

    #[test]
    fn percent_comments_and_whitespace_variants() {
        // Konect-style `%` headers, tabs, leading spaces.
        let txt = "% sym unweighted\n%more\n\t1\t2\n  2   3\n# snap too\n3 1\n";
        let g = parse_edge_list(Cursor::new(txt)).unwrap();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_edges(), 3);
    }

    #[test]
    fn only_self_loops_yields_empty_graph() {
        let g = parse_edge_list(Cursor::new("5 5\n7 7\n")).unwrap();
        assert_eq!(g.num_nodes(), 0);
        assert_eq!(g.num_edges(), 0);
    }

    #[test]
    fn parse_error_reports_line() {
        let err = parse_edge_list(Cursor::new("1 2\nxyz 4\n")).unwrap_err();
        match err {
            Error::Parse { line, .. } => assert_eq!(line, 2),
            other => panic!("expected parse error, got {other}"),
        }
    }

    #[test]
    fn missing_endpoint_rejected() {
        assert!(parse_edge_list(Cursor::new("7\n")).is_err());
        assert!(parse_edge_list(Cursor::new("7")).is_err(), "EOF after one token");
    }

    #[test]
    fn trailing_tokens_ignored_like_split_whitespace() {
        // SNAP dumps with weights/timestamps: only the first two tokens count.
        let g = parse_edge_list(Cursor::new("1 2 0.5 1234\n2 3 9\n")).unwrap();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_edges(), 2);
        g.validate().unwrap();
    }

    #[test]
    fn crlf_and_no_trailing_newline() {
        let g = parse_edge_list(Cursor::new("1 2\r\n2 3\r\n3 1")).unwrap();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_edges(), 3);
        g.validate().unwrap();
    }

    #[test]
    fn malformed_and_overflow_tokens_rejected_with_line() {
        for (txt, want_line) in [("1 2\n3 4x\n", 2), ("99999999999999999999999 1\n", 1)] {
            match parse_edge_list(Cursor::new(txt)).unwrap_err() {
                Error::Parse { line, .. } => assert_eq!(line, want_line, "{txt:?}"),
                other => panic!("expected parse error for {txt:?}, got {other}"),
            }
        }
    }

    #[test]
    fn edge_list_roundtrip() {
        let g = classic::karate();
        let dir = std::env::temp_dir().join("tricount_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("karate.txt");
        write_edge_list(&g, &p).unwrap();
        let g2 = read_edge_list(&p).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn binary_roundtrip() {
        let g = classic::petersen();
        let dir = std::env::temp_dir().join("tricount_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("petersen.bin");
        write_binary(&g, &p).unwrap();
        let g2 = read_binary(&p).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn binary_bad_magic() {
        let dir = std::env::temp_dir().join("tricount_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("junk.bin");
        std::fs::write(&p, b"NOTMAGIC rest").unwrap();
        assert!(read_binary(&p).is_err());
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("tricount_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn tcg_roundtrip() {
        for g in [classic::karate(), classic::petersen(), Csr::empty(0), Csr::empty(5)] {
            let p = tmp("roundtrip.tcg");
            write_tcg(&g, &p).unwrap();
            let g2 = read_tcg(&p).unwrap();
            assert_eq!(g, g2);
        }
    }

    #[test]
    fn tcg_corruption_taxonomy() {
        let p = tmp("corrupt.tcg");
        write_tcg(&classic::karate(), &p).unwrap();
        let good = std::fs::read(&p).unwrap();

        // Bad magic → Config.
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        std::fs::write(&p, &bad).unwrap();
        assert!(matches!(read_tcg(&p).unwrap_err(), Error::Config(_)), "magic");

        // Unsupported (future) version → Config.
        let mut bad = good.clone();
        bad[8] = 99;
        std::fs::write(&p, &bad).unwrap();
        assert!(matches!(read_tcg(&p).unwrap_err(), Error::Config(_)), "version");

        // Flipped payload byte → footer mismatch → Config.
        let mut bad = good.clone();
        let mid = TCG_HEADER_BYTES + 3;
        bad[mid] ^= 0x40;
        std::fs::write(&p, &bad).unwrap();
        assert!(matches!(read_tcg(&p).unwrap_err(), Error::Config(_)), "footer");

        // Flipped footer byte itself → Config.
        let mut bad = good.clone();
        *bad.last_mut().unwrap() ^= 1;
        std::fs::write(&p, &bad).unwrap();
        assert!(matches!(read_tcg(&p).unwrap_err(), Error::Config(_)), "footer bytes");

        // Header declaring more data than the file holds → Config before
        // any allocation (no runaway `vec![0; huge]`).
        let mut bad = good.clone();
        bad[16..24].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&p, &bad).unwrap();
        assert!(matches!(read_tcg(&p).unwrap_err(), Error::Config(_)), "size bomb");

        // Truncation at every boundary region: error (Config size check),
        // never a panic.
        for cut in [0, 4, TCG_HEADER_BYTES, good.len() - 9, good.len() - 1] {
            std::fs::write(&p, &good[..cut]).unwrap();
            assert!(read_tcg(&p).is_err(), "truncated at {cut}");
        }
    }

    #[test]
    fn chunked_parse_matches_serial_and_reports_serial_lines() {
        // A text with comments, blank lines, CRLF and ragged spacing, big
        // enough only via an explicit tiny chunk floor — so drive the
        // chunking through parse_edge_list_bytes at several thread counts.
        let mut txt = String::from("# header\n");
        for i in 0..2000u32 {
            txt.push_str(&format!("{} {}\n", i % 97, (i * 7) % 89 + 1));
        }
        let serial = parse_edge_list_bytes(txt.as_bytes(), 1).unwrap();
        for t in [2usize, 8] {
            let par = parse_edge_list_bytes(txt.as_bytes(), t).unwrap();
            assert_eq!(serial, par, "T={t}");
        }
        // Error line numbers must match the serial scan's regardless of
        // which chunk the bad token lands in.
        let mut bad = txt.clone();
        bad.push_str("oops 3\n");
        let want_line = 2002;
        for t in [1usize, 2, 8] {
            match parse_edge_list_bytes(bad.as_bytes(), t).unwrap_err() {
                Error::Parse { line, .. } => assert_eq!(line, want_line, "T={t}"),
                other => panic!("expected parse error, got {other}"),
            }
        }
    }
}
