//! Graph I/O: whitespace-separated edge-list text (the SNAP interchange
//! format the paper's datasets ship in) and a compact binary CSR format for
//! fast reloads of generated workloads.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::graph::csr::Csr;
use crate::VertexId;

/// Read a SNAP-style edge list: one `u v` pair per line, `#`/`%` comments
/// and blank lines ignored, node ids need not be contiguous — they are
/// compacted to `0..n` preserving relative order.
pub fn read_edge_list<P: AsRef<Path>>(path: P) -> Result<Csr> {
    let f = File::open(path)?;
    parse_edge_list(BufReader::new(f))
}

/// Parse an edge list from any reader (see [`read_edge_list`]).
///
/// Byte-level scanner with hand-rolled integer parsing: the seed's UTF-8
/// line iterator allocated a `String` and re-validated UTF-8 per line,
/// which dominated load time on multi-million-edge dumps. SNAP/Konect
/// files are plain ASCII, so the scanner walks the raw bytes once,
/// folding the normalize pass into parsing — `(min, max)` orientation and
/// self-loop dropping happen as each pair is decoded. Memory tradeoff:
/// the whole input is slurped (`read_to_end`), so the text (~13 B/edge)
/// and the pair vector (16 B/edge) are briefly live together — fine for
/// the generated workloads this repo parses; a chunked `fill_buf` scan
/// carrying partial lines would reclaim that for multi-GB dumps. Both
/// orientations
/// of an undirected edge and verbatim repeats are still scrubbed here
/// (canonicalize, sort, dedup) rather than deferred: a node mentioned
/// only by self-loops must not survive id compaction. The builder then
/// receives pre-normalized edges and skips its own normalize pass.
pub fn parse_edge_list<R: BufRead>(mut r: R) -> Result<Csr> {
    let mut buf = Vec::new();
    r.read_to_end(&mut buf)?;
    let b = &buf[..];
    let mut raw: Vec<(u64, u64)> = Vec::with_capacity(b.len() / 12 + 1);
    let mut line = 1usize;
    let mut i = 0usize;
    while i < b.len() {
        // Skip horizontal whitespace (spaces, tabs, CR of CRLF endings).
        while i < b.len() && matches!(b[i], b' ' | b'\t' | b'\r') {
            i += 1;
        }
        if i >= b.len() {
            break;
        }
        match b[i] {
            b'\n' => {
                line += 1;
                i += 1;
            }
            b'#' | b'%' => {
                // Comment line: skip to (not past) the newline.
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
            }
            _ => {
                let u = parse_u64(b, &mut i, line)?;
                while i < b.len() && matches!(b[i], b' ' | b'\t' | b'\r') {
                    i += 1;
                }
                if i >= b.len() || b[i] == b'\n' {
                    return Err(Error::Parse { line, msg: "missing endpoint".into() });
                }
                let v = parse_u64(b, &mut i, line)?;
                // Ignore the rest of the line (weights, timestamps).
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
                if u != v {
                    // Normalize inline: self loop dropped, (min, max) kept.
                    raw.push(if u < v { (u, v) } else { (v, u) });
                }
            }
        }
    }
    raw.sort_unstable();
    raw.dedup();
    // Compact ids. The map is monotone, so mapped edges stay (min, max).
    let mut ids: Vec<u64> = raw.iter().flat_map(|&(u, v)| [u, v]).collect();
    ids.sort_unstable();
    ids.dedup();
    let lookup = |x: u64| ids.binary_search(&x).unwrap() as VertexId;
    let edges: Vec<(VertexId, VertexId)> = raw.iter().map(|&(u, v)| (lookup(u), lookup(v))).collect();
    crate::graph::builder::from_normalized_edge_list(ids.len(), edges, crate::par::default_threads())
}

/// Decode one base-10 `u64` at `*i`, advancing past it. A token must be
/// digits terminated by whitespace or end-of-line — `12x` is malformed,
/// not an integer followed by junk (matching `str::parse`'s rejection).
fn parse_u64(b: &[u8], i: &mut usize, line: usize) -> Result<u64> {
    let start = *i;
    let mut x: u64 = 0;
    while *i < b.len() && b[*i].is_ascii_digit() {
        x = x
            .checked_mul(10)
            .and_then(|x| x.checked_add((b[*i] - b'0') as u64))
            .ok_or_else(|| Error::Parse { line, msg: "integer overflows u64".into() })?;
        *i += 1;
    }
    if *i == start {
        return Err(Error::Parse {
            line,
            msg: format!("expected an integer, found byte `{}`", b[*i].escape_ascii()),
        });
    }
    if *i < b.len() && !matches!(b[*i], b' ' | b'\t' | b'\r' | b'\n') {
        return Err(Error::Parse { line, msg: "malformed integer token".into() });
    }
    Ok(x)
}

/// Write a graph as an edge list (`u v` per line, each undirected edge once).
pub fn write_edge_list<P: AsRef<Path>>(g: &Csr, path: P) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    writeln!(w, "# tricount edge list: n={} m={}", g.num_nodes(), g.num_edges())?;
    for (u, v) in g.edges() {
        writeln!(w, "{u} {v}")?;
    }
    w.flush()?;
    Ok(())
}

const BIN_MAGIC: &[u8; 8] = b"TRICSR01";

/// Write the compact binary CSR format:
/// `magic | n: u64 | len(targets): u64 | offsets: (n+1)×u64 LE | targets: len×u32 LE`.
pub fn write_binary<P: AsRef<Path>>(g: &Csr, path: P) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(BIN_MAGIC)?;
    w.write_all(&(g.num_nodes() as u64).to_le_bytes())?;
    w.write_all(&(g.targets().len() as u64).to_le_bytes())?;
    for &o in g.offsets() {
        w.write_all(&o.to_le_bytes())?;
    }
    for &t in g.targets() {
        w.write_all(&t.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Read the binary CSR format written by [`write_binary`].
pub fn read_binary<P: AsRef<Path>>(path: P) -> Result<Csr> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != BIN_MAGIC {
        return Err(Error::Parse { line: 0, msg: "bad magic (not a TRICSR01 file)".into() });
    }
    let mut buf8 = [0u8; 8];
    r.read_exact(&mut buf8)?;
    let n = u64::from_le_bytes(buf8) as usize;
    r.read_exact(&mut buf8)?;
    let tl = u64::from_le_bytes(buf8) as usize;
    let mut offsets = vec![0u64; n + 1];
    for o in offsets.iter_mut() {
        r.read_exact(&mut buf8)?;
        *o = u64::from_le_bytes(buf8);
    }
    let mut targets = vec![0 as VertexId; tl];
    let mut buf4 = [0u8; 4];
    for t in targets.iter_mut() {
        r.read_exact(&mut buf4)?;
        *t = u32::from_le_bytes(buf4);
    }
    let g = Csr::from_parts(offsets, targets);
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::classic;
    use std::io::Cursor;

    #[test]
    fn parse_with_comments_and_gaps() {
        let txt = "# header\n10 20\n20 30\n\n% alt comment\n30 10\n";
        let g = parse_edge_list(Cursor::new(txt)).unwrap();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_edges(), 3);
    }

    #[test]
    fn duplicate_edges_merged_both_orientations() {
        // `u v` and `v u` (and a verbatim repeat) are one undirected edge.
        let txt = "1 2\n2 1\n1 2\n2 3\n";
        let g = parse_edge_list(Cursor::new(txt)).unwrap();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.degree(1), 2); // compacted id of node "2"
        g.validate().unwrap();
    }

    #[test]
    fn self_loops_dropped_at_parse_time() {
        // Node 9 appears only in a self-loop: it must not survive
        // compaction; the remaining graph is the single edge 1–2.
        let txt = "9 9\n1 2\n2 2\n";
        let g = parse_edge_list(Cursor::new(txt)).unwrap();
        assert_eq!(g.num_nodes(), 2);
        assert_eq!(g.num_edges(), 1);
        g.validate().unwrap();
    }

    #[test]
    fn percent_comments_and_whitespace_variants() {
        // Konect-style `%` headers, tabs, leading spaces.
        let txt = "% sym unweighted\n%more\n\t1\t2\n  2   3\n# snap too\n3 1\n";
        let g = parse_edge_list(Cursor::new(txt)).unwrap();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_edges(), 3);
    }

    #[test]
    fn only_self_loops_yields_empty_graph() {
        let g = parse_edge_list(Cursor::new("5 5\n7 7\n")).unwrap();
        assert_eq!(g.num_nodes(), 0);
        assert_eq!(g.num_edges(), 0);
    }

    #[test]
    fn parse_error_reports_line() {
        let err = parse_edge_list(Cursor::new("1 2\nxyz 4\n")).unwrap_err();
        match err {
            Error::Parse { line, .. } => assert_eq!(line, 2),
            other => panic!("expected parse error, got {other}"),
        }
    }

    #[test]
    fn missing_endpoint_rejected() {
        assert!(parse_edge_list(Cursor::new("7\n")).is_err());
        assert!(parse_edge_list(Cursor::new("7")).is_err(), "EOF after one token");
    }

    #[test]
    fn trailing_tokens_ignored_like_split_whitespace() {
        // SNAP dumps with weights/timestamps: only the first two tokens count.
        let g = parse_edge_list(Cursor::new("1 2 0.5 1234\n2 3 9\n")).unwrap();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_edges(), 2);
        g.validate().unwrap();
    }

    #[test]
    fn crlf_and_no_trailing_newline() {
        let g = parse_edge_list(Cursor::new("1 2\r\n2 3\r\n3 1")).unwrap();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_edges(), 3);
        g.validate().unwrap();
    }

    #[test]
    fn malformed_and_overflow_tokens_rejected_with_line() {
        for (txt, want_line) in [("1 2\n3 4x\n", 2), ("99999999999999999999999 1\n", 1)] {
            match parse_edge_list(Cursor::new(txt)).unwrap_err() {
                Error::Parse { line, .. } => assert_eq!(line, want_line, "{txt:?}"),
                other => panic!("expected parse error for {txt:?}, got {other}"),
            }
        }
    }

    #[test]
    fn edge_list_roundtrip() {
        let g = classic::karate();
        let dir = std::env::temp_dir().join("tricount_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("karate.txt");
        write_edge_list(&g, &p).unwrap();
        let g2 = read_edge_list(&p).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn binary_roundtrip() {
        let g = classic::petersen();
        let dir = std::env::temp_dir().join("tricount_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("petersen.bin");
        write_binary(&g, &p).unwrap();
        let g2 = read_binary(&p).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn binary_bad_magic() {
        let dir = std::env::temp_dir().join("tricount_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("junk.bin");
        std::fs::write(&p, b"NOTMAGIC rest").unwrap();
        assert!(read_binary(&p).is_err());
    }
}
