//! Degree-based total order `≺` and the oriented adjacency `N_v`.
//!
//! The paper (after [15], [16], [21]) orders nodes by
//! `u ≺ v ⇔ d_u < d_v or (d_u = d_v and u < v)` and keeps, for every node,
//! only the *higher-ordered* neighbors: `N_v = {u : (u,v) ∈ E, v ≺ u}`.
//! Each triangle `x₁ ≺ x₂ ≺ x₃` then survives exactly once, as
//! `x₂, x₃ ∈ N_{x₁}` and `x₃ ∈ N_{x₂}`, and is found by the intersection
//! `N_{x₁} ∩ N_{x₂}`.
//!
//! `N_v` is stored sorted ascending **by node id** (not by `≺`): the
//! intersection kernels need a common sort key, and the surrogate
//! algorithm's `LastProc` trick (§IV-C) needs nodes belonging to the same
//! consecutive-id partition to sit consecutively inside `N_v`.
//!
//! Rows whose oriented out-degree reaches the hub threshold additionally
//! get a packed [`BitmapRow`] (built here, at construction), so every
//! consumer that intersects through [`Oriented::view`] +
//! [`crate::adj::intersect_count`] gets the probe / word-AND kernels on
//! hub pairs for free. `from_graph` uses the `auto` density rule; see
//! [`HubThreshold`].

use crate::adj::bitmap::BitmapRow;
use crate::adj::hub::{HubIndex, HubStats, HubThreshold};
use crate::adj::view::NeighborView;
use crate::graph::csr::Csr;
use crate::VertexId;

/// The `≺` comparison given a degree lookup.
#[inline]
pub fn precedes(deg_u: u32, u: VertexId, deg_v: u32, v: VertexId) -> bool {
    deg_u < deg_v || (deg_u == deg_v && u < v)
}

/// Degree-ordered oriented adjacency: for every `v`, the sorted list
/// `N_v = {u ∈ 𝒩_v : v ≺ u}` plus the original degrees (kept because `≺`
/// and the cost estimators need `d_v` after orientation).
#[derive(Clone, Debug)]
pub struct Oriented {
    offsets: Vec<u64>,
    targets: Vec<VertexId>,
    degree: Vec<u32>,
    hubs: HubIndex,
}

/// Below this many rows a multi-thread orientation request degrades toward
/// serial (spawn overhead beats the per-row work).
const MIN_ROWS_PER_THREAD: usize = 4096;

impl Oriented {
    /// Orient a CSR graph by `≺` with the default (`auto`) hub threshold.
    /// O(m); runs on [`crate::par::default_threads`] threads.
    pub fn from_graph(g: &Csr) -> Self {
        Self::from_graph_with(g, HubThreshold::default())
    }

    /// Orient with an explicit hub-bitmap threshold policy.
    pub fn from_graph_with(g: &Csr, hub_threshold: HubThreshold) -> Self {
        Self::from_graph_threads(g, hub_threshold, crate::par::default_threads())
    }

    /// [`Oriented::from_graph_with`] at an explicit thread count. Every
    /// phase is a pure per-row function of the input CSR (count, filter,
    /// bitmap-pack), parallelized over contiguous node ranges whose target
    /// spans are disjoint `split_at_mut` chunks — so the result is
    /// bit-identical at every thread count.
    pub fn from_graph_threads(g: &Csr, hub_threshold: HubThreshold, threads: usize) -> Self {
        let n = g.num_nodes();
        // Host clamp before the shape floor: oversubscribing cores never
        // wins for fork-join row sweeps (see `par::clamp_to_host`).
        let t = crate::par::clamp_threads(crate::par::clamp_to_host(threads), n, MIN_ROWS_PER_THREAD);

        // Degrees, per row.
        let mut degree = vec![0u32; n];
        crate::par::for_chunks_mut(&mut degree, t, |_, start, chunk| {
            for (i, d) in chunk.iter_mut().enumerate() {
                *d = g.degree((start + i) as VertexId) as u32;
            }
        });

        // Oriented out-degrees, then a serial prefix into offsets.
        let mut offsets = vec![0u64; n + 1];
        crate::par::for_chunks_mut(&mut offsets[1..], t, |_, start, chunk| {
            for (i, o) in chunk.iter_mut().enumerate() {
                let v = (start + i) as VertexId;
                let dv = degree[v as usize];
                *o = g
                    .neighbors(v)
                    .iter()
                    .filter(|&&u| precedes(dv, v, degree[u as usize], u))
                    .count() as u64;
            }
        });
        for v in 0..n {
            offsets[v + 1] += offsets[v];
        }

        // Fill N_v rows; each part owns the contiguous target span of its
        // node range. Source lists are id-sorted; filtering keeps order.
        let vranges = crate::par::ranges(n, t);
        let total = *offsets.last().unwrap() as usize;
        let bounds: Vec<usize> = vranges
            .iter()
            .map(|r| offsets[r.start] as usize)
            .chain([total])
            .collect();
        let mut targets = vec![0 as VertexId; total];
        crate::par::for_uneven_chunks_mut(&mut targets, &bounds, |ti, _, out| {
            let mut w = 0usize;
            for v in vranges[ti].clone() {
                let v32 = v as VertexId;
                let dv = degree[v];
                for &u in g.neighbors(v32) {
                    if precedes(dv, v32, degree[u as usize], u) {
                        out[w] = u;
                        w += 1;
                    }
                }
            }
            debug_assert_eq!(w, out.len());
        });
        let hubs = HubIndex::build_threads(&offsets, &targets, hub_threshold, t);
        Oriented { offsets, targets, degree, hubs }
    }

    /// Number of nodes.
    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Total oriented edges — equals `m` of the source graph.
    #[inline]
    pub fn num_edges(&self) -> u64 {
        self.targets.len() as u64
    }

    /// `N_v`, sorted ascending by node id.
    #[inline]
    pub fn nbrs(&self, v: VertexId) -> &[VertexId] {
        let s = self.offsets[v as usize] as usize;
        let e = self.offsets[v as usize + 1] as usize;
        &self.targets[s..e]
    }

    /// `N_v` as a [`NeighborView`]: the sorted slice plus, for hub rows,
    /// the bitmap — what every counting path hands to
    /// [`crate::adj::intersect_count`].
    #[inline]
    pub fn view(&self, v: VertexId) -> NeighborView<'_> {
        NeighborView::hybrid(self.nbrs(v), self.hubs.get(v))
    }

    /// The bitmap row of `v`, when `v` is a hub.
    #[inline]
    pub fn hub_row(&self, v: VertexId) -> Option<&BitmapRow> {
        self.hubs.get(v)
    }

    /// What the hybrid dispatch charges for `N_v ∩ N_u`, in element steps —
    /// the true-execution cost measure shared by `node_work_true`, the
    /// simulators and the `hybrid` cost estimator.
    #[inline]
    pub fn intersect_cost(&self, v: VertexId, u: VertexId) -> u64 {
        crate::adj::intersect_cost(self.view(v), self.view(u))
    }

    /// Representation statistics (resolved threshold, hub rows, bytes).
    pub fn hub_stats(&self) -> HubStats {
        self.hubs.stats()
    }

    /// Effective degree `d̂_v = |N_v|`.
    #[inline]
    pub fn effective_degree(&self, v: VertexId) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    /// Original degree `d_v` in the undirected graph.
    #[inline]
    pub fn degree(&self, v: VertexId) -> u32 {
        self.degree[v as usize]
    }

    /// `u ≺ v` under this orientation's degree data.
    #[inline]
    pub fn precedes(&self, u: VertexId, v: VertexId) -> bool {
        precedes(self.degree[u as usize], u, self.degree[v as usize], v)
    }

    /// Raw offsets (length n+1).
    pub fn offsets(&self) -> &[u64] {
        &self.offsets
    }

    /// Raw targets (length m).
    pub fn targets(&self) -> &[VertexId] {
        &self.targets
    }

    /// Degrees slice.
    pub fn degrees(&self) -> &[u32] {
        &self.degree
    }

    /// Bytes held by this structure (offsets + targets + degrees + hub
    /// bitmaps).
    pub fn memory_bytes(&self) -> u64 {
        (self.offsets.len() * 8 + self.targets.len() * 4 + self.degree.len() * 4) as u64
            + self.hubs.bytes()
    }

    /// Relabel every vertex by `perm` (`perm[v]` is `v`'s new id),
    /// keeping the directed structure: `perm[u] ∈ N'_{perm[v]} ⇔ u ∈ N_v`.
    /// Rows are re-sorted by new id, degrees travel with their vertices,
    /// and the hub index is rebuilt under `hub` over the new rows. The
    /// mask (which oriented edges exist) was decided *before* the
    /// relabel, so triangle counts are invariant — but the id tie-break
    /// of `≺` is not re-derived, so [`Oriented::validate`] only holds for
    /// the original labeling. Used by
    /// [`crate::partition::tile2d::shuffled`] to decorrelate id intervals
    /// from degree. O(m log d̂) for the per-row sorts.
    pub fn relabeled(&self, perm: &[VertexId], hub: HubThreshold) -> Oriented {
        let n = self.num_nodes();
        assert_eq!(perm.len(), n, "perm must cover the id space");
        let mut degree = vec![0u32; n];
        let mut offsets = vec![0u64; n + 1];
        for v in 0..n {
            let nv = perm[v] as usize;
            degree[nv] = self.degree[v];
            offsets[nv + 1] = self.offsets[v + 1] - self.offsets[v];
        }
        for v in 0..n {
            offsets[v + 1] += offsets[v];
        }
        let mut targets = vec![0 as VertexId; self.targets.len()];
        let mut cursor: Vec<u64> = offsets[..n].to_vec();
        for v in 0..n {
            let nv = perm[v] as usize;
            for &u in self.nbrs(v as VertexId) {
                targets[cursor[nv] as usize] = perm[u as usize];
                cursor[nv] += 1;
            }
        }
        for v in 0..n {
            targets[offsets[v] as usize..offsets[v + 1] as usize].sort_unstable();
        }
        let hubs = HubIndex::build_threads(&offsets, &targets, hub, 1);
        Oriented { offsets, targets, degree, hubs }
    }

    /// Check orientation invariants (tests only; O(m log m)).
    pub fn validate(&self, g: &Csr) -> Result<(), String> {
        if self.num_nodes() != g.num_nodes() {
            return Err("node count mismatch".into());
        }
        if self.num_edges() != g.num_edges() {
            return Err(format!(
                "oriented edges {} != m {}",
                self.num_edges(),
                g.num_edges()
            ));
        }
        for v in 0..g.num_nodes() as VertexId {
            let ns = self.nbrs(v);
            for w in ns.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("N_{v} not strictly id-sorted"));
                }
            }
            for &u in ns {
                if !self.precedes(v, u) {
                    return Err(format!("edge ({v},{u}) violates v ≺ u"));
                }
                if !g.has_edge(v, u) {
                    return Err(format!("oriented edge ({v},{u}) not in G"));
                }
            }
        }
        // Hub-index invariants: every bitmap encodes exactly its row and
        // respects the cutoff.
        self.hubs.validate(&self.offsets, &self.targets)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_edges;
    use crate::graph::classic;

    #[test]
    fn star_orients_toward_hub() {
        // Star K_{1,4}: leaves (deg 1) ≺ hub (deg 4).
        let g = from_edges(5, [(0, 1), (0, 2), (0, 3), (0, 4)]).unwrap();
        let o = Oriented::from_graph(&g);
        assert_eq!(o.effective_degree(0), 0);
        for v in 1..5 {
            assert_eq!(o.nbrs(v), &[0]);
        }
        o.validate(&g).unwrap();
    }

    #[test]
    fn ties_broken_by_id() {
        // Triangle: all degree 2; ordering falls back to ids.
        let g = classic::complete(3);
        let o = Oriented::from_graph(&g);
        assert_eq!(o.nbrs(0), &[1, 2]);
        assert_eq!(o.nbrs(1), &[2]);
        assert_eq!(o.nbrs(2), &[] as &[VertexId]);
    }

    #[test]
    fn oriented_edge_count_equals_m() {
        let g = classic::complete(10);
        let o = Oriented::from_graph(&g);
        assert_eq!(o.num_edges(), g.num_edges());
        o.validate(&g).unwrap();
    }

    #[test]
    fn effective_degree_bounded_for_complete_graph() {
        // In K_n with id tie-breaks, d̂_v = n-1-v.
        let g = classic::complete(6);
        let o = Oriented::from_graph(&g);
        for v in 0..6u32 {
            assert_eq!(o.effective_degree(v), 5 - v as usize);
        }
    }

    #[test]
    fn hub_rows_respect_threshold_and_count_identically() {
        let g = classic::karate();
        let seq = crate::seq::node_iterator::count(&Oriented::from_graph(&g));
        for t in [HubThreshold::Off, HubThreshold::Auto, HubThreshold::Fixed(0), HubThreshold::Fixed(1), HubThreshold::Fixed(5)] {
            let o = Oriented::from_graph_with(&g, t);
            o.validate(&g).unwrap();
            assert_eq!(crate::seq::node_iterator::count(&o), seq, "{t}");
        }
        // Threshold 0 bitmaps every row; off bitmaps none.
        let all = Oriented::from_graph_with(&g, HubThreshold::Fixed(0));
        assert_eq!(all.hub_stats().hubs, g.num_nodes());
        let off = Oriented::from_graph_with(&g, HubThreshold::Off);
        assert_eq!(off.hub_stats().hubs, 0);
        assert_eq!(off.hub_stats().threshold, None);
        assert!(all.memory_bytes() > off.memory_bytes());
    }

    #[test]
    fn view_exposes_bitmap_exactly_for_hubs() {
        let g = classic::complete(8); // d̂_v = 7 - v
        let o = Oriented::from_graph_with(&g, HubThreshold::Fixed(4));
        for v in 0..8u32 {
            assert_eq!(o.view(v).is_hub(), o.effective_degree(v) >= 4, "node {v}");
            assert_eq!(o.view(v).list(), o.nbrs(v));
            assert_eq!(o.hub_row(v).is_some(), o.view(v).is_hub());
        }
    }

    #[test]
    fn intersect_cost_reflects_kernel_choice() {
        // K_8 with threshold 4: pair (0, 1) is hub×hub (d̂ 7 and 6) and the
        // dense span makes word-AND cheapest; a list×list pair charges the
        // adaptive cost.
        let g = classic::complete(8);
        let o = Oriented::from_graph_with(&g, HubThreshold::Fixed(4));
        assert_eq!(o.intersect_cost(0, 1), 1, "one shared word");
        let off = Oriented::from_graph_with(&g, HubThreshold::Off);
        assert_eq!(
            off.intersect_cost(0, 1),
            crate::intersect::adaptive_cost(7, 6)
        );
    }

    #[test]
    fn threaded_orientation_bit_identical_to_serial() {
        // n well past MIN_ROWS_PER_THREAD so the clamp leaves real
        // parallelism in play.
        let g = crate::gen::pa::preferential_attachment(
            20_000,
            8,
            &mut crate::gen::rng::Rng::seeded(17),
        );
        for policy in [HubThreshold::Auto, HubThreshold::Off, HubThreshold::Fixed(4)] {
            let serial = Oriented::from_graph_threads(&g, policy, 1);
            for t in [2, 8] {
                let par = Oriented::from_graph_threads(&g, policy, t);
                assert_eq!(par.offsets(), serial.offsets(), "{policy} T={t}");
                assert_eq!(par.targets(), serial.targets(), "{policy} T={t}");
                assert_eq!(par.degrees(), serial.degrees(), "{policy} T={t}");
                assert_eq!(par.hub_stats(), serial.hub_stats(), "{policy} T={t}");
                par.validate(&g).unwrap();
            }
        }
    }

    #[test]
    fn relabeled_preserves_structure_and_count() {
        let g = classic::karate();
        let o = Oriented::from_graph(&g);
        let n = g.num_nodes();
        // Reversal is the worst-case relabel for sortedness: every row
        // must be re-sorted end to end.
        let perm: Vec<VertexId> = (0..n as VertexId).map(|v| (n as u32 - 1) - v).collect();
        let r = o.relabeled(&perm, HubThreshold::Auto);
        assert_eq!(r.num_nodes(), n);
        assert_eq!(r.num_edges(), o.num_edges());
        for v in 0..n as VertexId {
            assert_eq!(r.degree(perm[v as usize]), o.degree(v));
            let mut want: Vec<VertexId> =
                o.nbrs(v).iter().map(|&u| perm[u as usize]).collect();
            want.sort_unstable();
            assert_eq!(r.nbrs(perm[v as usize]), &want[..], "row {v}");
        }
        assert_eq!(
            crate::seq::node_iterator::count(&r),
            crate::seq::node_iterator::count(&o),
            "triangle count is relabel-invariant"
        );
    }

    #[test]
    fn precedes_is_total_and_antisymmetric() {
        let g = classic::karate();
        let o = Oriented::from_graph(&g);
        let n = g.num_nodes() as VertexId;
        for u in 0..n {
            for v in 0..n {
                if u != v {
                    assert_ne!(o.precedes(u, v), o.precedes(v, u));
                }
            }
        }
    }
}
