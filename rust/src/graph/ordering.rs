//! Degree-based total order `≺` and the oriented adjacency `N_v`.
//!
//! The paper (after [15], [16], [21]) orders nodes by
//! `u ≺ v ⇔ d_u < d_v or (d_u = d_v and u < v)` and keeps, for every node,
//! only the *higher-ordered* neighbors: `N_v = {u : (u,v) ∈ E, v ≺ u}`.
//! Each triangle `x₁ ≺ x₂ ≺ x₃` then survives exactly once, as
//! `x₂, x₃ ∈ N_{x₁}` and `x₃ ∈ N_{x₂}`, and is found by the intersection
//! `N_{x₁} ∩ N_{x₂}`.
//!
//! `N_v` is stored sorted ascending **by node id** (not by `≺`): the
//! intersection kernels need a common sort key, and the surrogate
//! algorithm's `LastProc` trick (§IV-C) needs nodes belonging to the same
//! consecutive-id partition to sit consecutively inside `N_v`.

use crate::graph::csr::Csr;
use crate::VertexId;

/// The `≺` comparison given a degree lookup.
#[inline]
pub fn precedes(deg_u: u32, u: VertexId, deg_v: u32, v: VertexId) -> bool {
    deg_u < deg_v || (deg_u == deg_v && u < v)
}

/// Degree-ordered oriented adjacency: for every `v`, the sorted list
/// `N_v = {u ∈ 𝒩_v : v ≺ u}` plus the original degrees (kept because `≺`
/// and the cost estimators need `d_v` after orientation).
#[derive(Clone, Debug)]
pub struct Oriented {
    offsets: Vec<u64>,
    targets: Vec<VertexId>,
    degree: Vec<u32>,
}

impl Oriented {
    /// Orient a CSR graph by `≺`. O(m).
    pub fn from_graph(g: &Csr) -> Self {
        let n = g.num_nodes();
        let degree: Vec<u32> = (0..n as VertexId).map(|v| g.degree(v) as u32).collect();
        let mut offsets = vec![0u64; n + 1];
        for v in 0..n as VertexId {
            let dv = degree[v as usize];
            let cnt = g
                .neighbors(v)
                .iter()
                .filter(|&&u| precedes(dv, v, degree[u as usize], u))
                .count() as u64;
            offsets[v as usize + 1] = offsets[v as usize] + cnt;
        }
        let mut targets = vec![0 as VertexId; *offsets.last().unwrap() as usize];
        for v in 0..n as VertexId {
            let dv = degree[v as usize];
            let mut w = offsets[v as usize] as usize;
            // Source list is id-sorted; the filtered list stays id-sorted.
            for &u in g.neighbors(v) {
                if precedes(dv, v, degree[u as usize], u) {
                    targets[w] = u;
                    w += 1;
                }
            }
            debug_assert_eq!(w as u64, offsets[v as usize + 1]);
        }
        Oriented { offsets, targets, degree }
    }

    /// Number of nodes.
    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Total oriented edges — equals `m` of the source graph.
    #[inline]
    pub fn num_edges(&self) -> u64 {
        self.targets.len() as u64
    }

    /// `N_v`, sorted ascending by node id.
    #[inline]
    pub fn nbrs(&self, v: VertexId) -> &[VertexId] {
        let s = self.offsets[v as usize] as usize;
        let e = self.offsets[v as usize + 1] as usize;
        &self.targets[s..e]
    }

    /// Effective degree `d̂_v = |N_v|`.
    #[inline]
    pub fn effective_degree(&self, v: VertexId) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    /// Original degree `d_v` in the undirected graph.
    #[inline]
    pub fn degree(&self, v: VertexId) -> u32 {
        self.degree[v as usize]
    }

    /// `u ≺ v` under this orientation's degree data.
    #[inline]
    pub fn precedes(&self, u: VertexId, v: VertexId) -> bool {
        precedes(self.degree[u as usize], u, self.degree[v as usize], v)
    }

    /// Raw offsets (length n+1).
    pub fn offsets(&self) -> &[u64] {
        &self.offsets
    }

    /// Raw targets (length m).
    pub fn targets(&self) -> &[VertexId] {
        &self.targets
    }

    /// Degrees slice.
    pub fn degrees(&self) -> &[u32] {
        &self.degree
    }

    /// Bytes held by this structure (offsets + targets + degrees).
    pub fn memory_bytes(&self) -> u64 {
        (self.offsets.len() * 8 + self.targets.len() * 4 + self.degree.len() * 4) as u64
    }

    /// Check orientation invariants (tests only; O(m log m)).
    pub fn validate(&self, g: &Csr) -> Result<(), String> {
        if self.num_nodes() != g.num_nodes() {
            return Err("node count mismatch".into());
        }
        if self.num_edges() != g.num_edges() {
            return Err(format!(
                "oriented edges {} != m {}",
                self.num_edges(),
                g.num_edges()
            ));
        }
        for v in 0..g.num_nodes() as VertexId {
            let ns = self.nbrs(v);
            for w in ns.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("N_{v} not strictly id-sorted"));
                }
            }
            for &u in ns {
                if !self.precedes(v, u) {
                    return Err(format!("edge ({v},{u}) violates v ≺ u"));
                }
                if !g.has_edge(v, u) {
                    return Err(format!("oriented edge ({v},{u}) not in G"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_edges;
    use crate::graph::classic;

    #[test]
    fn star_orients_toward_hub() {
        // Star K_{1,4}: leaves (deg 1) ≺ hub (deg 4).
        let g = from_edges(5, [(0, 1), (0, 2), (0, 3), (0, 4)]).unwrap();
        let o = Oriented::from_graph(&g);
        assert_eq!(o.effective_degree(0), 0);
        for v in 1..5 {
            assert_eq!(o.nbrs(v), &[0]);
        }
        o.validate(&g).unwrap();
    }

    #[test]
    fn ties_broken_by_id() {
        // Triangle: all degree 2; ordering falls back to ids.
        let g = classic::complete(3);
        let o = Oriented::from_graph(&g);
        assert_eq!(o.nbrs(0), &[1, 2]);
        assert_eq!(o.nbrs(1), &[2]);
        assert_eq!(o.nbrs(2), &[] as &[VertexId]);
    }

    #[test]
    fn oriented_edge_count_equals_m() {
        let g = classic::complete(10);
        let o = Oriented::from_graph(&g);
        assert_eq!(o.num_edges(), g.num_edges());
        o.validate(&g).unwrap();
    }

    #[test]
    fn effective_degree_bounded_for_complete_graph() {
        // In K_n with id tie-breaks, d̂_v = n-1-v.
        let g = classic::complete(6);
        let o = Oriented::from_graph(&g);
        for v in 0..6u32 {
            assert_eq!(o.effective_degree(v), 5 - v as usize);
        }
    }

    #[test]
    fn precedes_is_total_and_antisymmetric() {
        let g = classic::karate();
        let o = Oriented::from_graph(&g);
        let n = g.num_nodes() as VertexId;
        for u in 0..n {
            for v in 0..n {
                if u != v {
                    assert_ne!(o.precedes(u, v), o.precedes(v, u));
                }
            }
        }
    }
}
