//! Edge-list → CSR construction.
//!
//! Deduplicates parallel edges, drops self loops, symmetrizes, and sorts
//! adjacency lists — producing a [`Csr`] that satisfies all its invariants.

use crate::error::{Error, Result};
use crate::graph::csr::Csr;
use crate::VertexId;

/// Incremental builder for undirected graphs.
///
/// ```
/// use tricount::graph::builder::GraphBuilder;
/// let g = GraphBuilder::new(4)
///     .edges([(0, 1), (1, 2), (2, 0), (1, 1), (0, 1)]) // self loop + dup dropped
///     .build()
///     .unwrap();
/// assert_eq!(g.num_edges(), 3);
/// ```
pub struct GraphBuilder {
    n: usize,
    edges: Vec<(VertexId, VertexId)>,
}

impl GraphBuilder {
    /// Builder for a graph on nodes `0..n`.
    pub fn new(n: usize) -> Self {
        GraphBuilder { n, edges: Vec::new() }
    }

    /// Pre-allocate for `m` expected edges.
    pub fn with_capacity(n: usize, m: usize) -> Self {
        GraphBuilder { n, edges: Vec::with_capacity(m) }
    }

    /// Add one undirected edge (order of endpoints irrelevant).
    pub fn edge(&mut self, u: VertexId, v: VertexId) -> &mut Self {
        self.edges.push((u, v));
        self
    }

    /// Add many edges (chainable).
    pub fn edges<I: IntoIterator<Item = (VertexId, VertexId)>>(mut self, it: I) -> Self {
        self.edges.extend(it);
        self
    }

    /// Number of raw (pre-dedup) edges added so far.
    pub fn raw_len(&self) -> usize {
        self.edges.len()
    }

    /// Build the CSR, consuming the builder.
    pub fn build(self) -> Result<Csr> {
        from_edge_list(self.n, self.edges)
    }
}

/// Build a CSR from an edge list. Self loops are dropped, duplicates merged.
/// Endpoints must be `< n`.
pub fn from_edge_list(n: usize, mut edges: Vec<(VertexId, VertexId)>) -> Result<Csr> {
    // Normalize: (min, max), drop self loops, validate range.
    let mut w = 0;
    for i in 0..edges.len() {
        let (u, v) = edges[i];
        if u as usize >= n || v as usize >= n {
            return Err(Error::InvalidGraph(format!(
                "edge ({u},{v}) out of range for n={n}"
            )));
        }
        if u == v {
            continue;
        }
        edges[w] = if u < v { (u, v) } else { (v, u) };
        w += 1;
    }
    edges.truncate(w);
    edges.sort_unstable();
    edges.dedup();

    // Counting sort into CSR, both directions.
    let mut deg = vec![0u64; n + 1];
    for &(u, v) in &edges {
        deg[u as usize + 1] += 1;
        deg[v as usize + 1] += 1;
    }
    let mut offsets = deg;
    for i in 0..n {
        offsets[i + 1] += offsets[i];
    }
    let mut cursor = offsets.clone();
    let mut targets = vec![0 as VertexId; *offsets.last().unwrap() as usize];
    for &(u, v) in &edges {
        targets[cursor[u as usize] as usize] = v;
        cursor[u as usize] += 1;
        targets[cursor[v as usize] as usize] = u;
        cursor[v as usize] += 1;
    }
    // Edge list was sorted by (u, v); the second insertion (v → u) is not
    // globally sorted, so sort each list. Lists are typically short; the
    // u-side entries are already in order.
    for v in 0..n {
        let s = offsets[v] as usize;
        let e = offsets[v + 1] as usize;
        targets[s..e].sort_unstable();
    }
    Ok(Csr::from_parts(offsets, targets))
}

/// Build directly from an iterator of edges without an intermediate builder.
pub fn from_edges<I: IntoIterator<Item = (VertexId, VertexId)>>(n: usize, it: I) -> Result<Csr> {
    from_edge_list(n, it.into_iter().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup_and_self_loops() {
        let g = from_edges(3, [(0, 1), (1, 0), (1, 1), (0, 1), (2, 1)]).unwrap();
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn out_of_range_rejected() {
        assert!(from_edges(2, [(0, 2)]).is_err());
    }

    #[test]
    fn triangle() {
        let g = from_edges(3, [(0, 1), (1, 2), (2, 0)]).unwrap();
        assert_eq!(g.num_edges(), 3);
        for v in 0..3 {
            assert_eq!(g.degree(v), 2);
        }
    }

    #[test]
    fn adjacency_sorted_even_with_reversed_input() {
        let g = from_edges(5, [(4, 0), (3, 0), (2, 0), (1, 0)]).unwrap();
        assert_eq!(g.neighbors(0), &[1, 2, 3, 4]);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn builder_chaining() {
        let mut b = GraphBuilder::with_capacity(4, 3);
        b.edge(0, 1).edge(1, 2).edge(2, 3);
        assert_eq!(b.raw_len(), 3);
        let g = b.build().unwrap();
        assert_eq!(g.num_edges(), 3);
    }

    #[test]
    fn isolated_vertices_allowed() {
        let g = from_edges(10, [(0, 9)]).unwrap();
        assert_eq!(g.num_nodes(), 10);
        assert_eq!(g.degree(5), 0);
    }
}
