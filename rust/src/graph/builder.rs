//! Edge-list → CSR construction via an O(m) two-pass counting/radix build.
//!
//! The seed comparison-sorted the edge list (O(m log m), single-threaded)
//! and then re-sorted every adjacency row. This builder never compares:
//! arcs are counting-scattered **by target, then by source** — a stable
//! two-pass radix on (source, target) — so rows come out globally sorted,
//! duplicates land adjacent, and dedup is a per-row linear sweep. Every
//! phase parallelizes over `--build-threads` scoped threads with disjoint
//! per-`(thread, bucket)` scatter regions, and the output is **bit-identical
//! at every thread count** (the final CSR is a pure function of the edge
//! *set*; see DESIGN.md §8 for the determinism argument).

use crate::error::{Error, Result};
use crate::graph::csr::Csr;
use crate::par::{self, UnsafeSlice};
use crate::VertexId;

/// Below this many input edges per thread a multi-thread request degrades
/// toward serial: spawn + histogram-merge overhead beats the win on small
/// inputs (e.g. per-batch stream compactions).
pub const MIN_EDGES_PER_THREAD: usize = 8192;

/// Incremental builder for undirected graphs.
///
/// ```
/// use tricount::graph::builder::GraphBuilder;
/// let g = GraphBuilder::new(4)
///     .edges([(0, 1), (1, 2), (2, 0), (1, 1), (0, 1)]) // self loop + dup dropped
///     .build()
///     .unwrap();
/// assert_eq!(g.num_edges(), 3);
/// ```
pub struct GraphBuilder {
    n: usize,
    edges: Vec<(VertexId, VertexId)>,
}

impl GraphBuilder {
    /// Builder for a graph on nodes `0..n`.
    pub fn new(n: usize) -> Self {
        GraphBuilder { n, edges: Vec::new() }
    }

    /// Pre-allocate for `m` expected edges.
    pub fn with_capacity(n: usize, m: usize) -> Self {
        GraphBuilder { n, edges: Vec::with_capacity(m) }
    }

    /// Add one undirected edge (order of endpoints irrelevant).
    pub fn edge(&mut self, u: VertexId, v: VertexId) -> &mut Self {
        self.edges.push((u, v));
        self
    }

    /// Add many edges (chainable).
    pub fn edges<I: IntoIterator<Item = (VertexId, VertexId)>>(mut self, it: I) -> Self {
        self.edges.extend(it);
        self
    }

    /// Number of raw (pre-dedup) edges added so far.
    pub fn raw_len(&self) -> usize {
        self.edges.len()
    }

    /// Build the CSR, consuming the builder.
    pub fn build(self) -> Result<Csr> {
        from_edge_list(self.n, self.edges)
    }
}

/// Build a CSR from an edge list. Self loops are dropped, duplicates merged.
/// Endpoints must be `< n`. Runs on [`par::default_threads`] threads (1
/// unless the CLI raised it via `--build-threads`); output is identical at
/// every thread count.
pub fn from_edge_list(n: usize, edges: Vec<(VertexId, VertexId)>) -> Result<Csr> {
    from_edge_list_threads(n, edges, par::default_threads())
}

/// [`from_edge_list`] with an explicit thread count.
pub fn from_edge_list_threads(
    n: usize,
    edges: Vec<(VertexId, VertexId)>,
    threads: usize,
) -> Result<Csr> {
    build(n, edges, threads, false)
}

/// Fast path for callers that already oriented every edge `(u < v)`,
/// dropped self loops and guaranteed endpoints `< n` — the byte-level
/// parser ([`crate::graph::io::parse_edge_list`]) compacts ids itself, so
/// the builder's normalize pass would only re-derive what the caller
/// proved. Invariants are `debug_assert`ed.
pub(crate) fn from_normalized_edge_list(
    n: usize,
    edges: Vec<(VertexId, VertexId)>,
    threads: usize,
) -> Result<Csr> {
    build(n, edges, threads, true)
}

/// Clamp a requested thread count by the input shape. Two floors: enough
/// edges per thread to amortize spawn + histogram-merge overhead
/// ([`MIN_EDGES_PER_THREAD`]), and enough edges per *node-width table*
/// that the O(t·n) per-thread histograms/cursors cannot dominate — each
/// extra thread costs O(n) scratch, so a thread must own at least n edges
/// to pay for it (a huge-n, tiny-m compaction degrades to the serial
/// O(n)-scratch path instead of allocating t n-wide tables).
fn effective_threads(requested: usize, num_edges: usize, n: usize) -> usize {
    // Oversubscription clamp first (requesting 8 threads on a 2-core host
    // must mean "2", not 8 time-shared workers), then the shape floors.
    let requested = par::clamp_to_host(requested);
    par::clamp_threads(requested, num_edges, MIN_EDGES_PER_THREAD)
        .min(par::clamp_threads(requested, num_edges, n))
}

/// Per-chunk result of the normalize pass.
struct NormChunk {
    /// Normalized edges kept (compacted to the chunk front).
    kept: usize,
    /// `hist[v]` = arcs targeting `v` from this chunk (= this chunk's
    /// contribution to `deg(v)`).
    hist: Vec<u32>,
    /// First invalid-edge message, if any.
    err: Option<String>,
}

fn build(
    n: usize,
    mut edges: Vec<(VertexId, VertexId)>,
    threads: usize,
    pre_normalized: bool,
) -> Result<Csr> {
    // All counters below are u32 (halves histogram memory); bound the
    // input so 2·m arcs can never overflow one.
    if edges.len() > (u32::MAX / 2) as usize {
        return Err(Error::InvalidGraph(format!(
            "edge list of {} entries exceeds the 2^31 counting-build bound",
            edges.len()
        )));
    }
    let t = effective_threads(threads, edges.len(), n);
    let chunk_ranges = par::ranges(edges.len(), t);

    // Phase 0 — normalize each chunk in place ((min,max) orientation, self
    // loops dropped, endpoints validated, survivors compacted to the chunk
    // front) while counting arc targets.
    let norms: Vec<NormChunk> = par::for_chunks_mut(&mut edges, t, |_, _, chunk| {
        let mut hist = vec![0u32; n];
        if pre_normalized {
            for &(u, v) in chunk.iter() {
                debug_assert!(u < v, "pre-normalized edge ({u},{v}) must have u < v");
                debug_assert!((v as usize) < n, "pre-normalized edge ({u},{v}) out of range");
                hist[u as usize] += 1;
                hist[v as usize] += 1;
            }
            return NormChunk { kept: chunk.len(), hist, err: None };
        }
        let mut w = 0usize;
        for i in 0..chunk.len() {
            let (u, v) = chunk[i];
            if u as usize >= n || v as usize >= n {
                return NormChunk {
                    kept: w,
                    hist,
                    err: Some(format!("edge ({u},{v}) out of range for n={n}")),
                };
            }
            if u == v {
                continue;
            }
            let e = if u < v { (u, v) } else { (v, u) };
            hist[e.0 as usize] += 1;
            hist[e.1 as usize] += 1;
            chunk[w] = e;
            w += 1;
        }
        NormChunk { kept: w, hist, err: None }
    });
    // Chunks are in input order, so the first erroring chunk's first bad
    // edge is the same edge the serial scan would have reported.
    for nc in &norms {
        if let Some(msg) = &nc.err {
            return Err(Error::InvalidGraph(msg.clone()));
        }
    }

    // Merge per-thread histograms into degrees, then prefix into offsets.
    let mut offsets = vec![0u64; n + 1];
    par::for_chunks_mut(&mut offsets[1..], t, |_, start, chunk| {
        for (i, o) in chunk.iter_mut().enumerate() {
            let v = start + i;
            *o = norms.iter().map(|nc| nc.hist[v] as u64).sum();
        }
    });
    for v in 0..n {
        offsets[v + 1] += offsets[v];
    }
    let total_arcs = *offsets.last().unwrap() as usize;

    // Disjoint per-(thread, bucket) scatter regions: thread `ti`'s slice of
    // bucket `v` starts after every earlier thread's share of `v`. Flat
    // layout `cursors[ti·n + v]`; each thread later owns row `ti` mutably.
    let mut cursors = vec![0u64; t * n];
    {
        let cur = UnsafeSlice::new(&mut cursors);
        par::for_ranges(n, t, |_, r| {
            for v in r {
                let mut at = offsets[v];
                for (ti, nc) in norms.iter().enumerate() {
                    // Disjoint: each v-range writes its own columns.
                    unsafe { cur.write(ti * n + v, at) };
                    at += nc.hist[v] as u64;
                }
            }
        });
    }

    // Pass 1 — scatter every arc by *target*: bucket `v` collects the
    // sources of all arcs into `v`, i.e. exactly `v`'s neighbor multiset
    // (in chunk order, which pass 2 makes irrelevant).
    let mut by_dst = vec![0 as VertexId; total_arcs];
    {
        let out = UnsafeSlice::new(&mut by_dst);
        par::for_chunks_mut(&mut cursors, t, |ti, _, cur| {
            let r = &chunk_ranges[ti];
            let chunk = &edges[r.start..r.start + norms[ti].kept];
            for &(u, v) in chunk {
                // Arc u→v lands in bucket v; arc v→u in bucket u. Regions
                // are disjoint per (thread, bucket) by construction.
                unsafe { out.write(cur[v as usize] as usize, u) };
                cur[v as usize] += 1;
                unsafe { out.write(cur[u as usize] as usize, v) };
                cur[u as usize] += 1;
            }
        });
    }
    drop(edges);

    // Pass 2a — per-thread source histograms over contiguous target ranges
    // (each thread owns a bucket range of `by_dst`, so entries are already
    // grouped; the arcs with source `s` total `deg(s)`, hence pass 2
    // reuses `offsets` as its bucket starts).
    let vranges = par::ranges(n, t);
    let hist2: Vec<Vec<u32>> = par::for_ranges(n, t, |_, r| {
        let mut h = vec![0u32; n];
        let s = offsets[r.start] as usize;
        let e = offsets[r.end] as usize;
        for &src in &by_dst[s..e] {
            h[src as usize] += 1;
        }
        h
    });
    {
        let cur = UnsafeSlice::new(&mut cursors);
        par::for_ranges(n, t, |_, r| {
            for v in r {
                let mut at = offsets[v];
                for (ti, h) in hist2.iter().enumerate() {
                    unsafe { cur.write(ti * n + v, at) };
                    at += h[v] as u64;
                }
            }
        });
    }

    // Pass 2b — scatter by *source*, scanning targets in ascending bucket
    // order: row `s` receives its targets smallest-first, so every row is
    // sorted with duplicates adjacent.
    let mut rows = vec![0 as VertexId; total_arcs];
    {
        let out = UnsafeSlice::new(&mut rows);
        par::for_chunks_mut(&mut cursors, t, |ti, _, cur| {
            for v in vranges[ti].clone() {
                let s = offsets[v] as usize;
                let e = offsets[v + 1] as usize;
                for &src in &by_dst[s..e] {
                    unsafe { out.write(cur[src as usize] as usize, v as VertexId) };
                    cur[src as usize] += 1;
                }
            }
        });
    }
    drop(by_dst);
    drop(cursors);

    // Pass 3 — per-row linear-sweep dedup in place. Each thread owns the
    // contiguous row span of its node range (`split_at_mut`-safe), and its
    // slice of the unique-count array.
    let row_bounds: Vec<usize> = vranges
        .iter()
        .map(|r| offsets[r.start] as usize)
        .chain([total_arcs])
        .collect();
    let mut uniq = vec![0u64; n + 1];
    {
        let uq = UnsafeSlice::new(&mut uniq);
        par::for_uneven_chunks_mut(&mut rows, &row_bounds, |ti, start, chunk| {
            for v in vranges[ti].clone() {
                let s = offsets[v] as usize - start;
                let e = offsets[v + 1] as usize - start;
                let mut w = s;
                for i in s..e {
                    let x = chunk[i];
                    if w == s || chunk[w - 1] != x {
                        chunk[w] = x;
                        w += 1;
                    }
                }
                // Disjoint: node v belongs to exactly one range.
                unsafe { uq.write(v + 1, (w - s) as u64) };
            }
        });
    }
    for v in 0..n {
        uniq[v + 1] += uniq[v];
    }
    let total_unique = uniq[n] as usize;
    if total_unique == total_arcs {
        // No duplicates anywhere (generators and the pre-normalized parse
        // path): the scattered rows are final.
        return Ok(Csr::from_parts(offsets, rows));
    }

    // Pass 4 — compact the unique prefixes into the final targets array;
    // each thread copies into the disjoint output span of its node range.
    let out_bounds: Vec<usize> = vranges
        .iter()
        .map(|r| uniq[r.start] as usize)
        .chain([total_unique])
        .collect();
    let mut targets = vec![0 as VertexId; total_unique];
    par::for_uneven_chunks_mut(&mut targets, &out_bounds, |ti, _, out| {
        let mut w = 0usize;
        for v in vranges[ti].clone() {
            let s = offsets[v] as usize;
            let cnt = (uniq[v + 1] - uniq[v]) as usize;
            out[w..w + cnt].copy_from_slice(&rows[s..s + cnt]);
            w += cnt;
        }
        debug_assert_eq!(w, out.len());
    });
    Ok(Csr::from_parts(uniq, targets))
}

/// Build directly from an iterator of edges without an intermediate builder.
pub fn from_edges<I: IntoIterator<Item = (VertexId, VertexId)>>(n: usize, it: I) -> Result<Csr> {
    from_edge_list(n, it.into_iter().collect())
}

/// The seed's comparison-sort build — O(m log m) `sort_unstable` + per-row
/// re-sort, kept verbatim (including its extra `offsets.clone()` cursor
/// allocation) as the reference implementation for the radix build's
/// property tests and the `bench-pipeline` baseline column.
#[doc(hidden)]
pub fn from_edge_list_sort_baseline(
    n: usize,
    mut edges: Vec<(VertexId, VertexId)>,
) -> Result<Csr> {
    let mut w = 0;
    for i in 0..edges.len() {
        let (u, v) = edges[i];
        if u as usize >= n || v as usize >= n {
            return Err(Error::InvalidGraph(format!(
                "edge ({u},{v}) out of range for n={n}"
            )));
        }
        if u == v {
            continue;
        }
        edges[w] = if u < v { (u, v) } else { (v, u) };
        w += 1;
    }
    edges.truncate(w);
    edges.sort_unstable();
    edges.dedup();

    let mut deg = vec![0u64; n + 1];
    for &(u, v) in &edges {
        deg[u as usize + 1] += 1;
        deg[v as usize + 1] += 1;
    }
    let mut offsets = deg;
    for i in 0..n {
        offsets[i + 1] += offsets[i];
    }
    let mut cursor = offsets.clone();
    let mut targets = vec![0 as VertexId; *offsets.last().unwrap() as usize];
    for &(u, v) in &edges {
        targets[cursor[u as usize] as usize] = v;
        cursor[u as usize] += 1;
        targets[cursor[v as usize] as usize] = u;
        cursor[v as usize] += 1;
    }
    for v in 0..n {
        let s = offsets[v] as usize;
        let e = offsets[v + 1] as usize;
        targets[s..e].sort_unstable();
    }
    Ok(Csr::from_parts(offsets, targets))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::rng::Rng;

    #[test]
    fn dedup_and_self_loops() {
        let g = from_edges(3, [(0, 1), (1, 0), (1, 1), (0, 1), (2, 1)]).unwrap();
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn out_of_range_rejected() {
        assert!(from_edges(2, [(0, 2)]).is_err());
    }

    #[test]
    fn out_of_range_message_matches_serial_at_any_thread_count() {
        // The bad edge sits in a late chunk; every thread count must report
        // the same first-in-input-order offender.
        let mut edges: Vec<(VertexId, VertexId)> = (0..50_000u32).map(|i| (i % 97, i % 89 + 97)).collect();
        edges.push((5, 999_999));
        edges.push((1_000_000, 3));
        let expect = from_edge_list_sort_baseline(200, edges.clone()).unwrap_err().to_string();
        for t in [1, 2, 8] {
            let got = from_edge_list_threads(200, edges.clone(), t).unwrap_err().to_string();
            assert_eq!(got, expect, "threads={t}");
        }
    }

    #[test]
    fn triangle() {
        let g = from_edges(3, [(0, 1), (1, 2), (2, 0)]).unwrap();
        assert_eq!(g.num_edges(), 3);
        for v in 0..3 {
            assert_eq!(g.degree(v), 2);
        }
    }

    #[test]
    fn adjacency_sorted_even_with_reversed_input() {
        let g = from_edges(5, [(4, 0), (3, 0), (2, 0), (1, 0)]).unwrap();
        assert_eq!(g.neighbors(0), &[1, 2, 3, 4]);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn builder_chaining() {
        let mut b = GraphBuilder::with_capacity(4, 3);
        b.edge(0, 1).edge(1, 2).edge(2, 3);
        assert_eq!(b.raw_len(), 3);
        let g = b.build().unwrap();
        assert_eq!(g.num_edges(), 3);
    }

    #[test]
    fn isolated_vertices_allowed() {
        let g = from_edges(10, [(0, 9)]).unwrap();
        assert_eq!(g.num_nodes(), 10);
        assert_eq!(g.degree(5), 0);
    }

    #[test]
    fn empty_inputs() {
        for t in [1, 4] {
            let g = from_edge_list_threads(0, vec![], t).unwrap();
            assert_eq!(g.num_nodes(), 0);
            let g = from_edge_list_threads(7, vec![], t).unwrap();
            assert_eq!(g.num_nodes(), 7);
            assert_eq!(g.num_edges(), 0);
            g.validate().unwrap();
        }
    }

    #[test]
    fn radix_matches_sort_baseline_on_messy_input() {
        // Duplicates, both orientations, self loops, skew — the whole
        // normalize surface — at several thread counts.
        crate::prop::quickcheck("radix build == sort build", |rng, _| {
            let n = 2 + rng.below_usize(120);
            let m = rng.below_usize(6 * n + 1);
            let mut edges: Vec<(VertexId, VertexId)> = (0..m)
                .map(|_| (rng.below(n as u64) as VertexId, rng.below(n as u64) as VertexId))
                .collect();
            // Duplicate a random prefix reversed, to force cross-chunk dups.
            let k = rng.below_usize(edges.len().min(20) + 1);
            let dup: Vec<_> = edges[..k].iter().map(|&(u, v)| (v, u)).collect();
            edges.extend(dup);
            let reference = from_edge_list_sort_baseline(n, edges.clone()).map_err(|e| e.to_string())?;
            for t in [1, 2, 8] {
                let got = from_edge_list_threads(n, edges.clone(), t).map_err(|e| e.to_string())?;
                if got != reference {
                    return Err(format!("radix(threads={t}) diverged on n={n} m={}", edges.len()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn parallel_path_exercised_above_chunk_floor() {
        // Enough edges that effective_threads(8, m) really is > 1.
        let mut rng = Rng::seeded(99);
        let n = 3_000usize;
        let edges: Vec<(VertexId, VertexId)> = (0..4 * MIN_EDGES_PER_THREAD)
            .map(|_| (rng.below(n as u64) as VertexId, rng.below(n as u64) as VertexId))
            .collect();
        assert!(effective_threads(8, edges.len(), n) > 1);
        let reference = from_edge_list_sort_baseline(n, edges.clone()).unwrap();
        for t in [2, 3, 8] {
            let got = from_edge_list_threads(n, edges.clone(), t).unwrap();
            assert_eq!(got, reference, "threads={t}");
        }
        reference.validate().unwrap();
    }

    #[test]
    fn pre_normalized_path_matches_general_path() {
        let mut rng = Rng::seeded(7);
        let n = 500usize;
        let mut edges: Vec<(VertexId, VertexId)> = (0..5_000)
            .map(|_| {
                let u = rng.below(n as u64) as VertexId;
                let v = rng.below(n as u64 - 1) as VertexId;
                let v = if v >= u { v + 1 } else { v };
                if u < v { (u, v) } else { (v, u) }
            })
            .collect();
        edges.sort_unstable();
        edges.dedup();
        let general = from_edge_list(n, edges.clone()).unwrap();
        for t in [1, 4] {
            let fast = from_normalized_edge_list(n, edges.clone(), t).unwrap();
            assert_eq!(fast, general, "threads={t}");
        }
    }

    #[test]
    fn effective_threads_floors_small_inputs() {
        assert_eq!(effective_threads(8, 100, 50), 1);
        assert_eq!(effective_threads(8, MIN_EDGES_PER_THREAD * 3, 100), 3);
        assert_eq!(effective_threads(2, MIN_EDGES_PER_THREAD * 100, 100), 2);
        assert_eq!(effective_threads(0, 100, 50), 1);
        // Table-width floor: n so large that per-thread O(n) scratch would
        // dominate the edge work forces the serial path.
        assert_eq!(effective_threads(8, MIN_EDGES_PER_THREAD * 16, 10_000_000), 1);
        // …and scales in proportion when edges outnumber nodes.
        assert_eq!(effective_threads(8, 64 * 10_000, 10_000), 8);
        assert_eq!(effective_threads(8, 4 * 10_000, 10_000), 4);
        assert_eq!(effective_threads(8, 0, 0), 1);
    }
}
