//! Table II — memory for the largest partition: non-overlapping (ours) vs
//! PATRIC's overlapping scheme, 100 partitions.
//!
//! Paper's shape: ours ≪ PATRIC everywhere; the gap explodes on skewed /
//! high-degree networks (Twitter 265.82 MB vs 6876.25 MB ≈ 26×;
//! PA(10M,100) 121.11 vs 2120.94 ≈ 17.5×).

use crate::error::Result;
use crate::exp::report::{Cell, Report};
use crate::exp::{cache, Options};
use crate::partition::balance::balanced_ranges;
use crate::partition::cost::prefix_sums;
use crate::partition::nonoverlap::partition_sizes;
use crate::partition::overlap::overlap_sizes;

/// Paper Table II rows: (our workload, paper MB ours, paper MB PATRIC).
const ROWS: &[(&str, f64, f64)] = &[
    ("miami-like", 10.63, 36.56),
    ("google-like", 1.49, 5.65),
    ("livejournal-like", 9.41, 22.15),
    ("twitter-like", 265.82, 6876.25),
    ("pa:1000000:100", 121.11, 2120.94), // paper: PA(10M, 100)
];

pub fn run(opts: &Options) -> Result<Report> {
    let p = if opts.quick { 10 } else { 100 };
    let scale = if opts.quick { 0.02 * opts.scale } else { opts.scale };
    let mut r = Report::new([
        "network", "ours MB", "ours measured MB", "PATRIC MB", "ratio", "avg deg",
        "paper ours", "paper PATRIC", "paper ratio",
    ]);
    for &(spec, paper_ours, paper_patric) in ROWS {
        let o = cache::oriented(spec, scale)?;
        // Both schemes partition the same ranges (apples-to-apples: the
        // overlap is then a strict superset per partition). Ranges are
        // balanced by stored edges |N_v| — "each partition has approximately
        // m/P edges" (§III).
        let edge_costs: Vec<u64> =
            (0..o.num_nodes() as u32).map(|v| o.effective_degree(v) as u64).collect();
        let ranges = balanced_ranges(&prefix_sums(&edge_costs), p);
        let ours_mb = partition_sizes(&o, &ranges)
            .iter()
            .map(|s| s.mb())
            .fold(0.0f64, f64::max);
        // Measured: the largest materialized rank partition (bitmaps off —
        // the table is about CSR bytes). Gated equal to the prediction.
        let measured_mb = crate::partition::owned::extract_nonoverlapping(
            &o,
            &ranges,
            crate::adj::HubThreshold::Off,
        )
        .iter()
        .map(|part| part.resident_bytes() as f64 / (1024.0 * 1024.0))
        .fold(0.0f64, f64::max);
        let g0 = cache::graph(spec, scale)?;
        let patric_mb = overlap_sizes(&g0, &o, &ranges)
            .iter()
            .map(|s| s.mb())
            .fold(0.0f64, f64::max);
        let g = cache::graph(spec, scale)?;
        r.row([
            spec.into(),
            Cell::Float(ours_mb),
            Cell::Float(measured_mb),
            Cell::Float(patric_mb),
            Cell::Float(patric_mb / ours_mb.max(1e-12)),
            Cell::Float(g.avg_degree()),
            Cell::Float(paper_ours),
            Cell::Float(paper_patric),
            Cell::Float(paper_patric / paper_ours),
        ]);
    }
    r.note(format!(
        "P = {p} partitions; workloads are scaled-down substitutes — compare *ratios*, not \
absolute MB; the measured column is physically allocated per-rank storage (== prediction)"
    ));
    Ok(r)
}

#[cfg(test)]
mod tests {
    #[test]
    fn quick_run_shape_holds() {
        let opts = crate::exp::Options { quick: true, out_dir: None, ..Default::default() };
        let r = super::run(&opts).unwrap();
        assert_eq!(r.rows.len(), super::ROWS.len());
        // Non-overlap must never exceed overlap, and the measured largest
        // partition must equal the prediction.
        for i in 0..r.rows.len() {
            let ours = r.float(i, "ours MB").unwrap();
            let measured = r.float(i, "ours measured MB").unwrap();
            let patric = r.float(i, "PATRIC MB").unwrap();
            assert!(ours <= patric * 1.001, "ours={ours} patric={patric}");
            assert!((ours - measured).abs() < 1e-9, "measured {measured} != predicted {ours}");
        }
    }
}
