//! Fig 4 — strong scaling of the §IV algorithm: speedup vs P, direct vs
//! surrogate, on Miami / LiveJournal / web-BerkStan (-like) networks.
//! Paper's shape: surrogate speedups rise steeply; direct flattens early
//! under redundant-message overhead.

use crate::config::CostFn;
use crate::error::Result;
use crate::exp::report::{Cell, Report};
use crate::exp::{cache, Options};
use crate::sim::calibrate::calibrated;
use crate::sim::space_efficient::{simulate_balanced, Scheme};

pub const NETWORKS: &[&str] = &["miami-like", "livejournal-like", "berkstan-like"];
pub const P_SWEEP: &[usize] = &[10, 25, 50, 100, 150, 200];

pub fn run(opts: &Options) -> Result<Report> {
    let (ps, scale): (&[usize], f64) = if opts.quick {
        (&[4, 16], 0.02 * opts.scale)
    } else {
        (P_SWEEP, opts.scale)
    };
    let model = calibrated();
    let mut r = Report::new(["network", "P", "speedup surrogate", "speedup direct", "msgs surrogate", "msgs direct"]);
    for net in NETWORKS {
        let o = cache::oriented(net, scale)?;
        for &p in ps {
            let s = simulate_balanced(&o, p, CostFn::SurrogateNew, Scheme::Surrogate, &model);
            let d = simulate_balanced(&o, p, CostFn::SurrogateNew, Scheme::Direct, &model);
            r.row([
                (*net).into(),
                Cell::Int(p as u64),
                Cell::Float(s.speedup()),
                Cell::Float(d.speedup()),
                Cell::Int(s.total_msgs()),
                Cell::Int(d.total_msgs()),
            ]);
        }
    }
    r.note("virtual time, calibrated α; expected: surrogate ≫ direct at every P");
    Ok(r)
}

#[cfg(test)]
mod tests {
    #[test]
    fn surrogate_dominates_direct() {
        let opts = crate::exp::Options { quick: true, out_dir: None, ..Default::default() };
        let r = super::run(&opts).unwrap();
        for i in 0..r.rows.len() {
            let s = r.float(i, "speedup surrogate").unwrap();
            let d = r.float(i, "speedup direct").unwrap();
            assert!(s >= d, "surrogate {s} !>= direct {d}");
        }
    }
}
