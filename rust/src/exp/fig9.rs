//! Fig 9 — weak scaling of the §IV algorithm: PA(P/10·1M, 50) in the paper
//! (problem size grows with P), runtime should rise only slowly with the
//! added communication overhead.

use crate::config::CostFn;
use crate::error::Result;
use crate::exp::report::{Cell, Report};
use crate::exp::{cache, Options};
use crate::sim::calibrate::calibrated;
use crate::sim::space_efficient::{simulate_balanced, Scheme};

pub const P_SWEEP: &[usize] = &[10, 25, 50, 100, 150, 200];
/// Nodes per processor at scale 1.0 (paper: 100K per processor, /10 per DESIGN §3).
pub const NODES_PER_P: usize = 10_000;

pub fn run(opts: &Options) -> Result<Report> {
    let (ps, npp): (&[usize], usize) = if opts.quick {
        (&[2, 4, 8], 500)
    } else {
        (P_SWEEP, ((NODES_PER_P as f64) * opts.scale) as usize)
    };
    let model = calibrated();
    let mut r = Report::new(["P", "n", "m", "virtual runtime", "efficiency"]);
    let mut t0 = None;
    for &p in ps {
        let n = npp * p;
        let o = cache::oriented(&format!("pa:{n}:50"), 1.0)?;
        let s = simulate_balanced(&o, p, CostFn::SurrogateNew, Scheme::Surrogate, &model);
        let t = s.makespan_ns / 1e9;
        let t0v = *t0.get_or_insert(t);
        r.row([
            Cell::Int(p as u64),
            Cell::Int(n as u64),
            Cell::Int(o.num_edges()),
            Cell::Secs(t),
            Cell::Float(t0v / t),
        ]);
    }
    r.note("weak scaling: runtime should grow slowly (PA triangle work grows mildly superlinearly with n)");
    Ok(r)
}

#[cfg(test)]
mod tests {
    #[test]
    fn runtime_growth_is_bounded() {
        let opts = crate::exp::Options { quick: true, out_dir: None, ..Default::default() };
        let r = super::run(&opts).unwrap();
        let ts: Vec<f64> = (0..r.rows.len())
            .map(|i| r.secs(i, "virtual runtime").unwrap())
            .collect();
        // 4× more processors+work must not blow runtime up by more than ~4×
        // (perfect weak scaling would be 1×; PA work superlinearity and comm
        // overhead push it above, but it must stay far from linear-in-total-work ~16×).
        assert!(
            ts.last().unwrap() / ts.first().unwrap() < 6.0,
            "weak scaling broke: {ts:?}"
        );
    }
}
