//! Fig 13 — idle time of worker processors: static task granularity vs the
//! paper's dynamically shrinking granularity (Eqn 2), Miami- and
//! LiveJournal-like networks. Paper's shape: static leaves some workers
//! idle for a large fraction of the run; dynamic granularity collapses the
//! idle tail to near zero.

use crate::config::CostFn;
use crate::error::Result;
use crate::exp::report::{Cell, Report};
use crate::exp::{cache, Options};
use crate::sim::calibrate::calibrated;
use crate::sim::dynamic::{simulate, DynamicSim, SimGranularity};

fn idle_stats(d: &DynamicSim) -> (f64, f64, f64) {
    let idles: Vec<f64> = d.workers.iter().map(|w| w.idle_ns / 1e9).collect();
    let max = idles.iter().copied().fold(0.0f64, f64::max);
    let mean = idles.iter().sum::<f64>() / idles.len() as f64;
    (mean, max, d.makespan_ns / 1e9)
}

pub fn run(opts: &Options) -> Result<Report> {
    let (p, scale): (usize, f64) = if opts.quick { (8, 0.02 * opts.scale) } else { (100, opts.scale) };
    let model = calibrated();
    let mut r = Report::new([
        "network", "granularity", "idle mean", "idle max", "idle/makespan %", "makespan",
    ]);
    for net in ["miami-like", "livejournal-like"] {
        let o = cache::oriented(net, scale)?;
        // "Static size": the dynamic region cut into one equal-cost task per
        // worker (no granularity adaptation) — the strawman of §V-B.
        let stat = simulate(&o, p, CostFn::Degree, SimGranularity::Fixed(p - 1), &model);
        let dynm = simulate(&o, p, CostFn::Degree, SimGranularity::Shrinking, &model);
        for (name, d) in [("static", &stat), ("dynamic", &dynm)] {
            let (mean, max, makespan) = idle_stats(d);
            r.row([
                net.into(),
                name.into(),
                Cell::Secs(mean),
                Cell::Secs(max),
                Cell::Float(100.0 * max / makespan),
                Cell::Secs(makespan),
            ]);
        }
    }
    r.note("expected: dynamic granularity cuts idle max and makespan");
    Ok(r)
}

#[cfg(test)]
mod tests {
    #[test]
    fn dynamic_reduces_idle() {
        let opts = crate::exp::Options { quick: true, out_dir: None, ..Default::default() };
        let r = super::run(&opts).unwrap();
        for i in (0..r.rows.len()).step_by(2) {
            let stat = r.secs(i, "idle max").unwrap();
            let dynm = r.secs(i + 1, "idle max").unwrap();
            assert!(dynm <= stat, "dynamic idle {dynm} !<= static idle {stat}");
        }
    }
}
