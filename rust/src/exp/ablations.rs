//! Ablations beyond the paper's own figures (DESIGN.md §5 "Additional"):
//!
//! * **noise-sigma** — σ-sensitivity of the dynamic-vs-static gap
//!   (Table IV's ratio as a function of the execution-noise magnitude);
//! * **granularity** — shrinking (Eqn 2) vs fixed-k task queues;
//! * **gallop-threshold** — the adaptive intersection kernel's switch point
//!   (EXPERIMENTS.md §Perf).

use crate::config::CostFn;
use crate::error::Result;
use crate::exp::report::{Cell, Report};
use crate::exp::{cache, Options};
use crate::sim::calibrate::calibrated;
use crate::sim::dynamic::{simulate, SimGranularity};
use crate::sim::model::CostModel;
use crate::sim::space_efficient::simulate_patric_balanced;

/// σ-sensitivity: how strongly does the estimate-vs-reality gap have to be
/// before dynamic balancing pays off (and how far it can go)?
pub fn run_noise(opts: &Options) -> Result<Report> {
    let (p, scale) = if opts.quick { (32, 0.05) } else { (200, opts.scale) };
    let base = calibrated();
    let mut r = Report::new(["sigma", "PATRIC", "dyn-LB", "ratio"]);
    let o = cache::oriented("livejournal-like", scale)?;
    for sigma in [0.0, 0.5, 1.0, 1.5, 2.0] {
        let model = CostModel { exec_noise_sigma: sigma, ..base };
        let stat = simulate_patric_balanced(&o, p, CostFn::PatricBest, &model);
        let dynm = simulate(&o, p, CostFn::Degree, SimGranularity::Shrinking, &model);
        r.row([
            Cell::Float(sigma),
            Cell::Secs(stat.makespan_ns / 1e9),
            Cell::Secs(dynm.makespan_ns / 1e9),
            Cell::Float(stat.makespan_ns / dynm.makespan_ns),
        ]);
    }
    r.note("livejournal-like; ratio ≥ 1 means dynamic wins; paper reports ≈ 2 on its cluster");
    Ok(r)
}

/// Task-granularity policy ablation: Eqn-2 shrinking vs fixed task counts.
pub fn run_granularity(opts: &Options) -> Result<Report> {
    let (p, scale) = if opts.quick { (32, 0.05) } else { (100, opts.scale) };
    let model = calibrated();
    let mut r = Report::new(["policy", "makespan", "idle max", "tasks"]);
    let o = cache::oriented("livejournal-like", scale)?;
    let policies: Vec<(String, SimGranularity)> = vec![
        ("shrinking (Eqn 2)".into(), SimGranularity::Shrinking),
        (format!("fixed {}", p - 1), SimGranularity::Fixed(p - 1)),
        (format!("fixed {}", 4 * (p - 1)), SimGranularity::Fixed(4 * (p - 1))),
        (format!("fixed {}", 16 * (p - 1)), SimGranularity::Fixed(16 * (p - 1))),
        ("static only".into(), SimGranularity::StaticOnly),
    ];
    for (name, g) in policies {
        let d = simulate(&o, p, CostFn::Degree, g, &model);
        let idle_max = d.workers.iter().map(|w| w.idle_ns).fold(0.0f64, f64::max);
        let tasks: u64 = d.workers.iter().map(|w| w.tasks_run).sum();
        r.row([
            name.into(),
            Cell::Secs(d.makespan_ns / 1e9),
            Cell::Secs(idle_max / 1e9),
            Cell::Int(tasks),
        ]);
    }
    r.note("shrinking should match the best fixed-k without tuning k");
    Ok(r)
}

/// Gallop-threshold ablation on real intersection timing (measured).
pub fn run_gallop(_opts: &Options) -> Result<Report> {
    use crate::intersect::{count_galloping, count_merge};
    use std::time::Instant;
    let mut rng = crate::gen::rng::Rng::seeded(7);
    let mut r = Report::new(["|short|", "|long|", "ratio", "merge ns", "gallop ns", "winner"]);
    let long: Vec<u32> = {
        let mut v: Vec<u32> = (0..200_000).map(|_| rng.next_u32() % 2_000_000).collect();
        v.sort_unstable();
        v.dedup();
        v
    };
    for shorts in [50usize, 200, 1_000, 5_000, 20_000, 100_000] {
        let short: Vec<u32> = {
            let mut v: Vec<u32> = (0..shorts).map(|_| rng.next_u32() % 2_000_000).collect();
            v.sort_unstable();
            v.dedup();
            v
        };
        let time_it = |f: &dyn Fn(&mut u64)| {
            let mut best = f64::INFINITY;
            for _ in 0..5 {
                let mut c = 0u64;
                let t0 = Instant::now();
                f(&mut c);
                std::hint::black_box(c);
                best = best.min(t0.elapsed().as_nanos() as f64);
            }
            best
        };
        let m = time_it(&|c| count_merge(&short, &long, c));
        let g = time_it(&|c| count_galloping(&short, &long, c));
        r.row([
            Cell::Int(short.len() as u64),
            Cell::Int(long.len() as u64),
            Cell::Float(long.len() as f64 / short.len() as f64),
            Cell::Float(m),
            Cell::Float(g),
            if g < m { "gallop".into() } else { "merge".into() },
        ]);
    }
    r.note(format!(
        "crossover informs intersect::GALLOP_RATIO (currently {})",
        crate::intersect::GALLOP_RATIO
    ));
    Ok(r)
}

#[cfg(test)]
mod tests {
    #[test]
    fn noise_ablation_runs_and_sigma_zero_favors_static() {
        let opts = crate::exp::Options { quick: true, out_dir: None, ..Default::default() };
        let r = super::run_noise(&opts).unwrap();
        assert_eq!(r.rows.len(), 5);
        // At σ=0 the static estimator is a perfect oracle: ratio ≤ ~1.
        if let crate::exp::report::Cell::Float(ratio0) = r.rows[0][3] {
            assert!(ratio0 <= 1.05, "σ=0 ratio {ratio0}");
        }
    }

    #[test]
    fn granularity_ablation_runs() {
        let opts = crate::exp::Options { quick: true, out_dir: None, ..Default::default() };
        let r = super::run_granularity(&opts).unwrap();
        assert_eq!(r.rows.len(), 5);
    }
}
