//! Fig 14 — scalability of the §V dynamic-LB algorithm with increasing
//! network size, compared against PATRIC [21]. Paper's shape: both scale,
//! dynamic-LB reaches clearly higher speedups at every size.

use crate::config::CostFn;
use crate::error::Result;
use crate::exp::report::{Cell, Report};
use crate::exp::{cache, Options};
use crate::sim::calibrate::calibrated;
use crate::sim::dynamic::{simulate, SimGranularity};
use crate::sim::space_efficient::simulate_patric_balanced;

pub fn run(opts: &Options) -> Result<Report> {
    let (ps, sizes): (&[usize], Vec<usize>) = if opts.quick {
        (&[16, 64], vec![5_000, 20_000])
    } else {
        (
            &[25, 50, 100, 200, 400],
            super::fig6::SIZES.iter().map(|&s| ((s as f64) * opts.scale) as usize).collect(),
        )
    };
    let model = calibrated();
    let mut r = Report::new(["n", "P", "speedup dyn-LB", "speedup PATRIC"]);
    for &n in &sizes {
        let o = cache::oriented(&format!("pa:{n}:50"), 1.0)?;
        for &p in ps {
            let p = p.max(2);
            let d = simulate(&o, p, CostFn::Degree, SimGranularity::Shrinking, &model);
            let patric = simulate_patric_balanced(&o, p, CostFn::PatricBest, &model);
            r.row([
                Cell::Int(n as u64),
                Cell::Int(p as u64),
                Cell::Float(d.speedup()),
                Cell::Float(patric.speedup()),
            ]);
        }
    }
    r.note("expected: dyn-LB ≥ PATRIC at every (n, P); knee moves right with n");
    Ok(r)
}

#[cfg(test)]
mod tests {
    use crate::exp::report::Cell;

    #[test]
    fn dynamic_wins_on_average() {
        let opts = crate::exp::Options { quick: true, out_dir: None, ..Default::default() };
        let r = super::run(&opts).unwrap();
        let (mut sd, mut sp) = (0.0, 0.0);
        for row in &r.rows {
            if let (Cell::Float(d), Cell::Float(p)) = (&row[2], &row[3]) {
                sd += d;
                sp += p;
            }
        }
        assert!(sd >= sp, "dyn {sd} !>= patric {sp}");
    }
}
