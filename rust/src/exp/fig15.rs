//! Fig 15 — weak scaling of the §V dynamic-LB algorithm: problem size
//! grows with P; runtime should increase only very slowly (the
//! request/assign protocol overhead is tiny).

use crate::config::CostFn;
use crate::error::Result;
use crate::exp::report::{Cell, Report};
use crate::exp::{cache, Options};
use crate::sim::calibrate::calibrated;
use crate::sim::dynamic::{simulate, SimGranularity};

pub fn run(opts: &Options) -> Result<Report> {
    let (ps, npp): (&[usize], usize) = if opts.quick {
        (&[2, 4, 8], 500)
    } else {
        (super::fig9::P_SWEEP, ((super::fig9::NODES_PER_P as f64) * opts.scale) as usize)
    };
    let model = calibrated();
    let mut r = Report::new(["P", "n", "virtual runtime", "control msgs", "efficiency"]);
    let mut t0 = None;
    for &p in ps {
        let p = p.max(2);
        let n = npp * p;
        let o = cache::oriented(&format!("pa:{n}:50"), 1.0)?;
        let d = simulate(&o, p, CostFn::Degree, SimGranularity::Shrinking, &model);
        let t = d.makespan_ns / 1e9;
        let t0v = *t0.get_or_insert(t);
        r.row([
            Cell::Int(p as u64),
            Cell::Int(n as u64),
            Cell::Secs(t),
            Cell::Int(d.control_msgs),
            Cell::Float(t0v / t),
        ]);
    }
    r.note("expected: very slow runtime growth (good weak scaling)");
    Ok(r)
}

#[cfg(test)]
mod tests {
    #[test]
    fn runtime_growth_is_slow() {
        let opts = crate::exp::Options { quick: true, out_dir: None, ..Default::default() };
        let r = super::run(&opts).unwrap();
        let ts: Vec<f64> = (0..r.rows.len())
            .map(|i| r.secs(i, "virtual runtime").unwrap())
            .collect();
        assert!(
            ts.last().unwrap() / ts.first().unwrap() < 6.0,
            "weak scaling broke: {ts:?}"
        );
    }
}
