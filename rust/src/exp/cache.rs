//! Process-wide workload cache: `exp all` reuses each generated graph (and
//! its orientation) across experiments instead of regenerating per table.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use crate::error::Result;
use crate::graph::ordering::Oriented;

type Key = (String, u64); // (spec, scale in 1e-6 units)

struct Cache {
    graphs: HashMap<Key, Arc<crate::graph::csr::Csr>>,
    oriented: HashMap<Key, Arc<Oriented>>,
}

fn cache() -> &'static Mutex<Cache> {
    static C: OnceLock<Mutex<Cache>> = OnceLock::new();
    C.get_or_init(|| Mutex::new(Cache { graphs: HashMap::new(), oriented: HashMap::new() }))
}

fn key(spec: &str, scale: f64) -> Key {
    (spec.to_string(), (scale * 1e6).round() as u64)
}

/// Build (or fetch) a workload graph. Seeds come from the spec/presets, so
/// equal (spec, scale) is equal graph.
pub fn graph(spec: &str, scale: f64) -> Result<Arc<crate::graph::csr::Csr>> {
    let k = key(spec, scale);
    if let Some(g) = cache().lock().unwrap().graphs.get(&k) {
        return Ok(g.clone());
    }
    let g = Arc::new(crate::config::build_workload(spec, scale, 42)?);
    cache().lock().unwrap().graphs.insert(k, g.clone());
    Ok(g)
}

/// Build (or fetch) the oriented adjacency of a workload.
pub fn oriented(spec: &str, scale: f64) -> Result<Arc<Oriented>> {
    let k = key(spec, scale);
    if let Some(o) = cache().lock().unwrap().oriented.get(&k) {
        return Ok(o.clone());
    }
    let g = graph(spec, scale)?;
    let o = Arc::new(Oriented::from_graph(&g));
    cache().lock().unwrap().oriented.insert(k, o.clone());
    Ok(o)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_returns_same_arc() {
        let a = graph("pa:300:4", 1.0).unwrap();
        let b = graph("pa:300:4", 1.0).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let oa = oriented("pa:300:4", 1.0).unwrap();
        let ob = oriented("pa:300:4", 1.0).unwrap();
        assert!(Arc::ptr_eq(&oa, &ob));
    }

    #[test]
    fn different_scale_different_graph() {
        let a = graph("pa:300:4", 1.0).unwrap();
        let b = graph("pa:300:4", 0.5).unwrap();
        assert!(!Arc::ptr_eq(&a, &b));
        assert_ne!(a.num_nodes(), b.num_nodes());
    }
}
