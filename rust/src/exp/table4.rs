//! Table IV — runtime of the §V dynamic-load-balancing algorithm vs
//! PATRIC [21]. Paper's shape: dynamic-LB is ≥ 2× faster on every network
//! (0.041s vs 0.10s on web-BerkStan, 5.241s vs 11.835s on PA(20M,50)).

use crate::config::CostFn;
use crate::error::Result;
use crate::exp::report::{Cell, Report};
use crate::exp::{cache, Options};
use crate::seq::node_iterator;
use crate::sim::calibrate::calibrated;
use crate::sim::dynamic::{simulate, SimGranularity};
use crate::sim::space_efficient::simulate_patric_balanced;

/// (our workload, paper PATRIC s, paper ours s, paper triangles).
const ROWS: &[(&str, f64, f64, &str)] = &[
    ("berkstan-like", 0.10, 0.041, "65M"),
    ("livejournal-like", 0.8, 0.384, "286M"),
    ("miami-like", 0.6, 0.301, "332M"),
    ("pa:2000000:50", 11.835, 5.241, "0.028M"), // paper: PA(20M, 50)
];

pub fn run(opts: &Options) -> Result<Report> {
    let p = if opts.quick { 64 } else { 200 };
    let scale = if opts.quick { 0.05 * opts.scale } else { opts.scale };
    let model = calibrated();
    let mut r = Report::new([
        "network", "[21]", "dyn-LB", "speedup vs [21]", "triangles", "paper [21]", "paper dyn", "paper ratio",
    ]);
    for &(spec, p21, pdyn, _pt) in ROWS {
        let o = cache::oriented(spec, scale)?;
        let patric = simulate_patric_balanced(&o, p, CostFn::PatricBest, &model);
        let dynamic = simulate(&o, p, CostFn::Degree, SimGranularity::Shrinking, &model);
        let triangles = node_iterator::count(&o);
        r.row([
            spec.into(),
            Cell::Secs(patric.makespan_ns / 1e9),
            Cell::Secs(dynamic.makespan_ns / 1e9),
            Cell::Float(patric.makespan_ns / dynamic.makespan_ns),
            Cell::Int(triangles),
            Cell::Secs(p21),
            Cell::Secs(pdyn),
            Cell::Float(p21 / pdyn),
        ]);
    }
    r.note(format!("P = {p}; dynamic-LB uses f(v)=d_v with shrinking granularity (Eqn 2)"));
    Ok(r)
}

#[cfg(test)]
mod tests {
    #[test]
    fn dynamic_at_least_as_fast_as_patric() {
        let opts = crate::exp::Options { quick: true, out_dir: None, ..Default::default() };
        let r = super::run(&opts).unwrap();
        for i in 0..r.rows.len() {
            let ratio = r.float(i, "speedup vs [21]").unwrap();
            assert!(ratio >= 1.0, "dynamic slower than PATRIC: ratio {ratio}");
        }
    }
}
