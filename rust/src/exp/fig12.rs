//! Fig 12 — strong scaling of the §V dynamic-LB algorithm with cost
//! functions f(v)=1 vs f(v)=d_v. Paper's shape: f=d_v clearly higher.

use crate::config::CostFn;
use crate::error::Result;
use crate::exp::report::{Cell, Report};
use crate::exp::{cache, Options};
use crate::sim::calibrate::calibrated;
use crate::sim::dynamic::{simulate, SimGranularity};

pub fn run(opts: &Options) -> Result<Report> {
    let (ps, scale): (&[usize], f64) = if opts.quick {
        (&[4, 16], 0.02 * opts.scale)
    } else {
        (super::fig4::P_SWEEP, opts.scale)
    };
    let model = calibrated();
    let mut r = Report::new(["network", "P", "speedup f=d_v", "speedup f=1"]);
    for net in super::fig4::NETWORKS {
        let o = cache::oriented(net, scale)?;
        for &p in ps {
            let p = p.max(2);
            let fd = simulate(&o, p, CostFn::Degree, SimGranularity::Shrinking, &model);
            let f1 = simulate(&o, p, CostFn::Unit, SimGranularity::Shrinking, &model);
            r.row([
                (*net).into(),
                Cell::Int(p as u64),
                Cell::Float(fd.speedup()),
                Cell::Float(f1.speedup()),
            ]);
        }
    }
    r.note("expected: f=d_v ≥ f=1 everywhere, gap widest on skewed nets");
    Ok(r)
}

#[cfg(test)]
mod tests {
    use crate::exp::report::Cell;

    #[test]
    fn degree_cost_fn_wins_on_average() {
        let opts = crate::exp::Options { quick: true, out_dir: None, ..Default::default() };
        let r = super::run(&opts).unwrap();
        let (mut sum_d, mut sum_1) = (0.0, 0.0);
        for row in &r.rows {
            if let (Cell::Float(d), Cell::Float(u)) = (&row[2], &row[3]) {
                sum_d += d;
                sum_1 += u;
            }
        }
        assert!(sum_d >= sum_1 * 0.98, "f=d_v {sum_d} vs f=1 {sum_1}");
    }
}
