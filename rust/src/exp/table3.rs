//! Table III — runtime of the §IV space-efficient algorithm (direct &
//! surrogate schemes) vs PATRIC [21], P = 200, plus exact triangle counts.
//!
//! Paper's shape: direct ≫ surrogate (3.8s vs 0.14s on web-BerkStan);
//! surrogate within ~1.3-1.6× of PATRIC (which needs no counting
//! communication but pays in memory).

use crate::config::CostFn;
use crate::error::Result;
use crate::exp::report::{Cell, Report};
use crate::exp::{cache, Options};
use crate::seq::node_iterator;
use crate::sim::calibrate::calibrated;
use crate::sim::space_efficient::{simulate_balanced, simulate_patric_balanced, Scheme};

/// (our workload, paper runtimes in seconds: PATRIC, direct, surrogate, paper triangles).
const ROWS: &[(&str, f64, f64, f64, &str)] = &[
    ("berkstan-like", 0.10, 3.8, 0.14, "65M"),
    ("miami-like", 0.6, 4.79, 0.79, "332M"),
    ("livejournal-like", 0.8, 5.12, 1.24, "286M"),
    ("twitter-like", 564.0, 2129.4, 739.8, "34.8B"),
    ("pa:1000000:20", 930.0, 4737.6, 1246.2, "0.403M"), // paper: PA(1B, 20)
];

pub fn run(opts: &Options) -> Result<Report> {
    let p = if opts.quick { 8 } else { 200 };
    let scale = if opts.quick { 0.02 * opts.scale } else { opts.scale };
    let model = calibrated();
    let mut r = Report::new([
        "network", "[21]", "direct", "surrogate", "triangles",
        "paper [21]", "paper direct", "paper surrogate", "paper T",
    ]);
    for &(spec, p21, pdir, psur, pt) in ROWS {
        let o = cache::oriented(spec, scale)?;
        let patric = simulate_patric_balanced(&o, p, CostFn::PatricBest, &model);
        let direct = simulate_balanced(&o, p, CostFn::SurrogateNew, Scheme::Direct, &model);
        let surrogate = simulate_balanced(&o, p, CostFn::SurrogateNew, Scheme::Surrogate, &model);
        let triangles = node_iterator::count(&o);
        r.row([
            spec.into(),
            Cell::Secs(patric.makespan_ns / 1e9),
            Cell::Secs(direct.makespan_ns / 1e9),
            Cell::Secs(surrogate.makespan_ns / 1e9),
            Cell::Int(triangles),
            Cell::Secs(p21),
            Cell::Secs(pdir),
            Cell::Secs(psur),
            pt.into(),
        ]);
    }
    r.note(format!(
        "P = {p} virtual processors; α = {:.2} ns/work-unit (calibrated); counts are exact (real kernel)",
        model.alpha_ns
    ));
    r.note("expected shape: direct ≫ surrogate ≳ [21]");
    Ok(r)
}

#[cfg(test)]
mod tests {
    #[test]
    fn quick_run_orderings_hold() {
        let opts = crate::exp::Options { quick: true, out_dir: None, ..Default::default() };
        let r = super::run(&opts).unwrap();
        for i in 0..r.rows.len() {
            let patric = r.secs(i, "[21]").unwrap();
            let direct = r.secs(i, "direct").unwrap();
            let surrogate = r.secs(i, "surrogate").unwrap();
            assert!(direct > surrogate, "direct {direct} !> surrogate {surrogate}");
            assert!(surrogate >= patric * 0.9, "surrogate {surrogate} vs patric {patric}");
        }
    }
}
