//! Fig 7 — space requirement vs average degree: PA(n, d) with d swept
//! 10→100, largest-partition bytes for the non-overlapping scheme (ours)
//! vs PATRIC's overlapping scheme. Paper's shape: ours grows slowly and
//! linearly; PATRIC's grows rapidly (the overlap multiplies with degree).

use crate::error::Result;
use crate::exp::report::{Cell, Report};
use crate::exp::{cache, Options};
use crate::partition::balance::balanced_ranges;
use crate::partition::cost::prefix_sums;
use crate::partition::nonoverlap::partition_sizes;
use crate::partition::overlap::overlap_sizes;

/// Node count at scale 1.0 (paper: 10M — scaled per DESIGN §3).
pub const N: usize = 100_000;
pub const DEGREES: &[usize] = &[10, 20, 30, 40, 50, 60, 70, 80, 90, 100];

pub fn run(opts: &Options) -> Result<Report> {
    let (n, p, degrees): (usize, usize, &[usize]) = if opts.quick {
        (3_000, 10, &[10, 30, 60])
    } else {
        (((N as f64) * opts.scale) as usize, 100, DEGREES)
    };
    let mut r = Report::new(["avg degree", "ours MB", "ours measured MB", "PATRIC MB", "ratio"]);
    for &d in degrees {
        let o = cache::oriented(&format!("pa:{n}:{d}"), 1.0)?;
        // Same edge-balanced ranges for both schemes (see table2.rs).
        let edge_costs: Vec<u64> =
            (0..o.num_nodes() as u32).map(|v| o.effective_degree(v) as u64).collect();
        let ranges = balanced_ranges(&prefix_sums(&edge_costs), p);
        let g0 = cache::graph(&format!("pa:{n}:{d}"), 1.0)?;
        let ours = partition_sizes(&o, &ranges).iter().map(|s| s.mb()).fold(0.0f64, f64::max);
        // Measured: what the largest materialized rank partition actually
        // holds (bitmaps off — this figure is about the CSR bytes).
        let measured =
            crate::partition::owned::extract_nonoverlapping(&o, &ranges, crate::adj::HubThreshold::Off)
                .iter()
                .map(|part| part.resident_bytes() as f64 / (1024.0 * 1024.0))
                .fold(0.0f64, f64::max);
        let patric = overlap_sizes(&g0, &o, &ranges).iter().map(|s| s.mb()).fold(0.0f64, f64::max);
        r.row([
            Cell::Int(d as u64),
            Cell::Float(ours),
            Cell::Float(measured),
            Cell::Float(patric),
            Cell::Float(patric / ours.max(1e-12)),
        ]);
    }
    r.note(format!(
        "PA({n}, d), P = {p}; expected: ratio grows with d; measured column is the \
materialized largest rank partition (== prediction)"
    ));
    Ok(r)
}

#[cfg(test)]
mod tests {
    #[test]
    fn overlap_ratio_grows_with_degree() {
        let opts = crate::exp::Options { quick: true, out_dir: None, ..Default::default() };
        let r = super::run(&opts).unwrap();
        let col = |name: &str| -> Vec<f64> {
            (0..r.rows.len()).map(|i| r.float(i, name).unwrap()).collect()
        };
        let ratios = col("ratio");
        assert!(
            ratios.last().unwrap() > ratios.first().unwrap(),
            "ratio must grow with degree: {ratios:?}"
        );
        // Measured largest partition must equal the prediction on every row.
        for (pred, meas) in col("ours MB").iter().zip(col("ours measured MB")) {
            assert!((pred - meas).abs() < 1e-9, "measured {meas} != predicted {pred}");
        }
    }
}
