//! Fig 8 — memory scalability: largest non-overlapping partition shrinks
//! as processors are added (≈ m/P decay), shown for Miami- and
//! LiveJournal-like networks.

use crate::error::Result;
use crate::exp::report::{Cell, Report};
use crate::exp::{cache, Options};
use crate::partition::balance::balanced_ranges;
use crate::partition::cost::prefix_sums;
use crate::partition::nonoverlap::partition_sizes;

pub const P_SWEEP: &[usize] = &[25, 50, 100, 150, 200];

pub fn run(opts: &Options) -> Result<Report> {
    let (ps, scale): (&[usize], f64) = if opts.quick {
        (&[2, 8, 32], 0.02 * opts.scale)
    } else {
        (P_SWEEP, opts.scale)
    };
    let mut r = Report::new(["network", "P", "largest partition MB", "m/P edges"]);
    for net in ["miami-like", "livejournal-like"] {
        let o = cache::oriented(net, scale)?;
        let edge_costs: Vec<u64> =
            (0..o.num_nodes() as u32).map(|v| o.effective_degree(v) as u64).collect();
        for &p in ps {
            let ranges = balanced_ranges(&prefix_sums(&edge_costs), p);
            let mb = partition_sizes(&o, &ranges).iter().map(|s| s.mb()).fold(0.0f64, f64::max);
            r.row([
                net.into(),
                Cell::Int(p as u64),
                Cell::Float(mb),
                Cell::Int(o.num_edges() / p as u64),
            ]);
        }
    }
    r.note("expected: largest partition decays ≈ 1/P");
    Ok(r)
}

#[cfg(test)]
mod tests {
    #[test]
    fn memory_decreases_with_p() {
        let opts = crate::exp::Options { quick: true, out_dir: None, ..Default::default() };
        let r = super::run(&opts).unwrap();
        let mbs: Vec<f64> = (0..r.rows.len())
            .map(|i| r.float(i, "largest partition MB").unwrap())
            .collect();
        // Within each network the MB column must be non-increasing in P.
        for chunk in mbs.chunks(3) {
            for w in chunk.windows(2) {
                assert!(w[1] <= w[0] * 1.05, "memory must shrink with P: {chunk:?}");
            }
        }
    }
}
