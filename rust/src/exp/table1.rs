//! Table I — dataset summary: the paper's datasets vs our generated
//! substitutes (see DESIGN.md §3 for the substitution rationale).

use crate::error::Result;
use crate::exp::report::{Cell, Report};
use crate::exp::{cache, Options};
use crate::gen::presets::PRESETS;
use crate::graph::stats::degree_stats;

pub fn run(opts: &Options) -> Result<Report> {
    let mut r = Report::new([
        "preset", "paper net", "paper n", "paper m", "our n", "our m", "d̄", "d_max", "cv",
    ]);
    let scale = if opts.quick { 0.05 * opts.scale } else { opts.scale };
    for p in PRESETS {
        let g = cache::graph(p.name, scale)?;
        let s = degree_stats(&g);
        r.row([
            p.name.into(),
            p.paper_name.into(),
            Cell::Float(p.paper_nodes),
            Cell::Float(p.paper_edges),
            Cell::Int(s.nodes as u64),
            Cell::Int(s.edges),
            Cell::Float(s.avg_degree),
            Cell::Int(s.max_degree as u64),
            Cell::Float(s.cv),
        ]);
    }
    r.note(format!(
        "substitutes at ~{:.2}× of 1/10-paper node counts; skew (cv) is the matched property",
        scale
    ));
    Ok(r)
}

#[cfg(test)]
mod tests {
    #[test]
    fn runs_quick() {
        let opts = crate::exp::Options { quick: true, out_dir: None, ..Default::default() };
        let r = super::run(&opts).unwrap();
        assert_eq!(r.rows.len(), crate::gen::presets::PRESETS.len());
    }
}
