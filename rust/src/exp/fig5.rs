//! Fig 5 — effect of the cost-estimation function: the §IV-F estimator
//! `f(v)=Σ_{u∈𝒩_v−N_v}(d̂_v+d̂_u)` vs PATRIC's best `f(v)=Σ_{u∈N_v}(…)`.
//! Paper's shape: the new estimator wins on skewed networks (LiveJournal,
//! web-BerkStan); on even-degree Miami the two are indistinguishable.

use crate::config::CostFn;
use crate::error::Result;
use crate::exp::report::{Cell, Report};
use crate::exp::{cache, Options};
use crate::sim::calibrate::calibrated;
use crate::sim::space_efficient::{simulate_balanced, Scheme};

pub fn run(opts: &Options) -> Result<Report> {
    let (ps, scale): (&[usize], f64) = if opts.quick {
        (&[4, 16], 0.02 * opts.scale)
    } else {
        (super::fig4::P_SWEEP, opts.scale)
    };
    let model = calibrated();
    let mut r = Report::new(["network", "P", "speedup new f(v)", "speedup PATRIC f(v)", "gain %"]);
    for net in super::fig4::NETWORKS {
        let o = cache::oriented(net, scale)?;
        for &p in ps {
            let new = simulate_balanced(&o, p, CostFn::SurrogateNew, Scheme::Surrogate, &model);
            let old = simulate_balanced(&o, p, CostFn::PatricBest, Scheme::Surrogate, &model);
            r.row([
                (*net).into(),
                Cell::Int(p as u64),
                Cell::Float(new.speedup()),
                Cell::Float(old.speedup()),
                Cell::Float(100.0 * (new.speedup() / old.speedup() - 1.0)),
            ]);
        }
    }
    r.note("expected: gain > 0 on skewed nets (livejournal/berkstan), ≈ 0 on miami-like");
    Ok(r)
}

#[cfg(test)]
mod tests {
    use crate::exp::report::Cell;

    #[test]
    fn new_estimator_not_worse_on_skewed_nets() {
        let opts = crate::exp::Options { quick: true, out_dir: None, ..Default::default() };
        let r = super::run(&opts).unwrap();
        // Averaged over the sweep, the new estimator must not lose.
        let mut gain_sum = 0.0;
        for row in &r.rows {
            if let Cell::Float(g) = row[4] {
                gain_sum += g;
            }
        }
        assert!(
            gain_sum / r.rows.len() as f64 > -2.0,
            "new estimator lost on average: {gain_sum}"
        );
    }
}
