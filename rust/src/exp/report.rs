//! Tabular report emitter shared by all experiment drivers: aligned text
//! to stdout (paper-shaped rows) + CSV for plotting.

use crate::error::{Error, Result};
use std::io::Write;

/// A cell value.
#[derive(Clone, Debug)]
pub enum Cell {
    Text(String),
    Int(u64),
    Float(f64),
    /// Seconds, pretty-printed (ms/s/m adaptive).
    Secs(f64),
}

impl Cell {
    /// Variant name for error messages.
    pub fn kind(&self) -> &'static str {
        match self {
            Cell::Text(_) => "text",
            Cell::Int(_) => "int",
            Cell::Float(_) => "float",
            Cell::Secs(_) => "secs",
        }
    }

    /// Typed extraction; a mismatched variant is an [`Error::Report`]
    /// naming the actual cell instead of a unit panic.
    pub fn as_int(&self) -> Result<u64> {
        match self {
            Cell::Int(x) => Ok(*x),
            other => Err(other.type_error("int")),
        }
    }

    /// See [`Cell::as_int`]; strict — an `Int` cell is not a float.
    pub fn as_float(&self) -> Result<f64> {
        match self {
            Cell::Float(x) => Ok(*x),
            other => Err(other.type_error("float")),
        }
    }

    /// See [`Cell::as_int`].
    pub fn as_secs(&self) -> Result<f64> {
        match self {
            Cell::Secs(x) => Ok(*x),
            other => Err(other.type_error("secs")),
        }
    }

    /// See [`Cell::as_int`].
    pub fn as_text(&self) -> Result<&str> {
        match self {
            Cell::Text(s) => Ok(s),
            other => Err(other.type_error("text")),
        }
    }

    fn type_error(&self, expected: &str) -> Error {
        Error::Report(format!("expected a {expected} cell, got {} `{}`", self.kind(), self.render()))
    }

    fn render(&self) -> String {
        match self {
            Cell::Text(s) => s.clone(),
            Cell::Int(x) => x.to_string(),
            Cell::Float(x) => {
                if x.abs() >= 100.0 {
                    format!("{x:.1}")
                } else {
                    format!("{x:.3}")
                }
            }
            Cell::Secs(s) => {
                if *s < 1e-3 {
                    format!("{:.1}µs", s * 1e6)
                } else if *s < 1.0 {
                    format!("{:.2}ms", s * 1e3)
                } else if *s < 120.0 {
                    format!("{s:.2}s")
                } else {
                    format!("{:.2}m", s / 60.0)
                }
            }
        }
    }

    fn csv(&self) -> String {
        match self {
            Cell::Text(s) => s.replace(',', ";"),
            Cell::Int(x) => x.to_string(),
            Cell::Float(x) => format!("{x}"),
            Cell::Secs(s) => format!("{s}"),
        }
    }

    /// JSON value: strings quoted+escaped, numbers bare (non-finite → null).
    fn json(&self) -> String {
        match self {
            Cell::Text(s) => json_string(s),
            Cell::Int(x) => x.to_string(),
            Cell::Float(x) | Cell::Secs(x) => {
                if x.is_finite() {
                    format!("{x}")
                } else {
                    "null".into()
                }
            }
        }
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

impl From<&str> for Cell {
    fn from(s: &str) -> Self {
        Cell::Text(s.into())
    }
}
impl From<String> for Cell {
    fn from(s: String) -> Self {
        Cell::Text(s)
    }
}
impl From<u64> for Cell {
    fn from(x: u64) -> Self {
        Cell::Int(x)
    }
}
impl From<usize> for Cell {
    fn from(x: usize) -> Self {
        Cell::Int(x as u64)
    }
}
impl From<f64> for Cell {
    fn from(x: f64) -> Self {
        Cell::Float(x)
    }
}

/// A report: header + rows + free-form notes.
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub columns: Vec<String>,
    pub rows: Vec<Vec<Cell>>,
    pub notes: Vec<String>,
}

impl Report {
    pub fn new<I: IntoIterator<Item = S>, S: Into<String>>(columns: I) -> Self {
        Report { columns: columns.into_iter().map(Into::into).collect(), rows: vec![], notes: vec![] }
    }

    pub fn row<I: IntoIterator<Item = Cell>>(&mut self, cells: I) {
        let row: Vec<Cell> = cells.into_iter().collect();
        debug_assert_eq!(row.len(), self.columns.len());
        self.rows.push(row);
    }

    pub fn note<S: Into<String>>(&mut self, s: S) {
        self.notes.push(s.into());
    }

    /// Cell lookup by row index and column *name*. A missing column, an
    /// out-of-range row, or a ragged row is an [`Error::Report`] with
    /// enough context to name the malformed cell.
    pub fn cell(&self, row: usize, col: &str) -> Result<&Cell> {
        let ci = self
            .columns
            .iter()
            .position(|c| c == col)
            .ok_or_else(|| {
                Error::Report(format!("no column `{col}` (have: {})", self.columns.join(", ")))
            })?;
        let r = self
            .rows
            .get(row)
            .ok_or_else(|| Error::Report(format!("row {row} out of range ({} rows)", self.rows.len())))?;
        r.get(ci).ok_or_else(|| {
            Error::Report(format!("row {row} has {} cells, no column `{col}` (index {ci})", r.len()))
        })
    }

    /// Typed accessors over [`Report::cell`] — the shared extraction the
    /// experiment assertions use instead of `match … panic!()`.
    pub fn int(&self, row: usize, col: &str) -> Result<u64> {
        self.cell(row, col)?.as_int().map_err(|e| Self::at(row, col, e))
    }

    /// See [`Report::int`].
    pub fn float(&self, row: usize, col: &str) -> Result<f64> {
        self.cell(row, col)?.as_float().map_err(|e| Self::at(row, col, e))
    }

    /// See [`Report::int`].
    pub fn secs(&self, row: usize, col: &str) -> Result<f64> {
        self.cell(row, col)?.as_secs().map_err(|e| Self::at(row, col, e))
    }

    /// See [`Report::int`].
    pub fn text(&self, row: usize, col: &str) -> Result<&str> {
        self.cell(row, col)?.as_text().map_err(|e| Self::at(row, col, e))
    }

    fn at(row: usize, col: &str, e: Error) -> Error {
        match e {
            Error::Report(m) => Error::Report(format!("row {row}, column `{col}`: {m}")),
            e => e,
        }
    }

    /// Aligned text table to stdout.
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        let rendered: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| r.iter().map(|c| c.render()).collect())
            .collect();
        for r in &rendered {
            for (i, cell) in r.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let head: Vec<String> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
            .collect();
        println!("{}", head.join("  "));
        println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for r in rendered {
            let line: Vec<String> = r
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect();
            println!("{}", line.join("  "));
        }
        for n in &self.notes {
            println!("  note: {n}");
        }
    }

    /// The shared JSON report schema (`tricount exp` and `tricount stream`
    /// both emit it): `{"columns": […], "rows": [{col: value…}…],
    /// "notes": […]}`. Dependency-free serialization.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n  \"columns\": [");
        out.push_str(
            &self
                .columns
                .iter()
                .map(|c| json_string(c))
                .collect::<Vec<_>>()
                .join(", "),
        );
        out.push_str("],\n  \"rows\": [");
        for (i, row) in self.rows.iter().enumerate() {
            out.push_str(if i == 0 { "\n" } else { ",\n" });
            let fields: Vec<String> = self
                .columns
                .iter()
                .zip(row)
                .map(|(c, cell)| format!("{}: {}", json_string(c), cell.json()))
                .collect();
            out.push_str(&format!("    {{{}}}", fields.join(", ")));
        }
        out.push_str("\n  ],\n  \"notes\": [");
        out.push_str(
            &self
                .notes
                .iter()
                .map(|n| json_string(n))
                .collect::<Vec<_>>()
                .join(", "),
        );
        out.push_str("]\n}\n");
        out
    }

    /// Write [`Report::to_json`] to a file.
    pub fn write_json(&self, path: &str) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(self.to_json().as_bytes())?;
        Ok(())
    }

    /// CSV (comma-separated; notes as trailing comments).
    pub fn write_csv(&self, path: &str) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "{}", self.columns.join(","))?;
        for r in &self.rows {
            let line: Vec<String> = r.iter().map(|c| c.csv()).collect();
            writeln!(f, "{}", line.join(","))?;
        }
        for n in &self.notes {
            writeln!(f, "# {n}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_renders() {
        let mut r = Report::new(["net", "P", "speedup"]);
        r.row(["miami".into(), Cell::Int(100), Cell::Float(52.5)]);
        r.note("virtual time");
        assert_eq!(r.rows.len(), 1);
        r.print(); // smoke: no panic
    }

    #[test]
    fn typed_accessors_and_context() {
        let mut r = Report::new(["net", "P", "t", "x"]);
        r.row(["miami".into(), Cell::Int(4), Cell::Secs(0.25), Cell::Float(1.5)]);
        assert_eq!(r.text(0, "net").unwrap(), "miami");
        assert_eq!(r.int(0, "P").unwrap(), 4);
        assert_eq!(r.secs(0, "t").unwrap(), 0.25);
        assert_eq!(r.float(0, "x").unwrap(), 1.5);

        // A malformed row fails with row/column/variant context.
        let e = r.float(0, "P").unwrap_err().to_string();
        assert!(e.contains("row 0"), "{e}");
        assert!(e.contains("column `P`"), "{e}");
        assert!(e.contains("expected a float cell, got int `4`"), "{e}");
        let e = r.int(0, "nope").unwrap_err().to_string();
        assert!(e.contains("no column `nope`"), "{e}");
        let e = r.int(3, "P").unwrap_err().to_string();
        assert!(e.contains("row 3 out of range"), "{e}");
        assert!(matches!(r.cell(0, "zzz"), Err(crate::error::Error::Report(_))));
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(Cell::Secs(0.0000005).render(), "0.5µs");
        assert_eq!(Cell::Secs(0.5).render(), "500.00ms");
        assert_eq!(Cell::Secs(12.0).render(), "12.00s");
        assert_eq!(Cell::Secs(744.0).render(), "12.40m");
    }

    #[test]
    fn json_schema_and_escaping() {
        let mut r = Report::new(["net", "P", "t"]);
        r.row([Cell::Text("say \"hi\"\n".into()), Cell::Int(4), Cell::Secs(0.25)]);
        r.note("virtual time");
        let j = r.to_json();
        assert!(j.contains("\"columns\": [\"net\", \"P\", \"t\"]"), "{j}");
        assert!(j.contains("{\"net\": \"say \\\"hi\\\"\\n\", \"P\": 4, \"t\": 0.25}"), "{j}");
        assert!(j.contains("\"notes\": [\"virtual time\"]"), "{j}");
        // Empty report is still valid schema.
        let empty = Report::new(["a"]).to_json();
        assert!(empty.contains("\"rows\": []"), "{empty}");
    }

    #[test]
    fn json_non_finite_floats_are_null() {
        let mut r = Report::new(["x"]);
        r.row([Cell::Float(f64::NAN)]);
        assert!(r.to_json().contains("{\"x\": null}"));
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("tricount_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("r.csv");
        let mut r = Report::new(["a", "b"]);
        r.row([Cell::Int(1), Cell::Text("x,y".into())]);
        r.write_csv(p.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("a,b\n1,x;y\n"), "{text}");
    }
}
