//! Fig 6 — improved scalability of the §IV algorithm with increasing
//! network size: bigger PA(n,50) networks keep gaining speedup at higher P
//! (the speedup knee moves right as n grows).

use crate::config::CostFn;
use crate::error::Result;
use crate::exp::report::{Cell, Report};
use crate::exp::{cache, Options};
use crate::sim::calibrate::calibrated;
use crate::sim::space_efficient::{simulate_balanced, Scheme};

/// Network-size sweep (paper uses PA(nM, 50); we scale by 1/10, DESIGN §3).
pub const SIZES: &[usize] = &[100_000, 200_000, 400_000];

pub fn run(opts: &Options) -> Result<Report> {
    let (ps, sizes): (&[usize], Vec<usize>) = if opts.quick {
        (&[4, 16, 64], vec![2_000, 8_000])
    } else {
        (
            &[25, 50, 100, 200, 400],
            SIZES.iter().map(|&s| ((s as f64) * opts.scale) as usize).collect(),
        )
    };
    let model = calibrated();
    let mut r = Report::new(["n", "P", "speedup"]);
    for &n in &sizes {
        let o = cache::oriented(&format!("pa:{n}:50"), 1.0)?;
        for &p in ps {
            let s = simulate_balanced(&o, p, CostFn::SurrogateNew, Scheme::Surrogate, &model);
            r.row([Cell::Int(n as u64), Cell::Int(p as u64), Cell::Float(s.speedup())]);
        }
    }
    r.note("expected: larger n sustains speedup growth to larger P");
    Ok(r)
}

#[cfg(test)]
mod tests {
    use crate::exp::report::Cell;

    #[test]
    fn bigger_networks_scale_further() {
        let opts = crate::exp::Options { quick: true, out_dir: None, ..Default::default() };
        let r = super::run(&opts).unwrap();
        // At the largest P, the biggest network must have the best speedup.
        let max_p = r
            .rows
            .iter()
            .filter_map(|row| if let Cell::Int(p) = row[1] { Some(p) } else { None })
            .max()
            .unwrap();
        let at_max: Vec<(u64, f64)> = r
            .rows
            .iter()
            .filter_map(|row| match (&row[0], &row[1], &row[2]) {
                (Cell::Int(n), Cell::Int(p), Cell::Float(s)) if *p == max_p => Some((*n, *s)),
                _ => None,
            })
            .collect();
        let small = at_max.iter().min_by_key(|(n, _)| *n).unwrap();
        let large = at_max.iter().max_by_key(|(n, _)| *n).unwrap();
        assert!(large.1 >= small.1, "larger net {large:?} !>= smaller {small:?}");
    }
}
