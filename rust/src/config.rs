//! Run configuration for the launcher (`tricount` CLI).
//!
//! A small, dependency-free key-value config format (TOML-subset: `key =
//! value` lines, `#` comments, sections ignored for flatness) plus CLI
//! override parsing. Every experiment driver takes a [`RunConfig`] so runs
//! are reproducible from a single file; `tricount --config run.toml`.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};

/// Which parallel algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// Sequential Fig-1 baseline.
    Sequential,
    /// §IV space-efficient, surrogate communication (the paper's headline).
    Surrogate,
    /// §IV with the direct request/response scheme (baseline).
    Direct,
    /// PATRIC [21] overlapping-partition baseline.
    Patric,
    /// §V dynamic load balancing.
    DynamicLb,
    /// 2D tile-partitioned driver with coalesced row/column broadcasts
    /// (O(m/√P) per-rank traffic; DESIGN.md §14).
    Tile2d,
    /// Hybrid dense-core (XLA tensor path) + sparse remainder.
    Hybrid,
}

impl std::str::FromStr for Algorithm {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "seq" | "sequential" => Algorithm::Sequential,
            "surrogate" => Algorithm::Surrogate,
            "direct" => Algorithm::Direct,
            "patric" => Algorithm::Patric,
            "dynamic" | "dynamic-lb" => Algorithm::DynamicLb,
            "tile2d" | "2d" => Algorithm::Tile2d,
            "hybrid" => Algorithm::Hybrid,
            other => return Err(Error::Config(format!("unknown algorithm `{other}`"))),
        })
    }
}

/// Cost function used for partition balancing / task sizing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CostFn {
    /// `f(v) = 1`.
    Unit,
    /// `f(v) = d_v`.
    Degree,
    /// PATRIC's best: `f(v) = Σ_{u∈N_v}(d̂_v + d̂_u)`.
    PatricBest,
    /// This paper's §IV-F estimator: `f(v) = Σ_{u∈𝒩_v−N_v}(d̂_v + d̂_u)`.
    SurrogateNew,
    /// Representation-aware: `f(v) = Σ_{u∈N_v} hybrid_cost(v, u)`, charging
    /// the `adj/` dispatch's actual kernel (probe / word-AND on hub rows)
    /// instead of the merge model — the estimator to use once bitmaps make
    /// hub work cheaper than any degree-based `f(v)` predicts.
    Hybrid,
}

impl std::str::FromStr for CostFn {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "unit" | "1" => CostFn::Unit,
            "degree" | "dv" => CostFn::Degree,
            "patric" | "patric-best" => CostFn::PatricBest,
            "new" | "surrogate-new" => CostFn::SurrogateNew,
            "hybrid" | "hybrid-aware" => CostFn::Hybrid,
            other => return Err(Error::Config(format!("unknown cost fn `{other}`"))),
        })
    }
}

/// Full run configuration with defaults.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Workload: a preset name (`livejournal-like`), `pa:<n>:<d>`,
    /// `rmat:<scale>:<ef>`, `er:<n>:<d̄>`, `contact:<n>:<d>`,
    /// `file:<path>` (edge-list text), `tcg:<path>` (zero-parse binary,
    /// see `tricount convert`), `bin:<path>` (legacy) or `karate`.
    pub workload: String,
    /// Number of processors (ranks) P.
    pub procs: usize,
    /// Algorithm to run.
    pub algorithm: Algorithm,
    /// Cost function for balancing.
    pub cost_fn: CostFn,
    /// Relative workload scale (presets only).
    pub scale: f64,
    /// RNG seed for generators.
    pub seed: u64,
    /// Dense-core size K for the hybrid tensor path (0 = auto).
    pub dense_core: usize,
    /// Directory of AOT artifacts.
    pub artifacts_dir: String,
    /// Hub-bitmap threshold policy for the oriented adjacency
    /// (`--hub-threshold <n|auto|off>`).
    pub hub_threshold: crate::adj::HubThreshold,
    /// Preprocessing thread count (`--build-threads <n|auto>`): CSR build,
    /// degree ordering, relabel, orientation and hub-index packing all fan
    /// out over this many scoped threads, with bit-identical output at
    /// every setting. The CLI installs the resolved value as
    /// [`crate::par::set_default_threads`], so per-batch stream
    /// compaction inherits it too.
    pub build_threads: crate::par::BuildThreads,
    /// `--mem-budget <bytes>` (suffixes `kb`/`mb`/`gb` accepted, binary
    /// units): when set on a partitioned §IV run, `procs` is overridden by
    /// the smallest `P` whose largest predicted partition fits the budget
    /// ([`crate::partition::nonoverlap::min_procs_for_budget`]) — the
    /// paper's Table II sizing question, answered by the tool.
    pub mem_budget: Option<u64>,
    /// `--on-fault <fail|recover|degrade>`: what a supervised run does
    /// when a rank dies (DESIGN.md §13). `fail` (default) propagates the
    /// error, `recover` re-executes the un-acked remainder on the
    /// survivors for the exact count, `degrade` answers from checkpoints
    /// with a stated confidence bound.
    pub on_fault: crate::ft::FaultPolicy,
    /// `--fabric <threads|tcp>`: which communication fabric carries the
    /// run. `threads` (default) is the in-process channel fabric; `tcp`
    /// runs each rank as its own OS process over loopback sockets
    /// (`comm::tcp`, DESIGN.md §15) — `tricount count --fabric tcp`
    /// delegates to the `launch` machinery.
    pub fabric: FabricKind,
}

/// Which communication fabric a `count` run uses (`--fabric`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FabricKind {
    /// In-process ranks over mpsc channels (the default).
    Threads,
    /// One OS process per rank over loopback TCP (`comm::tcp`).
    Tcp,
}

impl std::str::FromStr for FabricKind {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "threads" | "channel" => FabricKind::Threads,
            "tcp" | "socket" => FabricKind::Tcp,
            other => {
                return Err(Error::Config(format!(
                    "unknown fabric `{other}` (expected threads|tcp)"
                )))
            }
        })
    }
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            workload: "karate".into(),
            procs: 4,
            algorithm: Algorithm::Surrogate,
            cost_fn: CostFn::SurrogateNew,
            scale: 1.0,
            seed: 42,
            dense_core: 0,
            artifacts_dir: "artifacts".into(),
            hub_threshold: crate::adj::HubThreshold::Auto,
            build_threads: crate::par::BuildThreads::Auto,
            mem_budget: None,
            on_fault: crate::ft::FaultPolicy::Fail,
            fabric: FabricKind::Threads,
        }
    }
}

/// Parse a byte size: a plain integer, optionally suffixed `k`/`kb`,
/// `m`/`mb` or `g`/`gb` (case-insensitive, binary units).
pub fn parse_bytes(s: &str) -> Result<u64> {
    let t = s.trim().to_ascii_lowercase();
    let (digits, mult) = if let Some(d) = t.strip_suffix("kb").or_else(|| t.strip_suffix('k')) {
        (d, 1u64 << 10)
    } else if let Some(d) = t.strip_suffix("mb").or_else(|| t.strip_suffix('m')) {
        (d, 1u64 << 20)
    } else if let Some(d) = t.strip_suffix("gb").or_else(|| t.strip_suffix('g')) {
        (d, 1u64 << 30)
    } else {
        (t.as_str(), 1u64)
    };
    let n: u64 = digits
        .trim()
        .parse()
        .map_err(|_| Error::Config(format!("`{s}` is not a byte size (N, Nkb, Nmb, Ngb)")))?;
    n.checked_mul(mult)
        .ok_or_else(|| Error::Config(format!("byte size `{s}` overflows u64")))
}

impl RunConfig {
    /// Apply one `key = value` (or CLI `--key value`) pair.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "workload" => self.workload = value.to_string(),
            "procs" => {
                self.procs = value
                    .parse()
                    .map_err(|e| Error::Config(format!("procs: {e}")))?
            }
            "algorithm" => self.algorithm = value.parse()?,
            "cost_fn" | "cost-fn" => self.cost_fn = value.parse()?,
            "scale" => {
                self.scale = value
                    .parse()
                    .map_err(|e| Error::Config(format!("scale: {e}")))?
            }
            "seed" => {
                self.seed = value
                    .parse()
                    .map_err(|e| Error::Config(format!("seed: {e}")))?
            }
            "dense_core" | "dense-core" => {
                self.dense_core = value
                    .parse()
                    .map_err(|e| Error::Config(format!("dense_core: {e}")))?
            }
            "artifacts_dir" | "artifacts-dir" => self.artifacts_dir = value.to_string(),
            "hub_threshold" | "hub-threshold" => self.hub_threshold = value.parse()?,
            "build_threads" | "build-threads" => self.build_threads = value.parse()?,
            "mem_budget" | "mem-budget" => {
                let b = parse_bytes(value)?;
                if b == 0 {
                    return Err(Error::Config("mem-budget must be > 0 bytes".into()));
                }
                self.mem_budget = Some(b);
            }
            "on_fault" | "on-fault" => self.on_fault = value.parse()?,
            "fabric" => self.fabric = value.parse()?,
            other => return Err(Error::Config(format!("unknown key `{other}`"))),
        }
        if key == "procs" && self.procs == 0 {
            return Err(Error::Config("procs must be >= 1".into()));
        }
        Ok(())
    }

    /// Parse a flat TOML-subset file.
    pub fn from_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let mut cfg = RunConfig::default();
        for (k, v) in parse_kv(&text)? {
            cfg.set(&k, &v)?;
        }
        Ok(cfg)
    }

    /// Materialize the workload graph described by `self.workload`.
    pub fn build_graph(&self) -> Result<crate::graph::csr::Csr> {
        build_workload(&self.workload, self.scale, self.seed)
    }
}

/// Parse `key = value` lines; quotes optional; `[sections]` and comments skipped.
pub fn parse_kv(text: &str) -> Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    for (i, line) in text.lines().enumerate() {
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('[') {
            continue;
        }
        let (k, v) = t
            .split_once('=')
            .ok_or_else(|| Error::Parse { line: i + 1, msg: "expected key = value".into() })?;
        out.insert(
            k.trim().to_string(),
            v.trim().trim_matches('"').trim_matches('\'').to_string(),
        );
    }
    Ok(out)
}

/// Build a graph from a workload spec string (see [`RunConfig::workload`]).
pub fn build_workload(spec: &str, scale: f64, seed: u64) -> Result<crate::graph::csr::Csr> {
    use crate::gen::rng::Rng;
    if spec == "karate" {
        return Ok(crate::graph::classic::karate());
    }
    if let Some(p) = crate::gen::presets::by_name(spec) {
        return Ok(p.build_scaled(scale));
    }
    let parts: Vec<&str> = spec.split(':').collect();
    match parts.as_slice() {
        ["pa", n, d] => {
            let n: usize = n.parse().map_err(|e| Error::Config(format!("pa n: {e}")))?;
            let d: usize = d.parse().map_err(|e| Error::Config(format!("pa d: {e}")))?;
            let n = ((n as f64 * scale).round() as usize).max(d * 2 + 2);
            let d = if d % 2 == 0 { d } else { d + 1 };
            Ok(crate::gen::pa::preferential_attachment(n, d, &mut Rng::seeded(seed)))
        }
        ["rmat", s, ef] => {
            let s: u32 = s.parse().map_err(|e| Error::Config(format!("rmat scale: {e}")))?;
            let ef: usize = ef.parse().map_err(|e| Error::Config(format!("rmat ef: {e}")))?;
            Ok(crate::gen::rmat::rmat(s, ef, Default::default(), &mut Rng::seeded(seed)))
        }
        ["er", n, d] => {
            // Erdős–Rényi G(n, m) at average degree d̄ — the "no structure"
            // control of the bench-pipeline presets.
            let n: usize = n.parse().map_err(|e| Error::Config(format!("er n: {e}")))?;
            let d: usize = d.parse().map_err(|e| Error::Config(format!("er d̄: {e}")))?;
            let n = ((n as f64 * scale).round() as usize).max(4);
            let m = (n * d / 2).min(n * (n - 1) / 2);
            Ok(crate::gen::erdos_renyi::gnm(n, m, &mut Rng::seeded(seed)))
        }
        ["contact", n, d] => {
            let n: usize = n.parse().map_err(|e| Error::Config(format!("contact n: {e}")))?;
            let d: usize = d.parse().map_err(|e| Error::Config(format!("contact d: {e}")))?;
            let n = ((n as f64 * scale).round() as usize).max(d * 8);
            Ok(crate::gen::geometric::miami_like(n, d, &mut Rng::seeded(seed)))
        }
        ["file", path] => crate::graph::io::read_edge_list(path),
        ["bin", path] => crate::graph::io::read_binary(path),
        ["tcg", path] => crate::graph::io::read_tcg(path),
        _ => Err(Error::Config(format!("unknown workload spec `{spec}`"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_and_set() {
        let mut c = RunConfig::default();
        c.set("procs", "16").unwrap();
        c.set("algorithm", "dynamic-lb").unwrap();
        c.set("cost_fn", "dv").unwrap();
        assert_eq!(c.procs, 16);
        assert_eq!(c.algorithm, Algorithm::DynamicLb);
        c.set("algorithm", "tile2d").unwrap();
        assert_eq!(c.algorithm, Algorithm::Tile2d);
        assert_eq!(c.cost_fn, CostFn::Degree);
        assert_eq!(c.hub_threshold, crate::adj::HubThreshold::Auto);
        c.set("hub-threshold", "off").unwrap();
        assert_eq!(c.hub_threshold, crate::adj::HubThreshold::Off);
        c.set("hub_threshold", "256").unwrap();
        assert_eq!(c.hub_threshold, crate::adj::HubThreshold::Fixed(256));
        c.set("cost_fn", "hybrid").unwrap();
        assert_eq!(c.cost_fn, CostFn::Hybrid);
        assert_eq!(c.build_threads, crate::par::BuildThreads::Auto);
        c.set("build-threads", "8").unwrap();
        assert_eq!(c.build_threads, crate::par::BuildThreads::Fixed(8));
        c.set("build_threads", "auto").unwrap();
        assert_eq!(c.build_threads, crate::par::BuildThreads::Auto);
        assert!(c.set("build_threads", "0").is_err());
        assert!(c.set("build_threads", "some").is_err());
    }

    #[test]
    fn parse_bytes_suffixes() {
        assert_eq!(parse_bytes("1024").unwrap(), 1024);
        assert_eq!(parse_bytes("64kb").unwrap(), 64 << 10);
        assert_eq!(parse_bytes("64K").unwrap(), 64 << 10);
        assert_eq!(parse_bytes("3MB").unwrap(), 3 << 20);
        assert_eq!(parse_bytes("2g").unwrap(), 2 << 30);
        assert_eq!(parse_bytes(" 8 mb ").unwrap(), 8 << 20);
        assert!(parse_bytes("fast").is_err());
        assert!(parse_bytes("12tb").is_err());
        assert!(parse_bytes("-1").is_err());
        assert!(parse_bytes("99999999999999999999g").is_err());
    }

    #[test]
    fn mem_budget_key() {
        let mut c = RunConfig::default();
        assert_eq!(c.mem_budget, None);
        c.set("mem-budget", "256kb").unwrap();
        assert_eq!(c.mem_budget, Some(256 << 10));
        c.set("mem_budget", "1000").unwrap();
        assert_eq!(c.mem_budget, Some(1000));
        assert!(c.set("mem-budget", "0").is_err());
        assert!(c.set("mem-budget", "lots").is_err());
    }

    #[test]
    fn on_fault_key() {
        let mut c = RunConfig::default();
        assert_eq!(c.on_fault, crate::ft::FaultPolicy::Fail);
        c.set("on-fault", "recover").unwrap();
        assert_eq!(c.on_fault, crate::ft::FaultPolicy::Recover);
        c.set("on_fault", "degrade").unwrap();
        assert_eq!(c.on_fault, crate::ft::FaultPolicy::Degrade);
        c.set("on-fault", "fail").unwrap();
        assert_eq!(c.on_fault, crate::ft::FaultPolicy::Fail);
        assert!(c.set("on-fault", "panic").is_err());
    }

    #[test]
    fn fabric_key() {
        let mut c = RunConfig::default();
        assert_eq!(c.fabric, FabricKind::Threads);
        c.set("fabric", "tcp").unwrap();
        assert_eq!(c.fabric, FabricKind::Tcp);
        c.set("fabric", "threads").unwrap();
        assert_eq!(c.fabric, FabricKind::Threads);
        assert!(c.set("fabric", "carrier-pigeon").is_err());
    }

    #[test]
    fn rejects_bad_values() {
        let mut c = RunConfig::default();
        assert!(c.set("procs", "zero").is_err());
        assert!(c.set("procs", "0").is_err());
        assert!(c.set("algorithm", "quantum").is_err());
        assert!(c.set("nonsense", "1").is_err());
        assert!(c.set("hub_threshold", "sometimes").is_err());
    }

    #[test]
    fn parse_kv_skips_sections_and_comments() {
        let m = parse_kv("# hi\n[run]\nworkload = \"karate\"\nprocs = 8\n").unwrap();
        assert_eq!(m["workload"], "karate");
        assert_eq!(m["procs"], "8");
    }

    #[test]
    fn workload_specs() {
        assert_eq!(build_workload("karate", 1.0, 1).unwrap().num_nodes(), 34);
        let g = build_workload("pa:1000:6", 1.0, 1).unwrap();
        assert_eq!(g.num_nodes(), 1000);
        let g = build_workload("contact:2000:10", 1.0, 1).unwrap();
        assert_eq!(g.num_nodes(), 2000);
        let g = build_workload("er:1000:8", 1.0, 1).unwrap();
        assert_eq!(g.num_nodes(), 1000);
        assert_eq!(g.num_edges(), 4000);
        assert!(build_workload("wat:1", 1.0, 1).is_err());
        // `tcg:` specs route through the zero-parse binary loader.
        let p = std::env::temp_dir().join("tricount_cfg_spec.tcg");
        crate::graph::io::write_tcg(&crate::graph::classic::karate(), &p).unwrap();
        let g = build_workload(&format!("tcg:{}", p.display()), 1.0, 1).unwrap();
        assert_eq!(g.num_nodes(), 34);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn config_file_roundtrip() {
        let dir = std::env::temp_dir().join("tricount_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("run.toml");
        std::fs::write(&p, "workload = pa:500:4\nprocs = 3\nalgorithm = surrogate\n").unwrap();
        let c = RunConfig::from_file(&p).unwrap();
        assert_eq!(c.procs, 3);
        assert_eq!(c.workload, "pa:500:4");
    }
}
