//! The conformance suite: every counting path × every workload family ×
//! every cluster size × many adversarial schedules, against the
//! `seq::node_iterator` oracle.
//!
//! A *cell* is one `(path, workload, P, schedule-seed)` tuple. For each
//! cell the suite runs the full protocol twice on the virtual fabric
//! ([`Fabric::Sim`]) and asserts:
//!
//! 1. **Exactness** — the parallel count equals the sequential oracle
//!    (for the stream path: the from-scratch recount of the final graph),
//!    under a message schedule the OS would almost never produce;
//! 2. **Replay determinism** — both runs produce the identical trace hash
//!    (and count): the schedule is a value, not an accident;
//! 3. **Metric conservation** — Σ messages_sent == Σ messages_received
//!    and Σ control_sent == Σ control_received per tag class, i.e. every
//!    protocol drains its own traffic.
//!
//! A separate fault pass injects rank death into *every* path (under
//! `--on-fault fail` the run must yield `Err`, never hang) and message
//! loss into every path with point-to-point traffic (outcome must replay
//! identically; the request/reply protocols must *survive* the loss via
//! the `ft/` bounded-retry machinery — retries > 0, zero recv-guard trips,
//! exact count). A recovery matrix then kills one rank per cell — first /
//! middle / last transport op of the victim, probe-derived — across every
//! path × P and asserts `--on-fault recover` reproduces the exact oracle
//! count (twice, identical combined trace hash) and `--on-fault degrade`
//! returns a confidence bound containing the truth (DESIGN.md §13).
//!
//! Used by `tricount conformance --seeds n` (CI gates on it, twice, and
//! diffs the emitted JSON for the replay-determinism check) and by
//! `rust/tests/conformance.rs`. To add a new protocol, give it a
//! `run_on(&Fabric, …)` entry point, a [`Path`] variant, and an arm in
//! [`run_path`] — DESIGN.md §10 walks through it.
//!
//! **The live-wire axis** ([`run_tcp_matrix`], `tricount conformance
//! --fabric tcp`): the same path × workload × P grid, but each cell runs
//! as P OS processes over loopback TCP (`comm::tcp`, DESIGN.md §15) —
//! rank 0 in the calling process, ranks 1..P spawned as `tricount worker
//! … -- conformance-cell` children. Every rank re-derives the
//! deterministic workload (no graph bytes cross the wire), the oracle and
//! per-tag-class conservation are asserted on the allgathered metrics,
//! and children are always reaped (wait-with-timeout, then kill), so a
//! wedged cell fails the matrix instead of orphaning processes.

use std::sync::Arc;

use crate::adj::HubThreshold;
use crate::algo::{direct, dynamic_lb, local_counts, patric, surrogate, tile2d};
use crate::comm::metrics::ClusterMetrics;
use crate::config::CostFn;
use crate::error::Result;
use crate::gen::rng::Rng;
use crate::graph::csr::Csr;
use crate::graph::ordering::Oriented;
use crate::partition::balance::balanced_ranges;
use crate::partition::cost::{cost_vector, prefix_sums};
use crate::seq::node_iterator;
use crate::stream::batch::Batch;
use crate::stream::parallel::StreamOptions;
use crate::stream::state::StreamState;
use crate::stream::workload::{edge_stream, StreamSpec};
use crate::testkit::sched::{FaultPlan, SimConfig};
use crate::testkit::sim::Fabric;
use crate::testkit::trace::{combine_hashes, TraceReport};
use crate::TriangleCount;

/// Every message-passing counting path in the crate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Path {
    /// §IV space-efficient surrogate scheme over `OwnedPartition`s.
    Surrogate,
    /// §IV-C direct (request/reply) baseline.
    Direct,
    /// Overlapping-partition PATRIC baseline (reduce-only protocol).
    Patric,
    /// §V coordinator/worker dynamic load balancer.
    DynamicLb,
    /// Per-node counts through the §V protocol.
    LocalCounts,
    /// Incremental counting over edge-update batches (allreduce per batch).
    Stream,
    /// 2D process-grid tiling with coalesced row/column broadcasts
    /// (DESIGN.md §14).
    Tile2d,
}

impl Path {
    pub const ALL: [Path; 7] = [
        Path::Surrogate,
        Path::Direct,
        Path::Patric,
        Path::DynamicLb,
        Path::LocalCounts,
        Path::Stream,
        Path::Tile2d,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Path::Surrogate => "surrogate",
            Path::Direct => "direct",
            Path::Patric => "patric",
            Path::DynamicLb => "dynamic-lb",
            Path::LocalCounts => "local-counts",
            Path::Stream => "stream",
            Path::Tile2d => "tile2d",
        }
    }

    /// Inverse of [`Path::name`] (CLI `--paths`, `worker -- conformance-cell`).
    pub fn from_name(s: &str) -> Option<Path> {
        Path::ALL.iter().copied().find(|p| p.name() == s)
    }

    /// Does the protocol exchange point-to-point messages (and can
    /// therefore lose one)? PATRIC and the stream driver only reduce.
    pub fn has_p2p(self) -> bool {
        !matches!(self, Path::Patric | Path::Stream)
    }
}

/// Suite options; [`Options::default`] is the acceptance matrix.
#[derive(Clone, Debug)]
pub struct Options {
    /// Workload specs (`config::build_workload` grammar) — defaults cover
    /// the paper's three degree regimes: PA (skewed), R-MAT (power-law),
    /// ER (near-regular). Small on purpose: a cell runs a full protocol
    /// twice, serialized on the virtual fabric.
    pub workloads: Vec<String>,
    pub procs: Vec<usize>,
    /// Adversarial schedules per (path, workload, P) config.
    pub seeds: u64,
    pub paths: Vec<Path>,
    /// Run the rank-death / message-loss pass too.
    pub faults: bool,
}

impl Default for Options {
    fn default() -> Self {
        Options {
            workloads: vec!["pa:160:6".into(), "rmat:7:4".into(), "er:220:5".into()],
            procs: vec![2, 4, 8],
            seeds: 16,
            paths: Path::ALL.to_vec(),
            faults: true,
        }
    }
}

/// One counting run's observable outcome.
struct PathRun {
    count: TriangleCount,
    metrics: ClusterMetrics,
}

/// Per-(path, workload, P) summary over all schedule seeds.
#[derive(Clone, Debug)]
pub struct ConfigSummary {
    pub path: &'static str,
    pub workload: String,
    pub p: usize,
    pub schedules: u64,
    /// Combined trace hash over the config's schedules — the quantity the
    /// CI replay step diffs across two process invocations.
    pub hash: u64,
    pub ok: bool,
}

/// Result of a full suite run. `failures` is empty iff the suite passed;
/// the runner never aborts early, so one broken cell doesn't mask others.
#[derive(Clone, Debug, Default)]
pub struct ConformanceReport {
    pub configs: Vec<ConfigSummary>,
    /// Total schedule cells executed (each runs the protocol twice).
    pub cells: u64,
    pub fault_checks: u64,
    pub failures: Vec<String>,
    /// Combined hash over every cell trace, in fixed iteration order.
    pub matrix_hash: u64,
}

impl ConformanceReport {
    pub fn ok(&self) -> bool {
        self.failures.is_empty()
    }
}

/// A workload prepared once and shared by all its cells.
struct Prepared {
    spec: String,
    graph: Csr,
    oriented: Arc<Oriented>,
    oracle: TriangleCount,
    stream_base: Csr,
    stream_batches: Vec<Batch>,
    stream_initial: TriangleCount,
    stream_oracle: TriangleCount,
}

impl Prepared {
    fn build(spec: &str) -> Result<Prepared> {
        let graph = crate::config::build_workload(spec, 1.0, 1)?;
        let oriented = Arc::new(Oriented::from_graph(&graph));
        let oracle = node_iterator::count(&oriented);
        // Stream cells replay a deterministic update stream derived from
        // the same graph; the oracle is the sequential engine's recount.
        let sspec = StreamSpec {
            base_fraction: 0.6,
            batch_size: 30,
            batches: 5,
            delete_fraction: 0.25,
        };
        let w = edge_stream(&graph, &sspec, &mut Rng::seeded(0x517EA4));
        let mut st = StreamState::new(w.base.clone());
        for b in &w.batches {
            st.apply_batch(b)?;
        }
        let stream_oracle = st.recount()?;
        let stream_initial = node_iterator::count(&Oriented::from_graph(&w.base));
        Ok(Prepared {
            spec: spec.to_string(),
            graph,
            oriented,
            oracle,
            stream_base: w.base,
            stream_batches: w.batches,
            stream_initial,
            stream_oracle,
        })
    }

    fn oracle_for(&self, path: Path) -> TriangleCount {
        match path {
            Path::Stream => self.stream_oracle,
            _ => self.oracle,
        }
    }
}

fn ranges_for(o: &Oriented, cost: CostFn, p: usize) -> Vec<std::ops::Range<u32>> {
    balanced_ranges(&prefix_sums(&cost_vector(o, cost)), p)
}

/// Drive one counting path over one fabric. This is the only place that
/// knows how to launch each protocol — a new protocol needs exactly one
/// new arm here.
fn run_path(
    path: Path,
    fabric: &Fabric,
    w: &Prepared,
    p: usize,
) -> (Result<PathRun>, Option<TraceReport>) {
    match path {
        Path::Surrogate => {
            let ranges = ranges_for(&w.oriented, CostFn::SurrogateNew, p);
            let (r, t) = surrogate::run_on(fabric, &w.oriented, &ranges, HubThreshold::Auto);
            (r.map(|r| PathRun { count: r.triangles, metrics: r.metrics }), t)
        }
        Path::Direct => {
            let ranges = ranges_for(&w.oriented, CostFn::SurrogateNew, p);
            let (r, t) = direct::run_on(fabric, &w.oriented, &ranges, HubThreshold::Auto);
            (r.map(|r| PathRun { count: r.triangles, metrics: r.metrics }), t)
        }
        Path::Patric => {
            let ranges = ranges_for(&w.oriented, CostFn::PatricBest, p);
            let (r, t) =
                patric::run_on(fabric, &w.graph, &w.oriented, &ranges, HubThreshold::Auto);
            (r.map(|r| PathRun { count: r.triangles, metrics: r.metrics }), t)
        }
        Path::DynamicLb => {
            let (r, t) = dynamic_lb::run_on(fabric, &w.oriented, p, dynamic_lb::Options::default());
            (r.map(|r| PathRun { count: r.triangles, metrics: r.metrics }), t)
        }
        Path::LocalCounts => {
            let (r, t) = local_counts::per_node_counts_on(fabric, &w.oriented, p);
            (
                r.map(|(tv, metrics)| PathRun { count: tv.iter().sum::<u64>() / 3, metrics }),
                t,
            )
        }
        Path::Stream => {
            let (r, t) = crate::stream::parallel::run_with_initial_on(
                fabric,
                &w.stream_base,
                &w.stream_batches,
                p,
                StreamOptions::default(),
                w.stream_initial,
            );
            (r.map(|r| PathRun { count: r.final_triangles, metrics: r.metrics }), t)
        }
        Path::Tile2d => {
            let (r, t) = tile2d::run_on(fabric, &w.oriented, p, HubThreshold::Auto);
            (r.map(|r| PathRun { count: r.triangles, metrics: r.metrics }), t)
        }
    }
}

/// Cluster sizes a path is exercised at. The 2D path additionally runs at
/// perfect-square sizes (9, 16) so the grid factorization's square cells —
/// the configuration the O(m/√P) bound is about — are always in the
/// matrix, whatever `--procs` says.
fn procs_for(path: Path, procs: &[usize]) -> Vec<usize> {
    let mut out = procs.to_vec();
    if path == Path::Tile2d {
        for extra in [9usize, 16] {
            if !out.contains(&extra) {
                out.push(extra);
            }
        }
        out.sort_unstable();
    }
    out
}

/// Deterministic per-cell schedule seed.
fn cell_seed(wi: usize, p: usize, pi: usize, s: u64) -> u64 {
    combine_hashes([wi as u64, p as u64, pi as u64, s])
}

/// Run one representative cell (surrogate, `pa:160:6`, P=4) on an
/// adversarial virtual schedule and return its per-rank metrics — span
/// timelines included, in **virtual** ticks. `tricount conformance
/// --trace-out` exports this cell's timeline: with a fixed `seed` the
/// JSON is byte-identical across process invocations, which is the
/// suite's replay-determinism claim made visible in Perfetto.
pub fn demo_cell(seed: u64) -> Result<ClusterMetrics> {
    let w = Prepared::build("pa:160:6")?;
    let fabric = Fabric::Sim(SimConfig::adversarial(seed));
    let (r, _) = run_path(Path::Surrogate, &fabric, &w, 4);
    r.map(|run| run.metrics)
}

fn outcome_string(r: &Result<PathRun>) -> String {
    match r {
        Ok(run) => format!("ok: {} triangles", run.count),
        Err(e) => format!("err: {e}"),
    }
}

/// Σ sent == Σ received per tag class — the conservation predicate the
/// suite asserts on every cell: data envelopes, control markers, coalesced
/// frames, logical records, and the 2D row/column broadcast split each
/// drain (trivially 0 where a path doesn't use a class). Empty vec =
/// conserved.
pub fn conservation_violations(m: &ClusterMetrics) -> Vec<String> {
    let tot = m.totals();
    [
        ("data messages", tot.messages_sent, tot.messages_received),
        ("control messages", tot.control_sent, tot.control_received),
        ("frames", tot.frames_sent, tot.frames_received),
        ("records", tot.coalesced_sent, tot.coalesced_received),
        ("row-bcast", tot.row_bcast_sent, tot.row_bcast_received),
        ("col-bcast", tot.col_bcast_sent, tot.col_bcast_received),
    ]
    .iter()
    .filter(|(_, sent, received)| sent != received)
    .map(|(name, sent, received)| format!("{name} sent {sent} != received {received}"))
    .collect()
}

/// One conformance cell's observable outcome. On the TCP fabric every
/// rank's process gets the identical value (the result allgather), so a
/// worker can check its own copy and exit nonzero without waiting for
/// rank 0's verdict.
#[derive(Clone, Debug)]
pub struct CellOutcome {
    pub count: TriangleCount,
    pub oracle: TriangleCount,
    pub metrics: ClusterMetrics,
}

/// Run one `(path, workload, P)` cell on an arbitrary fabric. Workload
/// preparation is deterministic (fixed seeds), so separate processes
/// calling this with the same spec count the same graph — TCP cells ship
/// no graph bytes, only protocol traffic.
pub fn run_cell(path: Path, workload: &str, p: usize, fabric: &Fabric) -> Result<CellOutcome> {
    let w = Prepared::build(workload)?;
    let (r, _) = run_path(path, fabric, &w, p);
    let run = r?;
    Ok(CellOutcome { count: run.count, oracle: w.oracle_for(path), metrics: run.metrics })
}

// ---------------------------------------------------------------------------
// The live-wire (TCP) axis
// ---------------------------------------------------------------------------

/// Options for [`run_tcp_matrix`]: the same grid as [`Options`], each cell
/// run as P OS processes over loopback TCP.
#[derive(Clone, Debug)]
pub struct TcpOptions {
    /// The `tricount` binary to spawn workers from (tests:
    /// `env!("CARGO_BIN_EXE_tricount")`; CLI: `std::env::current_exe()`).
    pub bin: std::path::PathBuf,
    pub workloads: Vec<String>,
    pub procs: Vec<usize>,
    pub paths: Vec<Path>,
    /// Per-cell rendezvous join timeout (bounds a worker that never sees a
    /// full roster).
    pub join_timeout_ms: u64,
}

impl TcpOptions {
    /// The acceptance grid ([`Options::default`]) over a given binary.
    pub fn new(bin: impl Into<std::path::PathBuf>) -> TcpOptions {
        let d = Options::default();
        TcpOptions {
            bin: bin.into(),
            workloads: d.workloads,
            procs: d.procs,
            paths: d.paths,
            join_timeout_ms: 20_000,
        }
    }
}

/// Bind-and-drop a loopback listener to pick a free `ip:port` for a cell's
/// rendezvous.
pub fn free_loopback_addr() -> Result<String> {
    let l = std::net::TcpListener::bind("127.0.0.1:0")?;
    Ok(l.local_addr()?.to_string())
}

/// Reap spawned worker processes: wait-with-timeout, then kill. Returns
/// one failure string per worker that exited nonzero, timed out, or could
/// not be waited on; with `kill_now` the workers are killed first (the
/// local rank already failed) and only reaping errors are reported.
pub fn reap_children(
    children: &mut Vec<(usize, std::process::Child)>,
    timeout: std::time::Duration,
    kill_now: bool,
) -> Vec<String> {
    let mut failures = Vec::new();
    let deadline = std::time::Instant::now() + timeout;
    if kill_now {
        for (_, c) in children.iter_mut() {
            let _ = c.kill();
        }
    }
    for (rank, c) in children.iter_mut() {
        loop {
            match c.try_wait() {
                Ok(Some(status)) => {
                    if !status.success() && !kill_now {
                        failures.push(format!("worker rank {rank} exited with {status}"));
                    }
                    break;
                }
                Ok(None) if std::time::Instant::now() >= deadline => {
                    let _ = c.kill();
                    let _ = c.wait();
                    failures.push(format!(
                        "worker rank {rank} still running after {timeout:?} (killed)"
                    ));
                    break;
                }
                Ok(None) => std::thread::sleep(std::time::Duration::from_millis(10)),
                Err(e) => {
                    failures.push(format!("worker rank {rank} wait failed: {e}"));
                    break;
                }
            }
        }
    }
    failures
}

/// Run one cell as P OS processes: rank 0 in this process (so the caller
/// gets the allgathered metrics back as a value), ranks 1..P spawned as
/// `worker … -- conformance-cell` children of `opts.bin`. Children are
/// always reaped before this returns.
pub fn run_tcp_cell(
    opts: &TcpOptions,
    path: Path,
    workload: &str,
    p: usize,
    job_id: u64,
) -> Result<CellOutcome> {
    use std::process::{Command, Stdio};
    let addr = free_loopback_addr()?;
    let mut children: Vec<(usize, std::process::Child)> = Vec::new();
    for rank in 1..p {
        let spawned = Command::new(&opts.bin)
            .args([
                "worker",
                "--connect",
                &addr,
                "--rank",
                &rank.to_string(),
                "--procs",
                &p.to_string(),
                "--job-id",
                &job_id.to_string(),
                "--join-timeout-ms",
                &opts.join_timeout_ms.to_string(),
                "--",
                "conformance-cell",
                "--path",
                path.name(),
                "--workload",
                workload,
            ])
            .stdout(Stdio::null())
            .spawn();
        match spawned {
            Ok(child) => children.push((rank, child)),
            Err(e) => {
                let _ = reap_children(&mut children, std::time::Duration::from_secs(1), true);
                return Err(crate::error::Error::Config(format!(
                    "cannot spawn worker rank {rank} from `{}`: {e}",
                    opts.bin.display()
                )));
            }
        }
    }
    let net = crate::comm::tcp::TcpFabric {
        connect: addr,
        rank: 0,
        procs: p,
        job_id,
        join_timeout_ms: opts.join_timeout_ms,
    };
    let outcome = run_cell(path, workload, p, &Fabric::Tcp(net));
    let timeout = std::time::Duration::from_millis(opts.join_timeout_ms)
        + crate::comm::threads::recv_guard();
    let worker_failures = reap_children(&mut children, timeout, outcome.is_err());
    let outcome = outcome?;
    if !worker_failures.is_empty() {
        return Err(crate::error::Error::Cluster(format!(
            "tcp cell workers failed: {}",
            worker_failures.join("; ")
        )));
    }
    Ok(outcome)
}

/// The live-wire matrix: every `(workload, path, P)` cell over loopback
/// TCP, oracle equality and per-tag-class conservation asserted on the
/// allgathered metrics. Job ids are deterministic per matrix run (pid ‖
/// cell counter), so concurrent suites on one host can't cross-join.
pub fn run_tcp_matrix(opts: &TcpOptions) -> Result<ConformanceReport> {
    let mut report = ConformanceReport::default();
    let mut job_id = (std::process::id() as u64) << 32;
    for w in &opts.workloads {
        for &path in &opts.paths {
            for &p in &opts.procs {
                job_id += 1;
                report.cells += 1;
                let cell = format!("{} {} P={p} [tcp]", path.name(), w);
                let mut ok = true;
                match run_tcp_cell(opts, path, w, p, job_id) {
                    Ok(outcome) => {
                        if outcome.count != outcome.oracle {
                            report.failures.push(format!(
                                "{cell}: count {} != oracle {}",
                                outcome.count, outcome.oracle
                            ));
                            ok = false;
                        }
                        for v in conservation_violations(&outcome.metrics) {
                            report.failures.push(format!("{cell}: {v}"));
                            ok = false;
                        }
                    }
                    Err(e) => {
                        report.failures.push(format!("{cell}: {e}"));
                        ok = false;
                    }
                }
                report.configs.push(ConfigSummary {
                    path: path.name(),
                    workload: w.clone(),
                    p,
                    schedules: 1,
                    hash: 0,
                    ok,
                });
            }
        }
    }
    Ok(report)
}

/// Run the full matrix. `Err` only for setup failures (bad workload
/// spec); conformance violations are collected in
/// [`ConformanceReport::failures`].
pub fn run(opts: &Options) -> Result<ConformanceReport> {
    let mut report = ConformanceReport::default();
    let mut all_hashes: Vec<u64> = Vec::new();
    let prepared: Vec<Prepared> =
        opts.workloads.iter().map(|s| Prepared::build(s)).collect::<Result<_>>()?;

    for (wi, w) in prepared.iter().enumerate() {
        for (pi, &path) in opts.paths.iter().enumerate() {
            for p in procs_for(path, &opts.procs) {
                let mut cfg_hashes = Vec::with_capacity(opts.seeds as usize);
                let mut ok = true;
                for s in 0..opts.seeds {
                    // Every 4th schedule adds a straggler rank — a
                    // fault-shaped perturbation that must not move counts.
                    let faults = if s % 4 == 3 {
                        FaultPlan::slow_rank(p - 1, 16)
                    } else {
                        FaultPlan::default()
                    };
                    let cfg = SimConfig::with_faults(cell_seed(wi, p, pi, s), faults);
                    let fabric = Fabric::Sim(cfg);
                    let (r1, t1) = run_path(path, &fabric, w, p);
                    let (r2, t2) = run_path(path, &fabric, w, p);
                    report.cells += 1;
                    let cell = format!("{} {} P={p} schedule#{s}", path.name(), w.spec);
                    let mut fail = |msg: String, ok: &mut bool| {
                        report.failures.push(format!("{cell}: {msg}"));
                        *ok = false;
                    };
                    match (&r1, &r2, t1, t2) {
                        (Ok(a), Ok(b), Some(t1), Some(t2)) => {
                            let oracle = w.oracle_for(path);
                            if a.count != oracle {
                                fail(
                                    format!("count {} != oracle {oracle}", a.count),
                                    &mut ok,
                                );
                            }
                            if b.count != a.count {
                                fail(
                                    format!("replay count {} != first run {}", b.count, a.count),
                                    &mut ok,
                                );
                            }
                            if t1.hash != t2.hash {
                                fail(
                                    format!(
                                        "replay trace hash {:#x} != {:#x} (events {} vs {})",
                                        t2.hash, t1.hash, t2.events, t1.events
                                    ),
                                    &mut ok,
                                );
                            }
                            // Replayed schedules must reproduce the exact
                            // virtual-time span timeline per rank — the
                            // obs/ clock contract (DESIGN.md §11).
                            for (i, (ma, mb)) in a
                                .metrics
                                .per_rank
                                .iter()
                                .zip(b.metrics.per_rank.iter())
                                .enumerate()
                            {
                                if ma.spans != mb.spans {
                                    fail(
                                        format!(
                                            "rank {i}: replay span timeline differs \
                                             ({} vs {} spans, {} vs {} dropped)",
                                            mb.spans.recorded(),
                                            ma.spans.recorded(),
                                            mb.spans.dropped,
                                            ma.spans.dropped
                                        ),
                                        &mut ok,
                                    );
                                }
                                if ma.recv_wait != mb.recv_wait || ma.total != mb.total {
                                    fail(
                                        format!(
                                            "rank {i}: replay virtual times differ \
                                             (recv_wait {:?} vs {:?}, total {:?} vs {:?})",
                                            mb.recv_wait, ma.recv_wait, mb.total, ma.total
                                        ),
                                        &mut ok,
                                    );
                                }
                            }
                            for v in conservation_violations(&a.metrics) {
                                fail(v, &mut ok);
                            }
                            cfg_hashes.push(t1.hash);
                            all_hashes.push(t1.hash);
                        }
                        (r1, r2, _, _) => {
                            fail(
                                format!(
                                    "run failed: {} / replay: {}",
                                    outcome_string(r1),
                                    outcome_string(r2)
                                ),
                                &mut ok,
                            );
                        }
                    }
                }
                report.configs.push(ConfigSummary {
                    path: path.name(),
                    workload: w.spec.clone(),
                    p,
                    schedules: opts.seeds,
                    hash: combine_hashes(cfg_hashes),
                    ok,
                });
            }
        }
    }

    if opts.faults {
        if let Some(w) = prepared.first() {
            fault_suite(w, &opts.paths, &mut report);
            recovery_suite(w, &opts.paths, &opts.procs, &mut report);
        }
    }
    report.matrix_hash = combine_hashes(all_hashes);
    Ok(report)
}

/// The fault pass: rank death on every path, message loss on every path
/// with point-to-point traffic. P is fixed at 4 (all paths accept it).
fn fault_suite(w: &Prepared, paths: &[Path], report: &mut ConformanceReport) {
    const P: usize = 4;
    for (pi, &path) in paths.iter().enumerate() {
        // Rank death mid-protocol: the run must fail — with the same error
        // on replay — never hang.
        let cfg = SimConfig::with_faults(cell_seed(0xDEAD, P, pi, 0), FaultPlan::kill(1, 1));
        let fabric = Fabric::Sim(cfg);
        let (r1, _) = run_path(path, &fabric, w, P);
        let (r2, _) = run_path(path, &fabric, w, P);
        report.fault_checks += 1;
        match (&r1, &r2) {
            (Err(e1), Err(e2)) => {
                let (e1, e2) = (e1.to_string(), e2.to_string());
                if e1 != e2 {
                    report.failures.push(format!(
                        "{} rank-death: nondeterministic error (`{e1}` vs `{e2}`)",
                        path.name()
                    ));
                }
            }
            _ => report.failures.push(format!(
                "{} rank-death: expected Err, got {} / {}",
                path.name(),
                outcome_string(&r1),
                outcome_string(&r2)
            )),
        }

        // Message loss: outcome must replay identically. The request/reply
        // protocols (direct, dynamic-lb, local-counts) must *survive* the
        // loss through the `ft/` bounded-retry machinery: exact count,
        // retries > 0, deadline expiries recorded, zero recv-guard trips.
        // Surrogate's and tile2d's one-way data planes have no reply to
        // time out on — a lost data message is the supervisor's domain
        // (DESIGN.md §13), so their drop cells assert determinism only.
        if !path.has_p2p() {
            continue;
        }
        let (src, dst) = match path {
            // Workers talk to the coordinator first.
            Path::DynamicLb | Path::LocalCounts => (1usize, 0usize),
            _ => (0usize, 1usize),
        };
        let cfg =
            SimConfig::with_faults(cell_seed(0xD809, P, pi, 1), FaultPlan::drop_nth(src, dst, 1));
        let fabric = Fabric::Sim(cfg);
        let (r1, t1) = run_path(path, &fabric, w, P);
        let (r2, t2) = run_path(path, &fabric, w, P);
        report.fault_checks += 1;
        let (o1, o2) = (outcome_string(&r1), outcome_string(&r2));
        if o1 != o2 || t1.map(|t| t.hash) != t2.map(|t| t.hash) {
            report
                .failures
                .push(format!("{} message-drop: nondeterministic (`{o1}` vs `{o2}`)", path.name()));
        }
        if matches!(path, Path::Direct | Path::DynamicLb | Path::LocalCounts) {
            match (&r1, &t1) {
                (Ok(run), Some(t)) => {
                    if run.count != w.oracle_for(path) {
                        report.failures.push(format!(
                            "{} message-drop: retried count {} != oracle {}",
                            path.name(),
                            run.count,
                            w.oracle_for(path)
                        ));
                    }
                    let retries: u64 =
                        run.metrics.per_rank.iter().map(|m| m.retries).sum();
                    if retries == 0 {
                        report.failures.push(format!(
                            "{} message-drop: survived without retries — the drop never bit",
                            path.name()
                        ));
                    }
                    if t.guards != 0 {
                        report.failures.push(format!(
                            "{} message-drop: {} recv-guard trips (retry machinery must \
                             resolve the loss before the guard)",
                            path.name(),
                            t.guards
                        ));
                    }
                    if t.deadlines == 0 {
                        report.failures.push(format!(
                            "{} message-drop: no deadline expiries recorded, yet retries ran",
                            path.name()
                        ));
                    }
                }
                _ => report.failures.push(format!(
                    "{} message-drop: expected bounded-retry recovery (Ok + trace), got {}",
                    path.name(),
                    o1
                )),
            }
        }
    }
}

/// Where in the victim's life the kill lands (positions derived from a
/// fault-free probe of the same schedule family).
#[derive(Clone, Copy, Debug)]
enum KillPos {
    First,
    Middle,
    Last,
}

/// Build the supervisor job for a path over a prepared workload — mirrors
/// [`run_path`]'s launch parameters exactly, so supervised and plain runs
/// count the same protocol.
fn job_for<'a>(path: Path, w: &'a Prepared) -> crate::ft::Job<'a> {
    use crate::ft::Job;
    match path {
        Path::Surrogate => {
            Job::Surrogate { graph: &w.oriented, cost: CostFn::SurrogateNew, hub: HubThreshold::Auto }
        }
        Path::Direct => {
            Job::Direct { graph: &w.oriented, cost: CostFn::SurrogateNew, hub: HubThreshold::Auto }
        }
        Path::Patric => Job::Patric {
            g: &w.graph,
            graph: &w.oriented,
            cost: CostFn::PatricBest,
            hub: HubThreshold::Auto,
        },
        Path::DynamicLb => {
            Job::DynamicLb { graph: &w.oriented, opts: dynamic_lb::Options::default() }
        }
        Path::LocalCounts => Job::LocalCounts { graph: &w.oriented },
        Path::Stream => Job::Stream {
            base: &w.stream_base,
            batches: &w.stream_batches,
            opts: StreamOptions::default(),
            initial: w.stream_initial,
        },
        Path::Tile2d => Job::Tile2d { graph: &w.oriented, hub: HubThreshold::Auto },
    }
}

/// The recovery matrix: every path × P × {first, middle, last} kill
/// position. Per cell: `recover` must reproduce the exact oracle count —
/// twice, with identical combined trace hash — and `degrade` must return a
/// bound containing the truth. Kill positions are probed from a fault-free
/// run so "middle" and "last" track each protocol's actual op counts.
fn recovery_suite(w: &Prepared, paths: &[Path], procs: &[usize], report: &mut ConformanceReport) {
    use crate::ft::{supervise, FaultPolicy};
    for (pi, &path) in paths.iter().enumerate() {
        for p in procs_for(path, procs) {
            let probe_fabric = Fabric::Sim(SimConfig::adversarial(cell_seed(0xFA07, p, pi, 0)));
            let (probe, _) = run_path(path, &probe_fabric, w, p);
            let ops: Vec<u64> = match &probe {
                Ok(run) => run.metrics.per_rank.iter().map(|m| m.transport_ops).collect(),
                Err(e) => {
                    report
                        .failures
                        .push(format!("{} P={p} recovery probe failed: {e}", path.name()));
                    continue;
                }
            };
            let cells = [(0usize, KillPos::First), (p / 2, KillPos::Middle), (p - 1, KillPos::Last)];
            for (ci, &(victim, pos)) in cells.iter().enumerate() {
                let v_ops = ops.get(victim).copied().unwrap_or(1).max(1);
                let at_op = match pos {
                    KillPos::First => 1,
                    KillPos::Middle => (v_ops / 2).max(1),
                    KillPos::Last => v_ops,
                };
                let cfg = SimConfig::with_faults(
                    cell_seed(0xFA07, p, pi, 1 + ci as u64),
                    FaultPlan::kill_one(victim, at_op),
                );
                let fabric = Fabric::Sim(cfg);
                let job = job_for(path, w);
                let oracle = w.oracle_for(path);
                let cell = format!("{} P={p} kill(rank {victim} @op {at_op}, {pos:?})", path.name());
                report.fault_checks += 1;

                let a = supervise(&job, &fabric, p, FaultPolicy::Recover);
                let b = supervise(&job, &fabric, p, FaultPolicy::Recover);
                match (&a, &b) {
                    (Ok(a), Ok(b)) => {
                        if a.count != oracle {
                            report.failures.push(format!(
                                "{cell}: recovered count {} != oracle {oracle}",
                                a.count
                            ));
                        }
                        if b.count != a.count || b.trace_hash != a.trace_hash {
                            report.failures.push(format!(
                                "{cell}: recovery replay diverged (count {} vs {}, hash {:x?} \
                                 vs {:x?})",
                                b.count, a.count, b.trace_hash, a.trace_hash
                            ));
                        }
                    }
                    _ => {
                        let sup_outcome = |r: &Result<crate::ft::SupervisedRun>| match r {
                            Ok(run) => format!("ok: {}", run.count),
                            Err(e) => format!("err: {e}"),
                        };
                        report.failures.push(format!(
                            "{cell}: recovery failed ({} / replay {})",
                            sup_outcome(&a),
                            sup_outcome(&b)
                        ));
                    }
                }

                match supervise(&job, &fabric, p, FaultPolicy::Degrade) {
                    Ok(d) => match d.bound {
                        Some(bound) if !bound.contains(oracle) => {
                            report.failures.push(format!(
                                "{cell}: degrade bound {bound:?} excludes oracle {oracle}"
                            ));
                        }
                        // The kill landed after all counting finished (e.g.
                        // silently on the victim's final try_recv): the run
                        // completed and no bound was needed — exactness holds.
                        None if d.count != oracle => {
                            report.failures.push(format!(
                                "{cell}: degrade without bound returned {} != oracle {oracle}",
                                d.count
                            ));
                        }
                        _ => {}
                    },
                    Err(e) => {
                        report.failures.push(format!("{cell}: degrade errored: {e}"));
                    }
                }
            }
        }
    }
}
