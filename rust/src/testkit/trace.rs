//! Trace recording for the virtual fabric: every scheduler-visible event
//! is folded into an FNV-1a hash, so an entire run compresses to one u64
//! with the property *same seed ⇒ identical schedule ⇒ identical hash*.
//!
//! The hash covers, per event, the tuple
//! `(step, kind, src, dst, tag, bytes, virtual_time)` — enough that any
//! divergence in message order, payload size, fault firing or collective
//! sequencing changes it. The conformance suite runs every cell twice and
//! asserts hash equality (replay determinism), and CI diffs the whole
//! matrix across two process invocations (DESIGN.md §10).

/// Event kinds folded into the trace. Discriminants are part of the hash
/// domain — append new kinds, never renumber.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum EventKind {
    /// A rank handed an envelope to the fabric.
    Send = 1,
    /// The scheduler moved an envelope from the wire into a mailbox.
    Deliver = 2,
    /// A `DropRule` ate the envelope at send time.
    DropFault = 3,
    /// Delivery target was dead or already finished; envelope discarded.
    DropUnreachable = 4,
    /// A `Kill` fired.
    Death = 5,
    /// The virtual recv guard tripped (deadlock detected) for a rank.
    Guard = 6,
    /// A barrier generation completed.
    Barrier = 7,
    /// A reduce generation completed.
    Reduce = 8,
    /// A `recv_deadline` expired and woke its rank (ft/ retry machinery);
    /// unlike [`EventKind::Guard`] the rank continues, it does not fail.
    Deadline = 9,
}

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

#[inline]
fn fnv_fold(mut h: u64, x: u64) -> u64 {
    for b in x.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Incremental recorder owned by the scheduler state (all events are
/// appended under the execution token, so the sequence is serialized and
/// deterministic by construction).
#[derive(Clone, Debug)]
pub struct TraceRecorder {
    hash: u64,
    events: u64,
    sends: u64,
    delivered: u64,
    dropped: u64,
    deaths: u64,
    guards: u64,
    deadlines: u64,
    dead_mask: u64,
}

impl Default for TraceRecorder {
    fn default() -> Self {
        TraceRecorder {
            hash: FNV_OFFSET,
            events: 0,
            sends: 0,
            delivered: 0,
            dropped: 0,
            deaths: 0,
            guards: 0,
            deadlines: 0,
            dead_mask: 0,
        }
    }
}

impl TraceRecorder {
    /// Fold one event. `tag` is the message class (0 data, 1 control) for
    /// message events, and kind-specific otherwise (generation counters
    /// for collectives, op counters for deaths).
    pub fn event(&mut self, kind: EventKind, src: u64, dst: u64, tag: u64, bytes: u64, vt: u64) {
        self.events += 1;
        let mut h = self.hash;
        for x in [self.events, kind as u64, src, dst, tag, bytes, vt] {
            h = fnv_fold(h, x);
        }
        self.hash = h;
        match kind {
            EventKind::Send => self.sends += 1,
            EventKind::Deliver => self.delivered += 1,
            EventKind::DropFault | EventKind::DropUnreachable => self.dropped += 1,
            EventKind::Death => {
                self.deaths += 1;
                if src < 64 {
                    self.dead_mask |= 1u64 << src;
                }
            }
            EventKind::Guard => self.guards += 1,
            EventKind::Deadline => self.deadlines += 1,
            EventKind::Barrier | EventKind::Reduce => {}
        }
    }

    /// Snapshot into the public report.
    pub fn report(&self, vt_end: u64) -> TraceReport {
        TraceReport {
            hash: self.hash,
            events: self.events,
            sends: self.sends,
            delivered: self.delivered,
            dropped: self.dropped,
            deaths: self.deaths,
            guards: self.guards,
            deadlines: self.deadlines,
            dead_mask: self.dead_mask,
            vt_end,
        }
    }
}

/// What a virtual run leaves behind. `hash` is the replay fingerprint;
/// the counters make trace diffs human-readable when two hashes disagree.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceReport {
    /// FNV-1a over the full event sequence — the replay fingerprint.
    pub hash: u64,
    /// Total events folded.
    pub events: u64,
    /// Envelopes handed to the fabric.
    pub sends: u64,
    /// Envelopes delivered into a mailbox.
    pub delivered: u64,
    /// Envelopes lost (fault drops + unreachable targets).
    pub dropped: u64,
    /// Kill faults fired.
    pub deaths: u64,
    /// Ranks failed by the virtual recv guard.
    pub guards: u64,
    /// `recv_deadline` expiries (ranks woken to retry, not failed).
    pub deadlines: u64,
    /// Bit `r` set ⇔ rank `r` was killed by a fault plan (ranks ≥ 64
    /// are counted in `deaths` but not representable here; the sim caps
    /// out far below that). The `ft/` supervisor reads the victim set
    /// from this mask instead of parsing error strings.
    pub dead_mask: u64,
    /// Virtual clock at the end of the run.
    pub vt_end: u64,
}

impl TraceReport {
    /// The killed ranks, decoded from [`TraceReport::dead_mask`].
    pub fn dead_ranks(&self) -> Vec<usize> {
        (0..64).filter(|r| self.dead_mask >> r & 1 == 1).collect()
    }
}

/// Combine per-cell trace hashes into one matrix fingerprint (order
/// matters — the conformance runner feeds cells in a fixed order).
pub fn combine_hashes(hashes: impl IntoIterator<Item = u64>) -> u64 {
    let mut h = FNV_OFFSET;
    for x in hashes {
        h = fnv_fold(h, x);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_sequences_hash_identically() {
        let mut a = TraceRecorder::default();
        let mut b = TraceRecorder::default();
        for r in [&mut a, &mut b] {
            r.event(EventKind::Send, 0, 1, 0, 16, 5);
            r.event(EventKind::Deliver, 0, 1, 0, 16, 9);
        }
        assert_eq!(a.report(9), b.report(9));
        assert_eq!(a.report(9).sends, 1);
        assert_eq!(a.report(9).delivered, 1);
    }

    #[test]
    fn any_field_change_changes_hash() {
        let base = {
            let mut r = TraceRecorder::default();
            r.event(EventKind::Send, 0, 1, 0, 16, 5);
            r.report(5).hash
        };
        // Perturb each field in turn.
        let variants: Vec<(EventKind, u64, u64, u64, u64, u64)> = vec![
            (EventKind::Deliver, 0, 1, 0, 16, 5),
            (EventKind::Send, 2, 1, 0, 16, 5),
            (EventKind::Send, 0, 2, 0, 16, 5),
            (EventKind::Send, 0, 1, 1, 16, 5),
            (EventKind::Send, 0, 1, 0, 20, 5),
            (EventKind::Send, 0, 1, 0, 16, 6),
        ];
        for (k, a, b, t, n, v) in variants {
            let mut r = TraceRecorder::default();
            r.event(k, a, b, t, n, v);
            assert_ne!(r.report(v).hash, base, "{k:?} {a} {b} {t} {n} {v}");
        }
    }

    #[test]
    fn event_order_matters() {
        let mut a = TraceRecorder::default();
        a.event(EventKind::Send, 0, 1, 0, 8, 1);
        a.event(EventKind::Send, 1, 0, 0, 8, 1);
        let mut b = TraceRecorder::default();
        b.event(EventKind::Send, 1, 0, 0, 8, 1);
        b.event(EventKind::Send, 0, 1, 0, 8, 1);
        assert_ne!(a.report(1).hash, b.report(1).hash);
    }

    #[test]
    fn combine_is_order_sensitive() {
        assert_ne!(combine_hashes([1, 2, 3]), combine_hashes([3, 2, 1]));
        assert_eq!(combine_hashes([1, 2, 3]), combine_hashes([1, 2, 3]));
    }
}
