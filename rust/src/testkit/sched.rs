//! Schedule policies and fault plans for the virtual fabric.
//!
//! A "schedule" in the conformance suite is everything the OS and the
//! network would normally decide for us: which rank runs next, how long a
//! message spends on the wire, which rank is slow, which rank dies, which
//! message is lost. [`SimConfig`] pins all of it to a seed, so a schedule
//! is a *value* — replayable, shrinkable, diffable (DESIGN.md §10).

/// Knobs of the deterministic scheduler (`testkit::sim`). All randomness
/// is drawn from the run's seeded `gen::rng::Rng`, in a serialized order,
/// so a policy + seed names exactly one schedule.
#[derive(Clone, Debug)]
pub struct SchedulePolicy {
    /// Minimum wire latency of a message, in virtual ticks.
    pub min_delay: u64,
    /// Extra uniform latency in `0..jitter` ticks (0 = fixed latency).
    /// Jitter across *different* sender ranks is what reorders deliveries;
    /// per-(src,dst) order is always preserved (MPI non-overtaking).
    pub jitter: u64,
    /// Probability that a rank yields the execution token after a
    /// non-blocking transport op (send / try_recv), letting another rank
    /// interleave at that point.
    pub switch_prob: f64,
    /// Probability that the scheduler delivers the earliest in-flight
    /// message *before* resuming a runnable rank — biases schedules toward
    /// early message arrival (exercises the opportunistic `try_recv`
    /// paths); low values starve receivers until they block.
    pub deliver_bias: f64,
}

impl SchedulePolicy {
    /// The conformance default: jittered latencies, frequent interleaving,
    /// mixed eager/lazy delivery.
    pub fn adversarial() -> Self {
        SchedulePolicy { min_delay: 1, jitter: 24, switch_prob: 0.5, deliver_bias: 0.35 }
    }

    /// Near-synchronous: fixed latency, no voluntary yields, eager
    /// delivery. The tamest schedule the fabric can produce — useful as a
    /// baseline when debugging a failing adversarial seed.
    pub fn gentle() -> Self {
        SchedulePolicy { min_delay: 1, jitter: 0, switch_prob: 0.0, deliver_bias: 1.0 }
    }
}

/// Kill `rank` when its transport-op counter reaches `at_op` (1-based:
/// `at_op: 1` kills it at its very first transport op, `try_recv`
/// included). A kill landing on a fallible op fails it with a
/// deterministic `Error::Cluster`; one landing on a `try_recv` (which
/// cannot fail) kills the rank silently — `None` is returned and the next
/// fallible op surfaces the dead-rank error. Messages the rank already
/// sent stay on the wire, everything addressed to it afterwards is
/// dropped, and peers that can no longer make progress fail through the
/// virtual recv guard instead of hanging.
#[derive(Clone, Copy, Debug)]
pub struct Kill {
    pub rank: usize,
    pub at_op: u64,
}

/// Silently drop the `nth` (1-based) message sent on the directed edge
/// `src → dst`. The sender is unaware (the send succeeds), exactly like a
/// lost wire message; the receiver's protocol stalls and trips the
/// virtual recv guard deterministically.
#[derive(Clone, Copy, Debug)]
pub struct DropRule {
    pub src: usize,
    pub dst: usize,
    pub nth: u64,
}

/// Multiply the wire latency of every message to or from `rank` by
/// `factor` — a straggler. Purely a schedule perturbation: counts must be
/// unaffected.
#[derive(Clone, Copy, Debug)]
pub struct SlowRank {
    pub rank: usize,
    pub factor: u64,
}

/// Faults injected into a virtual run. Empty by default.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub kills: Vec<Kill>,
    pub drops: Vec<DropRule>,
    pub slow: Vec<SlowRank>,
}

impl FaultPlan {
    /// One straggler rank — a fault-shaped schedule perturbation that must
    /// not change any count.
    pub fn slow_rank(rank: usize, factor: u64) -> Self {
        FaultPlan { slow: vec![SlowRank { rank, factor }], ..Default::default() }
    }

    /// Kill one rank at its `at_op`-th transport operation.
    pub fn kill(rank: usize, at_op: u64) -> Self {
        FaultPlan { kills: vec![Kill { rank, at_op }], ..Default::default() }
    }

    /// Alias for [`FaultPlan::kill`] under the name the conformance fault
    /// matrix uses: exactly *one* victim per cell, so recovery always has
    /// `p − 1` survivors to re-execute on.
    pub fn kill_one(rank: usize, at_op: u64) -> Self {
        Self::kill(rank, at_op)
    }

    /// Strip the kills, keep drops/slow — the supervisor's recovery
    /// attempts run on a fabric where the victim cannot die twice but the
    /// schedule stays adversarial.
    pub fn without_kills(&self) -> Self {
        FaultPlan { kills: Vec::new(), drops: self.drops.clone(), slow: self.slow.clone() }
    }

    /// Drop the `nth` message on `src → dst`.
    pub fn drop_nth(src: usize, dst: usize, nth: u64) -> Self {
        FaultPlan { drops: vec![DropRule { src, dst, nth }], ..Default::default() }
    }
}

/// One fully specified virtual-cluster run: seed + policy + faults.
/// Same config ⇒ identical schedule ⇒ identical trace hash
/// (`testkit::trace`), which is what the replay-determinism gates assert.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub seed: u64,
    pub policy: SchedulePolicy,
    pub faults: FaultPlan,
}

impl SimConfig {
    /// The conformance suite's default schedule family.
    pub fn adversarial(seed: u64) -> Self {
        SimConfig { seed, policy: SchedulePolicy::adversarial(), faults: FaultPlan::default() }
    }

    /// Adversarial schedule plus a fault plan.
    pub fn with_faults(seed: u64, faults: FaultPlan) -> Self {
        SimConfig { seed, policy: SchedulePolicy::adversarial(), faults }
    }
}
