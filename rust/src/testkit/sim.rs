//! The seeded virtual fabric: a deterministic cluster simulator behind the
//! [`Transport`] trait.
//!
//! ## How determinism is achieved
//!
//! Rank programs run on real OS threads (so the production `Cluster`
//! launcher is reused verbatim), but **exactly one rank executes at a
//! time**: a single execution token is granted by the scheduler, and every
//! rank blocks in [`Transport::start`] until first granted it. Between
//! transport operations a rank computes while *holding* the token; at
//! every transport op it may yield (probability [`SchedulePolicy::switch_prob`]),
//! and it always releases the token when it blocks (recv / barrier /
//! reduce) or finishes. All scheduler decisions — who runs next, whether
//! to deliver the earliest in-flight message first, what latency a message
//! gets — are drawn from one seeded [`crate::gen::rng::Rng`] *under the
//! token*, so the decision sequence is a pure function of
//! `(SimConfig, rank programs)`. Wall-clock never enters: the run is
//! replayable, and the [`TraceReport`] hash proves it (DESIGN.md §10).
//!
//! ## Virtual time and delivery
//!
//! A send is stamped `max(now + delay, edge_clock[src→dst] + 1)` — jittered
//! latency, but strictly increasing per directed edge, preserving MPI's
//! non-overtaking guarantee while letting messages from different senders
//! interleave arbitrarily. The clock `now` only advances when the
//! scheduler delivers the earliest in-flight message.
//!
//! ## The virtual recv guard
//!
//! When no rank is runnable and nothing is in flight, every blocked rank
//! is deadlocked *provably* (nothing can ever wake it). Each one fails
//! with a deterministic `Error::Cluster` naming the blocked operation and
//! the virtual time — the exact-arithmetic analogue of the channel
//! fabric's 30s wall-clock [`crate::comm::threads::recv_guard`]. Rank
//! death and dropped messages surface through this path instead of
//! hanging.

use std::collections::{BinaryHeap, VecDeque};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

use crate::comm::metrics::CommMetrics;
use crate::comm::tcp::TcpFabric;
use crate::comm::threads::{try_recv_guard, Cluster, Comm, Progress};
use crate::comm::transport::{Envelope, Liveness, Payload, Transport, Wire};
use crate::error::{Error, Result};
use crate::gen::rng::Rng;
use crate::testkit::sched::SimConfig;
use crate::testkit::trace::{EventKind, TraceRecorder, TraceReport};

/// Which fabric a run uses. Every counting path exposes a `*_on(&Fabric, …)`
/// entry point; `Fabric::Channel` is the production default (and what the
/// plain `run(…)` wrappers pass), `Fabric::Sim` is the conformance fabric.
#[derive(Clone, Debug)]
pub enum Fabric {
    /// Production mpsc channels — no trace, wall-clock recv guard.
    Channel,
    /// Seeded deterministic simulator — returns a [`TraceReport`].
    Sim(SimConfig),
    /// Socket fabric (`comm::tcp`): this process runs ONE rank of a
    /// multi-process cluster described by the [`TcpFabric`] config; the
    /// result vector is the full allgather, identical on every rank.
    Tcp(TcpFabric),
}

impl Fabric {
    /// Launch `f` on `p` ranks over this fabric. The trace is `Some` iff
    /// the fabric is virtual, and is returned even when the run fails (so
    /// fault runs are replay-checkable too).
    pub fn try_run<M, R, F>(
        &self,
        p: usize,
        f: F,
    ) -> (Result<Vec<(R, CommMetrics)>>, Option<TraceReport>)
    where
        M: Payload,
        R: Wire + Send,
        F: Fn(&mut Comm<M>) -> Result<R> + Sync,
    {
        self.try_run_hooked(p, None, f)
    }

    /// [`Fabric::try_run`] with an `ft/` checkpoint sink installed on every
    /// rank — the supervised entry point (`ft::supervisor` uses this to
    /// harvest partial sums and acknowledgements across a faulting run).
    pub fn try_run_hooked<M, R, F>(
        &self,
        p: usize,
        progress: Option<Arc<dyn Progress>>,
        f: F,
    ) -> (Result<Vec<(R, CommMetrics)>>, Option<TraceReport>)
    where
        M: Payload,
        R: Wire + Send,
        F: Fn(&mut Comm<M>) -> Result<R> + Sync,
    {
        match self {
            Fabric::Channel => (Cluster::try_run_supervised(p, progress, f), None),
            Fabric::Sim(cfg) => {
                let (r, t) = try_run_sim_hooked(p, cfg, progress, f);
                (r, Some(t))
            }
            Fabric::Tcp(cfg) => (crate::comm::tcp::run_tcp_hooked(cfg, p, progress, f), None),
        }
    }
}

/// A message on the virtual wire. Ordered by `(at, seq)` — reversed so the
/// std max-heap pops the *earliest* flight.
struct Flight<M> {
    at: u64,
    seq: u64,
    dst: usize,
    env: Envelope<M>,
}

impl<M> PartialEq for Flight<M> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<M> Eq for Flight<M> {}
impl<M> PartialOrd for Flight<M> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<M> Ord for Flight<M> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (other.at, other.seq).cmp(&(self.at, self.seq))
    }
}

/// A rank's scheduling state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    /// Wants the token (startup, after a yield, or woken from a block).
    Ready,
    /// Holds the token.
    Running,
    /// Parked in `recv` with an empty mailbox.
    BlockedRecv,
    /// Parked in `barrier`.
    BlockedBarrier,
    /// Parked in `reduce_sum`.
    BlockedReduce,
    /// Rank program returned (Ok or Err).
    Done,
    /// A `Kill` fault fired.
    Dead,
}

struct RankCell<M> {
    phase: Phase,
    mailbox: VecDeque<Envelope<M>>,
    /// Message handed over by the scheduler while this rank was
    /// `BlockedRecv` (its mailbox is empty by definition at that point).
    handed: Option<Envelope<M>>,
    /// Virtual-recv-guard verdict, set by deadlock detection.
    fail: Option<String>,
    /// Transport ops performed — the `Kill::at_op` trigger counter.
    ops: u64,
    /// Virtual-time deadline armed by `recv_deadline`. A `BlockedRecv`
    /// rank carrying one is *woken* (empty-handed) instead of guard-failed
    /// when the fabric stalls — the ft/ retry tier's wake-up call.
    timeout_at: Option<u64>,
}

struct SimState<M> {
    cells: Vec<RankCell<M>>,
    in_flight: BinaryHeap<Flight<M>>,
    /// Last scheduled delivery time per directed edge (`src*p + dst`) —
    /// enforces per-edge FIFO.
    edge_clock: Vec<u64>,
    /// Messages sent per directed edge — the `DropRule::nth` counter.
    edge_sends: Vec<u64>,
    now: u64,
    seq: u64,
    rng: Rng,
    cfg: SimConfig,
    trace: TraceRecorder,
    current: Option<usize>,
    started: bool,
    barrier_waiting: usize,
    barrier_gen: u64,
    reduce_cells: Vec<Option<u64>>,
    reduce_result: u64,
    reduce_gen: u64,
}

impl<M: Payload> SimState<M> {
    fn new(p: usize, cfg: SimConfig) -> Self {
        SimState {
            cells: (0..p)
                .map(|_| RankCell {
                    phase: Phase::Ready,
                    mailbox: VecDeque::new(),
                    handed: None,
                    fail: None,
                    ops: 0,
                    timeout_at: None,
                })
                .collect(),
            in_flight: BinaryHeap::new(),
            edge_clock: vec![0; p * p],
            edge_sends: vec![0; p * p],
            now: 0,
            seq: 0,
            rng: Rng::seeded(cfg.seed),
            cfg,
            trace: TraceRecorder::default(),
            current: None,
            started: false,
            barrier_waiting: 0,
            barrier_gen: 0,
            reduce_cells: vec![None; p],
            reduce_result: 0,
            reduce_gen: 0,
        }
    }

    /// Pick what happens next: resume a ready rank, deliver the earliest
    /// in-flight message, or — when neither is possible and ranks are
    /// blocked — trip the virtual recv guard on all of them. Called only
    /// with the token unassigned, always under the state lock, so every
    /// `rng` draw happens in a serialized, replayable order.
    fn schedule(&mut self) {
        debug_assert!(self.current.is_none());
        loop {
            let ready: Vec<usize> = self
                .cells
                .iter()
                .enumerate()
                .filter(|(_, c)| c.phase == Phase::Ready)
                .map(|(i, _)| i)
                .collect();
            let can_deliver = !self.in_flight.is_empty();
            let deliver = can_deliver
                && (ready.is_empty() || {
                    let bias = self.cfg.policy.deliver_bias;
                    self.rng.chance(bias)
                });
            if deliver {
                let f = self.in_flight.pop().unwrap();
                if f.at > self.now {
                    self.now = f.at;
                }
                let now = self.now;
                let dst = f.dst;
                let (src, control, bytes) =
                    (f.env.src as u64, f.env.control as u64, f.env.msg.size_bytes());
                match self.cells[dst].phase {
                    Phase::Done | Phase::Dead => {
                        self.trace.event(
                            EventKind::DropUnreachable,
                            src,
                            dst as u64,
                            control,
                            bytes,
                            now,
                        );
                    }
                    _ => {
                        self.trace.event(EventKind::Deliver, src, dst as u64, control, bytes, now);
                        self.cells[dst].mailbox.push_back(f.env);
                        if self.cells[dst].phase == Phase::BlockedRecv {
                            let env = self.cells[dst].mailbox.pop_front().unwrap();
                            self.cells[dst].handed = Some(env);
                            self.cells[dst].phase = Phase::Ready;
                        }
                    }
                }
                continue;
            }
            if !ready.is_empty() {
                let pick = ready[self.rng.below_usize(ready.len())];
                self.cells[pick].phase = Phase::Running;
                self.current = Some(pick);
                return;
            }
            // Nothing runnable, nothing on the wire. Before declaring
            // deadlock, expire recv deadlines: in virtual time a total
            // stall means *every* pending deadline fires, so advance the
            // clock to the earliest one and wake the ranks it covers
            // empty-handed (their `recv_deadline` returns `Ok(None)` and
            // the retry tier takes over). Waking earliest-first keeps the
            // schedule faithful — a woken rank may resend and revive the
            // fabric before later deadlines ever fire. Livelock-free
            // because retries are bounded (`RetryPolicy::max_retries`).
            let next_deadline = self
                .cells
                .iter()
                .filter(|c| c.phase == Phase::BlockedRecv)
                .filter_map(|c| c.timeout_at)
                .min();
            if let Some(at) = next_deadline {
                if at > self.now {
                    self.now = at;
                }
                let now = self.now;
                for i in 0..self.cells.len() {
                    if self.cells[i].phase == Phase::BlockedRecv
                        && self.cells[i].timeout_at.is_some_and(|t| t <= now)
                    {
                        self.trace.event(EventKind::Deadline, i as u64, 0, 0, 0, now);
                        self.cells[i].timeout_at = None;
                        self.cells[i].phase = Phase::Ready;
                    }
                }
                continue;
            }
            // Every blocked rank is provably deadlocked — fail them all,
            // deterministically.
            let mut any_blocked = false;
            for i in 0..self.cells.len() {
                let what = match self.cells[i].phase {
                    Phase::BlockedRecv => "recv",
                    Phase::BlockedBarrier => "barrier",
                    Phase::BlockedReduce => "reduce_sum",
                    _ => continue,
                };
                any_blocked = true;
                let now = self.now;
                self.trace.event(EventKind::Guard, i as u64, 0, 0, 0, now);
                self.cells[i].fail = Some(format!(
                    "rank {i} virtual recv guard tripped: {what} deadlocked at virtual time \
                     {now} (no runnable rank, no message in flight)"
                ));
                self.cells[i].phase = Phase::Ready;
            }
            if !any_blocked {
                return; // everyone Done/Dead — nothing left to schedule
            }
            // Guard-failed ranks are Ready; loop back to grant the token.
        }
    }
}

struct SimShared<M> {
    state: Mutex<SimState<M>>,
    cv: Condvar,
}

/// A rank's endpoint into the virtual fabric.
pub struct VirtualEndpoint<M: Payload> {
    rank: usize,
    /// Rank count, fixed at fabric construction — cached here so `size()`
    /// (called in protocol hot loops) never touches the state mutex.
    size: usize,
    shared: Arc<SimShared<M>>,
}

impl<M: Payload> VirtualEndpoint<M> {
    /// Block until the scheduler grants this rank the token.
    fn wait_token<'a>(&self, mut g: MutexGuard<'a, SimState<M>>) -> MutexGuard<'a, SimState<M>> {
        while g.current != Some(self.rank) {
            g = self.shared.cv.wait(g).unwrap();
        }
        g
    }

    /// Release the token, reschedule, and block until it comes back.
    fn yield_token<'a>(&self, mut g: MutexGuard<'a, SimState<M>>) -> MutexGuard<'a, SimState<M>> {
        g.cells[self.rank].phase = Phase::Ready;
        g.current = None;
        g.schedule();
        self.shared.cv.notify_all();
        self.wait_token(g)
    }

    /// Park this rank in `phase`, reschedule, and block until the
    /// scheduler wakes it (with a message, a collective release, or a
    /// guard verdict) and grants the token back.
    fn block<'a>(
        &self,
        mut g: MutexGuard<'a, SimState<M>>,
        phase: Phase,
    ) -> MutexGuard<'a, SimState<M>> {
        g.cells[self.rank].phase = phase;
        g.current = None;
        g.schedule();
        self.shared.cv.notify_all();
        self.wait_token(g)
    }

    /// Count the op and, if a `Kill` is due, fire it: mark the rank Dead,
    /// trace the death, release the token and reschedule. Returns the
    /// `(op, virtual time)` of the death, or `None` if the rank lives.
    /// Shared by every transport op so fallible ops and `try_recv` can
    /// never drift apart on the kill protocol.
    fn fire_kill(&self, g: &mut MutexGuard<'_, SimState<M>>) -> Option<(u64, u64)> {
        let rank = self.rank;
        g.cells[rank].ops += 1;
        let ops = g.cells[rank].ops;
        if !g.cfg.faults.kills.iter().any(|k| k.rank == rank && ops >= k.at_op) {
            return None;
        }
        g.cells[rank].phase = Phase::Dead;
        let now = g.now;
        g.trace.event(EventKind::Death, rank as u64, 0, ops, 0, now);
        g.current = None;
        g.schedule();
        self.shared.cv.notify_all();
        Some((ops, now))
    }

    /// Fallible-op preamble: dead-rank check + [`Self::fire_kill`]. Called
    /// while holding the token (every transport op does).
    fn preamble(&self, g: &mut MutexGuard<'_, SimState<M>>) -> Result<()> {
        let rank = self.rank;
        if g.cells[rank].phase == Phase::Dead {
            return Err(Error::Cluster(format!("rank {rank} is dead (fault plan)")));
        }
        if let Some((ops, now)) = self.fire_kill(g) {
            return Err(Error::Cluster(format!(
                "rank {rank} killed by fault plan at transport op {ops} (virtual time {now})"
            )));
        }
        Ok(())
    }

    /// Draw this op's voluntary yield.
    fn maybe_switch<'a>(&self, mut g: MutexGuard<'a, SimState<M>>) -> MutexGuard<'a, SimState<M>> {
        let p = g.cfg.policy.switch_prob;
        if p > 0.0 && g.rng.chance(p) {
            g = self.yield_token(g);
        }
        g
    }
}

impl<M: Payload> Transport<M> for VirtualEndpoint<M> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    /// Gate the rank program on the first token grant; the very first
    /// caller kicks the scheduler once (all ranks start `Ready`, so the
    /// initial pick is independent of thread spawn order).
    fn start(&mut self) {
        let mut g = self.shared.state.lock().unwrap();
        if !g.started {
            g.started = true;
            g.schedule();
            self.shared.cv.notify_all();
        }
        let g = self.wait_token(g);
        drop(g);
    }

    fn send(&mut self, dst: usize, env: Envelope<M>) -> Result<()> {
        let mut g = self.shared.state.lock().unwrap();
        self.preamble(&mut g)?;
        if matches!(g.cells[dst].phase, Phase::Dead | Phase::Done) {
            // Channel-fabric parity: the peer's endpoint is gone.
            return Err(Error::Cluster(format!("rank {} send to dead rank {dst}", self.rank)));
        }
        let p = g.cells.len();
        let eidx = self.rank * p + dst;
        g.edge_sends[eidx] += 1;
        let nth = g.edge_sends[eidx];
        let (src, control, bytes) = (env.src as u64, env.control as u64, env.msg.size_bytes());
        let now = g.now;
        g.trace.event(EventKind::Send, src, dst as u64, control, bytes, now);
        let dropped =
            g.cfg.faults.drops.iter().any(|d| d.src == self.rank && d.dst == dst && d.nth == nth);
        if dropped {
            g.trace.event(EventKind::DropFault, src, dst as u64, control, bytes, now);
        } else {
            let jitter = g.cfg.policy.jitter;
            let mut delay =
                g.cfg.policy.min_delay + if jitter > 0 { g.rng.below(jitter) } else { 0 };
            for s in &g.cfg.faults.slow {
                if s.rank == self.rank || s.rank == dst {
                    delay = delay.saturating_mul(s.factor.max(1));
                }
            }
            let at = (now + delay).max(g.edge_clock[eidx] + 1);
            g.edge_clock[eidx] = at;
            g.seq += 1;
            let seq = g.seq;
            g.in_flight.push(Flight { at, seq, dst, env });
        }
        let g = self.maybe_switch(g);
        drop(g);
        Ok(())
    }

    /// Counts as a transport op for `Kill::at_op` like every other op; a
    /// kill landing here cannot return `Err` (the signature is `Option`),
    /// so the rank dies silently — `None` now, and every subsequent
    /// fallible op fails with the dead-rank error.
    fn try_recv(&mut self) -> Option<Envelope<M>> {
        let mut g = self.shared.state.lock().unwrap();
        if g.cells[self.rank].phase == Phase::Dead {
            return None;
        }
        if self.fire_kill(&mut g).is_some() {
            return None;
        }
        g = self.maybe_switch(g);
        g.cells[self.rank].mailbox.pop_front()
    }

    fn recv(&mut self) -> Result<Envelope<M>> {
        let mut g = self.shared.state.lock().unwrap();
        self.preamble(&mut g)?;
        g = self.maybe_switch(g);
        if let Some(env) = g.cells[self.rank].mailbox.pop_front() {
            return Ok(env);
        }
        g = self.block(g, Phase::BlockedRecv);
        if let Some(msg) = g.cells[self.rank].fail.take() {
            return Err(Error::Cluster(msg));
        }
        let env = g.cells[self.rank]
            .handed
            .take()
            .expect("virtual scheduler woke a recv without a message or a guard verdict");
        Ok(env)
    }

    /// Deadline recv in *virtual* time: `d` converts to virtual ticks
    /// (1 tick = 1µs), and the deadline only fires when the fabric stalls
    /// — which in virtual time is exactly when infinite wall time passes.
    /// Returns `Ok(None)` on expiry; the rank stays alive and retries.
    /// Fully replayable: the wake is a scheduler decision under the token,
    /// folded into the trace as [`EventKind::Deadline`].
    fn recv_deadline(&mut self, d: Duration) -> Result<Option<Envelope<M>>> {
        let mut g = self.shared.state.lock().unwrap();
        self.preamble(&mut g)?;
        g = self.maybe_switch(g);
        if let Some(env) = g.cells[self.rank].mailbox.pop_front() {
            return Ok(Some(env));
        }
        let ticks = (d.as_micros() as u64).max(1);
        let at = g.now.saturating_add(ticks);
        g.cells[self.rank].timeout_at = Some(at);
        g = self.block(g, Phase::BlockedRecv);
        g.cells[self.rank].timeout_at = None;
        if let Some(msg) = g.cells[self.rank].fail.take() {
            return Err(Error::Cluster(msg));
        }
        // `None` here means the scheduler woke us on the deadline.
        Ok(g.cells[self.rank].handed.take())
    }

    /// Peer state straight from the scheduler: a killed or finished rank
    /// is `Dead`, everything else is `Alive`. `Slow` never occurs — the
    /// one-token sim has no wall-clock staleness, and slowness faults only
    /// stretch delivery latency, which deadlines already observe.
    fn liveness(&self, rank: usize, _stale_after: Duration) -> Liveness {
        let g = self.shared.state.lock().unwrap();
        match g.cells[rank].phase {
            Phase::Dead | Phase::Done => Liveness::Dead,
            _ => Liveness::Alive,
        }
    }

    fn barrier(&mut self) -> Result<()> {
        let mut g = self.shared.state.lock().unwrap();
        self.preamble(&mut g)?;
        let p = g.cells.len();
        g.barrier_waiting += 1;
        if g.barrier_waiting == p {
            g.barrier_waiting = 0;
            g.barrier_gen += 1;
            let (gen, now) = (g.barrier_gen, g.now);
            g.trace.event(EventKind::Barrier, self.rank as u64, 0, gen, 0, now);
            for c in g.cells.iter_mut() {
                if c.phase == Phase::BlockedBarrier {
                    c.phase = Phase::Ready;
                }
            }
            g = self.yield_token(g);
        } else {
            g = self.block(g, Phase::BlockedBarrier);
            if let Some(msg) = g.cells[self.rank].fail.take() {
                return Err(Error::Cluster(msg));
            }
        }
        drop(g);
        Ok(())
    }

    fn reduce_sum(&mut self, value: u64) -> Result<u64> {
        let mut g = self.shared.state.lock().unwrap();
        self.preamble(&mut g)?;
        g.reduce_cells[self.rank] = Some(value);
        if g.reduce_cells.iter().all(|c| c.is_some()) {
            let sum: u64 = g.reduce_cells.iter().map(|c| c.unwrap()).sum();
            g.reduce_result = sum;
            g.reduce_gen += 1;
            for c in g.reduce_cells.iter_mut() {
                *c = None;
            }
            let (gen, now) = (g.reduce_gen, g.now);
            g.trace.event(EventKind::Reduce, self.rank as u64, 0, gen, sum, now);
            for c in g.cells.iter_mut() {
                if c.phase == Phase::BlockedReduce {
                    c.phase = Phase::Ready;
                }
            }
            g = self.yield_token(g);
        } else {
            g = self.block(g, Phase::BlockedReduce);
            if let Some(msg) = g.cells[self.rank].fail.take() {
                return Err(Error::Cluster(msg));
            }
        }
        // Safe to read after wake: the next reduce generation cannot
        // complete (and overwrite this) before *this* rank deposits again.
        let r = g.reduce_result;
        drop(g);
        Ok(r)
    }

    /// The scheduler's virtual clock. Deterministic whenever the calling
    /// rank is the scheduled one (it holds the execution token, so `now`
    /// cannot advance concurrently) — which is every point inside a rank
    /// program, including the instants `Comm` stamps spans at.
    fn virtual_now(&self) -> Option<u64> {
        Some(self.shared.state.lock().unwrap().now)
    }
}

/// Release the token and mark the rank finished when its program returns —
/// including early `Err` returns and panics mid-unwind. Without this, a
/// rank that exited while holding the token would freeze the simulation.
impl<M: Payload> Drop for VirtualEndpoint<M> {
    fn drop(&mut self) {
        let mut g = self.shared.state.lock().unwrap();
        if g.cells[self.rank].phase != Phase::Dead {
            g.cells[self.rank].phase = Phase::Done;
        }
        if g.current == Some(self.rank) {
            g.current = None;
            g.schedule();
        }
        drop(g);
        self.shared.cv.notify_all();
    }
}

/// Run `f` on `p` ranks over the virtual fabric described by `cfg`.
/// Returns the run outcome *and* the trace report (also on failure, so
/// fault runs can be replay-checked). Counterpart of
/// [`Cluster::try_run`].
pub fn try_run_sim<M, R, F>(
    p: usize,
    cfg: &SimConfig,
    f: F,
) -> (Result<Vec<(R, CommMetrics)>>, TraceReport)
where
    M: Payload,
    R: Send,
    F: Fn(&mut Comm<M>) -> Result<R> + Sync,
{
    try_run_sim_hooked(p, cfg, None, f)
}

/// [`try_run_sim`] with an `ft/` checkpoint sink installed on every rank.
pub fn try_run_sim_hooked<M, R, F>(
    p: usize,
    cfg: &SimConfig,
    progress: Option<Arc<dyn Progress>>,
    f: F,
) -> (Result<Vec<(R, CommMetrics)>>, TraceReport)
where
    M: Payload,
    R: Send,
    F: Fn(&mut Comm<M>) -> Result<R> + Sync,
{
    assert!(p >= 1, "cluster needs at least one rank");
    // Same startup contract as the channel fabric: a malformed recv-guard
    // override is a config error before any rank spawns.
    if let Err(e) = try_recv_guard() {
        return (Err(e), TraceRecorder::default().report(0));
    }
    let shared = Arc::new(SimShared {
        state: Mutex::new(SimState::new(p, cfg.clone())),
        cv: Condvar::new(),
    });
    let comms: Vec<Comm<M>> = (0..p)
        .map(|rank| Comm::from_virtual(VirtualEndpoint { rank, size: p, shared: shared.clone() }))
        .collect();
    let result = Cluster::launch(comms, progress, f);
    let g = shared.state.lock().unwrap();
    let report = g.trace.report(g.now);
    drop(g);
    (result, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::sched::FaultPlan;

    fn ring(p: usize, cfg: &SimConfig) -> (Result<Vec<(u64, CommMetrics)>>, TraceReport) {
        try_run_sim::<u64, u64, _>(p, cfg, |c| {
            let next = (c.rank() + 1) % c.size();
            c.send(next, (c.rank() * c.rank()) as u64)?;
            let (_src, v) = c.recv()?;
            Ok(v)
        })
    }

    #[test]
    fn ring_pass_is_exact_and_deterministic() {
        let cfg = SimConfig::adversarial(7);
        let (r1, t1) = ring(4, &cfg);
        let (r2, t2) = ring(4, &cfg);
        let mut got: Vec<u64> = r1.unwrap().iter().map(|(v, _)| *v).collect();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 4, 9]);
        assert_eq!(t1, t2, "same seed must replay to the identical trace");
        assert_eq!(t1.sends, 4);
        assert_eq!(t1.delivered, 4);
        assert_eq!(t1.dropped, 0);
        let r2: Vec<u64> = r2.unwrap().iter().map(|(v, _)| *v).collect();
        assert_eq!(r2.len(), 4);
    }

    #[test]
    fn different_seeds_schedule_differently() {
        let hashes: Vec<u64> = (0..6).map(|s| ring(4, &SimConfig::adversarial(s)).1.hash).collect();
        let distinct: std::collections::BTreeSet<u64> = hashes.iter().copied().collect();
        assert!(distinct.len() > 1, "all seeds produced one schedule: {hashes:?}");
    }

    #[test]
    fn per_edge_fifo_is_preserved_under_jitter() {
        for seed in 0..10 {
            let cfg = SimConfig::adversarial(seed);
            let (r, _) = try_run_sim::<u64, Vec<u64>, _>(2, &cfg, |c| {
                if c.rank() == 0 {
                    for i in 0..10u64 {
                        c.send(1, i)?;
                    }
                    Ok(Vec::new())
                } else {
                    let mut got = Vec::new();
                    for _ in 0..10 {
                        got.push(c.recv()?.1);
                    }
                    Ok(got)
                }
            });
            let got = &r.unwrap()[1].0;
            assert_eq!(*got, (0..10).collect::<Vec<u64>>(), "seed {seed} reordered an edge");
        }
    }

    #[test]
    fn cross_sender_order_varies_with_seed() {
        // Ranks 1 and 2 each send their id to rank 0; which arrives first
        // is schedule-dependent — over a few seeds both orders must occur.
        let mut orders = std::collections::BTreeSet::new();
        for seed in 0..16 {
            let cfg = SimConfig::adversarial(seed);
            let (r, _) = try_run_sim::<u64, u64, _>(3, &cfg, |c| {
                if c.rank() == 0 {
                    let a = c.recv()?.1;
                    let b = c.recv()?.1;
                    Ok(a * 10 + b)
                } else {
                    c.send(0, c.rank() as u64)?;
                    Ok(0)
                }
            });
            orders.insert(r.unwrap()[0].0);
        }
        assert!(orders.len() >= 2, "only one cross-sender order seen: {orders:?}");
    }

    #[test]
    fn reduce_and_barrier_work_virtually() {
        let cfg = SimConfig::adversarial(3);
        let (r, _) =
            try_run_sim::<u64, u64, _>(5, &cfg, |c| c.reduce_sum(c.rank() as u64 + 1));
        for (v, _) in r.unwrap() {
            assert_eq!(v, 15);
        }
        let (r, _) = try_run_sim::<u64, (), _>(4, &cfg, |c| {
            c.barrier()?;
            c.barrier()?;
            Ok(())
        });
        r.unwrap();
    }

    #[test]
    fn self_send_delivered_virtually() {
        let cfg = SimConfig::adversarial(9);
        let (r, _) = try_run_sim::<u64, u64, _>(2, &cfg, |c| {
            c.send(c.rank(), 99)?;
            Ok(c.recv()?.1)
        });
        for (v, _) in r.unwrap() {
            assert_eq!(v, 99);
        }
    }

    #[test]
    fn rank_death_fails_the_run_deterministically() {
        let cfg = SimConfig::with_faults(11, FaultPlan::kill(1, 1));
        let run = || {
            try_run_sim::<u64, u64, _>(2, &cfg, |c| {
                if c.rank() == 1 {
                    c.send(0, 5)?; // dies here (op 1)
                    Ok(0)
                } else {
                    Ok(c.recv()?.1) // nothing can arrive → virtual guard
                }
            })
        };
        let (r1, t1) = run();
        let (r2, t2) = run();
        let e1 = r1.unwrap_err().to_string();
        let e2 = r2.unwrap_err().to_string();
        assert_eq!(e1, e2, "fault runs must replay identically");
        assert_eq!(t1, t2);
        assert_eq!(t1.deaths, 1);
        assert!(
            e1.contains("killed by fault plan") || e1.contains("virtual recv guard"),
            "{e1}"
        );
    }

    #[test]
    fn dropped_message_trips_the_virtual_recv_guard() {
        let cfg = SimConfig::with_faults(13, FaultPlan::drop_nth(0, 1, 1));
        let run = || {
            try_run_sim::<u64, u64, _>(2, &cfg, |c| {
                if c.rank() == 0 {
                    c.send(1, 42)?; // eaten by the drop rule
                    Ok(0)
                } else {
                    Ok(c.recv()?.1)
                }
            })
        };
        let (r1, t1) = run();
        let (r2, t2) = run();
        let e1 = r1.unwrap_err().to_string();
        assert!(e1.contains("virtual recv guard"), "{e1}");
        assert!(e1.contains("recv deadlocked"), "{e1}");
        assert_eq!(e1, r2.unwrap_err().to_string());
        assert_eq!(t1, t2);
        assert_eq!(t1.dropped, 1);
        assert_eq!(t1.guards, 1);
    }

    #[test]
    fn recv_deadline_wakes_instead_of_guard_tripping() {
        // A rank waiting on a message that never comes, with a deadline
        // armed, is *woken* (Ok(None)) rather than failed by the guard.
        let cfg = SimConfig::adversarial(17);
        let run = || {
            try_run_sim::<u64, bool, _>(2, &cfg, |c| {
                if c.rank() == 1 {
                    let got = c.recv_deadline(Duration::from_millis(5))?;
                    Ok(got.is_none())
                } else {
                    Ok(true)
                }
            })
        };
        let (r1, t1) = run();
        let (r2, t2) = run();
        for (timed_out, _) in r1.unwrap() {
            assert!(timed_out, "nothing was sent — the deadline must expire");
        }
        assert_eq!(t1, t2, "deadline wakes must replay identically");
        assert_eq!(t1.deadlines, 1);
        assert_eq!(t1.guards, 0, "a deadline expiry is not a deadlock");
        r2.unwrap();
    }

    #[test]
    fn bounded_retry_recovers_a_dropped_request() {
        use crate::comm::transport::RetryPolicy;
        // Rank 1's first request to rank 0 is eaten by the fault plan; the
        // retry tier re-sends it after a virtual deadline and the exchange
        // completes — no guard trip, exactly one retry on the books.
        let cfg = SimConfig::with_faults(19, FaultPlan::drop_nth(1, 0, 1));
        let policy = RetryPolicy::default();
        let run = || {
            try_run_sim::<u64, u64, _>(2, &cfg, |c| {
                if c.rank() == 1 {
                    c.send(0, 7)?; // dropped
                    let got = c
                        .recv_retry(0, &policy, |c| c.send(0, 7))?
                        .expect("bounded retries must recover the exchange");
                    Ok(got.1)
                } else {
                    let (_src, v) = c.recv()?;
                    c.send(1, v * 6)?;
                    Ok(0)
                }
            })
        };
        let (r1, t1) = run();
        let (r2, t2) = run();
        let res = r1.unwrap();
        assert_eq!(res[1].0, 42);
        assert_eq!(res[1].1.retries, 1, "exactly one retransmission");
        assert_eq!(t1.dropped, 1);
        assert_eq!(t1.guards, 0, "retry must preempt the deadlock guard");
        assert!(t1.deadlines >= 1);
        assert_eq!(t1, t2, "retry schedules must replay identically");
        r2.unwrap();
    }

    #[test]
    fn liveness_reports_dead_peer_fast() {
        use crate::comm::transport::RetryPolicy;
        // Rank 0 dies *silently* on its first op (a kill landing in
        // `try_recv` cannot error); rank 1's retry loop must fail via the
        // liveness board ("peer is dead") instead of burning all retries
        // against a corpse.
        let cfg = SimConfig::with_faults(23, FaultPlan::kill(0, 1));
        let policy = RetryPolicy::default();
        let (r, t) = try_run_sim::<u64, u64, _>(2, &cfg, |c| {
            if c.rank() == 1 {
                let got = c.recv_retry(0, &policy, |c| {
                    // Peer already dead — resends fail; swallow and retry.
                    let _ = c.send(0, 7);
                    Ok(())
                })?;
                Ok(got.map(|(_, v)| v).unwrap_or(0))
            } else {
                let _ = c.try_recv(); // kill fires here (op 1), silently
                Ok(0)
            }
        });
        match r {
            Err(Error::RankFailure { rank, msg, .. }) => {
                assert_eq!(rank, 1, "the liveness check is the only surfaced failure");
                assert!(msg.contains("peer rank 0 is dead"), "{msg}");
            }
            other => panic!("expected rank 1's liveness failure, got {other:?}"),
        }
        assert_eq!(t.deaths, 1);
    }

    #[test]
    fn slow_rank_changes_schedule_not_results() {
        let base = SimConfig::adversarial(21);
        let slow = SimConfig::with_faults(21, FaultPlan::slow_rank(2, 50));
        let (r1, _) = ring(4, &base);
        let (r2, _) = ring(4, &slow);
        let mut a: Vec<u64> = r1.unwrap().iter().map(|(v, _)| *v).collect();
        let mut b: Vec<u64> = r2.unwrap().iter().map(|(v, _)| *v).collect();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn single_rank_virtual_cluster() {
        let cfg = SimConfig::adversarial(1);
        let (r, t) = try_run_sim::<u64, u64, _>(1, &cfg, |c| c.reduce_sum(7));
        assert_eq!(r.unwrap()[0].0, 7);
        assert_eq!(t.sends, 0);
    }

    #[test]
    fn metrics_account_messages_on_the_virtual_fabric() {
        let cfg = SimConfig::adversarial(2);
        let (r, _) = try_run_sim::<Vec<u32>, (), _>(2, &cfg, |c| {
            if c.rank() == 0 {
                c.send(1, vec![1, 2, 3])?;
                c.send_control(1, vec![9])?;
            } else {
                c.recv()?;
                c.recv()?;
            }
            Ok(())
        });
        let res = r.unwrap();
        assert_eq!(res[0].1.messages_sent, 1);
        assert_eq!(res[0].1.bytes_sent, 12);
        assert_eq!(res[0].1.control_sent, 1);
        assert_eq!(res[1].1.messages_received, 1);
        assert_eq!(res[1].1.control_received, 1);
    }
}
