//! Shared "true execution work" measure for all simulators: the hybrid
//! dispatch's per-pair cost ([`Oriented::intersect_cost`] — merge/gallop,
//! bitmap probe or word-AND, whichever the kernel would actually run),
//! scaled by the model's per-node execution noise (keyed by the node whose
//! list is intersected — so the noise is heavy-tailed and correlated the
//! way real cache behaviour is). Charging the bitmap cost model here is
//! load-bearing for §V: hub tasks get *cheaper* than any degree-based
//! `f(v)` predicts, so the dynamic balancer's task sizing reshuffles.

use crate::graph::ordering::Oriented;
use crate::sim::model::CostModel;
use crate::VertexId;

/// Executed work for one pair `(v, u)` with `u ∈ N_v`, both lists local
/// (hub bitmaps on both sides), in work units. Noise is keyed by `v` — the
/// node whose counting loop is being executed and whose cost `f(v)`
/// mispredicts.
#[inline]
pub fn pair_work(o: &Oriented, v: VertexId, u: VertexId, model: &CostModel) -> f64 {
    o.intersect_cost(v, u) as f64 * model.noise(v)
}

/// Executed work when `remote`'s list arrived over the wire: the real
/// drivers wrap wire payloads in a plain sorted view (no bitmap travels),
/// so only `local`'s hub bitmap can accelerate the pair — charging
/// [`pair_work`] here would undercount remote hub work.
#[inline]
pub fn pair_work_remote(
    o: &Oriented,
    local: VertexId,
    remote: VertexId,
    noise_key: VertexId,
    model: &CostModel,
) -> f64 {
    let cost = crate::adj::intersect_cost(
        o.view(local),
        crate::adj::NeighborView::sorted(o.nbrs(remote)),
    );
    cost as f64 * model.noise(noise_key)
}

/// Executed work of the whole Fig-1 loop for node `v`.
pub fn node_work(o: &Oriented, v: VertexId, model: &CostModel) -> f64 {
    let base: u64 = o.nbrs(v).iter().map(|&u| o.intersect_cost(v, u)).sum();
    base as f64 * model.noise(v)
}

/// Prefix sums of [`node_work`] over all nodes (`len n+1`), for O(1) range
/// queries in the task simulators.
pub fn node_work_prefix(o: &Oriented, model: &CostModel) -> Vec<f64> {
    let n = o.num_nodes();
    let mut p = Vec::with_capacity(n + 1);
    p.push(0.0);
    let mut acc = 0.0;
    for v in 0..n as VertexId {
        acc += node_work(o, v, model);
        p.push(acc);
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::classic;

    #[test]
    fn noiseless_matches_adaptive_measure() {
        let g = classic::karate();
        let o = Oriented::from_graph(&g);
        let m = CostModel::noiseless();
        for v in 0..34u32 {
            let expect = crate::seq::node_iterator::node_work_true(&o, v) as f64;
            assert!((node_work(&o, v, &m) - expect).abs() < 1e-9);
        }
    }

    #[test]
    fn noise_is_deterministic_and_mean_preserving() {
        let m = CostModel::default();
        assert_eq!(m.noise(42), m.noise(42));
        // Empirical mean of the normalized lognormal ≈ 1.
        let mean: f64 = (0..200_000u32).map(|v| m.noise(v)).sum::<f64>() / 200_000.0;
        assert!((mean - 1.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn prefix_is_monotone_and_total() {
        let g = classic::karate();
        let o = Oriented::from_graph(&g);
        let m = CostModel::default();
        let p = node_work_prefix(&o, &m);
        assert_eq!(p.len(), 35);
        for w in p.windows(2) {
            assert!(w[1] >= w[0]);
        }
        let total: f64 = (0..34u32).map(|v| node_work(&o, v, &m)).sum();
        assert!((p[34] - total).abs() < 1e-6);
    }
}
