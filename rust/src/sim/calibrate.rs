//! Calibrate the cost model's `α` (ns per intersection work unit) against
//! the *real* sequential kernel on this machine, so virtual-time results
//! are anchored to measured compute throughput rather than guesses.

use std::sync::OnceLock;
use std::time::Instant;

use crate::gen::rng::Rng;
use crate::graph::ordering::Oriented;
use crate::seq::node_iterator;
use crate::sim::model::CostModel;

/// Measure `α` by timing the Fig-1 kernel on a PA graph and dividing by the
/// work-unit total. Deterministic workload; a few hundred ms.
pub fn measure_alpha_ns() -> f64 {
    let g = crate::gen::pa::preferential_attachment(60_000, 16, &mut Rng::seeded(0xCAFE));
    let o = Oriented::from_graph(&g);
    let work: u64 = (0..o.num_nodes() as u32).map(|v| node_iterator::node_work_true(&o, v)).sum();
    // Warm-up + best-of-3 to shed first-touch noise.
    let mut best = f64::INFINITY;
    let mut sink = 0u64;
    for _ in 0..3 {
        let t0 = Instant::now();
        sink = sink.wrapping_add(node_iterator::count(&o));
        let dt = t0.elapsed().as_nanos() as f64;
        if dt < best {
            best = dt;
        }
    }
    std::hint::black_box(sink);
    (best / work as f64).max(0.05)
}

/// The calibrated model, memoized per process. `TRICOUNT_ALPHA_NS`
/// overrides the measurement (useful for deterministic CI output).
pub fn calibrated() -> CostModel {
    static MODEL: OnceLock<CostModel> = OnceLock::new();
    *MODEL.get_or_init(|| {
        let alpha = std::env::var("TRICOUNT_ALPHA_NS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(measure_alpha_ns);
        CostModel::with_alpha(alpha)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_in_sane_range() {
        // On any modern CPU the merge kernel runs 0.05-50 ns per element.
        let a = measure_alpha_ns();
        assert!(a > 0.01 && a < 100.0, "alpha={a}");
    }

    #[test]
    fn calibrated_is_memoized() {
        let a = calibrated();
        let b = calibrated();
        assert_eq!(a.alpha_ns, b.alpha_ns);
    }
}
