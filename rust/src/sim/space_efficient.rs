//! Virtual-time simulation of the §IV space-efficient algorithm (both
//! communication schemes) for arbitrary `P` — regenerates Figs 4, 5, 6, 9
//! and the runtime columns of Table III.
//!
//! The simulator walks the **exact** data structures the real algorithm
//! walks — the same oriented lists, the same `LastProc` send decisions, the
//! same `SURROGATECOUNT` work — but instead of moving bytes it charges each
//! rank virtual nanoseconds from the calibrated [`CostModel`]. Because the
//! §IV protocol is bulk-asynchronous (sends are fire-and-forget, receives
//! are drained opportunistically, and the completion phase is a full
//! barrier), the makespan is `max_i(compute_i + comm-endpoint_i)` — network
//! propagation overlaps with compute and only the endpoints' CPU burn
//! matters.

use std::ops::Range;

use crate::graph::ordering::Oriented;
use crate::sim::model::{CostModel, RankSim, SimResult};
use crate::VertexId;

/// Which §IV communication scheme to simulate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    Surrogate,
    Direct,
}

/// Simulate the space-efficient algorithm over consecutive `ranges`.
pub fn simulate(
    o: &Oriented,
    ranges: &[Range<u32>],
    owner: &[u32],
    scheme: Scheme,
    model: &CostModel,
) -> SimResult {
    let p = ranges.len();
    let mut ranks = vec![RankSim::default(); p];
    // Memory dimension: what each simulated rank would resident-hold — the
    // same prediction the real owned-partition drivers are gated against.
    for (r, s) in ranks
        .iter_mut()
        .zip(crate::partition::nonoverlap::partition_sizes(o, ranges))
    {
        r.mem_bytes = s.bytes();
    }

    // Sequential reference: all pair-work (true noisy adaptive-kernel
    // cost), no messages.
    let total_work: f64 = (0..o.num_nodes() as VertexId)
        .map(|v| crate::sim::work::node_work(o, v, model))
        .sum();
    let t_seq_ns = model.alpha_ns * total_work;

    for (i, r) in ranges.iter().enumerate() {
        for v in r.clone() {
            let nv = o.nbrs(v);
            let dv = nv.len() as u64;
            match scheme {
                Scheme::Surrogate => {
                    let mut last_proc: i64 = -1;
                    for &u in nv {
                        let j = owner[u as usize] as usize;
                        if j == i {
                            // Local intersection on rank i.
                            let w = crate::sim::work::pair_work(o, v, u, model);
                            ranks[i].compute_ns += model.alpha_ns * w;
                        } else if last_proc != j as i64 {
                            // One data message N_v → rank j; j does the
                            // surrogate work for ALL its members of N_v.
                            let bytes = 8 + 4 * dv;
                            ranks[i].msgs += 1;
                            ranks[i].bytes += bytes;
                            ranks[i].comm_ns += model.msg_endpoint_ns(bytes);
                            ranks[j].comm_ns += model.msg_endpoint_ns(bytes);
                            last_proc = j as i64;
                            // Surrogate compute: members of N_v owned by j.
                            let rj = &ranges[j];
                            let lo = nv.partition_point(|&x| x < rj.start);
                            let hi = nv.partition_point(|&x| x < rj.end);
                            let mut w = 0.0f64;
                            for &u2 in &nv[lo..hi] {
                                // Rank j intersects its local N_u2 against
                                // the wire copy of N_v (plain sorted view).
                                w += crate::sim::work::pair_work_remote(o, u2, v, v, model);
                            }
                            ranks[j].compute_ns += model.alpha_ns * w;
                        }
                    }
                }
                Scheme::Direct => {
                    for &u in nv {
                        let j = owner[u as usize] as usize;
                        let du = o.effective_degree(u) as u64;
                        if j == i {
                            let w = crate::sim::work::pair_work(o, v, u, model);
                            ranks[i].compute_ns += model.alpha_ns * w;
                        } else {
                            // Rank i intersects local N_v against the wire
                            // copy of N_u (plain sorted view).
                            let w = crate::sim::work::pair_work_remote(o, v, u, v, model);
                            // Request (16 B) i→j, response N_u j→i, then
                            // rank i intersects. Redundant re-fetches of the
                            // same N_u are *included* — that is the scheme's
                            // documented flaw.
                            let req = 16u64;
                            let resp = 12 + 4 * du;
                            ranks[i].msgs += 1;
                            ranks[i].bytes += req;
                            ranks[i].comm_ns += model.msg_endpoint_ns(req);
                            ranks[j].comm_ns += model.msg_endpoint_ns(req);
                            ranks[j].msgs += 1;
                            ranks[j].bytes += resp;
                            ranks[j].comm_ns += model.msg_endpoint_ns(resp);
                            ranks[i].comm_ns += model.msg_endpoint_ns(resp);
                            ranks[i].compute_ns += model.alpha_ns * w;
                        }
                    }
                }
            }
        }
    }

    let makespan_ns = ranks
        .iter()
        .map(|r| r.busy_ns())
        .fold(0.0f64, f64::max)
        // Partitioning phase (§IV-G: O(m/P + P log P), common to all ranks).
        + model.partition_phase_ns(o.num_edges(), p)
        // Completion notifiers: one P-way broadcast round.
        + model.control_rtt_ns();

    SimResult { per_rank: ranks, makespan_ns, t_seq_ns }
}

/// Virtual-time simulation of the 2D tile driver ([`crate::algo::tile2d`]).
///
/// The simulator replays the driver's **exact** broadcast plan — the same
/// [`crate::partition::tile2d::layout`], the same tiles, the same coalesced
/// frames out of [`crate::algo::tile2d::bcast_plan`] — so predicted frame
/// counts and bytes equal the measured `messages_sent`/`bytes_sent` of a
/// real run *exactly* (the CI smoke gates on this). Per-rank traffic is
/// `≈ m/r + m/c ≈ 2m/√P`, falling with P where the 1D schemes stay flat.
/// Compute is charged per tile mask edge from the assembled row/column
/// lengths (`|N_v| + indeg(u)`, the merge-intersection cost shape).
pub fn simulate_tile2d(o: &Oriented, p: usize, model: &CostModel) -> SimResult {
    use crate::adj::hub::HubThreshold;
    use crate::algo::tile2d::{bcast_plan, tile_csc};
    use crate::partition::tile2d as t2;

    // The driver shuffles before tiling (fixed seed); replaying its exact
    // frame plan means shuffling here identically.
    let sh = t2::shuffled(o);
    let o = &sh;
    let layout = t2::layout(o, p);
    let grid = layout.grid;
    let tiles = t2::extract_tiles(o, &layout, HubThreshold::Auto);
    let mut ranks = vec![RankSim::default(); p];
    for (r, s) in ranks.iter_mut().zip(t2::tile_sizes(o, &layout)) {
        r.mem_bytes = s.bytes();
    }

    // Oriented in-degrees — the assembled full-column lengths of phase 3.
    let mut indeg = vec![0u64; o.num_nodes()];
    for &u in o.targets() {
        indeg[u as usize] += 1;
    }

    let total_work: f64 = (0..o.num_nodes() as VertexId)
        .map(|v| crate::sim::work::node_work(o, v, model))
        .sum();

    for rank in 0..p {
        let Some((i, j)) = grid.coords(rank) else {
            continue; // remainder rank: empty tile, idles through the run
        };
        let tile = &tiles[rank];
        let cb = &layout.col_blocks[j];
        let csc = tile_csc(tile, cb);
        let plan = bcast_plan(tile, &csc, cb.start);
        // Phases 1–2: the same frames the real rank sends — row frames to
        // the c−1 grid-row peers, column frames to the r−1 grid-column
        // peers, endpoint cost on both sides.
        for pj in 0..grid.c {
            if pj == j {
                continue;
            }
            let dst = grid.rank_of(i, pj);
            for f in &plan.row_frames {
                let b = f.bytes();
                ranks[rank].msgs += 1;
                ranks[rank].bytes += b;
                ranks[rank].comm_ns += model.msg_endpoint_ns(b);
                ranks[dst].comm_ns += model.msg_endpoint_ns(b);
            }
        }
        for pi in 0..grid.r {
            if pi == i {
                continue;
            }
            let dst = grid.rank_of(pi, j);
            for f in &plan.col_frames {
                let b = f.bytes();
                ranks[rank].msgs += 1;
                ranks[rank].bytes += b;
                ranks[rank].comm_ns += model.msg_endpoint_ns(b);
                ranks[dst].comm_ns += model.msg_endpoint_ns(b);
            }
        }
        // Phase 3: one merge intersection per tile mask edge against the
        // assembled full row and column.
        let mut w = 0.0f64;
        for v in tile.range() {
            let dv = o.nbrs(v).len() as f64;
            for &u in tile.nbrs(v) {
                w += dv + indeg[u as usize] as f64;
            }
        }
        ranks[rank].compute_ns += model.alpha_ns * w;
    }

    let makespan_ns = ranks.iter().map(|r| r.busy_ns()).fold(0.0f64, f64::max)
        + model.partition_phase_ns(o.num_edges(), p)
        // Done markers closing both broadcasts: one control round.
        + model.control_rtt_ns();

    SimResult { per_rank: ranks, makespan_ns, t_seq_ns: model.alpha_ns * total_work }
}

/// Virtual-time PATRIC [21] baseline: overlapping partitions make every
/// list local, so a rank's time is pure compute over its core range and the
/// makespan is the statically balanced maximum (plus the final reduce).
/// Ranges are balanced with PATRIC's own best estimator by the callers.
pub fn simulate_patric(o: &Oriented, ranges: &[Range<u32>], model: &CostModel) -> SimResult {
    let mut ranks = vec![RankSim::default(); ranges.len()];
    let mut total_work = 0.0f64;
    for (i, r) in ranges.iter().enumerate() {
        let mut w = 0.0f64;
        for v in r.clone() {
            w += crate::sim::work::node_work(o, v, model);
        }
        ranks[i].compute_ns = model.alpha_ns * w;
        total_work += w;
    }
    let makespan_ns = ranks.iter().map(|r| r.busy_ns()).fold(0.0f64, f64::max)
        + model.partition_phase_ns(o.num_edges(), ranges.len())
        + model.control_rtt_ns();
    SimResult { per_rank: ranks, makespan_ns, t_seq_ns: model.alpha_ns * total_work }
}

/// [`simulate_patric`] with ranges balanced by a cost function.
pub fn simulate_patric_balanced(
    o: &Oriented,
    p: usize,
    cost_fn: crate::config::CostFn,
    model: &CostModel,
) -> SimResult {
    use crate::partition::balance::balanced_ranges;
    use crate::partition::cost::{cost_vector, prefix_sums};
    let prefix = prefix_sums(&cost_vector(o, cost_fn));
    simulate_patric(o, &balanced_ranges(&prefix, p), model)
}

/// Convenience: balance ranges with a cost function, then simulate.
pub fn simulate_balanced(
    o: &Oriented,
    p: usize,
    cost_fn: crate::config::CostFn,
    scheme: Scheme,
    model: &CostModel,
) -> SimResult {
    use crate::partition::balance::{balanced_ranges, owner_table};
    use crate::partition::cost::{cost_vector, prefix_sums};
    let prefix = prefix_sums(&cost_vector(o, cost_fn));
    let ranges = balanced_ranges(&prefix, p);
    let owner = owner_table(&ranges, o.num_nodes());
    simulate(o, &ranges, &owner, scheme, model)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CostFn;
    use crate::gen::rng::Rng;
    use crate::graph::ordering::Oriented;
    use crate::sim::model::CostModel;

    fn test_graph() -> Oriented {
        let g = crate::gen::pa::preferential_attachment(20_000, 30, &mut Rng::seeded(7));
        Oriented::from_graph(&g)
    }

    #[test]
    fn surrogate_faster_than_direct() {
        // The paper's Fig 4 headline, in virtual time.
        let o = test_graph();
        let m = CostModel::default();
        let s = simulate_balanced(&o, 16, CostFn::SurrogateNew, Scheme::Surrogate, &m);
        let d = simulate_balanced(&o, 16, CostFn::SurrogateNew, Scheme::Direct, &m);
        assert!(
            s.makespan_ns < d.makespan_ns,
            "surrogate {} !< direct {}",
            s.makespan_ns,
            d.makespan_ns
        );
        assert!(s.total_msgs() < d.total_msgs());
    }

    #[test]
    fn speedup_grows_with_p() {
        let o = test_graph();
        let m = CostModel::default();
        let s4 = simulate_balanced(&o, 4, CostFn::SurrogateNew, Scheme::Surrogate, &m);
        let s16 = simulate_balanced(&o, 16, CostFn::SurrogateNew, Scheme::Surrogate, &m);
        assert!(s16.speedup() > s4.speedup());
        assert!(s4.speedup() > 1.5, "speedup at P=4 was {}", s4.speedup());
    }

    #[test]
    fn p1_speedup_is_about_one() {
        let o = test_graph();
        let m = CostModel::default();
        let s = simulate_balanced(&o, 1, CostFn::SurrogateNew, Scheme::Surrogate, &m);
        assert!((s.speedup() - 1.0).abs() < 0.05, "speedup={}", s.speedup());
        assert_eq!(s.total_msgs(), 0);
    }

    #[test]
    fn work_conservation_surrogate() {
        // Σ compute across ranks == sequential compute (surrogate moves
        // work, never duplicates it).
        let o = test_graph();
        let m = CostModel::default();
        let s = simulate_balanced(&o, 8, CostFn::SurrogateNew, Scheme::Surrogate, &m);
        let total: f64 = s.per_rank.iter().map(|r| r.compute_ns).sum();
        assert!(
            (total - s.t_seq_ns).abs() / s.t_seq_ns < 1e-9,
            "compute {} vs seq {}",
            total,
            s.t_seq_ns
        );
    }

    #[test]
    fn sim_message_counts_match_real_run() {
        // The simulator must make the *same* send decisions as the threaded
        // implementation.
        use crate::adj::HubThreshold;
        use crate::partition::balance::{balanced_ranges, owner_table};
        use crate::partition::cost::{cost_vector, prefix_sums};
        let g = crate::gen::pa::preferential_attachment(600, 8, &mut Rng::seeded(12));
        let o = Oriented::from_graph(&g);
        let prefix = prefix_sums(&cost_vector(&o, CostFn::SurrogateNew));
        let ranges = balanced_ranges(&prefix, 5);
        let owner = owner_table(&ranges, o.num_nodes());
        let real = crate::algo::surrogate::run(&o, &ranges, HubThreshold::Auto).unwrap();
        let sim = simulate(&o, &ranges, &owner, Scheme::Surrogate, &CostModel::default());
        assert_eq!(real.metrics.totals().messages_sent, sim.total_msgs());
        let real_d = crate::algo::direct::run(&o, &ranges, HubThreshold::Auto).unwrap();
        let sim_d = simulate(&o, &ranges, &owner, Scheme::Direct, &CostModel::default());
        // Direct's envelopes are coalesced frames; the simulator predicts
        // the *logical* record traffic, which framing leaves unchanged.
        assert_eq!(real_d.metrics.totals().coalesced_sent, sim_d.total_msgs());
        // And the simulator's memory dimension is the same prediction the
        // real run's owned partitions were measured against.
        assert_eq!(sim.max_mem_bytes(), real.metrics.max_partition_bytes());
        assert!(sim.max_mem_bytes() > 0);
    }

    #[test]
    fn tile2d_sim_replays_real_frame_plan_exactly() {
        // The tile2d simulator shares the driver's bcast_plan, so frames,
        // bytes and per-rank memory match the measured run exactly — the
        // invariant the bench-comm CI gate rests on.
        use crate::adj::HubThreshold;
        let g = crate::gen::pa::preferential_attachment(800, 10, &mut Rng::seeded(3));
        let o = Oriented::from_graph(&g);
        for p in [4, 6, 9] {
            let real = crate::algo::tile2d::run(&o, p, HubThreshold::Auto).unwrap();
            let sim = simulate_tile2d(&o, p, &CostModel::default());
            let t = real.metrics.totals();
            assert_eq!(t.messages_sent, sim.total_msgs(), "P={p}");
            assert_eq!(t.frames_sent, sim.total_msgs(), "P={p}");
            assert_eq!(t.bytes_sent, sim.total_bytes(), "P={p}");
            assert_eq!(sim.max_mem_bytes(), real.metrics.max_partition_bytes(), "P={p}");
        }
    }

    #[test]
    fn tile2d_per_rank_traffic_falls_with_p() {
        // The headline: per-rank sent bytes shrink ≈ 1/√P for the 2D
        // exchange, while the 1D schemes' total-traffic stays flat.
        let o = test_graph();
        let m = CostModel::default();
        let max_rank_bytes = |s: &crate::sim::model::SimResult| {
            s.per_rank.iter().map(|r| r.bytes).max().unwrap_or(0)
        };
        let b4 = max_rank_bytes(&simulate_tile2d(&o, 4, &m));
        let b9 = max_rank_bytes(&simulate_tile2d(&o, 9, &m));
        let b16 = max_rank_bytes(&simulate_tile2d(&o, 16, &m));
        assert!(b4 > b9 && b9 > b16, "per-rank bytes {b4} → {b9} → {b16}");
        let d16 = simulate_balanced(&o, 16, CostFn::SurrogateNew, Scheme::Surrogate, &m);
        assert!(
            b16 < max_rank_bytes(&d16),
            "2D per-rank {} !< surrogate per-rank {}",
            b16,
            max_rank_bytes(&d16)
        );
    }
}
