//! Virtual-time projection of streaming throughput — the same calibrated
//! [`CostModel`] that regenerates the paper's scaling figures, applied to
//! the incremental engine's per-batch work profile.
//!
//! A streamed batch on `P` ranks costs, in virtual time,
//!
//! ```text
//! T_batch = max_i (α · W_i)  +  2 · T_allreduce(P)
//! ```
//!
//! where `W_i` is rank `i`'s counting work (the `|N_u| + |N_v|` element
//! steps recorded by [`crate::stream::delta`]) and the two allreduces are
//! the positive/negative Δ reductions of the parallel driver. Throughput
//! is effective updates over Σ batches. Two entry points: project the
//! *measured* per-rank split of a real run, or sweep `P` under ideal
//! balance to see where reduction latency caps batch rates.

use crate::sim::model::CostModel;

/// A projected streaming run.
#[derive(Clone, Copy, Debug)]
pub struct StreamProjection {
    /// Virtual makespan of the whole stream, ns.
    pub makespan_ns: f64,
    /// Virtual time of the same work on one rank (no reductions), ns.
    pub t_seq_ns: f64,
    /// Effective updates per virtual second.
    pub updates_per_sec: f64,
    /// `t_seq / makespan`.
    pub speedup: f64,
}

/// Virtual cost of an `MPI_Allreduce(SUM)` on a u64: recursive doubling,
/// `⌈log₂ P⌉` rounds of one small message each way.
pub fn allreduce_ns(model: &CostModel, p: usize) -> f64 {
    if p <= 1 {
        return 0.0;
    }
    let rounds = (p as f64).log2().ceil();
    rounds * (2.0 * model.net_latency_ns + 2.0 * model.cpu_per_msg_ns + model.msg_endpoint_ns(8))
}

/// Project from a measured run: `per_batch_work[b][i]` = rank `i`'s work
/// in batch `b` (see `StreamRunResult::per_batch`).
pub fn project_measured(
    model: &CostModel,
    per_batch_work: &[Vec<u64>],
    updates: u64,
) -> StreamProjection {
    let p = per_batch_work.first().map_or(1, Vec::len);
    let mut makespan = 0.0f64;
    let mut total_work = 0u64;
    for batch in per_batch_work {
        let max = batch.iter().copied().max().unwrap_or(0);
        total_work += batch.iter().sum::<u64>();
        makespan += model.compute_ns(max) + 2.0 * allreduce_ns(model, p);
    }
    finish(model, makespan, total_work, updates)
}

/// Project an idealized run: total counting work split perfectly over `p`
/// ranks, `batches` reduction rounds. The P-sweep the CLI prints.
pub fn project_ideal(
    model: &CostModel,
    total_work: u64,
    batches: usize,
    updates: u64,
    p: usize,
) -> StreamProjection {
    let makespan = model.compute_ns(total_work) / p.max(1) as f64
        + batches as f64 * 2.0 * allreduce_ns(model, p);
    finish(model, makespan, total_work, updates)
}

fn finish(model: &CostModel, makespan_ns: f64, total_work: u64, updates: u64) -> StreamProjection {
    let t_seq_ns = model.compute_ns(total_work);
    StreamProjection {
        makespan_ns,
        t_seq_ns,
        updates_per_sec: if makespan_ns > 0.0 {
            updates as f64 / (makespan_ns * 1e-9)
        } else {
            0.0
        },
        speedup: if makespan_ns > 0.0 { t_seq_ns / makespan_ns } else { 1.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allreduce_grows_with_p_and_is_free_on_one_rank() {
        let m = CostModel::default();
        assert_eq!(allreduce_ns(&m, 1), 0.0);
        assert!(allreduce_ns(&m, 2) > 0.0);
        assert!(allreduce_ns(&m, 16) > allreduce_ns(&m, 4));
    }

    #[test]
    fn measured_projection_uses_the_slowest_rank() {
        let m = CostModel::noiseless();
        let balanced = project_measured(&m, &[vec![100, 100]], 10);
        let skewed = project_measured(&m, &[vec![190, 10]], 10);
        assert!(skewed.makespan_ns > balanced.makespan_ns);
        assert_eq!(balanced.t_seq_ns, skewed.t_seq_ns, "same total work");
    }

    #[test]
    fn ideal_scaling_saturates_at_reduction_latency() {
        let m = CostModel::default();
        let one = project_ideal(&m, 1_000_000, 50, 50_000, 1);
        let eight = project_ideal(&m, 1_000_000, 50, 50_000, 8);
        assert!(eight.updates_per_sec > one.updates_per_sec);
        assert!(eight.speedup > 1.0 && eight.speedup <= 8.0);
        // With huge P the makespan floors at the reduction term.
        let huge = project_ideal(&m, 1_000_000, 50, 50_000, 4096);
        assert!(huge.makespan_ns >= 50.0 * 2.0 * allreduce_ns(&m, 4096));
    }
}
