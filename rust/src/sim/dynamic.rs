//! Event-driven virtual-time simulation of the §V dynamic load balancer —
//! regenerates Figs 12, 13, 14, 15 and Table IV for arbitrary `P`.
//!
//! Workers execute tasks whose *true* cost comes from the same
//! `node_work` measure the real kernel performs; task *sizing* uses the
//! cheap `f(v)` the paper allows (`1` or `d_v`) — the gap between sizing
//! estimate and true cost is exactly what produces idle time, so the
//! simulation reproduces the paper's Fig 13 mechanism, not just its curve.
//!
//! The coordinator is modeled as a FIFO server (service time σ); a task
//! round trip costs `γ + wait + σ + γ`. A static-partitioning run (PATRIC,
//! for Table IV / Fig 14 comparisons) is the degenerate case: one initial
//! task per worker, empty queue.

use std::collections::BinaryHeap;

use crate::algo::tasks::{self, Task};
use crate::config::CostFn;
use crate::graph::ordering::Oriented;
use crate::partition::cost::{cost_vector, prefix_sums};
use crate::sim::model::{CostModel, RankSim, SimResult};

/// Granularity policy (mirrors [`crate::algo::dynamic_lb::Granularity`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimGranularity {
    /// Paper Eqn 2 shrinking tasks.
    Shrinking,
    /// Equal-cost tasks, `k` of them (Fig 13's "static size" strawman).
    Fixed(usize),
    /// No dynamic phase at all: pure static partitioning (PATRIC-style).
    StaticOnly,
}

/// Per-worker outcome of a dynamic-LB simulation.
#[derive(Clone, Debug, Default)]
pub struct WorkerSim {
    pub busy_ns: f64,
    pub idle_ns: f64,
    pub tasks_run: u64,
}

/// Outcome of the event-driven simulation.
#[derive(Clone, Debug)]
pub struct DynamicSim {
    pub makespan_ns: f64,
    pub t_seq_ns: f64,
    pub workers: Vec<WorkerSim>,
    /// Control messages exchanged with the coordinator.
    pub control_msgs: u64,
}

impl DynamicSim {
    pub fn speedup(&self) -> f64 {
        if self.makespan_ns == 0.0 {
            1.0
        } else {
            self.t_seq_ns / self.makespan_ns
        }
    }

    /// Convert to the common [`SimResult`] shape (coordinator excluded).
    pub fn to_sim_result(&self) -> SimResult {
        SimResult {
            per_rank: self
                .workers
                .iter()
                .map(|w| RankSim {
                    compute_ns: w.busy_ns,
                    comm_ns: 0.0,
                    idle_ns: w.idle_ns,
                    msgs: w.tasks_run,
                    bytes: 0,
                    // §V ranks store the whole network — no partition.
                    mem_bytes: 0,
                })
                .collect(),
            makespan_ns: self.makespan_ns,
            t_seq_ns: self.t_seq_ns,
        }
    }
}

#[derive(PartialEq)]
struct Ev {
    time: f64,
    worker: usize,
}
impl Eq for Ev {}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Min-heap by time (reverse), tie-break by worker for determinism.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap()
            .then_with(|| other.worker.cmp(&self.worker))
    }
}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Simulate `p` ranks (1 coordinator + `p−1` workers).
pub fn simulate(
    o: &Oriented,
    p: usize,
    cost_fn: CostFn,
    granularity: SimGranularity,
    model: &CostModel,
) -> DynamicSim {
    assert!(p >= 2);
    let workers = p - 1;
    let n = o.num_nodes();

    // True per-node work: adaptive kernel cost × execution noise — the
    // thing no static estimator sees (see CostModel::exec_noise_sigma).
    let true_prefix = crate::sim::work::node_work_prefix(o, model);
    let t_seq_ns = model.alpha_ns * true_prefix[n];
    let task_ns = |t: &Task| {
        model.alpha_ns * (true_prefix[t.end() as usize] - true_prefix[t.start as usize])
    };

    // Sizing estimate (what the balancer *thinks* costs are).
    let est_prefix = prefix_sums(&cost_vector(o, cost_fn));

    // Build initial tasks + dynamic queue.
    let (initial, queue): (Vec<Task>, Vec<Task>) = match granularity {
        SimGranularity::StaticOnly => {
            (tasks::equal_cost_tasks(&est_prefix, 0, n, workers), Vec::new())
        }
        SimGranularity::Shrinking => {
            let tp = tasks::half_point(&est_prefix);
            (
                tasks::equal_cost_tasks(&est_prefix, 0, tp, workers),
                tasks::shrinking_tasks(&est_prefix, tp, workers),
            )
        }
        SimGranularity::Fixed(k) => {
            let tp = tasks::half_point(&est_prefix);
            (
                tasks::equal_cost_tasks(&est_prefix, 0, tp, workers),
                tasks::fixed_tasks(&est_prefix, tp, k),
            )
        }
    };

    let mut ws = vec![WorkerSim::default(); workers];
    let mut heap = BinaryHeap::new();
    // Initial tasks start at t=0 with no coordinator traffic (Eqn 1).
    for w in 0..workers {
        let t0 = initial.get(w).map(|t| {
            ws[w].busy_ns += task_ns(t);
            ws[w].tasks_run += 1;
            task_ns(t)
        });
        heap.push(Ev { time: t0.unwrap_or(0.0), worker: w });
    }

    let mut next = 0usize;
    let mut coord_free = 0.0f64;
    let mut control_msgs = 0u64;
    let mut done_at = vec![0.0f64; workers];

    while let Some(Ev { time, worker }) = heap.pop() {
        // Worker idle → request a task.
        control_msgs += 1; // request
        let arrive = time + model.net_latency_ns;
        let start = arrive.max(coord_free);
        coord_free = start + model.coord_service_ns;
        let reply_at = coord_free + model.net_latency_ns;
        control_msgs += 1; // assign / terminate
        if next < queue.len() {
            let task = queue[next];
            next += 1;
            let dur = task_ns(&task);
            ws[worker].idle_ns += reply_at - time;
            ws[worker].busy_ns += dur;
            ws[worker].tasks_run += 1;
            heap.push(Ev { time: reply_at + dur, worker });
        } else {
            // Terminate.
            done_at[worker] = reply_at;
        }
    }

    // Initial-assignment phase (§V-B: the Eqn-1 split is computed by the
    // same parallel partitioning machinery, O(n/P + P log P)).
    let phase = model.partition_phase_ns(n as u64, p);
    let makespan = done_at.iter().copied().fold(0.0f64, f64::max) + phase;
    // Terminal idle: after a worker's own terminate, it waits at the final
    // barrier until the last worker finishes (paper Fig 11 line 25).
    for (w, d) in done_at.iter().enumerate() {
        ws[w].idle_ns += makespan - d;
    }

    DynamicSim { makespan_ns: makespan, t_seq_ns, workers: ws, control_msgs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::rng::Rng;
    use crate::graph::ordering::Oriented;

    fn skewed_graph() -> Oriented {
        let g = crate::gen::pa::preferential_attachment(5000, 14, &mut Rng::seeded(3));
        Oriented::from_graph(&g)
    }

    #[test]
    fn degree_cost_beats_unit_cost() {
        // Paper Fig 12: f = d_v gives higher speedups than f = 1.
        let o = skewed_graph();
        let m = CostModel::default();
        let du = simulate(&o, 32, CostFn::Unit, SimGranularity::Shrinking, &m);
        let dd = simulate(&o, 32, CostFn::Degree, SimGranularity::Shrinking, &m);
        assert!(
            dd.speedup() >= du.speedup() * 0.98,
            "degree {} vs unit {}",
            dd.speedup(),
            du.speedup()
        );
    }

    #[test]
    fn dynamic_beats_static() {
        // Paper Table IV / Fig 13: dynamic balancing reduces idle time and
        // beats static partitioning with the same cheap estimator.
        let o = skewed_graph();
        let m = CostModel::default();
        let stat = simulate(&o, 16, CostFn::Degree, SimGranularity::StaticOnly, &m);
        let dynm = simulate(&o, 16, CostFn::Degree, SimGranularity::Shrinking, &m);
        assert!(
            dynm.makespan_ns < stat.makespan_ns,
            "dynamic {} !< static {}",
            dynm.makespan_ns,
            stat.makespan_ns
        );
        let idle_dyn: f64 = dynm.workers.iter().map(|w| w.idle_ns).sum();
        let idle_stat: f64 = stat.workers.iter().map(|w| w.idle_ns).sum();
        assert!(idle_dyn < idle_stat, "idle dyn {idle_dyn} !< static {idle_stat}");
    }

    #[test]
    fn work_conservation() {
        let o = skewed_graph();
        let m = CostModel::default();
        let d = simulate(&o, 8, CostFn::Degree, SimGranularity::Shrinking, &m);
        let busy: f64 = d.workers.iter().map(|w| w.busy_ns).sum();
        assert!(
            (busy - d.t_seq_ns).abs() / d.t_seq_ns < 1e-9,
            "busy {} vs seq {}",
            busy,
            d.t_seq_ns
        );
    }

    #[test]
    fn speedup_scales() {
        let o = skewed_graph();
        let m = CostModel::default();
        let s8 = simulate(&o, 8, CostFn::Degree, SimGranularity::Shrinking, &m);
        let s32 = simulate(&o, 32, CostFn::Degree, SimGranularity::Shrinking, &m);
        assert!(s32.speedup() > s8.speedup());
        assert!(s8.speedup() > 4.0, "speedup at 7 workers = {}", s8.speedup());
    }

    #[test]
    fn makespan_at_least_critical_path() {
        let o = skewed_graph();
        let m = CostModel::default();
        let d = simulate(&o, 16, CostFn::Degree, SimGranularity::Shrinking, &m);
        let max_busy = d.workers.iter().map(|w| w.busy_ns).fold(0.0f64, f64::max);
        assert!(d.makespan_ns >= max_busy);
    }

    #[test]
    fn deterministic() {
        let o = skewed_graph();
        let m = CostModel::default();
        let a = simulate(&o, 12, CostFn::Degree, SimGranularity::Shrinking, &m);
        let b = simulate(&o, 12, CostFn::Degree, SimGranularity::Shrinking, &m);
        assert_eq!(a.makespan_ns, b.makespan_ns);
        assert_eq!(a.control_msgs, b.control_msgs);
    }
}
