//! Cluster cost model for the virtual-time simulator.
//!
//! The container has one CPU core, so the paper's 200-processor scaling
//! figures cannot be *wall-clock measured* here; they are regenerated in
//! **virtual time** by driving the algorithms' exact work and message
//! patterns through this model (see DESIGN.md §3 Substitutions). The model
//! is deliberately simple — the paper's own complexity analysis (§IV-G)
//! uses the same three terms:
//!
//! * **compute**: `α` ns per work unit, where a work unit is one element
//!   step of the hybrid dispatch ([`crate::adj::intersect_cost`]: merge
//!   element, bitmap probe, or 64-bit word-AND — see
//!   [`crate::sim::work`]); `α` is *measured* on this machine by
//!   [`crate::sim::calibrate`] against the same hybrid kernel, so virtual
//!   seconds ≈ real seconds of the real kernel;
//! * **bandwidth**: `1/β` ns per payload byte;
//! * **per-message overhead**: `γ_cpu` ns of sender/receiver CPU, plus
//!   `γ_net` ns propagation (hidden by overlap except on the request/reply
//!   round trips of the dynamic-LB protocol).
//!
//! Defaults for the network terms are typical of the paper-era InfiniBand
//! cluster (Dell C6100): ~2 µs MPI latency, ~1.5 GB/s effective per-rank
//! bandwidth.

/// Nanosecond-denominated cost model.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// ns per intersection work unit (calibrated; see `calibrate.rs`).
    pub alpha_ns: f64,
    /// ns per payload byte (≈ 1 / 1.5 GB/s).
    pub ns_per_byte: f64,
    /// Per-message CPU overhead on each endpoint (pack/unpack, matching).
    pub cpu_per_msg_ns: f64,
    /// One-way network propagation latency.
    pub net_latency_ns: f64,
    /// Coordinator service time per request (dynamic-LB protocol).
    pub coord_service_ns: f64,
    /// Lognormal σ of per-node execution noise (see [`CostModel::noise`]).
    ///
    /// On a real cluster the time to intersect against `N_u` deviates from
    /// any degree-based estimate — cache/TLB behaviour, memory layout, and
    /// per-pair constants are invisible to `f(v)`. §V's dynamic balancing
    /// exists precisely because of this estimate-vs-reality gap (the paper's
    /// Fig 13 static idle times *are* that gap). We model it as a
    /// deterministic, heavy-tailed multiplicative factor keyed to the node
    /// whose list is being intersected, applied identically in every
    /// simulator, so static schemes can't see it but do pay it.
    /// `0.0` disables (used by message-count validation tests);
    /// EXPERIMENTS.md carries a σ-sensitivity ablation.
    pub exec_noise_sigma: f64,
}

/// Network-cost ratios relative to α, derived from the paper's own numbers:
/// from Table III (LJ: PATRIC 0.8s at P=200 over ~3.1B work units) the
/// paper's implementation runs at α_paper ≈ 52 ns/unit, and the
/// surrogate−PATRIC gap over ~n hot-rank messages implies ≈ 92 ns/message
/// ≈ 1.8·α_paper; MPI latency 2 µs ≈ 38·α_paper; 1.5 GB/s ≈ 0.013·α_paper
/// per byte. Our kernel is ~25× faster per work unit, so expressing the
/// network in units of α preserves the paper's compute:communication
/// balance — the quantity every scaling figure is about.
pub const MSG_ALPHA_RATIO: f64 = 1.8;
pub const LATENCY_ALPHA_RATIO: f64 = 38.0;
pub const BYTE_ALPHA_RATIO: f64 = 0.013;
pub const COORD_ALPHA_RATIO: f64 = 6.0;

/// Partitioning-phase constant: the paper's §IV-G runtime includes
/// `O(m/P + P log P)` for computing balanced partitions; this is the
/// per-(P log P) work-unit coefficient.
pub const PARTITION_PLOGP_UNITS: f64 = 32.0;

impl Default for CostModel {
    /// Constants at the reference α = 2 ns with the paper-derived ratios
    /// above. [`CostModel::with_alpha`] rescales everything to a measured α
    /// (what `calibrate::calibrated()` returns).
    fn default() -> Self {
        CostModel::with_alpha(2.0)
    }
}

impl CostModel {
    /// Model with all network terms scaled relative to a measured α.
    pub fn with_alpha(alpha_ns: f64) -> Self {
        CostModel {
            alpha_ns,
            ns_per_byte: BYTE_ALPHA_RATIO * alpha_ns,
            cpu_per_msg_ns: MSG_ALPHA_RATIO * alpha_ns,
            net_latency_ns: LATENCY_ALPHA_RATIO * alpha_ns,
            coord_service_ns: COORD_ALPHA_RATIO * alpha_ns,
            exec_noise_sigma: 1.0,
        }
    }

    /// Absolute constants of the paper-era cluster (Dell C6100, MPI):
    /// ~2 µs latency, ~0.6 µs per-message CPU, ~1.5 GB/s bandwidth, with
    /// the paper implementation's α ≈ 52 ns/unit. Use for absolute what-if
    /// projections on the paper's own hardware.
    pub fn paper_cluster() -> Self {
        CostModel {
            alpha_ns: 52.0,
            ns_per_byte: 0.67,
            cpu_per_msg_ns: 600.0,
            net_latency_ns: 2_000.0,
            coord_service_ns: 300.0,
            exec_noise_sigma: 1.0,
        }
    }

    /// The paper's §IV-G partitioning-phase cost `O(m/P + P log P)`, in ns.
    /// Charged to every rank in all scheme simulators (the phase is common
    /// to PATRIC, direct, surrogate and the §V initial assignment).
    pub fn partition_phase_ns(&self, m: u64, p: usize) -> f64 {
        let plogp = (p as f64) * (p as f64).log2().max(1.0);
        self.alpha_ns * (m as f64 / p as f64 + PARTITION_PLOGP_UNITS * plogp)
    }

    /// Noise disabled — exact cost-measure accounting (validation tests).
    pub fn noiseless() -> Self {
        CostModel { exec_noise_sigma: 0.0, ..CostModel::default() }
    }

    /// Deterministic per-node execution-noise factor: lognormal(0, σ²),
    /// normalized to mean 1 so totals stay calibrated. Keyed by node id.
    #[inline]
    pub fn noise(&self, v: u32) -> f64 {
        if self.exec_noise_sigma == 0.0 {
            return 1.0;
        }
        // splitmix64 hash → two uniforms → Box-Muller standard normal.
        let mut x = (v as u64).wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^= z >> 31;
            (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
        };
        let (u1, u2) = (next().max(1e-18), next());
        let z = (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
        let sigma = self.exec_noise_sigma;
        // E[exp(σz)] = exp(σ²/2); divide it out so the mean factor is 1.
        (sigma * z - sigma * sigma / 2.0).exp()
    }
}

impl CostModel {
    /// Compute time for `work` units.
    #[inline]
    pub fn compute_ns(&self, work: u64) -> f64 {
        self.alpha_ns * work as f64
    }

    /// Endpoint cost of a message of `bytes` (CPU + serialization share).
    #[inline]
    pub fn msg_endpoint_ns(&self, bytes: u64) -> f64 {
        self.cpu_per_msg_ns + self.ns_per_byte * bytes as f64
    }

    /// Round-trip of two small control messages through the network.
    #[inline]
    pub fn control_rtt_ns(&self) -> f64 {
        2.0 * self.net_latency_ns + 2.0 * self.cpu_per_msg_ns
    }
}

/// Per-rank virtual-time breakdown produced by the simulators.
#[derive(Clone, Debug, Default)]
pub struct RankSim {
    /// Local + surrogate compute, ns.
    pub compute_ns: f64,
    /// Send + receive endpoint overheads, ns.
    pub comm_ns: f64,
    /// Idle (only meaningful for the event-driven dynamic sim), ns.
    pub idle_ns: f64,
    /// Data messages sent.
    pub msgs: u64,
    /// Payload bytes sent.
    pub bytes: u64,
    /// Predicted partition residency of the rank
    /// ([`crate::partition::nonoverlap::PartitionSize::bytes`]); filled by
    /// the §IV space-efficient simulator so virtual-time sweeps report the
    /// memory dimension alongside runtime. 0 for simulators whose ranks
    /// hold the whole graph.
    pub mem_bytes: u64,
}

impl RankSim {
    /// Total busy time of the rank.
    pub fn busy_ns(&self) -> f64 {
        self.compute_ns + self.comm_ns
    }
}

/// Result of a virtual-time simulation.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub per_rank: Vec<RankSim>,
    /// Virtual makespan, ns.
    pub makespan_ns: f64,
    /// Virtual sequential time of the same workload, ns (speedup denominator).
    pub t_seq_ns: f64,
}

impl SimResult {
    /// Strong-scaling speedup `T_seq / T_P`.
    pub fn speedup(&self) -> f64 {
        if self.makespan_ns == 0.0 {
            1.0
        } else {
            self.t_seq_ns / self.makespan_ns
        }
    }

    /// Total data messages.
    pub fn total_msgs(&self) -> u64 {
        self.per_rank.iter().map(|r| r.msgs).sum()
    }

    /// Total payload bytes.
    pub fn total_bytes(&self) -> u64 {
        self.per_rank.iter().map(|r| r.bytes).sum()
    }

    /// Largest per-rank predicted partition residency (0 when the
    /// simulated scheme keeps the whole graph per rank).
    pub fn max_mem_bytes(&self) -> u64 {
        self.per_rank.iter().map(|r| r.mem_bytes).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_terms() {
        let m = CostModel::default();
        assert!(m.compute_ns(1000) > 0.0);
        assert!(m.msg_endpoint_ns(4096) > m.msg_endpoint_ns(0));
        assert!(m.control_rtt_ns() > 2.0 * m.net_latency_ns);
    }

    #[test]
    fn speedup_identity() {
        let r = SimResult {
            per_rank: vec![],
            makespan_ns: 50.0,
            t_seq_ns: 200.0,
        };
        assert!((r.speedup() - 4.0).abs() < 1e-12);
    }
}
