//! PJRT runtime — loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! Interchange is HLO **text** (not serialized `HloModuleProto`): jax ≥ 0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids and round-trips cleanly (see
//! /opt/xla-example/README.md). Python runs only at build time (`make
//! artifacts`); this module is the only thing that touches XLA at runtime.
//!
//! The `xla` crate is not part of the offline vendored set, so the real
//! client is gated behind the `xla-runtime` cargo feature. The default
//! build compiles the same API as a stub whose constructors return
//! [`Error::Xla`], keeping every call site (CLI `hybrid` path, benches,
//! integration tests) compiling and failing gracefully at runtime.

use std::path::Path;

use crate::error::{Error, Result};

#[cfg(feature = "xla-runtime")]
mod real {
    use super::*;

    /// A PJRT CPU client. One per process; executables are compiled once and
    /// reused across requests.
    pub struct Engine {
        client: xla::PjRtClient,
    }

    impl Engine {
        /// Create the CPU client.
        pub fn cpu() -> Result<Engine> {
            Ok(Engine { client: xla::PjRtClient::cpu()? })
        }

        /// Backend platform name (diagnostics).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO-text artifact into a dense triangle counter
        /// for `n × n` f32 adjacency blocks.
        pub fn load_dense_counter<P: AsRef<Path>>(&self, path: P, n: usize) -> Result<DenseCounter> {
            let path = path.as_ref();
            if !path.exists() {
                return Err(Error::Artifact(format!(
                    "missing artifact {} — run `make artifacts`",
                    path.display()
                )));
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| Error::Artifact("non-utf8 artifact path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            Ok(DenseCounter { exe, n })
        }
    }

    /// A compiled executable computing `sum((L·L) ⊙ L)` over an `n×n` 0/1
    /// oriented adjacency matrix — the exact count of triangles in the dense
    /// block (each triangle's vertices ordered by `≺` appear once).
    pub struct DenseCounter {
        exe: xla::PjRtLoadedExecutable,
        n: usize,
    }

    impl DenseCounter {
        /// Matrix side length this executable was compiled for.
        pub fn n(&self) -> usize {
            self.n
        }

        /// Count triangles in a row-major `n×n` 0/1 matrix.
        ///
        /// Exactness: the kernel accumulates per-tile partial sums in f32
        /// (bounded by `B²·n < 2²⁴` for `n ≤ 512`) and reduces tiles in f64,
        /// so the result is integral for every supported artifact size.
        pub fn count(&self, matrix: &[f32]) -> Result<u64> {
            if matrix.len() != self.n * self.n {
                return Err(Error::Artifact(format!(
                    "matrix len {} != {}²",
                    matrix.len(),
                    self.n
                )));
            }
            let lit = xla::Literal::vec1(matrix).reshape(&[self.n as i64, self.n as i64])?;
            let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
            // aot.py lowers with return_tuple=True → 1-tuple.
            let out = result.to_tuple1()?;
            let v = out.to_vec::<f64>()?;
            let x = v.first().copied().ok_or_else(|| Error::Artifact("empty result".into()))?;
            let rounded = x.round();
            if (x - rounded).abs() > 1e-6 {
                return Err(Error::Artifact(format!("non-integral triangle count {x}")));
            }
            Ok(rounded as u64)
        }
    }
}

#[cfg(not(feature = "xla-runtime"))]
mod stub {
    use super::*;

    const UNAVAILABLE: &str =
        "PJRT unavailable: built without the `xla-runtime` feature (vendor the `xla` crate and \
         rebuild with `--features xla-runtime`); the sparse algorithms and the pure-rust \
         `tensor::hybrid::count_reference` path are unaffected";

    /// Stub engine: same API as the real PJRT client, every constructor
    /// reports the runtime as unavailable.
    pub struct Engine {
        _priv: (),
    }

    impl Engine {
        /// Always fails in stub builds.
        pub fn cpu() -> Result<Engine> {
            Err(Error::Xla(UNAVAILABLE.into()))
        }

        /// Backend platform name (diagnostics).
        pub fn platform(&self) -> String {
            "stub (no PJRT)".into()
        }

        /// Always fails in stub builds.
        pub fn load_dense_counter<P: AsRef<Path>>(&self, _path: P, _n: usize) -> Result<DenseCounter> {
            Err(Error::Xla(UNAVAILABLE.into()))
        }
    }

    /// Unreachable in stub builds ([`Engine::cpu`] never succeeds); exists
    /// so signatures match the real module.
    pub struct DenseCounter {
        _priv: (),
    }

    impl DenseCounter {
        /// Matrix side length this executable was compiled for.
        pub fn n(&self) -> usize {
            0
        }

        /// Always fails in stub builds.
        pub fn count(&self, _matrix: &[f32]) -> Result<u64> {
            Err(Error::Xla(UNAVAILABLE.into()))
        }
    }
}

#[cfg(feature = "xla-runtime")]
pub use real::{DenseCounter, Engine};
#[cfg(not(feature = "xla-runtime"))]
pub use stub::{DenseCounter, Engine};

#[cfg(test)]
mod tests {
    // Engine tests that need artifacts live in rust/tests/runtime_xla.rs
    // (integration), so `cargo test --lib` stays independent of `make
    // artifacts`. Here: client creation only.
    use super::*;

    #[test]
    #[cfg_attr(
        not(feature = "xla-runtime"),
        ignore = "needs the PJRT CPU client (build with --features xla-runtime)"
    )]
    fn cpu_client_comes_up() {
        let e = Engine::cpu().expect("PJRT CPU client");
        assert!(!e.platform().is_empty());
    }

    #[test]
    #[cfg_attr(
        not(feature = "xla-runtime"),
        ignore = "needs the PJRT CPU client (build with --features xla-runtime)"
    )]
    fn missing_artifact_is_reported() {
        let e = Engine::cpu().unwrap();
        let err = match e.load_dense_counter("/nonexistent/foo.hlo.txt", 8) {
            Ok(_) => panic!("expected error"),
            Err(err) => err,
        };
        match err {
            Error::Artifact(msg) => assert!(msg.contains("make artifacts"), "{msg}"),
            other => panic!("unexpected error {other:?}"),
        }
    }

    #[cfg(not(feature = "xla-runtime"))]
    #[test]
    fn stub_reports_runtime_unavailable() {
        match Engine::cpu() {
            Err(Error::Xla(msg)) => assert!(msg.contains("xla-runtime"), "{msg}"),
            other => panic!("stub Engine::cpu must fail with Error::Xla, got {other:?}"),
        }
    }
}
