//! AOT artifact discovery.
//!
//! `python/compile/aot.py` writes `artifacts/triangle_count_<N>.hlo.txt`
//! for a set of block sizes; this module finds them and picks the smallest
//! one that fits a requested dense-core size.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// One discovered artifact.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Artifact {
    pub path: PathBuf,
    /// Matrix side length `N`.
    pub n: usize,
}

/// Scan a directory for `triangle_count_<N>.hlo.txt` artifacts, sorted by `N`.
pub fn discover<P: AsRef<Path>>(dir: P) -> Result<Vec<Artifact>> {
    let dir = dir.as_ref();
    let mut out = Vec::new();
    if !dir.exists() {
        return Ok(out);
    }
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        let Some(name) = path.file_name().and_then(|s| s.to_str()) else { continue };
        if let Some(n) = parse_name(name) {
            out.push(Artifact { path, n });
        }
    }
    out.sort_by_key(|a| a.n);
    Ok(out)
}

fn parse_name(name: &str) -> Option<usize> {
    let rest = name.strip_prefix("triangle_count_")?;
    let digits = rest.strip_suffix(".hlo.txt")?;
    digits.parse().ok()
}

/// Pick the smallest artifact with `n ≥ want`.
pub fn pick(artifacts: &[Artifact], want: usize) -> Result<&Artifact> {
    artifacts
        .iter()
        .find(|a| a.n >= want)
        .ok_or_else(|| {
            Error::Artifact(format!(
                "no artifact fits core size {want} (have: {:?}) — run `make artifacts`",
                artifacts.iter().map(|a| a.n).collect::<Vec<_>>()
            ))
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_parsing() {
        assert_eq!(parse_name("triangle_count_256.hlo.txt"), Some(256));
        assert_eq!(parse_name("triangle_count_abc.hlo.txt"), None);
        assert_eq!(parse_name("other_256.hlo.txt"), None);
        assert_eq!(parse_name("triangle_count_256.bin"), None);
    }

    #[test]
    fn discover_and_pick() {
        let dir = std::env::temp_dir().join("tricount_artifact_test");
        std::fs::create_dir_all(&dir).unwrap();
        for n in [128, 512, 256] {
            std::fs::write(dir.join(format!("triangle_count_{n}.hlo.txt")), "x").unwrap();
        }
        std::fs::write(dir.join("README"), "not an artifact").unwrap();
        let arts = discover(&dir).unwrap();
        assert_eq!(arts.iter().map(|a| a.n).collect::<Vec<_>>(), vec![128, 256, 512]);
        assert_eq!(pick(&arts, 100).unwrap().n, 128);
        assert_eq!(pick(&arts, 129).unwrap().n, 256);
        assert_eq!(pick(&arts, 512).unwrap().n, 512);
        assert!(pick(&arts, 513).is_err());
    }

    #[test]
    fn missing_dir_is_empty_not_error() {
        let arts = discover("/definitely/not/here").unwrap();
        assert!(arts.is_empty());
    }
}
