//! Slow, independent triangle-count oracles for validating everything else.
//!
//! These implement *different* counting strategies from the production
//! kernel, so agreement between them and [`crate::seq::node_iterator`] is a
//! strong correctness signal rather than a tautology.

use crate::graph::csr::Csr;
use crate::{TriangleCount, VertexId};

/// `O(n³)` brute force over all triples — only for tiny graphs (n ≤ ~300).
pub fn triple_count(g: &Csr) -> TriangleCount {
    let n = g.num_nodes() as VertexId;
    let mut t = 0;
    for u in 0..n {
        for v in (u + 1)..n {
            if !g.has_edge(u, v) {
                continue;
            }
            for w in (v + 1)..n {
                if g.has_edge(u, w) && g.has_edge(v, w) {
                    t += 1;
                }
            }
        }
    }
    t
}

/// Edge-iterator algorithm: for each edge `(u, v)` count common neighbors in
/// the *full* (unoriented) adjacency; each triangle is seen at its 3 edges,
/// so divide by 3. `O(Σ_{(u,v)∈E} (d_u + d_v))`. Goes through the
/// [`crate::adj`] dispatch like every other driver, but on plain sorted
/// views (the CSR has no hub bitmaps), so the counting *strategy* stays
/// independent of the oriented Fig-1 kernel.
pub fn edge_iterator_count(g: &Csr) -> TriangleCount {
    use crate::adj::{self, NeighborView};
    let mut t3 = 0u64;
    for (u, v) in g.edges() {
        adj::intersect_count(
            NeighborView::sorted(g.neighbors(u)),
            NeighborView::sorted(g.neighbors(v)),
            &mut t3,
        );
    }
    debug_assert_eq!(t3 % 3, 0);
    t3 / 3
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::rng::Rng;
    use crate::graph::classic;

    #[test]
    fn oracles_agree_on_classics() {
        for g in [
            classic::complete(7),
            classic::cycle(9),
            classic::karate(),
            classic::petersen(),
            classic::wheel(6),
            classic::barbell_k4(),
        ] {
            assert_eq!(triple_count(&g), edge_iterator_count(&g));
        }
    }

    #[test]
    fn karate_is_45_by_both() {
        let g = classic::karate();
        assert_eq!(triple_count(&g), 45);
        assert_eq!(edge_iterator_count(&g), 45);
    }

    #[test]
    fn oracles_agree_on_random_graphs() {
        let mut rng = Rng::seeded(31);
        for i in 0..10 {
            let g = crate::gen::erdos_renyi::gnm(60, 200 + 20 * i, &mut rng);
            assert_eq!(triple_count(&g), edge_iterator_count(&g));
        }
    }
}
