//! The state-of-the-art sequential algorithm (paper Fig 1).
//!
//! With the degree-ordered oriented adjacency [`Oriented`], every triangle
//! `x₁ ≺ x₂ ≺ x₃` is counted exactly once via `|N_{x₁} ∩ N_{x₂}|`. This is
//! both the sequential baseline (denominator of every speedup figure) and
//! the per-node work kernel the parallel algorithms and the simulator share.

use crate::adj;
use crate::graph::ordering::Oriented;
use crate::{TriangleCount, VertexId};

/// Count all triangles. `O(Σ_v Σ_{u∈N_v} (d̂_v + d̂_u))`.
pub fn count(o: &Oriented) -> TriangleCount {
    let mut t = 0u64;
    for v in 0..o.num_nodes() as VertexId {
        count_node(o, v, &mut t);
    }
    t
}

/// Count triangles attributed to node `v` (paper Fig 1 lines 7-10):
/// triangles `(v, u, w)` with `v ≺ u ≺ w`, i.e. those whose *lowest-ordered*
/// vertex is `v`. Summing over all `v` counts each triangle exactly once.
#[inline]
pub fn count_node(o: &Oriented, v: VertexId, t: &mut TriangleCount) {
    let vv = o.view(v);
    for &u in vv.list() {
        adj::intersect_count(vv, o.view(u), t);
    }
}

/// Count triangles for a contiguous node range `[lo, hi)` — the §V task
/// kernel (`COUNTTRIANGLES⟨v,t⟩`, paper Fig 10).
pub fn count_range(o: &Oriented, lo: VertexId, hi: VertexId, t: &mut TriangleCount) {
    for v in lo..hi {
        count_node(o, v, t);
    }
}

/// The work of [`count_node`] in the paper's cost measure
/// `Σ_{u∈N_v} (d̂_v + d̂_u)` — the quantity the §IV-B/F estimators model.
pub fn node_work(o: &Oriented, v: VertexId) -> u64 {
    let nv = o.nbrs(v);
    let dv = nv.len() as u64;
    nv.iter().map(|&u| dv + o.effective_degree(u) as u64).sum()
}

/// The work [`count_node`] *actually* performs with the hybrid dispatch
/// (merge/gallop, bitmap probe or word-AND per pair) — what the simulators
/// charge as execution time. The gap between this and [`node_work`] is the
/// real estimation error that static balancing suffers and §V's dynamic
/// scheme absorbs; hub bitmaps *widen* that gap, because the estimators
/// still model merges where the dispatch runs much cheaper kernels.
pub fn node_work_true(o: &Oriented, v: VertexId) -> u64 {
    o.nbrs(v).iter().map(|&u| o.intersect_cost(v, u)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::classic;
    use crate::graph::ordering::Oriented;

    fn count_graph(g: &crate::graph::csr::Csr) -> u64 {
        count(&Oriented::from_graph(g))
    }

    #[test]
    fn closed_form_counts() {
        assert_eq!(count_graph(&classic::complete(3)), 1);
        assert_eq!(count_graph(&classic::complete(6)), 20); // C(6,3)
        assert_eq!(count_graph(&classic::complete(10)), 120);
        assert_eq!(count_graph(&classic::cycle(3)), 1);
        assert_eq!(count_graph(&classic::cycle(10)), 0);
        assert_eq!(count_graph(&classic::star(50)), 0);
        assert_eq!(count_graph(&classic::complete_bipartite(5, 7)), 0);
        assert_eq!(count_graph(&classic::petersen()), 0);
        assert_eq!(count_graph(&classic::wheel(9)), 9);
        assert_eq!(count_graph(&classic::barbell_k4()), 8);
    }

    #[test]
    fn karate_45() {
        assert_eq!(count_graph(&classic::karate()), classic::KARATE_TRIANGLES);
    }

    #[test]
    fn range_counts_compose() {
        let g = classic::karate();
        let o = Oriented::from_graph(&g);
        let mut a = 0;
        count_range(&o, 0, 17, &mut a);
        let mut b = 0;
        count_range(&o, 17, 34, &mut b);
        assert_eq!(a + b, classic::KARATE_TRIANGLES);
    }

    #[test]
    fn node_work_sums_match_definition() {
        let g = classic::karate();
        let o = Oriented::from_graph(&g);
        let total: u64 = (0..34u32).map(|v| node_work(&o, v)).sum();
        // Σ_v Σ_{u∈N_v}(d̂_v + d̂_u) — compute independently.
        let mut expect = 0u64;
        for v in 0..34u32 {
            for &u in o.nbrs(v) {
                expect += o.effective_degree(v) as u64 + o.effective_degree(u) as u64;
            }
        }
        assert_eq!(total, expect);
    }
}
