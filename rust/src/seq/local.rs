//! Per-node triangle counts `T_v`, clustering coefficients and transitivity —
//! the downstream analyses the paper's introduction motivates (§I): the
//! reason triangle counting matters is that these quantities are computed
//! from it.

use crate::adj;
use crate::graph::csr::Csr;
use crate::graph::ordering::Oriented;
use crate::VertexId;

/// Per-node triangle counts: `T_v` = number of triangles containing `v`.
/// Computed on the oriented graph; each triangle `(v,u,w)` found once and
/// credited to all three corners. `Σ_v T_v = 3·T`.
pub fn per_node_counts(o: &Oriented) -> Vec<u64> {
    let n = o.num_nodes();
    let mut t = vec![0u64; n];
    let mut ws = Vec::new();
    for v in 0..n as VertexId {
        let vv = o.view(v);
        for &u in vv.list() {
            ws.clear();
            adj::intersect_into(vv, o.view(u), &mut ws);
            for &w in &ws {
                t[v as usize] += 1;
                t[u as usize] += 1;
                t[w as usize] += 1;
            }
        }
    }
    t
}

/// Local clustering coefficient `c_v = 2·T_v / (d_v·(d_v−1))` (0 when d_v < 2).
pub fn clustering_coefficients(g: &Csr, tv: &[u64]) -> Vec<f64> {
    (0..g.num_nodes() as VertexId)
        .map(|v| {
            let d = g.degree(v) as u64;
            if d < 2 {
                0.0
            } else {
                2.0 * tv[v as usize] as f64 / (d * (d - 1)) as f64
            }
        })
        .collect()
}

/// Average local clustering coefficient (Watts–Strogatz).
pub fn avg_clustering(g: &Csr, tv: &[u64]) -> f64 {
    let c = clustering_coefficients(g, tv);
    if c.is_empty() {
        0.0
    } else {
        c.iter().sum::<f64>() / c.len() as f64
    }
}

/// Global transitivity `3·T / #wedges`, where
/// `#wedges = Σ_v d_v·(d_v−1)/2` (paths of length 2).
pub fn transitivity(g: &Csr, total_triangles: u64) -> f64 {
    let wedges: u64 = (0..g.num_nodes() as VertexId)
        .map(|v| {
            let d = g.degree(v) as u64;
            d * d.saturating_sub(1) / 2
        })
        .sum();
    if wedges == 0 {
        0.0
    } else {
        3.0 * total_triangles as f64 / wedges as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::classic;
    use crate::graph::ordering::Oriented;
    use crate::seq::node_iterator;

    #[test]
    fn per_node_sums_to_3t() {
        let g = classic::karate();
        let o = Oriented::from_graph(&g);
        let tv = per_node_counts(&o);
        assert_eq!(tv.iter().sum::<u64>(), 3 * classic::KARATE_TRIANGLES);
    }

    #[test]
    fn complete_graph_clustering_is_one() {
        let g = classic::complete(8);
        let o = Oriented::from_graph(&g);
        let tv = per_node_counts(&o);
        // Every node is in C(7,2) = 21 triangles.
        assert!(tv.iter().all(|&t| t == 21));
        assert!((avg_clustering(&g, &tv) - 1.0).abs() < 1e-12);
        let total = node_iterator::count(&o);
        assert!((transitivity(&g, total) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn triangle_free_graph_zero() {
        let g = classic::petersen();
        let o = Oriented::from_graph(&g);
        let tv = per_node_counts(&o);
        assert!(tv.iter().all(|&t| t == 0));
        assert_eq!(transitivity(&g, 0), 0.0);
    }

    #[test]
    fn wheel_hub_in_all_triangles() {
        let g = classic::wheel(7);
        let o = Oriented::from_graph(&g);
        let tv = per_node_counts(&o);
        assert_eq!(tv[0], 7); // hub touches every rim triangle
        assert!(tv[1..].iter().all(|&t| t == 2));
    }
}
