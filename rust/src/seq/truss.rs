//! k-truss decomposition — the paper's §I "triangular connectivity"
//! application [1], [2], built directly on the triangle kernel.
//!
//! The *support* of an edge is the number of triangles containing it; the
//! k-truss is the maximal subgraph where every edge has support ≥ k−2.
//! `trussness(e)` is the largest k whose truss contains `e`. The standard
//! peeling algorithm repeatedly removes the minimum-support edge and
//! decrements its triangles' other edges.

use std::collections::HashMap;

use crate::adj::NeighborView;
use crate::graph::csr::Csr;
use crate::graph::ordering::Oriented;
use crate::VertexId;

/// Per-edge support (triangle count through each edge), keyed by `(u, v)`
/// with `u < v`. O(Σ intersections) using the oriented kernel: each
/// triangle `(v,u,w)` with `v ≺ u ≺ w` (found once) increments its three
/// edges.
pub fn edge_support(g: &Csr) -> HashMap<(VertexId, VertexId), u32> {
    let o = Oriented::from_graph(g);
    let mut sup: HashMap<(VertexId, VertexId), u32> =
        g.edges().map(|e| (e, 0)).collect();
    let mut bump = |a: VertexId, b: VertexId| {
        let key = if a < b { (a, b) } else { (b, a) };
        *sup.get_mut(&key).expect("triangle edge must exist") += 1;
    };
    let mut ws = Vec::new();
    for v in 0..g.num_nodes() as VertexId {
        let vv = o.view(v);
        for &u in vv.list() {
            ws.clear();
            crate::adj::intersect_into(vv, o.view(u), &mut ws);
            for &w in &ws {
                bump(v, u);
                bump(v, w);
                bump(u, w);
            }
        }
    }
    sup
}

/// Full truss decomposition: returns `trussness(e)` for every edge —
/// the max k such that e survives in the k-truss. Edges in no triangle get
/// trussness 2. Peeling with a bucket queue, O(m^1.5)-ish overall.
pub fn truss_decomposition(g: &Csr) -> HashMap<(VertexId, VertexId), u32> {
    let mut sup = edge_support(g);
    // Adjacency sets for fast triangle lookup during peeling: live edges.
    let mut live: HashMap<VertexId, Vec<VertexId>> = HashMap::new();
    for (u, v) in g.edges() {
        live.entry(u).or_default().push(v);
        live.entry(v).or_default().push(u);
    }
    for l in live.values_mut() {
        l.sort_unstable();
    }

    // Bucket queue over supports.
    let max_sup = sup.values().copied().max().unwrap_or(0) as usize;
    let mut buckets: Vec<Vec<(VertexId, VertexId)>> = vec![Vec::new(); max_sup + 1];
    for (&e, &s) in &sup {
        buckets[s as usize].push(e);
    }
    let mut trussness: HashMap<(VertexId, VertexId), u32> = HashMap::new();
    let mut k = 2u32;
    let mut cur = 0usize;
    let mut remaining = sup.len();
    while remaining > 0 {
        // Find the lowest non-empty bucket (entries may be stale).
        while cur < buckets.len() && buckets[cur].is_empty() {
            cur += 1;
        }
        if cur >= buckets.len() {
            break;
        }
        let e = buckets[cur].pop().unwrap();
        let Some(&s) = sup.get(&e) else { continue }; // already peeled
        if (s as usize) != cur {
            // Stale bucket entry; reinsert at the true position.
            if (s as usize) < cur {
                cur = s as usize;
            }
            buckets[s as usize].push(e);
            continue;
        }
        k = k.max(s + 2);
        trussness.insert(e, k);
        sup.remove(&e);
        remaining -= 1;
        // Remove e=(a,b) from live adjacency and decrement common neighbors.
        let (a, b) = e;
        let common: Vec<VertexId> = {
            let la = live.get(&a).cloned().unwrap_or_default();
            let lb = live.get(&b).cloned().unwrap_or_default();
            let mut c = Vec::new();
            crate::adj::intersect_into(NeighborView::sorted(&la), NeighborView::sorted(&lb), &mut c);
            c
        };
        for w in common {
            for other in [(a, w), (b, w)] {
                let key = if other.0 < other.1 { other } else { (other.1, other.0) };
                if let Some(s2) = sup.get_mut(&key) {
                    if *s2 > 0 {
                        *s2 -= 1;
                        let ns = *s2 as usize;
                        buckets[ns].push(key);
                        if ns < cur {
                            cur = ns;
                        }
                    }
                }
            }
        }
        for (x, y) in [(a, b), (b, a)] {
            if let Some(l) = live.get_mut(&x) {
                if let Ok(p) = l.binary_search(&y) {
                    l.remove(p);
                }
            }
        }
    }
    trussness
}

/// Max k such that the k-truss is non-empty.
pub fn max_truss(g: &Csr) -> u32 {
    truss_decomposition(g).values().copied().max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::classic;

    #[test]
    fn support_sums_to_3t() {
        let g = classic::karate();
        let sup = edge_support(&g);
        let total: u64 = sup.values().map(|&s| s as u64).sum();
        assert_eq!(total, 3 * classic::KARATE_TRIANGLES);
    }

    #[test]
    fn complete_graph_truss() {
        // K_n is an n-truss: every edge has support n−2.
        let g = classic::complete(6);
        let sup = edge_support(&g);
        assert!(sup.values().all(|&s| s == 4));
        assert_eq!(max_truss(&g), 6);
    }

    #[test]
    fn triangle_free_graph_trussness_two() {
        let g = classic::petersen();
        let t = truss_decomposition(&g);
        assert!(t.values().all(|&k| k == 2));
        assert_eq!(max_truss(&g), 2);
    }

    #[test]
    fn wheel_truss() {
        // Wheel: every rim triangle shares the hub; rim edges have support
        // 1 (one triangle each... hub-adjacent edges have 2). Max truss = 3.
        let g = classic::wheel(6);
        assert_eq!(max_truss(&g), 3);
    }

    #[test]
    fn barbell_keeps_k4_truss() {
        // Two K4s sharing a vertex: every K4 edge has support 2 → 4-truss.
        let g = classic::barbell_k4();
        assert_eq!(max_truss(&g), 4);
    }

    #[test]
    fn karate_truss_is_5() {
        // Known: Zachary karate club's maximum truss is the 5-truss.
        let g = classic::karate();
        assert_eq!(max_truss(&g), 5);
    }

    #[test]
    fn peeling_monotone_vs_support() {
        // trussness(e) ≤ support(e) + 2 always.
        let g = classic::karate();
        let sup = edge_support(&g);
        let tr = truss_decomposition(&g);
        for (e, k) in &tr {
            assert!(*k <= sup[e] + 2, "edge {e:?}: trussness {k} support {}", sup[e]);
        }
    }
}
