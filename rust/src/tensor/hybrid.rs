//! Hybrid counter: XLA dense-core + sparse remainder (exact).
//!
//! `triangles(G) = dense(core) + Σ_{v∉core} count_node(v)` — the split is
//! exact because the ≺-top-K core is upward closed (see
//! [`crate::tensor::core_extract`]). The dense term executes the AOT
//! Pallas/JAX artifact through PJRT; the sparse term runs the Fig-1 kernel
//! on every non-core node.

use std::path::Path;

use crate::error::Result;
use crate::graph::ordering::Oriented;
use crate::runtime::artifact;
use crate::runtime::engine::Engine;
use crate::seq::node_iterator;
use crate::tensor::core_extract::{auto_core_size, DenseCore};
use crate::tensor::pack::{dense_count_reference, pack_core};
use crate::{TriangleCount, VertexId};

/// Breakdown of a hybrid count.
#[derive(Clone, Debug)]
pub struct HybridResult {
    pub triangles: TriangleCount,
    pub dense_triangles: TriangleCount,
    pub sparse_triangles: TriangleCount,
    /// Core size actually used.
    pub core_size: usize,
    /// Artifact block size (0 when the rust reference path was used).
    pub block: usize,
    /// Core-internal oriented edges offloaded to the tensor path.
    pub offloaded_edges: u64,
}

/// Count with an explicit core size using a loaded engine + artifact dir.
pub fn count_with_engine<P: AsRef<Path>>(
    o: &Oriented,
    engine: &Engine,
    artifacts_dir: P,
    core_size: usize,
) -> Result<HybridResult> {
    let arts = artifact::discover(&artifacts_dir)?;
    let sizes: Vec<usize> = arts.iter().map(|a| a.n).collect();
    let k = if core_size == 0 { auto_core_size(o.num_nodes(), &sizes) } else { core_size };
    let core = DenseCore::extract(o, k);
    let art = artifact::pick(&arts, core.len())?;
    let counter = engine.load_dense_counter(&art.path, art.n)?;
    let m = pack_core(o, &core, art.n);
    let dense = counter.count(&m)?;
    let sparse = sparse_remainder(o, &core);
    Ok(HybridResult {
        triangles: dense + sparse,
        dense_triangles: dense,
        sparse_triangles: sparse,
        core_size: core.len(),
        block: art.n,
        offloaded_edges: core.internal_edges(o),
    })
}

/// Pure-rust fallback (no artifacts / no PJRT): same split, dense term via
/// [`dense_count_reference`]. Used by tests to validate the split logic
/// independently of XLA, and by `--dense-core` runs before `make artifacts`.
pub fn count_reference(o: &Oriented, core_size: usize) -> HybridResult {
    let core = DenseCore::extract(o, core_size);
    let n = core.len();
    let m = pack_core(o, &core, n.max(1));
    let dense = dense_count_reference(&m, n.max(1));
    let sparse = sparse_remainder(o, &core);
    HybridResult {
        triangles: dense + sparse,
        dense_triangles: dense,
        sparse_triangles: sparse,
        core_size: n,
        block: 0,
        offloaded_edges: core.internal_edges(o),
    }
}

/// Σ over non-core nodes of the Fig-1 per-node count.
fn sparse_remainder(o: &Oriented, core: &DenseCore) -> TriangleCount {
    let mut t = 0;
    for v in 0..o.num_nodes() as VertexId {
        if !core.in_core[v as usize] {
            node_iterator::count_node(o, v, &mut t);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::classic;
    use crate::graph::ordering::Oriented;

    #[test]
    fn split_is_exact_for_all_core_sizes() {
        let g = classic::karate();
        let o = Oriented::from_graph(&g);
        for k in [0, 1, 5, 10, 20, 34] {
            let r = count_reference(&o, k);
            assert_eq!(
                r.triangles,
                classic::KARATE_TRIANGLES,
                "core={k}: dense={} sparse={}",
                r.dense_triangles,
                r.sparse_triangles
            );
        }
    }

    #[test]
    fn full_core_means_all_dense() {
        let g = classic::complete(9);
        let o = Oriented::from_graph(&g);
        let r = count_reference(&o, 9);
        assert_eq!(r.dense_triangles, 84);
        assert_eq!(r.sparse_triangles, 0);
    }

    #[test]
    fn zero_core_means_all_sparse() {
        let g = classic::complete(9);
        let o = Oriented::from_graph(&g);
        let r = count_reference(&o, 0);
        assert_eq!(r.dense_triangles, 0);
        assert_eq!(r.sparse_triangles, 84);
    }

    #[test]
    fn prop_split_exact_on_random_graphs() {
        crate::prop::quickcheck("hybrid split exact", |rng, _| {
            let g = crate::prop::arb_graph(rng, 50);
            let o = Oriented::from_graph(&g);
            let expect = node_iterator::count(&o);
            let k = rng.below_usize(g.num_nodes() + 1);
            let r = count_reference(&o, k);
            if r.triangles != expect {
                return Err(format!(
                    "core={k}: {} + {} != {expect}",
                    r.dense_triangles, r.sparse_triangles
                ));
            }
            Ok(())
        });
    }
}
