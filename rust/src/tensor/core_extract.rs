//! Dense-core extraction for the hybrid tensor path.
//!
//! The paper's theme is networks whose highest-degree nodes dominate cost;
//! in real social/web graphs those hubs form a dense core. We take the `K`
//! **`≺`-maximal** nodes (the top-K by the degree ordering). Because `≺`
//! orients every edge toward higher-ordered nodes, this core is *upward
//! closed*: if a triangle's `≺`-minimal vertex is in the core, all three
//! vertices are. That gives an exact split:
//!
//! > triangles(G) = dense-count(core) + Σ_{v ∉ core} count_node(v)
//!
//! where the first term runs on the XLA/PJRT artifact (MXU-shaped matmul)
//! and the second on the sparse kernel.

use crate::graph::ordering::Oriented;
use crate::VertexId;

/// The extracted core: global node ids of the `K` ≺-maximal nodes, ordered
/// ascending by `≺` (so index order = ≺ order within the core), plus a
/// membership bitmap.
#[derive(Clone, Debug)]
pub struct DenseCore {
    /// `members[a]` = global id of core node `a`; `a < b ⇒ members[a] ≺ members[b]`.
    pub members: Vec<VertexId>,
    /// `in_core[v]` for all global v.
    pub in_core: Vec<bool>,
    /// `index_of[v]` = position in `members` (undefined when !in_core).
    index_of: Vec<u32>,
}

impl DenseCore {
    /// Extract the `k` ≺-maximal nodes. O(n log n).
    pub fn extract(o: &Oriented, k: usize) -> DenseCore {
        let n = o.num_nodes();
        let k = k.min(n);
        let mut order: Vec<VertexId> = (0..n as VertexId).collect();
        // Sort by ≺ descending: (degree, id) descending.
        order.sort_unstable_by(|&a, &b| {
            (o.degree(b), b).cmp(&(o.degree(a), a))
        });
        let mut members: Vec<VertexId> = order[..k].to_vec();
        // Ascending ≺ within the core.
        members.sort_unstable_by(|&a, &b| (o.degree(a), a).cmp(&(o.degree(b), b)));
        let mut in_core = vec![false; n];
        let mut index_of = vec![0u32; n];
        for (i, &v) in members.iter().enumerate() {
            in_core[v as usize] = true;
            index_of[v as usize] = i as u32;
        }
        DenseCore { members, in_core, index_of }
    }

    /// Core size `K`.
    pub fn len(&self) -> usize {
        self.members.len()
    }

    /// True if the core is empty.
    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Core index of a member node.
    #[inline]
    pub fn index(&self, v: VertexId) -> Option<u32> {
        if self.in_core[v as usize] {
            Some(self.index_of[v as usize])
        } else {
            None
        }
    }

    /// Number of core-internal oriented edges (= dense matrix nnz).
    pub fn internal_edges(&self, o: &Oriented) -> u64 {
        self.members
            .iter()
            .map(|&v| o.nbrs(v).iter().filter(|&&u| self.in_core[u as usize]).count() as u64)
            .sum()
    }

    /// Density of the core's induced oriented subgraph (nnz / K²).
    pub fn density(&self, o: &Oriented) -> f64 {
        let k = self.len();
        if k == 0 {
            return 0.0;
        }
        self.internal_edges(o) as f64 / (k * k) as f64
    }
}

/// Pick an automatic core size: largest artifact block that the graph can
/// fill meaningfully (≤ n, and not bigger than the largest artifact).
pub fn auto_core_size(n_nodes: usize, artifact_sizes: &[usize]) -> usize {
    artifact_sizes
        .iter()
        .copied()
        .filter(|&s| s <= n_nodes)
        .max()
        .or_else(|| artifact_sizes.iter().copied().min())
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::classic;
    use crate::graph::ordering::Oriented;

    #[test]
    fn core_is_upward_closed_under_precedes() {
        let g = crate::gen::pa::preferential_attachment(
            500,
            8,
            &mut crate::gen::rng::Rng::seeded(10),
        );
        let o = Oriented::from_graph(&g);
        let core = DenseCore::extract(&o, 64);
        // Upward closure: for any member v, every u with v ≺ u is a member.
        for v in 0..500u32 {
            if core.in_core[v as usize] {
                for u in 0..500u32 {
                    if u != v && o.precedes(v, u) {
                        assert!(
                            core.in_core[u as usize],
                            "core not upward closed: {v} ∈ core, {v} ≺ {u} ∉ core"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn members_sorted_by_precedes() {
        let g = classic::karate();
        let o = Oriented::from_graph(&g);
        let core = DenseCore::extract(&o, 10);
        for w in core.members.windows(2) {
            assert!(o.precedes(w[0], w[1]));
        }
    }

    #[test]
    fn k_clamped_to_n() {
        let g = classic::complete(5);
        let o = Oriented::from_graph(&g);
        let core = DenseCore::extract(&o, 100);
        assert_eq!(core.len(), 5);
        assert_eq!(core.internal_edges(&o), 10);
    }

    #[test]
    fn index_roundtrip() {
        let g = classic::karate();
        let o = Oriented::from_graph(&g);
        let core = DenseCore::extract(&o, 8);
        for (i, &v) in core.members.iter().enumerate() {
            assert_eq!(core.index(v), Some(i as u32));
        }
        let non_member = (0..34u32).find(|&v| !core.in_core[v as usize]).unwrap();
        assert_eq!(core.index(non_member), None);
    }

    #[test]
    fn auto_size_picks_largest_fitting() {
        assert_eq!(auto_core_size(1000, &[128, 256, 512]), 512);
        assert_eq!(auto_core_size(300, &[128, 256, 512]), 256);
        assert_eq!(auto_core_size(50, &[128, 256]), 128); // fallback: smallest
        assert_eq!(auto_core_size(50, &[]), 0);
    }
}
