//! Pack a dense core's oriented adjacency into the padded f32 matrix the
//! XLA artifact consumes.

use crate::graph::ordering::Oriented;
use crate::tensor::core_extract::DenseCore;

/// Build the row-major `n×n` 0/1 f32 matrix `M` with `M[a][b] = 1` iff the
/// oriented edge `(members[a] → members[b])` exists. `n` is the artifact
/// block size; the core (`K ≤ n`) occupies the top-left `K×K` corner and
/// the padding stays zero, contributing nothing to `sum((M·M) ⊙ M)`.
pub fn pack_core(o: &Oriented, core: &DenseCore, n: usize) -> Vec<f32> {
    assert!(core.len() <= n, "core {} exceeds artifact block {n}", core.len());
    let mut m = vec![0f32; n * n];
    for (a, &v) in core.members.iter().enumerate() {
        for &u in o.nbrs(v) {
            if let Some(b) = core.index(u) {
                m[a * n + b as usize] = 1.0;
            }
        }
    }
    m
}

/// Reference dense count (pure rust): `Σ_{a,b} (M·M)[a,b] · M[a,b]` — used
/// to validate the XLA path end-to-end and as a fallback when artifacts are
/// absent. O(K·nnz) over the packed matrix.
pub fn dense_count_reference(m: &[f32], n: usize) -> u64 {
    let mut t = 0u64;
    for a in 0..n {
        for b in 0..n {
            if m[a * n + b] != 0.0 {
                // (M·M)[a,b] = Σ_c M[a,c]·M[c,b]
                let mut paths = 0u64;
                for c in 0..n {
                    if m[a * n + c] != 0.0 && m[c * n + b] != 0.0 {
                        paths += 1;
                    }
                }
                t += paths;
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::classic;
    use crate::graph::ordering::Oriented;
    use crate::tensor::core_extract::DenseCore;

    #[test]
    fn packed_complete_graph_counts_triangles() {
        let g = classic::complete(8);
        let o = Oriented::from_graph(&g);
        let core = DenseCore::extract(&o, 8);
        let m = pack_core(&o, &core, 16);
        assert_eq!(dense_count_reference(&m, 16), 56); // C(8,3)
    }

    #[test]
    fn padding_is_harmless() {
        let g = classic::karate();
        let o = Oriented::from_graph(&g);
        let core = DenseCore::extract(&o, 34);
        let a = dense_count_reference(&pack_core(&o, &core, 34), 34);
        let b = dense_count_reference(&pack_core(&o, &core, 64), 64);
        assert_eq!(a, b);
        assert_eq!(a, classic::KARATE_TRIANGLES); // whole graph as core
    }

    #[test]
    fn matrix_is_strictly_upper_triangular_in_core_order() {
        // members are ≺-ascending and edges point ≺-upward, so M must be
        // strictly upper triangular — no diagonal, no lower entries.
        let g = classic::karate();
        let o = Oriented::from_graph(&g);
        let core = DenseCore::extract(&o, 12);
        let n = 16;
        let m = pack_core(&o, &core, n);
        for a in 0..n {
            for b in 0..=a {
                assert_eq!(m[a * n + b], 0.0, "entry ({a},{b}) must be 0");
            }
        }
    }

    #[test]
    fn nnz_matches_internal_edges() {
        let g = crate::gen::pa::preferential_attachment(
            400,
            10,
            &mut crate::gen::rng::Rng::seeded(77),
        );
        let o = Oriented::from_graph(&g);
        let core = DenseCore::extract(&o, 50);
        let m = pack_core(&o, &core, 64);
        let nnz = m.iter().filter(|&&x| x != 0.0).count() as u64;
        assert_eq!(nnz, core.internal_edges(&o));
    }
}
