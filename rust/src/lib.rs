//! # tricount — Parallel Triangle Counting in Networks with Large Degrees
//!
//! A production-grade reproduction of Arifuzzaman, Khan & Marathe,
//! *"Parallel Algorithms for Counting Triangles in Networks with Large
//! Degrees"* (CS.DC 2014), built as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's distributed algorithms and every
//!   substrate they depend on: a CSR graph library with degree-ordered
//!   orientation, graph generators, an MPI-shaped message-passing runtime,
//!   partitioners (non-overlapping §IV / overlapping PATRIC), the
//!   space-efficient *surrogate* algorithm, the *direct* baseline, the
//!   PATRIC baseline, the §V dynamic load balancer, and a calibrated
//!   cluster cost-model simulator that regenerates the paper's scaling
//!   figures on a single machine.
//! * **`partition/owned`** — every §IV counting rank holds a fully
//!   materialized [`partition::owned::OwnedPartition`] (its own
//!   offsets/targets slice, per-partition hub index, O(P)
//!   [`partition::balance::OwnerTable`]) instead of a view into the shared
//!   graph — the rank closures cannot capture `Arc<Oriented>`, so the
//!   space-efficiency claim is a type-level invariant. Measured per-rank
//!   resident bytes are gated equal to the `PartitionSize`/`OverlapSize`
//!   predictions, and `tricount count --mem-budget` sizes the smallest P
//!   that fits a byte budget (DESIGN.md §9).
//! * **`adj/`** — the hybrid hub-bitmap adjacency layer: hub rows (oriented
//!   out-degree ≥ an auto-tuned threshold) carry a packed bitmap
//!   ([`adj::bitmap::BitmapRow`]) beside their sorted slice, and every
//!   counting path intersects through the [`adj::view::NeighborView`] dispatch
//!   (list×list merge/gallop with a SWAR u64-blocked tier on balanced
//!   pairs, list×bitmap probe, bitmap×bitmap word-AND) — see DESIGN.md §7
//!   for the representation rule and kernel matrix, §12 for the SWAR
//!   dispatch guard.
//! * **`algo/tile2d` + `comm/coalesce`** — the 2D tile-partitioned driver
//!   (DESIGN.md §14): an r×c process grid over the oriented adjacency
//!   matrix ([`partition::tile2d`]), a three-phase row/column-broadcast
//!   exchange whose pieces travel as per-destination coalescing frames
//!   ([`comm::coalesce`], flush-watermark bounded, frames vs logical
//!   records audited in [`comm::metrics::CommMetrics`]), O(m/√P) per-rank
//!   traffic vs the 1D drivers' O(m) — gated measured == predicted
//!   against `sim::space_efficient::simulate_tile2d`, compared across all
//!   four §IV drivers by `tricount bench-comm` (`BENCH_comm.json`).
//! * **`stream/`** — incremental parallel counting over edge-update
//!   batches: an [`stream::overlay::AdjDelta`] mutable overlay on the
//!   immutable CSR, an exact per-batch Δ counter going through the `adj/`
//!   dispatch (with per-batch hub bitmap caching),
//!   a parallel driver sharding ops by min-`≺`-endpoint ownership
//!   over `comm::threads`, sliding-window expiry, periodic compaction back
//!   into a fresh CSR, and a cost-model throughput projector in
//!   `sim::streaming`. See `DESIGN.md` §6 for the lifecycle.
//! * **`comm/tcp`** — the socket fabric (DESIGN.md §15): the same
//!   [`comm::Transport`] contract carried over real TCP streams with
//!   length-prefixed binary frames ([`comm::transport::Wire`]), a rank-0
//!   rendezvous (magic + wire version + job id handshake, validated
//!   roster, broadcast peer table), rank-0-coordinated collectives on the
//!   same streams, and an end-of-run result allgather so every process
//!   returns the identical rank-ordered `(result, metrics)` vector.
//!   `tricount launch --procs P -- count …` runs a multi-*process*
//!   cluster on loopback; `tricount worker` joins one rank by hand.
//!   Declared payload bytes stay the accounting truth on every fabric;
//!   TCP framing is reported separately
//!   (`CommMetrics::wire_overhead_bytes`).
//! * **`testkit/`** — deterministic cluster simulation behind the
//!   [`comm::Transport`] trait: `Cluster` runs every protocol unchanged
//!   over either the production channel fabric or a seeded virtual fabric
//!   ([`testkit::sim`]) with virtual time, adversarial delivery schedules,
//!   injectable faults (rank death, message loss, stragglers) and an
//!   FNV trace hash with *same seed ⇒ identical trace* replay semantics.
//!   [`testkit::conformance`] runs every counting path — the three §IV
//!   drivers, both §V drivers, and `stream/` — against the
//!   `seq::node_iterator` oracle across workload × P × schedule matrices
//!   (`tricount conformance`, gated in CI; DESIGN.md §10).
//! * **`par/` + the radix build** — the multithreaded preprocessing
//!   pipeline: [`graph::builder`] constructs the CSR with an O(m)
//!   two-pass counting/radix scatter (no comparison sort, no per-row
//!   re-sort), text ingestion splits the document at newline boundaries
//!   and scans chunks in parallel ([`graph::io::parse_edge_list_bytes`]),
//!   and the whole parse → build → relabel → orient → hub-index
//!   chain fans out over `--build-threads` scoped threads
//!   ([`par::BuildThreads`], clamped to the host's cores by
//!   [`par::clamp_to_host`]) with **bit-identical output at every thread
//!   count** (disjoint per-`(thread, bucket)` scatter regions; DESIGN.md
//!   §8). For repeated loads, `tricount convert` re-encodes any workload
//!   as a zero-parse `.tcg` binary ([`graph::io::write_tcg`] /
//!   [`graph::io::read_tcg`]; DESIGN.md §12). [`pipeline`]
//!   (`tricount bench-pipeline`) times the stages against the retained
//!   comparison-sort baseline and writes `BENCH_pipeline.json`, the
//!   repo's recorded perf baseline.
//! * **`ft/`** — fault-tolerant execution (DESIGN.md §13): every counting
//!   path runs under [`ft::supervisor::supervise`], which installs a
//!   shared [`ft::checkpoint::CheckpointStore`] (per-rank partial sums +
//!   acked progress units at phase boundaries), detects rank death through
//!   the transport's liveness board / the virtual fabric's dead mask, and
//!   applies the `--on-fault` policy: `fail` propagates, `recover`
//!   re-executes only the un-acked remainder on the survivors (exact
//!   count, §IV re-extraction or §V task stealing per path), `degrade`
//!   answers from checkpoints with a stated `lower ≤ T ≤ upper` confidence
//!   bound. Transport-level hardening (deadline-based `recv_deadline`,
//!   bounded deterministic retries, heartbeat liveness distinguishing slow
//!   from dead) lives in [`comm::transport`] / [`comm::threads`] and is
//!   answered in *virtual time* on the testkit fabric, so every fault
//!   schedule replays to an identical trace hash.
//! * **`obs/`** — the observability layer: per-rank phase-span timelines
//!   ([`obs::span`], ring-buffered, wall-clock on the channel fabric and
//!   *virtual-time* on the testkit fabric so adversarial schedules replay
//!   to bit-identical timelines), a unified schema-versioned metrics
//!   registry ([`obs::registry`]: comm counters + per-rank kernel mix +
//!   stream batches + pipeline phases in one JSON snapshot),
//!   Chrome/Perfetto trace export ([`obs::export`], `--trace-out` on
//!   `count`/`stream`/`bench-pipeline`/`conformance`), and the Fig-13
//!   idle/imbalance breakdown ([`obs::report`], `tricount obs-report`).
//!   See DESIGN.md §11.
//! * **L2/L1 (python/, build-time only)** — a blocked dense triangle-count
//!   formulated for the MXU (`sum((L@L) ⊙ L)`) as a Pallas kernel inside a
//!   JAX model, AOT-lowered to HLO text.
//! * **runtime** — a PJRT CPU client (the `xla` crate) that loads the AOT
//!   artifacts and executes them from the Rust hot path; `tensor` uses it
//!   for hybrid dense-core counting.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index, and `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! ## Quickstart
//!
//! ```
//! use tricount::gen::{self, rng::Rng};
//! use tricount::graph::ordering::Oriented;
//! use tricount::seq;
//!
//! let g = gen::pa::preferential_attachment(10_000, 8, &mut Rng::seeded(7));
//! let o = Oriented::from_graph(&g);
//! let t = seq::node_iterator::count(&o);
//! assert_eq!(t, seq::naive::edge_iterator_count(&g));
//! ```

pub mod config;
pub mod error;
pub mod par;

pub mod graph {
    pub mod builder;
    pub mod classic;
    pub mod csr;
    pub mod io;
    pub mod ordering;
    pub mod relabel;
    pub mod stats;
}

pub mod gen {
    pub mod erdos_renyi;
    pub mod geometric;
    pub mod pa;
    pub mod presets;
    pub mod rmat;
    pub mod rng;
}

pub mod intersect;

pub mod adj {
    pub mod bitmap;
    pub mod hub;
    pub mod stats;
    pub mod view;
    pub use hub::{HubStats, HubThreshold};
    pub use view::{intersect_cost, intersect_count, intersect_into, NeighborView};
}

pub mod approx;

pub mod baseline {
    pub mod mapreduce;
}

pub mod seq {
    pub mod local;
    pub mod naive;
    pub mod node_iterator;
    pub mod truss;
}

pub mod comm {
    pub mod coalesce;
    pub mod metrics;
    pub mod tcp;
    pub mod threads;
    pub mod transport;
    pub use threads::{Cluster, Comm};
    pub use transport::{Payload, Transport};
}

pub mod obs {
    pub mod export;
    pub mod registry;
    pub mod report;
    pub mod span;
    pub use registry::{MetricsRegistry, SCHEMA_VERSION};
    pub use span::{ClockDomain, Span, SpanLog, SpanPhase, SpanRecorder};
}

pub mod testkit {
    pub mod conformance;
    pub mod sched;
    pub mod sim;
    pub mod trace;
    pub use sched::{FaultPlan, SchedulePolicy, SimConfig};
    pub use sim::Fabric;
    pub use trace::TraceReport;
}

pub mod ft {
    pub mod checkpoint;
    pub mod supervisor;
    pub use checkpoint::{CheckpointStore, RankMap};
    pub use supervisor::{supervise, Bound, FaultPolicy, Job, RecoveryReport, SupervisedRun};
}

pub mod partition {
    pub mod balance;
    pub mod cost;
    pub mod nonoverlap;
    pub mod overlap;
    pub mod owned;
    pub mod tile2d;
}

pub mod algo {
    pub mod direct;
    pub mod driver;
    pub mod dynamic_lb;
    pub mod local_counts;
    pub mod patric;
    pub mod surrogate;
    pub mod tasks;
    pub mod tile2d;
    pub use driver::RunResult;
}

pub mod sim {
    pub mod calibrate;
    pub mod dynamic;
    pub mod model;
    pub mod space_efficient;
    pub mod streaming;
    pub mod work;
}

pub mod stream {
    pub mod batch;
    pub mod compact;
    pub mod delta;
    pub mod overlay;
    pub mod parallel;
    pub mod state;
    pub mod window;
    pub mod workload;
}

pub mod runtime {
    pub mod artifact;
    pub mod engine;
}

pub mod tensor {
    pub mod core_extract;
    pub mod hybrid;
    pub mod pack;
}

pub mod exp;

pub mod pipeline;

pub mod prop;

/// Node identifier. Graphs up to 4B nodes; edge counts use `u64`/`usize`.
pub type VertexId = u32;

/// Triangle counts can exceed `u32` on modest graphs (LiveJournal: 286M;
/// Twitter: 34.8B) — always 64-bit.
pub type TriangleCount = u64;
