//! Minimal in-crate property-testing harness.
//!
//! The container is offline and `proptest` is not in the vendored crate
//! set, so this module provides the small slice of it the test suite needs:
//! run a property over many seeded random cases, and on failure report the
//! *seed and case index* so the exact input is reproducible, then attempt a
//! simple size-shrink pass for graph-shaped inputs.

use crate::gen::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    /// Number of random cases.
    pub cases: u32,
    /// Base seed; case `i` uses `seed ^ i`-derived stream.
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        // Fixed default seed: CI-stable. Override with TRICOUNT_PROP_SEED.
        let seed = std::env::var("TRICOUNT_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        let cases = std::env::var("TRICOUNT_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64);
        PropConfig { cases, seed }
    }
}

/// Run `prop(rng, case_index)` for each case; the closure returns
/// `Err(message)` to fail. Panics with seed + case info on failure.
pub fn check<F>(name: &str, cfg: PropConfig, mut prop: F)
where
    F: FnMut(&mut Rng, u32) -> Result<(), String>,
{
    for i in 0..cfg.cases {
        let mut rng = Rng::seeded(cfg.seed.wrapping_add(0x9E3779B97F4A7C15u64.wrapping_mul(i as u64 + 1)));
        if let Err(msg) = prop(&mut rng, i) {
            panic!(
                "property `{name}` failed at case {i}/{} (seed={:#x}): {msg}\n\
                 reproduce with TRICOUNT_PROP_SEED={} and this case index",
                cfg.cases, cfg.seed, cfg.seed
            );
        }
    }
}

/// Shorthand with default config.
pub fn quickcheck<F>(name: &str, prop: F)
where
    F: FnMut(&mut Rng, u32) -> Result<(), String>,
{
    check(name, PropConfig::default(), prop);
}

/// Draw a random small graph for property tests: up to `max_n` nodes and a
/// density regime chosen per-case (sparse / medium / skewed star-heavy).
pub fn arb_graph(rng: &mut Rng, max_n: usize) -> crate::graph::csr::Csr {
    let n = 2 + rng.below_usize(max_n.max(3) - 2);
    let style = rng.below(4);
    let m_max = n * (n - 1) / 2;
    match style {
        0 => {
            // sparse
            let m = rng.below_usize(m_max.min(2 * n) + 1);
            crate::gen::erdos_renyi::gnm(n, m, rng)
        }
        1 => {
            // denser
            let m = rng.below_usize(m_max / 2 + 1);
            crate::gen::erdos_renyi::gnm(n, m, rng)
        }
        2 => {
            // skewed: star spine + random extras
            let mut edges: Vec<(u32, u32)> = (1..n as u32).map(|v| (0, v)).collect();
            for _ in 0..rng.below_usize(2 * n + 1) {
                let u = rng.below(n as u64) as u32;
                let v = rng.below(n as u64) as u32;
                edges.push((u, v));
            }
            crate::graph::builder::from_edge_list(n, edges).unwrap()
        }
        _ => {
            // preferential attachment when big enough
            if n > 6 {
                crate::gen::pa::preferential_attachment(n, 4.min((n - 2) & !1).max(2), rng)
            } else {
                crate::gen::erdos_renyi::gnm(n, m_max.min(3), rng)
            }
        }
    }
}

/// Draw a random sequence of edge-update batches over node set `0..n`:
/// each update is an insert or delete of a uniformly random pair, so
/// duplicates, self-loops, no-ops and insert/delete churn all occur —
/// exactly the input the stream normalizer must absorb.
pub fn arb_update_batches(
    rng: &mut Rng,
    n: usize,
    max_batches: usize,
    max_batch_len: usize,
) -> Vec<crate::stream::batch::Batch> {
    use crate::stream::batch::{Batch, EdgeUpdate};
    let batches = 1 + rng.below_usize(max_batches.max(1));
    (0..batches)
        .map(|_| {
            let len = rng.below_usize(max_batch_len.max(1) + 1);
            Batch::new(
                (0..len)
                    .map(|_| {
                        let u = rng.below(n as u64) as u32;
                        let v = rng.below(n as u64) as u32;
                        if rng.chance(0.4) {
                            EdgeUpdate::delete(u, v)
                        } else {
                            EdgeUpdate::insert(u, v)
                        }
                    })
                    .collect(),
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", PropConfig { cases: 10, seed: 1 }, |rng, _| {
            let x = rng.below(100);
            if x < 100 { Ok(()) } else { Err("impossible".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property `failing`")]
    fn check_reports_failure() {
        check("failing", PropConfig { cases: 5, seed: 2 }, |_, i| {
            if i < 3 { Ok(()) } else { Err("boom".into()) }
        });
    }

    #[test]
    fn arb_graph_always_valid() {
        quickcheck("arb_graph valid", |rng, _| {
            let g = arb_graph(rng, 40);
            g.validate().map_err(|e| format!("invalid: {e}"))
        });
    }
}
