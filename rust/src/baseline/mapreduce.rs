//! MapReduce 2-path baseline analysis (Suri & Vassilvitskii [17]).
//!
//! The paper's §I motivation: "for networks with larger degrees,
//! Map-Reduce based algorithms generate prohibitively large intermediate
//! data" — the MR-NodeIterator emits every 2-path (wedge) centered at each
//! node as intermediate key-value data, which is `Σ_v d_v(d_v−1)/2`
//! records: quadratic in degree, catastrophic under skew.
//!
//! This module *measures* that blow-up exactly (record and byte counts for
//! the shuffle phase, plus the improved ordered-emit variant) so the
//! motivation claim can be validated against the MPI algorithms' measured
//! message volumes (`tricount exp` / `examples/skewed_degrees`).

use crate::graph::csr::Csr;
use crate::graph::ordering::Oriented;
use crate::VertexId;

/// Intermediate-data accounting for the MapReduce 2-path algorithms.
#[derive(Clone, Debug, PartialEq)]
pub struct MrShuffleStats {
    /// MR-NodeIterator: wedges emitted = Σ_v C(d_v, 2).
    pub wedges_all: u64,
    /// MR-NodeIterator++ (degree-ordered emit): Σ_v C(d̂_v, 2) — the
    /// "last reducer" fix, still quadratic in effective degree.
    pub wedges_ordered: u64,
    /// Plus one record per edge for the closure-check join.
    pub edge_records: u64,
    /// Largest single reducer's input in the ordered variant (the "curse
    /// of the last reducer": the max-degree node's wedge list).
    pub max_reducer_records: u64,
}

impl MrShuffleStats {
    /// Shuffle bytes for the ordered variant at 12 B per wedge record
    /// (key + two endpoints) and 8 B per edge record.
    pub fn shuffle_bytes(&self) -> u64 {
        self.wedges_ordered * 12 + self.edge_records * 8
    }
}

/// Compute the exact shuffle volumes for a graph. O(n + m).
pub fn shuffle_stats(g: &Csr) -> MrShuffleStats {
    let o = Oriented::from_graph(g);
    let mut wedges_all = 0u64;
    let mut wedges_ordered = 0u64;
    let mut max_reducer = 0u64;
    for v in 0..g.num_nodes() as VertexId {
        let d = g.degree(v) as u64;
        wedges_all += d * d.saturating_sub(1) / 2;
        let dh = o.effective_degree(v) as u64;
        let w = dh * dh.saturating_sub(1) / 2;
        wedges_ordered += w;
        max_reducer = max_reducer.max(w);
    }
    MrShuffleStats {
        wedges_all,
        wedges_ordered,
        edge_records: g.num_edges(),
        max_reducer_records: max_reducer,
    }
}

/// Blow-up factor of MR intermediate data vs the graph itself
/// (records / edges) — the paper's "prohibitively large" quantity.
pub fn blowup_factor(g: &Csr) -> f64 {
    let s = shuffle_stats(g);
    s.wedges_all as f64 / g.num_edges().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::rng::Rng;
    use crate::graph::classic;

    #[test]
    fn star_blowup_is_quadratic() {
        // Star K_{1,k}: hub emits C(k,2) wedges from k edges.
        let g = classic::star(100);
        let s = shuffle_stats(&g);
        assert_eq!(s.wedges_all, 100 * 99 / 2);
        assert!(blowup_factor(&g) > 49.0);
    }

    #[test]
    fn ordered_emit_is_smaller() {
        let g = crate::gen::pa::preferential_attachment(3000, 20, &mut Rng::seeded(9));
        let s = shuffle_stats(&g);
        assert!(
            s.wedges_ordered < s.wedges_all,
            "ordering must shrink wedges: {} vs {}",
            s.wedges_ordered,
            s.wedges_all
        );
    }

    #[test]
    fn skew_drives_blowup() {
        // Same edge budget: skewed PA vs near-regular contact network —
        // PA's MR blow-up must be far larger (the paper's core claim).
        let pa = crate::gen::pa::preferential_attachment(5000, 20, &mut Rng::seeded(10));
        let reg = crate::gen::geometric::miami_like(5000, 20, &mut Rng::seeded(11));
        assert!(
            blowup_factor(&pa) > 2.0 * blowup_factor(&reg),
            "pa {} vs regular {}",
            blowup_factor(&pa),
            blowup_factor(&reg)
        );
    }

    #[test]
    fn wedges_match_local_module() {
        // Σ wedges must equal the transitivity denominator.
        let g = classic::karate();
        let s = shuffle_stats(&g);
        let wedges: u64 = (0..34u32)
            .map(|v| {
                let d = g.degree(v) as u64;
                d * d.saturating_sub(1) / 2
            })
            .sum();
        assert_eq!(s.wedges_all, wedges);
    }

    #[test]
    fn intermediate_volume_matches_closed_form_on_hub_heavy_pa() {
        // The paper's "prohibitively large intermediate data" claim, made
        // checkable: MR-NodeIterator's shuffle volume has the closed form
        //   Σ_v C(d_v, 2) = (Σ_v d_v² − Σ_v d_v) / 2 = Σd²/2 − m,
        // so the measured wedge count must equal the degree-square sum
        // exactly, and on a hub-heavy PA graph the quadratic term must
        // dwarf the edge set itself.
        let g = crate::gen::pa::preferential_attachment(4000, 24, &mut Rng::seeded(31));
        let s = shuffle_stats(&g);
        let sum_d2: u64 = (0..g.num_nodes() as crate::VertexId)
            .map(|v| {
                let d = g.degree(v) as u64;
                d * d
            })
            .sum();
        let m = g.num_edges();
        assert_eq!(s.wedges_all, sum_d2 / 2 - m, "closed form Σd²/2 − m");
        assert_eq!(s.edge_records, m);
        // Independent re-derivation of the ordered-emit volume and the
        // record-size constants (12 B/wedge + 8 B/edge) from the oriented
        // effective degrees — pins the formula, not just its own output.
        let o = Oriented::from_graph(&g);
        let sum_ordered: u64 = (0..g.num_nodes() as crate::VertexId)
            .map(|v| {
                let dh = o.effective_degree(v) as u64;
                dh * dh.saturating_sub(1) / 2
            })
            .sum();
        assert_eq!(s.wedges_ordered, sum_ordered);
        assert_eq!(s.shuffle_bytes(), sum_ordered * 12 + m * 8);
        let blowup = blowup_factor(&g);
        assert!(
            (blowup - (sum_d2 as f64 / 2.0 - m as f64) / m as f64).abs() < 1e-9,
            "blow-up factor must be the closed form"
        );
        // Hub-heaviness: the intermediate data is an order of magnitude
        // beyond the input, and the single largest hub alone out-emits
        // its own edge budget by a wide margin.
        assert!(s.wedges_all > 10 * m, "wedges {} vs m {m}", s.wedges_all);
        let dmax = g.max_degree() as u64;
        assert!(dmax * (dmax - 1) / 2 > 20 * dmax, "dmax {dmax} is not hub-heavy");
    }

    #[test]
    fn mr_shuffle_exceeds_mpi_messages() {
        // The motivating comparison: MR shuffle bytes ≫ surrogate bytes.
        use crate::partition::balance::balanced_ranges;
        use crate::partition::cost::{cost_vector, prefix_sums};
        let g = crate::gen::pa::preferential_attachment(2000, 30, &mut Rng::seeded(12));
        let o = Oriented::from_graph(&g);
        let prefix = prefix_sums(&cost_vector(&o, crate::config::CostFn::SurrogateNew));
        let ranges = balanced_ranges(&prefix, 8);
        let r = crate::algo::surrogate::run(&o, &ranges, crate::adj::HubThreshold::Auto).unwrap();
        let mpi_bytes = r.metrics.totals().bytes_sent;
        let mr_bytes = shuffle_stats(&g).shuffle_bytes();
        assert!(
            mr_bytes > 2 * mpi_bytes,
            "MR {mr_bytes} bytes vs MPI surrogate {mpi_bytes} bytes"
        );
    }
}
