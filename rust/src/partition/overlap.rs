//! PATRIC's overlapping partitions [21] — the baseline whose blow-up on
//! large-degree networks motivates this paper (§III-B, Table II, Fig 7).
//!
//! Partition `G_i` holds the oriented lists `N_u` for every node in
//! `V_i = V_i^c ∪ ⋃_{v∈V_i^c} 𝒩_v` — the *core* plus every **full-
//! neighborhood** contact of a core node (PATRIC loads complete
//! neighborhoods and orients inside the partition). On a graph with
//! average degree `d̄` the overlap can be `d̄`× the core, and with an
//! `O(n)`-degree hub the partition containing it *is* the whole network —
//! exactly the §III worst case this paper's non-overlapping scheme avoids.

use std::ops::Range;

use crate::graph::csr::Csr;
use crate::graph::ordering::Oriented;

/// Size accounting for one PATRIC overlapping partition.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct OverlapSize {
    /// Core nodes `|V_i^c|`.
    pub core_nodes: u64,
    /// Core + overlap nodes `|V_i|`.
    pub all_nodes: u64,
    /// Oriented edges stored: `Σ_{u ∈ V_i} |N_u|` (core **and** overlap
    /// lists — that is the overlap scheme's cost).
    pub edges: u64,
}

impl OverlapSize {
    /// Bytes: one 8-byte offset per stored row (+1), one 4-byte target per
    /// edge, plus the 4-byte sorted row table mapping member ids to rows —
    /// exactly the arrays [`crate::partition::owned::extract_overlapping`]
    /// materializes, so the PATRIC comparison is measured like-for-like
    /// with the non-overlapping scheme (whose core rows are an id-interval
    /// and need no row table).
    pub fn bytes(&self) -> u64 {
        (self.all_nodes + 1) * 8 + self.edges * 4 + self.all_nodes * 4
    }

    /// Megabytes.
    pub fn mb(&self) -> f64 {
        self.bytes() as f64 / (1024.0 * 1024.0)
    }
}

/// Compute [`OverlapSize`] for every core range. Uses a stamp array; total
/// `O(n + m + Σ_i overlap_i)` (the last term is the quantity being measured
/// and can approach `P·m` on dense graphs — measurement cost mirrors the
/// scheme's own blow-up, which is the point). Takes both the unoriented
/// graph (full neighborhoods define the overlap membership) and the
/// orientation (the stored lists are `N_u`).
pub fn overlap_sizes(g: &Csr, o: &Oriented, ranges: &[Range<u32>]) -> Vec<OverlapSize> {
    let n = o.num_nodes();
    debug_assert_eq!(g.num_nodes(), n);
    let mut stamp = vec![u32::MAX; n];
    ranges
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let i = i as u32;
            let mut members: Vec<u32> = Vec::new();
            for v in r.clone() {
                if stamp[v as usize] != i {
                    stamp[v as usize] = i;
                    members.push(v);
                }
                for &u in g.neighbors(v) {
                    if stamp[u as usize] != i {
                        stamp[u as usize] = i;
                        members.push(u);
                    }
                }
            }
            let edges: u64 = members.iter().map(|&u| o.effective_degree(u) as u64).sum();
            OverlapSize {
                core_nodes: (r.end - r.start) as u64,
                all_nodes: members.len() as u64,
                edges,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CostFn;
    use crate::graph::classic;
    use crate::graph::ordering::Oriented;
    use crate::partition::balance::balanced_ranges;
    use crate::partition::cost::{cost_vector, prefix_sums};
    use crate::partition::nonoverlap::partition_sizes;

    #[test]
    fn overlap_superset_of_nonoverlap() {
        let g = crate::gen::pa::preferential_attachment(
            3000,
            20,
            &mut crate::gen::rng::Rng::seeded(17),
        );
        let o = Oriented::from_graph(&g);
        let prefix = prefix_sums(&cost_vector(&o, CostFn::PatricBest));
        let ranges = balanced_ranges(&prefix, 8);
        let non = partition_sizes(&o, &ranges);
        let over = overlap_sizes(&g, &o, &ranges);
        for (a, b) in non.iter().zip(&over) {
            assert!(b.edges >= a.edges, "overlap must store at least the core lists");
            assert!(b.all_nodes >= a.all_nodes, "overlap references a superset");
        }
    }

    #[test]
    fn hub_partition_approaches_whole_graph() {
        // §III's worst case: with high-degree nodes, an overlapping
        // partition's stored edges approach the whole graph. In a clique,
        // every core node references every other node, so each partition
        // stores (almost) every oriented list — P× duplication.
        let g = classic::complete(60);
        let o = Oriented::from_graph(&g);
        let ranges = vec![0..20u32, 20..40u32, 40..60u32];
        let over = overlap_sizes(&g, &o, &ranges);
        // Partition 0's core lists reference all 60 nodes.
        assert_eq!(over[0].all_nodes, 60);
        // …so it stores (nearly) all m oriented edges, not m/3.
        assert_eq!(over[0].edges, o.num_edges());
        let total: u64 = over.iter().map(|s| s.edges).sum();
        assert!(
            total == 3 * o.num_edges(),
            "overlap must duplicate edges heavily: {total} vs m={}",
            o.num_edges()
        );
    }

    #[test]
    fn single_partition_equals_graph() {
        let g = classic::karate();
        let o = Oriented::from_graph(&g);
        let over = overlap_sizes(&g, &o, &[0..34u32]);
        assert_eq!(over[0].edges, o.num_edges());
    }

    #[test]
    fn hub_pulls_in_whole_network() {
        // §III: a node with degree n−1 makes its partition the whole graph.
        let g = classic::star(199);
        let o = Oriented::from_graph(&g);
        // Partition 0 holds the hub (node 0).
        let over = overlap_sizes(&g, &o, &[0..100u32, 100..200u32]);
        assert_eq!(over[0].all_nodes, 200, "hub partition must reference all nodes");
        assert_eq!(over[0].edges, o.num_edges(), "hub partition stores the whole network");
    }
}
