//! Balanced consecutive-range partitioning of the node set (§IV-B).
//!
//! Given a per-node cost vector, split `V` into `P` ranges of *consecutive
//! node ids* whose cost sums are as equal as possible — the paper reuses
//! PATRIC's parallel prefix-sum scheme; on one machine the same boundaries
//! come from a sequential prefix-sum + binary search in `O(n + P log n)`.
//! Consecutiveness is load-bearing: the surrogate algorithm's `LastProc`
//! message-elimination trick requires each partition to be an id-interval.

use crate::partition::cost::range_cost;
use crate::VertexId;
use std::ops::Range;
use std::sync::Arc;

/// Split `[0, n)` into `p` consecutive ranges balancing `prefix` costs:
/// boundary `k` is the smallest index whose cumulative cost reaches
/// `k/p · total`. Ranges may be empty when `p > n` or costs are lumpy.
pub fn balanced_ranges(prefix: &[u64], p: usize) -> Vec<Range<u32>> {
    assert!(p >= 1);
    let n = prefix.len() - 1;
    let total = prefix[n];
    let mut bounds = Vec::with_capacity(p + 1);
    bounds.push(0u32);
    for k in 1..p {
        // Smallest i with prefix[i] >= total·k/p.
        let target = (total as u128 * k as u128 / p as u128) as u64;
        let i = partition_point(prefix, target).max(bounds[k - 1] as usize);
        bounds.push(i.min(n) as u32);
    }
    bounds.push(n as u32);
    (0..p).map(|k| bounds[k]..bounds[k + 1]).collect()
}

/// Smallest `i` such that `prefix[i] >= target` (binary search).
fn partition_point(prefix: &[u64], target: u64) -> usize {
    let mut lo = 0usize;
    let mut hi = prefix.len() - 1;
    while lo < hi {
        let mid = (lo + hi) / 2;
        if prefix[mid] >= target {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    lo
}

/// Max/mean cost ratio of a set of ranges (1.0 = perfect balance).
pub fn imbalance(prefix: &[u64], ranges: &[Range<u32>]) -> f64 {
    if ranges.is_empty() {
        return 1.0;
    }
    let costs: Vec<u64> = ranges
        .iter()
        .map(|r| range_cost(prefix, r.start as usize, r.end as usize))
        .collect();
    let max = *costs.iter().max().unwrap() as f64;
    let mean = costs.iter().sum::<u64>() as f64 / costs.len() as f64;
    if mean == 0.0 {
        1.0
    } else {
        max / mean
    }
}

/// Compact owner lookup for consecutive ranges: the `P+1` ascending range
/// bounds. This is the O(P) global metadata a real cluster broadcasts after
/// the partitioning phase — unlike the O(n) [`owner_table`], a rank can hold
/// it without holding anything proportional to the graph, which is why the
/// owned-partition counting paths route through it.
///
/// `owner_of` is a binary search over the bounds; [`OwnerTable::runs`]
/// walks an id-sorted neighbor list as contiguous per-owner runs (sound
/// because partitions are id-intervals), which is simultaneously the
/// surrogate scheme's `LastProc` message-elimination trick and an
/// O(runs · log d) replacement for per-edge owner lookups.
#[derive(Clone, Debug)]
pub struct OwnerTable {
    /// `bounds[j]..bounds[j+1]` = partition `j`'s node range; shared
    /// read-only across ranks (it is public knowledge, like rank ids).
    bounds: Arc<Vec<u32>>,
}

impl OwnerTable {
    /// Build from consecutive ranges tiling `[0, n)`.
    pub fn new(ranges: &[Range<u32>]) -> Self {
        assert!(!ranges.is_empty(), "owner table needs at least one range");
        debug_assert_eq!(ranges[0].start, 0);
        debug_assert!(ranges.windows(2).all(|w| w[0].end == w[1].start));
        let mut bounds = Vec::with_capacity(ranges.len() + 1);
        bounds.push(ranges[0].start);
        bounds.extend(ranges.iter().map(|r| r.end));
        OwnerTable { bounds: Arc::new(bounds) }
    }

    /// Number of partitions `P`.
    #[inline]
    pub fn num_parts(&self) -> usize {
        self.bounds.len() - 1
    }

    /// The rank owning node `v` (the unique half-open range containing it).
    #[inline]
    pub fn owner_of(&self, v: VertexId) -> u32 {
        debug_assert!(v < *self.bounds.last().unwrap());
        (self.bounds.partition_point(|&b| b <= v) - 1) as u32
    }

    /// Partition `j`'s node range.
    #[inline]
    pub fn range_of(&self, j: usize) -> Range<u32> {
        self.bounds[j]..self.bounds[j + 1]
    }

    /// Iterate an **id-sorted** list as maximal contiguous runs of a single
    /// owner, in ascending owner order. Each `(owner, index_range)` item
    /// covers `list[index_range]`; the runs tile the list exactly.
    pub fn runs<'a>(&'a self, list: &'a [VertexId]) -> OwnerRuns<'a> {
        debug_assert!(list.windows(2).all(|w| w[0] < w[1]), "list must be id-sorted");
        OwnerRuns { bounds: &self.bounds, list, at: 0 }
    }
}

/// Iterator over the per-owner runs of an id-sorted list (see
/// [`OwnerTable::runs`]).
pub struct OwnerRuns<'a> {
    bounds: &'a [u32],
    list: &'a [VertexId],
    at: usize,
}

impl Iterator for OwnerRuns<'_> {
    type Item = (u32, Range<usize>);

    fn next(&mut self) -> Option<(u32, Range<usize>)> {
        if self.at >= self.list.len() {
            return None;
        }
        let j = (self.bounds.partition_point(|&b| b <= self.list[self.at]) - 1) as u32;
        let end_id = self.bounds[j as usize + 1];
        let end = self.at + self.list[self.at..].partition_point(|&x| x < end_id);
        let run = self.at..end;
        self.at = end;
        Some((j, run))
    }
}

/// Owner lookup for consecutive ranges: `owner[v] = rank holding v`.
/// O(n) to build, O(1) to query — used by the simulators and the streaming
/// driver, which legitimately operate on the whole graph; the owned
/// §IV counting ranks use the O(P) [`OwnerTable`] instead.
pub fn owner_table(ranges: &[Range<u32>], n: usize) -> Vec<u32> {
    let mut owner = vec![0u32; n];
    for (i, r) in ranges.iter().enumerate() {
        for v in r.clone() {
            owner[v as usize] = i as u32;
        }
    }
    owner
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::cost::prefix_sums;

    #[test]
    fn covers_and_disjoint() {
        let prefix = prefix_sums(&[1; 10]);
        let rs = balanced_ranges(&prefix, 3);
        assert_eq!(rs.len(), 3);
        assert_eq!(rs[0].start, 0);
        assert_eq!(rs.last().unwrap().end, 10);
        for w in rs.windows(2) {
            assert_eq!(w[0].end, w[1].start);
        }
    }

    #[test]
    fn uniform_costs_equal_ranges() {
        let prefix = prefix_sums(&[1; 12]);
        let rs = balanced_ranges(&prefix, 4);
        for r in &rs {
            assert_eq!(r.end - r.start, 3);
        }
    }

    #[test]
    fn skewed_costs_shift_boundaries() {
        // One heavy node at the front: it should sit alone in range 0.
        let costs = [100, 1, 1, 1, 1, 1, 1, 1];
        let prefix = prefix_sums(&costs);
        let rs = balanced_ranges(&prefix, 2);
        assert_eq!(rs[0], 0..1);
        assert_eq!(rs[1], 1..8);
    }

    #[test]
    fn more_parts_than_nodes() {
        let prefix = prefix_sums(&[1, 1]);
        let rs = balanced_ranges(&prefix, 5);
        assert_eq!(rs.len(), 5);
        assert_eq!(rs.last().unwrap().end, 2);
        let nonempty: usize = rs.iter().filter(|r| !r.is_empty()).count();
        assert_eq!(nonempty, 2);
    }

    #[test]
    fn zero_cost_nodes() {
        let prefix = prefix_sums(&[0, 0, 5, 0, 5, 0]);
        let rs = balanced_ranges(&prefix, 2);
        assert!(imbalance(&prefix, &rs) <= 1.01, "{rs:?}");
    }

    #[test]
    fn owner_table_roundtrip() {
        let prefix = prefix_sums(&[1; 7]);
        let rs = balanced_ranges(&prefix, 3);
        let owner = owner_table(&rs, 7);
        for (i, r) in rs.iter().enumerate() {
            for v in r.clone() {
                assert_eq!(owner[v as usize], i as u32);
            }
        }
    }

    #[test]
    fn imbalance_perfect_and_skewed() {
        let prefix = prefix_sums(&[1; 8]);
        let rs = balanced_ranges(&prefix, 4);
        assert!((imbalance(&prefix, &rs) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn owner_table_struct_agrees_with_dense_table() {
        // Lumpy costs so some ranges are empty — the duplicate-bound case
        // the binary search must route around.
        let costs = [0, 100, 0, 0, 1, 1, 100, 0, 0];
        let prefix = prefix_sums(&costs);
        for p in [1, 2, 4, 7, 12] {
            let rs = balanced_ranges(&prefix, p);
            let dense = owner_table(&rs, costs.len());
            let t = OwnerTable::new(&rs);
            assert_eq!(t.num_parts(), p);
            for v in 0..costs.len() as u32 {
                assert_eq!(t.owner_of(v), dense[v as usize], "P={p} v={v}");
                assert!(t.range_of(t.owner_of(v) as usize).contains(&v));
            }
        }
    }

    #[test]
    fn owner_runs_tile_sorted_lists() {
        let prefix = prefix_sums(&[1; 20]);
        let rs = balanced_ranges(&prefix, 6);
        let t = OwnerTable::new(&rs);
        let list: Vec<u32> = vec![0, 1, 4, 5, 9, 10, 11, 18, 19];
        let mut covered = 0usize;
        let mut last_owner = None;
        for (j, run) in t.runs(&list) {
            assert_eq!(run.start, covered, "runs must tile the list");
            assert!(!run.is_empty());
            covered = run.end;
            if let Some(prev) = last_owner {
                assert!(j > prev, "owners ascend over a sorted list");
            }
            last_owner = Some(j);
            for &u in &list[run] {
                assert_eq!(t.owner_of(u), j);
            }
        }
        assert_eq!(covered, list.len());
        assert_eq!(t.runs(&[]).count(), 0);
    }
}
