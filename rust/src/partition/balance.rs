//! Balanced consecutive-range partitioning of the node set (§IV-B).
//!
//! Given a per-node cost vector, split `V` into `P` ranges of *consecutive
//! node ids* whose cost sums are as equal as possible — the paper reuses
//! PATRIC's parallel prefix-sum scheme; on one machine the same boundaries
//! come from a sequential prefix-sum + binary search in `O(n + P log n)`.
//! Consecutiveness is load-bearing: the surrogate algorithm's `LastProc`
//! message-elimination trick requires each partition to be an id-interval.

use crate::partition::cost::range_cost;
use std::ops::Range;

/// Split `[0, n)` into `p` consecutive ranges balancing `prefix` costs:
/// boundary `k` is the smallest index whose cumulative cost reaches
/// `k/p · total`. Ranges may be empty when `p > n` or costs are lumpy.
pub fn balanced_ranges(prefix: &[u64], p: usize) -> Vec<Range<u32>> {
    assert!(p >= 1);
    let n = prefix.len() - 1;
    let total = prefix[n];
    let mut bounds = Vec::with_capacity(p + 1);
    bounds.push(0u32);
    for k in 1..p {
        // Smallest i with prefix[i] >= total·k/p.
        let target = (total as u128 * k as u128 / p as u128) as u64;
        let i = partition_point(prefix, target).max(bounds[k - 1] as usize);
        bounds.push(i.min(n) as u32);
    }
    bounds.push(n as u32);
    (0..p).map(|k| bounds[k]..bounds[k + 1]).collect()
}

/// Smallest `i` such that `prefix[i] >= target` (binary search).
fn partition_point(prefix: &[u64], target: u64) -> usize {
    let mut lo = 0usize;
    let mut hi = prefix.len() - 1;
    while lo < hi {
        let mid = (lo + hi) / 2;
        if prefix[mid] >= target {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    lo
}

/// Max/mean cost ratio of a set of ranges (1.0 = perfect balance).
pub fn imbalance(prefix: &[u64], ranges: &[Range<u32>]) -> f64 {
    if ranges.is_empty() {
        return 1.0;
    }
    let costs: Vec<u64> = ranges
        .iter()
        .map(|r| range_cost(prefix, r.start as usize, r.end as usize))
        .collect();
    let max = *costs.iter().max().unwrap() as f64;
    let mean = costs.iter().sum::<u64>() as f64 / costs.len() as f64;
    if mean == 0.0 {
        1.0
    } else {
        max / mean
    }
}

/// Owner lookup for consecutive ranges: `owner[v] = rank holding v`.
/// O(n) to build, O(1) to query — the surrogate hot loop queries this for
/// every oriented edge.
pub fn owner_table(ranges: &[Range<u32>], n: usize) -> Vec<u32> {
    let mut owner = vec![0u32; n];
    for (i, r) in ranges.iter().enumerate() {
        for v in r.clone() {
            owner[v as usize] = i as u32;
        }
    }
    owner
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::cost::prefix_sums;

    #[test]
    fn covers_and_disjoint() {
        let prefix = prefix_sums(&[1; 10]);
        let rs = balanced_ranges(&prefix, 3);
        assert_eq!(rs.len(), 3);
        assert_eq!(rs[0].start, 0);
        assert_eq!(rs.last().unwrap().end, 10);
        for w in rs.windows(2) {
            assert_eq!(w[0].end, w[1].start);
        }
    }

    #[test]
    fn uniform_costs_equal_ranges() {
        let prefix = prefix_sums(&[1; 12]);
        let rs = balanced_ranges(&prefix, 4);
        for r in &rs {
            assert_eq!(r.end - r.start, 3);
        }
    }

    #[test]
    fn skewed_costs_shift_boundaries() {
        // One heavy node at the front: it should sit alone in range 0.
        let costs = [100, 1, 1, 1, 1, 1, 1, 1];
        let prefix = prefix_sums(&costs);
        let rs = balanced_ranges(&prefix, 2);
        assert_eq!(rs[0], 0..1);
        assert_eq!(rs[1], 1..8);
    }

    #[test]
    fn more_parts_than_nodes() {
        let prefix = prefix_sums(&[1, 1]);
        let rs = balanced_ranges(&prefix, 5);
        assert_eq!(rs.len(), 5);
        assert_eq!(rs.last().unwrap().end, 2);
        let nonempty: usize = rs.iter().filter(|r| !r.is_empty()).count();
        assert_eq!(nonempty, 2);
    }

    #[test]
    fn zero_cost_nodes() {
        let prefix = prefix_sums(&[0, 0, 5, 0, 5, 0]);
        let rs = balanced_ranges(&prefix, 2);
        assert!(imbalance(&prefix, &rs) <= 1.01, "{rs:?}");
    }

    #[test]
    fn owner_table_roundtrip() {
        let prefix = prefix_sums(&[1; 7]);
        let rs = balanced_ranges(&prefix, 3);
        let owner = owner_table(&rs, 7);
        for (i, r) in rs.iter().enumerate() {
            for v in r.clone() {
                assert_eq!(owner[v as usize], i as u32);
            }
        }
    }

    #[test]
    fn imbalance_perfect_and_skewed() {
        let prefix = prefix_sums(&[1; 8]);
        let rs = balanced_ranges(&prefix, 4);
        assert!((imbalance(&prefix, &rs) - 1.0).abs() < 1e-12);
    }
}
