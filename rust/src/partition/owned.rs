//! Fully materialized per-rank partitions — the §IV space-efficiency
//! claim as an *invariant*, not an accounting convention.
//!
//! The seed's `PartitionView` wrapped the full shared `Arc<Oriented>` and
//! enforced the distributed-memory discipline only by panicking on remote
//! access; every "space-efficient" rank silently held the whole graph.
//! [`OwnedPartition`] replaces it with a real per-rank subgraph: its own
//! offsets/targets arrays sliced out of the orientation, an optional
//! overlap row table (PATRIC), a per-partition hub-bitmap index, and the
//! O(P) [`OwnerTable`] — nothing proportional to the rest of the graph.
//! The §IV rank mains take `&OwnedPartition` and their closures no longer
//! capture the `Arc`, so a counting rank *cannot* touch remote lists; it
//! must message for them, exactly as on a real cluster.
//!
//! Layouts (and the byte accounting they pin down):
//!
//! * **Non-overlapping** (ours, [`extract_nonoverlapping`]): rows are the
//!   core range `V_i`; `offsets` has `|V_i|+1` 8-byte entries, `targets`
//!   holds `|E_i'|` 4-byte global ids. Resident bytes =
//!   [`crate::partition::nonoverlap::PartitionSize::bytes`] **exactly** —
//!   the equality `tricount count` gates on.
//! * **Overlapping** (PATRIC, [`extract_overlapping`]): rows are the full
//!   membership `V_i = V_i^c ∪ ⋃_{v∈V_i^c} 𝒩_v`, addressed through a
//!   sorted 4-byte row table `members`. Resident bytes =
//!   [`crate::partition::overlap::OverlapSize::bytes`] exactly — the rank
//!   physically holds the overlap blow-up the paper measures.
//!
//! Hub bitmaps are an *accelerator* riding on top (budgeted by
//! [`crate::adj::hub::HubThreshold`] per partition); they are reported as
//! [`OwnedPartition::accel_bytes`], apart from the CSR bytes the paper's
//! Table II / Fig 7 claim is about.
//!
//! Extraction fans out over the [`crate::par`] scoped-thread helpers (one
//! partition is one work item); each partition is a pure function of
//! `(graph, range)`, so the result is identical at every thread count.

use std::ops::Range;

use crate::adj::hub::{HubIndex, HubThreshold};
use crate::adj::view::NeighborView;
use crate::graph::csr::Csr;
use crate::graph::ordering::Oriented;
use crate::partition::balance::OwnerTable;
use crate::VertexId;

/// A rank's fully materialized partition (see module docs for the two
/// layouts). All node ids in `targets` remain *global*; only row storage
/// is partition-local.
pub struct OwnedPartition {
    /// Core node range `V_i` (id-interval).
    range: Range<u32>,
    /// `Some(ids)` ⇒ overlap layout: sorted row table, one entry per
    /// stored row (superset of `range`). `None` ⇒ rows are exactly `range`.
    members: Option<Vec<VertexId>>,
    /// Row `r` is `targets[offsets[r]..offsets[r+1]]`.
    offsets: Vec<u64>,
    targets: Vec<VertexId>,
    /// Per-partition hub-bitmap index, keyed by local row index.
    hubs: HubIndex,
    /// Global partition bounds (O(P) shared metadata).
    owners: OwnerTable,
}

impl OwnedPartition {
    /// Owned core range `V_i`.
    #[inline]
    pub fn range(&self) -> Range<u32> {
        self.range.clone()
    }

    /// The global partition-bounds table.
    #[inline]
    pub fn owners(&self) -> &OwnerTable {
        &self.owners
    }

    /// Stored rows (core, plus overlap members when present).
    #[inline]
    pub fn num_rows(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Stored oriented edges `|E_i'|`.
    #[inline]
    pub fn num_edges(&self) -> u64 {
        self.targets.len() as u64
    }

    /// Local row index of `v`; panics when this partition does not hold
    /// `N_v` — that data lives on another machine.
    #[inline]
    fn row_index(&self, v: VertexId) -> usize {
        match &self.members {
            None => {
                assert!(
                    self.range.contains(&v),
                    "rank owning {:?} accessed N_{v} (remote data)",
                    self.range
                );
                (v - self.range.start) as usize
            }
            Some(ids) => ids
                .binary_search(&v)
                .unwrap_or_else(|_| panic!("partition of {:?} holds no row for node {v}", self.range)),
        }
    }

    /// `N_v` for a stored row, sorted ascending by global id.
    #[inline]
    pub fn nbrs(&self, v: VertexId) -> &[VertexId] {
        let r = self.row_index(v);
        &self.targets[self.offsets[r] as usize..self.offsets[r + 1] as usize]
    }

    /// Hybrid [`NeighborView`] of a stored row — sorted slice plus the
    /// partition-local hub bitmap when the row qualified.
    #[inline]
    pub fn view(&self, v: VertexId) -> NeighborView<'_> {
        let r = self.row_index(v);
        let list = &self.targets[self.offsets[r] as usize..self.offsets[r + 1] as usize];
        NeighborView::hybrid(list, self.hubs.get(r as VertexId))
    }

    /// Effective degree `d̂_v` of a stored row.
    #[inline]
    pub fn effective_degree(&self, v: VertexId) -> usize {
        let r = self.row_index(v);
        (self.offsets[r + 1] - self.offsets[r]) as usize
    }

    /// Resident bytes of the partition's graph storage: offsets + targets
    /// (+ the overlap row table). Matches the scheme's size prediction
    /// *exactly* — [`crate::partition::nonoverlap::PartitionSize::bytes`]
    /// for the non-overlapping layout,
    /// [`crate::partition::overlap::OverlapSize::bytes`] for the overlap
    /// layout — which is what makes the Table II / Fig 7 numbers measured
    /// facts instead of arithmetic.
    pub fn resident_bytes(&self) -> u64 {
        (self.offsets.len() * 8
            + self.targets.len() * 4
            + self.members.as_ref().map_or(0, |m| m.len() * 4)) as u64
    }

    /// Bytes of the hub-bitmap accelerator riding on this partition
    /// (bounded by the `auto` budget; reported apart from
    /// [`OwnedPartition::resident_bytes`]).
    pub fn accel_bytes(&self) -> u64 {
        self.hubs.bytes()
    }

    /// Assemble a partition from pre-materialized rows — the 2D tile
    /// extractor (`partition::tile2d`) filters each row's targets to its
    /// column block and reuses this exact layout (rows = `range`, no
    /// member table), so tile residency is accounted by the same
    /// [`OwnedPartition::resident_bytes`] rule the 1D layouts are gated
    /// on. `offsets` must have `range.len() + 1` entries rebased to 0.
    pub(crate) fn from_rows(
        range: Range<u32>,
        offsets: Vec<u64>,
        targets: Vec<VertexId>,
        hub: HubThreshold,
        owners: OwnerTable,
    ) -> OwnedPartition {
        debug_assert_eq!(offsets.len(), range.len() + 1);
        debug_assert_eq!(*offsets.last().unwrap() as usize, targets.len());
        let hubs = HubIndex::build(&offsets, &targets, hub);
        OwnedPartition { range, members: None, offsets, targets, hubs, owners }
    }
}

/// Materialize the non-overlapping partition of every range (paper
/// Definition 1): rank `i` gets `N_v` for `v ∈ V_i` and nothing else.
/// Partitions are extracted on [`crate::par::default_threads`] scoped
/// threads, one partition per work item.
pub fn extract_nonoverlapping(
    o: &Oriented,
    ranges: &[Range<u32>],
    hub: HubThreshold,
) -> Vec<OwnedPartition> {
    let owners = OwnerTable::new(ranges);
    let p = ranges.len();
    let t = crate::par::clamp_threads(crate::par::default_threads(), p, 1);
    crate::par::for_ranges(p, t, |_, idx| {
        idx.map(|i| extract_core(o, ranges[i].clone(), hub, owners.clone()))
            .collect::<Vec<_>>()
    })
    .into_iter()
    .flatten()
    .collect()
}

fn extract_core(o: &Oriented, range: Range<u32>, hub: HubThreshold, owners: OwnerTable) -> OwnedPartition {
    let goff = o.offsets();
    let base = goff[range.start as usize];
    let offsets: Vec<u64> = goff[range.start as usize..=range.end as usize]
        .iter()
        .map(|&x| x - base)
        .collect();
    let targets = o.targets()[base as usize..goff[range.end as usize] as usize].to_vec();
    let hubs = HubIndex::build(&offsets, &targets, hub);
    OwnedPartition { range, members: None, offsets, targets, hubs, owners }
}

/// Materialize PATRIC's overlapping partition of every core range: rank
/// `i` gets `N_u` for every `u ∈ V_i^c ∪ ⋃_{v∈V_i^c} 𝒩_v` (full
/// neighborhoods define membership — PATRIC loads complete neighborhoods
/// and orients inside the partition, which is exactly the blow-up
/// [`crate::partition::overlap::overlap_sizes`] predicts and this
/// extraction now physically allocates).
pub fn extract_overlapping(
    g: &Csr,
    o: &Oriented,
    ranges: &[Range<u32>],
    hub: HubThreshold,
) -> Vec<OwnedPartition> {
    debug_assert_eq!(g.num_nodes(), o.num_nodes());
    let owners = OwnerTable::new(ranges);
    let p = ranges.len();
    let t = crate::par::clamp_threads(crate::par::default_threads(), p, 1);
    crate::par::for_ranges(p, t, |_, idx| {
        idx.map(|i| extract_overlap(g, o, ranges[i].clone(), hub, owners.clone()))
            .collect::<Vec<_>>()
    })
    .into_iter()
    .flatten()
    .collect()
}

fn extract_overlap(
    g: &Csr,
    o: &Oriented,
    range: Range<u32>,
    hub: HubThreshold,
    owners: OwnerTable,
) -> OwnedPartition {
    // Ghosts: full-neighborhood contacts outside the core id-interval.
    let mut ghosts: Vec<VertexId> = Vec::new();
    for v in range.clone() {
        ghosts.extend(g.neighbors(v).iter().copied().filter(|u| !range.contains(u)));
    }
    ghosts.sort_unstable();
    ghosts.dedup();
    // Members ascend: ghosts below the core interval, the core, ghosts above.
    let split = ghosts.partition_point(|&u| u < range.start);
    let mut members = Vec::with_capacity(ghosts.len() + range.len());
    members.extend_from_slice(&ghosts[..split]);
    members.extend(range.clone());
    members.extend_from_slice(&ghosts[split..]);

    let mut offsets = Vec::with_capacity(members.len() + 1);
    offsets.push(0u64);
    let mut targets = Vec::new();
    for &u in &members {
        targets.extend_from_slice(o.nbrs(u));
        offsets.push(targets.len() as u64);
    }
    let hubs = HubIndex::build(&offsets, &targets, hub);
    OwnedPartition { range, members: Some(members), offsets, targets, hubs, owners }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CostFn;
    use crate::graph::classic;
    use crate::partition::balance::balanced_ranges;
    use crate::partition::cost::{cost_vector, prefix_sums};
    use crate::partition::nonoverlap::partition_sizes;
    use crate::partition::overlap::overlap_sizes;

    fn setup(p: usize) -> (Csr, Oriented, Vec<Range<u32>>) {
        let g = classic::karate();
        let o = Oriented::from_graph(&g);
        let prefix = prefix_sums(&cost_vector(&o, CostFn::SurrogateNew));
        let ranges = balanced_ranges(&prefix, p);
        (g, o, ranges)
    }

    #[test]
    fn core_rows_match_shared_graph() {
        let (_g, o, ranges) = setup(5);
        let parts = extract_nonoverlapping(&o, &ranges, HubThreshold::Auto);
        assert_eq!(parts.len(), 5);
        let mut edges = 0u64;
        for part in &parts {
            for v in part.range() {
                assert_eq!(part.nbrs(v), o.nbrs(v), "row {v}");
                assert_eq!(part.view(v).list(), o.nbrs(v));
                assert_eq!(part.effective_degree(v), o.effective_degree(v));
            }
            edges += part.num_edges();
        }
        assert_eq!(edges, o.num_edges(), "partitions tile E");
    }

    #[test]
    fn single_partition_is_the_whole_orientation() {
        let (_g, o, _r) = setup(1);
        let ranges = vec![0..o.num_nodes() as u32];
        let parts = extract_nonoverlapping(&o, &ranges, HubThreshold::Off);
        assert_eq!(parts[0].offsets, o.offsets());
        assert_eq!(parts[0].targets, o.targets());
        assert_eq!(parts[0].accel_bytes(), 0);
    }

    #[test]
    fn remote_access_panics_on_core_partition() {
        let (_g, o, ranges) = setup(3);
        let parts = extract_nonoverlapping(&o, &ranges, HubThreshold::Auto);
        let remote = ranges[0].start;
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = parts[1].nbrs(remote);
        }));
        assert!(caught.is_err(), "remote access must panic — the data is not here");
    }

    #[test]
    fn resident_bytes_match_predictions_exactly() {
        let (g, o, ranges) = setup(4);
        let parts = extract_nonoverlapping(&o, &ranges, HubThreshold::Auto);
        for (part, s) in parts.iter().zip(partition_sizes(&o, &ranges)) {
            assert_eq!(part.resident_bytes(), s.bytes());
        }
        let over = extract_overlapping(&g, &o, &ranges, HubThreshold::Auto);
        for (part, s) in over.iter().zip(overlap_sizes(&g, &o, &ranges)) {
            assert_eq!(part.resident_bytes(), s.bytes());
            assert_eq!(part.num_rows() as u64, s.all_nodes);
            assert_eq!(part.num_edges(), s.edges);
        }
    }

    #[test]
    fn overlap_holds_every_referenced_row() {
        let (g, o, ranges) = setup(4);
        let parts = extract_overlapping(&g, &o, &ranges, HubThreshold::Auto);
        for part in &parts {
            for v in part.range() {
                for &u in part.nbrs(v) {
                    // Oriented targets are full-neighborhood contacts, so
                    // the overlap partition must hold their rows locally.
                    assert_eq!(part.nbrs(u), o.nbrs(u), "ghost row {u}");
                }
            }
        }
    }

    #[test]
    fn extraction_identical_at_any_thread_count() {
        let g = crate::gen::pa::preferential_attachment(
            1500,
            8,
            &mut crate::gen::rng::Rng::seeded(9),
        );
        let o = Oriented::from_graph(&g);
        let prefix = prefix_sums(&cost_vector(&o, CostFn::SurrogateNew));
        let ranges = balanced_ranges(&prefix, 7);
        let prev = crate::par::default_threads();
        crate::par::set_default_threads(1);
        let serial = extract_nonoverlapping(&o, &ranges, HubThreshold::Auto);
        crate::par::set_default_threads(4);
        let par = extract_nonoverlapping(&o, &ranges, HubThreshold::Auto);
        crate::par::set_default_threads(prev);
        for (a, b) in serial.iter().zip(&par) {
            assert_eq!(a.offsets, b.offsets);
            assert_eq!(a.targets, b.targets);
            assert_eq!(a.members, b.members);
            assert_eq!(a.resident_bytes(), b.resident_bytes());
            assert_eq!(a.accel_bytes(), b.accel_bytes());
        }
    }

    #[test]
    fn empty_ranges_yield_empty_partitions() {
        let (_g, o, _r) = setup(1);
        let n = o.num_nodes() as u32;
        let ranges = vec![0..0u32, 0..n, n..n];
        let parts = extract_nonoverlapping(&o, &ranges, HubThreshold::Auto);
        assert_eq!(parts[0].num_rows(), 0);
        assert_eq!(parts[0].num_edges(), 0);
        assert_eq!(parts[0].resident_bytes(), 8, "one offset entry");
        assert_eq!(parts[2].num_rows(), 0);
        assert_eq!(parts[1].num_edges(), o.num_edges());
    }
}
