//! Non-overlapping partitions (paper Definition 1) and their memory
//! accounting — the heart of the §IV space-efficiency claim.
//!
//! Partition `G_i(V_i', E_i')`:
//! * `V_i` — a consecutive range of node ids (from [`balanced_ranges`]);
//! * `E_i' = {(v,u) : v ∈ V_i, u ∈ N_v}` — each oriented edge lives in
//!   exactly one partition;
//! * `V_i' = V_i ∪ {u : u ∈ N_v, v ∈ V_i}`.
//!
//! `Σ_i |E_i'| = m`: the partitions tile the edge set, which is exactly why
//! the scheme stays small where PATRIC's overlapping partitions blow up.

use std::ops::Range;
use std::sync::Arc;

use crate::graph::ordering::Oriented;
use crate::VertexId;

/// Size accounting for one non-overlapping partition.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PartitionSize {
    /// Core nodes `|V_i|`.
    pub core_nodes: u64,
    /// All referenced nodes `|V_i'|`.
    pub all_nodes: u64,
    /// Oriented edges stored `|E_i'|`.
    pub edges: u64,
}

impl PartitionSize {
    /// Bytes to store the partition: one 8-byte offset per core node (+1),
    /// one 4-byte target per edge, 4-byte degree per referenced node —
    /// mirroring [`Oriented`]'s layout restricted to the partition.
    pub fn bytes(&self) -> u64 {
        (self.core_nodes + 1) * 8 + self.edges * 4 + self.all_nodes * 4
    }

    /// Megabytes (for Table II / Fig 7 rows).
    pub fn mb(&self) -> f64 {
        self.bytes() as f64 / (1024.0 * 1024.0)
    }
}

/// Compute [`PartitionSize`] for every range. O(n + m) total using a stamp
/// array for `|V_i'|`.
pub fn partition_sizes(o: &Oriented, ranges: &[Range<u32>]) -> Vec<PartitionSize> {
    let n = o.num_nodes();
    let mut stamp = vec![u32::MAX; n];
    ranges
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let i = i as u32;
            let mut all = 0u64;
            let mut edges = 0u64;
            for v in r.clone() {
                if stamp[v as usize] != i {
                    stamp[v as usize] = i;
                    all += 1;
                }
                for &u in o.nbrs(v) {
                    edges += 1;
                    if stamp[u as usize] != i {
                        stamp[u as usize] = i;
                        all += 1;
                    }
                }
            }
            PartitionSize { core_nodes: (r.end - r.start) as u64, all_nodes: all, edges }
        })
        .collect()
}

/// A rank's *view* of its non-overlapping partition.
///
/// Semantically each rank owns only `N_v` for `v ∈ V_i` (Definition 1). In
/// this in-process reproduction the underlying arrays are shared read-only
/// via `Arc` to avoid physically copying the graph per rank; the view
/// **enforces** the distributed-memory discipline by panicking on any
/// access outside the owned range (debug) — the algorithms must fetch
/// remote lists through messages, exactly as on a real cluster. Memory
/// *accounting* (Table II, Figs 7/8) always uses [`partition_sizes`], i.e.
/// what a real rank would allocate, not what this process allocates.
#[derive(Clone)]
pub struct PartitionView {
    graph: Arc<Oriented>,
    range: Range<u32>,
}

impl PartitionView {
    /// Create the view for one rank.
    pub fn new(graph: Arc<Oriented>, range: Range<u32>) -> Self {
        PartitionView { graph, range }
    }

    /// Owned node range `V_i`.
    #[inline]
    pub fn range(&self) -> Range<u32> {
        self.range.clone()
    }

    /// `N_v` for an **owned** node (panics otherwise — that data would live
    /// on another machine).
    #[inline]
    pub fn nbrs(&self, v: VertexId) -> &[VertexId] {
        assert!(
            self.range.contains(&v),
            "rank owning {:?} accessed N_{v} (remote data)",
            self.range
        );
        self.graph.nbrs(v)
    }

    /// Hybrid [`crate::adj::NeighborView`] of an **owned** node — list plus
    /// hub bitmap; same ownership discipline as [`PartitionView::nbrs`].
    #[inline]
    pub fn view(&self, v: VertexId) -> crate::adj::NeighborView<'_> {
        assert!(
            self.range.contains(&v),
            "rank owning {:?} accessed N_{v} (remote data)",
            self.range
        );
        self.graph.view(v)
    }

    /// Effective degree of an owned node.
    #[inline]
    pub fn effective_degree(&self, v: VertexId) -> usize {
        assert!(self.range.contains(&v));
        self.graph.effective_degree(v)
    }

    /// Total node count (global metadata — ids/ranges are public knowledge).
    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.graph.num_nodes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CostFn;
    use crate::graph::classic;
    use crate::partition::balance::balanced_ranges;
    use crate::partition::cost::{cost_vector, prefix_sums};

    fn setup(p: usize) -> (Arc<Oriented>, Vec<Range<u32>>) {
        let g = classic::karate();
        let o = Arc::new(Oriented::from_graph(&g));
        let costs = cost_vector(&o, CostFn::SurrogateNew);
        let ranges = balanced_ranges(&prefix_sums(&costs), p);
        (o, ranges)
    }

    #[test]
    fn edges_tile_the_edge_set() {
        let (o, ranges) = setup(5);
        let sizes = partition_sizes(&o, &ranges);
        let total_edges: u64 = sizes.iter().map(|s| s.edges).sum();
        assert_eq!(total_edges, o.num_edges());
    }

    #[test]
    fn all_nodes_at_least_core() {
        let (o, ranges) = setup(4);
        for s in partition_sizes(&o, &ranges) {
            assert!(s.all_nodes >= s.core_nodes);
            assert!(s.bytes() > 0);
        }
    }

    #[test]
    fn single_partition_is_whole_graph() {
        let (o, ranges) = setup(1);
        let sizes = partition_sizes(&o, &ranges);
        assert_eq!(sizes.len(), 1);
        assert_eq!(sizes[0].edges, o.num_edges());
        // V_0' covers every non-isolated node (karate: all 34 nodes).
        assert_eq!(sizes[0].all_nodes, 34);
    }

    #[test]
    fn view_allows_owned_and_rejects_remote() {
        let (o, ranges) = setup(3);
        let view = PartitionView::new(o, ranges[1].clone());
        let v = ranges[1].start;
        let _ = view.nbrs(v); // owned: fine
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let remote = ranges[0].start;
            let _ = view.nbrs(remote);
        }));
        assert!(caught.is_err(), "remote access must panic");
    }

    #[test]
    fn memory_shrinks_with_more_partitions() {
        // Paper Fig 8: largest partition shrinks as P grows.
        let g = crate::gen::pa::preferential_attachment(
            2000,
            10,
            &mut crate::gen::rng::Rng::seeded(8),
        );
        let o = Arc::new(Oriented::from_graph(&g));
        let costs = cost_vector(&o, CostFn::SurrogateNew);
        let prefix = prefix_sums(&costs);
        let max_bytes = |p: usize| {
            partition_sizes(&o, &balanced_ranges(&prefix, p))
                .iter()
                .map(|s| s.bytes())
                .max()
                .unwrap()
        };
        assert!(max_bytes(16) < max_bytes(4));
        assert!(max_bytes(4) < max_bytes(1));
    }
}
