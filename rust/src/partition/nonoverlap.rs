//! Non-overlapping partitions (paper Definition 1) and their memory
//! accounting — the heart of the §IV space-efficiency claim.
//!
//! Partition `G_i(V_i', E_i')`:
//! * `V_i` — a consecutive range of node ids (from [`balanced_ranges`]);
//! * `E_i' = {(v,u) : v ∈ V_i, u ∈ N_v}` — each oriented edge lives in
//!   exactly one partition;
//! * `V_i' = V_i ∪ {u : u ∈ N_v, v ∈ V_i}`.
//!
//! `Σ_i |E_i'| = m`: the partitions tile the edge set, which is exactly why
//! the scheme stays small where PATRIC's overlapping partitions blow up.
//!
//! [`partition_sizes`] is the arithmetic *prediction*;
//! [`crate::partition::owned::OwnedPartition`] is the matching physical
//! allocation every §IV counting rank actually holds, and the two are
//! gated equal byte-for-byte (`tricount count`, CI smoke).

use std::ops::Range;

use crate::graph::ordering::Oriented;

/// Size accounting for one non-overlapping partition.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PartitionSize {
    /// Core nodes `|V_i|`.
    pub core_nodes: u64,
    /// All referenced nodes `|V_i'|`.
    pub all_nodes: u64,
    /// Oriented edges stored `|E_i'|`.
    pub edges: u64,
}

impl PartitionSize {
    /// Bytes to store the partition: one 8-byte offset per core node (+1)
    /// and one 4-byte target per edge — exactly the arrays
    /// [`crate::partition::owned::OwnedPartition`] materializes, so
    /// `tricount count` can gate measured == predicted byte-for-byte.
    /// Referenced non-core nodes (`V_i' − V_i`) cost nothing beyond their
    /// occurrences inside `targets`: ids are global and the partition
    /// stores no per-ghost state.
    pub fn bytes(&self) -> u64 {
        (self.core_nodes + 1) * 8 + self.edges * 4
    }

    /// Megabytes (for Table II / Fig 7 rows).
    pub fn mb(&self) -> f64 {
        self.bytes() as f64 / (1024.0 * 1024.0)
    }
}

/// Compute [`PartitionSize`] for every range. O(n + m) total using a stamp
/// array for `|V_i'|`.
pub fn partition_sizes(o: &Oriented, ranges: &[Range<u32>]) -> Vec<PartitionSize> {
    let n = o.num_nodes();
    let mut stamp = vec![u32::MAX; n];
    ranges
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let i = i as u32;
            let mut all = 0u64;
            let mut edges = 0u64;
            for v in r.clone() {
                if stamp[v as usize] != i {
                    stamp[v as usize] = i;
                    all += 1;
                }
                for &u in o.nbrs(v) {
                    edges += 1;
                    if stamp[u as usize] != i {
                        stamp[u as usize] = i;
                        all += 1;
                    }
                }
            }
            PartitionSize { core_nodes: (r.end - r.start) as u64, all_nodes: all, edges }
        })
        .collect()
}

/// Smallest `P ≤ max_p` whose largest predicted partition fits `budget`
/// bytes ([`PartitionSize::bytes`]), with ranges balanced on `prefix` —
/// the paper Table II sizing question ("how many machines do I need so
/// every rank fits in memory?"), answered by `tricount count --mem-budget`.
///
/// Doubling then bisection; each probe is an O(n + m) [`partition_sizes`]
/// pass. Assumes the largest-partition size is non-increasing in `P`
/// (true up to boundary rounding); the returned `P` is always one that was
/// directly verified to fit. `None` when even `max_p` partitions cannot
/// fit (some single row exceeds the budget). Hub-bitmap accelerator bytes
/// are *not* in the budget — they are opt-in and separately bounded by the
/// `auto` rule (see `partition/owned.rs`).
pub fn min_procs_for_budget(
    o: &Oriented,
    prefix: &[u64],
    budget: u64,
    max_p: usize,
) -> Option<usize> {
    use crate::partition::balance::balanced_ranges;
    let max_p = max_p.max(1);
    let fits = |p: usize| {
        partition_sizes(o, &balanced_ranges(prefix, p))
            .iter()
            .map(|s| s.bytes())
            .max()
            .unwrap_or(0)
            <= budget
    };
    if fits(1) {
        return Some(1);
    }
    // Bracket the fit boundary by doubling: lo never fits, hi fits.
    let mut lo = 1usize;
    let mut hi = 2usize;
    loop {
        if hi > max_p {
            return None;
        }
        if fits(hi) {
            break;
        }
        if hi == max_p {
            return None;
        }
        lo = hi;
        hi = (hi * 2).min(max_p);
    }
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if fits(mid) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Some(hi)
}

/// Which decomposition a budget search settled on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    /// 1D consecutive ranges ([`balanced_ranges`] over the cost prefix).
    OneD,
    /// 2D process-grid tiles ([`crate::partition::tile2d::layout`]).
    Tile2d,
}

impl std::fmt::Display for Layout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Layout::OneD => "1d",
            Layout::Tile2d => "tile2d",
        })
    }
}

/// [`min_procs_for_budget`] searching **both** decompositions: the 1D
/// consecutive ranges and the 2D tile grid. Returns the smallest fitting
/// `P` and which layout achieved it (ties prefer 1D — same footprint,
/// simpler protocol). Tiles are not monotone in `P` (a remainder rank or
/// an uneven grid can regress one step), so the 2D side is a linear scan
/// with an O(1) lower-bound prune (`Σ tile bytes / (r·c) > budget` ⇒ the
/// largest tile cannot fit either); each surviving probe is an O(n + m)
/// [`crate::partition::tile2d::tile_sizes`] pass. `tricount count
/// --mem-budget` reports both candidates and runs the winner.
pub fn min_procs_for_budget_layouts(
    o: &Oriented,
    prefix: &[u64],
    budget: u64,
    max_p: usize,
) -> Option<(usize, Layout)> {
    use crate::partition::tile2d;
    let max_p = max_p.max(1);
    let one_d = min_procs_for_budget(o, prefix, budget, max_p);
    let cap = one_d.unwrap_or(max_p); // no point scanning past a known fit
    let mut two_d = None;
    let n = o.num_nodes() as u64;
    let m = o.num_edges();
    // Size tiles over the same shuffled labeling the driver will run on.
    let sh = tile2d::shuffled(o);
    for p in 1..=cap {
        let g = tile2d::grid_for(p);
        let active = g.active() as u64;
        // Lower bound: (r·c + sum of per-tile (rows+1)) offsets + m targets
        // spread over the active tiles — if the *average* tile busts the
        // budget, the largest certainly does.
        let avg = ((n + active) * 8 + m * 4) / active;
        if avg > budget {
            continue;
        }
        let l = tile2d::layout(&sh, p);
        let worst = tile2d::tile_sizes(&sh, &l).iter().map(|s| s.bytes()).max().unwrap_or(0);
        if worst <= budget {
            two_d = Some(p);
            break;
        }
    }
    match (one_d, two_d) {
        (Some(a), Some(b)) if b < a => Some((b, Layout::Tile2d)),
        (Some(a), _) => Some((a, Layout::OneD)),
        (None, Some(b)) => Some((b, Layout::Tile2d)),
        (None, None) => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CostFn;
    use crate::graph::classic;
    use crate::partition::balance::balanced_ranges;
    use crate::partition::cost::{cost_vector, prefix_sums};

    fn setup(p: usize) -> (Oriented, Vec<Range<u32>>) {
        let g = classic::karate();
        let o = Oriented::from_graph(&g);
        let costs = cost_vector(&o, CostFn::SurrogateNew);
        let ranges = balanced_ranges(&prefix_sums(&costs), p);
        (o, ranges)
    }

    #[test]
    fn edges_tile_the_edge_set() {
        let (o, ranges) = setup(5);
        let sizes = partition_sizes(&o, &ranges);
        let total_edges: u64 = sizes.iter().map(|s| s.edges).sum();
        assert_eq!(total_edges, o.num_edges());
    }

    #[test]
    fn all_nodes_at_least_core() {
        let (o, ranges) = setup(4);
        for s in partition_sizes(&o, &ranges) {
            assert!(s.all_nodes >= s.core_nodes);
            assert!(s.bytes() > 0);
        }
    }

    #[test]
    fn single_partition_is_whole_graph() {
        let (o, ranges) = setup(1);
        let sizes = partition_sizes(&o, &ranges);
        assert_eq!(sizes.len(), 1);
        assert_eq!(sizes[0].edges, o.num_edges());
        // V_0' covers every non-isolated node (karate: all 34 nodes).
        assert_eq!(sizes[0].all_nodes, 34);
    }

    #[test]
    fn budget_selection_is_minimal_and_verified() {
        let g = crate::gen::pa::preferential_attachment(
            3000,
            12,
            &mut crate::gen::rng::Rng::seeded(11),
        );
        let o = Oriented::from_graph(&g);
        let prefix = prefix_sums(&cost_vector(&o, CostFn::SurrogateNew));
        let max_bytes = |p: usize| {
            partition_sizes(&o, &balanced_ranges(&prefix, p))
                .iter()
                .map(|s| s.bytes())
                .max()
                .unwrap()
        };
        // A budget the whole graph fits in: P = 1.
        assert_eq!(min_procs_for_budget(&o, &prefix, max_bytes(1), 256), Some(1));
        // A budget between P=1 and the P=256 floor: the result fits and
        // sits on the fit boundary (the bisection invariant: `P` fits,
        // `P−1` does not).
        let budget = max_bytes(6);
        let p = min_procs_for_budget(&o, &prefix, budget, 256).unwrap();
        assert!(p > 1);
        assert!(max_bytes(p) <= budget);
        assert!(max_bytes(p - 1) > budget, "P−1 must not fit");
        // Impossible budget: even one node per partition cannot fit 1 byte.
        assert_eq!(min_procs_for_budget(&o, &prefix, 1, 4096), None);
    }

    #[test]
    fn layout_search_never_worse_than_one_d() {
        // The two-layout search dominates the 1D-only answer and its
        // returned candidate is directly verified to fit.
        use crate::partition::tile2d;
        let g = crate::gen::pa::preferential_attachment(
            3000,
            12,
            &mut crate::gen::rng::Rng::seeded(11),
        );
        let o = Oriented::from_graph(&g);
        let prefix = prefix_sums(&cost_vector(&o, CostFn::SurrogateNew));
        let max_1d = |p: usize| {
            partition_sizes(&o, &balanced_ranges(&prefix, p))
                .iter()
                .map(|s| s.bytes())
                .max()
                .unwrap()
        };
        for budget in [max_1d(1), max_1d(3), max_1d(6), max_1d(12)] {
            let (p, layout) = min_procs_for_budget_layouts(&o, &prefix, budget, 256).unwrap();
            let one_d = min_procs_for_budget(&o, &prefix, budget, 256).unwrap();
            assert!(p <= one_d, "budget {budget}: {p} !≤ 1D {one_d}");
            let worst = match layout {
                Layout::OneD => max_1d(p),
                Layout::Tile2d => {
                    let sh = tile2d::shuffled(&o);
                    let l = tile2d::layout(&sh, p);
                    tile2d::tile_sizes(&sh, &l).iter().map(|s| s.bytes()).max().unwrap()
                }
            };
            assert!(worst <= budget, "budget {budget}: winner does not fit");
        }
        // Whole graph fits ⇒ P=1, and both layouts are the same there —
        // the tie goes to 1D.
        assert_eq!(
            min_procs_for_budget_layouts(&o, &prefix, max_1d(1), 256),
            Some((1, Layout::OneD))
        );
        assert_eq!(min_procs_for_budget_layouts(&o, &prefix, 1, 4096), None);
    }

    #[test]
    fn memory_shrinks_with_more_partitions() {
        // Paper Fig 8: largest partition shrinks as P grows.
        let g = crate::gen::pa::preferential_attachment(
            2000,
            10,
            &mut crate::gen::rng::Rng::seeded(8),
        );
        let o = Oriented::from_graph(&g);
        let costs = cost_vector(&o, CostFn::SurrogateNew);
        let prefix = prefix_sums(&costs);
        let max_bytes = |p: usize| {
            partition_sizes(&o, &balanced_ranges(&prefix, p))
                .iter()
                .map(|s| s.bytes())
                .max()
                .unwrap()
        };
        assert!(max_bytes(16) < max_bytes(4));
        assert!(max_bytes(4) < max_bytes(1));
    }
}
